//! Retained scalar reference kernels for the DistillCycle tensor core.
//!
//! These are the original plain loop-nest implementations of the four
//! hot kernels (conv fwd/bwd, dense fwd/bwd), kept verbatim as the
//! **bit-level specification** of the reduction order the blocked
//! [`super::tensor`] microkernels must reproduce: per output element the
//! accumulation runs bias-first then `(ky, kx, ci)` ascending (conv
//! forward), output pixels in `(s, oy, ox)` order then `co` ascending
//! (conv backward), and `d` ascending per class (dense). The equivalence
//! property tests (`tests/prop_invariants.rs`) bit-compare the blocked
//! kernels against these across random shapes, widths and batch sizes;
//! `DistillConfig { threads: 0 }` routes the whole trainer through them
//! (the serial reference path, also the scalar baseline the bench
//! speedups are measured against).
//!
//! Do not "optimize" this module — its value is being obviously-correct
//! scalar code with a fixed f32 operation sequence.

use super::tensor::{Conv, Dense};

/// conv SAME + bias over the active `(cin_a, cout_a)` slice — scalar
/// reference. See [`super::tensor::conv_fwd`] for the blocked twin.
pub fn conv_fwd(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    conv: &Conv,
    cin_a: usize,
    cout_a: usize,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * h * w * cin_a);
    let k = conv.k;
    let pad = k / 2;
    let mut out = vec![0.0f32; n * h * w * cout_a];
    for s in 0..n {
        for oy in 0..h {
            for ox in 0..w {
                let obase = ((s * h + oy) * w + ox) * cout_a;
                for co in 0..cout_a {
                    let mut acc = conv.b[co];
                    for ky in 0..k {
                        let iy = oy + ky;
                        if iy < pad || iy - pad >= h {
                            continue;
                        }
                        let iy = iy - pad;
                        for kx in 0..k {
                            let ix = ox + kx;
                            if ix < pad || ix - pad >= w {
                                continue;
                            }
                            let ix = ix - pad;
                            let ibase = ((s * h + iy) * w + ix) * cin_a;
                            for ci in 0..cin_a {
                                acc += x[ibase + ci] * conv.w[conv.widx(ky, kx, ci, co)];
                            }
                        }
                    }
                    out[obase + co] = acc;
                }
            }
        }
    }
    out
}

/// conv SAME backward — scalar reference. Accumulates into the full-size
/// `gw`/`gb` buffers (active slice only) and returns `dx` (empty when
/// `compute_dx` is false).
#[allow(clippy::too_many_arguments)]
pub fn conv_bwd(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    conv: &Conv,
    cin_a: usize,
    cout_a: usize,
    dpre: &[f32],
    gw: &mut [f32],
    gb: &mut [f32],
    compute_dx: bool,
) -> Vec<f32> {
    debug_assert_eq!(gw.len(), conv.w.len());
    debug_assert_eq!(gb.len(), conv.b.len());
    let k = conv.k;
    let pad = k / 2;
    let mut dx = vec![0.0f32; if compute_dx { n * h * w * cin_a } else { 0 }];
    for s in 0..n {
        for oy in 0..h {
            for ox in 0..w {
                let obase = ((s * h + oy) * w + ox) * cout_a;
                for co in 0..cout_a {
                    let g = dpre[obase + co];
                    if g == 0.0 {
                        continue;
                    }
                    gb[co] += g;
                    for ky in 0..k {
                        let iy = oy + ky;
                        if iy < pad || iy - pad >= h {
                            continue;
                        }
                        let iy = iy - pad;
                        for kx in 0..k {
                            let ix = ox + kx;
                            if ix < pad || ix - pad >= w {
                                continue;
                            }
                            let ix = ix - pad;
                            let ibase = ((s * h + iy) * w + ix) * cin_a;
                            for ci in 0..cin_a {
                                gw[conv.widx(ky, kx, ci, co)] += x[ibase + ci] * g;
                                if compute_dx {
                                    dx[ibase + ci] += conv.w[conv.widx(ky, kx, ci, co)] * g;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    dx
}

/// Dense head forward — scalar reference (`[n, dim] x [dim, classes] + b`
/// with the zero-row skip the blocked kernel also takes).
pub fn fc_fwd(x: &[f32], n: usize, head: &Dense) -> Vec<f32> {
    let (dim, classes) = (head.dim, head.classes);
    debug_assert_eq!(x.len(), n * dim);
    let mut out = vec![0.0f32; n * classes];
    for s in 0..n {
        let row = &x[s * dim..(s + 1) * dim];
        let o = &mut out[s * classes..(s + 1) * classes];
        o.copy_from_slice(&head.b);
        for (d, &xv) in row.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &head.w[d * classes..(d + 1) * classes];
            for (c, &wv) in wrow.iter().enumerate() {
                o[c] += xv * wv;
            }
        }
    }
    out
}

/// Dense head backward — scalar reference.
pub fn fc_bwd(
    x: &[f32],
    n: usize,
    head: &Dense,
    dlogits: &[f32],
    gw: &mut [f32],
    gb: &mut [f32],
) -> Vec<f32> {
    let (dim, classes) = (head.dim, head.classes);
    let mut dx = vec![0.0f32; n * dim];
    for s in 0..n {
        let row = &x[s * dim..(s + 1) * dim];
        let g = &dlogits[s * classes..(s + 1) * classes];
        for (c, &gv) in g.iter().enumerate() {
            gb[c] += gv;
        }
        for (d, &xv) in row.iter().enumerate() {
            let wrow = &head.w[d * classes..(d + 1) * classes];
            let mut acc = 0.0f32;
            for (c, &gv) in g.iter().enumerate() {
                gw[d * classes + c] += xv * gv;
                acc += wrow[c] * gv;
            }
            dx[s * dim + d] = acc;
        }
    }
    dx
}
