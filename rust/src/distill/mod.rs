//! DistillCycle — joint full-model + subnetwork training with
//! hierarchical knowledge distillation (Sec. IV-B, Algorithm 2).
//!
//! The third ForgeMorph pillar: every NeuroMorph execution path must be
//! an *accurate* standalone network, so the trainer jointly optimizes
//! the full model and all of its (depth, width) subnetworks:
//!
//! 1. **Grow progressively** — stage `i` appends Layer-Block `B_i`
//!    (Eq. 19) and trains the depth-`i` network as the current *teacher*
//!    with plain cross-entropy (Eq. 16).
//! 2. **Train in cycles** — within each stage, teacher epochs alternate
//!    with *student* phases over the cycled morph paths: the previous
//!    depth (the teacher's depth-wise parent branch) and the stage's
//!    reduced-width variants. A final head-only *calibration* pass
//!    re-aligns every subnetwork head with the finished trunk.
//! 3. **Hierarchical KD** — students minimize
//!    `λ·CE + (1−λ)·τ²·KL(σ(t/τ) ‖ σ(s/τ))` against their parent path's
//!    fresh logits (Eqs. 17–18).
//! 4. **LR decay for stability** — block `j < i` trains at `α·γ^(i−1−j)`
//!    (Eq. 20) against catastrophic forgetting; fresh heads are exempt.
//!
//! The engine is the Rust twin of `python/compile/train.py` (pinned
//! against its reference behavior by `tests/distill_reference.rs`) built
//! on the deterministic [`tensor`] core: seeded, no allocator- or
//! thread-count-dependent numerics — two runs with the same seed produce
//! **byte-identical** [`AccuracyProfile`] JSON, for *any*
//! [`DistillConfig::threads`] value. The KD cycles themselves mutate the
//! shared trunk and stay sequential; the phases where ladder paths are
//! truly independent — the final head-only calibration against the
//! frozen trunk, and the accuracy sweep — fan out across a scoped worker
//! pool (the `dse::run` pattern) with RNG schedules pre-drawn on the
//! main thread and results merged in ladder order, so the worker count
//! changes wall-clock only, never a single bit of output.
//!
//! The output feeds the rest of the pipeline:
//! * [`AccuracyProfile::apply_to`] persists trained accuracies into the
//!   runtime manifest ([`crate::runtime::Manifest`]);
//! * [`AccuracyProfile::morph_paths`] hands the ladder to
//!   [`crate::dse`] as the third NSGA-II objective and to the
//!   [`crate::morph::governor`] as its accuracy-floor registry.

pub mod data;
pub mod tensor;
pub mod tensor_ref;

use std::collections::BTreeMap;

use crate::morph::MorphPath;
use crate::quant::QParams;
use crate::runtime::ModelManifest;
use crate::sim::GateMask;
use crate::util::json::Json;
use crate::util::rng::Rng;

use data::Dataset;
use tensor::{Conv, Dense, Scratch};

/// Errors from spec construction / profile parsing.
#[derive(Debug, Clone, PartialEq)]
pub enum DistillError {
    /// model cannot be distilled (not a chain of conv blocks)
    Unsupported(String),
    /// a ladder width outside the deployable gate range
    Width(usize),
    /// AccuracyProfile JSON malformed
    Profile(String),
}

impl std::fmt::Display for DistillError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DistillError::Unsupported(m) => write!(f, "distill: unsupported model: {m}"),
            DistillError::Width(pct) => write!(
                f,
                "distill: ladder width {pct}% outside the deployable range (10..=100)"
            ),
            DistillError::Profile(m) => write!(f, "accuracy profile: {m}"),
        }
    }
}

impl std::error::Error for DistillError {}

/// One morphable execution path: the first `depth` blocks at `width_pct`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PathSpec {
    pub depth: usize,
    pub width_pct: usize,
}

impl PathSpec {
    pub fn name(&self) -> String {
        format!("d{}_w{}", self.depth, self.width_pct)
    }
}

/// Architecture descriptor of the morphable `a-2a-3a...` pipeline
/// (the Rust twin of `model.py::ModelSpec`).
#[derive(Debug, Clone)]
pub struct DistillSpec {
    pub name: String,
    /// input (h, w, c)
    pub input: (usize, usize, usize),
    pub num_classes: usize,
    /// per Layer-Block conv filter counts
    pub filters: Vec<usize>,
    pub kernel: usize,
    /// width ladder the morph layer exposes; every depth trains at each
    /// of these (`100` is implicit and always present)
    pub widths: Vec<usize>,
}

fn width_of(f: usize, pct: usize) -> usize {
    ((f * pct) / 100).max(1)
}

impl DistillSpec {
    /// Validated constructor: every ladder width must be deployable on
    /// the gate fabric (the same `GateMask::try_width` boundary the
    /// morph/governor layer enforces) — the sampler is gate-aligned by
    /// construction.
    pub fn new(
        name: impl Into<String>,
        input: (usize, usize, usize),
        num_classes: usize,
        filters: Vec<usize>,
        widths: Vec<usize>,
    ) -> Result<DistillSpec, DistillError> {
        for &pct in &widths {
            GateMask::try_width(pct as f64 / 100.0).map_err(|_| DistillError::Width(pct))?;
        }
        if filters.is_empty() {
            return Err(DistillError::Unsupported("no conv blocks".into()));
        }
        Ok(DistillSpec {
            name: name.into(),
            input,
            num_classes,
            filters,
            kernel: 3,
            widths,
        })
    }

    /// Derive the spec from a small a-2a-3a zoo chain. The trained twin
    /// is `model.py`'s Layer-Block template — conv3x3(SAME, stride 1) +
    /// ReLU + maxpool2 per block — so any network whose convs deviate
    /// from that template (strides, other kernels, depthwise blocks,
    /// branchy edges) is rejected rather than silently trained as a
    /// different architecture. (Pooling follows the L2 reference: every
    /// block pools while `min(h, w) >= 2`, even where an L3 descriptor
    /// skips a trailing pool — the training model is `model.py`'s, by
    /// design.)
    pub fn from_network(net: &crate::graph::Network) -> Result<DistillSpec, DistillError> {
        use crate::graph::LayerKind;
        let mut filters = Vec::new();
        for l in &net.layers {
            match &l.kind {
                LayerKind::Conv { filters: f, k, stride, .. } => {
                    if *k != 3 || *stride != 1 {
                        return Err(DistillError::Unsupported(format!(
                            "{}: conv '{}' is {k}x{k}/s{stride}; the DistillCycle \
                             Layer-Block template is 3x3/s1",
                            net.name, l.name
                        )));
                    }
                    filters.push(*f);
                }
                LayerKind::DwConv { .. } => {
                    return Err(DistillError::Unsupported(format!(
                        "{}: depthwise blocks are not morphable depth prefixes",
                        net.name
                    )))
                }
                _ => {}
            }
        }
        for &(s, d) in &net.connections {
            // a chain has exactly the implicit (i, i+1) edges
            if d != s + 1 {
                return Err(DistillError::Unsupported(format!(
                    "{}: branchy graph (edge {s}->{d}); DistillCycle trains chains",
                    net.name
                )));
            }
        }
        let classes = crate::backend::net_num_classes(net);
        DistillSpec::new(net.name.clone(), net.input_dims(), classes, filters, vec![50])
    }

    /// Tiny 3-block spec shared by tests, the report harness and the
    /// bench: fast enough to train in a debug-build test, deep enough to
    /// exercise every DistillCycle phase (3 depths × 2 widths).
    pub fn tiny() -> DistillSpec {
        DistillSpec::new("tiny3", (16, 16, 1), 4, vec![8, 12, 16], vec![50]).unwrap()
    }

    /// The gate-aligned subnetwork ladder: the full `GateMask` widths ×
    /// depth-ladder cross product — every depth prefix at full width and
    /// at each reduced ladder width, exactly the execution paths the
    /// morph layer can gate. Training the reduced widths at *every*
    /// depth shapes the sliced filter prefixes from the first stage on
    /// (a half-width path that only ever trains at full depth inherits
    /// channels co-adapted to full-width use and underperforms —
    /// measured, not hypothetical).
    pub fn paths(&self) -> Vec<PathSpec> {
        let d = self.filters.len();
        let mut out: Vec<PathSpec> = Vec::new();
        for depth in 1..=d {
            out.push(PathSpec { depth, width_pct: 100 });
            for &pct in self.widths.iter().filter(|&&p| p != 100) {
                out.push(PathSpec { depth, width_pct: pct });
            }
        }
        out
    }

    pub fn full_path(&self) -> PathSpec {
        PathSpec { depth: self.filters.len(), width_pct: 100 }
    }

    /// (h, w) of the feature map after `depth` Layer-Blocks.
    pub fn feature_shape(&self, depth: usize) -> (usize, usize) {
        let (mut h, mut w, _) = self.input;
        for _ in 0..depth {
            if h.min(w) >= 2 {
                h /= 2;
                w /= 2;
            }
        }
        (h, w)
    }

    /// FC head input size: the flattened streamed feature map (Eq. 5).
    fn head_dim(&self, path: PathSpec) -> usize {
        let (h, w) = self.feature_shape(path.depth);
        h * w * width_of(self.filters[path.depth - 1], path.width_pct)
    }

    /// Active parameters on one path.
    pub fn count_params(&self, path: PathSpec) -> usize {
        let k = self.kernel;
        let mut cin = self.input.2;
        let mut total = 0;
        for i in 0..path.depth {
            let cout = width_of(self.filters[i], path.width_pct);
            total += k * k * cin * cout + cout;
            cin = cout;
        }
        total + self.head_dim(path) * self.num_classes + self.num_classes
    }

    /// MACs per frame on one path (conv + head).
    pub fn count_macs(&self, path: PathSpec) -> usize {
        let k = self.kernel;
        let (mut h, mut w, mut cin) = self.input;
        let mut total = 0;
        for i in 0..path.depth {
            let cout = width_of(self.filters[i], path.width_pct);
            total += h * w * k * k * cin * cout;
            if h.min(w) >= 2 {
                h /= 2;
                w /= 2;
            }
            cin = cout;
        }
        total + h * w * cin * self.num_classes
    }

    /// Seeded synthetic dataset with this spec's geometry. Noise/shift
    /// are gentler than the Python reference's MNIST-scale settings:
    /// tiny images average far less noise per feature, and these values
    /// keep every ladder path comfortably above chance on the small
    /// training budgets the offline tests/CI use.
    pub fn dataset(&self, n_train: usize, n_test: usize, seed: u64) -> Dataset {
        let (h, w, c) = self.input;
        data::make_dataset(&self.name, h, w, c, self.num_classes, n_train, n_test, 0.35, 1, seed)
    }
}

/// DistillCycle hyperparameters (Algorithm 2's `params` input) —
/// mirrors `train.py::TrainConfig`.
#[derive(Debug, Clone)]
pub struct DistillConfig {
    /// α0
    pub lr: f32,
    pub momentum: f32,
    /// λ — CE vs KD mix (Eq. 18)
    pub lam: f32,
    /// τ — distillation temperature (Eq. 17)
    pub tau: f32,
    /// γ — per-block LR decay (Eq. 20)
    pub gamma: f32,
    pub epochs_per_stage: usize,
    pub batch: usize,
    /// α shrink between growth stages (Alg. 2's α ← α/10, softened)
    pub lr_stage_decay: f32,
    pub seed: u64,
    /// quantization-aware KD: fake-quant every block activation at this
    /// bit width during training (straight-through gradients)
    pub qat_bits: Option<u32>,
    /// worker threads for the independent ladder phases (head
    /// calibration, accuracy sweep): `0` routes everything through the
    /// scalar [`tensor_ref`] kernels serially (the reference/baseline
    /// path), `>= 1` uses the blocked [`tensor`] microkernels with up to
    /// N scoped workers. Output is byte-identical for every value — the
    /// blocked kernels reproduce the reference reduction order and the
    /// fan-out only covers paths that share no trainable state.
    pub threads: usize,
    /// optional span/event sink (`distill --trace-out`): `Some` records
    /// one virtual-clock KD span per [`LossRecord`] after training (the
    /// history is built on the main thread in a fixed order, so traces
    /// are byte-identical across `threads`); `None` records nothing and
    /// training output is identical either way.
    pub trace: Option<std::sync::Arc<crate::obs::TraceSink>>,
}

impl Default for DistillConfig {
    fn default() -> Self {
        DistillConfig {
            // higher than train.py's 0.02: the offline regime trains on a
            // few hundred samples for a handful of epochs, and the larger
            // step (with the same norm-5 clip) is what reaches useful
            // accuracy inside that budget
            lr: 0.05,
            momentum: 0.9,
            lam: 0.5,
            tau: 3.0,
            gamma: 0.5,
            epochs_per_stage: 3,
            batch: 64,
            lr_stage_decay: 0.6,
            seed: 0,
            qat_bits: None,
            threads: 1,
            trace: None,
        }
    }
}

/// Trainable parameters: shared conv blocks + one head per morph path.
#[derive(Debug, Clone)]
pub struct Params {
    pub blocks: Vec<Conv>,
    pub heads: BTreeMap<String, Dense>,
}

/// He-init conv blocks + one FC head per morph path (fixed draw order:
/// blocks first, then heads in ladder order — reproducible).
pub fn init_params(spec: &DistillSpec, seed: u64) -> Params {
    let mut rng = Rng::new(seed);
    let k = spec.kernel;
    let mut blocks = Vec::with_capacity(spec.filters.len());
    let mut cin = spec.input.2;
    for &f in &spec.filters {
        let fan_in = (k * k * cin) as f64;
        let scale = (2.0 / fan_in).sqrt();
        let w: Vec<f32> = (0..k * k * cin * f).map(|_| (rng.gauss() * scale) as f32).collect();
        blocks.push(Conv { w, b: vec![0.0; f], k, cin, cout: f });
        cin = f;
    }
    let mut heads = BTreeMap::new();
    for path in spec.paths() {
        let dim = spec.head_dim(path);
        let scale = (1.0 / dim as f64).sqrt();
        let w: Vec<f32> =
            (0..dim * spec.num_classes).map(|_| (rng.gauss() * scale) as f32).collect();
        heads.insert(
            path.name(),
            Dense { w, b: vec![0.0; spec.num_classes], dim, classes: spec.num_classes },
        );
    }
    Params { blocks, heads }
}

/// SGD velocity mirroring the parameter layout.
struct Velocity {
    blocks: Vec<(Vec<f32>, Vec<f32>)>,
    heads: BTreeMap<String, (Vec<f32>, Vec<f32>)>,
}

impl Velocity {
    fn zeros(p: &Params) -> Velocity {
        Velocity {
            blocks: p
                .blocks
                .iter()
                .map(|b| (vec![0.0; b.w.len()], vec![0.0; b.b.len()]))
                .collect(),
            heads: p
                .heads
                .iter()
                .map(|(n, h)| (n.clone(), (vec![0.0; h.w.len()], vec![0.0; h.b.len()])))
                .collect(),
        }
    }

    /// Velocity reset at every phase switch: teacher and students
    /// optimize different losses over shared blocks, and carrying
    /// momentum across the switch destabilizes the cycle (train.py).
    fn zero(&mut self) {
        for (w, b) in &mut self.blocks {
            w.iter_mut().for_each(|v| *v = 0.0);
            b.iter_mut().for_each(|v| *v = 0.0);
        }
        for (w, b) in self.heads.values_mut() {
            w.iter_mut().for_each(|v| *v = 0.0);
            b.iter_mut().for_each(|v| *v = 0.0);
        }
    }
}

/// Per-worker kernel context: selects the tensor core (blocked
/// microkernels vs the retained scalar reference) and owns the reusable
/// [`Scratch`] the blocked kernels pack into — the train loop allocates
/// no im2col/transpose buffers per step. Both cores produce bit-identical
/// results (the property suite's central claim); `reference` exists so
/// `threads: 0` stays an auditable, obviously-correct serial baseline.
struct KernelCtx {
    reference: bool,
    sc: Scratch,
}

impl KernelCtx {
    fn new(reference: bool) -> KernelCtx {
        KernelCtx { reference, sc: Scratch::new() }
    }

    fn for_cfg(cfg: &DistillConfig) -> KernelCtx {
        KernelCtx::new(cfg.threads == 0)
    }

    fn conv_fwd(
        &mut self,
        x: &[f32],
        n: usize,
        h: usize,
        w: usize,
        conv: &Conv,
        cin_a: usize,
        cout_a: usize,
    ) -> Vec<f32> {
        if self.reference {
            tensor_ref::conv_fwd(x, n, h, w, conv, cin_a, cout_a)
        } else {
            let mut out = Vec::new();
            tensor::conv_fwd_scratch(&mut self.sc, x, n, h, w, conv, cin_a, cout_a, &mut out);
            out
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn conv_bwd(
        &mut self,
        x: &[f32],
        n: usize,
        h: usize,
        w: usize,
        conv: &Conv,
        cin_a: usize,
        cout_a: usize,
        dpre: &[f32],
        gw: &mut [f32],
        gb: &mut [f32],
        compute_dx: bool,
    ) -> Vec<f32> {
        if self.reference {
            tensor_ref::conv_bwd(x, n, h, w, conv, cin_a, cout_a, dpre, gw, gb, compute_dx)
        } else {
            let mut dx = Vec::new();
            tensor::conv_bwd_scratch(
                &mut self.sc,
                x,
                n,
                h,
                w,
                conv,
                cin_a,
                cout_a,
                dpre,
                gw,
                gb,
                compute_dx,
                &mut dx,
            );
            dx
        }
    }

    fn fc_fwd(&mut self, x: &[f32], n: usize, head: &Dense) -> Vec<f32> {
        if self.reference {
            tensor_ref::fc_fwd(x, n, head)
        } else {
            tensor::fc_fwd(x, n, head)
        }
    }

    fn fc_bwd(
        &mut self,
        x: &[f32],
        n: usize,
        head: &Dense,
        dlogits: &[f32],
        gw: &mut [f32],
        gb: &mut [f32],
    ) -> Vec<f32> {
        if self.reference {
            tensor_ref::fc_bwd(x, n, head, dlogits, gw, gb)
        } else {
            let mut dx = Vec::new();
            tensor::fc_bwd_scratch(&mut self.sc, x, n, head, dlogits, gw, gb, &mut dx);
            dx
        }
    }
}

/// Per-leaf learning rates — Eq. 20: block `j` at stage `i` trains at
/// `base_lr * gamma^max(0, stage-1-j)`; heads are fresh capacity (never
/// "earlier layers"), so they train at `head_lr`.
#[derive(Debug, Clone, PartialEq)]
pub struct LrTree {
    pub blocks: Vec<f32>,
    pub head: f32,
}

pub fn lr_tree(spec: &DistillSpec, stage: usize, base_lr: f32, gamma: f32, head_lr: f32) -> LrTree {
    let blocks = (0..spec.filters.len())
        .map(|j| base_lr * gamma.powi((stage as i32 - 1 - j as i32).max(0)))
        .collect();
    LrTree { blocks, head: head_lr }
}

/// Mean CE over the batch (Eq. 16).
pub fn cross_entropy(logits: &[f32], classes: usize, y: &[u32]) -> f64 {
    let n = y.len();
    let mut total = 0.0f64;
    for s in 0..n {
        let row = &logits[s * classes..(s + 1) * classes];
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
        let lse = row.iter().map(|&v| ((v as f64) - m).exp()).sum::<f64>().ln() + m;
        total += lse - row[y[s] as usize] as f64;
    }
    total / n as f64
}

/// τ²-scaled KL between softened teacher/student outputs (Eq. 17).
pub fn kd_loss(student: &[f32], teacher: &[f32], classes: usize, tau: f32) -> f64 {
    let n = student.len() / classes;
    let mut total = 0.0f64;
    for s in 0..n {
        let sl = &student[s * classes..(s + 1) * classes];
        let tl = &teacher[s * classes..(s + 1) * classes];
        let t = softmax_f64(tl, tau);
        let sm = softmax_f64(sl, tau);
        for c in 0..classes {
            let tc = t[c].max(1e-9);
            total += tc * (tc.ln() - sm[c].max(1e-12).ln());
        }
    }
    (tau as f64) * (tau as f64) * total / n as f64
}

fn softmax_f64(row: &[f32], tau: f32) -> Vec<f64> {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f64> = row.iter().map(|&v| (((v - m) / tau) as f64).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

/// Per-block forward cache for backprop.
struct BlockAct {
    h_in: usize,
    w_in: usize,
    cin: usize,
    cout: usize,
    /// pre-activation (h_in × w_in × cout)
    pre: Vec<f32>,
    /// post-ReLU (and post fake-quant under QAT)
    post: Vec<f32>,
    /// pool argmax when this block pooled
    pool_idx: Option<Vec<u32>>,
    /// block output (post-pool) — next block's input
    out: Vec<f32>,
    h_out: usize,
    w_out: usize,
}

/// Forward one morph path with caches. `x` is `[n, h, w, c]`.
fn forward_cached(
    ctx: &mut KernelCtx,
    params: &Params,
    spec: &DistillSpec,
    path: PathSpec,
    x: &[f32],
    n: usize,
    qat: Option<u32>,
) -> (Vec<BlockAct>, Vec<f32>) {
    let (mut h, mut w, mut cin_a) = spec.input;
    let mut acts: Vec<BlockAct> = Vec::with_capacity(path.depth);
    for i in 0..path.depth {
        let cur: &[f32] = if i == 0 { x } else { &acts[i - 1].out };
        let conv = &params.blocks[i];
        let cout_a = width_of(spec.filters[i], path.width_pct);
        let pre = ctx.conv_fwd(cur, n, h, w, conv, cin_a, cout_a);
        let mut post = tensor::relu(&pre);
        if let Some(bits) = qat {
            fake_quant_tensor(&mut post, bits);
        }
        let (out, pool_idx, h_out, w_out) = if h.min(w) >= 2 {
            let (o, idx) = tensor::pool_fwd(&post, n, h, w, cout_a);
            (o, Some(idx), h / 2, w / 2)
        } else {
            (post.clone(), None, h, w)
        };
        acts.push(BlockAct {
            h_in: h,
            w_in: w,
            cin: cin_a,
            cout: cout_a,
            pre,
            post,
            pool_idx,
            out,
            h_out,
            w_out,
        });
        h = h_out;
        w = w_out;
        cin_a = cout_a;
    }
    let feats = &acts.last().expect("depth >= 1").out;
    let logits = ctx.fc_fwd(feats, n, &params.heads[&path.name()]);
    (acts, logits)
}

/// Symmetric per-tensor fake-quant of an activation tensor (the same
/// round trip the Pallas kernels apply in their MAC epilogue —
/// [`crate::quant::QParams`]). Gradients use the straight-through
/// estimator: the backward pass treats this as identity.
fn fake_quant_tensor(t: &mut [f32], bits: u32) {
    let amax = t.iter().fold(0.0f64, |m, &v| m.max((v as f64).abs())).max(1e-8);
    let p = QParams { scale: amax / QParams::qmax(bits) as f64, bits };
    for v in t.iter_mut() {
        *v = p.fake_quant(*v as f64) as f32;
    }
}

/// Inference-only forward (teacher logits / accuracy evaluation): the
/// same arithmetic as [`forward_cached`] — bit-identical logits — with
/// no backprop caches, no argmax bookkeeping and in-place ReLU. This is
/// the hot inner loop of every student/calibration phase (teacher
/// logits are recomputed per batch).
pub fn forward(
    params: &Params,
    spec: &DistillSpec,
    path: PathSpec,
    x: &[f32],
    n: usize,
    qat: Option<u32>,
) -> Vec<f32> {
    forward_with(&mut KernelCtx::new(false), params, spec, path, x, n, qat)
}

/// [`forward`] through a caller-held [`KernelCtx`] — the hot loops reuse
/// one context (and its im2col scratch) across every batch they run.
fn forward_with(
    ctx: &mut KernelCtx,
    params: &Params,
    spec: &DistillSpec,
    path: PathSpec,
    x: &[f32],
    n: usize,
    qat: Option<u32>,
) -> Vec<f32> {
    debug_assert!(path.depth >= 1);
    let (mut h, mut w, mut cin_a) = spec.input;
    let mut cur: Vec<f32> = Vec::new();
    for i in 0..path.depth {
        let xin: &[f32] = if i == 0 { x } else { &cur };
        let cout_a = width_of(spec.filters[i], path.width_pct);
        let mut act = ctx.conv_fwd(xin, n, h, w, &params.blocks[i], cin_a, cout_a);
        // in-place ReLU, same -0.0 normalization as tensor::relu
        for v in act.iter_mut() {
            *v = if *v > 0.0 { *v } else { 0.0 };
        }
        if let Some(bits) = qat {
            fake_quant_tensor(&mut act, bits);
        }
        if h.min(w) >= 2 {
            cur = tensor::pool_max(&act, n, h, w, cout_a);
            h /= 2;
            w /= 2;
        } else {
            cur = act;
        }
        cin_a = cout_a;
    }
    ctx.fc_fwd(&cur, n, &params.heads[&path.name()])
}

/// Gradients for one step (full-size buffers; zero outside active slices).
struct Grads {
    blocks: Vec<(Vec<f32>, Vec<f32>)>,
    head_w: Vec<f32>,
    head_b: Vec<f32>,
}

/// One SGD step on one morph path; optionally distilling (Eq. 18).
/// Returns the scalar loss.
#[allow(clippy::too_many_arguments)]
fn train_step(
    ctx: &mut KernelCtx,
    params: &mut Params,
    vel: &mut Velocity,
    spec: &DistillSpec,
    path: PathSpec,
    x: &[f32],
    y: &[u32],
    teacher_logits: Option<&[f32]>,
    cfg: &DistillConfig,
    lrs: &LrTree,
) -> f64 {
    let n = y.len();
    let classes = spec.num_classes;
    let (acts, logits) = forward_cached(ctx, params, spec, path, x, n, cfg.qat_bits);

    // loss + dlogits
    let ce = cross_entropy(&logits, classes, y);
    let mut loss = ce;
    let mut dlogits = vec![0.0f32; n * classes];
    let ce_w = if teacher_logits.is_some() { cfg.lam } else { 1.0 };
    for s in 0..n {
        let p = softmax_f64(&logits[s * classes..(s + 1) * classes], 1.0);
        for c in 0..classes {
            let onehot = if c == y[s] as usize { 1.0 } else { 0.0 };
            dlogits[s * classes + c] = ce_w * (((p[c] - onehot) / n as f64) as f32);
        }
    }
    if let Some(t_logits) = teacher_logits {
        let kd = kd_loss(&logits, t_logits, classes, cfg.tau);
        loss = (cfg.lam as f64) * ce + (1.0 - cfg.lam as f64) * kd;
        // dKD/dS = τ·(σ(s/τ) − σ(t/τ))/N per element
        for s in 0..n {
            let sp = softmax_f64(&logits[s * classes..(s + 1) * classes], cfg.tau);
            let tp = softmax_f64(&t_logits[s * classes..(s + 1) * classes], cfg.tau);
            for c in 0..classes {
                dlogits[s * classes + c] += (1.0 - cfg.lam)
                    * ((cfg.tau as f64 * (sp[c] - tp[c]) / n as f64) as f32);
            }
        }
    }

    // backward
    let head_name = path.name();
    let head = &params.heads[&head_name];
    let mut grads = Grads {
        blocks: params
            .blocks
            .iter()
            .map(|b| (vec![0.0; b.w.len()], vec![0.0; b.b.len()]))
            .collect(),
        head_w: vec![0.0; head.w.len()],
        head_b: vec![0.0; head.b.len()],
    };
    let feats = &acts.last().expect("depth >= 1").out;
    let mut dout = ctx.fc_bwd(feats, n, head, &dlogits, &mut grads.head_w, &mut grads.head_b);
    // head-only phases (calibration) freeze the trunk: skip the conv
    // backward entirely — the head update and the clip norm then see
    // exactly the gradients that will be applied
    let head_only = lrs.blocks.iter().take(path.depth).all(|&l| l == 0.0);
    if !head_only {
        for (i, act) in acts.iter().enumerate().rev() {
            let dpost = match &act.pool_idx {
                Some(idx) => tensor::pool_bwd(&dout, idx, act.post.len()),
                None => dout,
            };
            // QAT fake-quant: straight-through (identity) backward
            let dpre = tensor::relu_bwd(&act.pre, &dpost);
            let x_in: &[f32] = if i == 0 { x } else { &acts[i - 1].out };
            let (gw, gb) = &mut grads.blocks[i];
            // the first block's input gradient has no consumer
            dout = ctx.conv_bwd(
                x_in, n, act.h_in, act.w_in, &params.blocks[i], act.cin, act.cout, &dpre, gw,
                gb, i != 0,
            );
        }
    }
    let _ = dout;

    // global-norm clipping at 5.0 (train.py): keeps the alternating
    // teacher/student updates stable across growth stages
    let mut sq = 1e-12f64;
    for (gw, gb) in &grads.blocks {
        sq += gw.iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>();
        sq += gb.iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>();
    }
    sq += grads.head_w.iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>();
    sq += grads.head_b.iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>();
    let clip = (5.0 / sq.sqrt()).min(1.0) as f32;

    // SGD + momentum with the per-leaf LR tree
    let m = cfg.momentum;
    for (i, block) in params.blocks.iter_mut().enumerate().take(path.depth) {
        let (gw, gb) = &grads.blocks[i];
        let (vw, vb) = &mut vel.blocks[i];
        let lr = lrs.blocks[i];
        for ((p, v), &g) in block.w.iter_mut().zip(vw.iter_mut()).zip(gw.iter()) {
            *v = m * *v + g * clip;
            *p -= lr * *v;
        }
        for ((p, v), &g) in block.b.iter_mut().zip(vb.iter_mut()).zip(gb.iter()) {
            *v = m * *v + g * clip;
            *p -= lr * *v;
        }
    }
    let head = params.heads.get_mut(&head_name).expect("head exists");
    let (vw, vb) = vel.heads.get_mut(&head_name).expect("velocity exists");
    for ((p, v), &g) in head.w.iter_mut().zip(vw.iter_mut()).zip(grads.head_w.iter()) {
        *v = m * *v + g * clip;
        *p -= lrs.head * *v;
    }
    for ((p, v), &g) in head.b.iter_mut().zip(vb.iter_mut()).zip(grads.head_b.iter()) {
        *v = m * *v + g * clip;
        *p -= lrs.head * *v;
    }
    loss
}

/// Which DistillCycle phase produced a loss record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Teacher,
    Student,
    Polish,
    /// head-only KD refresh against the final trunk (see
    /// [`distillcycle_train`])
    Calibrate,
}

impl Phase {
    pub fn as_str(&self) -> &'static str {
        match self {
            Phase::Teacher => "teacher",
            Phase::Student => "student",
            Phase::Polish => "polish",
            Phase::Calibrate => "calibrate",
        }
    }
}

/// One epoch's mean loss for one (stage, phase, path).
#[derive(Debug, Clone)]
pub struct LossRecord {
    pub stage: usize,
    pub phase: Phase,
    pub path: String,
    pub epoch: usize,
    pub loss: f64,
}

/// Training outcome: parameters, per-path accuracy, full loss history.
pub struct TrainResult {
    pub params: Params,
    /// (path name, test accuracy) in ladder order
    pub accuracies: Vec<(String, f64)>,
    pub history: Vec<LossRecord>,
}

/// Shuffled full-batch index chunks; the trailing partial batch is
/// dropped, matching `train.py::_epoch_batches` (reference parity — the
/// CLI warns when the train count is not a batch multiple).
fn epoch_batches(rng: &mut Rng, n: usize, batch: usize) -> Vec<Vec<usize>> {
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    order.chunks_exact(batch.min(n).max(1)).map(|c| c.to_vec()).collect()
}

fn gather(ds_x: &[f32], frame: usize, idx: &[usize]) -> Vec<f32> {
    let mut out = Vec::with_capacity(idx.len() * frame);
    for &i in idx {
        out.extend_from_slice(&ds_x[i * frame..(i + 1) * frame]);
    }
    out
}

/// Top-1 accuracy of one morph path on the test split. `qat` must match
/// the training datapath: a QAT-trained ladder is evaluated through the
/// same fake-quant forward it will deploy with, so the profile reports
/// the quantized accuracy the governor/DSE actually get.
pub fn accuracy(
    params: &Params,
    spec: &DistillSpec,
    path: PathSpec,
    ds: &Dataset,
    qat: Option<u32>,
) -> f64 {
    accuracy_with(&mut KernelCtx::new(false), params, spec, path, ds, qat)
}

fn accuracy_with(
    ctx: &mut KernelCtx,
    params: &Params,
    spec: &DistillSpec,
    path: PathSpec,
    ds: &Dataset,
    qat: Option<u32>,
) -> f64 {
    let frame = ds.frame_len();
    let classes = spec.num_classes;
    let mut hits = 0usize;
    let batch = 256usize;
    let n = ds.n_test();
    if n == 0 {
        // an empty test split measures nothing; 0.0 (the manifest's
        // "untrained" marker) beats a NaN that would poison the profile
        return 0.0;
    }
    let mut i = 0;
    while i < n {
        let m = batch.min(n - i);
        let x = &ds.x_test[i * frame..(i + m) * frame];
        let logits = forward_with(ctx, params, spec, path, x, m, qat);
        for s in 0..m {
            let row = &logits[s * classes..(s + 1) * classes];
            let arg = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
                .unwrap()
                .0;
            if arg == ds.y_test[i + s] as usize {
                hits += 1;
            }
        }
        i += m;
    }
    hits as f64 / n as f64
}

/// `dse::run`'s scoped worker pattern in miniature: fan `jobs` out over
/// up to `threads` scoped workers (shared-iterator work stealing) and
/// place every result by its job index — output order is input order
/// whatever the worker count or completion interleaving. `threads <= 1`
/// (or a single job) runs inline with no threads spawned.
fn parallel_map<T, R>(jobs: Vec<T>, threads: usize, f: impl Fn(T) -> R + Sync) -> Vec<R>
where
    T: Send,
    R: Send,
{
    let threads = threads.max(1).min(jobs.len());
    if threads <= 1 {
        return jobs.into_iter().map(f).collect();
    }
    let n = jobs.len();
    let queue = std::sync::Mutex::new(jobs.into_iter().enumerate());
    let (tx, rx) = std::sync::mpsc::channel::<(usize, R)>();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let queue = &queue;
            let f = &f;
            scope.spawn(move || loop {
                // take the lock only to draw the next job; run it outside
                let job = queue.lock().expect("job queue lock").next();
                let Some((i, t)) = job else { break };
                if tx.send((i, f(t))).is_err() {
                    break;
                }
            });
        }
        drop(tx); // only worker clones remain
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("every job reports")).collect()
        // scope joins the workers here
    })
}

/// Algorithm 2: progressive growth with teacher/student KD cycles and a
/// final full-path polish. Deterministic: seeded; the KD cycles run
/// sequentially (they mutate the shared trunk), the independent phases
/// (head calibration, accuracy sweep) fan out over
/// [`DistillConfig::threads`] workers with byte-identical results for
/// any worker count.
pub fn distillcycle_train(spec: &DistillSpec, ds: &Dataset, cfg: &DistillConfig) -> TrainResult {
    let mut rng = Rng::new(cfg.seed);
    let mut params = init_params(spec, cfg.seed);
    let mut vel = Velocity::zeros(&params);
    let mut ctx = KernelCtx::for_cfg(cfg);
    let frame = ds.frame_len();
    let n_train = ds.n_train();
    let mut history: Vec<LossRecord> = Vec::new();

    let n_stages = spec.filters.len();
    let mut alpha = cfg.lr;
    for stage in 1..=n_stages {
        let teacher = PathSpec { depth: stage, width_pct: 100 };
        // students cycled within every epoch (Alg. 2's
        // morphing_schedule): each subnetwork distills from its parent
        // path — the previous depth (early-exit branch, depth-wise
        // parent) and this stage's reduced widths (width-wise children
        // of the current teacher)
        let mut students: Vec<PathSpec> = Vec::new();
        if stage > 1 {
            students.push(PathSpec { depth: stage - 1, width_pct: 100 });
        }
        for &pct in spec.widths.iter().filter(|&&p| p != 100) {
            students.push(PathSpec { depth: stage, width_pct: pct });
        }

        let lr_teacher = lr_tree(spec, stage, alpha, cfg.gamma, cfg.lr);
        for epoch in 0..cfg.epochs_per_stage {
            // Phase 1 — teacher: grow and train N_full^(i) with CE
            vel.zero();
            let mut losses = Vec::new();
            for idx in epoch_batches(&mut rng, n_train, cfg.batch) {
                let bx = gather(&ds.x_train, frame, &idx);
                let by: Vec<u32> = idx.iter().map(|&i| ds.y_train[i]).collect();
                losses.push(train_step(
                    &mut ctx, &mut params, &mut vel, spec, teacher, &bx, &by, None, cfg,
                    &lr_teacher,
                ));
            }
            history.push(LossRecord {
                stage,
                phase: Phase::Teacher,
                path: teacher.name(),
                epoch,
                loss: mean(&losses),
            });

            // Phase 2 — students: CE + KD against the fresh teacher
            for &spath in &students {
                let lr_student = lr_tree(spec, stage, alpha, cfg.gamma, cfg.lr);
                vel.zero();
                let mut losses = Vec::new();
                for idx in epoch_batches(&mut rng, n_train, cfg.batch) {
                    let bx = gather(&ds.x_train, frame, &idx);
                    let by: Vec<u32> = idx.iter().map(|&i| ds.y_train[i]).collect();
                    let t_logits =
                        forward_with(&mut ctx, &params, spec, teacher, &bx, by.len(), cfg.qat_bits);
                    losses.push(train_step(
                        &mut ctx,
                        &mut params,
                        &mut vel,
                        spec,
                        spath,
                        &bx,
                        &by,
                        Some(&t_logits),
                        cfg,
                        &lr_student,
                    ));
                }
                history.push(LossRecord {
                    stage,
                    phase: Phase::Student,
                    path: spath.name(),
                    epoch,
                    loss: mean(&losses),
                });
            }
        }
        alpha *= cfg.lr_stage_decay; // α ← α/10 in Alg. 2, softened
    }

    // Final polish: the last-added block+head saw the fewest updates, so
    // the full path gets one extra teacher-only cycle (keeps full >=
    // subnets, the ordering the paper reports).
    let full = spec.full_path();
    let lr_full = lr_tree(spec, n_stages, alpha, cfg.gamma, cfg.lr);
    vel.zero();
    for epoch in 0..cfg.epochs_per_stage {
        let mut losses = Vec::new();
        for idx in epoch_batches(&mut rng, n_train, cfg.batch) {
            let bx = gather(&ds.x_train, frame, &idx);
            let by: Vec<u32> = idx.iter().map(|&i| ds.y_train[i]).collect();
            losses.push(train_step(
                &mut ctx, &mut params, &mut vel, spec, full, &bx, &by, None, cfg, &lr_full,
            ));
        }
        history.push(LossRecord {
            stage: n_stages + 1,
            phase: Phase::Polish,
            path: full.name(),
            epoch,
            loss: mean(&losses),
        });
    }

    // Head calibration: every non-full head was last trained against an
    // *earlier* trunk, and later stages + polish keep moving the shared
    // blocks (at γ-decayed but nonzero rates) — enough drift to strand a
    // head trained stages ago. One head-only KD pass per path against
    // the FINAL network re-aligns every readout with the trunk that
    // actually ships; trunk weights are frozen (block LR 0), so no path
    // can disturb another — which makes the ladder's calibration passes
    // *independent*: each worker trains its path's head on a clone of
    // the frozen network and only that head merges back, in ladder
    // order. RNG schedules are pre-drawn on the main thread in the
    // serial order (path-major, epoch-minor), so the stream consumed —
    // and every trained bit — is identical for any worker count.
    let lr_cal = LrTree { blocks: vec![0.0; n_stages], head: cfg.lr };
    let cal_jobs: Vec<(PathSpec, Vec<Vec<Vec<usize>>>)> = spec
        .paths()
        .into_iter()
        .filter(|&p| p != full)
        .map(|p| {
            let sched = (0..cfg.epochs_per_stage)
                .map(|_| epoch_batches(&mut rng, n_train, cfg.batch))
                .collect();
            (p, sched)
        })
        .collect();
    let calibrated = parallel_map(cal_jobs, cfg.threads, |(cpath, sched)| {
        let mut p = params.clone();
        let mut v = Velocity::zeros(&p);
        let mut ctx = KernelCtx::for_cfg(cfg);
        let mut losses = Vec::new();
        for batches in &sched {
            for idx in batches {
                let bx = gather(&ds.x_train, frame, idx);
                let by: Vec<u32> = idx.iter().map(|&i| ds.y_train[i]).collect();
                let t_logits = forward_with(&mut ctx, &p, spec, full, &bx, by.len(), cfg.qat_bits);
                losses.push(train_step(
                    &mut ctx,
                    &mut p,
                    &mut v,
                    spec,
                    cpath,
                    &bx,
                    &by,
                    Some(&t_logits),
                    cfg,
                    &lr_cal,
                ));
            }
        }
        let head = p.heads.remove(&cpath.name()).expect("head exists");
        (cpath, head, mean(&losses))
    });
    for (cpath, head, loss) in calibrated {
        params.heads.insert(cpath.name(), head);
        history.push(LossRecord {
            stage: n_stages + 2,
            phase: Phase::Calibrate,
            path: cpath.name(),
            epoch: 0,
            loss,
        });
    }

    // Accuracy sweep: read-only per path — the other trivially parallel
    // ladder phase; results collect in ladder order regardless of which
    // worker finishes first.
    let accuracies = parallel_map(spec.paths(), cfg.threads, |p| {
        let mut ctx = KernelCtx::for_cfg(cfg);
        (p.name(), accuracy_with(&mut ctx, &params, spec, p, ds, cfg.qat_bits))
    });

    // KD-cycle trace: one virtual-clock span per loss record, stamped on
    // the training's logical timeline (1 ms per record). The history is
    // pushed on the main thread in a fixed order, so the trace is
    // byte-identical across `cfg.threads` and reruns.
    if let Some(sink) = &cfg.trace {
        use crate::obs::{Clock, Name, TraceEntry};
        for (i, r) in history.iter().enumerate() {
            let name = match r.phase {
                Phase::Teacher => Name::KdTeacher,
                Phase::Student => Name::KdStudent,
                Phase::Polish => Name::KdPolish,
                Phase::Calibrate => Name::KdCalibrate,
            };
            let ts = i as u64 * 1_000;
            let loss_u = (r.loss.max(0.0) * 1e6).round() as u64;
            let span = TraceEntry::span(Clock::Virtual, name, ts, 1_000, r.stage as u64)
                .with_path(sink.intern(&r.path))
                .with_args(r.epoch as u64, loss_u);
            sink.record(0, span);
        }
    }
    TrainResult { params, accuracies, history }
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

// ---------------------------------------------------------------------------
// AccuracyProfile — the artifact the rest of the pipeline consumes
// ---------------------------------------------------------------------------

/// One execution path's trained outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct PathAccuracy {
    pub name: String,
    pub depth: usize,
    pub width_pct: usize,
    pub accuracy: f64,
    pub params: usize,
    pub macs: usize,
    /// per-epoch mean loss trajectory of this path (KD loss for student
    /// phases, CE for teacher/polish), in training order
    pub loss_trajectory: Vec<f64>,
}

/// Per-execution-path accuracies + loss trajectories: the DistillCycle
/// output persisted next to the AOT manifest and consumed by the
/// governor (accuracy floor) and the DSE (third objective).
#[derive(Debug, Clone, PartialEq)]
pub struct AccuracyProfile {
    pub model: String,
    pub seed: u64,
    pub qat_bits: Option<u32>,
    pub paths: Vec<PathAccuracy>,
}

impl AccuracyProfile {
    /// Build from a training run.
    pub fn from_result(spec: &DistillSpec, cfg: &DistillConfig, res: &TrainResult) -> AccuracyProfile {
        let paths = spec
            .paths()
            .iter()
            .map(|&p| {
                let name = p.name();
                let accuracy = res
                    .accuracies
                    .iter()
                    .find(|(n, _)| *n == name)
                    .map(|(_, a)| *a)
                    .unwrap_or(0.0);
                let loss_trajectory = res
                    .history
                    .iter()
                    .filter(|r| r.path == name)
                    .map(|r| r.loss)
                    .collect();
                PathAccuracy {
                    name,
                    depth: p.depth,
                    width_pct: p.width_pct,
                    accuracy,
                    params: spec.count_params(p),
                    macs: spec.count_macs(p),
                    loss_trajectory,
                }
            })
            .collect();
        AccuracyProfile { model: spec.name.clone(), seed: cfg.seed, qat_bits: cfg.qat_bits, paths }
    }

    /// The hard accuracy floor this profile supports: the worst trained
    /// path. Any path falling below it (corruption, an untrained entry)
    /// is not deployable.
    pub fn floor(&self) -> f64 {
        self.paths.iter().map(|p| p.accuracy).fold(f64::INFINITY, f64::min)
    }

    /// The ladder as governor/DSE-facing morph paths.
    pub fn morph_paths(&self) -> Vec<MorphPath> {
        self.paths
            .iter()
            .map(|p| MorphPath {
                name: p.name.clone(),
                depth: p.depth,
                width_pct: p.width_pct,
                accuracy: p.accuracy,
                params: p.params,
                macs: p.macs,
            })
            .collect()
    }

    /// Persist trained accuracies into a loaded runtime manifest entry.
    /// Every profile path must exist in the manifest; returns the number
    /// of updated paths.
    pub fn apply_to(&self, manifest: &mut ModelManifest) -> Result<usize, DistillError> {
        let mut updated = 0;
        for p in &self.paths {
            match manifest.paths.iter_mut().find(|mp| mp.path.name == p.name) {
                Some(mp) => {
                    mp.path.accuracy = p.accuracy;
                    updated += 1;
                }
                None => {
                    return Err(DistillError::Profile(format!(
                        "path '{}' not in manifest for model '{}'",
                        p.name, manifest.name
                    )))
                }
            }
        }
        Ok(updated)
    }

    /// Deterministic JSON encoding — byte-identical for identical
    /// profiles (BTreeMap key order + Rust's shortest-roundtrip float
    /// formatting).
    pub fn to_json(&self) -> String {
        let mut root = BTreeMap::new();
        root.insert("model".to_string(), Json::Str(self.model.clone()));
        root.insert("seed".to_string(), Json::Num(self.seed as f64));
        root.insert(
            "qat_bits".to_string(),
            self.qat_bits.map(|b| Json::Num(b as f64)).unwrap_or(Json::Null),
        );
        root.insert("floor".to_string(), Json::Num(self.floor()));
        let paths = self
            .paths
            .iter()
            .map(|p| {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::Str(p.name.clone()));
                o.insert("depth".to_string(), Json::Num(p.depth as f64));
                o.insert("width_pct".to_string(), Json::Num(p.width_pct as f64));
                o.insert("accuracy".to_string(), Json::Num(p.accuracy));
                o.insert("params".to_string(), Json::Num(p.params as f64));
                o.insert("macs".to_string(), Json::Num(p.macs as f64));
                o.insert(
                    "loss_trajectory".to_string(),
                    Json::Arr(p.loss_trajectory.iter().map(|&l| Json::Num(l)).collect()),
                );
                Json::Obj(o)
            })
            .collect();
        root.insert("paths".to_string(), Json::Arr(paths));
        Json::Obj(root).to_string()
    }

    /// Parse a profile emitted by [`AccuracyProfile::to_json`].
    pub fn parse(text: &str) -> Result<AccuracyProfile, DistillError> {
        let bad = |m: &str| DistillError::Profile(m.to_string());
        let root = Json::parse(text).map_err(|e| DistillError::Profile(e.to_string()))?;
        let model = root
            .get("model")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("missing 'model'"))?
            .to_string();
        let seed = root.get("seed").and_then(Json::as_u64).ok_or_else(|| bad("missing 'seed'"))?;
        let qat_bits = match root.get("qat_bits") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_u64().ok_or_else(|| bad("bad 'qat_bits'"))? as u32),
        };
        let mut paths = Vec::new();
        for p in root.get("paths").and_then(Json::as_arr).ok_or_else(|| bad("missing 'paths'"))? {
            let name = p
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| bad("path missing 'name'"))?
                .to_string();
            let accuracy = p
                .get("accuracy")
                .and_then(Json::as_f64)
                .ok_or_else(|| bad("path missing 'accuracy'"))?;
            if !(0.0..=1.0).contains(&accuracy) {
                return Err(DistillError::Profile(format!(
                    "path '{name}': accuracy {accuracy} outside 0.0..=1.0"
                )));
            }
            // macs is load-bearing: the DSE scales candidate latency by
            // the path's MAC fraction, so a defaulted 0 would make the
            // path report zero latency and dominate every front
            let macs = p
                .get("macs")
                .and_then(Json::as_u64)
                .ok_or_else(|| bad("path missing 'macs'"))? as usize;
            if macs == 0 {
                return Err(DistillError::Profile(format!("path '{name}': macs must be > 0")));
            }
            paths.push(PathAccuracy {
                name,
                depth: p.get("depth").and_then(Json::as_u64).ok_or_else(|| bad("path missing 'depth'"))?
                    as usize,
                width_pct: p
                    .get("width_pct")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| bad("path missing 'width_pct'"))? as usize,
                accuracy,
                params: p.get("params").and_then(Json::as_u64).unwrap_or(0) as usize,
                macs,
                loss_trajectory: p
                    .get("loss_trajectory")
                    .and_then(Json::as_f64_vec)
                    .unwrap_or_default(),
            });
        }
        if paths.is_empty() {
            return Err(bad("empty 'paths'"));
        }
        Ok(AccuracyProfile { model, seed, qat_bits, paths })
    }
}

/// Train the full DistillCycle and package the profile — the one-call
/// entry the CLI / report / bench use.
pub fn train_profile(spec: &DistillSpec, ds: &Dataset, cfg: &DistillConfig) -> AccuracyProfile {
    let res = distillcycle_train(spec, ds, cfg);
    AccuracyProfile::from_result(spec, cfg, &res)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> DistillConfig {
        DistillConfig { epochs_per_stage: 1, batch: 32, ..DistillConfig::default() }
    }

    fn one_block_spec() -> DistillSpec {
        DistillSpec::new("micro", (12, 12, 1), 3, vec![8], vec![50]).unwrap()
    }

    #[test]
    fn ladder_matches_morph_gates() {
        let spec = DistillSpec::tiny();
        let names: Vec<String> = spec.paths().iter().map(|p| p.name()).collect();
        // the full GateMask-width × depth cross product
        assert_eq!(
            names,
            vec!["d1_w100", "d1_w50", "d2_w100", "d2_w50", "d3_w100", "d3_w50"]
        );
        // every ladder path must translate to a deployable gate mask
        let net = crate::graph::zoo::mnist();
        for p in spec.paths() {
            let mp = MorphPath {
                name: p.name(),
                depth: p.depth,
                width_pct: p.width_pct,
                accuracy: 0.5,
                params: 1,
                macs: 1,
            };
            assert!(crate::morph::gate_mask_for(&net, &mp).is_ok(), "{}", p.name());
        }
    }

    #[test]
    fn undeployable_width_rejected() {
        let err = DistillSpec::new("bad", (8, 8, 1), 2, vec![4], vec![5]).unwrap_err();
        assert_eq!(err, DistillError::Width(5));
    }

    #[test]
    fn spec_from_zoo_chain_and_branchy_rejected() {
        let spec = DistillSpec::from_network(&crate::graph::zoo::mnist()).unwrap();
        assert_eq!(spec.filters, vec![8, 16, 32]);
        assert_eq!(spec.input, (28, 28, 1));
        assert_eq!(spec.num_classes, 10);
        assert!(DistillSpec::from_network(&crate::graph::zoo::unet_tiny()).is_err());
        // resnet50's 7x7/s2 stem deviates from the Layer-Block template:
        // rejected instead of silently trained as a different net
        assert!(DistillSpec::from_network(&crate::graph::zoo::resnet50()).is_err());
    }

    #[test]
    fn counts_match_reference_formulas() {
        // mirror model.py::count_params on the mnist spec, d1_w100:
        // conv 3*3*1*8 + 8 = 80; head 14*14*8*10 + 10 = 15690 -> 15770?
        // model.py feature_shape(1) = 14 -> head dim 14*14*8 = 1568
        let spec = DistillSpec::from_network(&crate::graph::zoo::mnist()).unwrap();
        let d1 = PathSpec { depth: 1, width_pct: 100 };
        assert_eq!(spec.count_params(d1), 3 * 3 * 8 + 8 + 1568 * 10 + 10);
        let full = spec.full_path();
        // the sample_paths macs in morph::tests were computed from the
        // python reference; full-depth macs must match that scale
        assert_eq!(spec.count_macs(full), 28 * 28 * 9 * 8 + 14 * 14 * 9 * 8 * 16 + 7 * 7 * 9 * 16 * 32 + 3 * 3 * 32 * 10);
    }

    #[test]
    fn kd_trace_mirrors_history_and_is_reproducible() {
        use crate::obs::{Clock, Kind, TraceSink};
        let spec = one_block_spec();
        let ds = spec.dataset(64, 32, 3);
        let mk = || DistillConfig { trace: Some(TraceSink::shared()), ..quick_cfg() };
        let (c1, c2) = (mk(), mk());
        let res = distillcycle_train(&spec, &ds, &c1);
        distillcycle_train(&spec, &ds, &c2);
        let (t1, t2) = (c1.trace.unwrap().drain(), c2.trace.unwrap().drain());
        assert_eq!(t1.entries, t2.entries, "KD trace must be reproducible");
        assert_eq!(t1.dropped, 0);
        assert_eq!(t1.entries.len(), res.history.len());
        for (e, r) in t1.entries.iter().zip(&res.history) {
            assert_eq!(e.kind, Kind::Span);
            assert_eq!(e.clock, Clock::Virtual);
            assert_eq!(e.id, r.stage as u64);
            assert_eq!(e.a0, r.epoch as u64);
            assert_eq!(t1.path_name(e.path), Some(r.path.as_str()), "{}", r.path);
        }
    }

    #[test]
    fn training_reduces_teacher_loss_and_beats_chance() {
        let spec = one_block_spec();
        let ds = spec.dataset(256, 96, 0);
        let cfg = DistillConfig { epochs_per_stage: 3, ..quick_cfg() };
        let res = distillcycle_train(&spec, &ds, &cfg);
        let teacher: Vec<f64> = res
            .history
            .iter()
            .filter(|r| r.stage == 1 && r.phase == Phase::Teacher)
            .map(|r| r.loss)
            .collect();
        assert!(teacher.last().unwrap() < teacher.first().unwrap(), "{teacher:?}");
        // chance is 1/3; every ladder path must clear it decisively
        for (name, acc) in &res.accuracies {
            assert!(*acc > 0.40, "{name}: {acc} (chance 0.33)");
        }
    }

    #[test]
    fn qat_training_still_learns() {
        let spec = one_block_spec();
        let ds = spec.dataset(256, 96, 0);
        let cfg = DistillConfig {
            epochs_per_stage: 3,
            qat_bits: Some(8),
            ..quick_cfg()
        };
        let res = distillcycle_train(&spec, &ds, &cfg);
        let (_, acc) = res.accuracies.iter().find(|(n, _)| n == "d1_w100").unwrap();
        assert!(*acc > 0.35, "int8 QAT accuracy {acc} (chance 0.33)");
    }

    #[test]
    fn inference_forward_matches_cached_forward() {
        // the lean inference forward must be bit-identical to the
        // training forward (teacher logits feed the KD loss)
        let spec = DistillSpec::tiny();
        let params = init_params(&spec, 7);
        let ds = spec.dataset(8, 8, 7);
        for &p in &spec.paths() {
            for qat in [None, Some(8)] {
                let lean = forward(&params, &spec, p, &ds.x_test, 8, qat);
                let (_, cached) =
                    forward_cached(&mut KernelCtx::new(false), &params, &spec, p, &ds.x_test, 8, qat);
                assert_eq!(lean, cached, "{} qat {qat:?}", p.name());
                // and the scalar reference core agrees bit-for-bit
                let reference =
                    forward_with(&mut KernelCtx::new(true), &params, &spec, p, &ds.x_test, 8, qat);
                assert_eq!(lean, reference, "{} qat {qat:?} (reference core)", p.name());
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_profile() {
        // threads=0 (serial, scalar reference kernels), threads=1
        // (blocked kernels, inline) and threads=3 (blocked kernels,
        // scoped fan-out) must emit byte-identical AccuracyProfile JSON
        // — the invariant the CLI's --threads default leans on. The
        // wider 1-vs-4 sweep over two seeds lives in
        // tests/prop_invariants.rs.
        let spec = one_block_spec();
        let base = quick_cfg();
        let emit = |threads: usize| {
            let cfg = DistillConfig { threads, ..base.clone() };
            train_profile(&spec, &spec.dataset(96, 48, 5), &cfg).to_json()
        };
        let serial_ref = emit(0);
        assert_eq!(serial_ref, emit(1));
        assert_eq!(serial_ref, emit(3));
    }

    #[test]
    fn profile_roundtrip_and_floor() {
        let spec = one_block_spec();
        let ds = spec.dataset(96, 48, 1);
        let cfg = quick_cfg();
        let prof = train_profile(&spec, &ds, &cfg);
        assert_eq!(prof.paths.len(), 2); // d1_w100 + d1_w50
        let parsed = AccuracyProfile::parse(&prof.to_json()).unwrap();
        assert_eq!(parsed, prof);
        let floor = prof.floor();
        assert!(prof.paths.iter().all(|p| p.accuracy >= floor));
    }

    #[test]
    fn profile_rejects_out_of_range_accuracy() {
        let text = r#"{"model":"m","seed":0,"qat_bits":null,
          "paths":[{"name":"d1_w100","depth":1,"width_pct":100,"accuracy":1.5}]}"#;
        assert!(matches!(
            AccuracyProfile::parse(text),
            Err(DistillError::Profile(_))
        ));
    }

    #[test]
    fn profile_rejects_missing_or_zero_macs() {
        // macs scales DSE latency: a defaulted 0 would make the path
        // report zero latency and dominate every front
        for macs in ["", r#","macs":0"#] {
            let text = format!(
                r#"{{"model":"m","seed":0,"qat_bits":null,
                  "paths":[{{"name":"d1_w100","depth":1,"width_pct":100,
                             "accuracy":0.9,"params":10{macs}}}]}}"#
            );
            assert!(
                matches!(AccuracyProfile::parse(&text), Err(DistillError::Profile(_))),
                "macs case {macs:?} must be rejected"
            );
        }
    }

    #[test]
    fn profile_applies_to_manifest() {
        let spec = one_block_spec();
        let ds = spec.dataset(96, 48, 1);
        let prof = train_profile(&spec, &ds, &quick_cfg());
        // manifest with matching path names
        let manifest_text = format!(
            r#"{{"version":1,"models":{{"micro":{{
              "input_shape":[8,8,1],"num_classes":3,"filters":[4],"batches":[1],
              "paths":[
                {{"name":"d1_w100","depth":1,"width_pct":100,"accuracy":null,
                  "artifacts":{{"1":"a.hlo.txt"}}}},
                {{"name":"d1_w50","depth":1,"width_pct":50,"accuracy":null,
                  "artifacts":{{"1":"b.hlo.txt"}}}}],
              "probe":{{"shape":[1,1],"x":[0.5],"logits":{{}}}}}}}}}}"#
        );
        let mut manifest =
            crate::runtime::Manifest::parse(std::path::Path::new("/tmp"), &manifest_text).unwrap();
        let model = manifest.models.get_mut("micro").unwrap();
        assert_eq!(prof.apply_to(model).unwrap(), 2);
        for (mp, pp) in model.paths.iter().zip(&prof.paths) {
            assert_eq!(mp.path.accuracy, pp.accuracy);
        }
        // unknown path -> explicit error
        let mut bad = prof.clone();
        bad.paths[0].name = "d9_w100".into();
        assert!(bad.apply_to(model).is_err());
    }

    #[test]
    fn lr_tree_matches_eq20() {
        let spec = DistillSpec::tiny();
        let t = lr_tree(&spec, 3, 0.1, 0.5, 0.1);
        assert_eq!(t.blocks, vec![0.025, 0.05, 0.1]); // γ², γ¹, γ⁰
        assert_eq!(t.head, 0.1);
        let t2 = lr_tree(&spec, 2, 0.01, 0.5, 0.3);
        assert_eq!(t2.head, 0.3);
        assert_eq!(t2.blocks[2], 0.01); // beyond-stage blocks undecayed
    }

    #[test]
    fn byte_identical_profiles_across_runs() {
        let spec = one_block_spec();
        let cfg = quick_cfg();
        let a = train_profile(&spec, &spec.dataset(96, 48, 2), &cfg).to_json();
        let b = train_profile(&spec, &spec.dataset(96, 48, 2), &cfg).to_json();
        assert_eq!(a, b);
    }
}
