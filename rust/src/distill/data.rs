//! Deterministic synthetic classification datasets for the Rust-side
//! DistillCycle trainer — the same procedural stand-in scheme as
//! `python/compile/data.py` (DESIGN.md §2), regenerated here with
//! [`crate::util::rng::Rng`] so the training engine needs no files and
//! no Python at all.
//!
//! Each class is a fixed mixture of 2-D sinusoidal gratings and Gaussian
//! blobs; samples perturb the class template with amplitude jitter,
//! random spatial shifts (wrap-around roll) and additive noise, then the
//! whole batch is min-max normalized to `[0, 1]`. Shifts make shallow
//! subnets strictly weaker than deep ones — the accuracy-vs-depth/width
//! gradient DistillCycle and NeuroMorph trade on. Everything is seeded:
//! two runs generate byte-identical datasets.

use crate::util::rng::Rng;

/// Train/test split with flat NHWC images in `[0, 1]` and integer labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub num_classes: usize,
    pub x_train: Vec<f32>,
    pub y_train: Vec<u32>,
    pub x_test: Vec<f32>,
    pub y_test: Vec<u32>,
}

impl Dataset {
    pub fn n_train(&self) -> usize {
        self.y_train.len()
    }

    pub fn n_test(&self) -> usize {
        self.y_test.len()
    }

    pub fn frame_len(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// Process-independent seed for a dataset name (FNV-1a over the name,
/// mixed with the user seed) — the Rust twin of `data._stable_seed`.
fn stable_seed(name: &str, seed: u64) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h.wrapping_add(seed)
}

fn uniform(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    lo + rng.f64() * (hi - lo)
}

/// One `[h, w, c]` template per class: gratings + blobs, unit-normalized.
fn class_templates(rng: &mut Rng, h: usize, w: usize, c: usize, classes: usize) -> Vec<Vec<f32>> {
    let mut templates = Vec::with_capacity(classes);
    for _ in 0..classes {
        let mut img = vec![0.0f64; h * w * c];
        // sinusoidal gratings — orientation/frequency keyed to the class
        for _ in 0..3 {
            let fx = uniform(rng, 0.5, 3.0);
            let fy = uniform(rng, 0.5, 3.0);
            let phase = uniform(rng, 0.0, 2.0 * std::f64::consts::PI);
            let chan = rng.below(c);
            for yy in 0..h {
                for xx in 0..w {
                    let g = (2.0 * std::f64::consts::PI
                        * (fx * xx as f64 / w as f64 + fy * yy as f64 / h as f64)
                        + phase)
                        .sin();
                    img[(yy * w + xx) * c + chan] += g;
                }
            }
        }
        // gaussian blobs — spatial landmarks on every channel
        for _ in 0..2 {
            let cx = uniform(rng, 0.2, 0.8) * w as f64;
            let cy = uniform(rng, 0.2, 0.8) * h as f64;
            let sigma = uniform(rng, 0.08, 0.2) * h.min(w) as f64;
            for yy in 0..h {
                for xx in 0..w {
                    let d2 = (yy as f64 - cy).powi(2) + (xx as f64 - cx).powi(2);
                    let blob = (-d2 / (2.0 * sigma * sigma)).exp();
                    for ch in 0..c {
                        img[(yy * w + xx) * c + ch] += blob;
                    }
                }
            }
        }
        // unit-normalize the template
        let mean = img.iter().sum::<f64>() / img.len() as f64;
        let var = img.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / img.len() as f64;
        let std = var.sqrt().max(1e-6);
        templates.push(img.iter().map(|v| ((v - mean) / std) as f32).collect());
    }
    templates
}

/// Sample `n` images: template * amplitude jitter, rolled by a random
/// shift, plus Gaussian noise; batch-global min-max map to `[0, 1]`.
#[allow(clippy::too_many_arguments)]
fn sample(
    rng: &mut Rng,
    templates: &[Vec<f32>],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    noise: f64,
    max_shift: usize,
) -> (Vec<f32>, Vec<u32>) {
    let classes = templates.len();
    let frame = h * w * c;
    let mut x = vec![0.0f32; n * frame];
    let mut y = vec![0u32; n];
    for s in 0..n {
        let cls = rng.below(classes);
        y[s] = cls as u32;
        let amp = uniform(rng, 0.7, 1.3) as f32;
        let (sy, sx) = if max_shift > 0 {
            let m = max_shift as i64;
            (rng.range(-m, m), rng.range(-m, m))
        } else {
            (0, 0)
        };
        let t = &templates[cls];
        let dst = &mut x[s * frame..(s + 1) * frame];
        for yy in 0..h {
            // wrap-around roll (np.roll semantics)
            let ty = (yy as i64 - sy).rem_euclid(h as i64) as usize;
            for xx in 0..w {
                let tx = (xx as i64 - sx).rem_euclid(w as i64) as usize;
                for ch in 0..c {
                    dst[(yy * w + xx) * c + ch] = t[(ty * w + tx) * c + ch] * amp;
                }
            }
        }
        for v in dst.iter_mut() {
            *v += (rng.gauss() * noise) as f32;
        }
    }
    // map the whole batch to [0, 1] like pixel data
    let lo = x.iter().copied().fold(f32::INFINITY, f32::min);
    let hi = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let span = (hi - lo).max(1e-6);
    for v in x.iter_mut() {
        *v = (*v - lo) / span;
    }
    (x, y)
}

/// Build a seeded synthetic dataset with the given geometry.
#[allow(clippy::too_many_arguments)]
pub fn make_dataset(
    name: &str,
    h: usize,
    w: usize,
    c: usize,
    classes: usize,
    n_train: usize,
    n_test: usize,
    noise: f64,
    max_shift: usize,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(stable_seed(name, seed));
    let templates = class_templates(&mut rng, h, w, c, classes);
    let (x_train, y_train) = sample(&mut rng, &templates, n_train, h, w, c, noise, max_shift);
    let (x_test, y_test) = sample(&mut rng, &templates, n_test, h, w, c, noise, max_shift);
    Dataset { h, w, c, num_classes: classes, x_train, y_train, x_test, y_test }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_identical_across_runs() {
        let a = make_dataset("t", 8, 8, 1, 4, 32, 16, 1.0, 2, 0);
        let b = make_dataset("t", 8, 8, 1, 4, 32, 16, 1.0, 2, 0);
        assert_eq!(a.x_train, b.x_train);
        assert_eq!(a.y_train, b.y_train);
        assert_eq!(a.x_test, b.x_test);
    }

    #[test]
    fn seeds_and_names_differ() {
        let a = make_dataset("t", 8, 8, 1, 4, 32, 16, 1.0, 2, 0);
        let b = make_dataset("t", 8, 8, 1, 4, 32, 16, 1.0, 2, 1);
        let c = make_dataset("u", 8, 8, 1, 4, 32, 16, 1.0, 2, 0);
        assert_ne!(a.x_train, b.x_train);
        assert_ne!(a.x_train, c.x_train);
    }

    #[test]
    fn values_in_unit_range_and_labels_valid() {
        let d = make_dataset("t", 6, 6, 3, 5, 64, 32, 1.0, 1, 3);
        assert!(d.x_train.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(d.y_train.iter().all(|&y| (y as usize) < 5));
        assert_eq!(d.x_train.len(), 64 * d.frame_len());
        // every class appears in a 64-sample draw with 5 classes
        let mut seen = [false; 5];
        for &y in &d.y_train {
            seen[y as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn classes_are_separable_by_template() {
        // nearest-template classification on noiseless samples is perfect
        let d = make_dataset("sep", 8, 8, 1, 3, 0, 0, 0.0, 0, 7);
        let _ = d; // geometry-only smoke: zero-sample build must not panic
    }
}
