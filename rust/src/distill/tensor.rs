//! Deterministic tensor core for the DistillCycle trainer — blocked
//! im2col microkernels.
//!
//! Flat `Vec<f32>` NHWC tensors with explicit dims — no BLAS, no SIMD
//! intrinsics — but structured for the auto-vectorizer: the conv kernels
//! pack each input patch into a reusable im2col scratch buffer (zero
//! padding materialized, `(ky, kx, ci)` order) and run a register-blocked
//! matmul microkernel over it. Determinism survives the blocking because
//! the **reduction order per output element is fixed** and identical to
//! the retained scalar reference kernels ([`super::tensor_ref`]): every
//! accumulator starts from its bias (or `+0.0`) and consumes its terms in
//! the reference sequence; blocking/vectorization only runs *independent*
//! accumulators side by side (4 output pixels × the `co` lane), never a
//! tree reduction. The property suite bit-compares both cores across
//! random shapes, widths and batch sizes (see DESIGN.md §11 for the
//! `±0.0` argument that makes the zero-skips exact).
//!
//! The ops mirror `python/compile/kernels/ref.py`: conv3x3 SAME, ReLU,
//! 2x2 max-pool (stride 2, odd edge dropped) and a dense head.
//!
//! Width-morphing follows `model.py::slice_block`: weight buffers are
//! allocated at full width and the active `(cin, cout)` slice is indexed
//! via precomputed packed-row offsets, so gated filters are never touched
//! — the software twin of clock-gated PEs never toggling.

/// One morphable conv block's parameters (full-width storage).
#[derive(Debug, Clone)]
pub struct Conv {
    /// `[k, k, cin, cout]` weights, row-major
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub k: usize,
    pub cin: usize,
    pub cout: usize,
}

impl Conv {
    #[inline]
    pub fn widx(&self, ky: usize, kx: usize, ci: usize, co: usize) -> usize {
        ((ky * self.k + kx) * self.cin + ci) * self.cout + co
    }
}

/// One execution path's dense output head.
#[derive(Debug, Clone)]
pub struct Dense {
    /// `[dim, classes]` weights, row-major
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub dim: usize,
    pub classes: usize,
}

/// Reusable scratch for the blocked kernels: one per worker thread, grown
/// on demand and reused across every batch/layer it touches — the hot
/// loops allocate nothing per step beyond their output tensors.
#[derive(Debug, Default)]
pub struct Scratch {
    /// im2col patch matrix, `[n*h*w, k*k*cin_a]`
    col: Vec<f32>,
    /// transposed active weights for the backward scatter,
    /// `[cout_a, k*k*cin_a]` (conv) or `[classes, dim]` (dense)
    wt: Vec<f32>,
    /// packed patch column `j = (ky,kx,ci)` -> full-width weight row
    /// offset `((ky*k+kx)*cin + ci)*cout` — the indirection that keeps
    /// gated channels untouched under width morphing
    row_off: Vec<usize>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }
}

/// Pack `x` (`[n, h, w, cin_a]`, SAME pad `k/2`) into the im2col patch
/// matrix `col[row][j]` with `row = (s, oy, ox)` and `j = (ky, kx, ci)`
/// ascending — the fixed reduction order of the reference kernels, with
/// out-of-bounds taps materialized as `+0.0`. Contiguous `kx` runs are
/// bulk-copied.
pub fn im2col(x: &[f32], n: usize, h: usize, w: usize, cin_a: usize, k: usize, col: &mut Vec<f32>) {
    debug_assert_eq!(x.len(), n * h * w * cin_a);
    let pad = k / 2;
    let kk = k * k * cin_a;
    col.clear();
    col.resize(n * h * w * kk, 0.0);
    let mut r = 0usize;
    for s in 0..n {
        for oy in 0..h {
            for ox in 0..w {
                let row = &mut col[r * kk..(r + 1) * kk];
                for ky in 0..k {
                    let seg = &mut row[ky * k * cin_a..(ky + 1) * k * cin_a];
                    let iy = oy + ky;
                    if iy < pad || iy - pad >= h {
                        seg.fill(0.0);
                        continue;
                    }
                    let iy = iy - pad;
                    // valid kx span: pad <= ox + kx < w + pad
                    let kx_lo = pad.saturating_sub(ox);
                    let kx_hi = k.min(w + pad - ox);
                    let ix_lo = ox + kx_lo - pad;
                    seg[..kx_lo * cin_a].fill(0.0);
                    let src = &x[((s * h + iy) * w + ix_lo) * cin_a..][..(kx_hi - kx_lo) * cin_a];
                    seg[kx_lo * cin_a..kx_hi * cin_a].copy_from_slice(src);
                    seg[kx_hi * cin_a..].fill(0.0);
                }
                r += 1;
            }
        }
    }
}

/// Packed patch column -> full-width weight row offsets (the active
/// `co` slice of row `j` is `w[row_off[j]..row_off[j] + cout_a]`).
fn fill_row_off(row_off: &mut Vec<usize>, conv: &Conv, cin_a: usize) {
    let k = conv.k;
    row_off.clear();
    row_off.reserve(k * k * cin_a);
    for t in 0..k * k {
        for ci in 0..cin_a {
            row_off.push((t * conv.cin + ci) * conv.cout);
        }
    }
}

/// conv SAME + bias over the active `(cin_a, cout_a)` slice — blocked
/// im2col microkernel. Input `x` is `[n, h, w, cin_a]` (activations are
/// stored compact at the active width); the pre-activation
/// `[n, h, w, cout_a]` is written into `out`.
///
/// Microkernel shape: 4 output pixels ride together (shared weight-row
/// loads), the `co` loop is the vector lane; each `out[p][co]`
/// accumulator starts at `b[co]` and consumes `j = (ky, kx, ci)`
/// ascending — the reference reduction order, padding taps contributing
/// inert `±0.0` terms.
#[allow(clippy::too_many_arguments)]
pub fn conv_fwd_scratch(
    sc: &mut Scratch,
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    conv: &Conv,
    cin_a: usize,
    cout_a: usize,
    out: &mut Vec<f32>,
) {
    debug_assert_eq!(x.len(), n * h * w * cin_a);
    let rows = n * h * w;
    let kk = conv.k * conv.k * cin_a;
    im2col(x, n, h, w, cin_a, conv.k, &mut sc.col);
    fill_row_off(&mut sc.row_off, conv, cin_a);
    out.clear();
    out.resize(rows * cout_a, 0.0);
    let bias = &conv.b[..cout_a];
    let col = &sc.col;
    let ro = &sc.row_off;

    const MR: usize = 4;
    let mut r = 0usize;
    while r + MR <= rows {
        let chunk = &mut out[r * cout_a..(r + MR) * cout_a];
        for orow in chunk.chunks_exact_mut(cout_a) {
            orow.copy_from_slice(bias);
        }
        let (o0, rest) = chunk.split_at_mut(cout_a);
        let (o1, rest) = rest.split_at_mut(cout_a);
        let (o2, o3) = rest.split_at_mut(cout_a);
        let c0 = &col[r * kk..(r + 1) * kk];
        let c1 = &col[(r + 1) * kk..(r + 2) * kk];
        let c2 = &col[(r + 2) * kk..(r + 3) * kk];
        let c3 = &col[(r + 3) * kk..(r + 4) * kk];
        for j in 0..kk {
            let wrow = &conv.w[ro[j]..ro[j] + cout_a];
            let (x0, x1, x2, x3) = (c0[j], c1[j], c2[j], c3[j]);
            for (co, &wv) in wrow.iter().enumerate() {
                o0[co] += x0 * wv;
                o1[co] += x1 * wv;
                o2[co] += x2 * wv;
                o3[co] += x3 * wv;
            }
        }
        r += MR;
    }
    while r < rows {
        let orow = &mut out[r * cout_a..(r + 1) * cout_a];
        orow.copy_from_slice(bias);
        let crow = &col[r * kk..(r + 1) * kk];
        for (j, &xv) in crow.iter().enumerate() {
            let wrow = &conv.w[ro[j]..ro[j] + cout_a];
            for (co, &wv) in wrow.iter().enumerate() {
                orow[co] += xv * wv;
            }
        }
        r += 1;
    }
}

/// conv SAME + bias — allocating wrapper over [`conv_fwd_scratch`] (the
/// hot loops hold a per-worker [`Scratch`] instead).
pub fn conv_fwd(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    conv: &Conv,
    cin_a: usize,
    cout_a: usize,
) -> Vec<f32> {
    let mut sc = Scratch::new();
    let mut out = Vec::new();
    conv_fwd_scratch(&mut sc, x, n, h, w, conv, cin_a, cout_a, &mut out);
    out
}

/// conv SAME backward — blocked twin of [`super::tensor_ref::conv_bwd`]:
/// given `dpre` (gradient at the pre-activation), accumulate weight/bias
/// grads into the full-size `gw`/`gb` buffers (active slice only — gated
/// filters stay untouched) and write `dx` (left empty when
/// `compute_dx` is false: the first block's input gradient has no
/// consumer and its feature map is the largest in the net).
///
/// Reduction orders (all matching the reference bit-for-bit):
/// * `gb[co]`, `gw[j][co]`: output pixels `(s, oy, ox)` ascending — the
///   pixel loop stays outermost and accumulates straight into the
///   buffers, so no per-tile partials ever get merged;
/// * `dx[e]`: pixels ascending, then `co` ascending — the per-`co`
///   scatter adds each `w·g` term directly, as the reference does.
/// Zero skips (`xv == 0.0` patch columns, `g == 0.0` lanes) drop only
/// inert `±0.0` terms — exactness argued in DESIGN.md §11.
#[allow(clippy::too_many_arguments)]
pub fn conv_bwd_scratch(
    sc: &mut Scratch,
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    conv: &Conv,
    cin_a: usize,
    cout_a: usize,
    dpre: &[f32],
    gw: &mut [f32],
    gb: &mut [f32],
    compute_dx: bool,
    dx: &mut Vec<f32>,
) {
    debug_assert_eq!(gw.len(), conv.w.len());
    debug_assert_eq!(gb.len(), conv.b.len());
    let k = conv.k;
    let pad = k / 2;
    let kk = k * k * cin_a;
    im2col(x, n, h, w, cin_a, k, &mut sc.col);
    fill_row_off(&mut sc.row_off, conv, cin_a);
    dx.clear();
    dx.resize(if compute_dx { n * h * w * cin_a } else { 0 }, 0.0);
    if compute_dx {
        // transposed active weights: wt[co][j] with j = (ky, kx, ci)
        // packed — contiguous ci runs for the saxpy scatter below
        sc.wt.clear();
        sc.wt.resize(cout_a * kk, 0.0);
        for co in 0..cout_a {
            let wtr = &mut sc.wt[co * kk..(co + 1) * kk];
            for (j, wv) in wtr.iter_mut().enumerate() {
                *wv = conv.w[sc.row_off[j] + co];
            }
        }
    }
    let col = &sc.col;
    let ro = &sc.row_off;
    let gbs = &mut gb[..cout_a];

    let mut r = 0usize;
    for s in 0..n {
        for oy in 0..h {
            for ox in 0..w {
                let g = &dpre[r * cout_a..(r + 1) * cout_a];
                for (co, &gv) in g.iter().enumerate() {
                    gbs[co] += gv;
                }
                let crow = &col[r * kk..(r + 1) * kk];
                for (j, &xv) in crow.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let grow = &mut gw[ro[j]..ro[j] + cout_a];
                    for (co, &gv) in g.iter().enumerate() {
                        grow[co] += xv * gv;
                    }
                }
                if compute_dx {
                    let kx_lo = pad.saturating_sub(ox);
                    let kx_hi = k.min(w + pad - ox);
                    let ix_lo = ox + kx_lo - pad;
                    for (co, &gv) in g.iter().enumerate() {
                        if gv == 0.0 {
                            continue;
                        }
                        let wtr = &sc.wt[co * kk..(co + 1) * kk];
                        for ky in 0..k {
                            let iy = oy + ky;
                            if iy < pad || iy - pad >= h {
                                continue;
                            }
                            let iy = iy - pad;
                            let dseg = &mut dx[((s * h + iy) * w + ix_lo) * cin_a..]
                                [..(kx_hi - kx_lo) * cin_a];
                            let wseg =
                                &wtr[(ky * k + kx_lo) * cin_a..(ky * k + kx_hi) * cin_a];
                            for (dv, &wv) in dseg.iter_mut().zip(wseg) {
                                *dv += gv * wv;
                            }
                        }
                    }
                }
                r += 1;
            }
        }
    }
}

/// conv SAME backward — allocating wrapper over [`conv_bwd_scratch`].
#[allow(clippy::too_many_arguments)]
pub fn conv_bwd(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    conv: &Conv,
    cin_a: usize,
    cout_a: usize,
    dpre: &[f32],
    gw: &mut [f32],
    gb: &mut [f32],
    compute_dx: bool,
) -> Vec<f32> {
    let mut sc = Scratch::new();
    let mut dx = Vec::new();
    conv_bwd_scratch(&mut sc, x, n, h, w, conv, cin_a, cout_a, dpre, gw, gb, compute_dx, &mut dx);
    dx
}

/// 2x2 max-pool, stride 2 (odd trailing row/col dropped, matching the
/// reference kernels). Returns the pooled tensor and the argmax index of
/// every output element (flat index into the input) for the backward
/// routing.
pub fn pool_fwd(x: &[f32], n: usize, h: usize, w: usize, c: usize) -> (Vec<f32>, Vec<u32>) {
    let ho = h / 2;
    let wo = w / 2;
    let mut out = vec![0.0f32; n * ho * wo * c];
    let mut idx = vec![0u32; n * ho * wo * c];
    for s in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bi = 0usize;
                    for dy in 0..2 {
                        for dx_ in 0..2 {
                            let i = ((s * h + oy * 2 + dy) * w + ox * 2 + dx_) * c + ch;
                            // strict `>` keeps the first (top-left) max —
                            // a fixed, deterministic tie-break
                            if x[i] > best {
                                best = x[i];
                                bi = i;
                            }
                        }
                    }
                    let o = ((s * ho + oy) * wo + ox) * c + ch;
                    out[o] = best;
                    idx[o] = bi as u32;
                }
            }
        }
    }
    (out, idx)
}

/// 2x2 max-pool without the argmax bookkeeping — the inference path
/// (teacher logits, accuracy evaluation), where no backward follows.
/// Values are identical to [`pool_fwd`]'s output.
pub fn pool_max(x: &[f32], n: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let ho = h / 2;
    let wo = w / 2;
    let mut out = vec![0.0f32; n * ho * wo * c];
    for s in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx_ in 0..2 {
                            let i = ((s * h + oy * 2 + dy) * w + ox * 2 + dx_) * c + ch;
                            if x[i] > best {
                                best = x[i];
                            }
                        }
                    }
                    out[((s * ho + oy) * wo + ox) * c + ch] = best;
                }
            }
        }
    }
    out
}

/// Max-pool backward: route each output gradient to its argmax input.
pub fn pool_bwd(dout: &[f32], idx: &[u32], in_len: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; in_len];
    for (g, &i) in dout.iter().zip(idx) {
        dx[i as usize] += g;
    }
    dx
}

/// Dense head forward into a reusable buffer:
/// `[n, dim] x [dim, classes] + b`. Already a saxpy over the contiguous
/// `classes` lane with `d` ascending per accumulator (the reference
/// order); the zero-row skip exploits post-ReLU/post-pool sparsity.
pub fn fc_fwd_into(x: &[f32], n: usize, head: &Dense, out: &mut Vec<f32>) {
    let (dim, classes) = (head.dim, head.classes);
    debug_assert_eq!(x.len(), n * dim);
    out.clear();
    out.resize(n * classes, 0.0);
    for s in 0..n {
        let row = &x[s * dim..(s + 1) * dim];
        let o = &mut out[s * classes..(s + 1) * classes];
        o.copy_from_slice(&head.b);
        for (d, &xv) in row.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &head.w[d * classes..(d + 1) * classes];
            for (c, &wv) in wrow.iter().enumerate() {
                o[c] += xv * wv;
            }
        }
    }
}

/// Dense head forward — allocating wrapper over [`fc_fwd_into`].
pub fn fc_fwd(x: &[f32], n: usize, head: &Dense) -> Vec<f32> {
    let mut out = Vec::new();
    fc_fwd_into(x, n, head, &mut out);
    out
}

/// Dense head backward — blocked twin of
/// [`super::tensor_ref::fc_bwd`]: accumulates into `gw`/`gb`, writes
/// `dx`. The combined reference loop is split into a vectorizable
/// `gw` saxpy (contiguous `classes` lane, `s` ascending per element)
/// and a transposed-weight `dx` saxpy (contiguous `dim` lane, `c`
/// ascending per element — the reference's inner-dot order).
pub fn fc_bwd_scratch(
    sc: &mut Scratch,
    x: &[f32],
    n: usize,
    head: &Dense,
    dlogits: &[f32],
    gw: &mut [f32],
    gb: &mut [f32],
    dx: &mut Vec<f32>,
) {
    let (dim, classes) = (head.dim, head.classes);
    dx.clear();
    dx.resize(n * dim, 0.0);
    // transposed head weights: wt[c][d]
    sc.wt.clear();
    sc.wt.resize(classes * dim, 0.0);
    for (d, wrow) in head.w.chunks_exact(classes).enumerate() {
        for (c, &wv) in wrow.iter().enumerate() {
            sc.wt[c * dim + d] = wv;
        }
    }
    for s in 0..n {
        let row = &x[s * dim..(s + 1) * dim];
        let g = &dlogits[s * classes..(s + 1) * classes];
        for (c, &gv) in g.iter().enumerate() {
            gb[c] += gv;
        }
        for (d, &xv) in row.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let grow = &mut gw[d * classes..(d + 1) * classes];
            for (c, &gv) in g.iter().enumerate() {
                grow[c] += xv * gv;
            }
        }
        let dxrow = &mut dx[s * dim..(s + 1) * dim];
        for (c, &gv) in g.iter().enumerate() {
            if gv == 0.0 {
                continue;
            }
            let wtr = &sc.wt[c * dim..(c + 1) * dim];
            for (dv, &wv) in dxrow.iter_mut().zip(wtr) {
                *dv += gv * wv;
            }
        }
    }
}

/// Dense head backward — allocating wrapper over [`fc_bwd_scratch`].
pub fn fc_bwd(
    x: &[f32],
    n: usize,
    head: &Dense,
    dlogits: &[f32],
    gw: &mut [f32],
    gb: &mut [f32],
) -> Vec<f32> {
    let mut sc = Scratch::new();
    let mut dx = Vec::new();
    fc_bwd_scratch(&mut sc, x, n, head, dlogits, gw, gb, &mut dx);
    dx
}

/// In-place ReLU; returns the output (pre-activation left in `pre`).
pub fn relu(pre: &[f32]) -> Vec<f32> {
    pre.iter().map(|&v| if v > 0.0 { v } else { 0.0 }).collect()
}

/// ReLU backward mask: `dpre = dpost * [pre > 0]`.
pub fn relu_bwd(pre: &[f32], dpost: &[f32]) -> Vec<f32> {
    pre.iter()
        .zip(dpost)
        .map(|(&p, &g)| if p > 0.0 { g } else { 0.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv1() -> Conv {
        // 1x1 identity-ish kernel on 1 channel: w = 2, b = 1
        Conv { w: vec![2.0], b: vec![1.0], k: 1, cin: 1, cout: 1 }
    }

    #[test]
    fn conv_1x1_scales_and_biases() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let y = conv_fwd(&x, 1, 2, 2, &conv1(), 1, 1);
        assert_eq!(y, vec![3.0, 5.0, 7.0, 9.0]);
    }

    #[test]
    fn conv_same_padding_border() {
        // 3x3 all-ones kernel on a 2x2 of ones: corners see 4 taps
        let c = Conv { w: vec![1.0; 9], b: vec![0.0], k: 3, cin: 1, cout: 1 };
        let y = conv_fwd(&[1.0; 4], 1, 2, 2, &c, 1, 1);
        assert_eq!(y, vec![4.0; 4]);
    }

    #[test]
    fn im2col_packs_padded_patches() {
        // 2x2 single-channel image, 3x3 patches: center-of-kernel is the
        // pixel itself; corners of the patch fall outside -> zeros
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut col = Vec::new();
        im2col(&x, 1, 2, 2, 1, 3, &mut col);
        assert_eq!(col.len(), 4 * 9);
        // patch at (0,0): only (ky,kx) in {(1,1),(1,2),(2,1),(2,2)} valid
        assert_eq!(&col[..9], &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
        // patch at (1,1): top-left quadrant valid
        assert_eq!(&col[27..36], &[1.0, 2.0, 0.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn conv_grad_matches_finite_difference() {
        // tiny 3x3 input, 3x3 kernel, 2 in / 2 out channels
        let (h, w, cin, cout) = (3usize, 3usize, 2usize, 2usize);
        let mut conv = Conv {
            w: (0..9 * cin * cout).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.1).collect(),
            b: vec![0.05, -0.05],
            k: 3,
            cin,
            cout,
        };
        let x: Vec<f32> = (0..h * w * cin).map(|i| ((i * 5 % 11) as f32 - 5.0) * 0.2).collect();
        // loss = sum(conv(x)) -> dpre = 1 everywhere
        let dpre = vec![1.0f32; h * w * cout];
        let mut gw = vec![0.0f32; conv.w.len()];
        let mut gb = vec![0.0f32; conv.b.len()];
        let dx = conv_bwd(&x, 1, h, w, &conv, cin, cout, &dpre, &mut gw, &mut gb, true);
        // compute_dx=false: same weight grads, empty dx
        let mut gw2 = vec![0.0f32; conv.w.len()];
        let mut gb2 = vec![0.0f32; conv.b.len()];
        let dx2 = conv_bwd(&x, 1, h, w, &conv, cin, cout, &dpre, &mut gw2, &mut gb2, false);
        assert_eq!(gw, gw2);
        assert_eq!(gb, gb2);
        assert!(dx2.is_empty());
        let loss = |c: &Conv, xv: &[f32]| -> f64 {
            conv_fwd(xv, 1, h, w, c, cin, cout).iter().map(|&v| v as f64).sum()
        };
        let eps = 1e-2f32;
        // spot-check a few weight grads
        for wi in [0usize, 7, 17, conv.w.len() - 1] {
            let orig = conv.w[wi];
            conv.w[wi] = orig + eps;
            let up = loss(&conv, &x);
            conv.w[wi] = orig - eps;
            let dn = loss(&conv, &x);
            conv.w[wi] = orig;
            let fd = (up - dn) / (2.0 * eps as f64);
            assert!((fd - gw[wi] as f64).abs() < 1e-2, "w[{wi}]: fd {fd} vs {}", gw[wi]);
        }
        // and an input grad
        let mut x2 = x.clone();
        x2[4] += eps;
        let up = loss(&conv, &x2);
        x2[4] = x[4] - eps;
        let dn = loss(&conv, &x2);
        let fd = (up - dn) / (2.0 * eps as f64);
        assert!((fd - dx[4] as f64).abs() < 1e-2, "dx: fd {fd} vs {}", dx[4]);
        assert_eq!(gb, vec![9.0, 9.0]); // 9 output pixels per channel
    }

    #[test]
    fn pool_takes_max_and_routes_grad() {
        // 2x2 single-channel: max at position 3
        let x = vec![0.1f32, 0.2, 0.3, 0.9];
        let (y, idx) = pool_fwd(&x, 1, 2, 2, 1);
        assert_eq!(y, vec![0.9]);
        assert_eq!(idx, vec![3]);
        let dx = pool_bwd(&[2.0], &idx, 4);
        assert_eq!(dx, vec![0.0, 0.0, 0.0, 2.0]);
    }

    #[test]
    fn pool_drops_odd_edge() {
        let x = vec![1.0f32; 3 * 3];
        let (y, _) = pool_fwd(&x, 1, 3, 3, 1);
        assert_eq!(y.len(), 1);
    }

    #[test]
    fn fc_fwd_bwd_consistent() {
        let head = Dense {
            w: vec![0.5, -0.5, 0.25, 0.75],
            b: vec![0.1, -0.1],
            dim: 2,
            classes: 2,
        };
        let x = vec![1.0f32, 2.0];
        let y = fc_fwd(&x, 1, &head);
        assert!((y[0] - (0.1 + 0.5 + 0.5)).abs() < 1e-6);
        assert!((y[1] - (-0.1 - 0.5 + 1.5)).abs() < 1e-6);
        let mut gw = vec![0.0f32; 4];
        let mut gb = vec![0.0f32; 2];
        let dx = fc_bwd(&x, 1, &head, &[1.0, 0.0], &mut gw, &mut gb);
        assert_eq!(gb, vec![1.0, 0.0]);
        assert_eq!(gw, vec![1.0, 0.0, 2.0, 0.0]);
        assert_eq!(dx, vec![0.5, 0.25]);
    }

    #[test]
    fn relu_masks_negative() {
        let pre = vec![-1.0f32, 0.0, 2.0];
        assert_eq!(relu(&pre), vec![0.0, 0.0, 2.0]);
        assert_eq!(relu_bwd(&pre, &[5.0, 5.0, 5.0]), vec![0.0, 0.0, 5.0]);
    }

    /// Blocked kernels vs the retained scalar reference on one awkward
    /// geometry (the exhaustive random sweep lives in
    /// `tests/prop_invariants.rs`).
    #[test]
    fn blocked_matches_reference_smoke() {
        use super::super::tensor_ref;
        let (n, h, w, cin, cout, cin_a, cout_a) = (2usize, 5, 3, 3, 4, 2, 3);
        let conv = Conv {
            w: (0..9 * cin * cout).map(|i| ((i * 37 % 41) as f32 - 20.0) * 0.07).collect(),
            b: (0..cout).map(|i| (i as f32 - 1.0) * 0.11).collect(),
            k: 3,
            cin,
            cout,
        };
        let x: Vec<f32> = (0..n * h * w * cin_a)
            .map(|i| if i % 5 == 0 { 0.0 } else { ((i * 13 % 23) as f32 - 11.0) * 0.09 })
            .collect();
        let fwd = conv_fwd(&x, n, h, w, &conv, cin_a, cout_a);
        let fwd_ref = tensor_ref::conv_fwd(&x, n, h, w, &conv, cin_a, cout_a);
        assert_eq!(fwd, fwd_ref);
        let dpre: Vec<f32> = (0..n * h * w * cout_a)
            .map(|i| if i % 4 == 0 { 0.0 } else { ((i * 7 % 19) as f32 - 9.0) * 0.05 })
            .collect();
        let (mut gw, mut gb) = (vec![0.0f32; conv.w.len()], vec![0.0f32; conv.b.len()]);
        let (mut gw2, mut gb2) = (gw.clone(), gb.clone());
        let dx = conv_bwd(&x, n, h, w, &conv, cin_a, cout_a, &dpre, &mut gw, &mut gb, true);
        let dx_ref =
            tensor_ref::conv_bwd(&x, n, h, w, &conv, cin_a, cout_a, &dpre, &mut gw2, &mut gb2, true);
        assert_eq!(dx, dx_ref);
        assert_eq!(gw, gw2);
        assert_eq!(gb, gb2);
    }
}
