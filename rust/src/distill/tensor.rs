//! Deterministic tensor core for the DistillCycle trainer.
//!
//! Flat `Vec<f32>` NHWC tensors with explicit dims and plain loop nests —
//! no BLAS, no threads, no SIMD intrinsics — so every training run is a
//! single fixed sequence of f32 operations: bit-identical across reruns
//! and independent of whatever `--threads N` the rest of the pipeline
//! uses. The ops mirror `python/compile/kernels/ref.py`: conv3x3 SAME,
//! ReLU, 2x2 max-pool (stride 2, odd edge dropped) and a dense head.
//!
//! Width-morphing follows `model.py::slice_block`: weight buffers are
//! allocated at full width and the active `(cin, cout)` slice is indexed
//! directly, so gated filters are never touched — the software twin of
//! clock-gated PEs never toggling.

/// One morphable conv block's parameters (full-width storage).
#[derive(Debug, Clone)]
pub struct Conv {
    /// `[k, k, cin, cout]` weights, row-major
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub k: usize,
    pub cin: usize,
    pub cout: usize,
}

impl Conv {
    #[inline]
    pub fn widx(&self, ky: usize, kx: usize, ci: usize, co: usize) -> usize {
        ((ky * self.k + kx) * self.cin + ci) * self.cout + co
    }
}

/// One execution path's dense output head.
#[derive(Debug, Clone)]
pub struct Dense {
    /// `[dim, classes]` weights, row-major
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub dim: usize,
    pub classes: usize,
}

/// conv SAME + bias over the active `(cin_a, cout_a)` slice.
/// Input `x` is `[n, h, w, cin_a]` (activations are stored compact at the
/// active width); output is the pre-activation `[n, h, w, cout_a]`.
pub fn conv_fwd(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    conv: &Conv,
    cin_a: usize,
    cout_a: usize,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * h * w * cin_a);
    let k = conv.k;
    let pad = k / 2;
    let mut out = vec![0.0f32; n * h * w * cout_a];
    for s in 0..n {
        for oy in 0..h {
            for ox in 0..w {
                let obase = ((s * h + oy) * w + ox) * cout_a;
                for co in 0..cout_a {
                    let mut acc = conv.b[co];
                    for ky in 0..k {
                        let iy = oy + ky;
                        if iy < pad || iy - pad >= h {
                            continue;
                        }
                        let iy = iy - pad;
                        for kx in 0..k {
                            let ix = ox + kx;
                            if ix < pad || ix - pad >= w {
                                continue;
                            }
                            let ix = ix - pad;
                            let ibase = ((s * h + iy) * w + ix) * cin_a;
                            for ci in 0..cin_a {
                                acc += x[ibase + ci] * conv.w[conv.widx(ky, kx, ci, co)];
                            }
                        }
                    }
                    out[obase + co] = acc;
                }
            }
        }
    }
    out
}

/// conv SAME backward: given `dpre` (gradient at the pre-activation),
/// accumulate weight/bias grads into the full-size `gw`/`gb` buffers
/// (active slice only — gated filters stay untouched) and return `dx`.
/// `compute_dx: false` (the first block, whose input gradient nobody
/// consumes) skips the propagation accumulation — it runs over the
/// largest feature map in the net — and returns an empty vec.
#[allow(clippy::too_many_arguments)]
pub fn conv_bwd(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    conv: &Conv,
    cin_a: usize,
    cout_a: usize,
    dpre: &[f32],
    gw: &mut [f32],
    gb: &mut [f32],
    compute_dx: bool,
) -> Vec<f32> {
    debug_assert_eq!(gw.len(), conv.w.len());
    debug_assert_eq!(gb.len(), conv.b.len());
    let k = conv.k;
    let pad = k / 2;
    let mut dx = vec![0.0f32; if compute_dx { n * h * w * cin_a } else { 0 }];
    for s in 0..n {
        for oy in 0..h {
            for ox in 0..w {
                let obase = ((s * h + oy) * w + ox) * cout_a;
                for co in 0..cout_a {
                    let g = dpre[obase + co];
                    if g == 0.0 {
                        continue;
                    }
                    gb[co] += g;
                    for ky in 0..k {
                        let iy = oy + ky;
                        if iy < pad || iy - pad >= h {
                            continue;
                        }
                        let iy = iy - pad;
                        for kx in 0..k {
                            let ix = ox + kx;
                            if ix < pad || ix - pad >= w {
                                continue;
                            }
                            let ix = ix - pad;
                            let ibase = ((s * h + iy) * w + ix) * cin_a;
                            for ci in 0..cin_a {
                                gw[conv.widx(ky, kx, ci, co)] += x[ibase + ci] * g;
                                if compute_dx {
                                    dx[ibase + ci] += conv.w[conv.widx(ky, kx, ci, co)] * g;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    dx
}

/// 2x2 max-pool, stride 2 (odd trailing row/col dropped, matching the
/// reference kernels). Returns the pooled tensor and the argmax index of
/// every output element (flat index into the input) for the backward
/// routing.
pub fn pool_fwd(x: &[f32], n: usize, h: usize, w: usize, c: usize) -> (Vec<f32>, Vec<u32>) {
    let ho = h / 2;
    let wo = w / 2;
    let mut out = vec![0.0f32; n * ho * wo * c];
    let mut idx = vec![0u32; n * ho * wo * c];
    for s in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bi = 0usize;
                    for dy in 0..2 {
                        for dx_ in 0..2 {
                            let i = ((s * h + oy * 2 + dy) * w + ox * 2 + dx_) * c + ch;
                            // strict `>` keeps the first (top-left) max —
                            // a fixed, deterministic tie-break
                            if x[i] > best {
                                best = x[i];
                                bi = i;
                            }
                        }
                    }
                    let o = ((s * ho + oy) * wo + ox) * c + ch;
                    out[o] = best;
                    idx[o] = bi as u32;
                }
            }
        }
    }
    (out, idx)
}

/// 2x2 max-pool without the argmax bookkeeping — the inference path
/// (teacher logits, accuracy evaluation), where no backward follows.
/// Values are identical to [`pool_fwd`]'s output.
pub fn pool_max(x: &[f32], n: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let ho = h / 2;
    let wo = w / 2;
    let mut out = vec![0.0f32; n * ho * wo * c];
    for s in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx_ in 0..2 {
                            let i = ((s * h + oy * 2 + dy) * w + ox * 2 + dx_) * c + ch;
                            if x[i] > best {
                                best = x[i];
                            }
                        }
                    }
                    out[((s * ho + oy) * wo + ox) * c + ch] = best;
                }
            }
        }
    }
    out
}

/// Max-pool backward: route each output gradient to its argmax input.
pub fn pool_bwd(dout: &[f32], idx: &[u32], in_len: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; in_len];
    for (g, &i) in dout.iter().zip(idx) {
        dx[i as usize] += g;
    }
    dx
}

/// Dense head forward: `[n, dim] x [dim, classes] + b`.
pub fn fc_fwd(x: &[f32], n: usize, head: &Dense) -> Vec<f32> {
    let (dim, classes) = (head.dim, head.classes);
    debug_assert_eq!(x.len(), n * dim);
    let mut out = vec![0.0f32; n * classes];
    for s in 0..n {
        let row = &x[s * dim..(s + 1) * dim];
        let o = &mut out[s * classes..(s + 1) * classes];
        o.copy_from_slice(&head.b);
        for (d, &xv) in row.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &head.w[d * classes..(d + 1) * classes];
            for (c, &wv) in wrow.iter().enumerate() {
                o[c] += xv * wv;
            }
        }
    }
    out
}

/// Dense head backward: accumulates into `gw`/`gb`, returns `dx`.
pub fn fc_bwd(
    x: &[f32],
    n: usize,
    head: &Dense,
    dlogits: &[f32],
    gw: &mut [f32],
    gb: &mut [f32],
) -> Vec<f32> {
    let (dim, classes) = (head.dim, head.classes);
    let mut dx = vec![0.0f32; n * dim];
    for s in 0..n {
        let row = &x[s * dim..(s + 1) * dim];
        let g = &dlogits[s * classes..(s + 1) * classes];
        for (c, &gv) in g.iter().enumerate() {
            gb[c] += gv;
        }
        for (d, &xv) in row.iter().enumerate() {
            let wrow = &head.w[d * classes..(d + 1) * classes];
            let mut acc = 0.0f32;
            for (c, &gv) in g.iter().enumerate() {
                gw[d * classes + c] += xv * gv;
                acc += wrow[c] * gv;
            }
            dx[s * dim + d] = acc;
        }
    }
    dx
}

/// In-place ReLU; returns the output (pre-activation left in `pre`).
pub fn relu(pre: &[f32]) -> Vec<f32> {
    pre.iter().map(|&v| if v > 0.0 { v } else { 0.0 }).collect()
}

/// ReLU backward mask: `dpre = dpost * [pre > 0]`.
pub fn relu_bwd(pre: &[f32], dpost: &[f32]) -> Vec<f32> {
    pre.iter()
        .zip(dpost)
        .map(|(&p, &g)| if p > 0.0 { g } else { 0.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv1() -> Conv {
        // 1x1 identity-ish kernel on 1 channel: w = 2, b = 1
        Conv { w: vec![2.0], b: vec![1.0], k: 1, cin: 1, cout: 1 }
    }

    #[test]
    fn conv_1x1_scales_and_biases() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let y = conv_fwd(&x, 1, 2, 2, &conv1(), 1, 1);
        assert_eq!(y, vec![3.0, 5.0, 7.0, 9.0]);
    }

    #[test]
    fn conv_same_padding_border() {
        // 3x3 all-ones kernel on a 2x2 of ones: corners see 4 taps
        let c = Conv { w: vec![1.0; 9], b: vec![0.0], k: 3, cin: 1, cout: 1 };
        let y = conv_fwd(&[1.0; 4], 1, 2, 2, &c, 1, 1);
        assert_eq!(y, vec![4.0; 4]);
    }

    #[test]
    fn conv_grad_matches_finite_difference() {
        // tiny 3x3 input, 3x3 kernel, 2 in / 2 out channels
        let (h, w, cin, cout) = (3usize, 3usize, 2usize, 2usize);
        let mut conv = Conv {
            w: (0..9 * cin * cout).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.1).collect(),
            b: vec![0.05, -0.05],
            k: 3,
            cin,
            cout,
        };
        let x: Vec<f32> = (0..h * w * cin).map(|i| ((i * 5 % 11) as f32 - 5.0) * 0.2).collect();
        // loss = sum(conv(x)) -> dpre = 1 everywhere
        let dpre = vec![1.0f32; h * w * cout];
        let mut gw = vec![0.0f32; conv.w.len()];
        let mut gb = vec![0.0f32; conv.b.len()];
        let dx = conv_bwd(&x, 1, h, w, &conv, cin, cout, &dpre, &mut gw, &mut gb, true);
        // compute_dx=false: same weight grads, empty dx
        let mut gw2 = vec![0.0f32; conv.w.len()];
        let mut gb2 = vec![0.0f32; conv.b.len()];
        let dx2 = conv_bwd(&x, 1, h, w, &conv, cin, cout, &dpre, &mut gw2, &mut gb2, false);
        assert_eq!(gw, gw2);
        assert_eq!(gb, gb2);
        assert!(dx2.is_empty());
        let loss = |c: &Conv, xv: &[f32]| -> f64 {
            conv_fwd(xv, 1, h, w, c, cin, cout).iter().map(|&v| v as f64).sum()
        };
        let eps = 1e-2f32;
        // spot-check a few weight grads
        for wi in [0usize, 7, 17, conv.w.len() - 1] {
            let orig = conv.w[wi];
            conv.w[wi] = orig + eps;
            let up = loss(&conv, &x);
            conv.w[wi] = orig - eps;
            let dn = loss(&conv, &x);
            conv.w[wi] = orig;
            let fd = (up - dn) / (2.0 * eps as f64);
            assert!((fd - gw[wi] as f64).abs() < 1e-2, "w[{wi}]: fd {fd} vs {}", gw[wi]);
        }
        // and an input grad
        let mut x2 = x.clone();
        x2[4] += eps;
        let up = loss(&conv, &x2);
        x2[4] = x[4] - eps;
        let dn = loss(&conv, &x2);
        let fd = (up - dn) / (2.0 * eps as f64);
        assert!((fd - dx[4] as f64).abs() < 1e-2, "dx: fd {fd} vs {}", dx[4]);
        assert_eq!(gb, vec![9.0, 9.0]); // 9 output pixels per channel
    }

    #[test]
    fn pool_takes_max_and_routes_grad() {
        // 2x2 single-channel: max at position 3
        let x = vec![0.1f32, 0.2, 0.3, 0.9];
        let (y, idx) = pool_fwd(&x, 1, 2, 2, 1);
        assert_eq!(y, vec![0.9]);
        assert_eq!(idx, vec![3]);
        let dx = pool_bwd(&[2.0], &idx, 4);
        assert_eq!(dx, vec![0.0, 0.0, 0.0, 2.0]);
    }

    #[test]
    fn pool_drops_odd_edge() {
        let x = vec![1.0f32; 3 * 3];
        let (y, _) = pool_fwd(&x, 1, 3, 3, 1);
        assert_eq!(y.len(), 1);
    }

    #[test]
    fn fc_fwd_bwd_consistent() {
        let head = Dense {
            w: vec![0.5, -0.5, 0.25, 0.75],
            b: vec![0.1, -0.1],
            dim: 2,
            classes: 2,
        };
        let x = vec![1.0f32, 2.0];
        let y = fc_fwd(&x, 1, &head);
        assert!((y[0] - (0.1 + 0.5 + 0.5)).abs() < 1e-6);
        assert!((y[1] - (-0.1 - 0.5 + 1.5)).abs() < 1e-6);
        let mut gw = vec![0.0f32; 4];
        let mut gb = vec![0.0f32; 2];
        let dx = fc_bwd(&x, 1, &head, &[1.0, 0.0], &mut gw, &mut gb);
        assert_eq!(gb, vec![1.0, 0.0]);
        assert_eq!(gw, vec![1.0, 0.0, 2.0, 0.0]);
        assert_eq!(dx, vec![0.5, 0.25]);
    }

    #[test]
    fn relu_masks_negative() {
        let pre = vec![-1.0f32, 0.0, 2.0];
        assert_eq!(relu(&pre), vec![0.0, 0.0, 2.0]);
        assert_eq!(relu_bwd(&pre, &[5.0, 5.0, 5.0]), vec![0.0, 0.0, 5.0]);
    }
}
