//! Deterministic bounded-retry backoff.
//!
//! Retry instants must be a pure function of `(request id, attempt)` so
//! the canonical fault log — and therefore CI's byte-diff across
//! `--workers 1` vs `--workers 4` — never depends on which worker
//! performs the retry or when it gets scheduled in host time. Jitter
//! comes from a per-(id, attempt) seeded [`Rng`] stream, not a shared
//! mutable one.

use crate::util::rng::Rng;

/// Exponential backoff with seeded, per-request deterministic jitter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Max retries after the first attempt (attempts = max_retries + 1).
    pub max_retries: u32,
    /// First backoff delay, on the virtual clock, in milliseconds.
    pub base_ms: f64,
    /// Multiplier per further retry.
    pub factor: f64,
    /// Symmetric jitter fraction in `[0, 1)`: delay = nominal * (1 ± j).
    pub jitter_pct: f64,
    /// Seed folded into every per-request jitter stream.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 2, base_ms: 0.5, factor: 2.0, jitter_pct: 0.25, seed: 42 }
    }
}

impl RetryPolicy {
    /// Backoff delay before retry number `attempt` (0-based: the delay
    /// between the first failure and the first retry is `attempt == 0`).
    ///
    /// Pure in `(self, request_id, attempt)` — same inputs, same delay,
    /// on any worker, at any worker count.
    pub fn backoff_ms(&self, request_id: u64, attempt: u32) -> f64 {
        let nominal = self.base_ms * self.factor.powi(attempt as i32);
        if self.jitter_pct == 0.0 {
            return nominal;
        }
        let mut rng = Rng::new(
            self.seed
                ^ request_id.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (attempt as u64).wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        nominal * (1.0 + self.jitter_pct * (2.0 * rng.f64() - 1.0))
    }

    /// Cumulative retry instants (ms after the original failure) for the
    /// first `retries` retries of `request_id`.
    pub fn instants_ms(&self, request_id: u64, retries: u32) -> Vec<f64> {
        let mut t = 0.0;
        (0..retries)
            .map(|a| {
                t += self.backoff_ms(request_id, a);
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_pure_per_id_and_attempt() {
        let p = RetryPolicy::default();
        for id in [1u64, 7, 900] {
            for attempt in 0..3 {
                assert_eq!(p.backoff_ms(id, attempt), p.backoff_ms(id, attempt));
            }
        }
        // distinct requests jitter independently
        assert_ne!(p.backoff_ms(1, 0), p.backoff_ms(2, 0));
    }

    #[test]
    fn instants_are_strictly_increasing_and_bounded() {
        let p = RetryPolicy::default();
        let ts = p.instants_ms(11, 3);
        assert_eq!(ts.len(), 3);
        let mut prev = 0.0;
        for (a, &t) in ts.iter().enumerate() {
            assert!(t > prev, "instant {a} not increasing: {ts:?}");
            prev = t;
        }
        // each delay within nominal * (1 ± jitter)
        let d0 = ts[0];
        assert!(d0 >= p.base_ms * (1.0 - p.jitter_pct) && d0 <= p.base_ms * (1.0 + p.jitter_pct));
    }

    #[test]
    fn zero_jitter_is_exactly_exponential() {
        let p = RetryPolicy { jitter_pct: 0.0, ..RetryPolicy::default() };
        assert_eq!(p.backoff_ms(5, 0), 0.5);
        assert_eq!(p.backoff_ms(5, 1), 1.0);
        assert_eq!(p.backoff_ms(5, 2), 2.0);
    }

    #[test]
    fn seed_changes_jitter() {
        let a = RetryPolicy::default();
        let b = RetryPolicy { seed: 43, ..a };
        assert_ne!(a.backoff_ms(3, 0), b.backoff_ms(3, 0));
    }
}
