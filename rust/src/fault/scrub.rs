//! CRC scrubbing over the loaded path manifest / gate state.
//!
//! FPGA configuration memory takes SEU bit-flips; the classic mitigation
//! is a periodic scrubber that walks the configuration frames, compares
//! a CRC against a golden copy, and rewrites corrupted frames. We model
//! the NeuroMorph-relevant slice of that state — which morph path is
//! loaded (the gate/manifest word) — as a small byte image protected by
//! CRC-32 and a golden shadow. [`ScrubbedState::flip_bit`] is the SEU
//! injection point; [`ScrubbedState::scrub`] is the repair pass.

/// Bitwise CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320).
///
/// Table-free on purpose: the state image is a handful of bytes and the
/// scrubber runs once per scrub period, so clarity beats table setup.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Encode the active morph-path index as the protected gate-state image.
///
/// Layout: index as little-endian `u32`, then a fixed pad of config-frame
/// filler so single-bit SEUs usually land outside the index word too
/// (silent-until-scrubbed corruption, like real configuration memory).
pub fn encode_gate_state(index: usize) -> Vec<u8> {
    let mut bytes = (index as u32).to_le_bytes().to_vec();
    bytes.extend_from_slice(&[0xA5, 0x5A, 0xC3, 0x3C]);
    bytes
}

/// Decode the path index back out of a (possibly corrupted) image.
pub fn decode_index(bytes: &[u8]) -> usize {
    let mut w = [0u8; 4];
    w.copy_from_slice(&bytes[..4]);
    u32::from_le_bytes(w) as usize
}

/// A byte image with a golden copy + CRC, i.e. scrubbable state.
#[derive(Debug, Clone)]
pub struct ScrubbedState {
    bytes: Vec<u8>,
    golden: Vec<u8>,
    crc: u32,
}

impl ScrubbedState {
    pub fn new(bytes: Vec<u8>) -> Self {
        let crc = crc32(&bytes);
        ScrubbedState { golden: bytes.clone(), bytes, crc }
    }

    /// Authorized rewrite (e.g. a committed swap): refreshes the golden
    /// copy and CRC, clearing any outstanding corruption.
    pub fn rewrite(&mut self, bytes: Vec<u8>) {
        self.crc = crc32(&bytes);
        self.golden = bytes.clone();
        self.bytes = bytes;
    }

    /// Inject an SEU: flip one bit (`bit` wraps modulo the image size).
    pub fn flip_bit(&mut self, bit: usize) {
        let n = self.bytes.len() * 8;
        let b = bit % n;
        self.bytes[b / 8] ^= 1 << (b % 8);
    }

    /// Does the live image still match its CRC?
    pub fn is_clean(&self) -> bool {
        crc32(&self.bytes) == self.crc
    }

    /// One scrub pass: verify CRC, repair from golden on mismatch.
    /// Returns `true` if a repair was performed.
    pub fn scrub(&mut self) -> bool {
        if self.is_clean() {
            return false;
        }
        self.bytes.copy_from_slice(&self.golden);
        true
    }

    /// The live (possibly corrupted) image — what the runtime reads.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // IEEE CRC-32 check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn flip_then_scrub_repairs() {
        let mut s = ScrubbedState::new(encode_gate_state(3));
        assert!(s.is_clean());
        assert!(!s.scrub(), "clean state must not report a repair");
        s.flip_bit(1);
        assert!(!s.is_clean());
        assert_ne!(decode_index(s.bytes()), 3);
        assert!(s.scrub());
        assert!(s.is_clean());
        assert_eq!(decode_index(s.bytes()), 3);
    }

    #[test]
    fn rewrite_clears_corruption_and_updates_golden() {
        let mut s = ScrubbedState::new(encode_gate_state(2));
        s.flip_bit(0);
        s.rewrite(encode_gate_state(5));
        assert!(s.is_clean());
        assert_eq!(decode_index(s.bytes()), 5);
        s.flip_bit(9);
        assert!(s.scrub());
        assert_eq!(decode_index(s.bytes()), 5, "golden must track the rewrite");
    }

    #[test]
    fn encode_decode_roundtrip() {
        for i in 0..16 {
            assert_eq!(decode_index(&encode_gate_state(i)), i);
        }
    }
}
