//! Deterministic fault injection + self-healing for the NeuroMorph runtime.
//!
//! Real DPR deployments fail in four characteristic ways the paper's
//! live-reconfiguration story must survive: transient backend inference
//! errors, worker stalls/stragglers, DPR swap failures mid-window, and
//! SEU bit-flips in configuration memory. This module injects all four
//! *deterministically* on the virtual clock of
//! [`replay_trace`](crate::coordinator::Coordinator::replay_trace):
//!
//! * a `--fault-trace` grammar ([`FaultPlan::parse_spec`]) mirroring the
//!   power-trace grammar in [`crate::coordinator::trace`];
//! * an [`Injector`] that expands the plan into per-frame occurrences and
//!   drives scrubbing, SEU routing corruption, swap-failure arming and
//!   a virtual-fleet health/capacity model;
//! * pure-function retry backoff ([`backoff::RetryPolicy`]) so retry
//!   instants depend only on `(request id, attempt)`;
//! * a host-time health board ([`health::HealthBoard`]) for the live
//!   (non-replay) serving path.
//!
//! **Determinism discipline:** every record in the canonical fault log is
//! produced *submit-side* from the plan and the governor's decisions —
//! never from worker threads — so the log is byte-identical across
//! `--workers 1` vs `--workers 4` and across reruns with the same seed.
//! Worker-side effects travel as per-request [`FaultDirective`] stamps
//! whose outcome depends only on `(request, attempt)`.

pub mod backoff;
pub mod health;
pub mod scrub;

pub use backoff::RetryPolicy;
pub use health::{HealthBoard, HealthState};
pub use scrub::ScrubbedState;

use std::collections::BTreeMap;

use crate::coordinator::trace::parse_kv_pairs;
use crate::obs;
use crate::util::did_you_mean;

/// The four injectable fault classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Backend inference error on a request (retriable).
    Transient,
    /// Worker straggler: the executing shard stalls for `stall_ms`.
    Stall,
    /// DPR swap failure mid-`SwapTimeline` (forces rollback + cooldown).
    SwapFail,
    /// Single-event upset: one bit flips in the loaded gate state.
    Seu,
}

impl FaultKind {
    pub const NAMES: &'static [&'static str] = &["transient", "stall", "swapfail", "seu"];

    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Transient => "transient",
            FaultKind::Stall => "stall",
            FaultKind::SwapFail => "swapfail",
            FaultKind::Seu => "seu",
        }
    }
}

/// One parsed fault clause, resolved onto the frame clock.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    pub kind: FaultKind,
    /// First frame the fault strikes.
    pub frame: usize,
    /// Number of occurrences (`swapfail`: number of armed failures).
    pub count: usize,
    /// Frames between occurrences.
    pub every: usize,
    /// `transient`: consecutive attempts that fail before success.
    pub fails: u32,
    /// `stall`: injected straggler latency in milliseconds.
    pub stall_ms: f64,
    /// `seu`: bit position to flip (None = derived from the plan seed).
    pub bit: Option<usize>,
}

/// A parsed `--fault-trace` spec: what to inject, when, and the seed
/// that fixes every derived quantity (backoff jitter, default SEU bits).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
    pub seed: u64,
}

impl FaultPlan {
    /// An empty plan: the injector runs but never fires (the
    /// "enabled-but-idle" overhead case benchmarked in bench_hotpath).
    pub fn empty(seed: u64) -> FaultPlan {
        FaultPlan { events: Vec::new(), seed }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The canonical fault-storm spec: all four kinds with defaults.
    pub fn storm_spec() -> &'static str {
        "seu;stall;swapfail;transient"
    }

    /// Parse a `serve --fault-trace` spec.
    ///
    /// Grammar: `;`-separated clauses, each
    /// `<kind>[:key=value[,key=value...]]` with the kinds
    /// `transient | stall | swapfail | seu`. Strike times are given as
    /// `frame=N` or `at=SECONDS` (converted via `rate_hz`); a bare kind
    /// name gets deterministic defaults placed relative to `frames` so
    /// every built-in storm exercises the corresponding healing path.
    /// Examples: `seu`, `seu:frame=80,bit=3`, `stall:at=0.03,ms=2,count=4`,
    /// `transient:frame=60,count=4,every=2,fails=1`, `swapfail:after=0`.
    pub fn parse_spec(
        spec: &str,
        frames: usize,
        rate_hz: f64,
        seed: u64,
    ) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let (name, rest) = clause.split_once(':').unwrap_or((clause, ""));
            let kind = match name {
                "transient" => FaultKind::Transient,
                "stall" => FaultKind::Stall,
                "swapfail" => FaultKind::SwapFail,
                "seu" => FaultKind::Seu,
                other => {
                    let hint = did_you_mean(other, FaultKind::NAMES);
                    return Err(format!(
                        "fault-trace: unknown fault kind '{other}'{hint} \
                         (valid: transient|stall|swapfail|seu)"
                    ));
                }
            };
            let kv = parse_kv_pairs(&format!("fault-trace '{clause}'"), rest)?;
            let known: &[&str] = match kind {
                FaultKind::Transient => &["at", "frame", "count", "every", "fails"],
                FaultKind::Stall => &["at", "frame", "count", "every", "ms"],
                FaultKind::SwapFail => &["at", "frame", "after", "count"],
                FaultKind::Seu => &["at", "frame", "count", "every", "bit"],
            };
            if let Some(bad) = kv.keys().find(|k| !known.contains(&k.as_str())) {
                return Err(format!(
                    "fault-trace '{name}': unknown key '{bad}' (valid: {})",
                    known.join(", ")
                ));
            }
            let get = |k: &str, d: f64| kv.get(k).copied().unwrap_or(d);
            // strike frame: at= (seconds) wins, then frame=/after=, then
            // a per-kind default spread across the run
            let default_frame = match kind {
                FaultKind::Transient => frames / 4,
                FaultKind::Stall => frames / 2,
                FaultKind::SwapFail => 0,
                FaultKind::Seu => frames / 3,
            };
            let frame = if let Some(at) = kv.get("at") {
                (at * rate_hz).round().max(0.0) as usize
            } else if kind == FaultKind::SwapFail {
                get("after", get("frame", default_frame as f64)).max(0.0) as usize
            } else {
                get("frame", default_frame as f64).max(0.0) as usize
            };
            let default_count = match kind {
                FaultKind::Transient | FaultKind::Stall => 4.0,
                FaultKind::SwapFail | FaultKind::Seu => 1.0,
            };
            events.push(FaultEvent {
                kind,
                frame,
                count: get("count", default_count).max(1.0) as usize,
                every: get("every", 1.0).max(1.0) as usize,
                fails: get("fails", 1.0).max(0.0) as u32,
                stall_ms: get("ms", 2.0).max(0.0),
                bit: kv.get("bit").map(|b| b.max(0.0) as usize),
            });
        }
        Ok(FaultPlan { events, seed })
    }
}

/// Worker-side fault stamp carried on a request. The executing shard
/// honors it mechanically; outcomes depend only on `(request, attempt)`,
/// never on which worker runs it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultDirective {
    /// Straggler latency the executing shard must simulate (ms).
    pub stall_ms: f64,
    /// Attempts `0..fail_attempts` of this request fail with a transient
    /// backend error; attempt `fail_attempts` (if reached) succeeds.
    pub fail_attempts: u32,
}

impl FaultDirective {
    /// Stalled requests must not share a batch with innocent neighbors —
    /// the batcher isolates them so the straggler penalty lands only on
    /// the faulted request.
    pub fn isolating(&self) -> bool {
        self.stall_ms > 0.0
    }
}

/// One entry of the canonical (submit-side, deterministic) fault log.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultRecord {
    Seu { frame: usize, bit: usize, loaded: usize },
    ScrubRepair { frame: usize, mttr_ms: f64 },
    Transient { frame: usize, id: u64, fails: u32, retries_at_ms: Vec<f64>, recovered: bool },
    Stall { frame: usize, id: u64, ms: f64, vshard: usize },
    SwapRollback { frame: usize, from: String, to: String, swap_ms: f64, cooldown_frames: usize },
}

impl std::fmt::Display for FaultRecord {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultRecord::Seu { frame, bit, loaded } => write!(
                f,
                "[frame {frame:05}] fault seu: bit {bit} flipped in gate state \
                 (loaded path {loaded} -> corrupt)"
            ),
            FaultRecord::ScrubRepair { frame, mttr_ms } => write!(
                f,
                "[frame {frame:05}] scrub: crc mismatch repaired, mttr {mttr_ms:.3} ms"
            ),
            FaultRecord::Transient { frame, id, fails, retries_at_ms, recovered } => {
                write!(f, "[frame {frame:05}] fault transient: request {id} fails {fails}x")?;
                if retries_at_ms.is_empty() {
                    write!(f, ", no retries")?;
                } else {
                    let at: Vec<String> =
                        retries_at_ms.iter().map(|t| format!("+{t:.2}")).collect();
                    write!(f, ", retries at {} ms", at.join("/"))?;
                }
                write!(f, " -> {}", if *recovered { "recovered" } else { "failed" })
            }
            FaultRecord::Stall { frame, id, ms, vshard } => write!(
                f,
                "[frame {frame:05}] fault stall: request {id} delayed {ms:.2} ms \
                 (virtual shard {vshard} degraded)"
            ),
            FaultRecord::SwapRollback { frame, from, to, swap_ms, cooldown_frames } => write!(
                f,
                "[frame {frame:05}] fault swapfail: {from} -> {to} failed mid-window \
                 ({swap_ms:.3} ms wasted), rolled back to {from}, \
                 cooldown {cooldown_frames} frames"
            ),
        }
    }
}

/// Render the canonical fault log (one line per record, frame-prefixed
/// like the governor decision log so CI can byte-diff both together).
pub fn render_fault_log(records: &[FaultRecord]) -> String {
    records.iter().map(|r| r.to_string()).collect::<Vec<_>>().join("\n")
}

/// Convert the canonical fault log into virtual-clock trace entries
/// (lane 0 of `sink`). Pure function of the records + `rate_hz`, so the
/// recorded spans inherit the log's worker-invariance: SEU strikes and
/// transients as instants, scrubs/stalls/rollbacks as spans over their
/// modeled windows, and one retry instant per backoff step.
pub fn record_trace(records: &[FaultRecord], rate_hz: f64, sink: &obs::TraceSink) {
    use obs::{virtual_us, Clock, Name, TraceEntry};
    for r in records {
        match r {
            FaultRecord::Seu { frame, bit, loaded } => {
                let ts = virtual_us(*frame, rate_hz);
                sink.record(
                    0,
                    TraceEntry::instant(Clock::Virtual, Name::FaultSeu, ts, *frame as u64)
                        .with_args(*bit as u64, *loaded as u64),
                );
            }
            FaultRecord::ScrubRepair { frame, mttr_ms } => sink.record(
                0,
                TraceEntry::span(
                    Clock::Virtual,
                    Name::ScrubRepair,
                    virtual_us(*frame, rate_hz),
                    (mttr_ms.max(0.0) * 1_000.0).round() as u64,
                    *frame as u64,
                ),
            ),
            FaultRecord::Transient { frame, id, fails, retries_at_ms, recovered } => {
                let ts = virtual_us(*frame, rate_hz);
                sink.record(
                    0,
                    TraceEntry::instant(Clock::Virtual, Name::FaultTransient, ts, *id)
                        .with_args(u64::from(*fails), u64::from(*recovered)),
                );
                for (k, at_ms) in retries_at_ms.iter().enumerate() {
                    let at = ts + (at_ms.max(0.0) * 1_000.0).round() as u64;
                    sink.record(
                        0,
                        TraceEntry::instant(Clock::Virtual, Name::Retry, at, *id)
                            .with_args(k as u64 + 1, 0),
                    );
                }
            }
            FaultRecord::Stall { frame, id, ms, vshard } => sink.record(
                0,
                TraceEntry::span(
                    Clock::Virtual,
                    Name::FaultStall,
                    virtual_us(*frame, rate_hz),
                    (ms.max(0.0) * 1_000.0).round() as u64,
                    *id,
                )
                .with_args(*vshard as u64, 0),
            ),
            FaultRecord::SwapRollback { frame, from, to, swap_ms, cooldown_frames } => {
                let timeline = crate::morph::schedule::SwapTimeline {
                    stall_frames: 0,
                    swap_ms: *swap_ms,
                };
                sink.record(
                    0,
                    TraceEntry::span(
                        Clock::Virtual,
                        Name::Rollback,
                        virtual_us(*frame, rate_hz),
                        timeline.window_us(),
                        *frame as u64,
                    )
                    .with_path(sink.intern(to))
                    .with_args(u64::from(sink.intern(from)), *cooldown_frames as u64),
                );
            }
        }
    }
}

/// Virtual shards in the capacity model. Fixed (NOT `--workers`): the
/// governor's graceful-degradation decisions must be identical at any
/// real worker count, so capacity is modeled over a constant virtual
/// fleet that faults degrade and time heals.
pub const VIRTUAL_SHARDS: usize = 4;
/// Frames a faulted virtual shard stays degraded before healing.
const HEAL_FRAMES: usize = 24;

/// Fault telemetry the injector accumulates submit-side.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct InjectorStats {
    pub faults_injected: u64,
    pub scrub_repairs: u64,
    pub misrouted_frames: u64,
    pub recovery_ms_sum: f64,
    pub recoveries: u64,
}

/// The deterministic fault engine driven by the replay loop, one call
/// set per frame: [`begin_frame`](Injector::begin_frame) →
/// [`directive_for`](Injector::directive_for) →
/// [`capacity`](Injector::capacity) → (on a Switch decision)
/// [`swap_should_fail`](Injector::swap_should_fail) /
/// [`on_commit`](Injector::on_commit) → [`route`](Injector::route).
#[derive(Debug)]
pub struct Injector {
    /// frame -> consecutive failing attempts for that frame's request
    transient: BTreeMap<usize, u32>,
    /// frame -> straggler milliseconds
    stall: BTreeMap<usize, f64>,
    /// frame -> bit to flip
    seu: BTreeMap<usize, usize>,
    /// (arm frame, failures remaining) — strikes the next swap attempts
    swapfail: Vec<(usize, usize)>,
    scrub_period: usize,
    state: ScrubbedState,
    n_paths: usize,
    rate_hz: f64,
    retry: RetryPolicy,
    /// per virtual shard: degraded until this frame
    vhealth: [usize; VIRTUAL_SHARDS],
    corrupt_since: Option<usize>,
    records: Vec<FaultRecord>,
    stats: InjectorStats,
}

impl Injector {
    pub fn new(
        plan: &FaultPlan,
        n_paths: usize,
        initial_index: usize,
        rate_hz: f64,
        scrub_period: usize,
        retry: RetryPolicy,
    ) -> Injector {
        let mut transient = BTreeMap::new();
        let mut stall = BTreeMap::new();
        let mut seu = BTreeMap::new();
        let mut swapfail = Vec::new();
        let state = ScrubbedState::new(scrub::encode_gate_state(initial_index));
        let n_bits = state.bytes().len() * 8;
        for ev in &plan.events {
            match ev.kind {
                FaultKind::Transient => {
                    for k in 0..ev.count {
                        transient.insert(ev.frame + k * ev.every, ev.fails);
                    }
                }
                FaultKind::Stall => {
                    for k in 0..ev.count {
                        stall.insert(ev.frame + k * ev.every, ev.stall_ms);
                    }
                }
                FaultKind::Seu => {
                    for k in 0..ev.count {
                        // default bit: seeded, spread across the image,
                        // biased toward the index word so most SEUs are
                        // routing-visible until scrubbed
                        let bit = ev.bit.unwrap_or_else(|| {
                            (plan.seed as usize).wrapping_mul(31).wrapping_add(13 * k) % n_bits
                        });
                        seu.insert(ev.frame + k * ev.every, bit % n_bits);
                    }
                }
                FaultKind::SwapFail => swapfail.push((ev.frame, ev.count)),
            }
        }
        Injector {
            transient,
            stall,
            seu,
            swapfail,
            scrub_period: scrub_period.max(1),
            state,
            n_paths: n_paths.max(1),
            rate_hz,
            retry,
            vhealth: [0; VIRTUAL_SHARDS],
            corrupt_since: None,
            records: Vec::new(),
            stats: InjectorStats::default(),
        }
    }

    /// Frame prologue: run the periodic scrubber, then inject any SEU
    /// scheduled for this frame (scrub-then-strike, so a fresh flip is
    /// live until the *next* scrub pass — that window is the MTTR).
    pub fn begin_frame(&mut self, frame: usize) {
        if frame > 0 && frame % self.scrub_period == 0 && self.state.scrub() {
            let since = self.corrupt_since.take().unwrap_or(frame);
            let mttr_ms = (frame - since) as f64 / self.rate_hz * 1e3;
            self.records.push(FaultRecord::ScrubRepair { frame, mttr_ms });
            self.stats.scrub_repairs += 1;
            self.stats.recovery_ms_sum += mttr_ms;
            self.stats.recoveries += 1;
        }
        if let Some(&bit) = self.seu.get(&frame) {
            let loaded = scrub::decode_index(self.state.bytes());
            self.state.flip_bit(bit);
            self.records.push(FaultRecord::Seu { frame, bit, loaded });
            self.stats.faults_injected += 1;
            if !self.state.is_clean() && self.corrupt_since.is_none() {
                self.corrupt_since = Some(frame);
            }
        }
    }

    /// Fault stamp for the request submitted at `frame` (with id `id`),
    /// recording the canonical transient/stall log lines and degrading
    /// the struck virtual shard.
    pub fn directive_for(&mut self, frame: usize, id: u64) -> Option<FaultDirective> {
        let fails = self.transient.get(&frame).copied();
        let stall_ms = self.stall.get(&frame).copied();
        if fails.is_none() && stall_ms.is_none() {
            return None;
        }
        let vshard = frame % VIRTUAL_SHARDS;
        if let Some(fails) = fails {
            let retries = fails.min(self.retry.max_retries);
            let retries_at_ms = self.retry.instants_ms(id, retries);
            let recovered = fails <= self.retry.max_retries;
            if recovered && fails > 0 {
                self.stats.recovery_ms_sum += retries_at_ms.last().copied().unwrap_or(0.0);
                self.stats.recoveries += 1;
            }
            self.records.push(FaultRecord::Transient {
                frame,
                id,
                fails,
                retries_at_ms,
                recovered,
            });
            self.stats.faults_injected += 1;
            self.vhealth[vshard] = self.vhealth[vshard].max(frame + HEAL_FRAMES);
        }
        if let Some(ms) = stall_ms {
            self.records.push(FaultRecord::Stall { frame, id, ms, vshard });
            self.stats.faults_injected += 1;
            self.vhealth[vshard] = self.vhealth[vshard].max(frame + HEAL_FRAMES);
        }
        Some(FaultDirective {
            stall_ms: stall_ms.unwrap_or(0.0),
            fail_attempts: fails.unwrap_or(0),
        })
    }

    /// Healthy fraction of the virtual fleet at `frame` in `(0, 1]` —
    /// the governor divides effective path latency by this, so a sick
    /// fleet degrades down the ladder to hold a latency budget.
    pub fn capacity(&self, frame: usize) -> f64 {
        let healthy = self.vhealth.iter().filter(|&&until| until <= frame).count();
        healthy.max(1) as f64 / VIRTUAL_SHARDS as f64
    }

    /// Should the swap attempted at `frame` fail? Consumes one armed
    /// failure if so.
    pub fn swap_should_fail(&mut self, frame: usize) -> bool {
        for arm in &mut self.swapfail {
            if frame >= arm.0 && arm.1 > 0 {
                arm.1 -= 1;
                return true;
            }
        }
        false
    }

    /// Record a rollback after a failed swap (the caller already paid
    /// `swap_ms` of the DPR window and reverted the governor).
    pub fn record_rollback(
        &mut self,
        frame: usize,
        from: String,
        to: String,
        swap_ms: f64,
        cooldown_frames: usize,
    ) {
        self.records.push(FaultRecord::SwapRollback {
            frame,
            from,
            to,
            swap_ms,
            cooldown_frames,
        });
        self.stats.faults_injected += 1;
    }

    /// A committed swap rewrites the gate state (repairing any live
    /// corruption the way a real DPR write refreshes config frames).
    pub fn on_commit(&mut self, new_index: usize) {
        self.state.rewrite(scrub::encode_gate_state(new_index));
        self.corrupt_since = None;
    }

    /// Resolve the frame's actual execution path. Clean state routes to
    /// the governor's choice; corrupted state routes through the (bad)
    /// decoded index — a valid-but-wrong index misroutes to that path,
    /// an out-of-range one clamps to the lightest path. Either way the
    /// frame is flagged `degraded` until a scrub or swap repairs it.
    pub fn route(&mut self, _frame: usize, chosen: usize) -> (usize, bool) {
        if self.state.is_clean() {
            return (chosen, false);
        }
        let decoded = scrub::decode_index(self.state.bytes());
        if decoded == chosen {
            // flip landed in the pad bytes: latent, not routing-visible
            return (chosen, false);
        }
        self.stats.misrouted_frames += 1;
        if decoded < self.n_paths {
            (decoded, true)
        } else {
            (0, true)
        }
    }

    pub fn records(&self) -> &[FaultRecord] {
        &self.records
    }

    pub fn into_records(self) -> Vec<FaultRecord> {
        self.records
    }

    pub fn stats(&self) -> InjectorStats {
        self.stats
    }

    pub fn retry(&self) -> RetryPolicy {
        self.retry
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(spec: &str) -> FaultPlan {
        FaultPlan::parse_spec(spec, 240, 4000.0, 7).unwrap()
    }

    #[test]
    fn storm_spec_parses_with_defaults() {
        let p = plan(FaultPlan::storm_spec());
        assert_eq!(p.events.len(), 4);
        let kinds: Vec<_> = p.events.iter().map(|e| e.kind).collect();
        assert!(kinds.contains(&FaultKind::Seu));
        assert!(kinds.contains(&FaultKind::Stall));
        assert!(kinds.contains(&FaultKind::SwapFail));
        assert!(kinds.contains(&FaultKind::Transient));
        // defaults are placed inside the run
        assert!(p.events.iter().all(|e| e.frame < 240));
    }

    #[test]
    fn explicit_keys_override_defaults() {
        let p = plan("transient:frame=60,count=4,every=2,fails=3");
        assert_eq!(
            p.events[0],
            FaultEvent {
                kind: FaultKind::Transient,
                frame: 60,
                count: 4,
                every: 2,
                fails: 3,
                stall_ms: 2.0,
                bit: None,
            }
        );
        // at= converts seconds to frames at rate_hz
        let p = plan("stall:at=0.03,ms=1.5");
        assert_eq!(p.events[0].frame, 120);
        assert_eq!(p.events[0].stall_ms, 1.5);
        let p = plan("seu:frame=80,bit=3");
        assert_eq!(p.events[0].bit, Some(3));
        let p = plan("swapfail:after=100,count=2");
        assert_eq!((p.events[0].frame, p.events[0].count), (100, 2));
    }

    #[test]
    fn unknown_kind_gets_did_you_mean() {
        let e = FaultPlan::parse_spec("sue", 240, 4000.0, 7).unwrap_err();
        assert!(e.contains("'sue'") && e.contains("did you mean 'seu'?"), "{e}");
        assert!(e.contains("transient|stall|swapfail|seu"), "{e}");
        let e = FaultPlan::parse_spec("stale:ms=2", 240, 4000.0, 7).unwrap_err();
        assert!(e.contains("did you mean 'stall'?"), "{e}");
    }

    #[test]
    fn bad_keys_and_values_are_named() {
        let e = FaultPlan::parse_spec("seu:bite=3", 240, 4000.0, 7).unwrap_err();
        assert!(e.contains("unknown key 'bite'") && e.contains("bit"), "{e}");
        let e = FaultPlan::parse_spec("stall:ms=slow", 240, 4000.0, 7).unwrap_err();
        assert!(e.contains("non-numeric value 'slow' for 'ms'"), "{e}");
        let e = FaultPlan::parse_spec("stall:ms", 240, 4000.0, 7).unwrap_err();
        assert!(e.contains("expected key=value"), "{e}");
    }

    #[test]
    fn empty_spec_clauses_are_skipped() {
        let p = plan("seu;;stall;");
        assert_eq!(p.events.len(), 2);
        assert!(FaultPlan::empty(1).is_empty());
        assert!(!p.is_empty());
    }

    fn injector(spec: &str) -> Injector {
        Injector::new(&plan(spec), 4, 3, 4000.0, 16, RetryPolicy::default())
    }

    #[test]
    fn injector_is_deterministic_per_plan() {
        let drive = |mut inj: Injector| -> (String, InjectorStats) {
            for f in 0..240usize {
                inj.begin_frame(f);
                inj.directive_for(f, f as u64 + 1);
                let chosen = 3;
                inj.route(f, chosen);
            }
            (render_fault_log(inj.records()), inj.stats())
        };
        let spec = FaultPlan::storm_spec();
        let (log_a, stats_a) = drive(injector(spec));
        let (log_b, stats_b) = drive(injector(spec));
        assert_eq!(log_a, log_b);
        assert_eq!(stats_a, stats_b);
        assert!(stats_a.faults_injected > 0);
    }

    #[test]
    fn seu_misroutes_until_scrub_repairs() {
        // flip bit 1 of the index word at frame 20: loaded path 3 -> 1
        let mut inj = Injector::new(
            &plan("seu:frame=20,bit=1"),
            4,
            3,
            4000.0,
            16,
            RetryPolicy::default(),
        );
        let mut degraded_frames = 0;
        let mut repaired_at = None;
        for f in 0..64usize {
            inj.begin_frame(f);
            let (actual, degraded) = inj.route(f, 3);
            if degraded {
                degraded_frames += 1;
                assert_eq!(actual, 1, "bit 1 of index 3 -> index 1");
            }
            if repaired_at.is_none() && inj.stats().scrub_repairs > 0 {
                repaired_at = Some(f);
            }
        }
        // corrupt from frame 20 until the frame-32 scrub pass
        assert_eq!(degraded_frames, 12);
        assert_eq!(repaired_at, Some(32));
        let s = inj.stats();
        assert_eq!(s.scrub_repairs, 1);
        assert_eq!(s.misrouted_frames, 12);
        assert!(s.recovery_ms_sum > 0.0);
        let log = render_fault_log(inj.records());
        assert!(log.contains("fault seu: bit 1"), "{log}");
        assert!(log.contains("scrub: crc mismatch repaired, mttr 3.000 ms"), "{log}");
    }

    #[test]
    fn out_of_range_seu_clamps_to_lightest_path() {
        // bit 30 sets a high bit of the index word: decoded >> n_paths
        let mut inj = Injector::new(
            &plan("seu:frame=0,bit=30"),
            4,
            3,
            4000.0,
            16,
            RetryPolicy::default(),
        );
        inj.begin_frame(0);
        let (actual, degraded) = inj.route(0, 3);
        assert!(degraded);
        assert_eq!(actual, 0, "out-of-range index clamps to the lightest path");
    }

    #[test]
    fn committed_swap_repairs_corruption() {
        let mut inj = Injector::new(
            &plan("seu:frame=0,bit=1"),
            4,
            3,
            4000.0,
            16,
            RetryPolicy::default(),
        );
        inj.begin_frame(0);
        assert!(inj.route(0, 3).1);
        inj.on_commit(0);
        assert!(!inj.route(1, 0).1, "DPR rewrite refreshes gate state");
        assert_eq!(inj.stats().scrub_repairs, 0, "repair-by-rewrite is not a scrub");
    }

    #[test]
    fn transient_directive_matches_spec_and_counts_recovery() {
        let mut inj = injector("transient:frame=10,count=2,every=5,fails=1");
        for f in 0..20usize {
            inj.begin_frame(f);
            let d = inj.directive_for(f, f as u64 + 1);
            match f {
                10 | 15 => {
                    assert_eq!(d, Some(FaultDirective { stall_ms: 0.0, fail_attempts: 1 }))
                }
                _ => assert_eq!(d, None),
            }
        }
        let s = inj.stats();
        assert_eq!(s.faults_injected, 2);
        assert_eq!(s.recoveries, 2, "fails=1 <= max_retries recovers");
        let log = render_fault_log(inj.records());
        assert!(log.contains("request 11 fails 1x, retries at +"), "{log}");
        assert!(log.contains("-> recovered"), "{log}");
    }

    #[test]
    fn exhausted_retries_log_failed() {
        let mut inj = injector("transient:frame=5,count=1,fails=9");
        inj.begin_frame(5);
        inj.directive_for(5, 6);
        let log = render_fault_log(inj.records());
        assert!(log.contains("fails 9x"), "{log}");
        assert!(log.ends_with("-> failed"), "{log}");
        assert_eq!(inj.stats().recoveries, 0);
    }

    #[test]
    fn faults_degrade_virtual_capacity_then_heal() {
        let mut inj = injector("stall:frame=40,count=4,every=1,ms=2");
        assert_eq!(inj.capacity(0), 1.0);
        for f in 0..240usize {
            inj.begin_frame(f);
            inj.directive_for(f, f as u64 + 1);
        }
        // frames 40..44 degrade all four virtual shards; capacity floors
        // at 1/V (never zero) and heals after the window
        assert_eq!(inj.capacity(44), 1.0 / VIRTUAL_SHARDS as f64);
        assert!(inj.capacity(50) < 1.0);
        assert_eq!(inj.capacity(40 + 3 + 24), 1.0, "healed");
    }

    #[test]
    fn swapfail_arms_and_decrements() {
        let mut inj = injector("swapfail:after=100,count=2");
        assert!(!inj.swap_should_fail(50), "not armed yet");
        assert!(inj.swap_should_fail(100));
        assert!(inj.swap_should_fail(120));
        assert!(!inj.swap_should_fail(130), "both failures consumed");
        inj.record_rollback(100, "d3_w100".into(), "d1_w100".into(), 0.5, 8);
        let log = render_fault_log(inj.records());
        assert!(
            log.contains("d3_w100 -> d1_w100 failed mid-window (0.500 ms wasted)"),
            "{log}"
        );
        assert!(log.contains("rolled back to d3_w100, cooldown 8 frames"), "{log}");
    }

    #[test]
    fn empty_plan_injector_is_inert() {
        let mut inj = Injector::new(
            &FaultPlan::empty(7),
            4,
            3,
            4000.0,
            16,
            RetryPolicy::default(),
        );
        for f in 0..100usize {
            inj.begin_frame(f);
            assert_eq!(inj.directive_for(f, f as u64 + 1), None);
            assert_eq!(inj.route(f, 3), (3, false));
            assert!(!inj.swap_should_fail(f));
            assert_eq!(inj.capacity(f), 1.0);
        }
        assert_eq!(inj.stats(), InjectorStats::default());
        assert!(inj.records().is_empty());
    }

    #[test]
    fn fault_records_convert_to_virtual_trace_entries() {
        use crate::obs::{Clock, Kind, Name, TraceSink};
        let sink = TraceSink::new(64);
        let records = vec![
            FaultRecord::Seu { frame: 4, bit: 2, loaded: 1 },
            FaultRecord::ScrubRepair { frame: 16, mttr_ms: 1.5 },
            FaultRecord::Transient {
                frame: 8,
                id: 9,
                fails: 2,
                retries_at_ms: vec![2.0, 6.0],
                recovered: true,
            },
            FaultRecord::Stall { frame: 10, id: 11, ms: 3.0, vshard: 1 },
            FaultRecord::SwapRollback {
                frame: 12,
                from: "a".into(),
                to: "b".into(),
                swap_ms: 0.5,
                cooldown_frames: 8,
            },
        ];
        record_trace(&records, 4000.0, &sink);
        let trace = sink.drain();
        // 1 seu + 1 scrub + 1 transient + 2 retries + 1 stall + 1 rollback
        assert_eq!(trace.entries.len(), 7);
        assert!(trace.entries.iter().all(|e| e.clock == Clock::Virtual));
        let retry: Vec<_> = trace.entries.iter().filter(|e| e.name == Name::Retry).collect();
        assert_eq!(retry.len(), 2);
        // frame 8 at 4 kHz = 2000 us; backoff instants +2 ms and +6 ms
        assert_eq!(retry[0].ts_us, 4_000);
        assert_eq!(retry[1].ts_us, 8_000);
        let rb = trace.entries.iter().find(|e| e.name == Name::Rollback).unwrap();
        assert_eq!(rb.kind, Kind::Span);
        assert_eq!(rb.dur_us, 500);
        assert_eq!(trace.path_name(rb.path), Some("b"));
        assert_eq!(trace.path_name(rb.a0 as u16), Some("a"));
        let stall = trace.entries.iter().find(|e| e.name == Name::FaultStall).unwrap();
        assert_eq!(stall.dur_us, 3_000);
        assert_eq!(stall.a0, 1);
    }
}
