//! Per-shard health tracking for the live (host-clock) serving path.
//!
//! Workers report execute outcomes; the board classifies each shard as
//! Healthy → Degraded → Quarantined on consecutive failures and routes
//! retries/steals away from sick shards. Quarantine is left after a
//! cool-off once a backend probe succeeds. The board deliberately plays
//! no part in the virtual-clock replay path — replay determinism comes
//! from the submit-side injector, and the board's host-time state must
//! never leak into logs that CI byte-diffs.

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Shard health ladder. Ordering is by severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum HealthState {
    Healthy,
    Degraded,
    Quarantined,
}

impl std::fmt::Display for HealthState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HealthState::Healthy => write!(f, "healthy"),
            HealthState::Degraded => write!(f, "degraded"),
            HealthState::Quarantined => write!(f, "quarantined"),
        }
    }
}

#[derive(Debug)]
struct ShardHealth {
    state: HealthState,
    fail_streak: u32,
    ok_streak: u32,
    quarantined_until: Option<Instant>,
}

impl ShardHealth {
    fn new() -> Self {
        ShardHealth {
            state: HealthState::Healthy,
            fail_streak: 0,
            ok_streak: 0,
            quarantined_until: None,
        }
    }
}

/// Consecutive failures that demote Healthy → Degraded.
const DEGRADE_AFTER: u32 = 2;
/// Consecutive failures that demote Degraded → Quarantined.
const QUARANTINE_AFTER: u32 = 4;
/// Consecutive successes that promote Degraded → Healthy.
const RECOVER_AFTER: u32 = 3;
/// Minimum quarantine dwell before a probe may release the shard.
const QUARANTINE_DWELL: Duration = Duration::from_millis(50);

/// Shared health board, one slot per shard.
#[derive(Debug)]
pub struct HealthBoard {
    shards: Mutex<Vec<ShardHealth>>,
}

impl HealthBoard {
    pub fn new(shards: usize) -> Self {
        HealthBoard { shards: Mutex::new((0..shards).map(|_| ShardHealth::new()).collect()) }
    }

    pub fn state(&self, shard: usize) -> HealthState {
        self.shards.lock().unwrap()[shard].state
    }

    pub fn healthy_count(&self) -> usize {
        self.shards
            .lock()
            .unwrap()
            .iter()
            .filter(|s| s.state != HealthState::Quarantined)
            .count()
    }

    /// Record a failed execute on `shard`. Never quarantines the last
    /// non-quarantined shard — someone must keep answering requests.
    pub fn record_failure(&self, shard: usize) {
        let mut shards = self.shards.lock().unwrap();
        let alive =
            shards.iter().filter(|s| s.state != HealthState::Quarantined).count();
        let s = &mut shards[shard];
        s.ok_streak = 0;
        s.fail_streak += 1;
        if s.fail_streak >= QUARANTINE_AFTER && alive > 1 {
            s.state = HealthState::Quarantined;
            s.quarantined_until = Some(Instant::now() + QUARANTINE_DWELL);
        } else if s.fail_streak >= DEGRADE_AFTER && s.state == HealthState::Healthy {
            s.state = HealthState::Degraded;
        }
    }

    /// Record a successful execute on `shard`.
    pub fn record_success(&self, shard: usize) {
        let s = &mut self.shards.lock().unwrap()[shard];
        s.fail_streak = 0;
        s.ok_streak += 1;
        if s.state == HealthState::Degraded && s.ok_streak >= RECOVER_AFTER {
            s.state = HealthState::Healthy;
        }
    }

    /// Has `shard` dwelled long enough in quarantine to be probed?
    pub fn probe_due(&self, shard: usize) -> bool {
        let shards = self.shards.lock().unwrap();
        let s = &shards[shard];
        s.state == HealthState::Quarantined
            && s.quarantined_until.is_some_and(|t| Instant::now() >= t)
    }

    /// A successful probe releases the shard back to Degraded (it must
    /// earn Healthy through real traffic).
    pub fn release(&self, shard: usize) {
        let s = &mut self.shards.lock().unwrap()[shard];
        if s.state == HealthState::Quarantined {
            s.state = HealthState::Degraded;
            s.fail_streak = 0;
            s.ok_streak = 0;
            s.quarantined_until = None;
        }
    }

    /// Next non-quarantined shard at or after `start` (wrapping); falls
    /// back to `start` itself if everything is quarantined (can't happen
    /// via `record_failure`, but steals race with releases).
    pub fn next_healthy(&self, start: usize) -> usize {
        let shards = self.shards.lock().unwrap();
        let n = shards.len();
        (0..n)
            .map(|k| (start + k) % n)
            .find(|&i| shards[i].state != HealthState::Quarantined)
            .unwrap_or(start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failures_walk_the_ladder() {
        let b = HealthBoard::new(2);
        assert_eq!(b.state(0), HealthState::Healthy);
        b.record_failure(0);
        assert_eq!(b.state(0), HealthState::Healthy);
        b.record_failure(0);
        assert_eq!(b.state(0), HealthState::Degraded);
        b.record_failure(0);
        b.record_failure(0);
        assert_eq!(b.state(0), HealthState::Quarantined);
        assert_eq!(b.healthy_count(), 1);
        assert_eq!(b.next_healthy(0), 1);
    }

    #[test]
    fn last_shard_standing_is_never_quarantined() {
        let b = HealthBoard::new(1);
        for _ in 0..20 {
            b.record_failure(0);
        }
        assert_ne!(b.state(0), HealthState::Quarantined);
        assert_eq!(b.healthy_count(), 1);
    }

    #[test]
    fn successes_recover_degraded() {
        let b = HealthBoard::new(2);
        b.record_failure(0);
        b.record_failure(0);
        assert_eq!(b.state(0), HealthState::Degraded);
        for _ in 0..RECOVER_AFTER {
            b.record_success(0);
        }
        assert_eq!(b.state(0), HealthState::Healthy);
    }

    #[test]
    fn release_returns_to_degraded_not_healthy() {
        let b = HealthBoard::new(2);
        for _ in 0..QUARANTINE_AFTER {
            b.record_failure(1);
        }
        assert_eq!(b.state(1), HealthState::Quarantined);
        b.release(1);
        assert_eq!(b.state(1), HealthState::Degraded);
    }
}
