//! Convolutional PE (`C_PE`) analytical model — Sec. III-A.1, Eqs. 1-4, 11.
//!
//! A C_PE is a two-stage pipeline: a Line Buffer Controller (K-1 row
//! FIFOs + tap register bank) feeding a MAC core (K^2 multipliers + adder
//! tree). One output per clock after pipeline fill.

use super::{luts, Blanking, FpRep, Resources};

/// Configuration of one conv PE instance, bound to its layer's geometry.
#[derive(Debug, Clone, Copy)]
pub struct ConvPe {
    /// kernel size K
    pub k: usize,
    /// input feature-map width (FM_W) — line-buffer depth
    pub fm_w: usize,
    /// input feature-map height (FM_H)
    pub fm_h: usize,
    /// fixed-point representation
    pub rep: FpRep,
    /// whether a ReLU stage follows the adder tree
    pub relu: bool,
    /// first pipeline layer pays the input-interface delay D_in
    pub first_layer: bool,
}

impl ConvPe {
    /// Eq. 1: number of multipliers in the MAC core.
    pub fn n_mult(&self) -> usize {
        self.k * self.k
    }

    /// Eq. 2: adder-tree depth, `ceil(log2(K^2)) + 1` stages.
    pub fn add_stages(&self) -> usize {
        (self.k * self.k) .next_power_of_two().trailing_zeros() as usize + 1
    }

    /// Eq. 3 (closed form): a K^2-leaf binary reduction uses K^2 - 1 adders.
    pub fn n_add(&self) -> usize {
        self.k * self.k - 1
    }

    /// Eq. 4 core term: cycles to stream the frame through the window
    /// generator, including blanking intervals.
    pub fn core_cycles(&self, blank: Blanking) -> usize {
        let d_in = if self.first_layer { 4 } else { 0 };
        let pb = blank.back_porch;
        let pf = blank.front_porch;
        d_in + (pb + 1) / 2 + (self.fm_w + pb + pf) * self.fm_h
    }

    /// Eq. 4 overhead term: pad + tap + mul + adder-tree + D_out + ReLU.
    pub fn overhead_cycles(&self) -> usize {
        let t_pad = self.k;
        let t_tap = self.k;
        let t_mul = self.k;
        let t_add = self.add_stages() + 2;
        let d_out = 4;
        let t_relu = usize::from(self.relu);
        t_pad + t_tap + t_mul + t_add + d_out + t_relu
    }

    /// Eq. 4: total latency of one pass of one C_PE, in clock cycles.
    pub fn latency_cycles(&self, blank: Blanking) -> usize {
        self.core_cycles(blank) + self.overhead_cycles()
    }

    /// Eq. 11: line-buffer BRAM requirement (18 Kb blocks). A 1x1 kernel
    /// needs no window assembly — no line buffer, zero BRAM.
    pub fn line_buffer_bram(&self) -> usize {
        if self.k < 2 {
            return 0;
        }
        let bits = self.fm_w * self.k * self.rep.bits();
        bits.div_ceil(18 * 1024).max(1)
    }

    /// Per-PE resource vector (DSP = K^2 per Sec. III-B; LUT/FF from
    /// Table I; BRAM from Eq. 11).
    pub fn resources(&self) -> Resources {
        Resources {
            dsp: self.n_mult(),
            lut: luts::conv_luts(self.k),
            ff: luts::conv_regs(self.k),
            bram: self.line_buffer_bram(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pe3() -> ConvPe {
        ConvPe { k: 3, fm_w: 28, fm_h: 28, rep: FpRep::Int16, relu: true, first_layer: true }
    }

    #[test]
    fn eq1_multipliers() {
        assert_eq!(pe3().n_mult(), 9);
        assert_eq!(ConvPe { k: 5, ..pe3() }.n_mult(), 25);
    }

    #[test]
    fn eq2_adder_stages() {
        // paper: 3x3 kernel -> 9 mult, 8 adders across 5 pipeline stages
        assert_eq!(pe3().add_stages(), 5);
        assert_eq!(ConvPe { k: 2, ..pe3() }.add_stages(), 3);
    }

    #[test]
    fn eq3_adders() {
        assert_eq!(pe3().n_add(), 8);
        assert_eq!(ConvPe { k: 4, ..pe3() }.n_add(), 15);
    }

    #[test]
    fn eq4_latency_structure() {
        let pe = pe3();
        let blank = Blanking::default();
        // core dominated by (W + Pb + Pf) * H
        let core = pe.core_cycles(blank);
        assert!(core >= 28 * 28);
        assert_eq!(core, 4 + 1 + (28 + 4) * 28);
        // overhead small and constant
        assert_eq!(pe.overhead_cycles(), 3 + 3 + 3 + 7 + 4 + 1);
        assert_eq!(pe.latency_cycles(blank), core + pe.overhead_cycles());
    }

    #[test]
    fn eq11_bram() {
        // 28 px * 3 rows * 16 bits = 1344 bits -> 1 block
        assert_eq!(pe3().line_buffer_bram(), 1);
        let wide = ConvPe { fm_w: 640, k: 5, ..pe3() };
        // 640*5*16 = 51200 bits -> 3 blocks
        assert_eq!(wide.line_buffer_bram(), 3);
    }

    #[test]
    fn int8_halves_buffer_bits() {
        let w16 = ConvPe { fm_w: 1200, ..pe3() };
        let w8 = ConvPe { rep: FpRep::Int8, ..w16 };
        assert!(w8.line_buffer_bram() <= w16.line_buffer_bram());
    }

    #[test]
    fn non_first_layer_skips_d_in() {
        let a = pe3();
        let b = ConvPe { first_layer: false, ..a };
        assert_eq!(
            a.core_cycles(Blanking::default()) - b.core_cycles(Blanking::default()),
            4
        );
    }

    #[test]
    fn resources_match_table1() {
        let r = pe3().resources();
        assert_eq!(r.dsp, 9);
        assert_eq!(r.lut, 850);
        assert_eq!(r.ff, 2000);
    }
}
