//! Fully connected PE (`FC_PE`) analytical model — Sec. III-A.3,
//! Eqs. 5-10.
//!
//! Each output head owns a MAC that accumulates streamed input-weight
//! products (Eq. 5). Channel-wise parallelism (Eq. 6) splits the input
//! across `n_pe` FC-Accumulation blocks; the parallelism coefficient
//! `P = Ch_D / FC_PE` serializes the stream when fewer PEs than channels
//! are allocated (Eq. 10).

use super::{luts, Blanking, Resources};

/// Max physical output heads instantiated at once; wider FC layers
/// time-multiplex head groups over the same MAC bank (a 1000-class
/// ImageNet head would otherwise monopolize half the device's DSPs).
pub const HEAD_BANK: usize = 64;

/// An FC layer's PE bank configuration.
#[derive(Debug, Clone, Copy)]
pub struct FcPe {
    /// number of output heads (FC_out)
    pub fc_out: usize,
    /// FC_PE units allocated per head (N in Eqs. 7-9)
    pub n_pe: usize,
    /// input channel depth Ch_D (the serialization driver of Eq. 10)
    pub channels: usize,
    /// incoming feature-map geometry (vectorized streaming, Eq. 10)
    pub fm_w: usize,
    pub fm_h: usize,
}

impl FcPe {
    /// Physical heads instantiated (logical heads beyond the bank are
    /// time-multiplexed).
    pub fn phys_heads(&self) -> usize {
        self.fc_out.min(HEAD_BANK)
    }

    /// Sequential head groups (1 when fc_out <= HEAD_BANK).
    pub fn head_groups(&self) -> usize {
        self.fc_out.div_ceil(HEAD_BANK).max(1)
    }
    /// Eq. 10's parallelism coefficient `P = Ch_D / FC_PE` (ceil for
    /// non-dividing allocations; P=1 means fully channel-parallel).
    pub fn parallelism(&self) -> usize {
        self.channels.div_ceil(self.n_pe.max(1)).max(1)
    }

    /// Eq. 7: multipliers = FC_out * N.
    pub fn n_mult(&self) -> usize {
        self.fc_out * self.n_pe
    }

    /// Eq. 8: adders = FC_out*N + FC_out*L, with L the aggregation-tree
    /// adder count over N partial sums (N-1 for a binary tree).
    pub fn n_add(&self) -> usize {
        let l = self.n_pe.saturating_sub(1);
        self.fc_out * self.n_pe + self.fc_out * l
    }

    /// Eq. 9: accumulation registers = FC_out * N.
    pub fn n_reg(&self) -> usize {
        self.fc_out * self.n_pe
    }

    /// Eq. 10: latency = Clk * [(FM_W + BP + FP)(FM_H - 1) + FM_H] * P,
    /// times the head-group multiplexing factor for very wide layers.
    pub fn latency_cycles(&self, blank: Blanking) -> usize {
        let bp = blank.back_porch;
        let fp = blank.front_porch;
        let stream = (self.fm_w + bp + fp) * self.fm_h.saturating_sub(1) + self.fm_h;
        stream * self.parallelism() * self.head_groups()
    }

    /// Sec. III-B: 1 DSP + ~360 LUTs per FC_PE, no BRAM. Physical units
    /// are capped at [`HEAD_BANK`] heads (time-multiplexed beyond that).
    pub fn resources(&self) -> Resources {
        let units = self.phys_heads() * self.n_pe;
        Resources {
            dsp: units,
            lut: units * luts::AVG_FC_PE_LUTS,
            ff: units * 16, // 16-bit accumulation registers
            bram: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pe() -> FcPe {
        FcPe { fc_out: 10, n_pe: 4, channels: 32, fm_w: 3, fm_h: 3 }
    }

    #[test]
    fn eq7_multipliers() {
        assert_eq!(pe().n_mult(), 40);
    }

    #[test]
    fn eq8_adders() {
        // L = N-1 = 3 -> 10*4 + 10*3 = 70
        assert_eq!(pe().n_add(), 70);
    }

    #[test]
    fn eq9_registers() {
        assert_eq!(pe().n_reg(), 40);
    }

    #[test]
    fn eq10_parallelism() {
        assert_eq!(pe().parallelism(), 8); // 32/4
        assert_eq!(FcPe { n_pe: 32, ..pe() }.parallelism(), 1);
        assert_eq!(FcPe { n_pe: 5, ..pe() }.parallelism(), 7); // ceil(32/5)
    }

    #[test]
    fn eq10_latency_linear_in_p() {
        let blank = Blanking::default();
        let serial = FcPe { n_pe: 1, ..pe() }.latency_cycles(blank);
        let parallel = FcPe { n_pe: 32, ..pe() }.latency_cycles(blank);
        assert_eq!(serial, parallel * 32);
    }

    #[test]
    fn one_dsp_per_unit() {
        assert_eq!(pe().resources().dsp, 40);
        assert_eq!(pe().resources().bram, 0);
    }
}
