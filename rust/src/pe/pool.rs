//! Pooling PE (`PU_PE`) analytical model — Sec. III-A.2.
//!
//! Pooling reuses the C_PE line-buffer controller; max pooling swaps the
//! MAC core for a K^2-comparator tree, average pooling keeps the MAC with
//! fixed 1/K^2 coefficients. No DSP slices are consumed (comparisons /
//! shifts only); one BRAM per PU_PE buffers the window rows.

use super::{luts, Blanking, Resources};

/// Pooling flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Avg,
}

/// One pooling PE bound to its layer geometry.
#[derive(Debug, Clone, Copy)]
pub struct PoolPe {
    pub k: usize,
    pub stride: usize,
    pub fm_w: usize,
    pub fm_h: usize,
    pub kind: PoolKind,
}

impl PoolPe {
    /// Comparator count of the max tree (or adders of the avg core).
    pub fn n_compare(&self) -> usize {
        self.k * self.k - 1
    }

    /// Streaming latency: the window walk over the frame plus the tree
    /// depth; same blanking structure as the C_PE core (shared LBC).
    pub fn latency_cycles(&self, blank: Blanking) -> usize {
        let pb = blank.back_porch;
        let pf = blank.front_porch;
        let tree = (self.k * self.k).next_power_of_two().trailing_zeros() as usize + 1;
        (self.fm_w + pb + pf) * self.fm_h + self.k + tree + 4
    }

    /// Sec. III-B: ~420 LUTs per PU_PE (Table I for sized windows), zero
    /// DSP, one BRAM.
    pub fn resources(&self) -> Resources {
        Resources {
            dsp: 0,
            lut: luts::pool_luts(self.k),
            ff: luts::pool_regs(self.k),
            bram: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pe() -> PoolPe {
        PoolPe { k: 2, stride: 2, fm_w: 28, fm_h: 28, kind: PoolKind::Max }
    }

    #[test]
    fn no_dsp_for_pooling() {
        assert_eq!(pe().resources().dsp, 0);
    }

    #[test]
    fn one_bram_per_pe() {
        assert_eq!(pe().resources().bram, 1);
    }

    #[test]
    fn table1_luts() {
        assert_eq!(pe().resources().lut, 300);
        assert_eq!(PoolPe { k: 3, ..pe() }.resources().lut, 420);
    }

    #[test]
    fn comparator_tree_size() {
        assert_eq!(pe().n_compare(), 3);
        assert_eq!(PoolPe { k: 3, ..pe() }.n_compare(), 8);
    }

    #[test]
    fn latency_scales_with_frame() {
        let small = pe().latency_cycles(Blanking::default());
        let big = PoolPe { fm_w: 56, fm_h: 56, ..pe() }.latency_cycles(Blanking::default());
        assert!(big > 3 * small);
    }
}
