//! Table I: empirical LUT / slice-register utilization per filter size.
//!
//! These constants come from the paper's block-level profiling of the
//! Simulink-generated PE implementations; they back the `Y_LUT` lookup of
//! Algorithm 1 (line 16).

/// (filter size K, conv LUTs, pool LUTs, conv slice regs, pool slice regs)
pub const TABLE1: &[(usize, usize, usize, usize, usize)] = &[
    (2, 550, 300, 1250, 750),
    (3, 850, 420, 2000, 1000),
    (4, 1400, 700, 3500, 1400),
    (5, 2000, 900, 5500, 2200),
];

/// Conv PE LUTs for kernel size `k` (nearest Table I row, extrapolating
/// quadratically beyond K=5 — LUTs track K^2 multiplier fan-in).
pub fn conv_luts(k: usize) -> usize {
    lookup(k, 1)
}

/// Pooling PE LUTs for window size `k`.
pub fn pool_luts(k: usize) -> usize {
    lookup(k, 2)
}

/// Conv PE slice registers (FFs).
pub fn conv_regs(k: usize) -> usize {
    lookup(k, 3)
}

/// Pooling PE slice registers (FFs).
pub fn pool_regs(k: usize) -> usize {
    lookup(k, 4)
}

fn column(row: &(usize, usize, usize, usize, usize), col: usize) -> usize {
    match col {
        1 => row.1,
        2 => row.2,
        3 => row.3,
        _ => row.4,
    }
}

/// 1x1 "conv" PEs have no window assembly at all (a bare MAC + control):
/// much leaner than any Table I row. (LUT conv, LUT pool, FF conv, FF pool)
const K1_ROW: (usize, usize, usize, usize) = (110, 70, 140, 70);

fn lookup(k: usize, col: usize) -> usize {
    if k < 2 {
        return match col {
            1 => K1_ROW.0,
            2 => K1_ROW.1,
            3 => K1_ROW.2,
            _ => K1_ROW.3,
        };
    }
    if let Some(row) = TABLE1.iter().find(|r| r.0 == k) {
        return column(row, col);
    }
    // beyond Table I: extrapolate from the K=5 row by K^2 ratio
    let last = TABLE1.last().unwrap();
    column(last, col) * (k * k) / (last.0 * last.0)
}

/// Average per-PE LUT constants quoted in Sec. III-B for quick estimates.
pub const AVG_CONV_PE_LUTS: usize = 800;
pub const AVG_POOL_PE_LUTS: usize = 420;
pub const AVG_FC_PE_LUTS: usize = 360;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_rows() {
        assert_eq!(conv_luts(3), 850);
        assert_eq!(pool_luts(3), 420);
        assert_eq!(conv_regs(5), 5500);
        assert_eq!(pool_regs(2), 750);
    }

    #[test]
    fn one_by_one_scaled_down() {
        assert!(conv_luts(1) < conv_luts(2));
        assert!(conv_regs(1) < conv_regs(2));
        assert!(conv_luts(1) >= 100);
    }

    #[test]
    fn extrapolation_monotone() {
        assert!(conv_luts(7) > conv_luts(5));
        assert_eq!(conv_luts(7), 2000 * 49 / 25);
    }
}
