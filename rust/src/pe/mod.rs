//! Processing-Element analytical models (Sec. III-A/B, Eqs. 1-11).
//!
//! These are NeuroForge's *estimators*: closed-form latency and resource
//! models for the three PE families (conv `C_PE`, pooling `PU_PE`, fully
//! connected `FC_PE`). The DSE evaluates thousands of candidate mappings
//! against these models instead of synthesizing RTL — the paper validates
//! them at 95%+ accuracy for DSP/BRAM and 10-15% for latency (Fig. 10 /
//! Table III); our cycle simulator (`sim/`) plays the "Real" column.

pub mod conv;
pub mod fc;
pub mod luts;
pub mod pool;

/// FPGA resource vector (the objective space of Alg. 1).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Resources {
    pub dsp: usize,
    pub lut: usize,
    pub ff: usize,
    /// 18 Kb block-RAM units
    pub bram: usize,
}

impl Resources {
    pub fn add(&self, other: &Resources) -> Resources {
        Resources {
            dsp: self.dsp + other.dsp,
            lut: self.lut + other.lut,
            ff: self.ff + other.ff,
            bram: self.bram + other.bram,
        }
    }

    pub fn scale(&self, n: usize) -> Resources {
        Resources {
            dsp: self.dsp * n,
            lut: self.lut * n,
            ff: self.ff * n,
            bram: self.bram * n,
        }
    }

    /// Component-wise `<=` against a device budget.
    pub fn fits(&self, budget: &Resources) -> bool {
        self.dsp <= budget.dsp
            && self.lut <= budget.lut
            && self.ff <= budget.ff
            && self.bram <= budget.bram
    }
}

/// Fixed-point width of the datapath (FP_rep of Eq. 11).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FpRep {
    Int8,
    Int16,
}

impl FpRep {
    pub fn bits(self) -> usize {
        match self {
            FpRep::Int8 => 8,
            FpRep::Int16 => 16,
        }
    }
}

/// Target device resource budgets.
#[derive(Debug, Clone, Copy)]
pub struct Device {
    pub name: &'static str,
    pub budget: Resources,
    pub clock_mhz: f64,
}

/// Xilinx Zynq-7100 (Table V header: 444K LUTs, 26.5 Mb BRAM, 2020 DSPs),
/// operated at 250 MHz throughout the paper.
pub const ZYNQ_7100: Device = Device {
    name: "Zynq-7100",
    budget: Resources {
        dsp: 2020,
        lut: 444_000,
        ff: 554_800,
        bram: 1510, // 26.5 Mb / 18 Kb blocks
    },
    clock_mhz: 250.0,
};

/// Zynq-7020 (PYNQ-class part) — the small-edge portability target.
pub const ZYNQ_7020: Device = Device {
    name: "Zynq-7020",
    budget: Resources { dsp: 220, lut: 53_200, ff: 106_400, bram: 280 },
    clock_mhz: 200.0,
};

/// ZCU102 (Zynq UltraScale+ ZU9EG) — the board Vitis-AI rows use.
pub const ZCU102: Device = Device {
    name: "ZCU102",
    budget: Resources { dsp: 2520, lut: 274_080, ff: 548_160, bram: 1824 },
    clock_mhz: 300.0,
};

/// Kintex-7 410T — the hls4ml comparison part.
pub const KINTEX_7: Device = Device {
    name: "Kintex-7",
    budget: Resources { dsp: 1540, lut: 254_200, ff: 508_400, bram: 1590 },
    clock_mhz: 200.0,
};

/// Device catalog for portability sweeps.
pub const DEVICES: &[&Device] = &[&ZYNQ_7020, &KINTEX_7, &ZYNQ_7100, &ZCU102];

/// Streaming interface blanking intervals (the back/front porch of Eq. 4;
/// the video-style control signalling of Fig. 4).
#[derive(Debug, Clone, Copy)]
pub struct Blanking {
    pub back_porch: usize,
    pub front_porch: usize,
}

impl Default for Blanking {
    fn default() -> Self {
        Blanking { back_porch: 2, front_porch: 2 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resources_arithmetic() {
        let a = Resources { dsp: 1, lut: 10, ff: 20, bram: 2 };
        let b = a.scale(3);
        assert_eq!(b, Resources { dsp: 3, lut: 30, ff: 60, bram: 6 });
        assert_eq!(a.add(&b).dsp, 4);
    }

    #[test]
    fn fits_budget() {
        let need = Resources { dsp: 100, lut: 1000, ff: 0, bram: 5 };
        assert!(need.fits(&ZYNQ_7100.budget));
        let over = Resources { dsp: 3000, ..need };
        assert!(!over.fits(&ZYNQ_7100.budget));
    }

    #[test]
    fn zynq_constants_match_table5() {
        assert_eq!(ZYNQ_7100.budget.dsp, 2020);
        assert_eq!(ZYNQ_7100.budget.lut, 444_000);
        assert_eq!(ZYNQ_7100.clock_mhz, 250.0);
    }

    #[test]
    fn device_catalog_ordered_by_dsp_capacity_class() {
        assert!(ZYNQ_7020.budget.dsp < KINTEX_7.budget.dsp);
        assert!(ZYNQ_7100.budget.dsp < ZCU102.budget.dsp);
        assert_eq!(DEVICES.len(), 4);
    }
}
