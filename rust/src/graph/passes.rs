//! Pass pipeline: lowers a validated [`Network`] into a [`StagePlan`] —
//! the scheduled streaming-dataflow form every downstream consumer
//! (`design`, `sim`, `rtl`, `dse`, `morph`) reads instead of walking the
//! raw layer list.
//!
//! Three passes run in sequence:
//!
//! 1. **canonicalize** — fold standalone [`LayerKind::Relu`] nodes into
//!    their producing conv/FC (exporters often emit activation as its own
//!    node; the hardware fuses it into the PE's output stage for free).
//!    Ids are renumbered densely and every `from` reference is remapped.
//! 2. **fuse / block grouping** — conv-like stages are numbered into
//!    *gate blocks* (the NeuroMorph clock-gate bits: gate block `i` is
//!    the i-th conv/dwconv stage in stream order, and the non-conv
//!    stages it dominates ride on the same enable). Chains keep the
//!    legacy "one bit per conv layer" semantics exactly.
//! 3. **schedule** — emit stages in topological order with explicit
//!    dataflow edges. Layer-id order *is* a topological order
//!    (`Network::validate` rejects non-forward edges), and the pass
//!    re-verifies producer-before-consumer for every edge. Each edge
//!    carries its FIFO/buffer requirement:
//!
//!    * `Stream` — in-band pipeline edge; buffering lives in the
//!      consumer's line buffers, zero extra words.
//!    * `Skip` — residual shortcut; folded into the adder's register
//!      FIFO (the legacy `ResidualAdd` LUT/FF cost), zero extra words.
//!    * `Branch` — a non-primary `Concat` input. The merge must
//!      re-synchronize branches of different latency, so the edge
//!      buffers its full source feature map (`h*w*c` words); `design`
//!      turns the words into 18 Kb BRAM at the datapath width.
//!
//! The plan also fixes the **DSE gene order**: `conv_stage_ids[g]` is the
//! stage that chromosome slot `g` parallelizes, with bounds
//! [`StagePlan::conv_bounds`] — identical to the legacy
//! `Network::conv_filter_bounds` order, so chromosomes and
//! `BENCH_dse.json` stay comparable.

use super::shapes::{self, FeatureShape};
use super::{Layer, LayerKind, Network};
use crate::util::json::Json;

/// Error raised by the pass pipeline.
#[derive(Debug)]
pub enum PassError {
    Shape(shapes::ShapeError),
    Invalid(String),
}

impl std::fmt::Display for PassError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PassError::Shape(e) => write!(f, "pass pipeline: {e}"),
            PassError::Invalid(msg) => write!(f, "pass pipeline: {msg}"),
        }
    }
}

impl std::error::Error for PassError {}

impl From<shapes::ShapeError> for PassError {
    fn from(e: shapes::ShapeError) -> Self {
        PassError::Shape(e)
    }
}

/// How an edge of the scheduled dataflow graph is buffered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// In-band pipeline edge (line buffers inside the consumer).
    Stream,
    /// Residual shortcut (register FIFO inside the adder).
    Skip,
    /// Fork/merge branch: buffers its full source fmap for re-sync.
    Branch,
}

/// One scheduled dataflow edge with its buffering requirement.
#[derive(Debug, Clone)]
pub struct EdgeBuf {
    /// producing stage id
    pub src: usize,
    /// consuming stage id
    pub dst: usize,
    /// feature map crossing the edge (the producer's output)
    pub shape: FeatureShape,
    /// words of FIFO buffering the edge needs (0 for Stream/Skip)
    pub fifo_words: usize,
    pub kind: EdgeKind,
}

/// One streaming stage of the scheduled plan.
#[derive(Debug, Clone)]
pub struct Stage {
    /// stage id == canonical layer id (topological order)
    pub id: usize,
    pub name: String,
    pub kind: LayerKind,
    /// primary (or, for Concat, merged) input shape
    pub input: FeatureShape,
    pub output: FeatureShape,
    /// producing stage ids, primary first (Concat: the `from` list)
    pub preds: Vec<usize>,
    /// DSE chromosome slot driving this stage's parallelism (conv-like)
    pub conv_slot: Option<usize>,
    /// NeuroMorph clock-gate bit this stage toggles with (conv-like)
    pub gate_block: Option<usize>,
}

impl Stage {
    pub fn is_conv_like(&self) -> bool {
        matches!(self.kind, LayerKind::Conv { .. } | LayerKind::DwConv { .. })
    }
}

/// The scheduled plan: the single source of truth for every consumer.
#[derive(Debug, Clone)]
pub struct StagePlan {
    pub net_name: String,
    /// input frame dimensions (h, w, c)
    pub input_dims: (usize, usize, usize),
    /// stages in topological (stream) order
    pub stages: Vec<Stage>,
    /// all dataflow edges with buffering requirements
    pub edges: Vec<EdgeBuf>,
    /// stage id per DSE chromosome slot, in gene order
    pub conv_stage_ids: Vec<usize>,
    /// number of NeuroMorph gate blocks (== conv-like stage count)
    pub gate_blocks: usize,
}

impl StagePlan {
    /// Per-gene parallelism upper bounds, in chromosome order — identical
    /// to the legacy `Network::conv_filter_bounds`.
    pub fn conv_bounds(&self) -> Vec<usize> {
        self.conv_stage_ids
            .iter()
            .map(|&s| match self.stages[s].kind {
                LayerKind::Conv { filters, .. } => filters,
                LayerKind::DwConv { .. } => 1,
                _ => unreachable!("conv_stage_ids only lists conv-like stages"),
            })
            .collect()
    }

    /// Total branch-FIFO words buffered at a merge stage's inputs.
    pub fn branch_words_into(&self, stage: usize) -> usize {
        self.edges
            .iter()
            .filter(|e| e.dst == stage && e.kind == EdgeKind::Branch)
            .map(|e| e.fifo_words)
            .sum()
    }

    /// True when the plan is a pure chain (every stage has <= 1 pred and
    /// no branch buffering anywhere).
    pub fn is_chain(&self) -> bool {
        self.stages.iter().all(|s| s.preds.len() <= 1)
    }

    /// JSON view of the plan (the `graph dump` CLI payload).
    pub fn to_json(&self) -> Json {
        fn shape_json(s: FeatureShape) -> Json {
            Json::Arr(vec![
                Json::Num(s.h as f64),
                Json::Num(s.w as f64),
                Json::Num(s.c as f64),
            ])
        }
        let stages = self
            .stages
            .iter()
            .map(|s| {
                let mut o = std::collections::BTreeMap::new();
                o.insert("id".into(), Json::Num(s.id as f64));
                o.insert("name".into(), Json::Str(s.name.clone()));
                o.insert("op".into(), Json::Str(kind_name(&s.kind).into()));
                o.insert("input".into(), shape_json(s.input));
                o.insert("output".into(), shape_json(s.output));
                o.insert(
                    "preds".into(),
                    Json::Arr(s.preds.iter().map(|&p| Json::Num(p as f64)).collect()),
                );
                if let Some(slot) = s.conv_slot {
                    o.insert("conv_slot".into(), Json::Num(slot as f64));
                }
                if let Some(g) = s.gate_block {
                    o.insert("gate_block".into(), Json::Num(g as f64));
                }
                Json::Obj(o)
            })
            .collect();
        let edges = self
            .edges
            .iter()
            .map(|e| {
                let mut o = std::collections::BTreeMap::new();
                o.insert("src".into(), Json::Num(e.src as f64));
                o.insert("dst".into(), Json::Num(e.dst as f64));
                o.insert(
                    "kind".into(),
                    Json::Str(
                        match e.kind {
                            EdgeKind::Stream => "stream",
                            EdgeKind::Skip => "skip",
                            EdgeKind::Branch => "branch",
                        }
                        .into(),
                    ),
                );
                o.insert("fifo_words".into(), Json::Num(e.fifo_words as f64));
                o.insert("shape".into(), shape_json(e.shape));
                Json::Obj(o)
            })
            .collect();
        let mut root = std::collections::BTreeMap::new();
        root.insert("name".into(), Json::Str(self.net_name.clone()));
        root.insert(
            "input".into(),
            Json::Arr(vec![
                Json::Num(self.input_dims.0 as f64),
                Json::Num(self.input_dims.1 as f64),
                Json::Num(self.input_dims.2 as f64),
            ]),
        );
        root.insert("stages".into(), Json::Arr(stages));
        root.insert("edges".into(), Json::Arr(edges));
        root.insert(
            "conv_bounds".into(),
            Json::Arr(self.conv_bounds().iter().map(|&b| Json::Num(b as f64)).collect()),
        );
        root.insert("gate_blocks".into(), Json::Num(self.gate_blocks as f64));
        Json::Obj(root)
    }
}

/// Short op mnemonic for dumps and reports.
pub fn kind_name(kind: &LayerKind) -> &'static str {
    match kind {
        LayerKind::Input { .. } => "input",
        LayerKind::Conv { .. } => "conv",
        LayerKind::DwConv { .. } => "dwconv",
        LayerKind::MaxPool { .. } => "maxpool",
        LayerKind::AvgPool { .. } => "avgpool",
        LayerKind::GlobalAvgPool => "gap",
        LayerKind::Fc { .. } => "fc",
        LayerKind::ResidualAdd { .. } => "residual_add",
        LayerKind::Concat { .. } => "concat",
        LayerKind::Upsample { .. } => "upsample",
        LayerKind::SpatialPyramidPool { .. } => "sppf",
        LayerKind::Relu => "relu",
        LayerKind::Softmax => "softmax",
    }
}

/// Pass 1: fold standalone `Relu` nodes into their conv/FC producer,
/// renumbering ids densely and remapping every `from` reference. A `Relu`
/// whose producer cannot carry an activation (pools, merges, ...) is kept
/// as its own pass-through stage. Networks without standalone `Relu`
/// come back byte-identical.
pub fn canonicalize(net: &Network) -> Result<Network, PassError> {
    net.validate_structure().map_err(PassError::Invalid)?;
    if !net.layers.iter().any(|l| matches!(l.kind, LayerKind::Relu)) {
        return Ok(net.clone());
    }
    let preds = shapes::predecessors(net);
    let n = net.layers.len();
    // out-degree per layer: a relu only folds into a producer whose SOLE
    // consumer it is — if anyone else taps the producer pre-activation
    // (a fork), folding would silently hand them the activated stream
    let mut out_deg = vec![0usize; n];
    for &(s, d) in &net.connections {
        if s < d && d < n {
            out_deg[s] += 1;
        }
    }
    let mut map: Vec<usize> = vec![0; n];
    // old relu id -> old producer id it folds into
    let mut fold_into: Vec<Option<usize>> = vec![None; n];
    let mut layers: Vec<Layer> = Vec::new();

    for (i, l) in net.layers.iter().enumerate() {
        if matches!(l.kind, LayerKind::Relu) && i > 0 {
            let p = preds[i].first().copied().unwrap_or(i - 1);
            let fusable = matches!(
                net.layers[p].kind,
                LayerKind::Conv { .. } | LayerKind::DwConv { .. } | LayerKind::Fc { .. }
            ) && out_deg[p] <= 1;
            if fusable {
                fold_into[i] = Some(p);
                map[i] = map[p];
                continue;
            }
        }
        let id = layers.len();
        map[i] = id;
        layers.push(Layer { id, name: l.name.clone(), kind: l.kind.clone() });
    }

    for i in 0..n {
        if let Some(p) = fold_into[i] {
            match &mut layers[map[p]].kind {
                LayerKind::Conv { relu, .. }
                | LayerKind::DwConv { relu, .. }
                | LayerKind::Fc { relu, .. } => *relu = true,
                _ => unreachable!("fold target is conv-like by construction"),
            }
        }
    }
    for l in &mut layers {
        match &mut l.kind {
            LayerKind::ResidualAdd { from } => *from = map[*from],
            LayerKind::Concat { from } => {
                for f in from.iter_mut() {
                    *f = map[*f];
                }
            }
            _ => {}
        }
    }
    let mut connections: Vec<(usize, usize)> = Vec::new();
    for &(s, d) in &net.connections {
        let e = (map[s], map[d]);
        if e.0 != e.1 && !connections.contains(&e) {
            connections.push(e);
        }
    }
    let canon = Network { name: net.name.clone(), layers, connections };
    canon.validate_structure().map_err(PassError::Invalid)?;
    Ok(canon)
}

/// Passes 2+3: canonicalize, group gate blocks and schedule the plan.
/// Exactly ONE shape inference runs per call (it doubles as the shape
/// validation), and relu-free networks are scheduled without cloning.
pub fn schedule(net: &Network) -> Result<StagePlan, PassError> {
    let canon: std::borrow::Cow<'_, Network> =
        if net.layers.iter().any(|l| matches!(l.kind, LayerKind::Relu)) {
            std::borrow::Cow::Owned(canonicalize(net)?)
        } else {
            net.validate_structure().map_err(PassError::Invalid)?;
            std::borrow::Cow::Borrowed(net)
        };
    let canon: &Network = &canon;
    let shp = shapes::infer(canon)?;
    let preds = shapes::predecessors(canon);
    let n = canon.layers.len();

    let mut stages: Vec<Stage> = Vec::with_capacity(n);
    let mut edges: Vec<EdgeBuf> = Vec::new();
    let mut conv_stage_ids: Vec<usize> = Vec::new();

    for l in &canon.layers {
        let id = l.id;
        // effective inputs, primary first; hand-assembled graphs without
        // recorded edges fall back to the chain predecessor (mirrors
        // shapes::infer)
        let eff: Vec<usize> = match &l.kind {
            LayerKind::Input { .. } => Vec::new(),
            LayerKind::Concat { from } => from.clone(),
            _ if preds[id].is_empty() && id > 0 => vec![id - 1],
            _ => preds[id].clone(),
        };
        for &p in &eff {
            if p >= id {
                return Err(PassError::Invalid(format!(
                    "stage {id} ({}) consumes later stage {p} — not schedulable",
                    l.name
                )));
            }
        }
        match &l.kind {
            LayerKind::Concat { .. } => {
                for (i, &p) in eff.iter().enumerate() {
                    let shape = shp.output(p);
                    let (kind, words) = if i == 0 {
                        (EdgeKind::Stream, 0)
                    } else {
                        (EdgeKind::Branch, shape.features())
                    };
                    edges.push(EdgeBuf { src: p, dst: id, shape, fifo_words: words, kind });
                }
            }
            LayerKind::ResidualAdd { from } => {
                for (i, &p) in eff.iter().enumerate() {
                    let kind = if i > 0 || (p == *from && eff.len() == 1) {
                        EdgeKind::Skip
                    } else {
                        EdgeKind::Stream
                    };
                    edges.push(EdgeBuf {
                        src: p,
                        dst: id,
                        shape: shp.output(p),
                        fifo_words: 0,
                        kind,
                    });
                }
            }
            _ => {
                for &p in &eff {
                    edges.push(EdgeBuf {
                        src: p,
                        dst: id,
                        shape: shp.output(p),
                        fifo_words: 0,
                        kind: EdgeKind::Stream,
                    });
                }
            }
        }
        let conv_like =
            matches!(l.kind, LayerKind::Conv { .. } | LayerKind::DwConv { .. });
        let (conv_slot, gate_block) = if conv_like {
            let slot = conv_stage_ids.len();
            conv_stage_ids.push(id);
            (Some(slot), Some(slot))
        } else {
            (None, None)
        };
        stages.push(Stage {
            id,
            name: l.name.clone(),
            kind: l.kind.clone(),
            input: shp.input(id),
            output: shp.output(id),
            preds: eff,
            conv_slot,
            gate_block,
        });
    }

    let gate_blocks = conv_stage_ids.len();
    // Load-bearing morph invariant: GateMask::depth_prefix and
    // gate_mask_for size masks from the RAW network's conv count, while
    // the simulator gates by the plan's gate_block indices. Any future
    // pass that merges/reorders conv-like stages must renumber both
    // sides together — fail loudly here rather than desync silently.
    if gate_blocks != net.conv_layer_ids().len() {
        return Err(PassError::Invalid(format!(
            "pass pipeline changed the conv-stage count ({} -> {gate_blocks}); \
             morph gate masks would desync",
            net.conv_layer_ids().len()
        )));
    }
    Ok(StagePlan {
        net_name: canon.name.clone(),
        input_dims: net.input_dims(),
        stages,
        edges,
        conv_stage_ids,
        gate_blocks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{zoo, NetworkBuilder, Padding};

    #[test]
    fn chain_plan_mirrors_layer_list() {
        let net = zoo::mnist();
        let plan = schedule(&net).unwrap();
        assert_eq!(plan.stages.len(), net.layers.len());
        assert!(plan.is_chain());
        assert_eq!(plan.conv_bounds(), net.conv_filter_bounds());
        assert_eq!(plan.gate_blocks, net.conv_layer_ids().len());
        for s in &plan.stages {
            for &p in &s.preds {
                assert!(p < s.id, "producer after consumer");
            }
        }
        // every edge unbuffered on a chain
        assert!(plan.edges.iter().all(|e| e.fifo_words == 0));
    }

    #[test]
    fn residual_plan_keeps_zero_cost_skips() {
        let plan = schedule(&zoo::resnet50()).unwrap();
        let skips: Vec<&EdgeBuf> =
            plan.edges.iter().filter(|e| e.kind == EdgeKind::Skip).collect();
        assert!(!skips.is_empty());
        assert!(skips.iter().all(|e| e.fifo_words == 0));
        assert_eq!(plan.branch_words_into(plan.stages.len() - 1), 0);
    }

    #[test]
    fn concat_branches_get_full_fmap_fifos() {
        let mut b = NetworkBuilder::new("y", 8, 8, 4).conv(4, 3, 1, Padding::Same, true);
        let stem = b.mark();
        b = b.conv(2, 1, 1, Padding::Same, true);
        let left = b.mark();
        b = b.branch_from(stem).conv(6, 1, 1, Padding::Same, true);
        let right = b.mark();
        b = b.concat(&[left, right]);
        let merge = b.mark();
        let net = b.build();
        let plan = schedule(&net).unwrap();
        assert!(!plan.is_chain());
        // primary input streams, the other buffers its whole 8x8x6 fmap
        assert_eq!(plan.branch_words_into(merge), 8 * 8 * 6);
        let branch = plan
            .edges
            .iter()
            .find(|e| e.kind == EdgeKind::Branch)
            .expect("branch edge");
        assert_eq!((branch.src, branch.dst), (right, merge));
    }

    #[test]
    fn relu_fuses_into_producer() {
        let net = NetworkBuilder::new("r", 8, 8, 1)
            .conv(4, 3, 1, Padding::Same, false)
            .relu()
            .maxpool(2, 2)
            .build();
        let canon = canonicalize(&net).unwrap();
        assert_eq!(canon.layers.len(), net.layers.len() - 1);
        assert!(matches!(
            canon.layers[1].kind,
            LayerKind::Conv { relu: true, .. }
        ));
        // edges re-route around the folded node
        assert!(canon.connections.contains(&(1, 2)));
        // shape agreement pre/post fusion at the surviving frontier
        let pre = crate::graph::shapes::infer(&net).unwrap();
        let post = crate::graph::shapes::infer(&canon).unwrap();
        assert_eq!(pre.final_output(), post.final_output());
    }

    #[test]
    fn relu_not_fused_when_producer_is_forked() {
        // conv feeds both a standalone relu AND a pre-activation branch
        // consumer: folding would hand the branch the activated stream,
        // so the relu must survive as its own stage
        let mut b = NetworkBuilder::new("f", 8, 8, 2).conv(4, 3, 1, Padding::Same, false);
        let stem = b.mark();
        b = b.relu();
        let act = b.mark();
        b = b.branch_from(stem).conv(4, 1, 1, Padding::Same, false);
        let side = b.mark();
        let net = b.concat(&[act, side]).build();
        let canon = canonicalize(&net).unwrap();
        assert_eq!(canon.layers.len(), net.layers.len(), "no fold on forked producer");
        assert!(matches!(canon.layers[stem].kind, LayerKind::Conv { relu: false, .. }));
        assert!(matches!(canon.layers[act].kind, LayerKind::Relu));
        let plan = schedule(&net).unwrap();
        assert_eq!(plan.stages.len(), net.layers.len());
    }

    #[test]
    fn unfusable_relu_stays_a_stage() {
        let net = NetworkBuilder::new("r2", 8, 8, 2)
            .maxpool(2, 2)
            .relu()
            .build();
        let canon = canonicalize(&net).unwrap();
        assert_eq!(canon.layers.len(), net.layers.len());
        assert!(matches!(canon.layers[2].kind, LayerKind::Relu));
        let plan = schedule(&net).unwrap();
        assert_eq!(plan.stages.len(), 3);
    }

    #[test]
    fn no_relu_network_is_untouched() {
        let net = zoo::cifar10();
        let canon = canonicalize(&net).unwrap();
        assert_eq!(canon.layers, net.layers);
        assert_eq!(canon.connections, net.connections);
    }

    #[test]
    fn plan_json_shape() {
        let plan = schedule(&zoo::mnist()).unwrap();
        let j = plan.to_json();
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert!(back.get("stages").is_some());
        assert!(back.get("gate_blocks").is_some());
    }
}
