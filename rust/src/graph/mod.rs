//! CNN dataflow-graph IR — the input language of NeuroForge (Sec. III-A).
//!
//! The parser/builder produce a [`Network`]: a layer list in topological
//! order plus an explicit connection table (the dataflow edges).
//! Sequential CNNs are strict chains; residual architectures add skip
//! edges that converge in [`LayerKind::ResidualAdd`] layers; branchy
//! topologies (CSP blocks, FPN/PAN necks, U-Nets) fork the stream and
//! re-merge it through [`LayerKind::Concat`] (multi-input, channel-wise)
//! with [`LayerKind::Upsample`] / [`LayerKind::SpatialPyramidPool`]
//! covering the remaining detector-family constructs.
//!
//! Downstream consumers do not walk this layer list directly: the
//! [`passes`] pipeline (canonicalize -> fuse -> schedule) lowers a
//! validated `Network` into a [`passes::StagePlan`] of streaming stages
//! with per-edge FIFO requirements, and `design`/`sim`/`rtl`/`dse`/
//! `morph` all consume the plan.

pub mod builder;
pub mod parser;
pub mod passes;
pub mod shapes;
pub mod zoo;

pub use builder::NetworkBuilder;
pub use passes::{schedule, StagePlan};
pub use shapes::{FeatureShape, ShapeError};

/// Spatial padding mode of a conv layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Padding {
    Same,
    Valid,
}

/// One node of the network graph.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// Source of the streaming pipeline: frame dimensions.
    Input { h: usize, w: usize, c: usize },
    /// Standard convolution (maps to a C_PE array).
    Conv {
        filters: usize,
        k: usize,
        stride: usize,
        padding: Padding,
        relu: bool,
    },
    /// Depthwise convolution (MobileNet-style; one filter per channel).
    DwConv { k: usize, stride: usize, padding: Padding, relu: bool },
    /// Max pooling (PU_PE with comparator tree).
    MaxPool { k: usize, stride: usize },
    /// Average pooling (PU_PE with fixed coefficients).
    AvgPool { k: usize, stride: usize },
    /// Global average pooling to a vector.
    GlobalAvgPool,
    /// Fully connected layer (FC_PE bank).
    Fc { out: usize, relu: bool },
    /// Element-wise addition merging a skip edge from `from` (layer id).
    ResidualAdd { from: usize },
    /// Channel-wise concatenation of the listed source layers, in order.
    /// Unlike `ResidualAdd` the inputs are fully explicit: the layer is
    /// connected to exactly the ids in `from` (all spatially equal).
    Concat { from: Vec<usize> },
    /// Nearest-neighbour spatial upsampling by an integer factor
    /// (FPN top-down pathway).
    Upsample { factor: usize },
    /// SPPF-style pyramid: three cascaded stride-1 `k x k` max pools
    /// whose taps (input + the three pool outputs) concatenate to 4x the
    /// input channels. Spatial dimensions are preserved.
    SpatialPyramidPool { k: usize },
    /// Standalone rectifier (some exporters emit activation as its own
    /// node); the pass pipeline fuses it into the producing conv/FC.
    Relu,
    /// Final classifier non-linearity (optional, streamed inline).
    Softmax,
}

/// A layer instance with identity and kind.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub id: usize,
    pub name: String,
    pub kind: LayerKind,
}

/// A parsed network: layers in topological (stream) order plus the
/// connection table (src -> dst layer ids). For sequential models the
/// table is the chain `(i, i+1)`; residual models add skip edges and
/// branchy models add fork edges (`builder::NetworkBuilder::branch_from`)
/// plus the multi-input edges of `Concat` merges. `validate` enforces
/// that every edge points forward, so layer-id order is always a valid
/// topological order of the dataflow graph.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
    pub connections: Vec<(usize, usize)>,
}

impl Network {
    /// The input layer dimensions. Panics if the network is malformed
    /// (builder/parser guarantee layer 0 is `Input`).
    pub fn input_dims(&self) -> (usize, usize, usize) {
        match self.layers[0].kind {
            LayerKind::Input { h, w, c } => (h, w, c),
            _ => unreachable!("layer 0 is always Input"),
        }
    }

    /// Ids of conv-like layers (the DSE decision variables map onto these).
    pub fn conv_layer_ids(&self) -> Vec<usize> {
        self.layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv { .. } | LayerKind::DwConv { .. }))
            .map(|l| l.id)
            .collect()
    }

    /// Per-conv-layer filter counts — the DSE upper bounds ub(i) (Alg. 1).
    pub fn conv_filter_bounds(&self) -> Vec<usize> {
        self.layers
            .iter()
            .filter_map(|l| match l.kind {
                LayerKind::Conv { filters, .. } => Some(filters),
                LayerKind::DwConv { .. } => Some(1), // one PE lane per channel group
                _ => None,
            })
            .collect()
    }

    /// True if the network contains skip connections.
    pub fn is_residual(&self) -> bool {
        self.layers
            .iter()
            .any(|l| matches!(l.kind, LayerKind::ResidualAdd { .. }))
    }

    /// True if the network forks into parallel branches that re-merge
    /// through `Concat` (CSP / FPN / U-Net style topologies).
    pub fn has_branches(&self) -> bool {
        self.layers
            .iter()
            .any(|l| matches!(l.kind, LayerKind::Concat { .. }))
    }

    /// Total trainable parameters (weights + biases), following shapes.
    pub fn count_params(&self) -> Result<usize, ShapeError> {
        let shapes = shapes::infer(self)?;
        let mut total = 0usize;
        for layer in &self.layers {
            let cin = shapes.input_channels(layer.id);
            total += match layer.kind {
                LayerKind::Conv { filters, k, .. } => k * k * cin * filters + filters,
                LayerKind::DwConv { k, .. } => k * k * cin + cin,
                LayerKind::Fc { out, .. } => shapes.input_features(layer.id) * out + out,
                _ => 0,
            };
        }
        Ok(total)
    }

    /// Total MAC operations for one frame.
    pub fn count_macs(&self) -> Result<usize, ShapeError> {
        let shapes = shapes::infer(self)?;
        let mut total = 0usize;
        for layer in &self.layers {
            let out = shapes.output(layer.id);
            let cin = shapes.input_channels(layer.id);
            total += match layer.kind {
                LayerKind::Conv { k, .. } => out.h * out.w * out.c * k * k * cin,
                LayerKind::DwConv { k, .. } => out.h * out.w * out.c * k * k,
                LayerKind::Fc { out: o, .. } => shapes.input_features(layer.id) * o,
                _ => 0,
            };
        }
        Ok(total)
    }

    /// Validate graph structure AND shape feasibility (runs full shape
    /// inference). The pass pipeline uses [`Self::validate_structure`] +
    /// its own single inference instead, so `schedule()` never infers
    /// shapes twice.
    pub fn validate(&self) -> Result<(), String> {
        self.validate_structure()?;
        shapes::infer(self).map_err(|e| e.to_string())?;
        Ok(())
    }

    /// Structural validation only: ids contiguous, connections reference
    /// existing layers and point forward, merge sources precede their
    /// merge point. No shape inference.
    pub fn validate_structure(&self) -> Result<(), String> {
        if self.layers.is_empty() {
            return Err("empty network".into());
        }
        if !matches!(self.layers[0].kind, LayerKind::Input { .. }) {
            return Err("first layer must be Input".into());
        }
        for (i, l) in self.layers.iter().enumerate() {
            if l.id != i {
                return Err(format!("layer {i} has id {}", l.id));
            }
            if i > 0 && matches!(l.kind, LayerKind::Input { .. }) {
                return Err(format!("layer {i}: Input must be unique/first"));
            }
            match &l.kind {
                LayerKind::ResidualAdd { from } => {
                    if *from >= i {
                        return Err(format!(
                            "layer {i}: residual source {from} must precede the merge"
                        ));
                    }
                }
                LayerKind::Concat { from } => {
                    if from.len() < 2 {
                        return Err(format!(
                            "layer {i}: concat needs at least 2 inputs, has {}",
                            from.len()
                        ));
                    }
                    for &f in from {
                        if f >= i {
                            return Err(format!(
                                "layer {i}: concat source {f} must precede the merge"
                            ));
                        }
                    }
                }
                LayerKind::Upsample { factor } => {
                    if *factor == 0 {
                        return Err(format!("layer {i}: upsample factor must be >= 1"));
                    }
                }
                LayerKind::SpatialPyramidPool { k } => {
                    if *k < 2 {
                        return Err(format!("layer {i}: pyramid pool window must be >= 2"));
                    }
                }
                _ => {}
            }
        }
        for &(s, d) in &self.connections {
            if s >= self.layers.len() || d >= self.layers.len() {
                return Err(format!("connection ({s},{d}) references missing layer"));
            }
            if s >= d {
                return Err(format!("connection ({s},{d}) must be forward"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        NetworkBuilder::new("tiny", 8, 8, 1)
            .conv(4, 3, 1, Padding::Same, true)
            .maxpool(2, 2)
            .fc(10, false)
            .build()
    }

    #[test]
    fn chain_connections() {
        let n = tiny();
        assert_eq!(n.connections, vec![(0, 1), (1, 2), (2, 3)]);
        assert!(n.validate().is_ok());
        assert!(!n.is_residual());
    }

    #[test]
    fn conv_bounds() {
        let n = tiny();
        assert_eq!(n.conv_layer_ids(), vec![1]);
        assert_eq!(n.conv_filter_bounds(), vec![4]);
    }

    #[test]
    fn param_count_manual() {
        let n = tiny();
        // conv 3*3*1*4+4 = 40 ; fc: 4*4*4=64 feats -> 64*10+10 = 650
        assert_eq!(n.count_params().unwrap(), 40 + 650);
    }

    #[test]
    fn mac_count_manual() {
        let n = tiny();
        // conv: 8*8*4*9*1 = 2304 ; fc 64*10 = 640
        assert_eq!(n.count_macs().unwrap(), 2304 + 640);
    }

    #[test]
    fn validation_rejects_bad_residual() {
        let mut n = tiny();
        n.layers.push(Layer {
            id: 4,
            name: "res".into(),
            kind: LayerKind::ResidualAdd { from: 9 },
        });
        assert!(n.validate().is_err());
    }

    #[test]
    fn validation_rejects_backward_edge() {
        let mut n = tiny();
        n.connections.push((3, 1));
        assert!(n.validate().is_err());
    }
}
