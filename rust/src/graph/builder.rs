//! Programmatic network construction (the "from high-level specification"
//! entry point; the JSON descriptor parser builds on the same API).

use super::{Layer, LayerKind, Network, Padding};

/// Fluent builder producing a validated [`Network`].
pub struct NetworkBuilder {
    name: String,
    layers: Vec<Layer>,
    connections: Vec<(usize, usize)>,
    /// id of the most recently appended layer (chain tail)
    tail: usize,
}

impl NetworkBuilder {
    pub fn new(name: &str, h: usize, w: usize, c: usize) -> Self {
        NetworkBuilder {
            name: name.to_string(),
            layers: vec![Layer {
                id: 0,
                name: "input".into(),
                kind: LayerKind::Input { h, w, c },
            }],
            connections: Vec::new(),
            tail: 0,
        }
    }

    fn push(&mut self, name: String, kind: LayerKind) -> usize {
        let id = self.layers.len();
        self.layers.push(Layer { id, name, kind });
        self.connections.push((self.tail, id));
        self.tail = id;
        id
    }

    pub fn conv(mut self, filters: usize, k: usize, stride: usize, padding: Padding, relu: bool) -> Self {
        let n = format!("conv{}", self.layers.len());
        self.push(n, LayerKind::Conv { filters, k, stride, padding, relu });
        self
    }

    pub fn dwconv(mut self, k: usize, stride: usize, padding: Padding, relu: bool) -> Self {
        let n = format!("dwconv{}", self.layers.len());
        self.push(n, LayerKind::DwConv { k, stride, padding, relu });
        self
    }

    pub fn maxpool(mut self, k: usize, stride: usize) -> Self {
        let n = format!("maxpool{}", self.layers.len());
        self.push(n, LayerKind::MaxPool { k, stride });
        self
    }

    pub fn avgpool(mut self, k: usize, stride: usize) -> Self {
        let n = format!("avgpool{}", self.layers.len());
        self.push(n, LayerKind::AvgPool { k, stride });
        self
    }

    pub fn global_avg_pool(mut self) -> Self {
        let n = format!("gap{}", self.layers.len());
        self.push(n, LayerKind::GlobalAvgPool);
        self
    }

    pub fn fc(mut self, out: usize, relu: bool) -> Self {
        let n = format!("fc{}", self.layers.len());
        self.push(n, LayerKind::Fc { out, relu });
        self
    }

    pub fn softmax(mut self) -> Self {
        let n = format!("softmax{}", self.layers.len());
        self.push(n, LayerKind::Softmax);
        self
    }

    /// Mark the current tail as the start of a residual block; returns a
    /// token to merge later with [`Self::residual_add`].
    pub fn fork(&self) -> usize {
        self.tail
    }

    /// Merge the current chain with the skip edge from `fork` (the paper's
    /// convergence point, synthesized as a ResidualAdd arithmetic unit).
    pub fn residual_add(mut self, fork: usize) -> Self {
        let n = format!("resadd{}", self.layers.len());
        let id = self.push(n, LayerKind::ResidualAdd { from: fork });
        self.connections.push((fork, id));
        self
    }

    pub fn build(self) -> Network {
        let net = self.build_unchecked();
        debug_assert!(net.validate().is_ok(), "builder produced invalid net");
        net
    }

    /// Build without validation — for tests that construct intentionally
    /// malformed graphs to exercise error paths.
    pub fn build_unchecked(self) -> Network {
        Network {
            name: self.name,
            layers: self.layers,
            connections: self.connections,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_block_wiring() {
        let mut b = NetworkBuilder::new("res", 16, 16, 8);
        b = b.conv(8, 3, 1, Padding::Same, true);
        let fork = b.fork();
        b = b
            .conv(8, 3, 1, Padding::Same, true)
            .conv(8, 3, 1, Padding::Same, false)
            .residual_add(fork);
        let net = b.build();
        assert!(net.is_residual());
        assert!(net.validate().is_ok());
        // skip edge present
        let merge = net.layers.last().unwrap().id;
        assert!(net.connections.contains(&(fork, merge)));
    }

    #[test]
    fn names_unique() {
        let net = NetworkBuilder::new("x", 8, 8, 1)
            .conv(2, 3, 1, Padding::Same, true)
            .conv(2, 3, 1, Padding::Same, true)
            .build();
        let names: std::collections::BTreeSet<_> =
            net.layers.iter().map(|l| l.name.clone()).collect();
        assert_eq!(names.len(), net.layers.len());
    }
}
