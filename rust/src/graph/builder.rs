//! Programmatic network construction (the "from high-level specification"
//! entry point; the JSON descriptor parser builds on the same API).

use super::{Layer, LayerKind, Network, Padding};

/// Fluent builder producing a validated [`Network`].
pub struct NetworkBuilder {
    name: String,
    layers: Vec<Layer>,
    connections: Vec<(usize, usize)>,
    /// id of the most recently appended layer (chain tail)
    tail: usize,
}

impl NetworkBuilder {
    pub fn new(name: &str, h: usize, w: usize, c: usize) -> Self {
        NetworkBuilder {
            name: name.to_string(),
            layers: vec![Layer {
                id: 0,
                name: "input".into(),
                kind: LayerKind::Input { h, w, c },
            }],
            connections: Vec::new(),
            tail: 0,
        }
    }

    fn push(&mut self, name: String, kind: LayerKind) -> usize {
        let id = self.layers.len();
        self.layers.push(Layer { id, name, kind });
        self.connections.push((self.tail, id));
        self.tail = id;
        id
    }

    pub fn conv(mut self, filters: usize, k: usize, stride: usize, padding: Padding, relu: bool) -> Self {
        let n = format!("conv{}", self.layers.len());
        self.push(n, LayerKind::Conv { filters, k, stride, padding, relu });
        self
    }

    pub fn dwconv(mut self, k: usize, stride: usize, padding: Padding, relu: bool) -> Self {
        let n = format!("dwconv{}", self.layers.len());
        self.push(n, LayerKind::DwConv { k, stride, padding, relu });
        self
    }

    pub fn maxpool(mut self, k: usize, stride: usize) -> Self {
        let n = format!("maxpool{}", self.layers.len());
        self.push(n, LayerKind::MaxPool { k, stride });
        self
    }

    pub fn avgpool(mut self, k: usize, stride: usize) -> Self {
        let n = format!("avgpool{}", self.layers.len());
        self.push(n, LayerKind::AvgPool { k, stride });
        self
    }

    pub fn global_avg_pool(mut self) -> Self {
        let n = format!("gap{}", self.layers.len());
        self.push(n, LayerKind::GlobalAvgPool);
        self
    }

    pub fn fc(mut self, out: usize, relu: bool) -> Self {
        let n = format!("fc{}", self.layers.len());
        self.push(n, LayerKind::Fc { out, relu });
        self
    }

    pub fn softmax(mut self) -> Self {
        let n = format!("softmax{}", self.layers.len());
        self.push(n, LayerKind::Softmax);
        self
    }

    pub fn relu(mut self) -> Self {
        let n = format!("relu{}", self.layers.len());
        self.push(n, LayerKind::Relu);
        self
    }

    pub fn upsample(mut self, factor: usize) -> Self {
        let n = format!("up{}", self.layers.len());
        self.push(n, LayerKind::Upsample { factor });
        self
    }

    /// SPPF-style spatial pyramid pool (three cascaded stride-1 `k x k`
    /// max pools, four-tap concat to 4x channels).
    pub fn sppf(mut self, k: usize) -> Self {
        let n = format!("sppf{}", self.layers.len());
        self.push(n, LayerKind::SpatialPyramidPool { k });
        self
    }

    /// Mark the current tail as the start of a residual block; returns a
    /// token to merge later with [`Self::residual_add`].
    pub fn fork(&self) -> usize {
        self.tail
    }

    /// Id of the most recently appended layer — a token for later
    /// [`Self::branch_from`] / [`Self::concat`] wiring.
    pub fn mark(&self) -> usize {
        self.tail
    }

    /// Rewind the chain tail to an earlier layer: the next appended layer
    /// consumes `id`'s output, opening a parallel branch of the graph.
    pub fn branch_from(mut self, id: usize) -> Self {
        assert!(id < self.layers.len(), "branch_from({id}) out of range");
        self.tail = id;
        self
    }

    /// Merge the current chain with the skip edge from `fork` (the paper's
    /// convergence point, synthesized as a ResidualAdd arithmetic unit).
    pub fn residual_add(mut self, fork: usize) -> Self {
        let n = format!("resadd{}", self.layers.len());
        let id = self.push(n, LayerKind::ResidualAdd { from: fork });
        self.connections.push((fork, id));
        self
    }

    /// Channel-wise concatenation of `from` (all spatially equal). The
    /// merge is connected to exactly these sources, in order — the chain
    /// tail is NOT an implicit input.
    pub fn concat(mut self, from: &[usize]) -> Self {
        let id = self.layers.len();
        for &f in from {
            assert!(f < id, "concat source {f} out of range");
            self.connections.push((f, id));
        }
        self.layers.push(Layer {
            id,
            name: format!("concat{id}"),
            kind: LayerKind::Concat { from: from.to_vec() },
        });
        self.tail = id;
        self
    }

    pub fn build(self) -> Network {
        let net = self.build_unchecked();
        debug_assert!(net.validate().is_ok(), "builder produced invalid net");
        net
    }

    /// Build without validation — for tests that construct intentionally
    /// malformed graphs to exercise error paths.
    pub fn build_unchecked(self) -> Network {
        Network {
            name: self.name,
            layers: self.layers,
            connections: self.connections,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_block_wiring() {
        let mut b = NetworkBuilder::new("res", 16, 16, 8);
        b = b.conv(8, 3, 1, Padding::Same, true);
        let fork = b.fork();
        b = b
            .conv(8, 3, 1, Padding::Same, true)
            .conv(8, 3, 1, Padding::Same, false)
            .residual_add(fork);
        let net = b.build();
        assert!(net.is_residual());
        assert!(net.validate().is_ok());
        // skip edge present
        let merge = net.layers.last().unwrap().id;
        assert!(net.connections.contains(&(fork, merge)));
    }

    #[test]
    fn branch_and_concat_wiring() {
        // two parallel conv branches off one stem, merged channel-wise
        let mut b = NetworkBuilder::new("fork", 16, 16, 8).conv(8, 3, 1, Padding::Same, true);
        let stem = b.mark();
        b = b.conv(4, 1, 1, Padding::Same, true);
        let left = b.mark();
        b = b.branch_from(stem).conv(12, 3, 1, Padding::Same, true);
        let right = b.mark();
        b = b.concat(&[left, right]);
        let merge = b.mark();
        let net = b.conv(6, 1, 1, Padding::Same, true).build();
        assert!(net.has_branches());
        assert!(net.connections.contains(&(left, merge)));
        assert!(net.connections.contains(&(right, merge)));
        // the merge is NOT chained to the branch tail implicitly
        assert_eq!(
            net.connections.iter().filter(|&&(_, d)| d == merge).count(),
            2
        );
        let s = crate::graph::shapes::infer(&net).unwrap();
        assert_eq!(s.output(merge).c, 16);
        assert_eq!(s.final_output().c, 6);
    }

    #[test]
    fn upsample_and_sppf_shapes() {
        let net = NetworkBuilder::new("u", 8, 8, 4)
            .conv(4, 3, 2, Padding::Same, true)
            .upsample(2)
            .sppf(5)
            .build();
        let s = crate::graph::shapes::infer(&net).unwrap();
        assert_eq!(s.output(2), crate::graph::FeatureShape { h: 8, w: 8, c: 4 });
        assert_eq!(s.output(3), crate::graph::FeatureShape { h: 8, w: 8, c: 16 });
    }

    #[test]
    fn concat_spatial_mismatch_rejected() {
        let mut b = NetworkBuilder::new("bad", 16, 16, 4);
        let stem = b.mark();
        b = b.conv(4, 3, 2, Padding::Same, true); // 8x8
        let small = b.mark();
        b = b.branch_from(stem).conv(4, 3, 1, Padding::Same, true); // 16x16
        let big = b.mark();
        let net = b.concat(&[small, big]).build_unchecked();
        assert!(net.validate().is_err());
    }

    #[test]
    fn names_unique() {
        let net = NetworkBuilder::new("x", 8, 8, 1)
            .conv(2, 3, 1, Padding::Same, true)
            .conv(2, 3, 1, Padding::Same, true)
            .build();
        let names: std::collections::BTreeSet<_> =
            net.layers.iter().map(|l| l.name.clone()).collect();
        assert_eq!(names.len(), net.layers.len());
    }
}
