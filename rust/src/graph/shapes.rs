//! Shape inference over the network dataflow graph.
//!
//! Walks the layers in topological (id) order, resolving each layer's
//! input from its incoming edges in the connection table — the feature-map
//! dimensions (FM_H, FM_W, Ch_D) feed the PE latency/resource models
//! (Eqs. 1-11). Multi-input merges (`Concat`) check spatial agreement and
//! sum channels; layers with no recorded edge fall back to the chain
//! predecessor `id - 1`, which keeps hand-assembled test graphs working.

use super::{LayerKind, Network, Padding};

/// Feature-map dimensions at one point of the stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FeatureShape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl FeatureShape {
    pub fn features(&self) -> usize {
        self.h * self.w * self.c
    }
}

#[derive(Debug)]
pub enum ShapeError {
    Invalid { id: usize, name: String, msg: String },
}

impl std::fmt::Display for ShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let ShapeError::Invalid { id, name, msg } = self;
        write!(f, "layer {id} ({name}): {msg}")
    }
}

impl std::error::Error for ShapeError {}

/// Result of inference: per-layer input and output shapes.
#[derive(Debug, Clone)]
pub struct Shapes {
    inputs: Vec<FeatureShape>,
    outputs: Vec<FeatureShape>,
}

impl Shapes {
    pub fn input(&self, layer_id: usize) -> FeatureShape {
        self.inputs[layer_id]
    }

    pub fn output(&self, layer_id: usize) -> FeatureShape {
        self.outputs[layer_id]
    }

    pub fn input_channels(&self, layer_id: usize) -> usize {
        self.inputs[layer_id].c
    }

    pub fn input_features(&self, layer_id: usize) -> usize {
        self.inputs[layer_id].features()
    }

    /// Final output shape of the network.
    pub fn final_output(&self) -> FeatureShape {
        *self.outputs.last().unwrap()
    }
}

fn conv_out(size: usize, k: usize, stride: usize, padding: Padding) -> usize {
    match padding {
        Padding::Same => size.div_ceil(stride),
        Padding::Valid => (size.saturating_sub(k)) / stride + 1,
    }
}

/// Incoming edges per layer, in connection-table insertion order (the
/// builder and parser push the primary/stream edge first).
pub(crate) fn predecessors(net: &Network) -> Vec<Vec<usize>> {
    let n = net.layers.len();
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
    for &(s, d) in &net.connections {
        if s < d && d < n {
            preds[d].push(s);
        }
    }
    preds
}

/// Infer shapes for every layer, validating spatial feasibility.
pub fn infer(net: &Network) -> Result<Shapes, ShapeError> {
    let mut inputs = Vec::with_capacity(net.layers.len());
    let mut outputs: Vec<FeatureShape> = Vec::with_capacity(net.layers.len());
    let preds = predecessors(net);

    for layer in &net.layers {
        let err = |msg: String| ShapeError::Invalid {
            id: layer.id,
            name: layer.name.clone(),
            msg,
        };
        // primary input: first recorded edge, chain fallback otherwise
        let prev = if layer.id == 0 {
            FeatureShape { h: 0, w: 0, c: 0 }
        } else {
            match preds[layer.id].first() {
                Some(&p) => outputs[p],
                None => outputs[layer.id - 1],
            }
        };
        let prev = if let LayerKind::Concat { from } = &layer.kind {
            // merged input: spatially equal sources, channels summed
            let mut merged: Option<FeatureShape> = None;
            for &f in from {
                if f >= layer.id {
                    return Err(err(format!(
                        "concat source {f} does not precede the merge"
                    )));
                }
                let s = outputs[f];
                merged = Some(match merged {
                    None => s,
                    Some(m) => {
                        if (m.h, m.w) != (s.h, s.w) {
                            return Err(err(format!(
                                "concat inputs disagree spatially: {}x{} vs {}x{} \
                                 (source '{}')",
                                m.h, m.w, s.h, s.w, net.layers[f].name
                            )));
                        }
                        FeatureShape { h: m.h, w: m.w, c: m.c + s.c }
                    }
                });
            }
            merged.ok_or_else(|| err("concat has no inputs".into()))?
        } else {
            prev
        };
        inputs.push(prev);
        let out = match layer.kind {
            LayerKind::Input { h, w, c } => {
                if h == 0 || w == 0 || c == 0 {
                    return Err(err("zero input dimension".into()));
                }
                FeatureShape { h, w, c }
            }
            LayerKind::Conv { filters, k, stride, padding, .. } => {
                if stride == 0 || k == 0 {
                    return Err(err("zero kernel/stride".into()));
                }
                if padding == Padding::Valid && (prev.h < k || prev.w < k) {
                    return Err(err(format!(
                        "frame {}x{} smaller than kernel {k}", prev.h, prev.w
                    )));
                }
                FeatureShape {
                    h: conv_out(prev.h, k, stride, padding),
                    w: conv_out(prev.w, k, stride, padding),
                    c: filters,
                }
            }
            LayerKind::DwConv { k, stride, padding, .. } => {
                if padding == Padding::Valid && (prev.h < k || prev.w < k) {
                    return Err(err("frame smaller than kernel".into()));
                }
                FeatureShape {
                    h: conv_out(prev.h, k, stride, padding),
                    w: conv_out(prev.w, k, stride, padding),
                    c: prev.c,
                }
            }
            LayerKind::MaxPool { k, stride } | LayerKind::AvgPool { k, stride } => {
                if prev.h < k || prev.w < k {
                    return Err(err(format!(
                        "frame {}x{} smaller than pool window {k}", prev.h, prev.w
                    )));
                }
                FeatureShape {
                    h: (prev.h - k) / stride + 1,
                    w: (prev.w - k) / stride + 1,
                    c: prev.c,
                }
            }
            LayerKind::GlobalAvgPool => FeatureShape { h: 1, w: 1, c: prev.c },
            LayerKind::Fc { out, .. } => FeatureShape { h: 1, w: 1, c: out },
            LayerKind::ResidualAdd { from } => {
                if from >= layer.id {
                    return Err(err(format!(
                        "residual source {from} does not precede the merge"
                    )));
                }
                let skip = outputs[from];
                if skip != prev {
                    return Err(err(format!(
                        "skip shape {skip:?} != main path shape {prev:?}"
                    )));
                }
                prev
            }
            // merged shape already computed above
            LayerKind::Concat { .. } => prev,
            LayerKind::Upsample { factor } => {
                if factor == 0 {
                    return Err(err("upsample factor must be >= 1".into()));
                }
                FeatureShape { h: prev.h * factor, w: prev.w * factor, c: prev.c }
            }
            LayerKind::SpatialPyramidPool { k } => {
                if k < 2 {
                    return Err(err("pyramid pool window must be >= 2".into()));
                }
                if prev.c == 0 {
                    return Err(err("pyramid pool on empty frame".into()));
                }
                if prev.h < k || prev.w < k {
                    return Err(err(format!(
                        "frame {}x{} smaller than pyramid window {k}", prev.h, prev.w
                    )));
                }
                // stride-1 same-padded pools preserve HxW; four taps
                // (input + three cascaded pools) concatenate channel-wise
                FeatureShape { h: prev.h, w: prev.w, c: 4 * prev.c }
            }
            LayerKind::Relu => prev,
            LayerKind::Softmax => prev,
        };
        outputs.push(out);
    }
    Ok(Shapes { inputs, outputs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::NetworkBuilder;

    #[test]
    fn mnist_chain_shapes() {
        let net = NetworkBuilder::new("m", 28, 28, 1)
            .conv(8, 3, 1, Padding::Same, true)
            .maxpool(2, 2)
            .conv(16, 3, 1, Padding::Same, true)
            .maxpool(2, 2)
            .fc(10, false)
            .build();
        let s = infer(&net).unwrap();
        assert_eq!(s.output(1), FeatureShape { h: 28, w: 28, c: 8 });
        assert_eq!(s.output(2), FeatureShape { h: 14, w: 14, c: 8 });
        assert_eq!(s.output(4), FeatureShape { h: 7, w: 7, c: 16 });
        assert_eq!(s.final_output().c, 10);
        assert_eq!(s.input_features(5), 7 * 7 * 16);
    }

    #[test]
    fn valid_padding_and_stride() {
        let net = NetworkBuilder::new("v", 11, 11, 3)
            .conv(4, 3, 2, Padding::Valid, true)
            .build();
        let s = infer(&net).unwrap();
        assert_eq!(s.output(1), FeatureShape { h: 5, w: 5, c: 4 });
    }

    #[test]
    fn pool_too_large_rejected() {
        let net = NetworkBuilder::new("p", 3, 3, 1).maxpool(4, 4).build_unchecked();
        assert!(infer(&net).is_err());
    }

    #[test]
    fn residual_shape_mismatch_rejected() {
        // fork at 8ch, main path changes to 4ch -> merge must fail
        let mut b = NetworkBuilder::new("r", 8, 8, 8);
        let fork = b.fork();
        b = b.conv(4, 3, 1, Padding::Same, true).residual_add(fork);
        let net = b.build_unchecked();
        assert!(infer(&net).is_err());
    }

    #[test]
    fn dwconv_preserves_channels() {
        let net = NetworkBuilder::new("d", 16, 16, 24)
            .dwconv(3, 2, Padding::Same, true)
            .build();
        let s = infer(&net).unwrap();
        assert_eq!(s.output(1), FeatureShape { h: 8, w: 8, c: 24 });
    }
}
