//! Shape inference over the network graph.
//!
//! Walks the stream order, tracking the feature-map dimensions each layer
//! consumes and produces — the parameters (FM_H, FM_W, Ch_D) that feed
//! the PE latency/resource models (Eqs. 1-11).

use super::{LayerKind, Network, Padding};

/// Feature-map dimensions at one point of the stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FeatureShape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl FeatureShape {
    pub fn features(&self) -> usize {
        self.h * self.w * self.c
    }
}

#[derive(Debug)]
pub enum ShapeError {
    Invalid { id: usize, name: String, msg: String },
}

impl std::fmt::Display for ShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let ShapeError::Invalid { id, name, msg } = self;
        write!(f, "layer {id} ({name}): {msg}")
    }
}

impl std::error::Error for ShapeError {}

/// Result of inference: per-layer input and output shapes.
#[derive(Debug, Clone)]
pub struct Shapes {
    inputs: Vec<FeatureShape>,
    outputs: Vec<FeatureShape>,
}

impl Shapes {
    pub fn input(&self, layer_id: usize) -> FeatureShape {
        self.inputs[layer_id]
    }

    pub fn output(&self, layer_id: usize) -> FeatureShape {
        self.outputs[layer_id]
    }

    pub fn input_channels(&self, layer_id: usize) -> usize {
        self.inputs[layer_id].c
    }

    pub fn input_features(&self, layer_id: usize) -> usize {
        self.inputs[layer_id].features()
    }

    /// Final output shape of the network.
    pub fn final_output(&self) -> FeatureShape {
        *self.outputs.last().unwrap()
    }
}

fn conv_out(size: usize, k: usize, stride: usize, padding: Padding) -> usize {
    match padding {
        Padding::Same => size.div_ceil(stride),
        Padding::Valid => (size.saturating_sub(k)) / stride + 1,
    }
}

/// Infer shapes for every layer, validating spatial feasibility.
pub fn infer(net: &Network) -> Result<Shapes, ShapeError> {
    let mut inputs = Vec::with_capacity(net.layers.len());
    let mut outputs: Vec<FeatureShape> = Vec::with_capacity(net.layers.len());

    for layer in &net.layers {
        let err = |msg: String| ShapeError::Invalid {
            id: layer.id,
            name: layer.name.clone(),
            msg,
        };
        let prev = if layer.id == 0 {
            FeatureShape { h: 0, w: 0, c: 0 }
        } else {
            outputs[layer.id - 1]
        };
        inputs.push(prev);
        let out = match layer.kind {
            LayerKind::Input { h, w, c } => {
                if h == 0 || w == 0 || c == 0 {
                    return Err(err("zero input dimension".into()));
                }
                FeatureShape { h, w, c }
            }
            LayerKind::Conv { filters, k, stride, padding, .. } => {
                if stride == 0 || k == 0 {
                    return Err(err("zero kernel/stride".into()));
                }
                if padding == Padding::Valid && (prev.h < k || prev.w < k) {
                    return Err(err(format!(
                        "frame {}x{} smaller than kernel {k}", prev.h, prev.w
                    )));
                }
                FeatureShape {
                    h: conv_out(prev.h, k, stride, padding),
                    w: conv_out(prev.w, k, stride, padding),
                    c: filters,
                }
            }
            LayerKind::DwConv { k, stride, padding, .. } => {
                if padding == Padding::Valid && (prev.h < k || prev.w < k) {
                    return Err(err("frame smaller than kernel".into()));
                }
                FeatureShape {
                    h: conv_out(prev.h, k, stride, padding),
                    w: conv_out(prev.w, k, stride, padding),
                    c: prev.c,
                }
            }
            LayerKind::MaxPool { k, stride } | LayerKind::AvgPool { k, stride } => {
                if prev.h < k || prev.w < k {
                    return Err(err(format!(
                        "frame {}x{} smaller than pool window {k}", prev.h, prev.w
                    )));
                }
                FeatureShape {
                    h: (prev.h - k) / stride + 1,
                    w: (prev.w - k) / stride + 1,
                    c: prev.c,
                }
            }
            LayerKind::GlobalAvgPool => FeatureShape { h: 1, w: 1, c: prev.c },
            LayerKind::Fc { out, .. } => FeatureShape { h: 1, w: 1, c: out },
            LayerKind::ResidualAdd { from } => {
                let skip = outputs[from];
                if skip != prev {
                    return Err(err(format!(
                        "skip shape {skip:?} != main path shape {prev:?}"
                    )));
                }
                prev
            }
            LayerKind::Softmax => prev,
        };
        outputs.push(out);
    }
    Ok(Shapes { inputs, outputs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::NetworkBuilder;

    #[test]
    fn mnist_chain_shapes() {
        let net = NetworkBuilder::new("m", 28, 28, 1)
            .conv(8, 3, 1, Padding::Same, true)
            .maxpool(2, 2)
            .conv(16, 3, 1, Padding::Same, true)
            .maxpool(2, 2)
            .fc(10, false)
            .build();
        let s = infer(&net).unwrap();
        assert_eq!(s.output(1), FeatureShape { h: 28, w: 28, c: 8 });
        assert_eq!(s.output(2), FeatureShape { h: 14, w: 14, c: 8 });
        assert_eq!(s.output(4), FeatureShape { h: 7, w: 7, c: 16 });
        assert_eq!(s.final_output().c, 10);
        assert_eq!(s.input_features(5), 7 * 7 * 16);
    }

    #[test]
    fn valid_padding_and_stride() {
        let net = NetworkBuilder::new("v", 11, 11, 3)
            .conv(4, 3, 2, Padding::Valid, true)
            .build();
        let s = infer(&net).unwrap();
        assert_eq!(s.output(1), FeatureShape { h: 5, w: 5, c: 4 });
    }

    #[test]
    fn pool_too_large_rejected() {
        let net = NetworkBuilder::new("p", 3, 3, 1).maxpool(4, 4).build_unchecked();
        assert!(infer(&net).is_err());
    }

    #[test]
    fn residual_shape_mismatch_rejected() {
        // fork at 8ch, main path changes to 4ch -> merge must fail
        let mut b = NetworkBuilder::new("r", 8, 8, 8);
        let fork = b.fork();
        b = b.conv(4, 3, 1, Padding::Same, true).residual_add(fork);
        let net = b.build_unchecked();
        assert!(infer(&net).is_err());
    }

    #[test]
    fn dwconv_preserves_channels() {
        let net = NetworkBuilder::new("d", 16, 16, 24)
            .dwconv(3, 2, Padding::Same, true)
            .build();
        let s = infer(&net).unwrap();
        assert_eq!(s.output(1), FeatureShape { h: 8, w: 8, c: 24 });
    }
}
