//! JSON network-descriptor parser.
//!
//! NeuroForge "parses pre-trained network graphs from formats such as
//! MATLAB, TensorFlow, PyTorch, and ONNX" (Sec. III-A). Offline we accept
//! a neutral JSON descriptor — the common denominator those exporters
//! produce — with the same information content: layer list + parameters +
//! optional explicit connection table for residual topologies.
//!
//! ```json
//! {
//!   "name": "mnist-8-16-32",
//!   "input": [28, 28, 1],
//!   "layers": [
//!     {"type": "conv", "filters": 8, "k": 3, "stride": 1,
//!      "padding": "same", "relu": true},
//!     {"type": "maxpool", "k": 2, "stride": 2},
//!     {"type": "fc", "out": 10},
//!     {"type": "residual_add", "from": 1}
//!   ]
//! }
//! ```
//!
//! Branchy topologies use `concat` (multi-input, `"from": [ids...]`),
//! `upsample` (`"factor"`), `sppf` (`"k"`) and standalone `relu` nodes.
//! Errors are actionable: unknown ops suggest the closest known op, and
//! bad `from` references are reported with the layer names involved.

use super::{Layer, LayerKind, Network, Padding};
use crate::util::json::Json;

/// Every op the descriptor format accepts (suggestion source).
const KNOWN_OPS: &[&str] = &[
    "conv",
    "dwconv",
    "maxpool",
    "avgpool",
    "gap",
    "global_avg_pool",
    "fc",
    "residual_add",
    "concat",
    "upsample",
    "sppf",
    "spatial_pyramid_pool",
    "relu",
    "softmax",
];

#[derive(Debug)]
pub enum ParseError {
    Json(crate::util::json::JsonError),
    Schema(String),
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::Json(e) => write!(f, "descriptor json: {e}"),
            ParseError::Schema(msg) => write!(f, "descriptor: {msg}"),
        }
    }
}

impl std::error::Error for ParseError {}

impl From<crate::util::json::JsonError> for ParseError {
    fn from(e: crate::util::json::JsonError) -> Self {
        ParseError::Json(e)
    }
}

fn schema(msg: impl Into<String>) -> ParseError {
    ParseError::Schema(msg.into())
}

fn req_usize(obj: &Json, key: &str, ctx: &str) -> Result<usize, ParseError> {
    obj.get(key)
        .and_then(Json::as_u64)
        .map(|u| u as usize)
        .ok_or_else(|| schema(format!("{ctx}: missing/invalid '{key}'")))
}

fn opt_usize(obj: &Json, key: &str, default: usize) -> usize {
    obj.get(key).and_then(Json::as_u64).map(|u| u as usize).unwrap_or(default)
}

fn opt_bool(obj: &Json, key: &str, default: bool) -> bool {
    obj.get(key).and_then(Json::as_bool).unwrap_or(default)
}

fn padding_of(obj: &Json) -> Result<Padding, ParseError> {
    match obj.get("padding").and_then(Json::as_str).unwrap_or("same") {
        "same" | "SAME" => Ok(Padding::Same),
        "valid" | "VALID" => Ok(Padding::Valid),
        other => Err(schema(format!("unknown padding '{other}'"))),
    }
}

/// Parse a network descriptor from JSON text.
pub fn parse(text: &str) -> Result<Network, ParseError> {
    let root = Json::parse(text)?;
    let name = root
        .get("name")
        .and_then(Json::as_str)
        .unwrap_or("unnamed")
        .to_string();
    let input = root
        .get("input")
        .and_then(Json::as_usize_vec)
        .ok_or_else(|| schema("missing 'input' [h,w,c]"))?;
    if input.len() != 3 {
        return Err(schema("'input' must be [h, w, c]"));
    }

    let mut layers = vec![Layer {
        id: 0,
        name: "input".into(),
        kind: LayerKind::Input { h: input[0], w: input[1], c: input[2] },
    }];
    let mut connections = Vec::new();

    let layer_descs = root
        .get("layers")
        .and_then(Json::as_arr)
        .ok_or_else(|| schema("missing 'layers' array"))?;

    for (idx, desc) in layer_descs.iter().enumerate() {
        let id = layers.len();
        let ctx = format!("layers[{idx}]");
        let ty = desc
            .get("type")
            .and_then(Json::as_str)
            .ok_or_else(|| schema(format!("{ctx}: missing 'type'")))?;
        // a `from` reference must name an already-parsed layer; report the
        // offending reference with layer *names*, not bare indices
        let check_from = |from: usize, layers: &[Layer]| -> Result<(), ParseError> {
            if from < layers.len() {
                return Ok(());
            }
            let last = layers.last().map(|l| l.name.as_str()).unwrap_or("input");
            Err(schema(format!(
                "{ctx} ('{ty}'): 'from' references layer {from}, but only \
                 layers 0..={} exist here (latest is '{last}')",
                layers.len() - 1
            )))
        };
        let kind = match ty {
            "conv" => LayerKind::Conv {
                filters: req_usize(desc, "filters", &ctx)?,
                k: req_usize(desc, "k", &ctx)?,
                stride: opt_usize(desc, "stride", 1),
                padding: padding_of(desc)?,
                relu: opt_bool(desc, "relu", true),
            },
            "dwconv" => LayerKind::DwConv {
                k: req_usize(desc, "k", &ctx)?,
                stride: opt_usize(desc, "stride", 1),
                padding: padding_of(desc)?,
                relu: opt_bool(desc, "relu", true),
            },
            "maxpool" => LayerKind::MaxPool {
                k: req_usize(desc, "k", &ctx)?,
                stride: opt_usize(desc, "stride", req_usize(desc, "k", &ctx)?),
            },
            "avgpool" => LayerKind::AvgPool {
                k: req_usize(desc, "k", &ctx)?,
                stride: opt_usize(desc, "stride", req_usize(desc, "k", &ctx)?),
            },
            "gap" | "global_avg_pool" => LayerKind::GlobalAvgPool,
            "fc" => LayerKind::Fc {
                out: req_usize(desc, "out", &ctx)?,
                relu: opt_bool(desc, "relu", false),
            },
            "residual_add" => {
                let from = req_usize(desc, "from", &ctx)?;
                check_from(from, &layers)?;
                LayerKind::ResidualAdd { from }
            }
            "concat" => {
                let from = desc
                    .get("from")
                    .and_then(Json::as_usize_vec)
                    .ok_or_else(|| {
                        schema(format!("{ctx} ('concat'): missing 'from' id array"))
                    })?;
                if from.len() < 2 {
                    return Err(schema(format!(
                        "{ctx} ('concat'): needs at least 2 'from' inputs, has {}",
                        from.len()
                    )));
                }
                for &f in &from {
                    check_from(f, &layers)?;
                }
                LayerKind::Concat { from }
            }
            "upsample" => LayerKind::Upsample { factor: opt_usize(desc, "factor", 2) },
            "sppf" | "spatial_pyramid_pool" => {
                LayerKind::SpatialPyramidPool { k: opt_usize(desc, "k", 5) }
            }
            "relu" => LayerKind::Relu,
            "softmax" => LayerKind::Softmax,
            other => {
                let hint = crate::util::did_you_mean(other, KNOWN_OPS);
                return Err(schema(format!(
                    "{ctx}: unknown type '{other}'{hint} — known ops: {}",
                    KNOWN_OPS.join(", ")
                )));
            }
        };
        let lname = desc
            .get("name")
            .and_then(Json::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| format!("{ty}{id}"));
        match &kind {
            LayerKind::Concat { from } => {
                // explicit multi-input merge: connected to exactly `from`
                for &f in from {
                    connections.push((f, id));
                }
            }
            LayerKind::ResidualAdd { from } => {
                connections.push((id - 1, id));
                connections.push((*from, id));
            }
            _ => connections.push((id - 1, id)),
        }
        layers.push(Layer { id, name: lname, kind });
    }

    let net = Network { name, layers, connections };
    net.validate().map_err(ParseError::Schema)?;
    Ok(net)
}

#[cfg(test)]
mod tests {
    use super::*;

    const MNIST: &str = r#"{
      "name": "mnist-8-16-32",
      "input": [28, 28, 1],
      "layers": [
        {"type": "conv", "filters": 8, "k": 3},
        {"type": "maxpool", "k": 2},
        {"type": "conv", "filters": 16, "k": 3},
        {"type": "maxpool", "k": 2},
        {"type": "conv", "filters": 32, "k": 3},
        {"type": "maxpool", "k": 2},
        {"type": "fc", "out": 10}
      ]
    }"#;

    #[test]
    fn parses_mnist_descriptor() {
        let net = parse(MNIST).unwrap();
        assert_eq!(net.name, "mnist-8-16-32");
        assert_eq!(net.conv_filter_bounds(), vec![8, 16, 32]);
        assert_eq!(net.layers.len(), 8);
    }

    #[test]
    fn parses_residual() {
        let net = parse(
            r#"{"name":"r","input":[8,8,4],"layers":[
                {"type":"conv","filters":4,"k":3},
                {"type":"conv","filters":4,"k":3},
                {"type":"residual_add","from":1}
            ]}"#,
        )
        .unwrap();
        assert!(net.is_residual());
        assert!(net.connections.contains(&(1, 3)));
    }

    #[test]
    fn missing_field_is_schema_error() {
        let e = parse(r#"{"name":"x","input":[8,8,1],"layers":[{"type":"conv","k":3}]}"#);
        assert!(matches!(e, Err(ParseError::Schema(_))));
    }

    #[test]
    fn unknown_type_rejected() {
        let e = parse(r#"{"input":[8,8,1],"layers":[{"type":"lstm"}]}"#);
        assert!(e.is_err());
    }

    #[test]
    fn unknown_type_suggests_closest_op() {
        let e = parse(r#"{"input":[8,8,1],"layers":[{"type":"convv","filters":4,"k":3}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("did you mean 'conv'"), "{e}");
        let e2 = parse(r#"{"input":[8,8,1],"layers":[{"type":"upsamle"}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e2.contains("did you mean 'upsample'"), "{e2}");
        // hopeless typos still list the known ops
        let e3 = parse(r#"{"input":[8,8,1],"layers":[{"type":"transformer"}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e3.contains("known ops:") && e3.contains("concat"), "{e3}");
    }

    #[test]
    fn parses_branchy_ops() {
        let net = parse(
            r#"{"name":"b","input":[8,8,4],"layers":[
                {"type":"conv","filters":4,"k":3,"name":"stem"},
                {"type":"upsample","factor":2},
                {"type":"conv","filters":4,"k":3,"stride":2},
                {"type":"concat","from":[1,3]},
                {"type":"sppf","k":3},
                {"type":"relu"}
            ]}"#,
        )
        .unwrap();
        assert!(net.has_branches());
        // concat is connected to exactly its `from` list
        assert!(net.connections.contains(&(1, 4)) && net.connections.contains(&(3, 4)));
        assert_eq!(net.connections.iter().filter(|&&(_, d)| d == 4).count(), 2);
        let s = crate::graph::shapes::infer(&net).unwrap();
        assert_eq!(s.output(4).c, 8);
        assert_eq!(s.output(5).c, 32);
    }

    #[test]
    fn bad_from_reported_with_layer_names() {
        let e = parse(
            r#"{"input":[8,8,1],"layers":[
                {"type":"conv","filters":4,"k":3,"name":"stem"},
                {"type":"concat","from":[1,9]}
            ]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("references layer 9"), "{e}");
        assert!(e.contains("'stem'"), "{e}");
        let e2 = parse(
            r#"{"input":[4,4,2],"layers":[
                {"type":"residual_add","from":7}
            ]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e2.contains("references layer 7") && e2.contains("'input'"), "{e2}");
    }

    #[test]
    fn concat_arity_checked() {
        let e = parse(
            r#"{"input":[8,8,1],"layers":[
                {"type":"conv","filters":4,"k":3},
                {"type":"concat","from":[1]}
            ]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("at least 2"), "{e}");
    }

    #[test]
    fn invalid_shape_rejected_at_parse() {
        // 3x3 input cannot take a 4-wide pool
        let e = parse(r#"{"input":[3,3,1],"layers":[{"type":"maxpool","k":4}]}"#);
        assert!(e.is_err());
    }

    #[test]
    fn pool_stride_defaults_to_k() {
        let net = parse(
            r#"{"input":[8,8,1],"layers":[{"type":"maxpool","k":2}]}"#,
        )
        .unwrap();
        assert!(matches!(
            net.layers[1].kind,
            LayerKind::MaxPool { k: 2, stride: 2 }
        ));
    }
}
