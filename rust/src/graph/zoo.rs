//! Model zoo: every architecture the paper evaluates (Table II).
//!
//! The small nets (MNIST/SVHN/CIFAR a-2a-3a pipelines) are exact. The
//! large ImageNet/COCO models are *descriptors* — full layer tables built
//! programmatically, sized to match the paper's parameter/op counts
//! within a few percent. They feed the analytical mapping models for
//! Tables IV/V; no ImageNet training happens here (DESIGN.md §2).

use super::{Network, NetworkBuilder, Padding};

/// MNIST 8-16-32 (Table II row 1): 333.72K params, 6.79M ops.
pub fn mnist() -> Network {
    let mut b = NetworkBuilder::new("mnist-8-16-32", 28, 28, 1);
    for f in [8, 16, 32] {
        b = b.conv(f, 3, 1, Padding::Same, true).maxpool(2, 2);
    }
    b.fc(10, false).softmax().build()
}

/// SVHN 8-16-32-64 (Table II row 2): 639.58K params, 32.2M ops.
pub fn svhn() -> Network {
    let mut b = NetworkBuilder::new("svhn-8-16-32-64", 32, 32, 3);
    for f in [8, 16, 32, 64] {
        b = b.conv(f, 3, 1, Padding::Same, true).maxpool(2, 2);
    }
    b.fc(10, false).softmax().build()
}

/// CIFAR-10 8-16-32-64-64 (Table II row 3): 676K params, 83M ops.
pub fn cifar10() -> Network {
    let mut b = NetworkBuilder::new("cifar10-8-16-32-64-64", 32, 32, 3);
    for (i, f) in [8, 16, 32, 64, 64].into_iter().enumerate() {
        b = b.conv(f, 3, 1, Padding::Same, true);
        if i < 4 {
            b = b.maxpool(2, 2);
        }
    }
    b.fc(10, false).softmax().build()
}

/// ResNet-50 descriptor (ImageNet 224x224): ~25.6M params, ~4.1 GMACs.
pub fn resnet50() -> Network {
    let mut b = NetworkBuilder::new("resnet50", 224, 224, 3)
        .conv(64, 7, 2, Padding::Same, true)
        .maxpool(2, 2); // paper-style 3x3/2 approximated by 2x2/2
    // bottleneck stages: (planes, blocks, first-stride)
    for (planes, blocks, stride) in [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)] {
        for blk in 0..blocks {
            let s = if blk == 0 { stride } else { 1 };
            let fork = b.fork();
            b = b
                .conv(planes, 1, s, Padding::Same, true)
                .conv(planes, 3, 1, Padding::Same, true)
                .conv(planes * 4, 1, 1, Padding::Same, false);
            // projection shortcut on the first block changes shape; we fold
            // it into the descriptor as a plain merge after the 1x1 expand
            if blk == 0 {
                // shape changed vs fork -> model the projection conv on the
                // skip path by simply not merging (descriptor-level fusion)
                let _ = fork;
            } else {
                b = b.residual_add(fork);
            }
        }
    }
    b.global_avg_pool().fc(1000, false).softmax().build()
}

/// MobileNetV2 descriptor (ImageNet 224x224): ~2.3-3.5M params, ~300 MMACs.
pub fn mobilenet_v2() -> Network {
    let mut b = NetworkBuilder::new("mobilenetv2", 224, 224, 3)
        .conv(32, 3, 2, Padding::Same, true);
    // inverted residual settings (t, c, n, s) from the MobileNetV2 paper
    let settings = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut cin = 32;
    for (t, c, n, s) in settings {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            let expanded = cin * t;
            if t != 1 {
                b = b.conv(expanded, 1, 1, Padding::Same, true); // expand
            }
            b = b.dwconv(3, stride, Padding::Same, true); // depthwise
            b = b.conv(c, 1, 1, Padding::Same, false); // project (linear)
            cin = c;
        }
    }
    b = b.conv(1280, 1, 1, Padding::Same, true).global_avg_pool();
    b.fc(1000, false).softmax().build()
}

/// SqueezeNet 1.1 descriptor (ImageNet 224x224): ~1.24M params.
pub fn squeezenet() -> Network {
    let mut b = NetworkBuilder::new("squeezenet", 224, 224, 3)
        .conv(64, 3, 2, Padding::Same, true)
        .maxpool(2, 2);
    // fire modules: (squeeze, expand). The real expand splits 1x1/3x3 in
    // parallel from the squeeze output (params = s*e/2 + 9*s*e/2 = 5se);
    // our sequential chain models it as one 2x2 expand (4se) — within 20%
    // of the split's parameter/MAC cost while staying a pure stream.
    let fires = [
        (16, 128),
        (16, 128),
        (32, 256),
        (32, 256),
        (48, 384),
        (48, 384),
        (64, 512),
        (64, 512),
    ];
    for (i, (s, e)) in fires.into_iter().enumerate() {
        b = b
            .conv(s, 1, 1, Padding::Same, true)
            .conv(e, 2, 1, Padding::Same, true);
        if i == 2 || i == 4 {
            b = b.maxpool(2, 2);
        }
    }
    b = b.conv(1000, 1, 1, Padding::Same, true).global_avg_pool();
    b.softmax().build()
}

/// A YOLOv5 C3 module: CSP bottleneck stack with a parallel 1x1 side
/// branch, merged channel-wise and mixed by a 1x1 conv. `shortcut`
/// selects residual bottlenecks (backbone) vs plain ones (neck).
fn c3(mut b: NetworkBuilder, c2: usize, n: usize, shortcut: bool) -> NetworkBuilder {
    let c_ = c2 / 2;
    let input = b.mark();
    b = b.conv(c_, 1, 1, Padding::Same, true); // cv1
    for _ in 0..n {
        let f = b.mark();
        b = b
            .conv(c_, 1, 1, Padding::Same, true)
            .conv(c_, 3, 1, Padding::Same, true);
        if shortcut {
            b = b.residual_add(f);
        }
    }
    let main = b.mark();
    b = b.branch_from(input).conv(c_, 1, 1, Padding::Same, true); // cv2
    let side = b.mark();
    b.concat(&[main, side]).conv(c2, 1, 1, Padding::Same, true) // cv3
}

/// YOLOv5-Large, faithful (COCO 640x640): CSP backbone with real C3
/// fork/concat blocks, SPPF, FPN+PAN neck with upsample/concat merges,
/// and three 1x1 detect heads at P3/P4/P5. 46,533,693 params (0.1% off
/// the published 46.5M) and 54.5 GMACs (== the published 109 GFLOPs at
/// 2 FLOPs/MAC); the golden test below pins both counts exactly.
pub fn yolov5l() -> Network {
    let mut b = NetworkBuilder::new("yolov5l", 640, 640, 3)
        .conv(64, 6, 2, Padding::Same, true) // P1/2 stem
        .conv(128, 3, 2, Padding::Same, true); // P2/4
    b = c3(b, 128, 3, true);
    b = b.conv(256, 3, 2, Padding::Same, true); // P3/8
    b = c3(b, 256, 6, true);
    let p3 = b.mark();
    b = b.conv(512, 3, 2, Padding::Same, true); // P4/16
    b = c3(b, 512, 9, true);
    let p4 = b.mark();
    b = b.conv(1024, 3, 2, Padding::Same, true); // P5/32
    b = c3(b, 1024, 3, true);
    // SPPF: 1x1 squeeze, 4-tap pyramid (k=5), 1x1 expand
    b = b
        .conv(512, 1, 1, Padding::Same, true)
        .sppf(5)
        .conv(1024, 1, 1, Padding::Same, true);
    // FPN top-down
    b = b.conv(512, 1, 1, Padding::Same, true);
    let n10 = b.mark();
    b = b.upsample(2);
    let up = b.mark();
    b = c3(b.concat(&[up, p4]), 512, 3, false);
    b = b.conv(256, 1, 1, Padding::Same, true);
    let n14 = b.mark();
    b = b.upsample(2);
    let up2 = b.mark();
    b = c3(b.concat(&[up2, p3]), 256, 3, false);
    let d_p3 = b.mark();
    // PAN bottom-up
    b = b.conv(256, 3, 2, Padding::Same, true);
    let dn = b.mark();
    b = c3(b.concat(&[dn, n14]), 512, 3, false);
    let d_p4 = b.mark();
    b = b.conv(512, 3, 2, Padding::Same, true);
    let dn2 = b.mark();
    b = c3(b.concat(&[dn2, n10]), 1024, 3, false);
    let d_p5 = b.mark();
    // detect heads: 3 anchors x (80 classes + 5) = 255 channels per scale
    b = b.branch_from(d_p3).conv(255, 1, 1, Padding::Same, false);
    b = b.branch_from(d_p4).conv(255, 1, 1, Padding::Same, false);
    b = b.branch_from(d_p5).conv(255, 1, 1, Padding::Same, false);
    b.build()
}

/// U-Net-tiny (96x96x3 segmentation): two-level encoder/decoder with
/// skip concats across the bottleneck — the second branchy zoo workload
/// exercising Upsample + Concat on a non-detector topology.
pub fn unet_tiny() -> Network {
    let mut b = NetworkBuilder::new("unet-tiny", 96, 96, 3)
        .conv(16, 3, 1, Padding::Same, true)
        .conv(16, 3, 1, Padding::Same, true);
    let e1 = b.mark();
    b = b
        .maxpool(2, 2)
        .conv(32, 3, 1, Padding::Same, true)
        .conv(32, 3, 1, Padding::Same, true);
    let e2 = b.mark();
    b = b
        .maxpool(2, 2)
        .conv(64, 3, 1, Padding::Same, true)
        .conv(64, 3, 1, Padding::Same, true)
        .upsample(2);
    let up2 = b.mark();
    b = b
        .concat(&[up2, e2])
        .conv(32, 3, 1, Padding::Same, true)
        .conv(32, 3, 1, Padding::Same, true)
        .upsample(2);
    let up1 = b.mark();
    b = b
        .concat(&[up1, e1])
        .conv(16, 3, 1, Padding::Same, true)
        .conv(16, 3, 1, Padding::Same, true)
        .conv(4, 1, 1, Padding::Same, false); // per-pixel class head
    b.build()
}

/// Every zoo model name, in report order.
pub const NAMES: &[&str] = &[
    "mnist",
    "svhn",
    "cifar10",
    "resnet50",
    "mobilenetv2",
    "squeezenet",
    "yolov5l",
    "unet_tiny",
];

/// Unknown-model error carrying the valid name list (so call sites print
/// an actionable message instead of a bare lookup failure).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnknownModel {
    pub name: String,
}

impl std::fmt::Display for UnknownModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let hint = crate::util::did_you_mean(&self.name, NAMES);
        write!(
            f,
            "unknown model '{}'{hint} — valid models: {}",
            self.name,
            NAMES.join(", ")
        )
    }
}

impl std::error::Error for UnknownModel {}

/// Look up any zoo model by the names used in reports/benches.
pub fn by_name(name: &str) -> Result<Network, UnknownModel> {
    Ok(match name {
        "mnist" => mnist(),
        "svhn" => svhn(),
        "cifar10" => cifar10(),
        "resnet50" => resnet50(),
        "mobilenetv2" => mobilenet_v2(),
        "squeezenet" => squeezenet(),
        "yolov5l" => yolov5l(),
        "unet_tiny" => unet_tiny(),
        _ => return Err(UnknownModel { name: name.to_string() }),
    })
}

/// All (name, paper params, paper MACs) rows of Table II for reporting.
pub const TABLE2_ROWS: &[(&str, &str, f64, f64)] = &[
    ("MNIST", "8-16-32", 333.72e3, 6.79e6),
    ("SVHN", "8-16-32-64", 639.58e3, 32.2e6),
    ("CIFAR-10", "8-16-32-64-64", 676e3, 83e6),
    ("ImageNet", "ResNet-50", 25.56e6, 4.1e9),
    ("ImageNet", "MobileNetV2", 2.26e6, 300e6),
    ("ImageNet", "SqueezeNet", 1.24e6, 833e6),
    ("COCO 2017", "YOLOv5-Large", 46.5e6, 154.0e9),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_nets_validate() {
        for net in [mnist(), svhn(), cifar10()] {
            assert!(net.validate().is_ok(), "{}", net.name);
        }
    }

    #[test]
    fn big_nets_validate() {
        for net in [resnet50(), mobilenet_v2(), squeezenet(), yolov5l(), unet_tiny()] {
            assert!(net.validate().is_ok(), "{}", net.name);
        }
    }

    #[test]
    fn mnist_macs_exact_for_our_head() {
        // Table II counts 6.79M ops with an (unspecified) wide FC stack;
        // our descriptor uses the single flatten->10 head of the deployed
        // morphable model. The conv MACs are exact:
        // 28^2*9*8 + 14^2*9*8*16 + 7^2*9*16*32 + fc 3*3*32*10
        let macs = mnist().count_macs().unwrap();
        assert_eq!(macs, 56_448 + 225_792 + 225_792 + 2_880);
    }

    #[test]
    fn cifar_macs_order() {
        let m = mnist().count_macs().unwrap();
        let s = svhn().count_macs().unwrap();
        let c = cifar10().count_macs().unwrap();
        assert!(m < s && s < c);
    }

    #[test]
    fn resnet50_scale_faithful() {
        let net = resnet50();
        let params = net.count_params().unwrap() as f64;
        let macs = net.count_macs().unwrap() as f64;
        // paper: 25.56M params, 4.1B ops — descriptor within 35%
        assert!((params - 25.56e6).abs() / 25.56e6 < 0.35, "params {params}");
        assert!((macs - 4.1e9).abs() / 4.1e9 < 0.35, "macs {macs}");
    }

    #[test]
    fn mobilenetv2_scale_faithful() {
        let net = mobilenet_v2();
        let macs = net.count_macs().unwrap() as f64;
        assert!((macs - 300e6).abs() / 300e6 < 0.35, "macs {macs}");
    }

    #[test]
    fn squeezenet_params_faithful() {
        let params = squeezenet().count_params().unwrap() as f64;
        assert!((params - 1.24e6).abs() / 1.24e6 < 0.3, "params {params}");
    }

    #[test]
    fn yolov5l_params_faithful() {
        let params = yolov5l().count_params().unwrap() as f64;
        assert!((params - 46.5e6).abs() / 46.5e6 < 0.01, "params {params}");
    }

    #[test]
    fn yolov5l_golden_counts_pinned() {
        // faithful CSP/SPPF/FPN+PAN descriptor: exact parameter and MAC
        // counts, hand-verified against the published 46.5M params /
        // 109 GFLOPs (= 54.5 GMACs)
        let net = yolov5l();
        assert_eq!(net.count_params().unwrap(), 46_533_693);
        assert_eq!(net.count_macs().unwrap(), 54_496_870_400);
    }

    #[test]
    fn yolov5l_is_truly_branchy() {
        use crate::graph::LayerKind;
        let net = yolov5l();
        assert!(net.has_branches() && net.is_residual());
        let count = |pred: fn(&LayerKind) -> bool| {
            net.layers.iter().filter(|l| pred(&l.kind)).count()
        };
        // 8 C3 blocks + 4 FPN/PAN merges, 2 FPN upsamples, 1 SPPF,
        // 3 detect heads at 255 channels
        assert_eq!(count(|k| matches!(k, LayerKind::Concat { .. })), 12);
        assert_eq!(count(|k| matches!(k, LayerKind::Upsample { .. })), 2);
        assert_eq!(
            count(|k| matches!(k, LayerKind::SpatialPyramidPool { .. })),
            1
        );
        assert_eq!(
            count(|k| matches!(k, LayerKind::Conv { filters: 255, .. })),
            3
        );
    }

    #[test]
    fn unet_tiny_branchy_and_sane() {
        let net = unet_tiny();
        assert!(net.has_branches());
        let s = crate::graph::shapes::infer(&net).unwrap();
        // decoder restores full resolution; head emits 4 class planes
        let out = s.final_output();
        assert_eq!((out.h, out.w, out.c), (96, 96, 4));
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("mnist").is_ok());
        let err = by_name("nope").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("'nope'"), "{msg}");
        // the error lists every valid model
        for n in NAMES {
            assert!(msg.contains(n), "error must list {n}: {msg}");
        }
    }

    #[test]
    fn names_cover_by_name() {
        for n in NAMES {
            assert!(by_name(n).is_ok(), "{n}");
        }
    }

    #[test]
    fn unknown_model_suggests_closest() {
        // same "did you mean" phrasing as the parser/fault/onnx paths
        let msg = by_name("resnet5").unwrap_err().to_string();
        assert!(msg.contains("(did you mean 'resnet50'?)"), "{msg}");
        // far-off names get the plain listing, no suggestion clause
        let far = by_name("transformer").unwrap_err().to_string();
        assert!(!far.contains("did you mean"), "{far}");
        assert!(far.contains("valid models"), "{far}");
    }
}
