//! Roofline-model baseline (the "conventional approach" of Sec. III-C).
//!
//! The paper contrasts NeuroForge's MOGA with Roofline Models (RLM,
//! [Siracusa et al.]): RLM gives a high-level bound on achievable
//! throughput from compute vs bandwidth ceilings but "does not generate
//! concrete configurations". We implement it as the comparison baseline:
//!
//! * [`roofline_bound`] — the device's performance ceiling for a network
//!   (MACs/s limited by DSP compute or line-buffer bandwidth);
//! * [`roofline_allocate`] — the standard RLM-guided heuristic: assign
//!   parallelism proportional to each layer's MAC share (compute-balance
//!   heuristic), then clip to the budget.
//!
//! The ablation bench shows the MOGA dominates this allocation on the
//! latency/DSP plane — the paper's motivation for searching.

use crate::design::{self, DesignConfig};
use crate::graph::{shapes, LayerKind, Network};
use crate::pe::{Device, FpRep};

/// Performance ceilings for one network on one device.
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    /// peak MAC/s from the DSP array (compute roof)
    pub compute_macs_per_s: f64,
    /// peak element/s the streaming interface sustains (bandwidth roof)
    pub stream_elems_per_s: f64,
    /// frame MACs of the workload
    pub frame_macs: f64,
    /// frame elements streamed in
    pub frame_elems: f64,
}

impl Roofline {
    /// Upper bound on achievable FPS: min of compute- and stream-bound.
    pub fn fps_bound(&self) -> f64 {
        let compute = self.compute_macs_per_s / self.frame_macs;
        let stream = self.stream_elems_per_s / self.frame_elems;
        compute.min(stream)
    }

    /// Arithmetic intensity (MACs per streamed element).
    pub fn intensity(&self) -> f64 {
        self.frame_macs / self.frame_elems
    }

    /// True if the workload is compute-bound on this device.
    pub fn compute_bound(&self) -> bool {
        self.compute_macs_per_s / self.frame_macs
            <= self.stream_elems_per_s / self.frame_elems
    }
}

/// Compute the roofline for a network/device/precision.
pub fn roofline_bound(net: &Network, device: &Device, rep: FpRep) -> Roofline {
    let macs = net.count_macs().expect("valid net") as f64;
    let (h, w, c) = net.input_dims();
    // each DSP does one MAC/cycle (two when int8-packed)
    let simd = if rep == FpRep::Int8 { 2.0 } else { 1.0 };
    Roofline {
        compute_macs_per_s: device.budget.dsp as f64 * simd * device.clock_mhz * 1e6,
        stream_elems_per_s: device.clock_mhz * 1e6, // one pixel/clock interface
        frame_macs: macs,
        frame_elems: (h * w * c) as f64,
    }
}

/// RLM-guided allocation: parallelism proportional to per-layer MAC share
/// under the DSP budget. This is the deterministic heuristic NeuroForge's
/// MOGA is benchmarked against.
pub fn roofline_allocate(net: &Network, device: &Device, rep: FpRep) -> DesignConfig {
    let shp = shapes::infer(net).expect("valid net");
    let bounds = net.conv_filter_bounds();
    // per-conv-layer MAC counts
    let mut layer_macs: Vec<f64> = Vec::with_capacity(bounds.len());
    for layer in &net.layers {
        match layer.kind {
            LayerKind::Conv { k, .. } => {
                let out = shp.output(layer.id);
                let cin = shp.input_channels(layer.id);
                layer_macs.push((out.h * out.w * out.c * k * k * cin) as f64);
            }
            LayerKind::DwConv { k, .. } => {
                let out = shp.output(layer.id);
                layer_macs.push((out.h * out.w * out.c * k * k) as f64);
            }
            _ => {}
        }
    }
    let total: f64 = layer_macs.iter().sum();

    // start from the proportional share, then shrink uniformly until the
    // full design fits the device
    let mut scale = 1.0f64;
    loop {
        let parallelism: Vec<usize> = layer_macs
            .iter()
            .zip(&bounds)
            .map(|(&m, &ub)| {
                let share = m / total;
                let p = (share * device.budget.dsp as f64 * scale / 9.0).round() as usize;
                p.clamp(1, ub)
            })
            .collect();
        let cfg = DesignConfig { parallelism, rep };
        if let Ok(eval) = design::evaluate(net, &cfg, device) {
            if eval.fits(device) {
                return cfg;
            }
        }
        scale *= 0.8;
        if scale < 1e-3 {
            return DesignConfig::uniform(net, 1, rep);
        }
    }
}

/// Gene-dependent roofline lower bounds on a chromosome's objectives —
/// the MOGA's dominated-region pre-filter (`--prune`).
///
/// For each conv gene slot the bound keeps the facts that survive
/// dropping every boundary-coupled term ([`design::SlotFact`]):
///
/// * **latency**: a regular conv's serial factor is
///   `ceil(filters/(p*simd)) * ceil(cin/lanes_in)`; discarding the
///   (unknown, >= 1) boundary factor leaves the sound per-slot bound
///   `s_lb = ceil(filters/(p*simd))`, contributing `pass * s_lb` cycles
///   when `s_lb > 1` (when `s_lb == 1` the true serial factor may still
///   exceed 1, so the slot soundly contributes 0). A depthwise conv's
///   serial factor depends only on its own gene, so its term is exact.
///   Adding the gene-independent floor (source scan + fills + SPP
///   passes, [`design::Evaluator::latency_floor_cycles`]) gives
///   `latency_cycles_lb <= latency_cycles` for every chromosome.
/// * **DSP**: a conv's PE count is `p * lanes_in` with `lanes_in >= 1`,
///   so `dsp_per_pe * p` is a sound per-slot bound (exact for
///   depthwise). Non-conv stages contribute no DSPs.
///
/// Both bounds are monotone through the f64 conversions downstream
/// (cycles -> ms divides by a positive constant; the accuracy-ladder
/// ratio multiplies by a positive constant), so comparing the bound
/// against [`super::Constraints`] or a front point never misclassifies.
#[derive(Debug, Clone)]
pub struct GeneBounds {
    facts: Vec<design::SlotFact>,
    floor_cycles: usize,
    clock_mhz: f64,
    simd: usize,
    int8: bool,
}

impl GeneBounds {
    pub fn new(ev: &design::Evaluator, rep: FpRep) -> Self {
        GeneBounds {
            facts: ev.slot_facts(),
            floor_cycles: ev.latency_floor_cycles(),
            clock_mhz: ev.clock_mhz(),
            simd: if rep == FpRep::Int8 { 2 } else { 1 },
            int8: rep == FpRep::Int8,
        }
    }

    /// Lower bound on first-frame latency cycles for `conv_genes` (the
    /// chromosome without its path gene).
    pub fn latency_cycles_lb(&self, conv_genes: &[usize]) -> usize {
        let mut serialized = 0usize;
        for (f, &p) in self.facts.iter().zip(conv_genes) {
            if f.dw {
                let lanes = p.min(f.cin).max(1);
                let serial = f.cin.div_ceil(lanes * self.simd);
                if serial > 1 {
                    serialized += f.pass * serial;
                }
            } else {
                let s_lb = f.filters.div_ceil(p * self.simd);
                if s_lb > 1 {
                    serialized += f.pass * s_lb;
                }
            }
        }
        self.floor_cycles + serialized
    }

    /// Lower bound on first-frame latency in milliseconds.
    pub fn latency_ms_lb(&self, conv_genes: &[usize]) -> f64 {
        self.latency_cycles_lb(conv_genes) as f64 / (self.clock_mhz * 1e3)
    }

    /// Lower bound on the DSP count.
    pub fn dsp_lb(&self, conv_genes: &[usize]) -> usize {
        let mut dsp = 0usize;
        for (f, &p) in self.facts.iter().zip(conv_genes) {
            let per_pe = if self.int8 { f.dsp_per_pe8 } else { f.dsp_per_pe16 };
            let pes = if f.dw { p.min(f.cin).max(1) } else { p };
            dsp += per_pe * pes;
        }
        dsp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::pe::ZYNQ_7100;

    #[test]
    fn fps_bound_is_min_of_roofs() {
        let net = zoo::mnist();
        let r = roofline_bound(&net, &ZYNQ_7100, FpRep::Int16);
        assert!(r.fps_bound() > 0.0);
        let by_compute = r.compute_macs_per_s / r.frame_macs;
        let by_stream = r.stream_elems_per_s / r.frame_elems;
        assert!((r.fps_bound() - by_compute.min(by_stream)).abs() < 1e-9);
    }

    #[test]
    fn int8_doubles_compute_roof() {
        let net = zoo::mnist();
        let r16 = roofline_bound(&net, &ZYNQ_7100, FpRep::Int16);
        let r8 = roofline_bound(&net, &ZYNQ_7100, FpRep::Int8);
        assert!((r8.compute_macs_per_s / r16.compute_macs_per_s - 2.0).abs() < 1e-9);
    }

    #[test]
    fn mnist_is_stream_bound() {
        // tiny model, huge DSP array: the pixel interface is the roof
        let net = zoo::mnist();
        let r = roofline_bound(&net, &ZYNQ_7100, FpRep::Int16);
        assert!(!r.compute_bound());
    }

    #[test]
    fn resnet_is_compute_bound() {
        let net = zoo::resnet50();
        let r = roofline_bound(&net, &ZYNQ_7100, FpRep::Int16);
        assert!(r.compute_bound());
        assert!(r.intensity() > 20.0);
    }

    #[test]
    fn allocation_fits_device() {
        for name in ["mnist", "cifar10", "mobilenetv2"] {
            let net = zoo::by_name(name).unwrap();
            let cfg = roofline_allocate(&net, &ZYNQ_7100, FpRep::Int8);
            let eval = design::evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
            assert!(eval.fits(&ZYNQ_7100), "{name}");
        }
    }

    #[test]
    fn simulated_fps_below_roofline() {
        // no mapping may beat the roofline bound — a model-consistency check
        let net = zoo::mnist();
        let r = roofline_bound(&net, &ZYNQ_7100, FpRep::Int16);
        let cfg = DesignConfig::full(&net, FpRep::Int16);
        let eval = design::evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
        assert!(eval.fps() <= r.fps_bound() * 1.05, "{} > {}", eval.fps(), r.fps_bound());
    }

    #[test]
    fn gene_bounds_never_exceed_true_objectives() {
        // soundness of the pre-filter: the lower bound must never sit
        // above the value the full evaluator computes, else pruning
        // could discard an acceptable candidate
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        for net in
            [zoo::mnist(), zoo::mobilenet_v2(), zoo::unet_tiny(), zoo::yolov5l()]
        {
            let ev = design::Evaluator::new(&net, &ZYNQ_7100).unwrap();
            let bounds = net.conv_filter_bounds();
            let iters = if bounds.len() > 60 { 4 } else { 20 };
            for rep in [FpRep::Int16, FpRep::Int8] {
                let gb = GeneBounds::new(&ev, rep);
                for _ in 0..iters {
                    let genes: Vec<usize> = bounds
                        .iter()
                        .map(|&ub| rng.range(1, ub as i64) as usize)
                        .collect();
                    let fast = ev.objectives(&genes, rep).unwrap();
                    assert!(
                        gb.latency_cycles_lb(&genes) <= fast.latency_cycles,
                        "{} {:?}: latency lb above truth",
                        net.name,
                        rep
                    );
                    assert!(
                        gb.dsp_lb(&genes) <= fast.resources.dsp,
                        "{} {:?}: dsp lb above truth",
                        net.name,
                        rep
                    );
                }
            }
        }
    }

    #[test]
    fn moga_dominates_roofline_heuristic() {
        // the paper's argument for searching: the RLM heuristic is a
        // single point; the MOGA front contains a point at least as good
        let net = zoo::mnist();
        let rl_cfg = roofline_allocate(&net, &ZYNQ_7100, FpRep::Int16);
        let rl = design::evaluate(&net, &rl_cfg, &ZYNQ_7100).unwrap();
        let res = crate::dse::run(
            &net,
            &ZYNQ_7100,
            &crate::dse::DseConfig {
                population: 48,
                generations: 20,
                seed: 2,
                constraints: crate::dse::Constraints::device(&ZYNQ_7100),
                ..crate::dse::DseConfig::default()
            },
        );
        let dominated = res.pareto.iter().any(|c| {
            c.objectives.latency_ms <= rl.latency_ms() * 1.0001
                && c.objectives.dsp <= rl.resources.dsp
        });
        assert!(dominated, "no front point matches the roofline allocation");
    }
}
