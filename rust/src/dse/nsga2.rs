//! NSGA-II machinery: fast non-dominated sort, crowding distance,
//! rank+crowding tournament, and elitist environmental selection.
//!
//! Reference: Deb et al., "A Fast and Elitist Multiobjective Genetic
//! Algorithm: NSGA-II" — the standard realization of the multi-objective
//! GA Algorithm 1 sketches.
//!
//! §Perf: every O(n^2) comparison loop runs on [`ObjSoa`], a flat
//! structure-of-arrays view of `(violation, latency, dsp)` built once per
//! generation, instead of chasing `Candidate` structs — these comparisons
//! are the DSE generation step's hottest code. Selection is index-based
//! ([`select_ranked`]) so the engine never clones a `Candidate`.

use super::Candidate;
use crate::util::rng::Rng;

/// Flat structure-of-arrays objective view of a population: the single
/// dominance key `(violation, latency_ms, dsp, -accuracy)` per member,
/// kept in cache-friendly parallel arrays. Rebuilt (allocation-free at
/// steady state) once per generation and threaded through the sort,
/// crowding and selection kernels.
///
/// `accuracy_axis` gates the third *crowding* axis: it is `true` only
/// for accuracy-aware searches (a DistillCycle ladder was supplied), so
/// plain 2-objective runs keep their exact pre-accuracy selection — the
/// dominance key itself is harmless when disabled because every
/// candidate then carries the same constant accuracy.
#[derive(Debug, Default, Clone)]
pub struct ObjSoa {
    pub violation: Vec<f64>,
    pub latency: Vec<f64>,
    pub dsp: Vec<f64>,
    /// negated accuracy (all objectives minimize)
    pub neg_acc: Vec<f64>,
    /// modeled energy per frame, mJ (minimized when `energy_axis`)
    pub energy: Vec<f64>,
    /// include accuracy in crowding-distance spread (3-objective mode)
    pub accuracy_axis: bool,
    /// include energy in dominance + crowding (`--energy-front`). Off,
    /// the key's energy component is pinned to a constant, so existing
    /// searches keep their exact pre-energy selection.
    pub energy_axis: bool,
}

impl ObjSoa {
    pub fn from_candidates(pop: &[Candidate]) -> ObjSoa {
        let mut soa = ObjSoa::default();
        soa.rebuild(pop);
        soa
    }

    /// Refill from a population, reusing the existing buffers (the
    /// `accuracy_axis`/`energy_axis` flags are sticky across rebuilds).
    pub fn rebuild(&mut self, pop: &[Candidate]) {
        self.violation.clear();
        self.latency.clear();
        self.dsp.clear();
        self.neg_acc.clear();
        self.energy.clear();
        for c in pop {
            self.violation.push(c.violation);
            self.latency.push(c.objectives.latency_ms);
            self.dsp.push(c.objectives.dsp as f64);
            self.neg_acc.push(-c.objectives.accuracy);
            self.energy.push(c.objectives.energy_mj);
        }
    }

    pub fn len(&self) -> usize {
        self.violation.len()
    }

    pub fn is_empty(&self) -> bool {
        self.violation.is_empty()
    }

    #[inline(always)]
    fn key(&self, i: usize) -> (f64, f64, f64, f64, f64) {
        (
            self.violation[i],
            self.latency[i],
            self.dsp[i],
            self.neg_acc[i],
            if self.energy_axis { self.energy[i] } else { 0.0 },
        )
    }
}

/// Feasibility-first dominance kernel on a flat `(violation, latency,
/// dsp, -accuracy, energy)` key — the ONE implementation every
/// comparison site shares (struct-level [`beats`], the SoA sort, and the
/// engine's final-front extraction): a feasible candidate beats an
/// infeasible one; two infeasible compare by violation; two feasible by
/// Pareto dominance on (latency, DSP, -accuracy, energy). In 2-objective
/// searches every candidate carries the same accuracy and the SoA pins
/// the energy component to a constant, so the kernel degenerates to the
/// (latency, DSP) test.
#[inline(always)]
pub fn beats_key(a: (f64, f64, f64, f64, f64), b: (f64, f64, f64, f64, f64)) -> bool {
    if a.0 == 0.0 && b.0 > 0.0 {
        return true;
    }
    if a.0 > 0.0 {
        return b.0 > 0.0 && a.0 < b.0;
    }
    a.1 <= b.1
        && a.2 <= b.2
        && a.3 <= b.3
        && a.4 <= b.4
        && (a.1 < b.1 || a.2 < b.2 || a.3 < b.3 || a.4 < b.4)
}

/// [`beats_key`] on `Candidate` structs (convenience / test surface).
/// The energy component is pinned to the off-axis constant here — the
/// energy objective participates only through an [`ObjSoa`] whose
/// `energy_axis` is enabled.
#[inline]
pub fn beats(a: &Candidate, b: &Candidate) -> bool {
    beats_key(
        (
            a.violation,
            a.objectives.latency_ms,
            a.objectives.dsp as f64,
            -a.objectives.accuracy,
            0.0,
        ),
        (
            b.violation,
            b.objectives.latency_ms,
            b.objectives.dsp as f64,
            -b.objectives.accuracy,
            0.0,
        ),
    )
}

/// Fast non-dominated sort over a flat objective view: returns fronts as
/// index vectors, best first (members of each front in ascending index
/// order).
///
/// §Perf: instead of the textbook adjacency-list peel (two dominance
/// tests per pair plus O(n) `Vec` allocations per call), this pre-sorts
/// indices lexicographically by `(violation, latency, dsp)` — dominance
/// can then only flow forward — and runs a longest-dominating-chain DP
/// with ONE `beats_key` per surviving pair and three flat scratch
/// vectors. Dominance is transitive, so the chain length equals the
/// peeled front index.
pub fn sort_fronts_soa(soa: &ObjSoa) -> Vec<Vec<usize>> {
    let n = soa.len();
    if n == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| soa.key(a).partial_cmp(&soa.key(b)).unwrap());
    // contiguous sorted keys: the n^2 sweep reads them in order
    let keys: Vec<(f64, f64, f64, f64, f64)> = idx.iter().map(|&i| soa.key(i)).collect();
    let mut rank = vec![0usize; n]; // rank[sorted position]
    let mut max_rank = 0usize;
    for j in 1..n {
        let kj = keys[j];
        let mut f = 0usize;
        for i in 0..j {
            // `rank[i] >= f` first: skips the dominance test for every
            // predecessor that cannot raise the chain any further
            if rank[i] >= f && beats_key(keys[i], kj) {
                f = rank[i] + 1;
            }
        }
        rank[j] = f;
        max_rank = max_rank.max(f);
    }
    let mut fronts: Vec<Vec<usize>> = vec![Vec::new(); max_rank + 1];
    for (pos, &i) in idx.iter().enumerate() {
        fronts[rank[pos]].push(i);
    }
    for front in &mut fronts {
        front.sort_unstable();
    }
    fronts
}

/// Fast non-dominated sort on a candidate slice (builds the SoA view).
pub fn sort_fronts(pop: &[Candidate]) -> Vec<Vec<usize>> {
    sort_fronts_soa(&ObjSoa::from_candidates(pop))
}

/// Crowding distance of each member of one front — on latency and DSP,
/// plus the accuracy axis in 3-objective mode and the energy axis in
/// energy-front mode — computed on the flat objective view.
pub fn crowding_soa(soa: &ObjSoa, front: &[usize]) -> Vec<f64> {
    let m = front.len();
    let mut dist = vec![0.0f64; m];
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    let axes = 2 + usize::from(soa.accuracy_axis) + usize::from(soa.energy_axis);
    for axis in 0..axes {
        let key = |i: usize| -> f64 {
            match axis {
                0 => soa.latency[front[i]],
                1 => soa.dsp[front[i]],
                2 if soa.accuracy_axis => soa.neg_acc[front[i]],
                _ => soa.energy[front[i]],
            }
        };
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| key(a).partial_cmp(&key(b)).unwrap());
        let lo = key(order[0]);
        let hi = key(order[m - 1]);
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        if hi > lo {
            for w in 1..m - 1 {
                dist[order[w]] += (key(order[w + 1]) - key(order[w - 1])) / (hi - lo);
            }
        }
    }
    dist
}

/// Crowding distance on a candidate slice (builds the SoA view).
pub fn crowding(pop: &[Candidate], front: &[usize]) -> Vec<f64> {
    crowding_soa(&ObjSoa::from_candidates(pop), front)
}

/// Per-member front rank + crowding distance, precomputed ONCE per
/// generation and shared by every tournament of that generation —
/// the textbook NSGA-II mating-selection key.
#[derive(Debug, Clone)]
pub struct Ranking {
    /// front index of each member (0 = non-dominated)
    pub rank: Vec<usize>,
    /// crowding distance within the member's front
    pub crowding: Vec<f64>,
}

impl Ranking {
    pub fn build(soa: &ObjSoa) -> Ranking {
        let fronts = sort_fronts_soa(soa);
        let mut rank = vec![0usize; soa.len()];
        let mut crowd = vec![0.0f64; soa.len()];
        for (r, front) in fronts.iter().enumerate() {
            let d = crowding_soa(soa, front);
            for (k, &i) in front.iter().enumerate() {
                rank[i] = r;
                crowd[i] = d[k];
            }
        }
        Ranking { rank, crowding: crowd }
    }

    /// Indices of the current non-dominated (rank-0) members — the
    /// live Pareto front the roofline pre-filter prunes against.
    pub fn first_front(&self) -> impl Iterator<Item = usize> + '_ {
        self.rank.iter().enumerate().filter_map(|(i, &r)| (r == 0).then_some(i))
    }

    /// Crowded-comparison operator: lower rank wins; equal ranks break
    /// on larger crowding distance; `None` on a full tie.
    #[inline]
    pub fn prefer(&self, a: usize, b: usize) -> Option<usize> {
        if self.rank[a] != self.rank[b] {
            return Some(if self.rank[a] < self.rank[b] { a } else { b });
        }
        if self.crowding[a] > self.crowding[b] {
            Some(a)
        } else if self.crowding[b] > self.crowding[a] {
            Some(b)
        } else {
            None
        }
    }
}

/// Binary tournament on precomputed (rank, crowding): draw two members,
/// keep the crowded-comparison winner, coin-flip full ties. Returns the
/// index of the winner within the ranked population.
pub fn tournament(ranking: &Ranking, rng: &mut Rng) -> usize {
    let n = ranking.rank.len();
    let a = rng.below(n);
    let b = rng.below(n);
    match ranking.prefer(a, b) {
        Some(w) => w,
        None => {
            if rng.chance(0.5) {
                a
            } else {
                b
            }
        }
    }
}

/// Elitist (mu+lambda) environmental selection down to `target` members,
/// returned as indices into the SoA view (ascending front order; the
/// caller compacts its population without cloning a single `Candidate`)
/// PLUS the survivors' [`Ranking`], aligned with the returned index
/// order. Canonical NSGA-II reuses exactly these rank/crowding values as
/// the next generation's tournament key — reusing them here removes a
/// whole non-dominated sort from every generation of the DSE hot loop.
pub fn select_ranked(soa: &ObjSoa, target: usize) -> (Vec<usize>, Ranking) {
    let fronts = sort_fronts_soa(soa);
    let want = target.min(soa.len());
    let mut keep: Vec<usize> = Vec::with_capacity(want);
    let mut rank: Vec<usize> = Vec::with_capacity(want);
    let mut crowd: Vec<f64> = Vec::with_capacity(want);
    for (r, front) in fronts.iter().enumerate() {
        let d = crowding_soa(soa, front);
        if keep.len() + front.len() <= want {
            for (k, &i) in front.iter().enumerate() {
                keep.push(i);
                rank.push(r);
                crowd.push(d[k]);
            }
            if keep.len() == want {
                break;
            }
        } else {
            // partial front: take the most crowded-distant members
            let mut order: Vec<usize> = (0..front.len()).collect();
            order.sort_by(|&a, &b| d[b].partial_cmp(&d[a]).unwrap());
            for &w in order.iter().take(want - keep.len()) {
                keep.push(front[w]);
                rank.push(r);
                crowd.push(d[w]);
            }
            break;
        }
    }
    (keep, Ranking { rank, crowding: crowd })
}

/// Elitist selection on an owned population: library-surface wrapper
/// that delegates to [`select_ranked`] (single shared implementation —
/// the DSE engine calls `select_ranked` directly and compacts by
/// index).
pub fn select(pop: Vec<Candidate>, target: usize) -> Vec<Candidate> {
    if pop.len() <= target {
        return pop;
    }
    let (keep, _) = select_ranked(&ObjSoa::from_candidates(&pop), target);
    let mut taken = vec![false; pop.len()];
    for &i in &keep {
        taken[i] = true;
    }
    pop.into_iter()
        .enumerate()
        .filter_map(|(i, c)| taken[i].then_some(c))
        .collect()
}

/// The non-dominated subset of a candidate list (first front only).
pub fn non_dominated(pop: &[Candidate]) -> Vec<Candidate> {
    if pop.is_empty() {
        return Vec::new();
    }
    sort_fronts(pop)
        .into_iter()
        .next()
        .unwrap()
        .into_iter()
        .map(|i| pop[i].clone())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::DesignConfig;
    use crate::dse::Objectives;
    use crate::pe::FpRep;
    use crate::util::prop;

    fn cand(lat: f64, dsp: usize, viol: f64) -> Candidate {
        cand_acc(lat, dsp, viol, 1.0)
    }

    fn cand_acc(lat: f64, dsp: usize, viol: f64, acc: f64) -> Candidate {
        cand_energy(lat, dsp, viol, acc, 0.0)
    }

    fn cand_energy(lat: f64, dsp: usize, viol: f64, acc: f64, energy_mj: f64) -> Candidate {
        Candidate {
            config: DesignConfig { parallelism: vec![1], rep: FpRep::Int16 },
            objectives: Objectives {
                latency_ms: lat,
                dsp,
                lut: 0,
                bram: 0,
                total_pes: 0,
                accuracy: acc,
                power_mw: 0.0,
                energy_mj,
            },
            violation: viol,
        }
    }

    #[test]
    fn fronts_ordered_by_dominance() {
        let pop = vec![
            cand(1.0, 100, 0.0), // front 0
            cand(2.0, 50, 0.0),  // front 0 (trade-off)
            cand(2.0, 100, 0.0), // dominated by both
            cand(3.0, 200, 0.0), // dominated deeper
        ];
        let fronts = sort_fronts(&pop);
        assert_eq!(fronts[0], vec![0, 1]);
        assert!(fronts[1].contains(&2));
    }

    #[test]
    fn infeasible_always_loses() {
        let pop = vec![cand(0.1, 1, 1.0), cand(9.0, 900, 0.0)];
        let fronts = sort_fronts(&pop);
        assert_eq!(fronts[0], vec![1]);
    }

    #[test]
    fn crowding_extremes_infinite() {
        let pop = vec![
            cand(1.0, 300, 0.0),
            cand(2.0, 200, 0.0),
            cand(3.0, 100, 0.0),
        ];
        let d = crowding(&pop, &[0, 1, 2]);
        assert!(d[0].is_infinite());
        assert!(d[2].is_infinite());
        assert!(d[1].is_finite() && d[1] > 0.0);
    }

    #[test]
    fn select_keeps_first_front() {
        let pop = vec![
            cand(1.0, 100, 0.0),
            cand(2.0, 50, 0.0),
            cand(5.0, 500, 0.0),
            cand(6.0, 600, 0.0),
        ];
        let kept = select(pop, 2);
        assert_eq!(kept.len(), 2);
        let lats: Vec<f64> = kept.iter().map(|c| c.objectives.latency_ms).collect();
        assert!(lats.contains(&1.0) && lats.contains(&2.0));
    }

    #[test]
    fn select_ranked_agrees_with_select() {
        let pop = vec![
            cand(1.0, 100, 0.0),
            cand(2.0, 50, 0.0),
            cand(5.0, 500, 0.5),
            cand(6.0, 600, 0.0),
            cand(0.5, 400, 0.0),
        ];
        let (keep, ranking) = select_ranked(&ObjSoa::from_candidates(&pop), 3);
        assert_eq!(ranking.rank.len(), keep.len());
        assert_eq!(ranking.crowding.len(), keep.len());
        assert!(ranking.rank.windows(2).all(|w| w[0] <= w[1]), "keep is front-ordered");
        let kept = select(pop.clone(), 3);
        assert_eq!(keep.len(), kept.len());
        // the index-based path must pick the same members (order-insensitive)
        let mut by_idx: Vec<u64> =
            keep.iter().map(|&i| pop[i].objectives.latency_ms.to_bits()).collect();
        let mut by_val: Vec<u64> =
            kept.iter().map(|c| c.objectives.latency_ms.to_bits()).collect();
        by_idx.sort_unstable();
        by_val.sort_unstable();
        assert_eq!(by_idx, by_val);
    }

    #[test]
    fn non_dominated_extraction() {
        let pop = vec![cand(1.0, 100, 0.0), cand(0.5, 200, 0.0), cand(1.5, 150, 0.0)];
        let front = non_dominated(&pop);
        assert_eq!(front.len(), 2);
    }

    #[test]
    fn select_noop_when_small() {
        let pop = vec![cand(1.0, 1, 0.0)];
        assert_eq!(select(pop, 5).len(), 1);
    }

    /// Straight-line reference spec of feasibility-first dominance (the
    /// semantics `beats` and the old `beats_flat` each hand-implemented
    /// before they were collapsed into `beats_key`).
    fn beats_reference(a: &Candidate, b: &Candidate) -> bool {
        if a.violation == 0.0 && b.violation > 0.0 {
            return true;
        }
        if a.violation > 0.0 && b.violation > 0.0 {
            return a.violation < b.violation;
        }
        if a.violation > 0.0 {
            return false;
        }
        a.objectives.dominates(&b.objectives)
    }

    #[test]
    fn beats_kernel_matches_reference_on_random_candidates() {
        prop::check(
            "beats == reference",
            2000,
            77,
            |rng| {
                let mut mk = |rng: &mut crate::util::rng::Rng| {
                    cand_acc(
                        rng.f64() * 10.0,
                        rng.below(500),
                        if rng.chance(0.4) { rng.f64() * 2.0 } else { 0.0 },
                        // half the cases share one accuracy (2-objective
                        // shape), half spread it (3-objective shape)
                        if rng.chance(0.5) { 1.0 } else { rng.f64() },
                    )
                };
                let (a, b) = (mk(rng), mk(rng));
                // exercise the equal-key diagonal too
                if rng.chance(0.1) {
                    (a.clone(), a)
                } else {
                    (a, b)
                }
            },
            |(a, b)| {
                prop::ensure(
                    beats(a, b) == beats_reference(a, b)
                        && beats(b, a) == beats_reference(b, a),
                    format!(
                        "kernel {}/{} vs reference {}/{}",
                        beats(a, b),
                        beats(b, a),
                        beats_reference(a, b),
                        beats_reference(b, a)
                    ),
                )
            },
        );
    }

    #[test]
    fn accuracy_breaks_dominance_in_three_objective_mode() {
        // same latency/DSP, different accuracy: with the accuracy axis
        // the more accurate candidate dominates; identical accuracies
        // reproduce the 2-objective outcome exactly
        let hi = cand_acc(1.0, 100, 0.0, 0.9);
        let lo = cand_acc(1.0, 100, 0.0, 0.5);
        assert!(beats(&hi, &lo));
        assert!(!beats(&lo, &hi));
        let same = cand_acc(1.0, 100, 0.0, 0.9);
        assert!(!beats(&hi, &same) && !beats(&same, &hi));
        // a slower-but-more-accurate candidate is a trade-off, not dominated
        let slow_acc = cand_acc(2.0, 100, 0.0, 0.99);
        assert!(!beats(&hi, &slow_acc) && !beats(&slow_acc, &hi));
        let pop = vec![hi, lo, slow_acc];
        let mut soa = ObjSoa::from_candidates(&pop);
        soa.accuracy_axis = true;
        let fronts = sort_fronts_soa(&soa);
        assert_eq!(fronts[0], vec![0, 2]);
        assert_eq!(fronts[1], vec![1]);
    }

    #[test]
    fn accuracy_axis_changes_crowding_only_when_enabled() {
        // four mutually non-dominated members spread along accuracy at
        // identical latency-vs-dsp trade-off spacing
        let pop = vec![
            cand_acc(1.0, 400, 0.0, 0.70),
            cand_acc(2.0, 300, 0.0, 0.90),
            cand_acc(3.0, 200, 0.0, 0.95),
            cand_acc(4.0, 100, 0.0, 0.99),
        ];
        let front: Vec<usize> = (0..4).collect();
        let mut soa = ObjSoa::from_candidates(&pop);
        let two_axis = crowding_soa(&soa, &front);
        soa.accuracy_axis = true;
        let three_axis = crowding_soa(&soa, &front);
        // extremes stay infinite either way
        assert!(two_axis[0].is_infinite() && two_axis[3].is_infinite());
        assert!(three_axis[0].is_infinite() && three_axis[3].is_infinite());
        // interior members gain the accuracy-spread contribution
        assert!(three_axis[1] > two_axis[1]);
        assert!(three_axis[2] > two_axis[2]);
    }

    #[test]
    fn energy_axis_changes_dominance_only_when_enabled() {
        // identical (latency, dsp, accuracy), different energy: without
        // the axis they tie (one front); with it the cooler one dominates
        let pop = vec![
            cand_energy(1.0, 100, 0.0, 1.0, 5.0),
            cand_energy(1.0, 100, 0.0, 1.0, 2.0),
        ];
        let mut soa = ObjSoa::from_candidates(&pop);
        let fronts = sort_fronts_soa(&soa);
        assert_eq!(fronts[0].len(), 2, "axis off: energy must not discriminate");
        soa.energy_axis = true;
        let fronts = sort_fronts_soa(&soa);
        assert_eq!(fronts[0], vec![1]);
        assert_eq!(fronts[1], vec![0]);
        // a slower-but-cooler candidate is a trade-off, not dominated
        let pop = vec![
            cand_energy(1.0, 100, 0.0, 1.0, 5.0),
            cand_energy(2.0, 100, 0.0, 1.0, 2.0),
        ];
        let mut soa = ObjSoa::from_candidates(&pop);
        soa.energy_axis = true;
        assert_eq!(sort_fronts_soa(&soa)[0].len(), 2);
    }

    #[test]
    fn energy_axis_changes_crowding_only_when_enabled() {
        // four mutually non-dominated members spread along energy at
        // identical latency-vs-dsp spacing
        let pop = vec![
            cand_energy(1.0, 400, 0.0, 1.0, 1.0),
            cand_energy(2.0, 300, 0.0, 1.0, 4.0),
            cand_energy(3.0, 200, 0.0, 1.0, 5.0),
            cand_energy(4.0, 100, 0.0, 1.0, 9.0),
        ];
        let front: Vec<usize> = (0..4).collect();
        let mut soa = ObjSoa::from_candidates(&pop);
        let off = crowding_soa(&soa, &front);
        soa.energy_axis = true;
        let on = crowding_soa(&soa, &front);
        assert!(off[0].is_infinite() && on[0].is_infinite());
        assert!(on[1] > off[1]);
        assert!(on[2] > off[2]);
    }

    #[test]
    fn accuracy_and_energy_axes_compose() {
        // all four axes enabled: the crowding sum picks up both spreads
        let pop = vec![
            cand_energy(1.0, 400, 0.0, 0.70, 1.0),
            cand_energy(2.0, 300, 0.0, 0.90, 4.0),
            cand_energy(3.0, 200, 0.0, 0.95, 5.0),
            cand_energy(4.0, 100, 0.0, 0.99, 9.0),
        ];
        let front: Vec<usize> = (0..4).collect();
        let mut soa = ObjSoa::from_candidates(&pop);
        soa.accuracy_axis = true;
        let acc_only = crowding_soa(&soa, &front);
        soa.energy_axis = true;
        let both = crowding_soa(&soa, &front);
        assert!(both[1] > acc_only[1]);
        assert!(both[2] > acc_only[2]);
    }

    #[test]
    fn ranking_orders_fronts_and_crowding() {
        let pop = vec![
            cand(1.0, 100, 0.0), // front 0 extreme
            cand(2.0, 50, 0.0),  // front 0 extreme
            cand(2.0, 100, 0.0), // front 1
            cand(0.1, 999, 3.0), // infeasible: last front
        ];
        let r = Ranking::build(&ObjSoa::from_candidates(&pop));
        assert_eq!(r.rank[0], 0);
        assert_eq!(r.rank[1], 0);
        assert!(r.rank[2] > 0);
        assert!(r.rank[3] > r.rank[2], "infeasible must rank below dominated-feasible");
        assert!(r.crowding[0].is_infinite() && r.crowding[1].is_infinite());
    }

    #[test]
    fn first_front_yields_rank_zero_members() {
        let pop = vec![
            cand(1.0, 100, 0.0), // front 0
            cand(2.0, 50, 0.0),  // front 0
            cand(2.0, 100, 0.0), // dominated
            cand(0.1, 999, 3.0), // infeasible
        ];
        let r = Ranking::build(&ObjSoa::from_candidates(&pop));
        let front: Vec<usize> = r.first_front().collect();
        assert_eq!(front, vec![0, 1]);
    }

    #[test]
    fn tournament_prefers_lower_rank() {
        // two members: 0 dominates 1 → rank 0 vs rank 1. The winner is
        // rank-0 unless BOTH draws land on index 1 (probability 1/4), so
        // over 400 trials index 0 must win well over half.
        let pop = vec![cand(1.0, 100, 0.0), cand(2.0, 200, 0.0)];
        let ranking = Ranking::build(&ObjSoa::from_candidates(&pop));
        let mut rng = Rng::new(31);
        let wins0 = (0..400).filter(|_| tournament(&ranking, &mut rng) == 0).count();
        assert!(wins0 > 240, "rank-0 won only {wins0}/400");
    }

    #[test]
    fn tournament_prefers_crowding_within_front() {
        // three mutually non-dominated members: extremes get infinite
        // crowding, the middle is finite — a (extreme, middle) draw must
        // always return the extreme.
        let pop = vec![
            cand(1.0, 300, 0.0),
            cand(2.0, 200, 0.0),
            cand(3.0, 100, 0.0),
        ];
        let ranking = Ranking::build(&ObjSoa::from_candidates(&pop));
        assert_eq!(ranking.prefer(0, 1), Some(0));
        assert_eq!(ranking.prefer(1, 2), Some(2));
        assert_eq!(ranking.prefer(0, 2), None, "two extremes tie");
        let mut rng = Rng::new(32);
        let wins_mid = (0..600).filter(|_| tournament(&ranking, &mut rng) == 1).count();
        // middle only wins (1,1) draws: p = 1/9 → ~67 of 600
        assert!(wins_mid < 150, "finite-crowding middle won {wins_mid}/600");
    }
}
