//! NSGA-II machinery: fast non-dominated sort, crowding distance,
//! feasibility-first tournament, and elitist environmental selection.
//!
//! Reference: Deb et al., "A Fast and Elitist Multiobjective Genetic
//! Algorithm: NSGA-II" — the standard realization of the multi-objective
//! GA Algorithm 1 sketches.

use super::Candidate;
use crate::util::rng::Rng;

/// Feasibility-first comparison: a feasible candidate beats an infeasible
/// one; two infeasible compare by violation; two feasible by dominance.
fn beats(a: &Candidate, b: &Candidate) -> bool {
    if a.violation == 0.0 && b.violation > 0.0 {
        return true;
    }
    if a.violation > 0.0 && b.violation > 0.0 {
        return a.violation < b.violation;
    }
    if a.violation > 0.0 {
        return false;
    }
    a.objectives.dominates(&b.objectives)
}

/// Fast non-dominated sort: returns fronts as index vectors, best first.
///
/// §Perf: the O(n^2) comparison loop runs on a flat `(violation,
/// latency, dsp)` scratch array instead of chasing `Candidate` structs —
/// the comparisons are the DSE generation step's hottest code.
pub fn sort_fronts(pop: &[Candidate]) -> Vec<Vec<usize>> {
    let n = pop.len();
    // flat objective scratch: cache-friendly for the n^2 sweep
    let key: Vec<(f64, f64, f64)> = pop
        .iter()
        .map(|c| (c.violation, c.objectives.latency_ms, c.objectives.dsp as f64))
        .collect();
    #[inline(always)]
    fn beats_flat(a: (f64, f64, f64), b: (f64, f64, f64)) -> bool {
        if a.0 == 0.0 && b.0 > 0.0 {
            return true;
        }
        if a.0 > 0.0 {
            return a.0 < b.0 && b.0 > 0.0;
        }
        a.1 <= b.1 && a.2 <= b.2 && (a.1 < b.1 || a.2 < b.2)
    }

    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut dom_count = vec![0usize; n]; // how many dominate i
    for i in 0..n {
        let ki = key[i];
        for j in (i + 1)..n {
            let kj = key[j];
            if beats_flat(ki, kj) {
                dominated_by[i].push(j);
                dom_count[j] += 1;
            } else if beats_flat(kj, ki) {
                dominated_by[j].push(i);
                dom_count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| dom_count[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                dom_count[j] -= 1;
                if dom_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Crowding distance of each member of one front (on latency and DSP).
pub fn crowding(pop: &[Candidate], front: &[usize]) -> Vec<f64> {
    let m = front.len();
    let mut dist = vec![0.0f64; m];
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    // latency axis
    for axis in 0..2 {
        let key = |i: usize| -> f64 {
            let o = &pop[front[i]].objectives;
            if axis == 0 {
                o.latency_ms
            } else {
                o.dsp as f64
            }
        };
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| key(a).partial_cmp(&key(b)).unwrap());
        let lo = key(order[0]);
        let hi = key(order[m - 1]);
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        if hi > lo {
            for w in 1..m - 1 {
                dist[order[w]] += (key(order[w + 1]) - key(order[w - 1])) / (hi - lo);
            }
        }
    }
    dist
}

/// Binary tournament: rank (front index) first, then crowding distance.
/// Returns the index of the winner within `pop`.
pub fn tournament(pop: &[Candidate], rng: &mut Rng) -> usize {
    let a = rng.below(pop.len());
    let b = rng.below(pop.len());
    if beats(&pop[a], &pop[b]) {
        a
    } else if beats(&pop[b], &pop[a]) {
        b
    } else if rng.chance(0.5) {
        a
    } else {
        b
    }
}

/// Elitist (mu+lambda) environmental selection down to `target` members.
pub fn select(pop: Vec<Candidate>, target: usize) -> Vec<Candidate> {
    if pop.len() <= target {
        return pop;
    }
    let fronts = sort_fronts(&pop);
    let mut keep: Vec<usize> = Vec::with_capacity(target);
    for front in fronts {
        if keep.len() + front.len() <= target {
            keep.extend(front);
            if keep.len() == target {
                break;
            }
        } else {
            // partial front: take the most crowded-distant members
            let d = crowding(&pop, &front);
            let mut order: Vec<usize> = (0..front.len()).collect();
            order.sort_by(|&a, &b| d[b].partial_cmp(&d[a]).unwrap());
            for &w in order.iter().take(target - keep.len()) {
                keep.push(front[w]);
            }
            break;
        }
    }
    let mut out = Vec::with_capacity(target);
    let mut taken = vec![false; pop.len()];
    for i in keep {
        taken[i] = true;
    }
    for (i, c) in pop.into_iter().enumerate() {
        if taken[i] {
            out.push(c);
        }
    }
    out
}

/// The non-dominated subset of a candidate list (first front only).
pub fn non_dominated(pop: &[Candidate]) -> Vec<Candidate> {
    if pop.is_empty() {
        return Vec::new();
    }
    sort_fronts(pop)
        .into_iter()
        .next()
        .unwrap()
        .into_iter()
        .map(|i| pop[i].clone())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::DesignConfig;
    use crate::dse::Objectives;
    use crate::pe::FpRep;

    fn cand(lat: f64, dsp: usize, viol: f64) -> Candidate {
        Candidate {
            config: DesignConfig { parallelism: vec![1], rep: FpRep::Int16 },
            objectives: Objectives { latency_ms: lat, dsp, lut: 0, bram: 0, total_pes: 0 },
            violation: viol,
        }
    }

    #[test]
    fn fronts_ordered_by_dominance() {
        let pop = vec![
            cand(1.0, 100, 0.0), // front 0
            cand(2.0, 50, 0.0),  // front 0 (trade-off)
            cand(2.0, 100, 0.0), // dominated by both
            cand(3.0, 200, 0.0), // dominated deeper
        ];
        let fronts = sort_fronts(&pop);
        assert_eq!(fronts[0], vec![0, 1]);
        assert!(fronts[1].contains(&2));
    }

    #[test]
    fn infeasible_always_loses() {
        let pop = vec![cand(0.1, 1, 1.0), cand(9.0, 900, 0.0)];
        let fronts = sort_fronts(&pop);
        assert_eq!(fronts[0], vec![1]);
    }

    #[test]
    fn crowding_extremes_infinite() {
        let pop = vec![
            cand(1.0, 300, 0.0),
            cand(2.0, 200, 0.0),
            cand(3.0, 100, 0.0),
        ];
        let d = crowding(&pop, &[0, 1, 2]);
        assert!(d[0].is_infinite());
        assert!(d[2].is_infinite());
        assert!(d[1].is_finite() && d[1] > 0.0);
    }

    #[test]
    fn select_keeps_first_front() {
        let pop = vec![
            cand(1.0, 100, 0.0),
            cand(2.0, 50, 0.0),
            cand(5.0, 500, 0.0),
            cand(6.0, 600, 0.0),
        ];
        let kept = select(pop, 2);
        assert_eq!(kept.len(), 2);
        let lats: Vec<f64> = kept.iter().map(|c| c.objectives.latency_ms).collect();
        assert!(lats.contains(&1.0) && lats.contains(&2.0));
    }

    #[test]
    fn non_dominated_extraction() {
        let pop = vec![cand(1.0, 100, 0.0), cand(0.5, 200, 0.0), cand(1.5, 150, 0.0)];
        let front = non_dominated(&pop);
        assert_eq!(front.len(), 2);
    }

    #[test]
    fn select_noop_when_small() {
        let pop = vec![cand(1.0, 1, 0.0)];
        assert_eq!(select(pop, 5).len(), 1);
    }
}
