//! NeuroForge design space exploration — Sec. III-C, Algorithm 1.
//!
//! DSE is posed as multi-objective optimization: minimize inference
//! latency and resource utilization simultaneously, under user-defined
//! constraints `[t, DSP, LUT, BRAM]`. The decision vector is the
//! per-conv-layer parallelism `p(i)` with `1 <= p(i) <= ub(i)`; Eq. 14
//! expands it to PE allocations `L(i) = p(i) * p(i-1)`.
//!
//! The optimizer is an NSGA-II-style MOGA: fast non-dominated sorting,
//! crowding distance, binary tournament selection, uniform crossover and
//! Algorithm 1's bounded power-distribution mutation. Evaluation uses the
//! analytical models only (microseconds per candidate — no synthesis in
//! the loop), which is the paper's core speed claim over DNNBuilder-style
//! flows.
//!
//! §Perf — the search engine is parallel, memoized and allocation-free:
//!
//! * **Threading.** Fitness evaluation fans out over `threads - 1`
//!   persistent worker threads plus the main thread (scoped; spawned once
//!   per search, fed whole-generation batches over channels). Genetic
//!   operators and every RNG draw stay on the main thread, and results
//!   land in their batch slot by index, so `run` is bit-identical for any
//!   `threads` value (test-enforced).
//! * **Memoization.** GA populations are heavily duplicated (elitist
//!   re-selection, no-op mutations, clone-producing crossover). A
//!   chromosome cache keyed on `(conv genes, rep)` — `rep` is fixed per
//!   search, so the map keys on the conv-gene vector alone with the
//!   vendored [`crate::util::hash::FxHasher`] — skips re-evaluating
//!   duplicates, both across generations and within one batch. The
//!   3-objective path gene is excluded from the key: the cache stores
//!   the path-independent base fitness and candidates differing only in
//!   execution path share one analytical evaluation. Hit telemetry
//!   lands in [`DseResult`].
//! * **Segment reuse.** Chromosome-cache *misses* don't re-run the whole
//!   analytical model: each StagePlan stage's fit is keyed on its packed
//!   `(stage, own gene, boundary lanes)` window
//!   ([`design::Evaluator::stage_key`]) in a stage-level primary cache,
//!   and whole-candidate fitness is composed from the cached
//!   [`design::StageFit`]s by [`design::Evaluator::compose`] — the same
//!   order-independent integer math, so fronts stay bit-identical
//!   (test-enforced). Mutation neighbors, which share almost every gene
//!   with a parent, re-compute only the stages their changed genes
//!   actually touch.
//! * **Search shortcuts (opt-in).** `--prune` skips offspring whose
//!   sound roofline lower bound ([`roofline::GeneBounds`]) already
//!   violates the constraints or is Pareto-dominated by the current
//!   feasible front; `--surrogate` pre-orders offspring evaluation with
//!   a deterministic per-generation linear model on gene features
//!   (dispatch order only — results return to their batch slots, so
//!   fronts and telemetry stay bit-identical).
//! * **Allocation discipline.** Gene buffers recycle through a scratch
//!   pool ([`crossover_into`] fills caller buffers; discarded candidates
//!   donate their vectors back), environmental selection is index-based
//!   ([`nsga2::select_ranked`] on the flat [`nsga2::ObjSoa`] objective
//!   view), and tournament ranks + crowding are computed once per
//!   generation instead of per comparison.

pub mod nsga2;
pub mod roofline;

use std::sync::mpsc;
use std::time::Instant;

use crate::design::{self, DesignConfig};
use crate::graph::Network;
use crate::pe::{Device, FpRep, Resources};
use crate::power::{Activity, PowerModel};
use crate::util::hash::FxHashMap;
use crate::util::rng::Rng;

/// User constraints (Algorithm 1's `constraints [t, DSP, LUT, BRAM]`,
/// extended with the runtime power budget of the closed loop).
#[derive(Debug, Clone, Copy)]
pub struct Constraints {
    /// max latency, ms (None = unconstrained)
    pub latency_ms: Option<f64>,
    pub dsp: Option<usize>,
    pub lut: Option<usize>,
    pub bram: Option<usize>,
    /// max modeled power draw, mW (`explore --power-budget`): candidates
    /// above it are penalized exactly like resource overruns, so the
    /// search lands on designs the runtime governor can actually hold
    /// under the deployment's power cap
    pub power_mw: Option<f64>,
}

impl Constraints {
    pub fn none() -> Constraints {
        Constraints { latency_ms: None, dsp: None, lut: None, bram: None, power_mw: None }
    }

    /// Constrain to a device's full budget.
    pub fn device(dev: &Device) -> Constraints {
        Constraints {
            latency_ms: None,
            dsp: Some(dev.budget.dsp),
            lut: Some(dev.budget.lut),
            bram: Some(dev.budget.bram),
            power_mw: None,
        }
    }

    /// Total constraint violation (0 = feasible); used for
    /// feasibility-first dominance.
    pub fn violation(&self, obj: &Objectives) -> f64 {
        let mut v = 0.0;
        if let Some(t) = self.latency_ms {
            v += ((obj.latency_ms - t) / t).max(0.0);
        }
        if let Some(d) = self.dsp {
            v += ((obj.dsp as f64 - d as f64) / d as f64).max(0.0);
        }
        if let Some(l) = self.lut {
            v += ((obj.lut as f64 - l as f64) / l as f64).max(0.0);
        }
        if let Some(b) = self.bram {
            v += ((obj.bram as f64 - b as f64) / b as f64).max(0.0);
        }
        if let Some(p) = self.power_mw {
            v += ((obj.power_mw - p) / p).max(0.0);
        }
        v
    }
}

/// Objective vector `Y = {Y_t, Y_DSP, Y_LUT, Y_BRAM}` (Alg. 1 output),
/// extended with the DistillCycle path accuracy when the search runs in
/// 3-objective mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objectives {
    pub latency_ms: f64,
    pub dsp: usize,
    pub lut: usize,
    pub bram: usize,
    /// "Design PEs" (Table III indicator column)
    pub total_pes: usize,
    /// execution-path accuracy from the DistillCycle
    /// [`AccuracyProfile`](crate::distill::AccuracyProfile) (maximized);
    /// a constant `1.0` in plain 2-objective searches
    pub accuracy: f64,
    /// modeled power draw (mW): [`PowerModel`] over the allocated
    /// resources at the device clock; on a 3-objective search the
    /// dynamic share scales with the selected path's MAC fraction (the
    /// analytical serving backend's first-order model)
    pub power_mw: f64,
    /// modeled energy per frame (mJ) = power x path-scaled latency;
    /// the optional fourth search axis (`DseConfig::energy_objective`)
    pub energy_mj: f64,
}

impl Objectives {
    /// Pareto dominance on the optimized objectives: (latency, DSP)
    /// minimized and accuracy maximized — the paper optimizes DSP
    /// against latency and constraint-checks the rest; accuracy joins as
    /// the third axis in profile-driven searches (constant otherwise, so
    /// it never affects 2-objective dominance).
    pub fn dominates(&self, other: &Objectives) -> bool {
        let no_worse = self.latency_ms <= other.latency_ms
            && self.dsp <= other.dsp
            && self.accuracy >= other.accuracy;
        let better = self.latency_ms < other.latency_ms
            || self.dsp < other.dsp
            || self.accuracy > other.accuracy;
        no_worse && better
    }
}

/// One evaluated individual.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub config: DesignConfig,
    pub objectives: Objectives,
    pub violation: f64,
}

/// DSE hyperparameters.
#[derive(Debug, Clone)]
pub struct DseConfig {
    pub population: usize,
    pub generations: usize,
    pub crossover_rate: f64,
    pub mutation_rate: f64,
    /// power-distribution exponent for mutation step sizes (Alg. 1)
    pub mutation_power: f64,
    pub rep: FpRep,
    pub constraints: Constraints,
    pub seed: u64,
    /// fitness-evaluation threads (main thread included; 1 = serial).
    /// The Pareto front is bit-identical for every value.
    pub threads: usize,
    /// chromosome memo cache on/off (off reproduces the pre-cache
    /// baseline for benchmarking; results are identical either way)
    pub memo: bool,
    /// stage-level (segment) memo on/off — the primary level of the
    /// two-level cache, active only when `memo` is also on. On,
    /// chromosome-cache misses are composed from cached per-stage fits
    /// ([`design::Evaluator::stage_fit`]) instead of re-running the
    /// monolithic kernel — identical math, identical fronts and
    /// chromosome-level telemetry (test-enforced). Off reproduces the
    /// chromosome-memo-only engine for benchmarking.
    pub stage_memo: bool,
    /// roofline dominated-region pre-filter (`explore --prune`):
    /// offspring whose sound lower bound ([`roofline::GeneBounds`])
    /// already violates the latency/DSP constraints or is dominated by
    /// the current feasible front skip evaluation, counted in
    /// [`DseResult::roofline_pruned`]. Opt-in: a skipped candidate never
    /// enters the population, so the search *trajectory* (not the
    /// soundness of any single prune) differs from the unpruned run.
    pub prune: bool,
    /// deterministic surrogate ranker (`explore --surrogate`): a
    /// per-generation linear model on gene features pre-orders offspring
    /// evaluation most-promising-first, front-loading the eval budget.
    /// Dispatch order only — results return to their batch slots, so
    /// fronts and telemetry are bit-identical on/off (test-enforced).
    pub surrogate: bool,
    /// DistillCycle execution-path ladder (accuracy + MAC metadata,
    /// typically `AccuracyProfile::morph_paths()`). `Some` switches the
    /// search to three objectives: the chromosome gains one trailing
    /// path-selection gene, each candidate's latency is scaled by its
    /// path's MAC fraction (the same first-order model the analytical
    /// serving backend uses) and the path accuracy is maximized
    /// alongside (latency, DSP). `None` reproduces the 2-objective
    /// search bit-for-bit.
    pub accuracy_paths: Option<Vec<crate::morph::MorphPath>>,
    /// add modeled energy-per-frame as a minimized search axis
    /// (`explore --energy-front`). Off (the default), power/energy are
    /// computed for telemetry and the power-budget constraint only and
    /// contribute nothing to dominance or crowding — existing 2- and
    /// 3-objective searches stay bit-identical (test-enforced).
    pub energy_objective: bool,
    /// optional span/event sink (`explore --trace-out`): `Some` records
    /// one virtual-clock generation span plus cumulative engine counters
    /// per GA generation on lane 0. Every recorded value is computed on
    /// the main thread and already thread-count-invariant, so traces are
    /// byte-identical across `threads`; `None` records nothing and the
    /// search result is identical either way.
    pub trace: Option<std::sync::Arc<crate::obs::TraceSink>>,
}

impl Default for DseConfig {
    fn default() -> Self {
        DseConfig {
            population: 96,
            generations: 60,
            crossover_rate: 0.9,
            mutation_rate: 0.25,
            mutation_power: 3.0,
            rep: FpRep::Int16,
            constraints: Constraints::none(),
            seed: 0,
            threads: 1,
            memo: true,
            stage_memo: true,
            prune: false,
            surrogate: false,
            accuracy_paths: None,
            energy_objective: false,
            trace: None,
        }
    }
}

/// DSE outcome: the non-dominated feasible set plus search telemetry.
#[derive(Debug, Clone)]
pub struct DseResult {
    /// Pareto-optimal feasible candidates, sorted by latency ascending
    pub pareto: Vec<Candidate>,
    /// every evaluated (latency, dsp) pair — the Fig. 2 scatter
    pub evaluated: Vec<(f64, usize)>,
    /// per-generation best latency (convergence telemetry)
    pub best_latency_per_gen: Vec<f64>,
    /// total fitness lookups (memo hits included)
    pub evaluations: usize,
    /// analytical-model evaluations actually executed (memo misses)
    pub unique_evaluations: usize,
    /// chromosome-cache hits (cross-generation + within-batch)
    pub cache_hits: usize,
    /// stage-cache hits: stage lookups (chromosome misses × stages)
    /// served from the segment-level primary cache
    pub stage_hits: usize,
    /// stage-cache misses: per-stage kernel runs actually executed
    pub stage_misses: usize,
    /// offspring skipped by the roofline pre-filter (`--prune`) before
    /// ever reaching evaluation
    pub roofline_pruned: usize,
    /// offspring whose evaluation-dispatch position the surrogate
    /// ranker moved (`--surrogate`); 0 with the flag off
    pub surrogate_reorders: usize,
    /// wall-clock time of the whole search, milliseconds
    pub wall_ms: f64,
}

impl DseResult {
    /// Fraction of fitness lookups served from the **chromosome-level**
    /// (assembled) cache — whole-candidate duplicates. Stage-level reuse
    /// inside the misses is [`DseResult::stage_hit_rate`].
    pub fn cache_hit_rate(&self) -> f64 {
        if self.evaluations == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.evaluations as f64
        }
    }

    /// Fraction of stage-kernel lookups served from the stage-level
    /// (segment) cache. Only chromosome-cache *misses* reach the stage
    /// level, so this measures reuse across distinct-but-similar
    /// chromosomes (mutation/crossover neighbors).
    pub fn stage_hit_rate(&self) -> f64 {
        let total = self.stage_hits + self.stage_misses;
        if total == 0 {
            0.0
        } else {
            self.stage_hits as f64 / total as f64
        }
    }
}

/// Evaluate one chromosome into a Candidate (one-shot convenience; the
/// MOGA loop uses the allocation-free [`design::Evaluator`] fast path).
pub fn evaluate_candidate(
    net: &Network,
    parallelism: Vec<usize>,
    rep: FpRep,
    device: &Device,
    constraints: &Constraints,
) -> Candidate {
    let evaluator = design::Evaluator::new(net, device).expect("valid network");
    evaluate_with(&evaluator, parallelism, rep, constraints)
}

/// Fitness via a prebuilt evaluator — the DSE inner-loop fast path
/// (§Perf: ~5x over rebuilding shape inference per candidate).
pub fn evaluate_with(
    evaluator: &design::Evaluator,
    parallelism: Vec<usize>,
    rep: FpRep,
    constraints: &Constraints,
) -> Candidate {
    let (objectives, violation) = eval_genes(evaluator, &parallelism, rep, constraints, None);
    Candidate { config: DesignConfig { parallelism, rep }, objectives, violation }
}

/// Accuracy context of a 3-objective search: per-path latency ratios
/// (MAC fraction of the heaviest path — the deployed bitstream carries
/// every path's PEs, so *resources* stay those of the full design while
/// latency and accuracy follow the selected execution path) plus the
/// DistillCycle accuracies, indexed by the trailing path gene.
struct AccCtx {
    ratios: Vec<f64>,
    accs: Vec<f64>,
}

impl AccCtx {
    fn new(paths: &[crate::morph::MorphPath]) -> AccCtx {
        assert!(!paths.is_empty(), "accuracy ladder must not be empty");
        let full = paths.iter().map(|p| p.macs).max().unwrap_or(1).max(1);
        AccCtx {
            ratios: paths.iter().map(|p| p.macs as f64 / full as f64).collect(),
            accs: paths.iter().map(|p| p.accuracy).collect(),
        }
    }

    fn len(&self) -> usize {
        self.accs.len()
    }
}

/// Path-independent analytical fitness of the conv genes — the
/// expensive kernel (and the unit of memoization): everything below it
/// (path scaling, constraint checking, power scaling) is a handful of
/// multiplies.
#[derive(Debug, Clone, Copy)]
struct BaseFit {
    latency_ms: f64,
    dsp: usize,
    lut: usize,
    bram: usize,
    total_pes: usize,
    /// full-design power at the device clock and default activity
    power_mw: f64,
}

/// Finish a [`design::FastEval`] into the memoized base fitness —
/// shared by the monolithic kernel and the segment-composed path, so
/// both produce bit-identical `BaseFit`s from equal `FastEval`s.
#[inline]
fn base_from_fast(evaluator: &design::Evaluator, fast: &design::FastEval) -> BaseFit {
    let power_mw = PowerModel::default().total_mw(
        &fast.resources,
        evaluator.clock_mhz(),
        Activity::default(),
    );
    BaseFit {
        latency_ms: evaluator.latency_ms(fast),
        dsp: fast.resources.dsp,
        lut: fast.resources.lut,
        bram: fast.resources.bram,
        total_pes: fast.total_pes,
        power_mw,
    }
}

#[inline]
fn base_eval(evaluator: &design::Evaluator, conv_genes: &[usize], rep: FpRep) -> BaseFit {
    let fast = evaluator
        .objectives(conv_genes, rep)
        .expect("chromosome respects bounds by construction");
    base_from_fast(evaluator, &fast)
}

/// Apply the (optional) trailing path-selection gene and the
/// constraints to a base fitness: latency scales by the path's MAC
/// fraction, accuracy becomes the third objective, and the dynamic power
/// share scales with the active MAC fraction (the static + clock-tree
/// floor stays — clock-gated blocks leak but never toggle).
#[inline]
fn finish_fit(
    base: BaseFit,
    genes: &[usize],
    acc: Option<&AccCtx>,
    constraints: &Constraints,
    clock_mhz: f64,
) -> (Objectives, f64) {
    let mut latency_ms = base.latency_ms;
    let mut power_mw = base.power_mw;
    let mut accuracy = 1.0;
    if let Some(ctx) = acc {
        let pi = genes[genes.len() - 1] - 1; // path gene is 1-based
        latency_ms *= ctx.ratios[pi];
        accuracy = ctx.accs[pi];
        let floor = PowerModel::default().total_mw(
            &Resources::default(),
            clock_mhz,
            Activity::default(),
        );
        power_mw = floor + (base.power_mw - floor) * ctx.ratios[pi];
    }
    let energy_mj = power_mw * latency_ms / 1000.0;
    let objectives = Objectives {
        latency_ms,
        dsp: base.dsp,
        lut: base.lut,
        bram: base.bram,
        total_pes: base.total_pes,
        accuracy,
        power_mw,
        energy_mj,
    };
    let violation = constraints.violation(&objectives);
    (objectives, violation)
}

/// How many trailing non-conv genes the chromosome carries.
#[inline]
fn gene_strip(acc: Option<&AccCtx>) -> usize {
    usize::from(acc.is_some())
}

/// One-shot fitness on a full chromosome (public surface + workers).
#[inline]
fn eval_genes(
    evaluator: &design::Evaluator,
    genes: &[usize],
    rep: FpRep,
    constraints: &Constraints,
    acc: Option<&AccCtx>,
) -> (Objectives, f64) {
    let base = base_eval(evaluator, &genes[..genes.len() - gene_strip(acc)], rep);
    finish_fit(base, genes, acc, constraints, evaluator.clock_mhz())
}

/// A worker's share of one generation: chromosome-cache misses to run
/// through the monolithic kernel, or stage-cache fills to compute from
/// packed keys ([`design::Evaluator::stage_key`]). Both are pure
/// key→value work — the memoization and ordering decisions stay on the
/// main thread.
enum Job {
    /// (batch slot, chromosome) pairs
    Chromosomes(Vec<(usize, Vec<usize>)>),
    /// packed stage keys
    StageKeys(Vec<u64>),
}

/// Evaluated share, mirroring the [`Job`] variant it answers.
enum Done {
    /// (batch slot, chromosome back, base fitness)
    Chromosomes(Vec<(usize, Vec<usize>, BaseFit)>),
    StageFits(Vec<(u64, design::StageFit)>),
}

/// Chromosome memo cache. Keyed on `(conv genes, rep)`: `rep` is fixed
/// for a whole search, so the map keys on the boxed conv-gene slice
/// alone (lookups borrow `&[usize]` — no allocation on the hit path).
/// In 3-objective mode the trailing path gene is *excluded* from the
/// key and the cache stores the path-independent [`BaseFit`]: two
/// candidates that differ only in execution path share one analytical
/// evaluation, and the per-path latency/accuracy scaling is applied at
/// lookup. A `None` value is an in-flight sentinel: the conv genes'
/// first occurrence in the current batch is being evaluated, so later
/// duplicates wait on it instead of re-evaluating — one key boxing per
/// unique conv-gene vector, ever.
struct Memo {
    map: FxHashMap<Box<[usize]>, Option<BaseFit>>,
    hits: usize,
}

/// Stage-level memo — the primary level of the two-level cache: packed
/// [`design::Evaluator::stage_key`] → [`design::StageFit`]. A `None`
/// value is the in-flight sentinel for keys first seen in the current
/// batch (mirroring [`Memo`]'s), filled before composition. Probing and
/// hit counting happen on the main thread in batch order, so the
/// telemetry is thread-count-invariant (test-enforced); only the pure
/// key→fit kernel fans out.
#[derive(Default)]
struct StageMemo {
    map: FxHashMap<u64, Option<design::StageFit>>,
    hits: usize,
    misses: usize,
}

/// The per-search evaluation engine: shared immutable evaluator,
/// persistent scoped workers, memo cache, telemetry.
struct Engine<'a> {
    evaluator: &'a design::Evaluator,
    rep: FpRep,
    constraints: Constraints,
    /// 3-objective accuracy context (None ⇒ classic 2-objective search)
    acc: Option<&'a AccCtx>,
    memo: Option<Memo>,
    /// segment-level primary cache (None ⇒ monolithic kernel per miss)
    stage_memo: Option<StageMemo>,
    /// per-worker job channels (empty ⇒ serial)
    job_txs: Vec<mpsc::Sender<Job>>,
    done_rx: mpsc::Receiver<Done>,
    evaluations: usize,
    unique_evaluations: usize,
}

impl Engine<'_> {
    /// Finish a chromosome into a Candidate from its base fitness
    /// (path scaling + constraints — main-thread, deterministic).
    fn candidate(&self, genes: Vec<usize>, base: BaseFit) -> Candidate {
        let (objectives, violation) =
            finish_fit(base, &genes, self.acc, &self.constraints, self.evaluator.clock_mhz());
        Candidate { config: DesignConfig { parallelism: genes, rep: self.rep }, objectives, violation }
    }

    /// Evaluate a whole generation of chromosomes. Memo hits and
    /// within-batch duplicates are resolved on the main thread; misses
    /// fan out across the workers in index-chunked shares and land back
    /// in their slots, so the output order (and therefore the whole
    /// search) is independent of the thread count.
    fn eval_batch(&mut self, batch: Vec<Vec<usize>>) -> Vec<Candidate> {
        let n = batch.len();
        self.evaluations += n;
        let strip = gene_strip(self.acc);
        let mut slots: Vec<Option<Candidate>> = (0..n).map(|_| None).collect();
        let mut misses: Vec<(usize, Vec<usize>)> = Vec::new();
        // slots of in-batch duplicates, resolved from the memo afterwards
        let mut dups: Vec<(usize, Vec<usize>)> = Vec::new();

        for (i, genes) in batch.into_iter().enumerate() {
            if let Some(memo) = &mut self.memo {
                let key = &genes[..genes.len() - strip];
                // owned copy of the cached state — keeps the map free for
                // the pending-sentinel insert below
                match memo.map.get(key).copied() {
                    Some(Some(base)) => {
                        memo.hits += 1;
                        let (objectives, violation) = finish_fit(
                            base,
                            &genes,
                            self.acc,
                            &self.constraints,
                            self.evaluator.clock_mhz(),
                        );
                        slots[i] = Some(Candidate {
                            config: DesignConfig { parallelism: genes, rep: self.rep },
                            objectives,
                            violation,
                        });
                        continue;
                    }
                    Some(None) => {
                        // first occurrence is being evaluated in this batch
                        memo.hits += 1;
                        dups.push((i, genes));
                        continue;
                    }
                    None => {
                        memo.map.insert(key.to_vec().into_boxed_slice(), None);
                    }
                }
            }
            misses.push((i, genes));
        }
        self.unique_evaluations += misses.len();

        let done = if self.stage_memo.is_some() {
            self.eval_misses_staged(misses, strip)
        } else {
            self.eval_misses_monolithic(misses, strip)
        };

        for (i, genes, base) in done {
            if let Some(memo) = &mut self.memo {
                // fill the pending sentinel in place — the key was boxed
                // exactly once, at first sight
                *memo
                    .map
                    .get_mut(&genes[..genes.len() - strip])
                    .expect("pending entry present") = Some(base);
            }
            slots[i] = Some(self.candidate(genes, base));
        }
        for (i, genes) in dups {
            let memo = self.memo.as_ref().expect("dups only collected with memo on");
            let base = memo
                .map
                .get(&genes[..genes.len() - strip])
                .copied()
                .flatten()
                .expect("first occurrence evaluated");
            slots[i] = Some(self.candidate(genes, base));
        }
        slots.into_iter().map(|s| s.expect("every slot filled")).collect()
    }

    /// Pre-stage-cache path: every chromosome miss runs the monolithic
    /// [`base_eval`] kernel, fanned out whole-chromosome when the batch
    /// amortizes the channel round-trip.
    fn eval_misses_monolithic(
        &mut self,
        mut misses: Vec<(usize, Vec<usize>)>,
        strip: usize,
    ) -> Vec<(usize, Vec<usize>, BaseFit)> {
        let workers = self.job_txs.len();
        if workers == 0 || misses.len() < 2 * (workers + 1) {
            return misses
                .into_iter()
                .map(|(i, genes)| {
                    let base =
                        base_eval(self.evaluator, &genes[..genes.len() - strip], self.rep);
                    (i, genes, base)
                })
                .collect();
        }
        let share = misses.len().div_ceil(workers + 1);
        // main thread keeps the first share, workers take the rest
        let mut rest = misses.split_off(share.min(misses.len()));
        let mut sent = 0usize;
        for tx in &self.job_txs {
            if rest.is_empty() {
                break;
            }
            let tail = rest.split_off(share.min(rest.len()));
            tx.send(Job::Chromosomes(rest)).expect("dse worker alive");
            rest = tail;
            sent += 1;
        }
        debug_assert!(rest.is_empty());
        let mut done: Vec<(usize, Vec<usize>, BaseFit)> = misses
            .into_iter()
            .map(|(i, genes)| {
                let base = base_eval(self.evaluator, &genes[..genes.len() - strip], self.rep);
                (i, genes, base)
            })
            .collect();
        for _ in 0..sent {
            match self.done_rx.recv().expect("dse worker result") {
                Done::Chromosomes(d) => done.extend(d),
                Done::StageFits(_) => unreachable!("no stage jobs in flight"),
            }
        }
        done
    }

    /// Stage-cache path, three phases. **A** (main thread): key every
    /// stage of every miss and probe the stage memo in batch order, so
    /// hit/miss telemetry is independent of the thread count. **B**:
    /// compute the vacant `key → StageFit` bindings — pure values whose
    /// arrival order is irrelevant, so they fan out freely. **C** (main
    /// thread): compose each miss from its cached stage fits
    /// ([`design::Evaluator::compose`]) — bit-identical to the
    /// monolithic kernel by construction.
    fn eval_misses_staged(
        &mut self,
        misses: Vec<(usize, Vec<usize>)>,
        strip: usize,
    ) -> Vec<(usize, Vec<usize>, BaseFit)> {
        use std::collections::hash_map::Entry;
        let evaluator = self.evaluator;
        let rep = self.rep;
        let n_stages = evaluator.n_stages();
        // phase A
        let mut keys: Vec<u64> = Vec::with_capacity(misses.len() * n_stages);
        let mut need: Vec<u64> = Vec::new();
        {
            let sm = self.stage_memo.as_mut().expect("staged path needs the stage memo");
            for (_, genes) in &misses {
                let conv = &genes[..genes.len() - strip];
                for s in 0..n_stages {
                    let key = evaluator.stage_key(s, conv);
                    keys.push(key);
                    match sm.map.entry(key) {
                        Entry::Occupied(_) => sm.hits += 1,
                        Entry::Vacant(e) => {
                            e.insert(None);
                            sm.misses += 1;
                            need.push(key);
                        }
                    }
                }
            }
        }
        // phase B: stage fits are tiny, so fan out only on big fills
        let workers = self.job_txs.len();
        let fits: Vec<(u64, design::StageFit)> =
            if workers == 0 || need.len() < 32 * (workers + 1) {
                need.into_iter().map(|k| (k, evaluator.stage_fit_packed(k, rep))).collect()
            } else {
                let share = need.len().div_ceil(workers + 1);
                let mut rest = need.split_off(share.min(need.len()));
                let mut sent = 0usize;
                for tx in &self.job_txs {
                    if rest.is_empty() {
                        break;
                    }
                    let tail = rest.split_off(share.min(rest.len()));
                    tx.send(Job::StageKeys(rest)).expect("dse worker alive");
                    rest = tail;
                    sent += 1;
                }
                debug_assert!(rest.is_empty());
                let mut fits: Vec<(u64, design::StageFit)> = need
                    .into_iter()
                    .map(|k| (k, evaluator.stage_fit_packed(k, rep)))
                    .collect();
                for _ in 0..sent {
                    match self.done_rx.recv().expect("dse worker result") {
                        Done::StageFits(d) => fits.extend(d),
                        Done::Chromosomes(_) => unreachable!("no chromosome jobs in flight"),
                    }
                }
                fits
            };
        // phase C
        let sm = self.stage_memo.as_mut().expect("staged path needs the stage memo");
        for (k, fit) in fits {
            *sm.map.get_mut(&k).expect("pending stage entry present") = Some(fit);
        }
        let sm = self.stage_memo.as_ref().expect("staged path needs the stage memo");
        misses
            .into_iter()
            .enumerate()
            .map(|(mi, (i, genes))| {
                let window = &keys[mi * n_stages..(mi + 1) * n_stages];
                let fast = evaluator
                    .compose(window.iter().map(|k| sm.map[k].expect("stage fit computed")));
                let base = base_from_fast(evaluator, &fast);
                (i, genes, base)
            })
            .collect()
    }

    fn cache_hits(&self) -> usize {
        self.memo.as_ref().map_or(0, |m| m.hits)
    }

    fn stage_hits(&self) -> usize {
        self.stage_memo.as_ref().map_or(0, |m| m.hits)
    }

    fn stage_misses(&self) -> usize {
        self.stage_memo.as_ref().map_or(0, |m| m.misses)
    }
}

/// Run the MOGA (Algorithm 1). The chromosome is laid out in the
/// StagePlan's gene order — one slot per conv-like *stage* — so branchy
/// networks (concat/upsample/SPP merges between convs) explore exactly
/// like chains; the bounds come from the scheduled plan via the
/// [`design::Evaluator`]. With [`DseConfig::accuracy_paths`] set, one
/// trailing path-selection gene joins the chromosome and the search runs
/// on three objectives (latency, DSP, accuracy).
pub fn run(net: &Network, device: &Device, cfg: &DseConfig) -> DseResult {
    let evaluator = design::Evaluator::new(net, device).expect("valid network");
    let mut bounds = evaluator.bounds().to_vec();
    assert!(!bounds.is_empty(), "network has no conv stages to map");
    let acc_ctx = cfg.accuracy_paths.as_deref().map(AccCtx::new);
    if let Some(ctx) = &acc_ctx {
        bounds.push(ctx.len());
    }
    let threads = cfg.threads.max(1);
    let t0 = Instant::now();

    let mut res = std::thread::scope(|scope| {
        let (done_tx, done_rx) = mpsc::channel::<Done>();
        let mut job_txs: Vec<mpsc::Sender<Job>> = Vec::with_capacity(threads - 1);
        for _ in 1..threads {
            let (tx, rx) = mpsc::channel::<Job>();
            let done_tx = done_tx.clone();
            let evaluator = &evaluator;
            let rep = cfg.rep;
            let strip = gene_strip(acc_ctx.as_ref());
            scope.spawn(move || {
                // persistent worker: one wake-up per generation, exits
                // when the engine (and with it the job sender) drops.
                // Workers run only pure key→value kernels (chromosome or
                // stage); memo probing and the path/constraint finishing
                // stay on the main thread.
                while let Ok(job) = rx.recv() {
                    let done = match job {
                        Job::Chromosomes(share) => Done::Chromosomes(
                            share
                                .into_iter()
                                .map(|(i, genes)| {
                                    let base = base_eval(
                                        evaluator,
                                        &genes[..genes.len() - strip],
                                        rep,
                                    );
                                    (i, genes, base)
                                })
                                .collect(),
                        ),
                        Job::StageKeys(keys) => Done::StageFits(
                            keys.into_iter()
                                .map(|k| (k, evaluator.stage_fit_packed(k, rep)))
                                .collect(),
                        ),
                    };
                    if done_tx.send(done).is_err() {
                        break;
                    }
                }
            });
            job_txs.push(tx);
        }
        drop(done_tx); // only worker clones remain

        let mut engine = Engine {
            evaluator: &evaluator,
            rep: cfg.rep,
            constraints: cfg.constraints,
            acc: acc_ctx.as_ref(),
            memo: cfg.memo.then(|| Memo { map: FxHashMap::default(), hits: 0 }),
            // the segment cache only makes sense under the chromosome
            // memo (it serves that cache's misses); `--no-memo` disables
            // both, reproducing the uncached baseline
            stage_memo: (cfg.memo && cfg.stage_memo).then(StageMemo::default),
            job_txs,
            done_rx,
            evaluations: 0,
            unique_evaluations: 0,
        };
        ga_loop(&mut engine, &bounds, cfg)
        // engine drops here → job senders close → workers exit → scope joins
    });
    res.wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    res
}

/// The generational loop, single-threaded apart from `Engine::eval_batch`
/// fan-out. All stochastic decisions happen here, in one fixed order.
fn ga_loop(engine: &mut Engine<'_>, bounds: &[usize], cfg: &DseConfig) -> DseResult {
    let mut rng = Rng::new(cfg.seed);

    // ODE_config <- Initialize(l): seed the population with a spread of
    // uniform parallelism levels plus random vectors, so both extremes of
    // the front are reachable from generation 0.
    let mut batch: Vec<Vec<usize>> = Vec::with_capacity(cfg.population);
    for i in 0..cfg.population {
        let genes: Vec<usize> = if i < 8 {
            // ladder of uniform levels 1, 2, 4, 8, ...
            let level = 1usize << i.min(7);
            bounds.iter().map(|&ub| level.min(ub)).collect()
        } else {
            bounds.iter().map(|&ub| rng.range(1, ub as i64) as usize).collect()
        };
        batch.push(genes);
    }
    let mut pop = engine.eval_batch(batch);

    let mut evaluated: Vec<(f64, usize)> =
        pop.iter().map(|c| (c.objectives.latency_ms, c.objectives.dsp)).collect();
    let mut best_latency_per_gen = Vec::with_capacity(cfg.generations);
    // recycled gene buffers: crossover writes into these, discarded
    // candidates donate theirs back — zero steady-state allocation
    let mut spare: Vec<Vec<usize>> = Vec::new();
    let mut soa = nsga2::ObjSoa::default();
    // accuracy joins crowding-distance spread only in 3-objective mode,
    // so 2-objective searches keep their exact pre-accuracy selection;
    // likewise energy joins dominance + crowding only when requested
    soa.accuracy_axis = engine.acc.is_some();
    soa.energy_axis = cfg.energy_objective;
    // mating-selection key: front rank + crowding, computed once per
    // generation (NSGA-II's crowded tournament), built explicitly for
    // generation 0 and thereafter reused from environmental selection
    soa.rebuild(&pop);
    let mut ranking = nsga2::Ranking::build(&soa);

    // roofline pre-filter state (`--prune`): sound gene-dependent lower
    // bounds, built once — the floor and slot facts are gene-independent
    let gene_lb = cfg.prune.then(|| roofline::GeneBounds::new(engine.evaluator, cfg.rep));
    let mut roofline_pruned = 0usize;
    let mut surrogate_reorders = 0usize;
    // evaluations already spent before this generation: the per-gen
    // span's a0 is the delta, so trace readers see the eval budget flow
    let mut evals_before = engine.evaluations;

    for gen in 0..cfg.generations {
        // offspring genes via tournament + crossover + Alg.1 mutation —
        // main thread only, so the RNG stream is thread-count-invariant
        let mut batch: Vec<Vec<usize>> = Vec::with_capacity(cfg.population);
        while batch.len() < cfg.population {
            let a = nsga2::tournament(&ranking, &mut rng);
            let b = nsga2::tournament(&ranking, &mut rng);
            let mut g1 = spare.pop().unwrap_or_default();
            let mut g2 = spare.pop().unwrap_or_default();
            crossover_into(
                &pop[a].config.parallelism,
                &pop[b].config.parallelism,
                cfg.crossover_rate,
                &mut rng,
                &mut g1,
                &mut g2,
            );
            mutate(&mut g1, bounds, cfg, &mut rng);
            mutate(&mut g2, bounds, cfg, &mut rng);
            batch.push(g1);
            if batch.len() < cfg.population {
                batch.push(g2);
            } else {
                spare.push(g2);
            }
        }

        // roofline pre-filter: drop offspring whose sound lower bound is
        // already constraint-violating or dominated by the current
        // feasible front — they can never improve it. The gene buffers
        // go back to the scratch pool.
        if let Some(lb) = &gene_lb {
            let front: Vec<(f64, f64, f64)> = ranking
                .first_front()
                .filter(|&i| pop[i].violation == 0.0)
                .map(|i| {
                    let o = &pop[i].objectives;
                    (o.latency_ms, o.dsp as f64, o.accuracy)
                })
                .collect();
            let strip = gene_strip(engine.acc);
            batch.retain_mut(|genes| {
                let prune = roofline_prunes(
                    lb,
                    genes,
                    strip,
                    engine.acc,
                    &cfg.constraints,
                    cfg.energy_objective,
                    &front,
                );
                if prune {
                    roofline_pruned += 1;
                    let mut g = std::mem::take(genes);
                    g.clear();
                    spare.push(g);
                }
                !prune
            });
        }

        // surrogate ranker: permute only the evaluation *dispatch* order
        // (most promising first); results land back in their original
        // slots, so everything downstream is bit-identical to the
        // unranked run — what it buys is eval-budget front-loading.
        let offspring = if cfg.surrogate && !batch.is_empty() {
            let model = surrogate_fit(&pop);
            let scores: Vec<f64> = batch.iter().map(|g| surrogate_score(&model, g)).collect();
            let mut order: Vec<usize> = (0..batch.len()).collect();
            order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
            surrogate_reorders += order.iter().enumerate().filter(|&(j, &o)| o != j).count();
            let mut taken: Vec<Option<Vec<usize>>> = batch.into_iter().map(Some).collect();
            let permuted: Vec<Vec<usize>> =
                order.iter().map(|&o| taken[o].take().expect("order is a permutation")).collect();
            let evald = engine.eval_batch(permuted);
            let mut out: Vec<Option<Candidate>> = (0..evald.len()).map(|_| None).collect();
            for (j, c) in evald.into_iter().enumerate() {
                out[order[j]] = Some(c);
            }
            out.into_iter().map(|c| c.expect("every slot restored")).collect()
        } else {
            engine.eval_batch(batch)
        };
        evaluated
            .extend(offspring.iter().map(|c| (c.objectives.latency_ms, c.objectives.dsp)));

        // elitist (mu + lambda) environmental selection, index-based;
        // the survivors' (rank, crowding) double as the next
        // generation's tournament key
        pop.extend(offspring);
        soa.rebuild(&pop);
        let (keep, next_ranking) = nsga2::select_ranked(&soa, cfg.population);
        pop = compact(pop, &keep, &mut spare);
        ranking = next_ranking;

        let best = pop
            .iter()
            .filter(|c| c.violation == 0.0)
            .map(|c| c.objectives.latency_ms)
            .fold(f64::INFINITY, f64::min);
        best_latency_per_gen.push(best);

        // per-generation telemetry: one virtual-clock span (1 ms per
        // generation on the search's logical timeline) plus cumulative
        // engine counters. All values are main-thread state that is
        // already invariant across `cfg.threads`.
        if let Some(sink) = &cfg.trace {
            use crate::obs::{Clock, Name, TraceEntry};
            let ts = gen as u64 * 1_000;
            let evals = (engine.evaluations - evals_before) as u64;
            evals_before = engine.evaluations;
            let best_us = if best.is_finite() {
                (best * 1_000.0).round() as u64
            } else {
                0
            };
            let span = TraceEntry::span(Clock::Virtual, Name::DseGeneration, ts, 1_000, gen as u64)
                .with_args(evals, best_us);
            sink.record(0, span);
            let counters = [
                (Name::CacheHits, engine.cache_hits() as u64),
                (Name::StageHits, engine.stage_hits() as u64),
                (Name::RooflinePruned, roofline_pruned as u64),
                (Name::SurrogateReorders, surrogate_reorders as u64),
            ];
            for (name, value) in counters {
                sink.record(0, TraceEntry::counter(Clock::Virtual, name, ts, value));
            }
        }
    }

    // final front: feasible, non-dominated, deduped by chromosome
    let feasible: Vec<Candidate> =
        pop.into_iter().filter(|c| c.violation == 0.0).collect();
    soa.rebuild(&feasible);
    let first: Vec<usize> =
        nsga2::sort_fronts_soa(&soa).into_iter().next().unwrap_or_default();
    let mut pareto = {
        let mut taken = vec![false; feasible.len()];
        for &i in &first {
            taken[i] = true;
        }
        feasible
            .into_iter()
            .enumerate()
            .filter_map(|(i, c)| taken[i].then_some(c))
            .collect::<Vec<Candidate>>()
    };
    pareto.sort_by(|a, b| {
        a.objectives
            .latency_ms
            .partial_cmp(&b.objectives.latency_ms)
            .unwrap()
            .then(a.objectives.dsp.cmp(&b.objectives.dsp))
            .then(b.objectives.accuracy.partial_cmp(&a.objectives.accuracy).unwrap())
    });
    pareto.dedup_by(|a, b| a.config.parallelism == b.config.parallelism);

    DseResult {
        pareto,
        evaluated,
        best_latency_per_gen,
        evaluations: engine.evaluations,
        unique_evaluations: engine.unique_evaluations,
        cache_hits: engine.cache_hits(),
        stage_hits: engine.stage_hits(),
        stage_misses: engine.stage_misses(),
        roofline_pruned,
        surrogate_reorders,
        wall_ms: 0.0, // stamped by `run`
    }
}

/// `--prune` decision for one offspring: true iff the roofline lower
/// bound alone already proves the candidate violates a hard latency/DSP
/// constraint, or that a current feasible front member Pareto-dominates
/// it. Sound by [`roofline::GeneBounds`]'s bound direction: the true
/// latency/DSP only sit *above* the bound, so a point dominating the
/// bound dominates the truth (accuracy is exact — it depends only on
/// the path gene). With the energy axis on there is no sound energy
/// lower bound, so only the constraint rule applies.
fn roofline_prunes(
    lb: &roofline::GeneBounds,
    genes: &[usize],
    strip: usize,
    acc: Option<&AccCtx>,
    constraints: &Constraints,
    energy_objective: bool,
    front: &[(f64, f64, f64)],
) -> bool {
    let conv = &genes[..genes.len() - strip];
    let mut lat_lb = lb.latency_ms_lb(conv);
    let mut acc_cand = 1.0;
    if let Some(ctx) = acc {
        let pi = genes[genes.len() - 1] - 1; // path gene is 1-based
        lat_lb *= ctx.ratios[pi];
        acc_cand = ctx.accs[pi];
    }
    let dsp_lb = lb.dsp_lb(conv);
    if let Some(t) = constraints.latency_ms {
        if lat_lb > t {
            return true;
        }
    }
    if let Some(d) = constraints.dsp {
        if dsp_lb > d {
            return true;
        }
    }
    if energy_objective {
        return false;
    }
    let dsp_lb = dsp_lb as f64;
    front.iter().any(|&(l, d, a)| {
        l <= lat_lb
            && d <= dsp_lb
            && a >= acc_cand
            && (l < lat_lb || d < dsp_lb || a > acc_cand)
    })
}

/// Fit the surrogate: per-gene univariate least-squares slopes against
/// `latency + big·violation` over the current population — deterministic
/// (no RNG, fixed iteration order) and O(pop × genes).
fn surrogate_fit(pop: &[Candidate]) -> Vec<(f64, f64)> {
    let n = pop.len() as f64;
    let dim = pop[0].config.parallelism.len();
    let ys: Vec<f64> =
        pop.iter().map(|c| c.objectives.latency_ms + 1e6 * c.violation).collect();
    let y_mean = ys.iter().sum::<f64>() / n;
    let mut model = Vec::with_capacity(dim);
    for i in 0..dim {
        let x_mean = pop.iter().map(|c| c.config.parallelism[i] as f64).sum::<f64>() / n;
        let mut cov = 0.0;
        let mut var = 0.0;
        for (c, y) in pop.iter().zip(&ys) {
            let dx = c.config.parallelism[i] as f64 - x_mean;
            cov += dx * (y - y_mean);
            var += dx * dx;
        }
        model.push((x_mean, if var > 0.0 { cov / var } else { 0.0 }));
    }
    model
}

/// Predicted relative objective of a chromosome under the fitted model
/// (lower = more promising; only the ordering matters).
fn surrogate_score(model: &[(f64, f64)], genes: &[usize]) -> f64 {
    model.iter().zip(genes).map(|(&(m, w), &g)| w * (g as f64 - m)).sum()
}

/// Keep exactly `keep`, in `keep` order (so positions stay aligned with
/// the [`nsga2::Ranking`] that [`nsga2::select_ranked`] returned), and
/// recycle the discarded candidates' gene buffers into `spare`.
fn compact(pop: Vec<Candidate>, keep: &[usize], spare: &mut Vec<Vec<usize>>) -> Vec<Candidate> {
    let mut slots: Vec<Option<Candidate>> = pop.into_iter().map(Some).collect();
    let mut out = Vec::with_capacity(keep.len());
    for &i in keep {
        out.push(slots[i].take().expect("selection indices are unique"));
    }
    for dropped in slots.into_iter().flatten() {
        let mut genes = dropped.config.parallelism;
        genes.clear();
        spare.push(genes);
    }
    out
}

/// Uniform crossover on the parallelism vector, written into caller
/// scratch buffers (no per-offspring allocation).
fn crossover_into(
    a: &[usize],
    b: &[usize],
    rate: f64,
    rng: &mut Rng,
    g1: &mut Vec<usize>,
    g2: &mut Vec<usize>,
) {
    g1.clear();
    g2.clear();
    if !rng.chance(rate) {
        g1.extend_from_slice(a);
        g2.extend_from_slice(b);
        return;
    }
    for i in 0..a.len() {
        if rng.chance(0.5) {
            g1.push(a[i]);
            g2.push(b[i]);
        } else {
            g1.push(b[i]);
            g2.push(a[i]);
        }
    }
}

/// Algorithm 1 mutation: step toward a bound scaled by a power-distributed
/// random `s`:
/// `x <- x - s*(x - lb)` if `t < r` else `x <- x + s*(ub - x)`.
fn mutate(genes: &mut [usize], bounds: &[usize], cfg: &DseConfig, rng: &mut Rng) {
    for (i, g) in genes.iter_mut().enumerate() {
        if !rng.chance(cfg.mutation_rate) {
            continue;
        }
        let lb = 1.0;
        let ub = bounds[i] as f64;
        let x = *g as f64;
        let s = rng.power(cfg.mutation_power);
        // t: scaled distance from the lower bound; r ~ U(0,1)
        let t = if ub > lb { (x - lb) / (ub - lb) } else { 0.0 };
        let r = rng.f64();
        let nx = if t < r { x - s * (x - lb) } else { x + s * (ub - x) };
        *g = (nx.round() as i64).clamp(1, bounds[i] as i64) as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::pe::ZYNQ_7100;

    fn quick_cfg() -> DseConfig {
        DseConfig { population: 32, generations: 12, seed: 42, ..DseConfig::default() }
    }

    /// Bitwise identity key of a Pareto front.
    fn fingerprint(res: &DseResult) -> Vec<(Vec<usize>, u64, usize)> {
        res.pareto
            .iter()
            .map(|c| {
                (c.config.parallelism.clone(), c.objectives.latency_ms.to_bits(), c.objectives.dsp)
            })
            .collect()
    }

    #[test]
    fn finds_nontrivial_front_on_mnist() {
        let net = zoo::mnist();
        let res = run(&net, &ZYNQ_7100, &quick_cfg());
        assert!(res.pareto.len() >= 4, "front size {}", res.pareto.len());
        // front must span a real latency range (paper: orders of magnitude)
        let lo = res.pareto.first().unwrap().objectives.latency_ms;
        let hi = res.pareto.last().unwrap().objectives.latency_ms;
        assert!(hi / lo > 10.0, "span {}", hi / lo);
    }

    #[test]
    fn front_is_mutually_non_dominated() {
        let net = zoo::mnist();
        let res = run(&net, &ZYNQ_7100, &quick_cfg());
        for a in &res.pareto {
            for b in &res.pareto {
                assert!(
                    !a.objectives.dominates(&b.objectives)
                        || a.config.parallelism == b.config.parallelism,
                    "{:?} dominates {:?}",
                    a.objectives,
                    b.objectives
                );
            }
        }
    }

    #[test]
    fn constraints_respected() {
        let net = zoo::mnist();
        let mut cfg = quick_cfg();
        cfg.constraints = Constraints {
            latency_ms: Some(1.0),
            dsp: Some(600),
            lut: None,
            bram: None,
            power_mw: None,
        };
        let res = run(&net, &ZYNQ_7100, &cfg);
        assert!(!res.pareto.is_empty());
        for c in &res.pareto {
            assert!(c.objectives.latency_ms <= 1.0, "{:?}", c.objectives);
            assert!(c.objectives.dsp <= 600, "{:?}", c.objectives);
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let net = zoo::mnist();
        let a = run(&net, &ZYNQ_7100, &quick_cfg());
        let b = run(&net, &ZYNQ_7100, &quick_cfg());
        assert_eq!(fingerprint(&a), fingerprint(&b));
    }

    #[test]
    fn thread_count_does_not_change_results() {
        // mnist (3 genes) and mobilenet_v2 (52 genes): 1-thread vs
        // 4-thread runs must be bit-identical in every output field
        for net in [zoo::mnist(), zoo::mobilenet_v2()] {
            let mk = |threads: usize| DseConfig {
                population: 24,
                generations: 6,
                seed: 9,
                threads,
                constraints: Constraints::device(&ZYNQ_7100),
                ..DseConfig::default()
            };
            let serial = run(&net, &ZYNQ_7100, &mk(1));
            let parallel = run(&net, &ZYNQ_7100, &mk(4));
            assert_eq!(fingerprint(&serial), fingerprint(&parallel), "{}", net.name);
            assert_eq!(serial.evaluated, parallel.evaluated, "{}", net.name);
            assert_eq!(
                serial.best_latency_per_gen, parallel.best_latency_per_gen,
                "{}",
                net.name
            );
            assert_eq!(serial.evaluations, parallel.evaluations);
            assert_eq!(serial.unique_evaluations, parallel.unique_evaluations);
            assert_eq!(serial.cache_hits, parallel.cache_hits);
            // stage telemetry is probed on the main thread in batch
            // order, so it must be thread-count-invariant too
            assert_eq!(serial.stage_hits, parallel.stage_hits);
            assert_eq!(serial.stage_misses, parallel.stage_misses);
        }
    }

    #[test]
    fn generation_trace_is_thread_count_invariant() {
        use crate::obs::{Kind, Name, TraceSink};
        let net = zoo::mnist();
        let mk = |threads: usize| DseConfig {
            population: 24,
            generations: 6,
            seed: 9,
            threads,
            constraints: Constraints::device(&ZYNQ_7100),
            trace: Some(TraceSink::shared()),
            ..DseConfig::default()
        };
        let (c1, c4) = (mk(1), mk(4));
        run(&net, &ZYNQ_7100, &c1);
        run(&net, &ZYNQ_7100, &c4);
        let (t1, t4) = (c1.trace.unwrap().drain(), c4.trace.unwrap().drain());
        assert_eq!(t1.entries, t4.entries, "trace must not depend on thread count");
        assert_eq!(t1.dropped, 0);
        // one generation span + four cumulative counters per generation
        let spans: Vec<_> = t1.entries.iter().filter(|e| e.kind == Kind::Span).collect();
        assert_eq!(spans.len(), 6);
        assert!(spans.iter().all(|e| e.name == Name::DseGeneration));
        assert!(spans.iter().enumerate().all(|(g, e)| e.ts_us == g as u64 * 1_000));
        assert_eq!(t1.entries.iter().filter(|e| e.kind == Kind::Counter).count(), 24);
        // the last span's a1 carries the generation's best feasible
        // latency in whole microseconds — nonzero on mnist
        assert!(spans.last().unwrap().a1 > 0);
    }

    #[test]
    fn memo_cache_is_transparent_and_hits() {
        let net = zoo::mnist();
        let on = run(&net, &ZYNQ_7100, &quick_cfg());
        let off = run(&net, &ZYNQ_7100, &DseConfig { memo: false, ..quick_cfg() });
        // bit-identical results with and without the cache
        assert_eq!(fingerprint(&on), fingerprint(&off));
        assert_eq!(on.evaluated, off.evaluated);
        assert_eq!(on.evaluations, off.evaluations);
        // the GA population really is duplicated: the cache must fire
        assert!(on.cache_hits > 0, "expected cache hits on mnist");
        assert_eq!(on.unique_evaluations + on.cache_hits, on.evaluations);
        assert_eq!(off.cache_hits, 0);
        assert_eq!(off.unique_evaluations, off.evaluations);
        assert!(on.cache_hit_rate() > 0.0 && on.cache_hit_rate() < 1.0);
    }

    #[test]
    fn stage_cache_is_transparent_and_hits() {
        // the segment-level primary cache must not change anything the
        // chromosome-memo engine produced — only serve its misses faster
        let net = zoo::mobilenet_v2();
        let mk = |stage_memo: bool| DseConfig {
            population: 24,
            generations: 6,
            seed: 9,
            stage_memo,
            constraints: Constraints::device(&ZYNQ_7100),
            ..DseConfig::default()
        };
        let on = run(&net, &ZYNQ_7100, &mk(true));
        let off = run(&net, &ZYNQ_7100, &mk(false));
        assert_eq!(fingerprint(&on), fingerprint(&off));
        assert_eq!(on.evaluated, off.evaluated);
        assert_eq!(on.best_latency_per_gen, off.best_latency_per_gen);
        assert_eq!(on.evaluations, off.evaluations);
        assert_eq!(on.unique_evaluations, off.unique_evaluations);
        assert_eq!(on.cache_hits, off.cache_hits);
        // mutation neighbors share most stage keys with their parents
        assert!(on.stage_hits > 0, "stage cache never fired");
        let n_stages = design::Evaluator::new(&net, &ZYNQ_7100).unwrap().n_stages();
        assert_eq!(on.stage_hits + on.stage_misses, on.unique_evaluations * n_stages);
        assert!(on.stage_hit_rate() > 0.2, "rate {}", on.stage_hit_rate());
        assert_eq!(off.stage_hits, 0);
        assert_eq!(off.stage_misses, 0);
        assert_eq!(off.stage_hit_rate(), 0.0);
    }

    #[test]
    fn in_batch_duplicates_evaluate_once() {
        // regression for the `insert(key, None)` pending sentinel: a
        // batch made entirely of duplicates of one unseen chromosome
        // must run the kernel exactly once — with and without the stage
        // cache underneath the chromosome memo
        let net = zoo::mnist();
        let evaluator = design::Evaluator::new(&net, &ZYNQ_7100).unwrap();
        for stage_memo in [false, true] {
            let (_job_tx, done_rx) = mpsc::channel::<Done>();
            let mut engine = Engine {
                evaluator: &evaluator,
                rep: FpRep::Int16,
                constraints: Constraints::none(),
                acc: None,
                memo: Some(Memo { map: FxHashMap::default(), hits: 0 }),
                stage_memo: stage_memo.then(StageMemo::default),
                job_txs: Vec::new(),
                done_rx,
                evaluations: 0,
                unique_evaluations: 0,
            };
            let genes = vec![1usize; evaluator.bounds().len()];
            let batch: Vec<Vec<usize>> = (0..8).map(|_| genes.clone()).collect();
            let out = engine.eval_batch(batch);
            assert_eq!(out.len(), 8);
            assert_eq!(engine.unique_evaluations, 1, "stage_memo={stage_memo}");
            assert_eq!(engine.cache_hits(), 7, "stage_memo={stage_memo}");
            assert!(out.iter().all(|c| c.objectives == out[0].objectives));
            if stage_memo {
                // one composition: every stage key missed exactly once
                assert_eq!(engine.stage_misses(), evaluator.n_stages());
                assert_eq!(engine.stage_hits(), 0);
            }
        }
    }

    #[test]
    fn surrogate_reorders_but_never_changes_results() {
        let net = zoo::mnist();
        let mk = |surrogate: bool| DseConfig {
            population: 24,
            generations: 6,
            seed: 9,
            surrogate,
            constraints: Constraints::device(&ZYNQ_7100),
            ..DseConfig::default()
        };
        let base = run(&net, &ZYNQ_7100, &mk(false));
        let sur = run(&net, &ZYNQ_7100, &mk(true));
        assert_eq!(fingerprint(&base), fingerprint(&sur));
        assert_eq!(base.evaluated, sur.evaluated);
        assert_eq!(base.best_latency_per_gen, sur.best_latency_per_gen);
        assert_eq!(base.evaluations, sur.evaluations);
        assert_eq!(base.unique_evaluations, sur.unique_evaluations);
        assert_eq!(base.cache_hits, sur.cache_hits);
        assert_eq!(base.stage_hits, sur.stage_hits);
        assert_eq!(base.stage_misses, sur.stage_misses);
        assert_eq!(base.surrogate_reorders, 0);
        assert!(sur.surrogate_reorders > 0, "ranker never moved a candidate");
    }

    #[test]
    fn prune_skips_hopeless_offspring_and_keeps_front_feasible() {
        // a latency cap below the gene-independent floor makes every
        // offspring provably infeasible: the pre-filter must skip all of
        // them (gen 0 seeds are always evaluated) and count the skips
        let net = zoo::mnist();
        let cfg = DseConfig {
            population: 24,
            generations: 8,
            seed: 5,
            prune: true,
            constraints: Constraints { latency_ms: Some(1e-9), ..Constraints::none() },
            ..DseConfig::default()
        };
        let res = run(&net, &ZYNQ_7100, &cfg);
        assert!(res.roofline_pruned > 0);
        assert_eq!(res.evaluations + res.roofline_pruned, 24 * 9);
        assert!(res.pareto.is_empty(), "nothing can meet a 1ps latency cap");

        // and with achievable constraints, pruning never admits an
        // infeasible point into the front
        let cfg = DseConfig {
            population: 24,
            generations: 8,
            seed: 5,
            prune: true,
            constraints: Constraints::device(&ZYNQ_7100),
            ..DseConfig::default()
        };
        let res = run(&net, &ZYNQ_7100, &cfg);
        assert_eq!(res.evaluations + res.roofline_pruned, 24 * 9);
        assert!(!res.pareto.is_empty());
        assert!(res.pareto.iter().all(|c| c.violation == 0.0));
    }

    #[test]
    fn telemetry_counts_consistent() {
        let net = zoo::cifar10();
        let cfg = DseConfig { threads: 2, ..quick_cfg() };
        let res = run(&net, &ZYNQ_7100, &cfg);
        let expected = cfg.population * (cfg.generations + 1);
        assert_eq!(res.evaluations, expected);
        assert_eq!(res.evaluated.len(), expected);
        assert_eq!(res.unique_evaluations + res.cache_hits, res.evaluations);
        assert!(res.wall_ms > 0.0);
    }

    #[test]
    fn convergence_monotone_enough() {
        let net = zoo::cifar10();
        let res = run(&net, &ZYNQ_7100, &quick_cfg());
        let first = res.best_latency_per_gen.first().copied().unwrap();
        let last = res.best_latency_per_gen.last().copied().unwrap();
        assert!(last <= first, "search regressed: {first} -> {last}");
    }

    fn obj(latency_ms: f64, dsp: usize) -> Objectives {
        Objectives {
            latency_ms,
            dsp,
            lut: 0,
            bram: 0,
            total_pes: 0,
            accuracy: 1.0,
            power_mw: 0.0,
            energy_mj: 0.0,
        }
    }

    #[test]
    fn dominance_definition() {
        let a = obj(1.0, 100);
        let b = obj(2.0, 200);
        let c = obj(0.5, 300);
        assert!(a.dominates(&b));
        assert!(!b.dominates(&a));
        assert!(!a.dominates(&c) && !c.dominates(&a));
        assert!(!a.dominates(&a));
        // third axis: equal (latency, dsp) resolves on accuracy alone
        let hi = Objectives { accuracy: 0.9, ..a };
        let lo = Objectives { accuracy: 0.6, ..a };
        assert!(hi.dominates(&lo) && !lo.dominates(&hi));
    }

    #[test]
    fn violation_math() {
        let cons = Constraints {
            latency_ms: Some(1.0),
            dsp: Some(100),
            lut: None,
            bram: None,
            power_mw: None,
        };
        let ok = obj(0.9, 100);
        let bad = obj(2.0, 150);
        assert_eq!(cons.violation(&ok), 0.0);
        assert!((cons.violation(&bad) - 1.5).abs() < 1e-9);
        // power overruns penalize exactly like resource overruns
        let cons = Constraints { power_mw: Some(500.0), ..Constraints::none() };
        let hot = Objectives { power_mw: 750.0, ..obj(1.0, 10) };
        let cool = Objectives { power_mw: 500.0, ..obj(1.0, 10) };
        assert!((cons.violation(&hot) - 0.5).abs() < 1e-9);
        assert_eq!(cons.violation(&cool), 0.0);
    }

    #[test]
    fn three_objective_front_spans_accuracy() {
        // accuracy ladder from the morph layer: the search must surface
        // trade-offs across execution paths, with every accuracy value
        // drawn verbatim from the ladder
        let net = zoo::mnist();
        let paths = crate::morph::depth_ladder(&net);
        let ladder_accs: Vec<f64> = paths.iter().map(|p| p.accuracy).collect();
        let n_paths = paths.len();
        let cfg = DseConfig { accuracy_paths: Some(paths), ..quick_cfg() };
        let res = run(&net, &ZYNQ_7100, &cfg);
        assert!(!res.pareto.is_empty());
        let mut seen = std::collections::BTreeSet::new();
        for c in &res.pareto {
            assert!(
                ladder_accs.iter().any(|&a| a == c.objectives.accuracy),
                "accuracy {} not from the ladder",
                c.objectives.accuracy
            );
            seen.insert(c.objectives.accuracy.to_bits());
            // chromosome carries the trailing path gene
            let &pg = c.config.parallelism.last().unwrap();
            assert!((1..=n_paths).contains(&pg), "path gene {pg}");
        }
        assert!(seen.len() >= 2, "front collapsed to one accuracy level");
        // mutual non-dominance in 3-D
        for a in &res.pareto {
            for b in &res.pareto {
                assert!(
                    !a.objectives.dominates(&b.objectives)
                        || a.config.parallelism == b.config.parallelism
                );
            }
        }
    }

    #[test]
    fn three_objective_thread_invariance_and_determinism() {
        let net = zoo::mnist();
        let mk = |threads: usize| DseConfig {
            population: 24,
            generations: 6,
            seed: 9,
            threads,
            accuracy_paths: Some(crate::morph::depth_ladder(&net)),
            constraints: Constraints::device(&ZYNQ_7100),
            ..DseConfig::default()
        };
        let serial = run(&net, &ZYNQ_7100, &mk(1));
        let parallel = run(&net, &ZYNQ_7100, &mk(4));
        assert_eq!(fingerprint(&serial), fingerprint(&parallel));
        assert_eq!(serial.evaluated, parallel.evaluated);
        let acc = |r: &DseResult| -> Vec<u64> {
            r.pareto.iter().map(|c| c.objectives.accuracy.to_bits()).collect()
        };
        assert_eq!(acc(&serial), acc(&parallel));
    }

    #[test]
    fn three_objective_memo_shares_conv_evaluations() {
        // the memo keys on conv genes only: candidates differing in just
        // the path gene share one analytical evaluation, transparently
        let net = zoo::mnist();
        let paths = crate::morph::depth_ladder(&net);
        let mk = |memo: bool| DseConfig {
            population: 24,
            generations: 6,
            seed: 5,
            memo,
            accuracy_paths: Some(paths.clone()),
            ..DseConfig::default()
        };
        let on = run(&net, &ZYNQ_7100, &mk(true));
        let off = run(&net, &ZYNQ_7100, &mk(false));
        assert_eq!(fingerprint(&on), fingerprint(&off));
        assert_eq!(on.evaluated, off.evaluated);
        assert!(on.cache_hits > 0, "conv-keyed cache must fire");
        assert_eq!(on.unique_evaluations + on.cache_hits, on.evaluations);
    }

    #[test]
    fn no_ladder_reproduces_two_objective_search() {
        // accuracy_paths: None must leave the classic search untouched:
        // same chromosome length, every accuracy pinned at the 1.0
        // constant
        let net = zoo::mnist();
        let res = run(&net, &ZYNQ_7100, &quick_cfg());
        let n_genes = design::Evaluator::new(&net, &ZYNQ_7100).unwrap().bounds().len();
        for c in &res.pareto {
            assert_eq!(c.config.parallelism.len(), n_genes);
            assert_eq!(c.objectives.accuracy, 1.0);
        }
    }

    #[test]
    fn power_budget_constrains_front() {
        // every surviving candidate respects --power-budget, and the
        // telemetry fields are physically consistent
        let net = zoo::mnist();
        let mut cfg = quick_cfg();
        cfg.constraints = Constraints { power_mw: Some(520.0), ..Constraints::none() };
        let res = run(&net, &ZYNQ_7100, &cfg);
        assert!(!res.pareto.is_empty(), "520 mW admits small designs");
        for c in &res.pareto {
            assert!(c.objectives.power_mw <= 520.0, "{:?}", c.objectives);
            assert!(c.objectives.power_mw > 0.0);
            let want = c.objectives.power_mw * c.objectives.latency_ms / 1000.0;
            assert!((c.objectives.energy_mj - want).abs() < 1e-9);
        }
        // the cap really binds: the unconstrained front reaches hotter designs
        let free = run(&net, &ZYNQ_7100, &quick_cfg());
        let hottest = free
            .pareto
            .iter()
            .map(|c| c.objectives.power_mw)
            .fold(0.0f64, f64::max);
        assert!(hottest > 520.0, "unconstrained hottest {hottest}");
    }

    #[test]
    fn power_telemetry_does_not_change_selection() {
        // energy_objective=false (the default): the front must be
        // identical whether or not a (non-binding) power budget merely
        // reads the new fields — i.e. power is telemetry, not a hidden
        // objective
        let net = zoo::mnist();
        let base = run(&net, &ZYNQ_7100, &quick_cfg());
        let mut cfg = quick_cfg();
        cfg.constraints = Constraints { power_mw: Some(1e9), ..Constraints::none() };
        let loose = run(&net, &ZYNQ_7100, &cfg);
        assert_eq!(fingerprint(&base), fingerprint(&loose));
        assert_eq!(base.evaluated, loose.evaluated);
    }

    #[test]
    fn energy_objective_spans_energy_axis() {
        let net = zoo::mnist();
        let mut cfg = quick_cfg();
        cfg.energy_objective = true;
        let res = run(&net, &ZYNQ_7100, &cfg);
        assert!(!res.pareto.is_empty());
        // the 3rd axis surfaces energy trade-offs: front members must not
        // all collapse to one energy value
        let energies: std::collections::BTreeSet<u64> =
            res.pareto.iter().map(|c| c.objectives.energy_mj.to_bits()).collect();
        assert!(energies.len() >= 2, "front collapsed to one energy level");
        // mutual non-dominance under the energy-aware kernel
        let mut soa = nsga2::ObjSoa::from_candidates(&res.pareto);
        soa.energy_axis = true;
        let fronts = nsga2::sort_fronts_soa(&soa);
        assert_eq!(fronts[0].len(), res.pareto.len(), "dominated member on the front");
    }

    #[test]
    fn energy_objective_thread_invariant() {
        let net = zoo::mnist();
        let mk = |threads: usize| DseConfig {
            population: 24,
            generations: 6,
            seed: 9,
            threads,
            energy_objective: true,
            constraints: Constraints::device(&ZYNQ_7100),
            ..DseConfig::default()
        };
        let serial = run(&net, &ZYNQ_7100, &mk(1));
        let parallel = run(&net, &ZYNQ_7100, &mk(4));
        assert_eq!(fingerprint(&serial), fingerprint(&parallel));
        let e = |r: &DseResult| -> Vec<u64> {
            r.pareto.iter().map(|c| c.objectives.energy_mj.to_bits()).collect()
        };
        assert_eq!(e(&serial), e(&parallel));
    }

    #[test]
    fn three_objective_power_scales_with_path() {
        // on a 3-objective search the candidate's power follows its
        // execution path: lighter paths must never model hotter than the
        // full path on the same conv genes
        let net = zoo::mnist();
        let paths = crate::morph::depth_ladder(&net);
        let cfg = DseConfig { accuracy_paths: Some(paths.clone()), ..quick_cfg() };
        let res = run(&net, &ZYNQ_7100, &cfg);
        let full_macs = paths.iter().map(|p| p.macs).max().unwrap();
        for c in &res.pareto {
            let &pg = c.config.parallelism.last().unwrap();
            let ratio = paths[pg - 1].macs as f64 / full_macs as f64;
            assert!(c.objectives.power_mw > 0.0);
            if ratio < 1.0 {
                // a gated path draws less than the same fabric fully active
                let full_equiv = {
                    let conv = &c.config.parallelism[..c.config.parallelism.len() - 1];
                    let ev = design::Evaluator::new(&net, &ZYNQ_7100).unwrap();
                    let fast = ev.objectives(conv, cfg.rep).unwrap();
                    crate::power::PowerModel::default().total_mw(
                        &fast.resources,
                        ev.clock_mhz(),
                        crate::power::Activity::default(),
                    )
                };
                assert!(
                    c.objectives.power_mw < full_equiv,
                    "path ratio {ratio}: {} !< {full_equiv}",
                    c.objectives.power_mw
                );
            }
        }
    }

    #[test]
    fn mutation_respects_bounds() {
        let bounds = vec![8, 16, 32];
        let cfg = DseConfig { mutation_rate: 1.0, ..DseConfig::default() };
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let mut genes = vec![4, 9, 20];
            mutate(&mut genes, &bounds, &cfg, &mut rng);
            for (g, ub) in genes.iter().zip(&bounds) {
                assert!(*g >= 1 && g <= ub, "gene {g} bound {ub}");
            }
        }
    }

    #[test]
    fn crossover_into_reuses_buffers() {
        let mut rng = Rng::new(4);
        let a = vec![1usize, 2, 3, 4];
        let b = vec![4usize, 3, 2, 1];
        let mut g1 = vec![99usize; 10]; // stale content must be cleared
        let mut g2 = Vec::new();
        crossover_into(&a, &b, 1.0, &mut rng, &mut g1, &mut g2);
        assert_eq!(g1.len(), 4);
        assert_eq!(g2.len(), 4);
        for i in 0..4 {
            // each position holds (a[i], b[i]) in some order
            let pair = [g1[i], g2[i]];
            assert!(pair.contains(&a[i]) && pair.contains(&b[i]), "pos {i}: {pair:?}");
        }
        // rate 0 ⇒ verbatim copies
        crossover_into(&a, &b, 0.0, &mut rng, &mut g1, &mut g2);
        assert_eq!(g1, a);
        assert_eq!(g2, b);
    }
}
