//! NeuroForge design space exploration — Sec. III-C, Algorithm 1.
//!
//! DSE is posed as multi-objective optimization: minimize inference
//! latency and resource utilization simultaneously, under user-defined
//! constraints `[t, DSP, LUT, BRAM]`. The decision vector is the
//! per-conv-layer parallelism `p(i)` with `1 <= p(i) <= ub(i)`; Eq. 14
//! expands it to PE allocations `L(i) = p(i) * p(i-1)`.
//!
//! The optimizer is an NSGA-II-style MOGA: fast non-dominated sorting,
//! crowding distance, binary tournament selection, uniform crossover and
//! Algorithm 1's bounded power-distribution mutation. Evaluation uses the
//! analytical models only (microseconds per candidate — no synthesis in
//! the loop), which is the paper's core speed claim over DNNBuilder-style
//! flows.

pub mod nsga2;
pub mod roofline;

use crate::design::{self, DesignConfig};
use crate::graph::Network;
use crate::pe::{Device, FpRep};
use crate::util::rng::Rng;

/// User constraints (Algorithm 1's `constraints [t, DSP, LUT, BRAM]`).
#[derive(Debug, Clone, Copy)]
pub struct Constraints {
    /// max latency, ms (None = unconstrained)
    pub latency_ms: Option<f64>,
    pub dsp: Option<usize>,
    pub lut: Option<usize>,
    pub bram: Option<usize>,
}

impl Constraints {
    pub fn none() -> Constraints {
        Constraints { latency_ms: None, dsp: None, lut: None, bram: None }
    }

    /// Constrain to a device's full budget.
    pub fn device(dev: &Device) -> Constraints {
        Constraints {
            latency_ms: None,
            dsp: Some(dev.budget.dsp),
            lut: Some(dev.budget.lut),
            bram: Some(dev.budget.bram),
        }
    }

    /// Total constraint violation (0 = feasible); used for
    /// feasibility-first dominance.
    pub fn violation(&self, obj: &Objectives) -> f64 {
        let mut v = 0.0;
        if let Some(t) = self.latency_ms {
            v += ((obj.latency_ms - t) / t).max(0.0);
        }
        if let Some(d) = self.dsp {
            v += ((obj.dsp as f64 - d as f64) / d as f64).max(0.0);
        }
        if let Some(l) = self.lut {
            v += ((obj.lut as f64 - l as f64) / l as f64).max(0.0);
        }
        if let Some(b) = self.bram {
            v += ((obj.bram as f64 - b as f64) / b as f64).max(0.0);
        }
        v
    }
}

/// Objective vector `Y = {Y_t, Y_DSP, Y_LUT, Y_BRAM}` (Alg. 1 output).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objectives {
    pub latency_ms: f64,
    pub dsp: usize,
    pub lut: usize,
    pub bram: usize,
    /// "Design PEs" (Table III indicator column)
    pub total_pes: usize,
}

impl Objectives {
    /// Pareto dominance on the optimized pair (latency, DSP) — the paper
    /// optimizes DSP against latency and constraint-checks the rest.
    pub fn dominates(&self, other: &Objectives) -> bool {
        let no_worse = self.latency_ms <= other.latency_ms && self.dsp <= other.dsp;
        let better = self.latency_ms < other.latency_ms || self.dsp < other.dsp;
        no_worse && better
    }
}

/// One evaluated individual.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub config: DesignConfig,
    pub objectives: Objectives,
    pub violation: f64,
}

/// DSE hyperparameters.
#[derive(Debug, Clone)]
pub struct DseConfig {
    pub population: usize,
    pub generations: usize,
    pub crossover_rate: f64,
    pub mutation_rate: f64,
    /// power-distribution exponent for mutation step sizes (Alg. 1)
    pub mutation_power: f64,
    pub rep: FpRep,
    pub constraints: Constraints,
    pub seed: u64,
}

impl Default for DseConfig {
    fn default() -> Self {
        DseConfig {
            population: 96,
            generations: 60,
            crossover_rate: 0.9,
            mutation_rate: 0.25,
            mutation_power: 3.0,
            rep: FpRep::Int16,
            constraints: Constraints::none(),
            seed: 0,
        }
    }
}

/// DSE outcome: the non-dominated feasible set plus search telemetry.
#[derive(Debug, Clone)]
pub struct DseResult {
    /// Pareto-optimal feasible candidates, sorted by latency ascending
    pub pareto: Vec<Candidate>,
    /// every evaluated (latency, dsp) pair — the Fig. 2 scatter
    pub evaluated: Vec<(f64, usize)>,
    /// per-generation best latency (convergence telemetry)
    pub best_latency_per_gen: Vec<f64>,
    pub evaluations: usize,
}

/// Evaluate one chromosome into a Candidate (one-shot convenience; the
/// MOGA loop uses the allocation-free [`design::Evaluator`] fast path).
pub fn evaluate_candidate(
    net: &Network,
    parallelism: Vec<usize>,
    rep: FpRep,
    device: &Device,
    constraints: &Constraints,
) -> Candidate {
    let evaluator = design::Evaluator::new(net, device).expect("valid network");
    evaluate_with(&evaluator, parallelism, rep, constraints)
}

/// Fitness via a prebuilt evaluator — the DSE inner-loop fast path
/// (§Perf: ~5x over rebuilding shape inference per candidate).
pub fn evaluate_with(
    evaluator: &design::Evaluator,
    parallelism: Vec<usize>,
    rep: FpRep,
    constraints: &Constraints,
) -> Candidate {
    let fast = evaluator
        .objectives(&parallelism, rep)
        .expect("chromosome respects bounds by construction");
    let objectives = Objectives {
        latency_ms: evaluator.latency_ms(&fast),
        dsp: fast.resources.dsp,
        lut: fast.resources.lut,
        bram: fast.resources.bram,
        total_pes: fast.total_pes,
    };
    let violation = constraints.violation(&objectives);
    Candidate { config: DesignConfig { parallelism, rep }, objectives, violation }
}

/// Run the MOGA (Algorithm 1).
pub fn run(net: &Network, device: &Device, cfg: &DseConfig) -> DseResult {
    let bounds = net.conv_filter_bounds();
    assert!(!bounds.is_empty(), "network has no conv layers to map");
    let evaluator = design::Evaluator::new(net, device).expect("valid network");
    let mut rng = Rng::new(cfg.seed);

    // ODE_config <- Initialize(l): seed the population with a spread of
    // uniform parallelism levels plus random vectors, so both extremes of
    // the front are reachable from generation 0.
    let mut pop: Vec<Candidate> = Vec::with_capacity(cfg.population);
    for i in 0..cfg.population {
        let genes: Vec<usize> = if i < 8 {
            // ladder of uniform levels 1, 2, 4, 8, ...
            let level = 1usize << i.min(7);
            bounds.iter().map(|&ub| level.min(ub)).collect()
        } else {
            bounds.iter().map(|&ub| rng.range(1, ub as i64) as usize).collect()
        };
        pop.push(evaluate_with(&evaluator, genes, cfg.rep, &cfg.constraints));
    }

    let mut evaluated: Vec<(f64, usize)> =
        pop.iter().map(|c| (c.objectives.latency_ms, c.objectives.dsp)).collect();
    let mut best_latency_per_gen = Vec::with_capacity(cfg.generations);
    let mut evaluations = pop.len();

    for _gen in 0..cfg.generations {
        // offspring via tournament + crossover + Alg.1 mutation
        let mut offspring = Vec::with_capacity(cfg.population);
        while offspring.len() < cfg.population {
            let a = nsga2::tournament(&pop, &mut rng);
            let b = nsga2::tournament(&pop, &mut rng);
            let (mut g1, mut g2) = crossover(
                &pop[a].config.parallelism,
                &pop[b].config.parallelism,
                cfg.crossover_rate,
                &mut rng,
            );
            mutate(&mut g1, &bounds, cfg, &mut rng);
            mutate(&mut g2, &bounds, cfg, &mut rng);
            offspring.push(evaluate_with(&evaluator, g1, cfg.rep, &cfg.constraints));
            if offspring.len() < cfg.population {
                offspring.push(evaluate_with(&evaluator, g2, cfg.rep, &cfg.constraints));
            }
        }
        evaluations += offspring.len();
        evaluated
            .extend(offspring.iter().map(|c| (c.objectives.latency_ms, c.objectives.dsp)));

        // elitist (mu + lambda) environmental selection
        pop.extend(offspring);
        pop = nsga2::select(pop, cfg.population);

        let best = pop
            .iter()
            .filter(|c| c.violation == 0.0)
            .map(|c| c.objectives.latency_ms)
            .fold(f64::INFINITY, f64::min);
        best_latency_per_gen.push(best);
    }

    // final front: feasible, non-dominated, deduped by chromosome
    let feasible: Vec<Candidate> =
        pop.iter().filter(|c| c.violation == 0.0).cloned().collect();
    let mut pareto = nsga2::non_dominated(&feasible);
    pareto.sort_by(|a, b| {
        a.objectives
            .latency_ms
            .partial_cmp(&b.objectives.latency_ms)
            .unwrap()
            .then(a.objectives.dsp.cmp(&b.objectives.dsp))
    });
    pareto.dedup_by(|a, b| a.config.parallelism == b.config.parallelism);

    DseResult { pareto, evaluated, best_latency_per_gen, evaluations }
}

/// Uniform crossover on the parallelism vector.
fn crossover(
    a: &[usize],
    b: &[usize],
    rate: f64,
    rng: &mut Rng,
) -> (Vec<usize>, Vec<usize>) {
    if !rng.chance(rate) {
        return (a.to_vec(), b.to_vec());
    }
    let mut g1 = Vec::with_capacity(a.len());
    let mut g2 = Vec::with_capacity(a.len());
    for i in 0..a.len() {
        if rng.chance(0.5) {
            g1.push(a[i]);
            g2.push(b[i]);
        } else {
            g1.push(b[i]);
            g2.push(a[i]);
        }
    }
    (g1, g2)
}

/// Algorithm 1 mutation: step toward a bound scaled by a power-distributed
/// random `s`:
/// `x <- x - s*(x - lb)` if `t < r` else `x <- x + s*(ub - x)`.
fn mutate(genes: &mut [usize], bounds: &[usize], cfg: &DseConfig, rng: &mut Rng) {
    for (i, g) in genes.iter_mut().enumerate() {
        if !rng.chance(cfg.mutation_rate) {
            continue;
        }
        let lb = 1.0;
        let ub = bounds[i] as f64;
        let x = *g as f64;
        let s = rng.power(cfg.mutation_power);
        // t: scaled distance from the lower bound; r ~ U(0,1)
        let t = if ub > lb { (x - lb) / (ub - lb) } else { 0.0 };
        let r = rng.f64();
        let nx = if t < r { x - s * (x - lb) } else { x + s * (ub - x) };
        *g = (nx.round() as i64).clamp(1, bounds[i] as i64) as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::pe::ZYNQ_7100;

    fn quick_cfg() -> DseConfig {
        DseConfig { population: 32, generations: 12, seed: 42, ..DseConfig::default() }
    }

    #[test]
    fn finds_nontrivial_front_on_mnist() {
        let net = zoo::mnist();
        let res = run(&net, &ZYNQ_7100, &quick_cfg());
        assert!(res.pareto.len() >= 4, "front size {}", res.pareto.len());
        // front must span a real latency range (paper: orders of magnitude)
        let lo = res.pareto.first().unwrap().objectives.latency_ms;
        let hi = res.pareto.last().unwrap().objectives.latency_ms;
        assert!(hi / lo > 10.0, "span {}", hi / lo);
    }

    #[test]
    fn front_is_mutually_non_dominated() {
        let net = zoo::mnist();
        let res = run(&net, &ZYNQ_7100, &quick_cfg());
        for a in &res.pareto {
            for b in &res.pareto {
                assert!(
                    !a.objectives.dominates(&b.objectives)
                        || a.config.parallelism == b.config.parallelism,
                    "{:?} dominates {:?}",
                    a.objectives,
                    b.objectives
                );
            }
        }
    }

    #[test]
    fn constraints_respected() {
        let net = zoo::mnist();
        let mut cfg = quick_cfg();
        cfg.constraints = Constraints {
            latency_ms: Some(1.0),
            dsp: Some(600),
            lut: None,
            bram: None,
        };
        let res = run(&net, &ZYNQ_7100, &cfg);
        assert!(!res.pareto.is_empty());
        for c in &res.pareto {
            assert!(c.objectives.latency_ms <= 1.0, "{:?}", c.objectives);
            assert!(c.objectives.dsp <= 600, "{:?}", c.objectives);
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let net = zoo::mnist();
        let a = run(&net, &ZYNQ_7100, &quick_cfg());
        let b = run(&net, &ZYNQ_7100, &quick_cfg());
        assert_eq!(a.pareto.len(), b.pareto.len());
        for (x, y) in a.pareto.iter().zip(&b.pareto) {
            assert_eq!(x.config.parallelism, y.config.parallelism);
        }
    }

    #[test]
    fn convergence_monotone_enough() {
        let net = zoo::cifar10();
        let res = run(&net, &ZYNQ_7100, &quick_cfg());
        let first = res.best_latency_per_gen.first().copied().unwrap();
        let last = res.best_latency_per_gen.last().copied().unwrap();
        assert!(last <= first, "search regressed: {first} -> {last}");
    }

    #[test]
    fn dominance_definition() {
        let a = Objectives { latency_ms: 1.0, dsp: 100, lut: 0, bram: 0, total_pes: 0 };
        let b = Objectives { latency_ms: 2.0, dsp: 200, lut: 0, bram: 0, total_pes: 0 };
        let c = Objectives { latency_ms: 0.5, dsp: 300, lut: 0, bram: 0, total_pes: 0 };
        assert!(a.dominates(&b));
        assert!(!b.dominates(&a));
        assert!(!a.dominates(&c) && !c.dominates(&a));
        assert!(!a.dominates(&a));
    }

    #[test]
    fn violation_math() {
        let cons = Constraints { latency_ms: Some(1.0), dsp: Some(100), lut: None, bram: None };
        let ok = Objectives { latency_ms: 0.9, dsp: 100, lut: 0, bram: 0, total_pes: 0 };
        let bad = Objectives { latency_ms: 2.0, dsp: 150, lut: 0, bram: 0, total_pes: 0 };
        assert_eq!(cons.violation(&ok), 0.0);
        assert!((cons.violation(&bad) - 1.5).abs() < 1e-9);
    }

    #[test]
    fn mutation_respects_bounds() {
        let bounds = vec![8, 16, 32];
        let cfg = DseConfig { mutation_rate: 1.0, ..DseConfig::default() };
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let mut genes = vec![4, 9, 20];
            mutate(&mut genes, &bounds, &cfg, &mut rng);
            for (g, ub) in genes.iter().zip(&bounds) {
                assert!(*g >= 1 && g <= ub, "gene {g} bound {ub}");
            }
        }
    }
}
