//! Generated Verilog modules: the PE primitives of Sec. III-A and the
//! configured top-level pipeline.
//!
//! Structure mirrors the paper exactly:
//! * `line_buffer` — K-1 row FIFOs + tap register bank (Fig. 4's LBC),
//!   5-bit control signalling (Valid,hStart,hEnd,vStart,vEnd).
//! * `mac_core` — K^2 multipliers feeding `adder_tree` (Eqs. 1-3).
//! * `conv_pe` — LBC + MAC + optional ReLU, one output/clock.
//! * `pool_pe` — shared LBC with a comparator tree.
//! * `fc_pe` — streaming MAC accumulator per output head (Eq. 5).
//! * `gate_ctrl` — NeuroMorph's clock-gating toggle bank (Sec. IV).

use super::verilog::{Port, VerilogWriter};
use crate::design::{DesignConfig, DesignEval};
use crate::graph::passes::StagePlan;
use crate::graph::LayerKind;

/// Streaming control bus (Fig. 4): Valid, hStart, hEnd, vStart, vEnd.
pub const CTRL_BITS: usize = 5;

pub fn line_buffer(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "line_buffer: K-1 row FIFOs assembling KxK windows from the pixel\n\
         stream (Line Buffer Controller, Sec. III-A.1). One window/clock\n\
         once primed; stride handled by the tap scheduler.",
    );
    w.module(
        "line_buffer",
        &[
            ("WIDTH", width.to_string()),
            ("K", "3".into()),
            ("FM_W", "28".into()),
            ("STRIDE", "1".into()),
        ],
        &[
            Port::input("clk", 1),
            Port::input("rst", 1),
            Port::input("px_in", 0),
            Port::input("ctrl_in", CTRL_BITS),
            Port::output_reg("window_valid", 1),
            Port { dir: super::verilog::Dir::Output, width: 1, name: "win_flat".into() },
        ],
    );
    w.line("// K-1 full rows buffered; row RAM inferred as BRAM");
    w.line("reg [WIDTH-1:0] rows [0:K-2][0:FM_W-1];");
    w.line("reg [WIDTH-1:0] taps [0:K-1][0:K-1];");
    w.line("reg [$clog2(FM_W)-1:0] col;");
    w.line("reg [15:0] row;");
    w.line("integer r, c;");
    w.blank();
    w.always_ff("posedge clk");
    w.begin("if (rst)");
    w.line("col <= 0;");
    w.line("row <= 0;");
    w.line("window_valid <= 1'b0;");
    w.end();
    w.begin("else if (ctrl_in[0])"); // Valid
    w.line("// shift the tap bank left, push the new column");
    w.begin("for (r = 0; r < K; r = r + 1)");
    w.begin("for (c = 0; c < K-1; c = c + 1)");
    w.line("taps[r][c] <= taps[r][c+1];");
    w.end();
    w.end();
    w.begin("for (r = 0; r < K-1; r = r + 1)");
    w.line("taps[r][K-1] <= rows[r][col];");
    w.end();
    w.line("taps[K-1][K-1] <= px_in;");
    w.line("// rotate the row FIFOs");
    w.begin("for (r = 0; r < K-2; r = r + 1)");
    w.line("rows[r][col] <= rows[r+1][col];");
    w.end();
    w.line("rows[K-2][col] <= px_in;");
    w.line("col <= (ctrl_in[2]) ? 0 : col + 1;"); // hEnd resets column
    w.line("row <= (ctrl_in[2]) ? row + 1 : row;");
    w.line("window_valid <= (row >= K-1) && (col >= K-1) && (((col - (K-1)) % STRIDE) == 0);");
    w.end();
    w.end(); // always
    w.blank();
    w.line("// flattened window bus: K*K pixels");
    w.line("genvar gr, gc;");
    w.line("wire [K*K*WIDTH-1:0] win_flat;");
    w.begin("generate for (gr = 0; gr < K; gr = gr + 1)");
    w.begin("for (gc = 0; gc < K; gc = gc + 1)");
    w.line("assign win_flat[(gr*K+gc)*WIDTH +: WIDTH] = taps[gr][gc];");
    w.end();
    w.end();
    w.line("endgenerate");
    w.end_module();
    w.finish()
}

pub fn adder_tree(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "adder_tree: ceil(log2(N))+1-stage pipelined reduction (Eq. 2-3).",
    );
    w.module(
        "adder_tree",
        &[("WIDTH", width.to_string()), ("N", "9".into())],
        &[
            Port::input("clk", 1),
            Port::input("in_flat", 1),
            Port::output_reg("sum", 1),
        ],
    );
    w.line("// N*2*WIDTH-wide input bus of partial products");
    w.line("wire [N*2*WIDTH-1:0] in_flat;");
    w.line("reg  [2*WIDTH-1:0] stage [0:N-1];");
    w.line("reg  [2*WIDTH-1:0] acc;");
    w.line("output reg [2*WIDTH-1:0] sum;");
    w.line("integer i;");
    w.always_ff("posedge clk");
    w.line("acc = {2*WIDTH{1'b0}};");
    w.begin("for (i = 0; i < N; i = i + 1)");
    w.line("acc = acc + in_flat[i*2*WIDTH +: 2*WIDTH];");
    w.end();
    w.line("sum <= acc;");
    w.end();
    w.end_module();
    w.finish()
}

pub fn mac_core(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "mac_core: K^2 parallel multipliers (DSP slices) + adder tree\n\
         (Eq. 1: N_mult = K^2). One window MAC per clock.",
    );
    w.module(
        "mac_core",
        &[("WIDTH", width.to_string()), ("K", "3".into())],
        &[
            Port::input("clk", 1),
            Port::input("win_flat", 1),
            Port::input("wgt_flat", 1),
            Port::output("mac_out", 1),
        ],
    );
    w.line("wire [K*K*WIDTH-1:0] win_flat;");
    w.line("wire [K*K*WIDTH-1:0] wgt_flat;");
    w.line("wire [2*WIDTH-1:0] mac_out;");
    w.line("reg  [K*K*2*WIDTH-1:0] products;");
    w.line("integer i;");
    w.always_ff("posedge clk");
    w.begin("for (i = 0; i < K*K; i = i + 1)");
    w.line("// each product maps to one DSP48 slice");
    w.line(
        "products[i*2*WIDTH +: 2*WIDTH] <= $signed(win_flat[i*WIDTH +: WIDTH]) * $signed(wgt_flat[i*WIDTH +: WIDTH]);",
    );
    w.end();
    w.end();
    w.blank();
    w.line("adder_tree #(.WIDTH(WIDTH), .N(K*K)) tree (");
    w.line("    .clk(clk), .in_flat(products), .sum(mac_out)");
    w.line(");");
    w.end_module();
    w.finish()
}

pub fn relu(width: usize) -> String {
    let mut w = VerilogWriter::new("relu: comparator non-linearity, 1 cycle (T_ReLU).");
    w.module(
        "relu",
        &[("WIDTH", width.to_string())],
        &[
            Port::input("clk", 1),
            Port::input("x", 0),
            Port::output_reg("y", 1),
        ],
    );
    w.line("output reg [WIDTH-1:0] y;");
    w.always_ff("posedge clk");
    w.line("y <= x[WIDTH-1] ? {WIDTH{1'b0}} : x;");
    w.end();
    w.end_module();
    w.finish()
}

pub fn conv_pe(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "conv_pe: Line Buffer Controller -> MAC core -> ReLU, the C_PE\n\
         two-stage pipeline of Sec. III-A.1.",
    );
    w.module(
        "conv_pe",
        &[
            ("WIDTH", width.to_string()),
            ("K", "3".into()),
            ("FM_W", "28".into()),
            ("STRIDE", "1".into()),
            ("RELU", "1".into()),
        ],
        &[
            Port::input("clk", 1),
            Port::input("rst", 1),
            Port::input("en", 1), // clock-gate enable (NeuroMorph)
            Port::input("px_in", 0),
            Port::input("ctrl_in", CTRL_BITS),
            Port::input("wgt_flat", 1),
            Port::output("px_out", 1),
            Port::output("valid_out", 1),
        ],
    );
    w.line("wire [K*K*WIDTH-1:0] wgt_flat;");
    w.line("wire [K*K*WIDTH-1:0] window;");
    w.line("wire window_valid;");
    w.line("wire [2*WIDTH-1:0] mac;");
    w.line("wire [WIDTH-1:0] px_out;");
    w.line("wire valid_out;");
    w.line("wire gclk;");
    w.line("// clock gating cell: BUFGCE-style enable");
    w.line("assign gclk = clk & en;");
    w.blank();
    w.line("line_buffer #(.WIDTH(WIDTH), .K(K), .FM_W(FM_W), .STRIDE(STRIDE)) lbc (");
    w.line("    .clk(gclk), .rst(rst), .px_in(px_in), .ctrl_in(ctrl_in),");
    w.line("    .window_valid(window_valid), .win_flat(window)");
    w.line(");");
    w.line("mac_core #(.WIDTH(WIDTH), .K(K)) mac_i (");
    w.line("    .clk(gclk), .win_flat(window), .wgt_flat(wgt_flat), .mac_out(mac)");
    w.line(");");
    w.blank();
    w.line("// saturating truncation back to the datapath width");
    w.line("wire [WIDTH-1:0] trunc = mac[2*WIDTH-1] ? {1'b1, {(WIDTH-1){1'b0}}} : mac[WIDTH-1:0];");
    w.line("generate if (RELU) begin : g_relu");
    w.line("    relu #(.WIDTH(WIDTH)) act (.clk(gclk), .x(trunc), .y(px_out));");
    w.line("end else begin : g_pass");
    w.line("    assign px_out = trunc;");
    w.line("end endgenerate");
    w.line("assign valid_out = window_valid & en;");
    w.end_module();
    w.finish()
}

pub fn pool_pe(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "pool_pe: PU_PE — shared line buffer + K^2 comparator tree (max)\n\
         or fixed-coefficient averaging (Sec. III-A.2). No DSP slices.",
    );
    w.module(
        "pool_pe",
        &[
            ("WIDTH", width.to_string()),
            ("K", "2".into()),
            ("FM_W", "28".into()),
            ("MODE_MAX", "1".into()),
        ],
        &[
            Port::input("clk", 1),
            Port::input("rst", 1),
            Port::input("en", 1),
            Port::input("px_in", 0),
            Port::input("ctrl_in", CTRL_BITS),
            Port::output_reg("px_out", 1),
            Port::output("valid_out", 1),
        ],
    );
    w.line("output reg [WIDTH-1:0] px_out;");
    w.line("wire [K*K*WIDTH-1:0] window;");
    w.line("wire window_valid;");
    w.line("wire valid_out;");
    w.line("wire gclk = clk & en;");
    w.line("reg [WIDTH-1:0] best;");
    w.line("reg [WIDTH+7:0] accum;");
    w.line("integer i;");
    w.blank();
    w.line("line_buffer #(.WIDTH(WIDTH), .K(K), .FM_W(FM_W), .STRIDE(K)) lbc (");
    w.line("    .clk(gclk), .rst(rst), .px_in(px_in), .ctrl_in(ctrl_in),");
    w.line("    .window_valid(window_valid), .win_flat(window)");
    w.line(");");
    w.always_ff("posedge gclk");
    w.line("best = window[0 +: WIDTH];");
    w.line("accum = {(WIDTH+8){1'b0}};");
    w.begin("for (i = 0; i < K*K; i = i + 1)");
    w.begin("if (MODE_MAX)");
    w.line("best = ($signed(window[i*WIDTH +: WIDTH]) > $signed(best)) ? window[i*WIDTH +: WIDTH] : best;");
    w.end();
    w.begin("else");
    w.line("accum = accum + window[i*WIDTH +: WIDTH];");
    w.end();
    w.end();
    w.line("px_out <= MODE_MAX ? best : accum / (K*K);");
    w.end();
    w.line("assign valid_out = window_valid & en;");
    w.end_module();
    w.finish()
}

pub fn fc_pe(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "fc_pe: FC_PE streaming MAC accumulator (Eq. 5); one DSP slice,\n\
         weights preloaded, one input-weight product per clock.",
    );
    w.module(
        "fc_pe",
        &[("WIDTH", width.to_string()), ("N_IN", "1568".into())],
        &[
            Port::input("clk", 1),
            Port::input("rst", 1),
            Port::input("en", 1),
            Port::input("x_in", 0),
            Port::input("x_valid", 1),
            Port::input("wgt", 0),
            Port::input("bias", 0),
            Port::output_reg("y", 1),
            Port::output_reg("y_valid", 1),
        ],
    );
    w.line("output reg [2*WIDTH-1:0] y;");
    w.line("reg [2*WIDTH-1:0] acc;");
    w.line("reg [$clog2(N_IN):0] count;");
    w.line("wire gclk = clk & en;");
    w.always_ff("posedge gclk");
    w.begin("if (rst)");
    w.line("acc <= {2*WIDTH{1'b0}};");
    w.line("count <= 0;");
    w.line("y_valid <= 1'b0;");
    w.end();
    w.begin("else if (x_valid)");
    w.line("acc <= acc + $signed(x_in) * $signed(wgt);");
    w.line("count <= count + 1;");
    w.begin("if (count == N_IN - 1)");
    w.line("y <= acc + $signed(bias);");
    w.line("y_valid <= 1'b1;");
    w.line("acc <= {2*WIDTH{1'b0}};");
    w.line("count <= 0;");
    w.end();
    w.end();
    w.end();
    w.end_module();
    w.finish()
}

pub fn gate_ctrl() -> String {
    let mut w = VerilogWriter::new(
        "gate_ctrl: NeuroMorph clock-gating toggle bank (Sec. IV). The\n\
         runtime writes a one-hot morph-path select; each Layer-Block's\n\
         enable follows with a full-frame resynchronization delay.",
    );
    w.module(
        "gate_ctrl",
        &[("N_BLOCKS", "4".into()), ("N_PATHS", "4".into())],
        &[
            Port::input("clk", 1),
            Port::input("rst", 1),
            Port::input("path_sel", 4),
            Port::input("frame_start", 1),
            Port::output_reg("block_en", 8),
            Port::output_reg("resync", 1),
        ],
    );
    w.line("// path -> active-block mask ROM, programmed at generation time");
    w.line("reg [N_BLOCKS-1:0] mask_rom [0:N_PATHS-1];");
    w.line("reg [N_BLOCKS-1:0] pending;");
    w.line("output reg [N_BLOCKS-1:0] block_en;");
    w.line("integer p;");
    w.begin("initial");
    w.begin("for (p = 0; p < N_PATHS; p = p + 1)");
    w.line("mask_rom[p] = {N_BLOCKS{1'b1}} >> (N_PATHS - 1 - p);");
    w.end();
    w.end();
    w.always_ff("posedge clk");
    w.begin("if (rst)");
    w.line("block_en <= {N_BLOCKS{1'b1}};");
    w.line("resync <= 1'b0;");
    w.end();
    w.begin("else");
    w.line("pending <= mask_rom[path_sel];");
    w.line("// switch only on frame boundaries: in-flight frames drain");
    w.begin("if (frame_start)");
    w.line("resync <= (pending != block_en);");
    w.line("block_en <= pending;");
    w.end();
    w.end();
    w.end();
    w.end_module();
    w.finish()
}

pub fn concat_mux(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "concat_mux: channel-wise merge of N_IN branch streams. The\n\
         primary branch streams through; the others drain from their\n\
         re-sync FIFOs (BRAM, sized by the compiler's StagePlan) in\n\
         channel order behind it.\n\
         STRUCTURAL SKETCH (like the zero-weight PE banks): the producer\n\
         is assumed idle between a frame's vEnd and the end of the drain\n\
         phase — the frame-paced source of the analytical model provides\n\
         exactly that gap; no ready/backpressure wire is emitted.",
    );
    w.module(
        "concat_mux",
        &[
            ("WIDTH", width.to_string()),
            ("N_IN", "2".into()),
            ("FIFO_DEPTH", "1024".into()),
        ],
        &[
            Port::input("clk", 1),
            Port::input("rst", 1),
            Port::input("en", 1),
            Port::input("px_flat", 1),
            Port::input("valid_flat", 1),
            Port::input("ctrl_in", CTRL_BITS),
            Port::output_reg("px_out", 1),
            Port::output_reg("valid_out", 1),
        ],
    );
    w.line("// flattened input buses: one lane per branch");
    w.line("wire [N_IN*WIDTH-1:0] px_flat;");
    w.line("wire [N_IN-1:0] valid_flat;");
    w.line("output reg [WIDTH-1:0] px_out;");
    w.line("// branch re-sync FIFOs (BRAM inferred); branch 0 bypasses.");
    w.line("// Pointers wrap AT FIFO_DEPTH (not free-running). The");
    w.line("// compiler sizes FIFO_DEPTH strictly past the worst-case");
    w.line("// content, so equal pointers always mean empty, never full.");
    w.line("reg [WIDTH-1:0] fifo [1:N_IN-1][0:FIFO_DEPTH-1];");
    w.line("reg [$clog2(FIFO_DEPTH):0] wr_ptr [1:N_IN-1];");
    w.line("reg [$clog2(FIFO_DEPTH):0] rd_ptr [1:N_IN-1];");
    w.line("reg [$clog2(N_IN):0] sel;");
    w.line("wire [$clog2(FIFO_DEPTH):0] rd_next = (rd_ptr[sel] == FIFO_DEPTH-1) ? 0 : rd_ptr[sel] + 1;");
    w.line("integer b;");
    w.always_ff("posedge clk");
    w.begin("if (rst)");
    w.line("sel <= 0;");
    w.line("valid_out <= 1'b0;");
    w.begin("for (b = 1; b < N_IN; b = b + 1)");
    w.line("wr_ptr[b] <= 0;");
    w.line("rd_ptr[b] <= 0;");
    w.end();
    w.end();
    w.begin("else if (en)");
    w.line("// enqueue every non-primary branch as it arrives");
    w.begin("for (b = 1; b < N_IN; b = b + 1)");
    w.begin("if (valid_flat[b])");
    w.line("fifo[b][wr_ptr[b]] <= px_flat[b*WIDTH +: WIDTH];");
    w.line("wr_ptr[b] <= (wr_ptr[b] == FIFO_DEPTH-1) ? 0 : wr_ptr[b] + 1;");
    w.end();
    w.end();
    w.line("// emit: primary stream first, then drain the FIFOs in order");
    w.begin("if (sel == 0)");
    w.line("px_out <= px_flat[0 +: WIDTH];");
    w.line("valid_out <= valid_flat[0];");
    w.line("sel <= (ctrl_in[4]) ? 1 : 0;"); // vEnd advances the selector
    w.end();
    w.begin("else");
    w.line("px_out <= fifo[sel][rd_ptr[sel]];");
    w.line("valid_out <= rd_ptr[sel] != wr_ptr[sel];");
    w.line("// drain only while non-empty: an empty FIFO holds (waits for");
    w.line("// the lagging branch) instead of overrunning its writer");
    w.begin("if (rd_ptr[sel] != wr_ptr[sel])");
    w.line("rd_ptr[sel] <= rd_next;");
    w.line("sel <= (rd_next == wr_ptr[sel]) ? ((sel == N_IN-1) ? 0 : sel + 1) : sel;");
    w.end();
    w.end();
    w.end();
    w.end();
    w.end_module();
    w.finish()
}

pub fn upsample(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "upsample: nearest-neighbour row/column repeater. Each input row\n\
         is buffered once (one BRAM row of all channels) and replayed\n\
         FACTOR times with each pixel held FACTOR cycles.\n\
         STRUCTURAL SKETCH: the producer is assumed to deliver one input\n\
         row per FACTOR^2 x FM_W output cycles (the design model paces\n\
         this stage at its OUTPUT frame rate for exactly that reason);\n\
         no ready/backpressure wire is emitted, so a free-running\n\
         producer would overwrite the row bank mid-replay.",
    );
    w.module(
        "upsample",
        &[
            ("WIDTH", width.to_string()),
            ("FM_W", "28".into()),
            ("FACTOR", "2".into()),
        ],
        &[
            Port::input("clk", 1),
            Port::input("rst", 1),
            Port::input("en", 1),
            Port::input("px_in", 0),
            Port::input("ctrl_in", CTRL_BITS),
            Port::output_reg("px_out", 1),
            Port::output_reg("valid_out", 1),
        ],
    );
    w.line("output reg [WIDTH-1:0] px_out;");
    w.line("reg [WIDTH-1:0] row [0:FM_W-1];");
    w.line("reg [$clog2(FM_W)-1:0] col;");
    w.line("reg [$clog2(FM_W)-1:0] rep_col;");
    w.line("reg [7:0] rep_px;");
    w.line("reg primed; // a full input row is banked and replayable");
    w.always_ff("posedge clk");
    w.begin("if (rst)");
    w.line("col <= 0;");
    w.line("rep_col <= 0;");
    w.line("rep_px <= 0;");
    w.line("primed <= 1'b0;");
    w.line("valid_out <= 1'b0;");
    w.end();
    w.begin("else if (en)");
    w.line("// writer: bank the incoming row at the input rate");
    w.begin("if (ctrl_in[0])"); // Valid
    w.line("row[col] <= px_in;");
    w.line("col <= (ctrl_in[2]) ? 0 : col + 1;"); // hEnd wraps
    w.line("primed <= primed | ctrl_in[2];");
    w.end();
    w.line("// replayer: once primed it emits EVERY cycle — FACTOR copies");
    w.line("// of each pixel. Row replay pacing (FACTOR passes per banked");
    w.line("// row) is governed by the producer, which delivers one input");
    w.line("// row per FACTOR output rows — the design model paces this");
    w.line("// stage at its OUTPUT frame rate for exactly that reason.");
    w.begin("if (primed)");
    w.line("px_out <= row[rep_col];");
    w.line("valid_out <= 1'b1;");
    w.line("rep_px <= (rep_px == FACTOR-1) ? 0 : rep_px + 1;");
    w.begin("if (rep_px == FACTOR-1)");
    w.line("rep_col <= (rep_col == FM_W-1) ? 0 : rep_col + 1;");
    w.end(); // rep_px wrap
    w.end(); // primed replayer
    w.end(); // else if (en)
    w.end(); // always
    w.end_module();
    w.finish()
}

pub fn spp_pe(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "spp_pe: SPPF pyramid — three cascaded stride-1 KxK max pools\n\
         (shared line-buffer pattern) whose four taps (input + pool\n\
         outputs) stream out channel-concatenated through a concat_mux.",
    );
    w.module(
        "spp_pe",
        &[
            ("WIDTH", width.to_string()),
            ("K", "5".into()),
            ("FM_W", "20".into()),
        ],
        &[
            Port::input("clk", 1),
            Port::input("rst", 1),
            Port::input("en", 1),
            Port::input("px_in", 0),
            Port::input("ctrl_in", CTRL_BITS),
            Port::output("px_out", 1),
            Port::output("valid_out", 1),
        ],
    );
    w.line("wire [WIDTH-1:0] px_out;");
    w.line("wire valid_out;");
    w.line("wire [WIDTH-1:0] tap1, tap2, tap3;");
    w.line("wire v1, v2, v3;");
    w.blank();
    w.line("// cascaded stride-1 pools: receptive fields k, 2k-1, 3k-2");
    w.line("pool_pe #(.WIDTH(WIDTH), .K(K), .FM_W(FM_W), .MODE_MAX(1)) p1 (");
    w.line("    .clk(clk), .rst(rst), .en(en), .px_in(px_in), .ctrl_in(ctrl_in),");
    w.line("    .px_out(tap1), .valid_out(v1)");
    w.line(");");
    w.line("pool_pe #(.WIDTH(WIDTH), .K(K), .FM_W(FM_W), .MODE_MAX(1)) p2 (");
    w.line("    .clk(clk), .rst(rst), .en(en), .px_in(tap1), .ctrl_in(ctrl_in),");
    w.line("    .px_out(tap2), .valid_out(v2)");
    w.line(");");
    w.line("pool_pe #(.WIDTH(WIDTH), .K(K), .FM_W(FM_W), .MODE_MAX(1)) p3 (");
    w.line("    .clk(clk), .rst(rst), .en(en), .px_in(tap2), .ctrl_in(ctrl_in),");
    w.line("    .px_out(tap3), .valid_out(v3)");
    w.line(");");
    w.blank();
    w.line("// four-tap channel concat (input + three pyramid levels)");
    w.line("wire [4*WIDTH-1:0] taps_flat = {tap3, tap2, tap1, px_in};");
    w.line("wire [3:0] taps_valid = {v3, v2, v1, ctrl_in[0]};");
    w.line("// depth 8*FM_W: strictly past the 4-row-per-tap worst case,");
    w.line("// so the mux's equal-pointer test stays an empty test");
    w.line("concat_mux #(.WIDTH(WIDTH), .N_IN(4), .FIFO_DEPTH(8*FM_W)) cat (");
    w.line("    .clk(clk), .rst(rst), .en(en), .px_flat(taps_flat),");
    w.line("    .valid_flat(taps_valid), .ctrl_in(ctrl_in),");
    w.line("    .px_out(px_out), .valid_out(valid_out)");
    w.line(");");
    w.end_module();
    w.finish()
}

/// The configured top-level: wires every stage of the scheduled plan
/// along its dataflow edges (branches fork, merges consume multiple
/// stage outputs).
pub fn top(
    plan: &StagePlan,
    cfg: &DesignConfig,
    eval: &DesignEval,
    top_name: &str,
    width: usize,
) -> String {
    let mut w = VerilogWriter::new(&format!(
        "{top_name}: generated streaming pipeline for '{}'\n\
         design point p = {:?} ({} PEs, {} DSP, est. {:.3} ms @ {} MHz)",
        plan.net_name,
        cfg.parallelism,
        eval.total_pes,
        eval.resources.dsp,
        eval.latency_ms(),
        eval.clock_mhz,
    ));
    let n_blocks = plan.gate_blocks;
    // dataflow sinks: stages nobody consumes. Chains have exactly one;
    // multi-head detectors (yolov5l) get one result port per head so no
    // output dangles for synthesis to prune away.
    let mut consumed = vec![false; plan.stages.len()];
    for e in &plan.edges {
        consumed[e.src] = true;
    }
    let mut sinks: Vec<usize> = plan
        .stages
        .iter()
        .filter(|s| !consumed[s.id] && !matches!(s.kind, LayerKind::Input { .. }))
        .map(|s| s.id)
        .collect();
    if sinks.is_empty() {
        sinks.push(plan.stages.len() - 1);
    }
    let mut ports = vec![
        Port::input("clk", 1),
        Port::input("rst", 1),
        Port::input("px_in", 0),
        Port::input("ctrl_in", CTRL_BITS),
        Port::input("path_sel", 4),
        Port::input("frame_start", 1),
        Port::output("result", 1),
        Port::output("result_valid", 1),
    ];
    for i in 0..sinks.len().saturating_sub(1) {
        ports.push(Port::output(&format!("result_aux{i}"), 0));
        ports.push(Port::output(&format!("result_aux{i}_valid"), 1));
    }
    w.module(top_name, &[("WIDTH", width.to_string())], &ports);
    w.line(&format!("wire [{}:0] block_en;", n_blocks.max(1) - 1));
    w.line("wire resync;");
    w.line(&format!(
        "gate_ctrl #(.N_BLOCKS({n_blocks}), .N_PATHS({n_blocks})) gates ("
    ));
    w.line("    .clk(clk), .rst(rst), .path_sel(path_sel),");
    w.line("    .frame_start(frame_start), .block_en(block_en), .resync(resync)");
    w.line(");");
    w.blank();

    // per-stage output nets, wired along the plan's dataflow edges so
    // forked branches read their true producer, not the last emitted
    // stage. Pass-through stages alias their input net. The clock-gate
    // block likewise follows the DATAFLOW producer (a pool on a forked
    // branch rides its own branch's conv enable, not whichever conv was
    // emitted last in topological order).
    let mut px_of: Vec<String> = vec!["px_in".to_string(); plan.stages.len()];
    let mut ctrl_of: Vec<String> = vec!["ctrl_in".to_string(); plan.stages.len()];
    // producer valid nets (ctrl_in[0] is the source's Valid bit)
    let mut valid_of: Vec<String> = vec!["ctrl_in[0]".to_string(); plan.stages.len()];
    let mut block_of: Vec<usize> = vec![0usize; plan.stages.len()];
    for stage in &plan.stages {
        let sid = stage.id;
        let inp = stage.input;
        let (prev_px, prev_ctrl) = match stage.preds.first() {
            Some(&p) => (px_of[p].clone(), ctrl_of[p].clone()),
            None => ("px_in".to_string(), "ctrl_in".to_string()),
        };
        let prev_valid = stage
            .preds
            .first()
            .map(|&p| valid_of[p].clone())
            .unwrap_or_else(|| "ctrl_in[0]".to_string());
        // gate block inherited along the stream: own block for convs,
        // primary producer's block for everything else
        let inherited_block = stage.preds.first().map(|&p| block_of[p]).unwrap_or(0);
        block_of[sid] = stage.gate_block.unwrap_or(inherited_block);
        match &stage.kind {
            LayerKind::Conv { k, stride, relu, .. }
            | LayerKind::DwConv { k, stride, relu, .. } => {
                let lanes = eval.mappings[sid].pe_count;
                let block = stage.gate_block.expect("conv stage gated");
                w.line(&format!(
                    "// stage {sid}: {} — {} C_PE lanes, serial x{}",
                    stage.name, lanes, eval.mappings[sid].serial_factor
                ));
                w.line(&format!("wire [WIDTH-1:0] s{sid}_px;"));
                w.line(&format!("wire s{sid}_valid;"));
                w.line(&format!("wire [{CTRL_BITS}-1:0] s{sid}_ctrl = {prev_ctrl};"));
                w.line(&format!(
                    "conv_pe #(.WIDTH(WIDTH), .K({k}), .FM_W({}), .STRIDE({stride}), .RELU({})) u_{} (",
                    inp.w,
                    u8::from(*relu),
                    stage.name
                ));
                w.line(&format!(
                    "    .clk(clk), .rst(rst), .en(block_en[{block}]), .px_in({prev_px}),"
                ));
                w.line(&format!(
                    "    .ctrl_in({prev_ctrl}), .wgt_flat({}'d0), .px_out(s{sid}_px), .valid_out(s{sid}_valid)",
                    k * k * width
                ));
                w.line(");");
                px_of[sid] = format!("s{sid}_px");
                ctrl_of[sid] = format!("s{sid}_ctrl");
                valid_of[sid] = format!("s{sid}_valid");
            }
            LayerKind::MaxPool { k, .. } | LayerKind::AvgPool { k, .. } => {
                let is_max = matches!(stage.kind, LayerKind::MaxPool { .. });
                w.line(&format!("// stage {sid}: {}", stage.name));
                w.line(&format!("wire [WIDTH-1:0] s{sid}_px;"));
                w.line(&format!("wire s{sid}_valid;"));
                w.line(&format!("wire [{CTRL_BITS}-1:0] s{sid}_ctrl = {prev_ctrl};"));
                w.line(&format!(
                    "pool_pe #(.WIDTH(WIDTH), .K({k}), .FM_W({}), .MODE_MAX({})) u_{} (",
                    inp.w,
                    u8::from(is_max),
                    stage.name
                ));
                w.line(&format!(
                    "    .clk(clk), .rst(rst), .en(block_en[{}]), .px_in({prev_px}),",
                    block_of[sid]
                ));
                w.line(&format!(
                    "    .ctrl_in({prev_ctrl}), .px_out(s{sid}_px), .valid_out(s{sid}_valid)"
                ));
                w.line(");");
                px_of[sid] = format!("s{sid}_px");
                ctrl_of[sid] = format!("s{sid}_ctrl");
                valid_of[sid] = format!("s{sid}_valid");
            }
            LayerKind::Fc { out, .. } => {
                w.line(&format!("// stage {sid}: {} — {} heads", stage.name, out));
                w.line(&format!("wire [2*WIDTH-1:0] s{sid}_y;"));
                w.line(&format!("wire s{sid}_valid;"));
                w.line(&format!(
                    "fc_pe #(.WIDTH(WIDTH), .N_IN({})) u_{} (",
                    inp.features(),
                    stage.name
                ));
                w.line(&format!(
                    "    .clk(clk), .rst(rst), .en(1'b1), .x_in({prev_px}), .x_valid(1'b1),"
                ));
                w.line(&format!(
                    "    .wgt({width}'d0), .bias({width}'d0), .y(s{sid}_y), .y_valid(s{sid}_valid)"
                ));
                w.line(");");
                px_of[sid] = format!("s{sid}_y[WIDTH-1:0]");
                ctrl_of[sid] = prev_ctrl;
                valid_of[sid] = format!("s{sid}_valid");
            }
            LayerKind::Concat { .. } => {
                let n_in = stage.preds.len().max(1);
                // one PAST the worst-case content, so the mux's
                // equal-pointers test always means empty, never full
                let fifo = (plan.branch_words_into(sid).max(inp.w.max(1)) + 1)
                    .next_power_of_two();
                w.line(&format!(
                    "// stage {sid}: {} — {}-way channel concat, {} FIFO words",
                    stage.name,
                    n_in,
                    plan.branch_words_into(sid)
                ));
                w.line(&format!("wire [{n_in}*WIDTH-1:0] s{sid}_cat;"));
                for (i, &p) in stage.preds.iter().enumerate() {
                    w.line(&format!(
                        "assign s{sid}_cat[{i}*WIDTH +: WIDTH] = {};",
                        px_of[p]
                    ));
                }
                w.line(&format!("wire [{n_in}-1:0] s{sid}_cat_vld;"));
                for (i, &p) in stage.preds.iter().enumerate() {
                    w.line(&format!(
                        "assign s{sid}_cat_vld[{i}] = {};",
                        valid_of[p]
                    ));
                }
                w.line(&format!("wire [WIDTH-1:0] s{sid}_px;"));
                w.line(&format!("wire s{sid}_valid;"));
                w.line(&format!("wire [{CTRL_BITS}-1:0] s{sid}_ctrl = {prev_ctrl};"));
                w.line(&format!(
                    "concat_mux #(.WIDTH(WIDTH), .N_IN({n_in}), .FIFO_DEPTH({fifo})) u_{} (",
                    stage.name
                ));
                w.line(&format!(
                    "    .clk(clk), .rst(rst), .en(block_en[{}]), .px_flat(s{sid}_cat),",
                    block_of[sid]
                ));
                w.line(&format!(
                    "    .valid_flat(s{sid}_cat_vld), .ctrl_in({prev_ctrl}),"
                ));
                w.line(&format!(
                    "    .px_out(s{sid}_px), .valid_out(s{sid}_valid)"
                ));
                w.line(");");
                px_of[sid] = format!("s{sid}_px");
                ctrl_of[sid] = format!("s{sid}_ctrl");
                valid_of[sid] = format!("s{sid}_valid");
            }
            LayerKind::Upsample { factor } => {
                w.line(&format!("// stage {sid}: {} — x{factor} repeater", stage.name));
                w.line(&format!("wire [WIDTH-1:0] s{sid}_px;"));
                w.line(&format!("wire s{sid}_valid;"));
                w.line(&format!("wire [{CTRL_BITS}-1:0] s{sid}_ctrl = {prev_ctrl};"));
                w.line(&format!(
                    "upsample #(.WIDTH(WIDTH), .FM_W({}), .FACTOR({factor})) u_{} (",
                    inp.w, stage.name
                ));
                w.line(&format!(
                    "    .clk(clk), .rst(rst), .en(block_en[{}]), .px_in({prev_px}),",
                    block_of[sid]
                ));
                w.line(&format!(
                    "    .ctrl_in({prev_ctrl}), .px_out(s{sid}_px), .valid_out(s{sid}_valid)"
                ));
                w.line(");");
                px_of[sid] = format!("s{sid}_px");
                ctrl_of[sid] = format!("s{sid}_ctrl");
                valid_of[sid] = format!("s{sid}_valid");
            }
            LayerKind::SpatialPyramidPool { k } => {
                w.line(&format!("// stage {sid}: {} — SPPF k={k}", stage.name));
                w.line(&format!("wire [WIDTH-1:0] s{sid}_px;"));
                w.line(&format!("wire s{sid}_valid;"));
                w.line(&format!("wire [{CTRL_BITS}-1:0] s{sid}_ctrl = {prev_ctrl};"));
                w.line(&format!(
                    "spp_pe #(.WIDTH(WIDTH), .K({k}), .FM_W({})) u_{} (",
                    inp.w, stage.name
                ));
                w.line(&format!(
                    "    .clk(clk), .rst(rst), .en(block_en[{}]), .px_in({prev_px}),",
                    block_of[sid]
                ));
                w.line(&format!(
                    "    .ctrl_in({prev_ctrl}), .px_out(s{sid}_px), .valid_out(s{sid}_valid)"
                ));
                w.line(");");
                px_of[sid] = format!("s{sid}_px");
                ctrl_of[sid] = format!("s{sid}_ctrl");
                valid_of[sid] = format!("s{sid}_valid");
            }
            // pass-through stages alias their producer's net so every
            // downstream branch reference resolves
            _ => {
                px_of[sid] = prev_px;
                ctrl_of[sid] = prev_ctrl;
                valid_of[sid] = prev_valid.clone();
            }
        }
    }
    // the topologically-last sink is the primary result; every other
    // sink (extra detect heads) gets an aux port in stream order
    let primary = *sinks.last().expect("at least one sink");
    w.line(&format!("assign result = {};", px_of[primary]));
    w.line(&format!("assign result_valid = {};", valid_of[primary]));
    for (i, &s) in sinks[..sinks.len() - 1].iter().enumerate() {
        w.line(&format!("assign result_aux{i} = {};", px_of[s]));
        w.line(&format!("assign result_aux{i}_valid = {};", valid_of[s]));
    }
    w.end_module();
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_emit_nonempty() {
        for src in [
            line_buffer(16),
            mac_core(16),
            adder_tree(16),
            relu(8),
            pool_pe(16),
            fc_pe(16),
            gate_ctrl(),
        ] {
            assert!(src.contains("endmodule"));
            assert!(src.len() > 200);
        }
    }

    #[test]
    fn mac_core_instantiates_tree() {
        let src = mac_core(16);
        assert!(src.contains("adder_tree #(.WIDTH(WIDTH), .N(K*K))"));
        assert!(src.contains("DSP48"));
    }

    #[test]
    fn gate_ctrl_has_frame_sync() {
        let src = gate_ctrl();
        assert!(src.contains("frame_start"));
        assert!(src.contains("mask_rom"));
    }

    #[test]
    fn conv_pe_has_enable_gating() {
        let src = conv_pe(8);
        assert!(src.contains("clk & en"));
        assert!(src.contains("line_buffer #("));
    }
}
