//! Generated Verilog modules: the PE primitives of Sec. III-A and the
//! configured top-level pipeline.
//!
//! Structure mirrors the paper exactly:
//! * `line_buffer` — K-1 row FIFOs + tap register bank (Fig. 4's LBC),
//!   5-bit control signalling (Valid,hStart,hEnd,vStart,vEnd).
//! * `mac_core` — K^2 multipliers feeding `adder_tree` (Eqs. 1-3).
//! * `conv_pe` — LBC + MAC + optional ReLU, one output/clock.
//! * `pool_pe` — shared LBC with a comparator tree.
//! * `fc_pe` — streaming MAC accumulator per output head (Eq. 5).
//! * `gate_ctrl` — NeuroMorph's clock-gating toggle bank (Sec. IV).

use super::verilog::{Port, VerilogWriter};
use crate::design::{DesignConfig, DesignEval};
use crate::graph::{LayerKind, Network};

/// Streaming control bus (Fig. 4): Valid, hStart, hEnd, vStart, vEnd.
pub const CTRL_BITS: usize = 5;

pub fn line_buffer(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "line_buffer: K-1 row FIFOs assembling KxK windows from the pixel\n\
         stream (Line Buffer Controller, Sec. III-A.1). One window/clock\n\
         once primed; stride handled by the tap scheduler.",
    );
    w.module(
        "line_buffer",
        &[
            ("WIDTH", width.to_string()),
            ("K", "3".into()),
            ("FM_W", "28".into()),
            ("STRIDE", "1".into()),
        ],
        &[
            Port::input("clk", 1),
            Port::input("rst", 1),
            Port::input("px_in", 0),
            Port::input("ctrl_in", CTRL_BITS),
            Port::output_reg("window_valid", 1),
            Port { dir: super::verilog::Dir::Output, width: 1, name: "win_flat".into() },
        ],
    );
    w.line("// K-1 full rows buffered; row RAM inferred as BRAM");
    w.line("reg [WIDTH-1:0] rows [0:K-2][0:FM_W-1];");
    w.line("reg [WIDTH-1:0] taps [0:K-1][0:K-1];");
    w.line("reg [$clog2(FM_W)-1:0] col;");
    w.line("reg [15:0] row;");
    w.line("integer r, c;");
    w.blank();
    w.always_ff("posedge clk");
    w.begin("if (rst)");
    w.line("col <= 0;");
    w.line("row <= 0;");
    w.line("window_valid <= 1'b0;");
    w.end();
    w.begin("else if (ctrl_in[0])"); // Valid
    w.line("// shift the tap bank left, push the new column");
    w.begin("for (r = 0; r < K; r = r + 1)");
    w.begin("for (c = 0; c < K-1; c = c + 1)");
    w.line("taps[r][c] <= taps[r][c+1];");
    w.end();
    w.end();
    w.begin("for (r = 0; r < K-1; r = r + 1)");
    w.line("taps[r][K-1] <= rows[r][col];");
    w.end();
    w.line("taps[K-1][K-1] <= px_in;");
    w.line("// rotate the row FIFOs");
    w.begin("for (r = 0; r < K-2; r = r + 1)");
    w.line("rows[r][col] <= rows[r+1][col];");
    w.end();
    w.line("rows[K-2][col] <= px_in;");
    w.line("col <= (ctrl_in[2]) ? 0 : col + 1;"); // hEnd resets column
    w.line("row <= (ctrl_in[2]) ? row + 1 : row;");
    w.line("window_valid <= (row >= K-1) && (col >= K-1) && (((col - (K-1)) % STRIDE) == 0);");
    w.end();
    w.end(); // always
    w.blank();
    w.line("// flattened window bus: K*K pixels");
    w.line("genvar gr, gc;");
    w.line("wire [K*K*WIDTH-1:0] win_flat;");
    w.begin("generate for (gr = 0; gr < K; gr = gr + 1)");
    w.begin("for (gc = 0; gc < K; gc = gc + 1)");
    w.line("assign win_flat[(gr*K+gc)*WIDTH +: WIDTH] = taps[gr][gc];");
    w.end();
    w.end();
    w.line("endgenerate");
    w.end_module();
    w.finish()
}

pub fn adder_tree(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "adder_tree: ceil(log2(N))+1-stage pipelined reduction (Eq. 2-3).",
    );
    w.module(
        "adder_tree",
        &[("WIDTH", width.to_string()), ("N", "9".into())],
        &[
            Port::input("clk", 1),
            Port::input("in_flat", 1),
            Port::output_reg("sum", 1),
        ],
    );
    w.line("// N*2*WIDTH-wide input bus of partial products");
    w.line("wire [N*2*WIDTH-1:0] in_flat;");
    w.line("reg  [2*WIDTH-1:0] stage [0:N-1];");
    w.line("reg  [2*WIDTH-1:0] acc;");
    w.line("output reg [2*WIDTH-1:0] sum;");
    w.line("integer i;");
    w.always_ff("posedge clk");
    w.line("acc = {2*WIDTH{1'b0}};");
    w.begin("for (i = 0; i < N; i = i + 1)");
    w.line("acc = acc + in_flat[i*2*WIDTH +: 2*WIDTH];");
    w.end();
    w.line("sum <= acc;");
    w.end();
    w.end_module();
    w.finish()
}

pub fn mac_core(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "mac_core: K^2 parallel multipliers (DSP slices) + adder tree\n\
         (Eq. 1: N_mult = K^2). One window MAC per clock.",
    );
    w.module(
        "mac_core",
        &[("WIDTH", width.to_string()), ("K", "3".into())],
        &[
            Port::input("clk", 1),
            Port::input("win_flat", 1),
            Port::input("wgt_flat", 1),
            Port::output("mac_out", 1),
        ],
    );
    w.line("wire [K*K*WIDTH-1:0] win_flat;");
    w.line("wire [K*K*WIDTH-1:0] wgt_flat;");
    w.line("wire [2*WIDTH-1:0] mac_out;");
    w.line("reg  [K*K*2*WIDTH-1:0] products;");
    w.line("integer i;");
    w.always_ff("posedge clk");
    w.begin("for (i = 0; i < K*K; i = i + 1)");
    w.line("// each product maps to one DSP48 slice");
    w.line(
        "products[i*2*WIDTH +: 2*WIDTH] <= $signed(win_flat[i*WIDTH +: WIDTH]) * $signed(wgt_flat[i*WIDTH +: WIDTH]);",
    );
    w.end();
    w.end();
    w.blank();
    w.line("adder_tree #(.WIDTH(WIDTH), .N(K*K)) tree (");
    w.line("    .clk(clk), .in_flat(products), .sum(mac_out)");
    w.line(");");
    w.end_module();
    w.finish()
}

pub fn relu(width: usize) -> String {
    let mut w = VerilogWriter::new("relu: comparator non-linearity, 1 cycle (T_ReLU).");
    w.module(
        "relu",
        &[("WIDTH", width.to_string())],
        &[
            Port::input("clk", 1),
            Port::input("x", 0),
            Port::output_reg("y", 1),
        ],
    );
    w.line("output reg [WIDTH-1:0] y;");
    w.always_ff("posedge clk");
    w.line("y <= x[WIDTH-1] ? {WIDTH{1'b0}} : x;");
    w.end();
    w.end_module();
    w.finish()
}

pub fn conv_pe(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "conv_pe: Line Buffer Controller -> MAC core -> ReLU, the C_PE\n\
         two-stage pipeline of Sec. III-A.1.",
    );
    w.module(
        "conv_pe",
        &[
            ("WIDTH", width.to_string()),
            ("K", "3".into()),
            ("FM_W", "28".into()),
            ("STRIDE", "1".into()),
            ("RELU", "1".into()),
        ],
        &[
            Port::input("clk", 1),
            Port::input("rst", 1),
            Port::input("en", 1), // clock-gate enable (NeuroMorph)
            Port::input("px_in", 0),
            Port::input("ctrl_in", CTRL_BITS),
            Port::input("wgt_flat", 1),
            Port::output("px_out", 1),
            Port::output("valid_out", 1),
        ],
    );
    w.line("wire [K*K*WIDTH-1:0] wgt_flat;");
    w.line("wire [K*K*WIDTH-1:0] window;");
    w.line("wire window_valid;");
    w.line("wire [2*WIDTH-1:0] mac;");
    w.line("wire [WIDTH-1:0] px_out;");
    w.line("wire valid_out;");
    w.line("wire gclk;");
    w.line("// clock gating cell: BUFGCE-style enable");
    w.line("assign gclk = clk & en;");
    w.blank();
    w.line("line_buffer #(.WIDTH(WIDTH), .K(K), .FM_W(FM_W), .STRIDE(STRIDE)) lbc (");
    w.line("    .clk(gclk), .rst(rst), .px_in(px_in), .ctrl_in(ctrl_in),");
    w.line("    .window_valid(window_valid), .win_flat(window)");
    w.line(");");
    w.line("mac_core #(.WIDTH(WIDTH), .K(K)) mac_i (");
    w.line("    .clk(gclk), .win_flat(window), .wgt_flat(wgt_flat), .mac_out(mac)");
    w.line(");");
    w.blank();
    w.line("// saturating truncation back to the datapath width");
    w.line("wire [WIDTH-1:0] trunc = mac[2*WIDTH-1] ? {1'b1, {(WIDTH-1){1'b0}}} : mac[WIDTH-1:0];");
    w.line("generate if (RELU) begin : g_relu");
    w.line("    relu #(.WIDTH(WIDTH)) act (.clk(gclk), .x(trunc), .y(px_out));");
    w.line("end else begin : g_pass");
    w.line("    assign px_out = trunc;");
    w.line("end endgenerate");
    w.line("assign valid_out = window_valid & en;");
    w.end_module();
    w.finish()
}

pub fn pool_pe(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "pool_pe: PU_PE — shared line buffer + K^2 comparator tree (max)\n\
         or fixed-coefficient averaging (Sec. III-A.2). No DSP slices.",
    );
    w.module(
        "pool_pe",
        &[
            ("WIDTH", width.to_string()),
            ("K", "2".into()),
            ("FM_W", "28".into()),
            ("MODE_MAX", "1".into()),
        ],
        &[
            Port::input("clk", 1),
            Port::input("rst", 1),
            Port::input("en", 1),
            Port::input("px_in", 0),
            Port::input("ctrl_in", CTRL_BITS),
            Port::output_reg("px_out", 1),
            Port::output("valid_out", 1),
        ],
    );
    w.line("output reg [WIDTH-1:0] px_out;");
    w.line("wire [K*K*WIDTH-1:0] window;");
    w.line("wire window_valid;");
    w.line("wire valid_out;");
    w.line("wire gclk = clk & en;");
    w.line("reg [WIDTH-1:0] best;");
    w.line("reg [WIDTH+7:0] accum;");
    w.line("integer i;");
    w.blank();
    w.line("line_buffer #(.WIDTH(WIDTH), .K(K), .FM_W(FM_W), .STRIDE(K)) lbc (");
    w.line("    .clk(gclk), .rst(rst), .px_in(px_in), .ctrl_in(ctrl_in),");
    w.line("    .window_valid(window_valid), .win_flat(window)");
    w.line(");");
    w.always_ff("posedge gclk");
    w.line("best = window[0 +: WIDTH];");
    w.line("accum = {(WIDTH+8){1'b0}};");
    w.begin("for (i = 0; i < K*K; i = i + 1)");
    w.begin("if (MODE_MAX)");
    w.line("best = ($signed(window[i*WIDTH +: WIDTH]) > $signed(best)) ? window[i*WIDTH +: WIDTH] : best;");
    w.end();
    w.begin("else");
    w.line("accum = accum + window[i*WIDTH +: WIDTH];");
    w.end();
    w.end();
    w.line("px_out <= MODE_MAX ? best : accum / (K*K);");
    w.end();
    w.line("assign valid_out = window_valid & en;");
    w.end_module();
    w.finish()
}

pub fn fc_pe(width: usize) -> String {
    let mut w = VerilogWriter::new(
        "fc_pe: FC_PE streaming MAC accumulator (Eq. 5); one DSP slice,\n\
         weights preloaded, one input-weight product per clock.",
    );
    w.module(
        "fc_pe",
        &[("WIDTH", width.to_string()), ("N_IN", "1568".into())],
        &[
            Port::input("clk", 1),
            Port::input("rst", 1),
            Port::input("en", 1),
            Port::input("x_in", 0),
            Port::input("x_valid", 1),
            Port::input("wgt", 0),
            Port::input("bias", 0),
            Port::output_reg("y", 1),
            Port::output_reg("y_valid", 1),
        ],
    );
    w.line("output reg [2*WIDTH-1:0] y;");
    w.line("reg [2*WIDTH-1:0] acc;");
    w.line("reg [$clog2(N_IN):0] count;");
    w.line("wire gclk = clk & en;");
    w.always_ff("posedge gclk");
    w.begin("if (rst)");
    w.line("acc <= {2*WIDTH{1'b0}};");
    w.line("count <= 0;");
    w.line("y_valid <= 1'b0;");
    w.end();
    w.begin("else if (x_valid)");
    w.line("acc <= acc + $signed(x_in) * $signed(wgt);");
    w.line("count <= count + 1;");
    w.begin("if (count == N_IN - 1)");
    w.line("y <= acc + $signed(bias);");
    w.line("y_valid <= 1'b1;");
    w.line("acc <= {2*WIDTH{1'b0}};");
    w.line("count <= 0;");
    w.end();
    w.end();
    w.end();
    w.end_module();
    w.finish()
}

pub fn gate_ctrl() -> String {
    let mut w = VerilogWriter::new(
        "gate_ctrl: NeuroMorph clock-gating toggle bank (Sec. IV). The\n\
         runtime writes a one-hot morph-path select; each Layer-Block's\n\
         enable follows with a full-frame resynchronization delay.",
    );
    w.module(
        "gate_ctrl",
        &[("N_BLOCKS", "4".into()), ("N_PATHS", "4".into())],
        &[
            Port::input("clk", 1),
            Port::input("rst", 1),
            Port::input("path_sel", 4),
            Port::input("frame_start", 1),
            Port::output_reg("block_en", 8),
            Port::output_reg("resync", 1),
        ],
    );
    w.line("// path -> active-block mask ROM, programmed at generation time");
    w.line("reg [N_BLOCKS-1:0] mask_rom [0:N_PATHS-1];");
    w.line("reg [N_BLOCKS-1:0] pending;");
    w.line("output reg [N_BLOCKS-1:0] block_en;");
    w.line("integer p;");
    w.begin("initial");
    w.begin("for (p = 0; p < N_PATHS; p = p + 1)");
    w.line("mask_rom[p] = {N_BLOCKS{1'b1}} >> (N_PATHS - 1 - p);");
    w.end();
    w.end();
    w.always_ff("posedge clk");
    w.begin("if (rst)");
    w.line("block_en <= {N_BLOCKS{1'b1}};");
    w.line("resync <= 1'b0;");
    w.end();
    w.begin("else");
    w.line("pending <= mask_rom[path_sel];");
    w.line("// switch only on frame boundaries: in-flight frames drain");
    w.begin("if (frame_start)");
    w.line("resync <= (pending != block_en);");
    w.line("block_en <= pending;");
    w.end();
    w.end();
    w.end();
    w.end_module();
    w.finish()
}

/// The configured top-level: chains every stage of the design point.
pub fn top(
    net: &Network,
    cfg: &DesignConfig,
    eval: &DesignEval,
    top_name: &str,
    width: usize,
) -> String {
    let mut w = VerilogWriter::new(&format!(
        "{top_name}: generated streaming pipeline for '{}'\n\
         design point p = {:?} ({} PEs, {} DSP, est. {:.3} ms @ {} MHz)",
        net.name,
        cfg.parallelism,
        eval.total_pes,
        eval.resources.dsp,
        eval.latency_ms(),
        eval.clock_mhz,
    ));
    let n_blocks = net.conv_layer_ids().len();
    w.module(
        top_name,
        &[("WIDTH", width.to_string())],
        &[
            Port::input("clk", 1),
            Port::input("rst", 1),
            Port::input("px_in", 0),
            Port::input("ctrl_in", CTRL_BITS),
            Port::input("path_sel", 4),
            Port::input("frame_start", 1),
            Port::output("result", 1),
            Port::output("result_valid", 1),
        ],
    );
    w.line(&format!("wire [{}:0] block_en;", n_blocks.max(1) - 1));
    w.line("wire resync;");
    w.line(&format!(
        "gate_ctrl #(.N_BLOCKS({n_blocks}), .N_PATHS({n_blocks})) gates ("
    ));
    w.line("    .clk(clk), .rst(rst), .path_sel(path_sel),");
    w.line("    .frame_start(frame_start), .block_en(block_en), .resync(resync)");
    w.line(");");
    w.blank();

    let shapes = crate::graph::shapes::infer(net).expect("validated net");
    let mut stage = 0usize;
    let mut conv_idx = 0usize;
    let mut prev_px = "px_in".to_string();
    let mut prev_ctrl = "ctrl_in".to_string();
    for layer in &net.layers {
        let inp = shapes.input(layer.id);
        match &layer.kind {
            LayerKind::Conv { k, stride, relu, .. } | LayerKind::DwConv { k, stride, relu, .. } => {
                let lanes = eval.mappings[layer.id].pe_count;
                let block = conv_idx;
                conv_idx += 1;
                w.line(&format!(
                    "// stage {stage}: {} — {} C_PE lanes, serial x{}",
                    layer.name, lanes, eval.mappings[layer.id].serial_factor
                ));
                w.line(&format!("wire [WIDTH-1:0] s{stage}_px;"));
                w.line(&format!("wire s{stage}_valid;"));
                w.line(&format!("wire [{CTRL_BITS}-1:0] s{stage}_ctrl = {prev_ctrl};"));
                w.line(&format!(
                    "conv_pe #(.WIDTH(WIDTH), .K({k}), .FM_W({}), .STRIDE({stride}), .RELU({})) u_{} (",
                    inp.w,
                    u8::from(*relu),
                    layer.name
                ));
                w.line(&format!(
                    "    .clk(clk), .rst(rst), .en(block_en[{block}]), .px_in({prev_px}),"
                ));
                w.line(&format!(
                    "    .ctrl_in({prev_ctrl}), .wgt_flat({}'d0), .px_out(s{stage}_px), .valid_out(s{stage}_valid)",
                    k * k * width
                ));
                w.line(");");
                prev_px = format!("s{stage}_px");
                prev_ctrl = format!("s{stage}_ctrl");
                stage += 1;
            }
            LayerKind::MaxPool { k, .. } | LayerKind::AvgPool { k, .. } => {
                let is_max = matches!(layer.kind, LayerKind::MaxPool { .. });
                let block = conv_idx.saturating_sub(1);
                w.line(&format!("// stage {stage}: {}", layer.name));
                w.line(&format!("wire [WIDTH-1:0] s{stage}_px;"));
                w.line(&format!("wire s{stage}_valid;"));
                w.line(&format!("wire [{CTRL_BITS}-1:0] s{stage}_ctrl = {prev_ctrl};"));
                w.line(&format!(
                    "pool_pe #(.WIDTH(WIDTH), .K({k}), .FM_W({}), .MODE_MAX({})) u_{} (",
                    inp.w,
                    u8::from(is_max),
                    layer.name
                ));
                w.line(&format!(
                    "    .clk(clk), .rst(rst), .en(block_en[{block}]), .px_in({prev_px}),"
                ));
                w.line(&format!(
                    "    .ctrl_in({prev_ctrl}), .px_out(s{stage}_px), .valid_out(s{stage}_valid)"
                ));
                w.line(");");
                prev_px = format!("s{stage}_px");
                prev_ctrl = format!("s{stage}_ctrl");
                stage += 1;
            }
            LayerKind::Fc { out, .. } => {
                w.line(&format!("// stage {stage}: {} — {} heads", layer.name, out));
                w.line(&format!("wire [2*WIDTH-1:0] s{stage}_y;"));
                w.line(&format!("wire s{stage}_valid;"));
                w.line(&format!(
                    "fc_pe #(.WIDTH(WIDTH), .N_IN({})) u_{} (",
                    inp.features(),
                    layer.name
                ));
                w.line(&format!(
                    "    .clk(clk), .rst(rst), .en(1'b1), .x_in({prev_px}), .x_valid(1'b1),"
                ));
                w.line(&format!(
                    "    .wgt({width}'d0), .bias({width}'d0), .y(s{stage}_y), .y_valid(s{stage}_valid)"
                ));
                w.line(");");
                prev_px = format!("s{stage}_y[WIDTH-1:0]");
                stage += 1;
            }
            _ => {}
        }
    }
    w.line(&format!("assign result = {prev_px};"));
    w.line("assign result_valid = 1'b1;");
    w.end_module();
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_emit_nonempty() {
        for src in [
            line_buffer(16),
            mac_core(16),
            adder_tree(16),
            relu(8),
            pool_pe(16),
            fc_pe(16),
            gate_ctrl(),
        ] {
            assert!(src.contains("endmodule"));
            assert!(src.len() > 200);
        }
    }

    #[test]
    fn mac_core_instantiates_tree() {
        let src = mac_core(16);
        assert!(src.contains("adder_tree #(.WIDTH(WIDTH), .N(K*K))"));
        assert!(src.contains("DSP48"));
    }

    #[test]
    fn gate_ctrl_has_frame_sync() {
        let src = gate_ctrl();
        assert!(src.contains("frame_start"));
        assert!(src.contains("mask_rom"));
    }

    #[test]
    fn conv_pe_has_enable_gating() {
        let src = conv_pe(8);
        assert!(src.contains("clk & en"));
        assert!(src.contains("line_buffer #("));
    }
}
