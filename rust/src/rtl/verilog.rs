//! Verilog source construction primitives.
//!
//! A thin writer that tracks indentation and balances `module`/
//! `endmodule`, `begin`/`end` pairs — the emitter building block shared
//! by every generated module.

use std::fmt::Write as _;

/// Port direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    Input,
    Output,
    OutputReg,
}

/// A module port declaration.
#[derive(Debug, Clone)]
pub struct Port {
    pub dir: Dir,
    pub width: usize,
    pub name: String,
}

impl Port {
    pub fn input(name: &str, width: usize) -> Port {
        Port { dir: Dir::Input, width, name: name.into() }
    }

    pub fn output(name: &str, width: usize) -> Port {
        Port { dir: Dir::Output, width, name: name.into() }
    }

    pub fn output_reg(name: &str, width: usize) -> Port {
        Port { dir: Dir::OutputReg, width, name: name.into() }
    }
}

/// Indented Verilog writer.
pub struct VerilogWriter {
    buf: String,
    indent: usize,
    opened_modules: usize,
    opened_blocks: usize,
}

impl VerilogWriter {
    pub fn new(header_comment: &str) -> VerilogWriter {
        let mut w = VerilogWriter {
            buf: String::new(),
            indent: 0,
            opened_modules: 0,
            opened_blocks: 0,
        };
        for line in header_comment.lines() {
            let _ = writeln!(w.buf, "// {line}");
        }
        w.line("`timescale 1ns / 1ps");
        w.blank();
        w
    }

    pub fn line(&mut self, s: &str) {
        for _ in 0..self.indent {
            self.buf.push_str("    ");
        }
        self.buf.push_str(s);
        self.buf.push('\n');
    }

    pub fn blank(&mut self) {
        self.buf.push('\n');
    }

    /// Open `module name #(params) (ports);`
    pub fn module(&mut self, name: &str, params: &[(&str, String)], ports: &[Port]) {
        self.opened_modules += 1;
        if params.is_empty() {
            self.line(&format!("module {name} ("));
        } else {
            self.line(&format!("module {name} #("));
            self.indent += 1;
            for (i, (p, v)) in params.iter().enumerate() {
                let comma = if i + 1 < params.len() { "," } else { "" };
                self.line(&format!("parameter {p} = {v}{comma}"));
            }
            self.indent -= 1;
            self.line(") (");
        }
        self.indent += 1;
        for (i, p) in ports.iter().enumerate() {
            let dir = match p.dir {
                Dir::Input => "input  wire",
                Dir::Output => "output wire",
                Dir::OutputReg => "output reg ",
            };
            let width = if p.width > 1 {
                format!("[{}:0] ", p.width - 1)
            } else if p.width == 1 {
                String::new()
            } else {
                // parameterized width expressed via WIDTH param
                "[WIDTH-1:0] ".to_string()
            };
            let comma = if i + 1 < ports.len() { "," } else { "" };
            self.line(&format!("{dir} {width}{}{comma}", p.name));
        }
        self.indent -= 1;
        self.line(");");
        self.indent += 1;
    }

    pub fn end_module(&mut self) {
        assert!(self.opened_modules > 0, "end_module without module");
        assert_eq!(self.opened_blocks, 0, "unclosed begin blocks in module");
        self.opened_modules -= 1;
        self.indent -= 1;
        self.line("endmodule");
        self.blank();
    }

    /// `always @(posedge clk) begin`
    pub fn always_ff(&mut self, trigger: &str) {
        self.line(&format!("always @({trigger}) begin"));
        self.opened_blocks += 1;
        self.indent += 1;
    }

    pub fn begin(&mut self, head: &str) {
        self.line(&format!("{head} begin"));
        self.opened_blocks += 1;
        self.indent += 1;
    }

    pub fn end(&mut self) {
        assert!(self.opened_blocks > 0, "end without begin");
        self.opened_blocks -= 1;
        self.indent -= 1;
        self.line("end");
    }

    pub fn finish(self) -> String {
        assert_eq!(self.opened_modules, 0, "unterminated module");
        assert_eq!(self.opened_blocks, 0, "unterminated block");
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_balanced_module() {
        let mut w = VerilogWriter::new("test");
        w.module(
            "m",
            &[("WIDTH", "16".into())],
            &[Port::input("clk", 1), Port::output("q", 0)],
        );
        w.always_ff("posedge clk");
        w.line("q <= 1'b0;");
        w.end();
        w.end_module();
        let src = w.finish();
        assert!(src.contains("module m #("));
        assert!(src.contains("parameter WIDTH = 16"));
        assert!(src.contains("output wire [WIDTH-1:0] q"));
        assert!(src.contains("endmodule"));
    }

    #[test]
    #[should_panic(expected = "unterminated module")]
    fn unbalanced_module_panics() {
        let mut w = VerilogWriter::new("t");
        w.module("m", &[], &[Port::input("clk", 1)]);
        let _ = w.finish();
    }

    #[test]
    #[should_panic(expected = "end without begin")]
    fn unbalanced_block_panics() {
        let mut w = VerilogWriter::new("t");
        w.end();
    }

    #[test]
    fn port_widths() {
        let mut w = VerilogWriter::new("t");
        w.module("m", &[], &[Port::input("bus", 5), Port::output_reg("r", 1)]);
        w.end_module();
        let src = w.finish();
        assert!(src.contains("input  wire [4:0] bus"));
        assert!(src.contains("output reg  r"));
    }
}
