//! RTL emission — the compiler back-end (Fig. 1's "RTL generation").
//!
//! Generates synthesizable Verilog-2001 for a selected design point: per
//! layer a parameterized PE bank (line buffer + MAC core + adder tree for
//! conv; comparator tree for pooling; MAC accumulators for FC), plus a
//! top module chaining the stages with the 5-bit streaming control bus of
//! Fig. 4 (`Valid, hStart, hEnd, vStart, vEnd`).
//!
//! The emitter is deliberately template-free: every module is built from
//! the same [`VerilogWriter`] primitives so the structure is auditable
//! and golden-testable. We validate structure (ports, hierarchy, balanced
//! blocks), not synthesis — Vivado is out of scope offline (DESIGN.md §2).

pub mod modules;
pub mod verilog;

use crate::design::{DesignConfig, DesignEval};
use crate::graph::passes::{self, StagePlan};
use crate::graph::{LayerKind, Network};
use crate::pe::FpRep;

/// A generated RTL bundle: (file name, Verilog source) pairs.
#[derive(Debug, Clone)]
pub struct RtlBundle {
    pub files: Vec<(String, String)>,
    pub top_name: String,
}

impl RtlBundle {
    /// Total emitted source size (for reports).
    pub fn total_bytes(&self) -> usize {
        self.files.iter().map(|(_, s)| s.len()).sum()
    }

    pub fn file(&self, name: &str) -> Option<&str> {
        self.files
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.as_str())
    }

    /// Write all files into a directory.
    pub fn write_to(&self, dir: &std::path::Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        for (name, src) in &self.files {
            std::fs::write(dir.join(name), src)?;
        }
        Ok(())
    }
}

/// Emit the full RTL bundle for a design point. Schedules the pass
/// pipeline internally; holders of a [`StagePlan`] can use
/// [`emit_plan`].
pub fn emit(net: &Network, cfg: &DesignConfig, eval: &DesignEval) -> RtlBundle {
    let plan = passes::schedule(net).expect("validated network");
    emit_plan(&plan, cfg, eval)
}

/// Emit the full RTL bundle against a pre-scheduled plan.
pub fn emit_plan(plan: &StagePlan, cfg: &DesignConfig, eval: &DesignEval) -> RtlBundle {
    let width = match cfg.rep {
        FpRep::Int8 => 8,
        FpRep::Int16 => 16,
    };
    let mut files = vec![
        ("line_buffer.v".to_string(), modules::line_buffer(width)),
        ("mac_core.v".to_string(), modules::mac_core(width)),
        ("adder_tree.v".to_string(), modules::adder_tree(width)),
        ("relu.v".to_string(), modules::relu(width)),
        ("pool_pe.v".to_string(), modules::pool_pe(width)),
        ("fc_pe.v".to_string(), modules::fc_pe(width)),
        ("conv_pe.v".to_string(), modules::conv_pe(width)),
        ("concat_mux.v".to_string(), modules::concat_mux(width)),
        ("upsample.v".to_string(), modules::upsample(width)),
        ("spp_pe.v".to_string(), modules::spp_pe(width)),
        ("gate_ctrl.v".to_string(), modules::gate_ctrl()),
    ];
    let top_name = format!("{}_top", sanitize(&plan.net_name));
    files.push((format!("{top_name}.v"), modules::top(plan, cfg, eval, &top_name, width)));
    RtlBundle { files, top_name }
}

/// Identifier-safe module name.
pub fn sanitize(name: &str) -> String {
    let mut s: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    if s.chars().next().map(|c| c.is_ascii_digit()).unwrap_or(true) {
        s.insert(0, 'm');
    }
    s
}

/// Count emitted hardware stages (for reporting emitted hierarchy).
pub fn stage_count(net: &Network) -> usize {
    net.layers
        .iter()
        .filter(|l| {
            matches!(
                l.kind,
                LayerKind::Conv { .. }
                    | LayerKind::DwConv { .. }
                    | LayerKind::MaxPool { .. }
                    | LayerKind::AvgPool { .. }
                    | LayerKind::Fc { .. }
                    | LayerKind::Concat { .. }
                    | LayerKind::Upsample { .. }
                    | LayerKind::SpatialPyramidPool { .. }
            )
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design;
    use crate::graph::zoo;
    use crate::pe::{FpRep, ZYNQ_7100};

    fn bundle() -> RtlBundle {
        let net = zoo::mnist();
        let cfg = design::DesignConfig::uniform(&net, 2, FpRep::Int16);
        let eval = design::evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
        emit(&net, &cfg, &eval)
    }

    #[test]
    fn bundle_has_all_primitives() {
        let b = bundle();
        for f in [
            "line_buffer.v",
            "mac_core.v",
            "adder_tree.v",
            "conv_pe.v",
            "pool_pe.v",
            "fc_pe.v",
            "concat_mux.v",
            "upsample.v",
            "spp_pe.v",
            "gate_ctrl.v",
        ] {
            assert!(b.file(f).is_some(), "missing {f}");
        }
        assert_eq!(b.top_name, "mnist_8_16_32_top");
    }

    #[test]
    fn branchy_top_wires_merges() {
        let net = zoo::unet_tiny();
        let cfg = design::DesignConfig::uniform(&net, 2, FpRep::Int16);
        let eval = design::evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
        let b = emit(&net, &cfg, &eval);
        let top = b.file(&format!("{}.v", b.top_name)).unwrap();
        assert!(top.contains("concat_mux #("), "no concat instance");
        assert!(top.contains("upsample #("), "no upsample instance");
        // module/endmodule stays balanced on a DAG top
        assert_eq!(top.matches("module ").count(), top.matches("endmodule").count());
    }

    #[test]
    fn yolo_top_instantiates_sppf() {
        let net = zoo::yolov5l();
        let cfg = design::DesignConfig::uniform(&net, 1, FpRep::Int8);
        let eval = design::evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
        let b = emit(&net, &cfg, &eval);
        let top = b.file(&format!("{}.v", b.top_name)).unwrap();
        assert!(top.contains("spp_pe #("));
        assert!(top.matches("concat_mux #(").count() >= 10, "yolo has many concats");
    }

    #[test]
    fn every_file_balanced_module_endmodule() {
        let b = bundle();
        for (name, src) in &b.files {
            let m = src.matches("module ").count() - src.matches("endmodule").count();
            let e = src.matches("endmodule").count();
            assert!(e >= 1, "{name} lacks endmodule");
            assert_eq!(m, 0, "{name}: unbalanced module/endmodule");
            assert!(src.contains("input"), "{name}: no ports");
        }
    }

    #[test]
    fn top_instantiates_each_conv_stage() {
        let b = bundle();
        let top = b.file("mnist_8_16_32_top.v").unwrap();
        // 3 conv layers in mnist zoo net
        assert_eq!(top.matches("conv_pe #(").count(), 3);
        // pooling stages
        assert!(top.matches("pool_pe #(").count() >= 3);
        // gating controller for NeuroMorph
        assert!(top.contains("gate_ctrl"));
    }

    #[test]
    fn datapath_width_follows_rep() {
        let net = zoo::mnist();
        let cfg8 = design::DesignConfig::uniform(&net, 1, FpRep::Int8);
        let eval = design::evaluate(&net, &cfg8, &ZYNQ_7100).unwrap();
        let b = emit(&net, &cfg8, &eval);
        assert!(b.file("mac_core.v").unwrap().contains("WIDTH = 8"));
    }

    #[test]
    fn sanitize_identifiers() {
        assert_eq!(sanitize("mnist-8-16-32"), "mnist_8_16_32");
        assert_eq!(sanitize("8start"), "m8start");
    }

    #[test]
    fn write_to_disk() {
        let dir = std::env::temp_dir().join("forgemorph_rtl_test");
        let _ = std::fs::remove_dir_all(&dir);
        bundle().write_to(&dir).unwrap();
        assert!(dir.join("conv_pe.v").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
