//! CSV export of the figure-backing data series.
//!
//! The `report` functions print paper-formatted blocks; plotting needs
//! raw series. `forgemorph report <id> --csv <dir>` (and the tests here)
//! write the underlying data: the Fig. 2 scatter + front, the Fig. 10/
//! Table III est-vs-real rows, and the Fig. 11/12 morphing curves.

use std::fmt::Write as _;
use std::path::Path;

use crate::design::DesignConfig;
use crate::dse;
use crate::graph::zoo;
use crate::pe::{FpRep, ZYNQ_7100};
use crate::sim::{self, GateMask};

/// A generic CSV table.
#[derive(Debug, Clone)]
pub struct Csv {
    pub name: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.header.join(","));
        for row in &self.rows {
            let _ = writeln!(s, "{}", row.join(","));
        }
        s
    }

    pub fn write_to(&self, dir: &Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{}.csv", self.name)), self.to_string())
    }
}

/// Fig. 2 data: every evaluated (latency, dsp) point + front membership.
pub fn fig2_csv(pop: usize, gens: usize, seed: u64) -> Csv {
    let net = zoo::cifar10();
    let cfg = dse::DseConfig {
        population: pop,
        generations: gens,
        seed,
        constraints: dse::Constraints::device(&ZYNQ_7100),
        ..dse::DseConfig::default()
    };
    let res = dse::run(&net, &ZYNQ_7100, &cfg);
    let front: std::collections::BTreeSet<(u64, usize)> = res
        .pareto
        .iter()
        .map(|c| (c.objectives.latency_ms.to_bits(), c.objectives.dsp))
        .collect();
    Csv {
        name: "fig2_pareto".into(),
        header: vec!["latency_ms".into(), "dsp".into(), "on_front".into()],
        rows: res
            .evaluated
            .iter()
            .map(|&(lat, dsp)| {
                vec![
                    format!("{lat:.6}"),
                    dsp.to_string(),
                    u8::from(front.contains(&(lat.to_bits(), dsp))).to_string(),
                ]
            })
            .collect(),
    }
}

/// Fig. 10 / Table III data: est-vs-real per (model, p).
pub fn fig10_csv() -> Csv {
    let mut rows = Vec::new();
    for name in ["mnist", "svhn", "cifar10"] {
        let net = zoo::by_name(name).unwrap();
        for p in [8usize, 4, 2, 1] {
            let cfg = DesignConfig::uniform(&net, p, FpRep::Int16);
            let est = crate::design::evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
            let real = sim::simulate(&net, &cfg, &ZYNQ_7100, &GateMask::all_active());
            rows.push(vec![
                name.to_string(),
                p.to_string(),
                est.resources.dsp.to_string(),
                real.resources.dsp.to_string(),
                est.resources.lut.to_string(),
                real.resources.lut.to_string(),
                est.resources.bram.to_string(),
                real.resources.bram.to_string(),
                format!("{:.6}", est.latency_ms()),
                format!("{:.6}", real.latency_ms()),
                format!("{:.1}", real.power_mw),
            ]);
        }
    }
    Csv {
        name: "fig10_est_vs_real".into(),
        header: [
            "model", "p", "dsp_est", "dsp_real", "lut_est", "lut_real",
            "bram_est", "bram_real", "lat_est_ms", "lat_real_ms", "power_mw",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
        rows,
    }
}

/// Fig. 11/12 data: morphing curves across all small models.
pub fn morphing_csv() -> Csv {
    let manifest = super::try_manifest();
    let mut rows = Vec::new();
    for name in ["mnist", "svhn", "cifar10"] {
        let net = zoo::by_name(name).unwrap();
        let n = net.conv_layer_ids().len();
        for p in [8usize, 4, 2] {
            let cfg = DesignConfig::uniform(&net, p, FpRep::Int16);
            let mut push = |mode: &str, mask: GateMask, path: String| {
                let r = sim::simulate(&net, &cfg, &ZYNQ_7100, &mask);
                let acc = manifest
                    .as_ref()
                    .and_then(|m| m.model(name))
                    .and_then(|mm| mm.paths.iter().find(|pa| pa.path.name == path))
                    .map(|pa| format!("{:.4}", pa.path.accuracy))
                    .unwrap_or_default();
                rows.push(vec![
                    name.to_string(),
                    p.to_string(),
                    mode.to_string(),
                    path,
                    format!("{:.6}", r.latency_ms()),
                    format!("{:.1}", r.power_mw),
                    acc,
                ]);
            };
            for depth in 1..=n {
                let mask = if depth == n {
                    GateMask::all_active()
                } else {
                    GateMask::depth_prefix(&net, depth)
                };
                push("depth", mask, format!("d{depth}_w100"));
            }
            push("width", GateMask::width(0.5), format!("d{n}_w50"));
        }
    }
    Csv {
        name: "fig11_12_morphing".into(),
        header: ["model", "p", "mode", "path", "latency_ms", "power_mw", "accuracy"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        rows,
    }
}

/// Write every exportable series into `dir`.
pub fn export_all(dir: &Path) -> std::io::Result<Vec<String>> {
    let tables = [fig2_csv(48, 20, 7), fig10_csv(), morphing_csv()];
    let mut names = Vec::new();
    for t in &tables {
        t.write_to(dir)?;
        names.push(format!("{}.csv", t.name));
    }
    Ok(names)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_csv_marks_front_subset() {
        let csv = fig2_csv(16, 4, 1);
        assert_eq!(csv.header.len(), 3);
        let on_front = csv.rows.iter().filter(|r| r[2] == "1").count();
        assert!(on_front > 0 && on_front < csv.rows.len());
    }

    #[test]
    fn fig10_csv_rows_complete() {
        let csv = fig10_csv();
        assert_eq!(csv.rows.len(), 12); // 3 models x 4 configs
        for row in &csv.rows {
            assert_eq!(row.len(), csv.header.len());
            // dsp est == real (the exact columns)
            assert_eq!(row[2], row[3]);
        }
    }

    #[test]
    fn morphing_csv_covers_depth_and_width() {
        let csv = morphing_csv();
        assert!(csv.rows.iter().any(|r| r[2] == "depth"));
        assert!(csv.rows.iter().any(|r| r[2] == "width"));
        // mnist: 3 p-levels x (3 depth + 1 width) = 12 rows
        assert_eq!(csv.rows.iter().filter(|r| r[0] == "mnist").count(), 12);
    }

    #[test]
    fn export_writes_files() {
        let dir = std::env::temp_dir().join("forgemorph_csv_test");
        let _ = std::fs::remove_dir_all(&dir);
        let names = export_all(&dir).unwrap();
        assert_eq!(names.len(), 3);
        for n in names {
            assert!(dir.join(n).exists());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
