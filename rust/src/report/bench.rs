//! Perf-regression gate over the `BENCH_*.json` trajectory files
//! (`forgemorph report bench-check`).
//!
//! The bench harness (`cargo bench --bench bench_hotpath`) writes
//! machine-readable results to `BENCH_dse.json` / `BENCH_distill.json`
//! at the repo root; the committed copies are the baselines of the perf
//! trajectory. This module diffs a fresh run against a baseline:
//!
//! * **Gated by default — machine-independent metrics.** Parallel
//!   speedups (`speedup*`) and determinism booleans
//!   (`front_identical`) do not depend on the host's absolute speed:
//!   a drop beyond the tolerance is a real engine regression (lost
//!   parallel efficiency, broken thread invariance) wherever the bench
//!   runs.
//! * **Informational by default — absolute metrics.** Wall times,
//!   per-candidate µs, samples/s and cache-hit rates vary with the
//!   host; they are reported with their deltas and gated only under
//!   `--absolute` (for trajectory tracking on a fixed reference
//!   machine).
//!
//! A baseline still carrying `"provisional": true` (a hand-estimated
//! placeholder that was never measured on the reference machine) is
//! flagged loudly at the top of the report; the flag is metadata and is
//! never itself compared.
//!
//! Refresh baselines on the reference machine with
//! `BENCH_MS=800 cargo bench --bench bench_hotpath` and commit the
//! rewritten `BENCH_*.json` (see DESIGN.md §10-§11).

use std::fmt::Write as _;

use crate::util::json::Json;

/// Outcome of one baseline/current comparison.
#[derive(Debug, Default)]
pub struct GateResult {
    /// one human-readable line per compared metric
    pub lines: Vec<String>,
    /// metric paths that regressed beyond tolerance
    pub regressions: Vec<String>,
    /// metrics that actually gated (regression-capable)
    pub gated: usize,
}

impl GateResult {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }

    /// The full report as one printable block.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for l in &self.lines {
            let _ = writeln!(s, "{l}");
        }
        s
    }
}

/// Metric class, inferred from the key path.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Class {
    /// machine-independent, higher is better (speedups) — always gated
    RelativeHigher,
    /// absolute time, lower is better — gated only with `gate_absolute`
    AbsoluteLower,
    /// absolute rate, higher is better — gated only with `gate_absolute`
    AbsoluteHigher,
    /// reported with delta, never gated
    Info,
}

fn classify(path: &str) -> Class {
    let leaf = path.rsplit('.').next().unwrap_or(path);
    if leaf.contains("speedup") {
        Class::RelativeHigher
    } else if leaf.ends_with("_ms") || leaf.ends_with("_us") || leaf == "mean" || leaf == "p50" {
        Class::AbsoluteLower
    } else if leaf.contains("per_sec") {
        Class::AbsoluteHigher
    } else {
        Class::Info
    }
}

/// Compare a current bench JSON against a baseline. `tolerance_pct` is
/// the allowed relative slack; `gate_absolute` promotes absolute
/// time/throughput metrics from informational to gated.
pub fn check(
    baseline: &Json,
    current: &Json,
    tolerance_pct: f64,
    gate_absolute: bool,
) -> GateResult {
    let mut out = GateResult::default();
    if matches!(baseline.get("provisional"), Some(Json::Bool(true))) {
        // loud, but the warning itself never fails the check — metrics
        // below still gate as usual; the flag is metadata flagging a
        // hand-estimated placeholder that needs a real measurement
        out.lines.push(
            "WARN baseline is PROVISIONAL (estimated, never measured on the \
             reference machine): treat the deltas below with suspicion — \
             refresh with `BENCH_MS=800 cargo bench --bench bench_hotpath` on \
             the reference machine and commit the rewritten BENCH_*.json \
             (DESIGN.md §11)"
                .to_string(),
        );
    }
    let tol = tolerance_pct.max(0.0) / 100.0;
    walk("", baseline, current, tol, gate_absolute, &mut out);
    out
}

fn walk(path: &str, base: &Json, cur: &Json, tol: f64, gate_abs: bool, out: &mut GateResult) {
    let join = |key: &str| {
        if path.is_empty() {
            key.to_string()
        } else {
            format!("{path}.{key}")
        }
    };
    match (base, cur) {
        (Json::Obj(b), Json::Obj(c)) => {
            for (k, bv) in b {
                if path.is_empty() && k == "provisional" {
                    // baseline metadata, surfaced as the WARN header —
                    // never compared (a fresh run dropping the flag is
                    // the desired outcome, not a regression)
                    continue;
                }
                match c.get(k) {
                    Some(cv) => walk(&join(k), bv, cv, tol, gate_abs, out),
                    None => out.lines.push(format!("note {}: missing in current run", join(k))),
                }
            }
        }
        (Json::Arr(b), Json::Arr(c)) => {
            for (i, bv) in b.iter().enumerate() {
                if let Some(cv) = c.get(i) {
                    walk(&join(&i.to_string()), bv, cv, tol, gate_abs, out);
                } else {
                    out.lines.push(format!(
                        "note {path}: baseline has {} entries, current {}",
                        b.len(),
                        c.len()
                    ));
                    break;
                }
            }
        }
        (Json::Bool(b), Json::Bool(c)) => {
            out.gated += 1;
            if *b && !*c {
                out.regressions.push(path.to_string());
                out.lines.push(format!("REGR {path}: was true, now false"));
            } else {
                out.lines.push(format!("ok   {path}: {c}"));
            }
        }
        (Json::Num(b), Json::Num(c)) => num_metric(path, *b, *c, tol, gate_abs, out),
        _ => {}
    }
}

fn num_metric(path: &str, base: f64, cur: f64, tol: f64, gate_abs: bool, out: &mut GateResult) {
    let class = classify(path);
    let delta_pct = if base != 0.0 { (cur - base) / base * 100.0 } else { 0.0 };
    let gate = match class {
        Class::RelativeHigher => true,
        Class::AbsoluteLower | Class::AbsoluteHigher => gate_abs,
        Class::Info => false,
    };
    let regressed = match class {
        Class::RelativeHigher | Class::AbsoluteHigher => cur < base * (1.0 - tol),
        Class::AbsoluteLower => cur > base * (1.0 + tol),
        Class::Info => false,
    };
    if gate {
        out.gated += 1;
        if regressed {
            out.regressions.push(path.to_string());
            out.lines.push(format!(
                "REGR {path}: {cur:.4} vs baseline {base:.4} ({delta_pct:+.1}%)"
            ));
            return;
        }
        out.lines.push(format!(
            "ok   {path}: {cur:.4} vs baseline {base:.4} ({delta_pct:+.1}%)"
        ));
    } else {
        out.lines.push(format!(
            "info {path}: {cur:.4} vs baseline {base:.4} ({delta_pct:+.1}%)"
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn j(text: &str) -> Json {
        Json::parse(text).expect("valid test json")
    }

    #[test]
    fn speedup_drop_beyond_tolerance_regresses() {
        let base = j(r#"{"threads": [{"threads": 4, "speedup_vs_serial_nomemo": 3.0}]}"#);
        let ok = j(r#"{"threads": [{"threads": 4, "speedup_vs_serial_nomemo": 2.5}]}"#);
        let bad = j(r#"{"threads": [{"threads": 4, "speedup_vs_serial_nomemo": 2.0}]}"#);
        let r = check(&base, &ok, 20.0, false);
        assert!(r.passed(), "{:?}", r.regressions);
        let r = check(&base, &bad, 20.0, false);
        assert!(!r.passed());
        assert_eq!(r.regressions, vec!["threads.0.speedup_vs_serial_nomemo"]);
    }

    #[test]
    fn boolean_flip_regresses() {
        let base = j(r#"{"front_identical": true}"#);
        let r = check(&base, &j(r#"{"front_identical": false}"#), 20.0, false);
        assert!(!r.passed());
        let r = check(&base, &j(r#"{"front_identical": true}"#), 20.0, false);
        assert!(r.passed());
        assert_eq!(r.gated, 1);
    }

    #[test]
    fn absolute_times_gate_only_on_request() {
        let base = j(r#"{"wall_ms": 100.0, "samples_per_sec": 5000.0}"#);
        let slow = j(r#"{"wall_ms": 200.0, "samples_per_sec": 2000.0}"#);
        // informational by default: a slower machine must not fail CI
        let r = check(&base, &slow, 20.0, false);
        assert!(r.passed());
        assert_eq!(r.gated, 0);
        assert!(r.report().contains("info wall_ms"));
        // --absolute promotes them
        let r = check(&base, &slow, 20.0, true);
        assert!(!r.passed());
        assert!(r.regressions.contains(&"wall_ms".to_string()));
        assert!(r.regressions.contains(&"samples_per_sec".to_string()));
    }

    #[test]
    fn improvements_and_info_fields_pass() {
        let base = j(r#"{"cache_hit_rate": 0.4, "floor": 0.8, "paths": 8,
                          "threads": [{"speedup": 2.0}]}"#);
        let cur = j(r#"{"cache_hit_rate": 0.1, "floor": 0.7, "paths": 8,
                         "threads": [{"speedup": 4.0}]}"#);
        let r = check(&base, &cur, 20.0, false);
        assert!(r.passed(), "{:?}", r.regressions);
        // only the speedup gated
        assert_eq!(r.gated, 1);
    }

    #[test]
    fn missing_keys_are_noted_not_fatal() {
        let base = j(r#"{"threads": [{"speedup": 2.0}], "gone": 1.0}"#);
        let cur = j(r#"{"threads": [{"speedup": 2.0}]}"#);
        let r = check(&base, &cur, 20.0, false);
        assert!(r.passed());
        assert!(r.report().contains("missing in current run"));
    }

    #[test]
    fn provisional_baseline_warns_but_never_gates() {
        let base = j(r#"{"provisional": true, "speedup": 4.0}"#);
        let cur = j(r#"{"speedup": 1.0}"#);
        let r = check(&base, &cur, 20.0, false);
        assert!(r.report().contains("WARN baseline is PROVISIONAL"));
        // the flag itself is metadata: not compared, not "missing"
        assert!(!r.report().contains("provisional: missing"));
        // real metrics still gate as usual against a provisional baseline
        assert!(!r.passed());
        // a refreshed (non-provisional) baseline stays quiet
        let base = j(r#"{"speedup": 4.0}"#);
        let r = check(&base, &j(r#"{"speedup": 4.0}"#), 20.0, false);
        assert!(!r.report().contains("PROVISIONAL"));
        assert!(r.passed());
    }

    #[test]
    fn tolerance_boundary_is_exclusive() {
        let base = j(r#"{"speedup": 1.0}"#);
        // exactly at the edge stays ok; just past it regresses
        assert!(check(&base, &j(r#"{"speedup": 0.8}"#), 20.0, false).passed());
        assert!(!check(&base, &j(r#"{"speedup": 0.79}"#), 20.0, false).passed());
    }
}
