//! Report harness: regenerates every table and figure of the paper's
//! evaluation (Sec. V) from this reproduction's own models and simulator.
//!
//! Each `table*`/`fig*` function returns the formatted block the CLI
//! prints (`forgemorph report <id>`); `all()` concatenates everything.
//! Baseline rows that are published measurements (other compilers, edge
//! devices, ImageNet accuracies) come from [`crate::baselines`] and are
//! marked `[ref]`; every ForgeMorph row is computed live.

pub mod bench;
pub mod export;

use std::fmt::Write as _;
use std::path::Path;

use crate::baselines;
use crate::design::{self, DesignConfig};
use crate::dse;
use crate::graph::{zoo, Network};
use crate::morph::{MorphPath, PathRegistry};
use crate::pe::{luts, Device, FpRep, ZYNQ_7100};
use crate::power::PowerModel;
use crate::runtime::Manifest;
use crate::sim::{self, GateMask};

/// Small-benchmark list used across Table III / Figs. 10-12.
const SMALL_MODELS: &[&str] = &["mnist", "svhn", "cifar10"];

/// Uniform-parallelism ladder standing in for "NeuroForge configurations
/// of varying sizes" where the paper does not pin exact mappings.
const CONFIG_LADDER: &[usize] = &[8, 4, 2, 1];

fn header(title: &str) -> String {
    format!("\n=== {title} ===\n")
}

fn pct_err(est: f64, real: f64) -> f64 {
    if real == 0.0 {
        return 0.0;
    }
    ((est - real) / real * 100.0).abs()
}

fn opt_f(v: Option<f64>, unit: &str) -> String {
    v.map(|x| format!("{x:.2}{unit}")).unwrap_or_else(|| "NA".into())
}

/// Load the artifacts manifest if `make artifacts` has run.
pub fn try_manifest() -> Option<Manifest> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Manifest::load(&dir).ok()
}

fn manifest_accuracy(manifest: &Option<Manifest>, model: &str, path: &str) -> Option<f64> {
    manifest
        .as_ref()?
        .model(model)?
        .paths
        .iter()
        .find(|p| p.path.name == path)
        .map(|p| p.path.accuracy)
}

// ---------------------------------------------------------------------------
// Table I / II
// ---------------------------------------------------------------------------

/// Table I: per-filter-size LUT/FF constants (estimator inputs).
pub fn table1() -> String {
    let mut s = header("Table I: Resource utilization for different filter sizes");
    let _ = writeln!(s, "{:<12} {:>9} {:>9} {:>10} {:>10}", "Filter", "LUT conv", "LUT pool", "FF conv", "FF pool");
    for k in [2, 3, 4, 5] {
        let _ = writeln!(
            s,
            "{:<12} {:>9} {:>9} {:>10} {:>10}",
            format!("{k}x{k}"),
            luts::conv_luts(k),
            luts::pool_luts(k),
            luts::conv_regs(k),
            luts::pool_regs(k)
        );
    }
    s
}

/// Table II: benchmark architectures — paper counts vs our descriptors.
pub fn table2() -> String {
    let mut s = header("Table II: Architectures used for validation");
    let _ = writeln!(
        s,
        "{:<12} {:<16} {:>14} {:>13} {:>14} {:>13}",
        "Dataset", "Architecture", "paper params", "paper ops", "ours params", "ours MACs"
    );
    let nets: Vec<(&str, Network)> = vec![
        ("mnist", zoo::mnist()),
        ("svhn", zoo::svhn()),
        ("cifar10", zoo::cifar10()),
        ("resnet50", zoo::resnet50()),
        ("mobilenetv2", zoo::mobilenet_v2()),
        ("squeezenet", zoo::squeezenet()),
        ("yolov5l", zoo::yolov5l()),
    ];
    for ((dataset, arch, p_params, p_ops), (_, net)) in
        zoo::TABLE2_ROWS.iter().zip(nets.iter())
    {
        let _ = writeln!(
            s,
            "{:<12} {:<16} {:>14} {:>13} {:>14} {:>13}",
            dataset,
            arch,
            fmt_count(*p_params),
            fmt_count(*p_ops),
            fmt_count(net.count_params().unwrap() as f64),
            fmt_count(net.count_macs().unwrap() as f64)
        );
    }
    let _ = writeln!(
        s,
        "note: paper op counts include its (unspecified) FC stacks; our\n\
         descriptors use the deployed morphable heads — conv scale matches."
    );
    s
}

fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}B", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else {
        format!("{:.2}K", x / 1e3)
    }
}

// ---------------------------------------------------------------------------
// Fig. 2 / Fig. 8 — DSE behaviour
// ---------------------------------------------------------------------------

/// Fig. 2: Pareto front of DSP vs latency for the CIFAR-10 model.
pub fn fig2(pop: usize, gens: usize, seed: u64) -> String {
    let net = zoo::cifar10();
    let cfg = dse::DseConfig {
        population: pop,
        generations: gens,
        seed,
        constraints: dse::Constraints::device(&ZYNQ_7100),
        ..dse::DseConfig::default()
    };
    let res = dse::run(&net, &ZYNQ_7100, &cfg);
    let mut s = header("Fig. 2: NeuroForge DSE Pareto front (CIFAR-10 8-16-32-64-64)");
    let _ = writeln!(
        s,
        "evaluated {} candidates across {} generations (pop {})",
        res.evaluations, gens, pop
    );
    let _ = writeln!(
        s,
        "search telemetry: {} unique evaluations, cache hit rate {:.0}% \
         (chromosome), stage hit rate {:.0}% (segment), {:.1} ms wall",
        res.unique_evaluations,
        res.cache_hit_rate() * 100.0,
        res.stage_hit_rate() * 100.0,
        res.wall_ms
    );
    let _ = writeln!(s, "{:<28} {:>8} {:>12} {:>10}", "parallelism p(i)", "DSP", "latency ms", "PEs");
    for c in &res.pareto {
        let _ = writeln!(
            s,
            "{:<28} {:>8} {:>12.4} {:>10}",
            format!("{:?}", c.config.parallelism),
            c.objectives.dsp,
            c.objectives.latency_ms,
            c.objectives.total_pes
        );
    }
    let lo = res.pareto.first().map(|c| c.objectives.latency_ms).unwrap_or(0.0);
    let hi = res.pareto.last().map(|c| c.objectives.latency_ms).unwrap_or(0.0);
    let _ = writeln!(s, "front spans {:.1}x in latency ({:.4} .. {:.4} ms)", hi / lo.max(1e-12), lo, hi);
    s
}

/// Fig. 8: PE allocation example — how a p-vector expands via Eq. 14.
pub fn fig8() -> String {
    let net = zoo::mnist();
    let mut s = header("Fig. 8: Design-space generations (Eq. 14 PE expansion, MNIST)");
    for p in [vec![1usize, 2, 4], vec![2, 4, 8], vec![8, 16, 32]] {
        let cfg = DesignConfig { parallelism: p.clone(), rep: FpRep::Int16 };
        let eval = design::evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
        let lanes: Vec<String> = eval
            .mappings
            .iter()
            .filter(|m| m.name.starts_with("conv"))
            .map(|m| format!("{}x", m.pe_count))
            .collect();
        let _ = writeln!(
            s,
            "p = {:<12}  ->  L(i) = {:<18} total {} C_PEs, {} DSP, {:.3} ms",
            format!("{p:?}"),
            lanes.join(" + "),
            eval.total_pes,
            eval.resources.dsp,
            eval.latency_ms()
        );
    }
    s
}

// ---------------------------------------------------------------------------
// Fig. 10 / Table III — estimator validation against the simulator
// ---------------------------------------------------------------------------

/// One est-vs-real row for a (model, uniform-p) configuration.
struct EstReal {
    pes: usize,
    dsp_est: usize,
    dsp_real: usize,
    lut_est: usize,
    lut_real: usize,
    bram_est: usize,
    bram_real: usize,
    lat_est_ms: f64,
    lat_real_ms: f64,
    power_mw: f64,
}

fn est_real(net: &Network, p: usize, device: &Device) -> EstReal {
    let cfg = DesignConfig::uniform(net, p, FpRep::Int16);
    let est = design::evaluate(net, &cfg, device).unwrap();
    let real = sim::simulate(net, &cfg, device, &GateMask::all_active());
    EstReal {
        pes: est.total_pes,
        dsp_est: est.resources.dsp,
        dsp_real: real.resources.dsp,
        lut_est: est.resources.lut,
        lut_real: real.resources.lut,
        bram_est: est.resources.bram,
        bram_real: real.resources.bram,
        lat_est_ms: est.latency_ms(),
        lat_real_ms: real.latency_ms(),
        power_mw: real.power_mw,
    }
}

/// Fig. 10: estimated vs reported latency/resources across configs.
pub fn fig10() -> String {
    let mut s = header("Fig. 10: estimated vs simulated (\"reported\") resources & latency");
    let _ = writeln!(
        s,
        "{:<10} {:>4} | {:>8} {:>8} {:>6} | {:>9} {:>9} {:>6} | {:>9} {:>9} {:>6}",
        "model", "p", "DSP est", "DSP real", "err%", "LUT est", "LUT real", "err%", "lat est", "lat real", "err%"
    );
    for name in SMALL_MODELS {
        let net = zoo::by_name(name).unwrap();
        for &p in &[8usize, 4, 2] {
            let r = est_real(&net, p, &ZYNQ_7100);
            let _ = writeln!(
                s,
                "{:<10} {:>4} | {:>8} {:>8} {:>5.1}% | {:>9} {:>9} {:>5.1}% | {:>8.3}ms {:>8.3}ms {:>5.1}%",
                name,
                p,
                r.dsp_est,
                r.dsp_real,
                pct_err(r.dsp_est as f64, r.dsp_real as f64),
                r.lut_est,
                r.lut_real,
                pct_err(r.lut_est as f64, r.lut_real as f64),
                r.lat_est_ms,
                r.lat_real_ms,
                pct_err(r.lat_est_ms, r.lat_real_ms)
            );
        }
    }
    let _ = writeln!(
        s,
        "expected shape: DSP/BRAM exact, LUT a few %% (control/routing),\n\
         latency estimate optimistic by pass-switch overheads."
    );
    s
}

/// Table III: estimated + reported usage for a ladder of configurations.
pub fn table3() -> String {
    let mut s = header("Table III: estimated and reported resource usage (NeuroForge configs)");
    let _ = writeln!(
        s,
        "{:<10} {:>6} | {:>7} {:>7} {:>5} | {:>8} {:>8} {:>5} | {:>6} {:>6} {:>5} | {:>9} {:>9} | {:>8}",
        "dataset", "PEs", "DSPr", "DSPe", "err%", "LUTr", "LUTe", "err%", "BRAMr", "BRAMe", "err%", "lat est", "lat real", "power"
    );
    for name in SMALL_MODELS {
        let net = zoo::by_name(name).unwrap();
        for &p in CONFIG_LADDER {
            let r = est_real(&net, p, &ZYNQ_7100);
            let _ = writeln!(
                s,
                "{:<10} {:>6} | {:>7} {:>7} {:>4.1}% | {:>8} {:>8} {:>4.1}% | {:>6} {:>6} {:>4.1}% | {:>7.3}ms {:>7.3}ms | {:>6.0}mW",
                name,
                r.pes,
                r.dsp_real,
                r.dsp_est,
                pct_err(r.dsp_est as f64, r.dsp_real as f64),
                r.lut_real,
                r.lut_est,
                pct_err(r.lut_est as f64, r.lut_real as f64),
                r.bram_real,
                r.bram_est,
                pct_err(r.bram_est as f64, r.bram_real as f64),
                r.lat_est_ms,
                r.lat_real_ms,
                r.power_mw
            );
        }
    }
    s
}

// ---------------------------------------------------------------------------
// Table IV / V / VI — big-model mappings and comparisons
// ---------------------------------------------------------------------------

/// Best deterministic mapping within the device budget (bottleneck-
/// balancing greedy; see [`DesignConfig::balanced`]).
pub fn fit_design(net: &Network, rep: FpRep, device: &Device) -> DesignConfig {
    DesignConfig::balanced(net, rep, device)
}

/// Table IV: compiler comparison on the big models.
pub fn table4() -> String {
    let mut s = header("Table IV: FPGA compiler comparison (FPS / Top-1 / J per frame)");
    let pm = PowerModel::default();
    let _ = (&pm,);
    for (idx, (model_name, zoo_name)) in [
        ("MobileNetV2 (ImageNet)", "mobilenetv2"),
        ("ResNet-50 (ImageNet)", "resnet50"),
        ("SqueezeNet (ImageNet)", "squeezenet"),
        ("YOLOv5-Large (COCO 2017)", "yolov5l"),
    ]
    .iter()
    .enumerate()
    {
        let net = zoo::by_name(zoo_name).unwrap();
        let acc = baselines::TABLE4_FORGEMORPH_TOP1[idx];
        let _ = writeln!(s, "\n-- {model_name} --");
        let _ = writeln!(
            s,
            "{:<26} {:<6} {:>10} {:>8} {:>10} {:>6} {:<12}",
            "framework", "prec", "FPS", "Top-1", "J/frame", "MHz", "FPGA"
        );
        for (rep, label, top1) in [
            (FpRep::Int16, "NeuroForge-16", acc.1),
            (FpRep::Int8, "NeuroForge-8", acc.2),
        ] {
            let cfg = fit_design(&net, rep, &ZYNQ_7100);
            let r = sim::simulate(&net, &cfg, &ZYNQ_7100, &GateMask::all_active());
            let _ = writeln!(
                s,
                "{:<26} {:<6} {:>10.1} {:>7.1}* {:>10.3} {:>6.0} {:<12}",
                label,
                if rep == FpRep::Int8 { "int8" } else { "int16" },
                r.fps(),
                top1,
                r.energy_per_frame_j(),
                ZYNQ_7100.clock_mhz,
                ZYNQ_7100.name
            );
        }
        // NeuroMorph depth split (full / split) where the paper reports it
        if !acc.3.is_nan() {
            let cfg = fit_design(&net, FpRep::Int8, &ZYNQ_7100);
            let full = sim::simulate(&net, &cfg, &ZYNQ_7100, &GateMask::all_active());
            let depth = net.conv_layer_ids().len().div_ceil(2);
            let split = sim::simulate(&net, &cfg, &ZYNQ_7100, &GateMask::depth_prefix(&net, depth));
            let _ = writeln!(
                s,
                "{:<26} {:<6} {:>4.1}/{:>5.1} {:>3.1}/{:>4.1}* {:>4.3}/{:>5.3} {:>6.0} {:<12}",
                "NeuroMorph (full/split)",
                "int8",
                full.fps(),
                split.fps(),
                acc.3,
                acc.4,
                full.energy_per_frame_j(),
                split.energy_per_frame_j(),
                ZYNQ_7100.clock_mhz,
                ZYNQ_7100.name,
            );
        }
        for row in baselines::TABLE4_BASELINES[idx].1 {
            let _ = writeln!(
                s,
                "{:<26} {:<6} {:>10} {:>8} {:>10} {:>6} {:<12} [ref]",
                row.framework,
                row.precision,
                opt_f(row.fps, ""),
                opt_f(row.top1, ""),
                opt_f(row.energy_j_frame, ""),
                opt_f(row.freq_mhz, ""),
                row.fpga
            );
        }
    }
    let _ = writeln!(
        s,
        "\n* Top-1 for ForgeMorph rows is the paper's (ImageNet training is\n\
         out of scope offline — DESIGN.md §2); FPS/energy are simulated live."
    );
    s
}

/// Table V: post-P&R-style utilization of the big-model mappings.
pub fn table5() -> String {
    let mut s = header("Table V: resource utilization on Zynq-7100 (444K LUT, 1510x18Kb BRAM, 2020 DSP)");
    let _ = writeln!(
        s,
        "{:<14} {:<6} {:>14} {:>14} {:>12} {:>6}",
        "model", "prec", "kLUT (%)", "BRAM (%)", "DSP (%)", "MHz"
    );
    let budget = ZYNQ_7100.budget;
    for zoo_name in ["mobilenetv2", "resnet50", "squeezenet", "yolov5l"] {
        let net = zoo::by_name(zoo_name).unwrap();
        for rep in [FpRep::Int16, FpRep::Int8] {
            let cfg = fit_design(&net, rep, &ZYNQ_7100);
            let r = sim::simulate(&net, &cfg, &ZYNQ_7100, &GateMask::all_active());
            let _ = writeln!(
                s,
                "{:<14} {:<6} {:>8.1} ({:>3.0}%) {:>8} ({:>3.0}%) {:>6} ({:>3.0}%) {:>6.0}",
                zoo_name,
                if rep == FpRep::Int8 { "int8" } else { "int16" },
                r.resources.lut as f64 / 1000.0,
                r.resources.lut as f64 / budget.lut as f64 * 100.0,
                r.resources.bram,
                r.resources.bram as f64 / budget.bram as f64 * 100.0,
                r.resources.dsp,
                r.resources.dsp as f64 / budget.dsp as f64 * 100.0,
                ZYNQ_7100.clock_mhz
            );
        }
    }
    s
}

/// Table VI: edge-platform efficiency (inferences per Watt).
pub fn table6() -> String {
    let mut s = header("Table VI: edge devices on latency / power / inferences-per-Watt");
    let _ = writeln!(s, "{:<18} {:>12} {:>10} {:>12}", "device", "latency ms", "power W", "inf/W");
    for row in baselines::TABLE6_BASELINES {
        let _ = writeln!(
            s,
            "{:<18} {:>12.2} {:>10.2} {:>12.1} [ref]",
            row.device,
            row.latency_ms,
            row.power_w,
            row.inf_per_watt()
        );
    }
    // our FPGA row: MobileNet-class model simulated on the Zynq mapping
    // (paper used MobileNetV1; our zoo carries the V2 descriptor — same
    // depthwise-separable family and op scale)
    let net = zoo::mobilenet_v2();
    let cfg = fit_design(&net, FpRep::Int8, &ZYNQ_7100);
    let r = sim::simulate(&net, &cfg, &ZYNQ_7100, &GateMask::all_active());
    // sustained per-frame time of the pipelined design (the throughput
    // figure the other devices' MLPerf numbers correspond to)
    let lat_ms = 1000.0 / r.fps();
    let power_w = r.power_mw / 1000.0;
    let ipw = (1000.0 / lat_ms) / power_w;
    let _ = writeln!(
        s,
        "{:<18} {:>12.2} {:>10.2} {:>12.1} [ours, simulated]",
        "FPGA (ours)", lat_ms, power_w, ipw
    );
    let p = baselines::TABLE6_PAPER_FPGA;
    let _ = writeln!(
        s,
        "{:<18} {:>12.2} {:>10.2} {:>12.1} [paper]",
        p.device, p.latency_ms, p.power_w, p.inf_per_watt()
    );
    s
}

// ---------------------------------------------------------------------------
// Figs. 11 / 12 — NeuroMorph runtime reconfiguration
// ---------------------------------------------------------------------------

/// Morph paths of the small a-2a-3a models (mirrors `model.py`).
fn small_model_paths(net: &Network) -> Vec<MorphPath> {
    let n = net.conv_layer_ids().len();
    let mut out: Vec<MorphPath> = (1..=n)
        .map(|d| MorphPath {
            name: format!("d{d}_w100"),
            depth: d,
            width_pct: 100,
            accuracy: 0.0,
            params: 0,
            macs: d, // placeholder orderings; real macs come from manifest
        })
        .collect();
    out.push(MorphPath {
        name: format!("d{n}_w50"),
        depth: n,
        width_pct: 50,
        accuracy: 0.0,
        params: 0,
        macs: n,
    });
    out
}

/// Fig. 11: depth-wise morphing — latency/power/accuracy per subnet.
pub fn fig11() -> String {
    let manifest = try_manifest();
    let mut s = header("Fig. 11: depth-wise reconfiguration (MNIST 8-16-32, NeuroMorph)");
    let net = zoo::mnist();
    let n_blocks = net.conv_layer_ids().len();
    for &p in &[8usize, 4, 2] {
        let cfg = DesignConfig::uniform(&net, p, FpRep::Int16);
        let _ = writeln!(s, "\n-- NeuroForge config: uniform p={p} --");
        let _ = writeln!(
            s,
            "{:<10} {:>12} {:>10} {:>10} {:>10} {:>9}",
            "subnet", "latency ms", "power mW", "speedup", "power sav", "accuracy"
        );
        let full = sim::simulate(&net, &cfg, &ZYNQ_7100, &GateMask::all_active());
        for depth in 1..=n_blocks {
            let mask = if depth == n_blocks {
                GateMask::all_active()
            } else {
                GateMask::depth_prefix(&net, depth)
            };
            let r = sim::simulate(&net, &cfg, &ZYNQ_7100, &mask);
            let acc = manifest_accuracy(&manifest, "mnist", &format!("d{depth}_w100"));
            let _ = writeln!(
                s,
                "{:<10} {:>12.4} {:>10.0} {:>9.2}x {:>9.1}% {:>9}",
                format!("d{depth}"),
                r.latency_ms(),
                r.power_mw,
                full.latency_ms() / r.latency_ms(),
                (1.0 - (r.power_mw - 455.0).max(0.0) / (full.power_mw - 455.0).max(1.0)) * 100.0,
                acc.map(|a| format!("{:.1}%", a * 100.0)).unwrap_or_else(|| "run `make artifacts`".into())
            );
        }
    }
    s
}

/// Fig. 12: width-wise morphing across the three small models.
pub fn fig12() -> String {
    let manifest = try_manifest();
    let mut s = header("Fig. 12: width-wise reconfiguration (NeuroMorph, 50% filters)");
    let _ = writeln!(
        s,
        "{:<10} {:>3} | {:>11} {:>11} {:>8} | {:>9} {:>9} | {:>9} {:>9}",
        "model", "p", "lat full", "lat w50", "speedup", "pw full", "pw w50", "acc full", "acc w50"
    );
    for name in SMALL_MODELS {
        let net = zoo::by_name(name).unwrap();
        let n = net.conv_layer_ids().len();
        for &p in &[8usize, 4] {
            let cfg = DesignConfig::uniform(&net, p, FpRep::Int16);
            let full = sim::simulate(&net, &cfg, &ZYNQ_7100, &GateMask::all_active());
            let half = sim::simulate(&net, &cfg, &ZYNQ_7100, &GateMask::width(0.5));
            let acc_full = manifest_accuracy(&manifest, name, &format!("d{n}_w100"));
            let acc_half = manifest_accuracy(&manifest, name, &format!("d{n}_w50"));
            let fmt_acc = |a: Option<f64>| {
                a.map(|x| format!("{:.1}%", x * 100.0)).unwrap_or_else(|| "--".into())
            };
            let _ = writeln!(
                s,
                "{:<10} {:>3} | {:>9.3}ms {:>9.3}ms {:>7.2}x | {:>7.0}mW {:>7.0}mW | {:>9} {:>9}",
                name,
                p,
                full.latency_ms(),
                half.latency_ms(),
                full.latency_ms() / half.latency_ms(),
                full.power_mw,
                half.power_mw,
                fmt_acc(acc_full),
                fmt_acc(acc_half)
            );
        }
    }
    let _ = writeln!(s, "accuracies come from DistillCycle training (manifest); '--' = model not built");
    s
}

/// Backend comparison: the governor's per-path cost table as seen by
/// the cycle-level simulator vs the analytical Eq. 12-15 fast path —
/// the two offline implementations of `InferenceBackend`. The ordering
/// must agree (same morph decisions on any budget trace); magnitudes
/// differ by the second-order effects only the simulator models.
pub fn backends() -> String {
    use crate::backend::{AnalyticalBackend, InferenceBackend, SimBackend};
    let net = zoo::mnist();
    let cfg = DesignConfig::uniform(&net, 4, FpRep::Int16);
    let paths = crate::morph::depth_ladder(&net);
    let mut s = String::from(
        "\n== Serving backends: governor cost table, sim vs analytical (MNIST, p=4) ==\n",
    );
    let sim_b = SimBackend::new(
        net.clone(),
        cfg.clone(),
        ZYNQ_7100,
        paths.clone(),
        vec![1, 8],
        1,
    )
    .expect("sim backend");
    let ana_b = AnalyticalBackend::new(net, cfg, ZYNQ_7100, paths, vec![1, 8])
        .expect("analytical backend");
    let sim_costs = sim_b.path_costs();
    let ana_costs = ana_b.path_costs();
    let _ = writeln!(
        s,
        "{:<10} {:>12} {:>12} {:>14} {:>14}",
        "path", "sim mW", "ana mW", "sim lat ms", "ana lat ms"
    );
    for (name, sim_p, sim_l) in &sim_costs.rows {
        let (_, ana_p, ana_l) = ana_costs
            .rows
            .iter()
            .find(|(n, _, _)| n == name)
            .expect("path present in both tables");
        let _ = writeln!(
            s,
            "{name:<10} {sim_p:>12.1} {ana_p:>12.1} {sim_l:>14.4} {ana_l:>14.4}"
        );
    }
    // the serving engine's bucket-interpolated quantiles over the same
    // per-frame latencies (one sample per path per backend)
    let mut h = crate::coordinator::Histogram::default();
    for r in sim_costs.rows.iter().chain(&ana_costs.rows) {
        h.record(std::time::Duration::from_secs_f64(r.2 / 1000.0));
    }
    let _ = writeln!(
        s,
        "per-frame latency quantiles, both tables (bucket-interpolated): \
         p50 {:.3} ms | p95 {:.3} ms | p99 {:.3} ms",
        h.quantile(0.5) / 1000.0,
        h.quantile(0.95) / 1000.0,
        h.quantile(0.99) / 1000.0
    );
    let _ = writeln!(
        s,
        "both backends share the surrogate classifier: logits are bit-identical\n\
         (pinned by tests/backend_serving.rs at 1 and 4 worker shards)"
    );
    s
}

/// Graph IR / pass-pipeline summary: scheduled StagePlan shape for the
/// branchy zoo models — stage counts, dataflow edges, branch-FIFO
/// buffering and the resulting evaluate-model costs. (The chain models
/// schedule 1:1 onto their layer lists; the branchy ones are where the
/// plan earns its keep.)
pub fn graphs() -> String {
    use crate::graph::passes::{self, EdgeKind};
    let mut s = header("Graph IR: scheduled StagePlans (branchy zoo models)");
    let _ = writeln!(
        s,
        "{:<12} {:>7} {:>7} {:>8} {:>9} {:>12} {:>11} {:>12}",
        "model", "stages", "edges", "branches", "gates", "fifo words", "BRAM(int8)", "latency ms"
    );
    for name in ["yolov5l", "unet_tiny", "resnet50"] {
        let net = zoo::by_name(name).unwrap();
        let plan = passes::schedule(&net).unwrap();
        let branch_edges =
            plan.edges.iter().filter(|e| e.kind == EdgeKind::Branch).count();
        let fifo_words: usize = plan.edges.iter().map(|e| e.fifo_words).sum();
        let cfg = DesignConfig::uniform(&net, 2, FpRep::Int8);
        let eval = design::evaluate_plan(&plan, &cfg, &ZYNQ_7100).unwrap();
        let _ = writeln!(
            s,
            "{:<12} {:>7} {:>7} {:>8} {:>9} {:>12} {:>11} {:>12.3}",
            name,
            plan.stages.len(),
            plan.edges.len(),
            branch_edges,
            plan.gate_blocks,
            fifo_words,
            eval.resources.bram,
            eval.latency_ms()
        );
    }
    let _ = writeln!(
        s,
        "branch FIFOs buffer each non-primary concat input's full fmap for\n\
         re-sync; chain models carry zero branch words by construction."
    );
    s
}

/// NeuroMorph power loop: the paper's down-shift experiment (Figs.
/// 11-12 runtime claim, Table III power column) replayed live through
/// the serving stack — a step power trace drives the shared governor on
/// a virtual clock, morph transitions follow drain→swap→resume, and the
/// per-segment modeled power shows the squeeze saving. Deterministic:
/// the decision log is byte-identical for any worker count or seed.
pub fn power() -> String {
    use crate::backend::BackendSpec;
    use crate::coordinator::{trace, Coordinator, ServeConfig, TraceConfig};

    let net = zoo::mnist();
    // the Table III 164-PE-class mapping: large enough that gated blocks
    // dominate the draw, where the paper's ~32% saving lives
    let design = DesignConfig::uniform(&net, 16, FpRep::Int16);
    let paths = crate::morph::depth_ladder(&net);
    let spec = BackendSpec::sim(net, design, ZYNQ_7100, paths);
    let cfg = ServeConfig { workers: 1, external_pacing: true, ..ServeConfig::default() };

    let mut s = header("NeuroMorph power loop: trace-driven down-shift (Figs. 11-12 runtime claim)");
    let mut coord = match Coordinator::start(cfg, spec) {
        Ok(c) => c,
        Err(e) => {
            let _ = writeln!(s, "(serving stack unavailable: {e})");
            return s;
        }
    };
    let rows = coord.path_energy_rows();
    let _ = writeln!(
        s,
        "{:<10} {:>10} {:>12} {:>14} {:>12}",
        "path", "power mW", "frame ms", "energy mJ/f", "activity"
    );
    for e in &rows {
        let _ = writeln!(
            s,
            "{:<10} {:>10.1} {:>12.4} {:>14.4} {:>11.2}%",
            e.name,
            e.power_mw,
            e.frame_ms,
            e.energy_mj_per_frame(),
            e.activity.active_fraction * 100.0
        );
    }
    let cap = trace::default_squeeze_cap(&rows);
    let (frames, rate_hz) = (240usize, 4000.0);
    let events = trace::step(frames as f64 / rate_hz, cap);
    let outcome = match coord
        .replay_power_trace(&events, &TraceConfig { frames, rate_hz, seed: 7 })
    {
        Ok(o) => o,
        Err(e) => {
            let _ = writeln!(s, "(trace replay failed: {e})");
            return s;
        }
    };
    let _ = writeln!(s, "\nstep trace, cap {cap:.0} mW, {frames} frames @ {rate_hz:.0} Hz virtual:");
    s.push_str(&outcome.decision_log());
    s.push_str(&outcome.render_summary());
    let e2e = &outcome.metrics.e2e_latency;
    let _ = writeln!(
        s,
        "e2e latency quantiles (bucket-interpolated): \
         p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms",
        e2e.quantile(0.5) / 1000.0,
        e2e.quantile(0.95) / 1000.0,
        e2e.quantile(0.99) / 1000.0
    );
    s
}

/// Fault-storm experiment: the canonical `--fault-trace` storm (all four
/// fault kinds) replayed over the same step power trace as the `power`
/// report. Shows the self-healing machinery end to end — SEU corruption
/// scrubbed by CRC, a swap failure rolled back with cooldown, transient
/// errors retried with deterministic backoff, a straggler isolated and
/// its virtual shard degraded — and the zero-loss terminal accounting.
/// Deterministic: fault + decision logs are byte-identical for any
/// worker count or rerun (test-enforced).
pub fn faults() -> String {
    use crate::backend::BackendSpec;
    use crate::coordinator::{trace, Coordinator, ServeConfig, TraceConfig};
    use crate::fault::FaultPlan;

    let net = zoo::mnist();
    let design = DesignConfig::uniform(&net, 16, FpRep::Int16);
    let paths = crate::morph::depth_ladder(&net);
    let spec = BackendSpec::sim(net, design, ZYNQ_7100, paths);
    let cfg = ServeConfig { workers: 1, external_pacing: true, ..ServeConfig::default() };

    let mut s = header("Fault storm: deterministic injection + self-healing (NeuroMorph runtime)");
    let mut coord = match Coordinator::start(cfg, spec) {
        Ok(c) => c,
        Err(e) => {
            let _ = writeln!(s, "(serving stack unavailable: {e})");
            return s;
        }
    };
    let rows = coord.path_energy_rows();
    let cap = trace::default_squeeze_cap(&rows);
    let (frames, rate_hz) = (240usize, 4000.0);
    let events = trace::step(frames as f64 / rate_hz, cap);
    let fspec = FaultPlan::storm_spec();
    let plan = match FaultPlan::parse_spec(fspec, frames, rate_hz, 7) {
        Ok(p) => p,
        Err(e) => {
            let _ = writeln!(s, "(fault spec failed to parse: {e})");
            return s;
        }
    };
    let outcome = match coord.replay_trace(
        &events,
        &TraceConfig { frames, rate_hz, seed: 7 },
        Some(&plan),
    ) {
        Ok(o) => o,
        Err(e) => {
            let _ = writeln!(s, "(trace replay failed: {e})");
            return s;
        }
    };
    let _ = writeln!(
        s,
        "storm '{fspec}' over a step trace (cap {cap:.0} mW), \
         {frames} frames @ {rate_hz:.0} Hz virtual:"
    );
    s.push_str(&outcome.decision_log());
    s.push_str(&outcome.fault_log());
    s.push_str(&outcome.render_summary());
    s
}

/// DistillCycle summary: train the tiny demo ladder live and show the
/// per-path accuracy table, the loss trajectories' endpoints and the
/// governor floor the profile implies. (The small budget keeps this
/// report runnable in seconds; the real ladders come from
/// `forgemorph distill --model mnist|svhn|cifar10`.)
pub fn distill() -> String {
    use crate::distill::{self, DistillConfig, DistillSpec};
    let spec = DistillSpec::tiny();
    let cfg = DistillConfig { epochs_per_stage: 1, batch: 32, ..DistillConfig::default() };
    let ds = spec.dataset(192, 96, cfg.seed);
    let profile = distill::train_profile(&spec, &ds, &cfg);
    let mut s = header("DistillCycle: hierarchical-KD ladder training (tiny demo spec)");
    let _ = writeln!(
        s,
        "model '{}' — {} Layer-Blocks, widths {:?}, {} train / {} test samples",
        spec.name,
        spec.filters.len(),
        spec.widths,
        ds.n_train(),
        ds.n_test()
    );
    let _ = writeln!(
        s,
        "{:<10} {:>7} {:>9} {:>10} {:>10} {:>12}",
        "path", "params", "MACs", "accuracy", "first loss", "last loss"
    );
    for p in &profile.paths {
        let _ = writeln!(
            s,
            "{:<10} {:>7} {:>9} {:>9.1}% {:>10.4} {:>12.4}",
            p.name,
            p.params,
            p.macs,
            p.accuracy * 100.0,
            p.loss_trajectory.first().copied().unwrap_or(f64::NAN),
            p.loss_trajectory.last().copied().unwrap_or(f64::NAN)
        );
    }
    let _ = writeln!(
        s,
        "governor accuracy floor (worst trained path): {:.1}%",
        profile.floor() * 100.0
    );
    let _ = writeln!(
        s,
        "profiles feed `explore --profile` (3-objective fronts) and the\n\
         governor's hard floor; identical seeds give byte-identical JSON."
    );
    s
}

/// Deterministic trace timeline: the canonical fault-storm replay (the
/// `faults` report scenario) run with the span recorder attached,
/// exported as deterministic Chrome trace JSON and rendered through the
/// same [`render_trace_json`] path `report trace --in FILE` uses — one
/// code path for live and file-loaded traces.
pub fn trace_timeline() -> String {
    use crate::backend::BackendSpec;
    use crate::coordinator::{trace, Coordinator, ServeConfig, TraceConfig};
    use crate::fault::FaultPlan;
    use crate::obs::{export as obs_export, TraceSink};

    let net = zoo::mnist();
    let design = DesignConfig::uniform(&net, 16, FpRep::Int16);
    let paths = crate::morph::depth_ladder(&net);
    let spec = BackendSpec::sim(net, design, ZYNQ_7100, paths);
    let sink = TraceSink::shared();
    sink.set_meta("cmd", "report trace");
    sink.set_meta("model", "mnist");
    sink.set_meta("backend", &spec.describe());
    let cfg = ServeConfig {
        workers: 1,
        external_pacing: true,
        trace: Some(sink.clone()),
        ..ServeConfig::default()
    };

    let mut s = header("Trace timeline: storm replay through the span recorder");
    let mut coord = match Coordinator::start(cfg, spec) {
        Ok(c) => c,
        Err(e) => {
            let _ = writeln!(s, "(serving stack unavailable: {e})");
            return s;
        }
    };
    let rows = coord.path_energy_rows();
    let cap = trace::default_squeeze_cap(&rows);
    let (frames, rate_hz) = (240usize, 4000.0);
    let events = trace::step(frames as f64 / rate_hz, cap);
    let fspec = FaultPlan::storm_spec();
    let plan = match FaultPlan::parse_spec(fspec, frames, rate_hz, 7) {
        Ok(p) => p,
        Err(e) => {
            let _ = writeln!(s, "(fault spec failed to parse: {e})");
            return s;
        }
    };
    if let Err(e) =
        coord.replay_trace(&events, &TraceConfig { frames, rate_hz, seed: 7 }, Some(&plan))
    {
        let _ = writeln!(s, "(trace replay failed: {e})");
        return s;
    }
    // join the workers before draining so every lane is quiescent
    drop(coord);
    let json = obs_export::chrome_trace(&sink.drain(), true);
    let _ = writeln!(
        s,
        "storm '{fspec}' over a step trace (cap {cap:.0} mW), \
         {frames} frames @ {rate_hz:.0} Hz virtual, deterministic export:"
    );
    match render_trace_json(&json) {
        Ok(r) => s.push_str(&r),
        Err(e) => {
            let _ = writeln!(s, "(render failed: {e})");
        }
    }
    s
}

/// Render an exported Chrome trace (`--trace-out` JSON) as a text
/// timeline: per-path occupancy, governor switch/swap annotations,
/// retry ladders, fault/scrub marks and DSE/distill telemetry. The
/// renderer is total over any `forgemorph-trace-v1` file — sections for
/// absent span families are simply omitted.
pub fn render_trace_json(text: &str) -> Result<String, String> {
    use crate::util::json::Json;
    let root = Json::parse(text).map_err(|e| format!("not JSON: {e}"))?;
    let events = root
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| "not a trace: missing traceEvents".to_string())?;
    let other = root.get("otherData");
    let format = other.and_then(|o| o.get("format")).and_then(Json::as_str).unwrap_or("?");
    if !format.starts_with("forgemorph-trace") {
        return Err(format!("unrecognized trace format '{format}'"));
    }
    let dropped = other.and_then(|o| o.get("dropped")).and_then(Json::as_u64).unwrap_or(0);
    let deterministic = other
        .and_then(|o| o.get("deterministic"))
        .and_then(Json::as_bool)
        .unwrap_or(false);

    // one pass over the events, aggregating every section the renderer
    // shows; sections with no matching spans are omitted below
    let (mut spans, mut instants, mut counters) = (0usize, 0usize, 0usize);
    let mut occupancy: std::collections::BTreeMap<String, u64> = Default::default();
    let mut switches: Vec<String> = Vec::new();
    let (mut swap_count, mut swap_us, mut rollbacks) = (0usize, 0u64, 0usize);
    let mut retry_events = 0usize;
    let mut retry_depth: std::collections::BTreeMap<u64, u64> = Default::default();
    let (mut seu, mut transients, mut stalls) = (0usize, 0usize, 0usize);
    let (mut scrubs, mut scrub_us) = (0usize, 0u64);
    let (mut generations, mut last_best_us) = (0usize, 0u64);
    let mut kd = 0usize;
    for ev in events {
        let name = ev.get("name").and_then(Json::as_str).unwrap_or("");
        let ph = ev.get("ph").and_then(Json::as_str).unwrap_or("");
        let ts = ev.get("ts").and_then(Json::as_u64).unwrap_or(0);
        let dur = ev.get("dur").and_then(Json::as_u64).unwrap_or(0);
        let args = ev.get("args");
        let arg_str = |k: &str| {
            args.and_then(|a| a.get(k)).and_then(Json::as_str).map(str::to_string)
        };
        let arg_u64 = |k: &str| args.and_then(|a| a.get(k)).and_then(Json::as_u64);
        match ph {
            "X" => spans += 1,
            "i" => instants += 1,
            "C" => counters += 1,
            _ => {}
        }
        match name {
            "execute" if ph == "X" => {
                let path = arg_str("path").unwrap_or_else(|| "?".into());
                *occupancy.entry(path).or_insert(0) += dur;
            }
            "switch" => {
                let to = arg_str("path").unwrap_or_else(|| "?".into());
                let from = arg_str("from").unwrap_or_else(|| "?".into());
                let budget = arg_u64("budget_mw").unwrap_or(0);
                let b = if budget > 0 {
                    format!("{budget} mW cap")
                } else {
                    "uncapped".to_string()
                };
                switches.push(format!("  [t {ts:>8} us] switch {from} -> {to} ({b})"));
            }
            "rollback" => rollbacks += 1,
            "swap_window" => {
                swap_count += 1;
                swap_us += dur;
            }
            "retry" => {
                retry_events += 1;
                let id = arg_u64("id").unwrap_or(0);
                let attempt = arg_u64("attempt").unwrap_or(0);
                let d = retry_depth.entry(id).or_insert(0);
                *d = (*d).max(attempt);
            }
            "seu" => seu += 1,
            "scrub_repair" => {
                scrubs += 1;
                scrub_us += dur;
            }
            "transient" => transients += 1,
            "stall" if ph == "X" => stalls += 1,
            "generation" => {
                generations += 1;
                last_best_us = arg_u64("best_lat_us").unwrap_or(last_best_us);
            }
            n if n.starts_with("kd_") => kd += 1,
            _ => {}
        }
    }

    let mut s = String::new();
    let _ = writeln!(
        s,
        "trace: {format} ({})",
        if deterministic {
            "deterministic: virtual clock only"
        } else {
            "full: wall lanes included"
        }
    );
    if let Some(Json::Obj(meta)) = other {
        for (k, v) in meta {
            if matches!(k.as_str(), "format" | "deterministic" | "dropped") {
                continue;
            }
            if let Json::Str(v) = v {
                let _ = writeln!(s, "  {k}: {v}");
            }
        }
    }
    let _ = writeln!(
        s,
        "events: {} — {spans} spans, {instants} instants, {counters} counters; \
         dropped spans: {dropped}",
        events.len()
    );
    if !occupancy.is_empty() {
        let _ = writeln!(s, "per-path occupancy (execute spans):");
        let max = occupancy.values().copied().max().unwrap_or(1).max(1);
        for (path, us) in &occupancy {
            let bar = "#".repeat((us * 30 / max) as usize);
            let _ = writeln!(s, "  {path:<10} {us:>10} us  {bar}");
        }
    }
    if !switches.is_empty() || swap_count > 0 || rollbacks > 0 {
        let _ = writeln!(
            s,
            "governor: {} switch(es), {swap_count} swap window(s) totaling {swap_us} us, \
             {rollbacks} rollback(s)",
            switches.len()
        );
        for line in &switches {
            s.push_str(line);
            s.push('\n');
        }
    }
    if retry_events > 0 {
        let deepest = retry_depth.values().copied().max().unwrap_or(0);
        let _ = writeln!(
            s,
            "retry ladder: {retry_events} retry(ies) across {} request(s), \
             deepest attempt {deepest}",
            retry_depth.len()
        );
    }
    if seu + scrubs + transients + stalls > 0 {
        let _ = writeln!(
            s,
            "faults: {seu} seu, {scrubs} scrub repair(s) ({scrub_us} us modeled MTTR), \
             {transients} transient(s), {stalls} stall(s)"
        );
    }
    if generations > 0 {
        let _ = writeln!(
            s,
            "dse: {generations} generation(s), final best latency {last_best_us} us"
        );
    }
    if kd > 0 {
        let _ = writeln!(s, "distill: {kd} kd span(s)");
    }
    Ok(s)
}

/// Everything, in paper order.
pub fn all() -> String {
    let mut s = String::new();
    s.push_str(&table1());
    s.push_str(&table2());
    s.push_str(&fig2(48, 20, 7));
    s.push_str(&fig8());
    s.push_str(&fig10());
    s.push_str(&table3());
    s.push_str(&table4());
    s.push_str(&table5());
    s.push_str(&table6());
    s.push_str(&fig11());
    s.push_str(&fig12());
    s.push_str(&backends());
    s.push_str(&graphs());
    s.push_str(&distill());
    s.push_str(&power());
    s.push_str(&faults());
    s.push_str(&trace_timeline());
    s
}

/// Every id `by_name` accepts, plus the CLI-handled specials
/// (`bench-check`) — the suggestion source for `report`'s did-you-mean
/// error path.
pub const KNOWN_IDS: &[&str] = &[
    "table1",
    "table2",
    "table3",
    "table4",
    "table5",
    "table6",
    "fig2",
    "fig8",
    "fig10",
    "fig11",
    "fig12",
    "backends",
    "graphs",
    "distill",
    "power",
    "faults",
    "trace",
    "all",
    "bench-check",
];

/// Registry consumed by the CLI and by `bench_tables`.
pub fn by_name(id: &str) -> Option<String> {
    Some(match id {
        "table1" => table1(),
        "table2" => table2(),
        "table3" => table3(),
        "table4" => table4(),
        "table5" => table5(),
        "table6" => table6(),
        "fig2" => fig2(48, 20, 7),
        "fig8" => fig8(),
        "fig10" => fig10(),
        "fig11" => fig11(),
        "fig12" => fig12(),
        "backends" => backends(),
        "graphs" => graphs(),
        "distill" => distill(),
        "power" => power(),
        "faults" => faults(),
        "trace" => trace_timeline(),
        "all" => all(),
        _ => return None,
    })
}

/// Ensure the governor's registry can be built from the small models
/// (used by examples; exposed for tests).
pub fn small_registry(net: &Network) -> PathRegistry {
    let mut paths = small_model_paths(net);
    // order by depth/width cost proxy
    for (i, p) in paths.iter_mut().enumerate() {
        p.macs = (i + 1) * 1000;
    }
    PathRegistry::new(paths)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_contains_constants() {
        let t = table1();
        assert!(t.contains("850"));
        assert!(t.contains("3x3"));
    }

    #[test]
    fn table2_lists_all_models() {
        let t = table2();
        for m in ["MNIST", "ResNet-50", "YOLOv5-Large"] {
            assert!(t.contains(m), "{m} missing");
        }
    }

    #[test]
    fn fig8_shows_eq14_expansion() {
        let f = fig8();
        // p = [2,4,8] -> L = 2 + 8 + 32
        assert!(f.contains("2x + 8x + 32x"), "{f}");
    }

    #[test]
    fn fig2_reports_search_telemetry() {
        let f = fig2(16, 3, 1);
        assert!(f.contains("search telemetry:"), "{f}");
        assert!(f.contains("cache hit rate"), "{f}");
        assert!(f.contains("stage hit rate"), "{f}");
        assert!(f.contains("unique evaluations"), "{f}");
    }

    #[test]
    fn fig10_errors_bounded() {
        let f = fig10();
        // DSP error must be exactly zero everywhere
        for line in f.lines().filter(|l| l.contains("ms")) {
            let cols: Vec<&str> = line.split('|').collect();
            if cols.len() == 4 {
                assert!(cols[1].contains("0.0%"), "DSP err nonzero: {line}");
            }
        }
    }

    #[test]
    fn table3_has_all_ladder_rows() {
        let t = table3();
        let rows = t.lines().filter(|l| l.contains("mW")).count();
        assert_eq!(rows, SMALL_MODELS.len() * CONFIG_LADDER.len());
    }

    #[test]
    fn table6_ours_beats_jetsons_on_efficiency() {
        let t = table6();
        assert!(t.contains("FPGA (ours)"));
        // extract our inf/W and compare against AGX's 62.9
        let line = t.lines().find(|l| l.contains("[ours")).unwrap();
        let ipw: f64 = line
            .split_whitespace()
            .rev()
            .nth(2)
            .unwrap()
            .parse()
            .unwrap();
        assert!(ipw > 62.9, "ours {ipw} should beat AGX (paper shape: 2.8x)");
    }

    #[test]
    fn fig11_reports_speedups() {
        let f = fig11();
        assert!(f.contains("d1") && f.contains("d3"));
        assert!(f.contains("x"), "speedup column missing");
    }

    #[test]
    fn backends_table_orderings_agree() {
        let b = backends();
        // one row per depth path plus header/footer
        for p in ["d1_w100", "d2_w100", "d3_w100"] {
            assert!(b.contains(p), "{p} missing from backend table");
        }
        // power columns must both be monotone in depth: extract rows
        let rows: Vec<Vec<f64>> = b
            .lines()
            .filter(|l| l.starts_with('d'))
            .map(|l| {
                l.split_whitespace()
                    .skip(1)
                    .map(|v| v.parse().unwrap())
                    .collect()
            })
            .collect();
        assert_eq!(rows.len(), 3);
        for col in 0..4 {
            assert!(
                rows.windows(2).all(|w| w[0][col] < w[1][col]),
                "column {col} not monotone"
            );
        }
    }

    #[test]
    fn by_name_covers_everything() {
        for id in [
            "table1", "table2", "table3", "table4", "table5", "table6",
            "fig8", "fig10", "fig11", "fig12", "backends", "graphs", "distill",
            "power", "faults", "trace",
        ] {
            assert!(by_name(id).is_some(), "{id}");
        }
        assert!(by_name("nope").is_none());
        // every by_name id is listed in the suggestion source
        for id in ["fig2", "backends", "trace", "all", "bench-check"] {
            assert!(KNOWN_IDS.contains(&id), "{id} missing from KNOWN_IDS");
        }
    }

    #[test]
    fn trace_report_renders_storm_timeline() {
        let t = trace_timeline();
        // zero drops: the default lane capacity dwarfs the storm's spans
        assert!(t.contains("dropped spans: 0"), "{t}");
        assert!(t.contains("per-path occupancy"), "{t}");
        // every annotated span family the storm produces is rendered
        assert!(t.contains("switch d3_w100 -> ") || t.contains("switch d3_w100 ->"), "{t}");
        assert!(t.contains("swap window"), "{t}");
        assert!(t.contains("retry ladder:"), "{t}");
        assert!(t.contains("scrub repair"), "{t}");
        assert!(t.contains("rollback"), "{t}");
    }

    #[test]
    fn render_trace_json_rejects_non_traces() {
        assert!(render_trace_json("not json").is_err());
        assert!(render_trace_json("{\"traceEvents\": []}").is_err(), "missing format tag");
        assert!(render_trace_json("{\"answer\": 42}").is_err());
    }

    #[test]
    fn backends_and_power_report_interpolated_quantiles() {
        let b = backends();
        let line = b
            .lines()
            .find(|l| l.starts_with("per-frame latency quantiles"))
            .unwrap_or_else(|| panic!("no quantile line in:\n{b}"));
        for q in ["p50", "p95", "p99"] {
            assert!(line.contains(q), "{q} missing: {line}");
        }
        let p = power();
        assert!(
            p.lines().any(|l| l.starts_with("e2e latency quantiles")),
            "power report lost its quantile line:\n{p}"
        );
    }

    #[test]
    fn power_report_reproduces_paper_downshift() {
        let p = power();
        // a down-shift from the full path must fire...
        assert!(p.contains("switch d3_w100 -> "), "{p}");
        // ...and the squeeze saving must reach the paper's claim range
        let line = p
            .lines()
            .find(|l| l.starts_with("power reduction after squeeze:"))
            .unwrap_or_else(|| panic!("no reduction line in:\n{p}"));
        let pct: f64 = line
            .trim_end_matches('%')
            .rsplit(' ')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(pct >= 30.0, "reduction {pct}% below the paper's ~32% claim");
        // the release upshifts back and pays the reactivation stall
        assert!(p.contains("-> d3_w100 (stall 1"), "{p}");
    }

    #[test]
    fn faults_report_shows_healing_and_zero_loss() {
        let f = faults();
        // every storm kind leaves its mark in the canonical fault log
        assert!(f.contains("fault seu:"), "{f}");
        assert!(f.contains("fault stall:"), "{f}");
        assert!(f.contains("fault transient:"), "{f}");
        assert!(f.contains("fault swapfail:"), "{f}");
        assert!(f.contains("scrub: crc mismatch repaired"), "{f}");
        // the zero-loss terminal accounting line
        assert!(f.contains("(0 lost)"), "{f}");
        assert!(f.contains("terminal:"), "{f}");
    }

    #[test]
    fn distill_report_lists_ladder_and_floor() {
        let d = distill();
        for p in ["d1_w100", "d2_w100", "d3_w100", "d3_w50"] {
            assert!(d.contains(p), "{p} missing from distill report");
        }
        assert!(d.contains("accuracy floor"), "{d}");
    }

    #[test]
    fn graphs_report_shows_branch_buffering() {
        let g = graphs();
        assert!(g.contains("yolov5l") && g.contains("unet_tiny"));
        // resnet50's skip edges carry zero FIFO words; yolo's concats don't
        let row = |name: &str| {
            g.lines()
                .find(|l| l.starts_with(name))
                .unwrap_or_else(|| panic!("{name} row missing"))
                .split_whitespace()
                .map(str::to_string)
                .collect::<Vec<_>>()
        };
        let yolo_words: usize = row("yolov5l")[5].parse().unwrap();
        let resnet_words: usize = row("resnet50")[5].parse().unwrap();
        assert!(yolo_words > 0 && resnet_words == 0);
    }
}
