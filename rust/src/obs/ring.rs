//! Bounded per-lane trace storage.
//!
//! Each recording lane owns one fixed-capacity [`Ring`]. The hot path
//! (`push`) never allocates: the buffer is pre-allocated at `cap` and a
//! full ring *counts* what it sheds instead of growing or silently
//! overwriting — retention is oldest-first, so the kept prefix of a
//! truncated lane is exactly the head of the recording order. At drain
//! time lanes dump into [`RingDump`]s and fold together with [`merge`],
//! a sorted multiset union that is associative and commutative
//! (property-tested), so the merge order of lanes can never change the
//! drained trace.

use super::TraceEntry;

/// Fixed-capacity entry buffer with an overflow counter.
#[derive(Debug)]
pub struct Ring {
    buf: Vec<TraceEntry>,
    cap: usize,
    dropped: u64,
}

impl Ring {
    pub fn new(cap: usize) -> Ring {
        let cap = cap.max(1);
        Ring { buf: Vec::with_capacity(cap), cap, dropped: 0 }
    }

    /// Record one entry. Never allocates: a full ring sheds the entry
    /// and counts it in `dropped` — never a silent truncation, the
    /// exporters surface the counter.
    pub fn push(&mut self, e: TraceEntry) {
        if self.buf.len() < self.cap {
            self.buf.push(e);
        } else {
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Empty the ring into a sorted dump. Recording order within a lane
    /// is not globally time-sorted (fault spans are stamped
    /// retroactively from the injector's records), so the dump sorts by
    /// the entry's total order before merging.
    pub fn take(&mut self) -> RingDump {
        let mut entries = std::mem::take(&mut self.buf);
        self.buf.reserve_exact(self.cap);
        entries.sort_unstable();
        let dropped = self.dropped;
        self.dropped = 0;
        RingDump { entries, dropped }
    }
}

/// A drained lane: entries sorted by the total order, plus what the
/// lane shed.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RingDump {
    pub entries: Vec<TraceEntry>,
    pub dropped: u64,
}

/// Sorted multiset union of two dumps, drop counters summed.
/// Associative and commutative: [`TraceEntry`]'s derived total order
/// covers every field, so compare-equal entries are identical and any
/// merge tree over any lane grouping yields the same sequence.
pub fn merge(a: RingDump, b: RingDump) -> RingDump {
    let (ae, be) = (a.entries, b.entries);
    let mut out = Vec::with_capacity(ae.len() + be.len());
    let (mut i, mut j) = (0, 0);
    while i < ae.len() && j < be.len() {
        if ae[i] <= be[j] {
            out.push(ae[i]);
            i += 1;
        } else {
            out.push(be[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&ae[i..]);
    out.extend_from_slice(&be[j..]);
    RingDump { entries: out, dropped: a.dropped + b.dropped }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Clock, Kind, Name};
    use crate::util::prop::{check, ensure};
    use crate::util::rng::Rng;

    fn arb_entry(r: &mut Rng) -> TraceEntry {
        const NAMES: [Name; 6] = [
            Name::Enqueue,
            Name::Execute,
            Name::Switch,
            Name::Retry,
            Name::ScrubRepair,
            Name::SwapWindow,
        ];
        TraceEntry {
            ts_us: r.range(0, 999) as u64,
            dur_us: r.range(0, 99) as u64,
            clock: if r.range(0, 1) == 0 { Clock::Virtual } else { Clock::Wall },
            kind: Kind::Span,
            name: NAMES[r.below(NAMES.len())],
            id: r.range(0, 31) as u64,
            path: r.range(0, 3) as u16,
            a0: r.range(0, 7) as u64,
            a1: 0,
            lane: r.range(0, 8) as u16,
        }
    }

    fn arb_dump(r: &mut Rng) -> RingDump {
        let n = r.range(0, 24);
        let mut entries: Vec<TraceEntry> = (0..n).map(|_| arb_entry(r)).collect();
        entries.sort_unstable();
        RingDump { entries, dropped: r.range(0, 5) as u64 }
    }

    #[test]
    fn ring_bounds_and_counts_overflow() {
        let mut ring = Ring::new(4);
        let mut rng = Rng::new(7);
        let fed: Vec<TraceEntry> = (0..10).map(|_| arb_entry(&mut rng)).collect();
        for &e in &fed {
            ring.push(e);
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.dropped(), 6);
        let dump = ring.take();
        // oldest-first retention: the kept entries are the first 4 fed
        let mut expect = fed[..4].to_vec();
        expect.sort_unstable();
        assert_eq!(dump.entries, expect);
        assert_eq!(dump.dropped, 6);
        // take resets: the ring records again without allocating drops
        assert_eq!(ring.dropped(), 0);
        assert!(ring.is_empty());
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        check(
            "ring-merge-associative",
            200,
            11,
            |r| (arb_dump(r), arb_dump(r), arb_dump(r)),
            |(a, b, c)| {
                let left = merge(merge(a.clone(), b.clone()), c.clone());
                let right = merge(a.clone(), merge(b.clone(), c.clone()));
                ensure(left == right, "merge grouping changed the trace")?;
                let ab = merge(a.clone(), b.clone());
                let ba = merge(b.clone(), a.clone());
                ensure(ab == ba, "merge order changed the trace")?;
                ensure(
                    left.dropped == a.dropped + b.dropped + c.dropped,
                    "drop counters must sum",
                )?;
                ensure(
                    left.entries.len() == a.entries.len() + b.entries.len() + c.entries.len(),
                    "merge must be a multiset union",
                )?;
                ensure(left.entries.windows(2).all(|w| w[0] <= w[1]), "merge output sorted")
            },
        );
    }
}
