//! Trace exporters: Chrome trace-event JSON (Perfetto-loadable), folded
//! stacks (flamegraph text), and a plain-text snapshot.
//!
//! Every exporter takes a drained [`Trace`] plus a `deterministic`
//! flag. Deterministic output applies the §14 quarantine rule: only
//! [`Clock::Virtual`] entries survive, the recording lane is zeroed,
//! and the result is re-sorted by the entry total order — so the bytes
//! are identical for any worker count and any rerun of the same
//! replay. Non-deterministic output keeps everything, wall entries
//! included.

use std::collections::BTreeMap;

use super::{Clock, Kind, Name, Trace, TraceEntry};
use crate::util::json::Json;

/// Exporter identifier stamped into `otherData.format`.
pub const FORMAT: &str = "forgemorph-trace-v1";

/// Entries an export shows: all of them, or the quarantined
/// deterministic subset (virtual clock only, lanes zeroed, re-sorted).
pub fn visible(trace: &Trace, deterministic: bool) -> Vec<TraceEntry> {
    let mut entries: Vec<TraceEntry> = if deterministic {
        trace
            .entries
            .iter()
            .filter(|e| e.clock == Clock::Virtual)
            .map(|e| TraceEntry { lane: 0, ..*e })
            .collect()
    } else {
        trace.entries.clone()
    };
    entries.sort_unstable();
    entries
}

fn resolve(trace: &Trace, idx: u16) -> String {
    trace.path_name(idx).map(str::to_string).unwrap_or_else(|| format!("path#{idx}"))
}

/// Per-name argument rendering: semantic keys where the taxonomy fixes
/// a meaning, generic `v0`/`v1` otherwise (zeroes omitted).
fn args_for(trace: &Trace, e: &TraceEntry) -> BTreeMap<String, Json> {
    let mut args = BTreeMap::new();
    args.insert("id".to_string(), Json::Num(e.id as f64));
    args.insert(
        "clock".to_string(),
        Json::Str(match e.clock {
            Clock::Virtual => "virtual".to_string(),
            Clock::Wall => "wall".to_string(),
        }),
    );
    if e.path > 0 {
        args.insert("path".to_string(), Json::Str(resolve(trace, e.path)));
    }
    if e.kind == Kind::Counter {
        args.insert("value".to_string(), Json::Num(e.a0 as f64));
        return args;
    }
    let mut put = |k: &str, v: u64| {
        args.insert(k.to_string(), Json::Num(v as f64));
    };
    match e.name {
        Name::Switch => {
            args.insert("from".to_string(), Json::Str(resolve(trace, e.a0 as u16)));
            put("budget_mw", e.a1);
        }
        Name::Rollback => {
            args.insert("from".to_string(), Json::Str(resolve(trace, e.a0 as u16)));
            put("cooldown_frames", e.a1);
        }
        Name::SwapWindow => put("stall_frames", e.a0),
        Name::Retry => put("attempt", e.a0),
        Name::FaultTransient => {
            put("fails", e.a0);
            args.insert("recovered".to_string(), Json::Bool(e.a1 != 0));
        }
        Name::FaultStall => put("vshard", e.a0),
        Name::FaultSeu => {
            put("bit", e.a0);
            put("loaded", e.a1);
        }
        Name::Enqueue => {
            if e.a1 != 0 {
                args.insert("degraded".to_string(), Json::Bool(true));
            }
        }
        Name::DseGeneration => {
            put("evals", e.a0);
            put("best_lat_us", e.a1);
        }
        Name::KdTeacher | Name::KdStudent | Name::KdPolish | Name::KdCalibrate => {
            put("epoch", e.a0);
            put("loss_u", e.a1);
        }
        _ => {
            if e.a0 != 0 {
                put("v0", e.a0);
            }
            if e.a1 != 0 {
                put("v1", e.a1);
            }
        }
    }
    if e.clock == Clock::Wall {
        put("lane", u64::from(e.lane));
    }
    args
}

/// Chrome trace-event JSON (the object form, with `traceEvents` +
/// `otherData`) — drag into Perfetto / `chrome://tracing`. All
/// timestamps are microseconds, the unit the format expects.
pub fn chrome_trace(trace: &Trace, deterministic: bool) -> String {
    let events = visible(trace, deterministic);
    let evs: Vec<Json> = events
        .iter()
        .map(|e| {
            let mut o = BTreeMap::new();
            let cat = e.name.cat();
            o.insert(
                "ph".to_string(),
                Json::Str(
                    match e.kind {
                        Kind::Span => "X",
                        Kind::Instant => "i",
                        Kind::Counter => "C",
                    }
                    .to_string(),
                ),
            );
            o.insert("ts".to_string(), Json::Num(e.ts_us as f64));
            if e.kind == Kind::Span {
                o.insert("dur".to_string(), Json::Num(e.dur_us as f64));
            }
            if e.kind == Kind::Instant {
                o.insert("s".to_string(), Json::Str("t".to_string()));
            }
            o.insert("pid".to_string(), Json::Num(0.0));
            o.insert("tid".to_string(), Json::Num(cat.tid() as f64));
            o.insert("cat".to_string(), Json::Str(cat.as_str().to_string()));
            o.insert("name".to_string(), Json::Str(e.name.as_str().to_string()));
            o.insert("args".to_string(), Json::Obj(args_for(trace, e)));
            Json::Obj(o)
        })
        .collect();

    let mut other = BTreeMap::new();
    other.insert("format".to_string(), Json::Str(FORMAT.to_string()));
    other.insert("deterministic".to_string(), Json::Bool(deterministic));
    other.insert("dropped".to_string(), Json::Num(trace.dropped as f64));
    for (k, v) in &trace.meta {
        other.insert(k.clone(), Json::Str(v.clone()));
    }

    let mut root = BTreeMap::new();
    root.insert("traceEvents".to_string(), Json::Arr(evs));
    root.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    root.insert("otherData".to_string(), Json::Obj(other));
    format!("{}\n", Json::Obj(root))
}

/// Folded-stack flamegraph text: one `cat;name[;path] total_us` line
/// per span aggregate, sorted — pipe into any flamegraph renderer.
pub fn folded(trace: &Trace, deterministic: bool) -> String {
    let mut agg: BTreeMap<String, u64> = BTreeMap::new();
    for e in visible(trace, deterministic) {
        if e.kind != Kind::Span {
            continue;
        }
        let mut key = format!("{};{}", e.name.cat().as_str(), e.name.as_str());
        if e.path > 0 {
            key.push(';');
            key.push_str(&resolve(trace, e.path));
        }
        *agg.entry(key).or_insert(0) += e.dur_us;
    }
    let mut out = String::new();
    for (key, total) in agg {
        out.push_str(&format!("{key} {total}\n"));
    }
    out
}

/// Plain-text metrics snapshot: per-(category, name) event counts and
/// total span time, plus the drop counter and run metadata.
pub fn text_snapshot(trace: &Trace) -> String {
    let mut counts: BTreeMap<(String, String), (u64, u64)> = BTreeMap::new();
    for e in &trace.entries {
        let slot = counts
            .entry((e.name.cat().as_str().to_string(), e.name.as_str().to_string()))
            .or_insert((0, 0));
        slot.0 += 1;
        if e.kind == Kind::Span {
            slot.1 += e.dur_us;
        }
    }
    let mut out = format!(
        "trace snapshot: {} entries, {} dropped\n",
        trace.entries.len(),
        trace.dropped
    );
    for (k, v) in &trace.meta {
        out.push_str(&format!("  {k}: {v}\n"));
    }
    out.push_str(&format!("{:<28} {:>8} {:>14}\n", "category;name", "events", "span_us"));
    for ((cat, name), (n, dur)) in counts {
        let stack = format!("{cat};{name}");
        out.push_str(&format!("{stack:<28} {n:>8} {dur:>14}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        let sink = super::super::TraceSink::new(64);
        let p = sink.intern("d3_w100");
        sink.set_meta("model", "mnist");
        sink.record(0, TraceEntry::span(Clock::Virtual, Name::Execute, 250, 90, 1).with_path(p));
        sink.record(
            0,
            TraceEntry::instant(Clock::Virtual, Name::Switch, 500, 2)
                .with_path(p)
                .with_args(u64::from(p), 450),
        );
        sink.record(1, TraceEntry::span(Clock::Wall, Name::Execute, 123, 45, 1).with_path(p));
        sink.record(0, TraceEntry::counter(Clock::Virtual, Name::StageHits, 1000, 17));
        sink.drain()
    }

    #[test]
    fn chrome_trace_parses_and_quarantines_wall_entries() {
        let trace = sample_trace();
        let full = chrome_trace(&trace, false);
        let det = chrome_trace(&trace, true);
        for text in [&full, &det] {
            let parsed = Json::parse(text).expect("exporter emits valid JSON");
            let events = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
            assert!(!events.is_empty());
            let other = parsed.get("otherData").unwrap();
            assert_eq!(other.get("format").and_then(Json::as_str), Some(FORMAT));
            assert_eq!(other.get("dropped").and_then(Json::as_f64), Some(0.0));
            assert_eq!(other.get("model").and_then(Json::as_str), Some("mnist"));
        }
        assert!(full.contains("\"wall\""));
        assert!(!det.contains("\"wall\""), "deterministic export must quarantine wall entries");
        assert!(det.contains("\"switch\""));
        assert!(det.contains("\"d3_w100\""));
        assert!(det.contains("\"value\":17"));
    }

    #[test]
    fn folded_aggregates_span_time_by_stack() {
        let trace = sample_trace();
        let det = folded(&trace, true);
        assert_eq!(det, "request;execute;d3_w100 90\n");
        let full = folded(&trace, false);
        assert_eq!(full, "request;execute;d3_w100 135\n");
    }

    #[test]
    fn text_snapshot_surfaces_drop_counter() {
        let sink = super::super::TraceSink::new(1);
        sink.record(0, TraceEntry::instant(Clock::Wall, Name::Enqueue, 1, 1));
        sink.record(0, TraceEntry::instant(Clock::Wall, Name::Enqueue, 2, 2));
        let text = text_snapshot(&sink.drain());
        assert!(text.starts_with("trace snapshot: 1 entries, 1 dropped"));
        assert!(text.contains("request;enqueue"));
    }
}
