//! Structured span/event recorder — the observability substrate every
//! subsystem writes into (DESIGN.md §14).
//!
//! One [`TraceSink`] per run, handed around as `Option<Arc<TraceSink>>`
//! (`None` = tracing off, zero cost beyond one branch). Recording goes
//! through per-lane bounded [`ring::Ring`]s — submit-side/virtual
//! entries on lane 0, worker shards on their own lanes — merged at
//! [`TraceSink::drain`] with an associative sorted union, so the
//! drained trace is independent of lane grouping and drop counts are
//! never silently truncated.
//!
//! **Clock quarantine rule:** entries are stamped [`Clock::Virtual`]
//! wherever a virtual clock exists (trace replay frames, DSE
//! generations, distill epochs) and [`Clock::Wall`] otherwise (live
//! worker timings). Deterministic exports keep only `Virtual` entries
//! and zero the lane field, so `--trace-deterministic` output is
//! byte-identical across worker counts and reruns — the same contract
//! the power/fault replay logs already enforce.

pub mod export;
pub mod ring;

use std::sync::{Arc, Mutex};
use std::time::Instant;

use ring::{Ring, RingDump};

/// Which clock stamped an entry. `Wall` entries are quarantined: they
/// never appear in a deterministic export.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Clock {
    Virtual,
    Wall,
}

/// Chrome trace-event phase the entry exports as.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Kind {
    /// complete event (`"ph":"X"`, has a duration)
    Span,
    /// instant event (`"ph":"i"`)
    Instant,
    /// counter sample (`"ph":"C"`, value in `a0`)
    Counter,
}

/// Span category — one Chrome track (`tid`) per category.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Cat {
    Request,
    Governor,
    Swap,
    Fault,
    Scrub,
    Retry,
    Dse,
    Distill,
}

impl Cat {
    pub fn as_str(self) -> &'static str {
        match self {
            Cat::Request => "request",
            Cat::Governor => "governor",
            Cat::Swap => "swap",
            Cat::Fault => "fault",
            Cat::Scrub => "scrub",
            Cat::Retry => "retry",
            Cat::Dse => "dse",
            Cat::Distill => "distill",
        }
    }

    /// Stable per-category Chrome track id.
    pub fn tid(self) -> u64 {
        match self {
            Cat::Request => 1,
            Cat::Governor => 2,
            Cat::Swap => 3,
            Cat::Fault => 4,
            Cat::Scrub => 5,
            Cat::Retry => 6,
            Cat::Dse => 7,
            Cat::Distill => 8,
        }
    }
}

/// Span taxonomy (DESIGN.md §14). The name fixes the category.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Name {
    /// request entered a shard queue
    Enqueue,
    /// a worker pulled a batch (arg: batch length)
    Batch,
    /// frame execution — virtual (modeled path frame time) on the
    /// replay path, wall (measured backend time) on workers
    Execute,
    /// terminal responses delivered for a batch
    Respond,
    /// bounded-retry resubmission (arg: attempt)
    Retry,
    /// committed governor switch (args: from path, budget)
    Switch,
    /// failed swap rolled back (span over the wasted DPR window)
    Rollback,
    /// modeled DPR window of a committed switch
    SwapWindow,
    /// SEU strike on the gate state
    FaultSeu,
    /// CRC scrub pass repaired the gate state (span = MTTR)
    ScrubRepair,
    /// injected transient execute failure
    FaultTransient,
    /// injected straggler stall (span = stall)
    FaultStall,
    /// one DSE generation (args: evals, best feasible latency)
    DseGeneration,
    /// cumulative chromosome-memo hits (counter)
    CacheHits,
    /// cumulative stage-cache hits (counter)
    StageHits,
    /// cumulative roofline-pruned offspring (counter)
    RooflinePruned,
    /// cumulative surrogate dispatch reorders (counter)
    SurrogateReorders,
    /// KD teacher epoch (args: epoch, mean loss ×1e6)
    KdTeacher,
    /// KD student epoch
    KdStudent,
    /// final full-path polish epoch
    KdPolish,
    /// head-only calibration pass
    KdCalibrate,
}

impl Name {
    pub fn as_str(self) -> &'static str {
        match self {
            Name::Enqueue => "enqueue",
            Name::Batch => "batch",
            Name::Execute => "execute",
            Name::Respond => "respond",
            Name::Retry => "retry",
            Name::Switch => "switch",
            Name::Rollback => "rollback",
            Name::SwapWindow => "swap_window",
            Name::FaultSeu => "seu",
            Name::ScrubRepair => "scrub_repair",
            Name::FaultTransient => "transient",
            Name::FaultStall => "stall",
            Name::DseGeneration => "generation",
            Name::CacheHits => "cache_hits",
            Name::StageHits => "stage_hits",
            Name::RooflinePruned => "roofline_pruned",
            Name::SurrogateReorders => "surrogate_reorders",
            Name::KdTeacher => "kd_teacher",
            Name::KdStudent => "kd_student",
            Name::KdPolish => "kd_polish",
            Name::KdCalibrate => "kd_calibrate",
        }
    }

    pub fn cat(self) -> Cat {
        match self {
            Name::Enqueue | Name::Batch | Name::Execute | Name::Respond => Cat::Request,
            Name::Retry => Cat::Retry,
            Name::Switch | Name::Rollback => Cat::Governor,
            Name::SwapWindow => Cat::Swap,
            Name::FaultSeu | Name::FaultTransient | Name::FaultStall => Cat::Fault,
            Name::ScrubRepair => Cat::Scrub,
            Name::DseGeneration
            | Name::CacheHits
            | Name::StageHits
            | Name::RooflinePruned
            | Name::SurrogateReorders => Cat::Dse,
            Name::KdTeacher | Name::KdStudent | Name::KdPolish | Name::KdCalibrate => {
                Cat::Distill
            }
        }
    }
}

/// One recorded event. `Copy` with fixed-width fields only — pushing
/// one onto a pre-allocated ring is the whole hot-path cost. The
/// derived total order (declaration order: timestamp first, recording
/// lane last) is what drain-merge and deterministic export sort by;
/// because it covers every field, compare-equal entries are identical
/// and the order is total.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TraceEntry {
    pub ts_us: u64,
    pub dur_us: u64,
    pub clock: Clock,
    pub kind: Kind,
    pub name: Name,
    /// request id / frame / generation / stage — whatever the span keys
    pub id: u64,
    /// 1-based [`TraceSink::intern`] index of the morph path, 0 = none
    pub path: u16,
    pub a0: u64,
    pub a1: u64,
    /// recording lane — wall-side diagnostic only, zeroed (quarantined)
    /// in deterministic exports
    pub lane: u16,
}

impl TraceEntry {
    pub fn span(clock: Clock, name: Name, ts_us: u64, dur_us: u64, id: u64) -> TraceEntry {
        TraceEntry {
            ts_us,
            dur_us,
            clock,
            kind: Kind::Span,
            name,
            id,
            path: 0,
            a0: 0,
            a1: 0,
            lane: 0,
        }
    }

    pub fn instant(clock: Clock, name: Name, ts_us: u64, id: u64) -> TraceEntry {
        TraceEntry { kind: Kind::Instant, ..TraceEntry::span(clock, name, ts_us, 0, id) }
    }

    pub fn counter(clock: Clock, name: Name, ts_us: u64, value: u64) -> TraceEntry {
        TraceEntry {
            kind: Kind::Counter,
            a0: value,
            ..TraceEntry::span(clock, name, ts_us, 0, 0)
        }
    }

    pub fn with_path(mut self, path: u16) -> TraceEntry {
        self.path = path;
        self
    }

    pub fn with_args(mut self, a0: u64, a1: u64) -> TraceEntry {
        self.a0 = a0;
        self.a1 = a1;
        self
    }
}

/// Trace time of replay frame `i` at `rate_hz` — the virtual clock the
/// power/fault replay already runs on.
pub fn virtual_us(frame: usize, rate_hz: f64) -> u64 {
    ((frame as f64 / rate_hz.max(1e-9)) * 1e6).round() as u64
}

/// Recording lanes: lane 0 is the submit/virtual side, worker shard
/// `s` records on lane `1 + s % (LANES - 1)`.
pub const LANES: usize = 9;

/// Per-lane ring capacity of [`TraceSink::shared`].
pub const DEFAULT_LANE_CAPACITY: usize = 8192;

/// The run-wide recorder. Cheap to share (`Arc`), safe from any thread;
/// each lane is an independently locked bounded ring so shards never
/// contend with the submit side.
#[derive(Debug)]
pub struct TraceSink {
    lanes: [Mutex<Ring>; LANES],
    paths: Mutex<Vec<String>>,
    meta: Mutex<Vec<(String, String)>>,
    epoch: Instant,
}

impl TraceSink {
    pub fn new(capacity_per_lane: usize) -> TraceSink {
        TraceSink {
            lanes: std::array::from_fn(|_| Mutex::new(Ring::new(capacity_per_lane))),
            paths: Mutex::new(Vec::new()),
            meta: Mutex::new(Vec::new()),
            epoch: Instant::now(),
        }
    }

    /// The usual handle: default capacity, behind an `Arc`.
    pub fn shared() -> Arc<TraceSink> {
        Arc::new(TraceSink::new(DEFAULT_LANE_CAPACITY))
    }

    /// Intern a morph-path name, returning its 1-based entry index
    /// (0 = table full, entry stays unattributed). Idempotent; only the
    /// first sighting of a name allocates, so pre-interning the ladder
    /// (the replay path does) keeps indices deterministic and the hot
    /// path allocation-free.
    pub fn intern(&self, path: &str) -> u16 {
        let mut table = self.paths.lock().unwrap();
        if let Some(i) = table.iter().position(|p| p == path) {
            return (i + 1) as u16;
        }
        if table.len() >= usize::from(u16::MAX - 1) {
            return 0;
        }
        table.push(path.to_string());
        table.len() as u16
    }

    /// Deterministic run metadata carried into every export.
    pub fn set_meta(&self, key: &str, value: &str) {
        self.meta.lock().unwrap().push((key.to_string(), value.to_string()));
    }

    /// Record one entry on `lane` (wrapped into the lane array). The
    /// entry is `Copy` and the ring pre-allocated: no allocation, one
    /// uncontended lock.
    pub fn record(&self, lane: usize, mut e: TraceEntry) {
        let lane = if lane == 0 { 0 } else { 1 + (lane - 1) % (LANES - 1) };
        e.lane = lane as u16;
        self.lanes[lane].lock().unwrap().push(e);
    }

    /// Microseconds since the sink was created — the quarantined wall
    /// clock for live-path entries.
    pub fn wall_now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Entries recorded so far (diagnostic).
    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain every lane and fold the dumps with the associative
    /// [`ring::merge`] — the resulting entry sequence is the sorted
    /// multiset union of all lanes, independent of lane grouping.
    pub fn drain(&self) -> Trace {
        let mut merged = RingDump::default();
        for lane in &self.lanes {
            merged = ring::merge(merged, lane.lock().unwrap().take());
        }
        Trace {
            entries: merged.entries,
            dropped: merged.dropped,
            paths: self.paths.lock().unwrap().clone(),
            meta: self.meta.lock().unwrap().clone(),
        }
    }
}

/// A drained run: sorted entries, total shed count, interned path
/// table, run metadata.
#[derive(Debug, Clone)]
pub struct Trace {
    pub entries: Vec<TraceEntry>,
    pub dropped: u64,
    pub paths: Vec<String>,
    pub meta: Vec<(String, String)>,
}

impl Trace {
    /// Resolve a 1-based interned path index.
    pub fn path_name(&self, idx: u16) -> Option<&str> {
        idx.checked_sub(1).and_then(|i| self.paths.get(usize::from(i))).map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent_and_one_based() {
        let sink = TraceSink::new(16);
        assert_eq!(sink.intern("d3_w100"), 1);
        assert_eq!(sink.intern("d2_w75"), 2);
        assert_eq!(sink.intern("d3_w100"), 1);
        let trace = sink.drain();
        assert_eq!(trace.path_name(1), Some("d3_w100"));
        assert_eq!(trace.path_name(2), Some("d2_w75"));
        assert_eq!(trace.path_name(0), None);
        assert_eq!(trace.path_name(3), None);
    }

    #[test]
    fn drain_merges_lanes_sorted_with_drop_total() {
        let sink = TraceSink::new(2);
        for lane in [0usize, 1, 2] {
            for i in 0..3u64 {
                sink.record(
                    lane,
                    TraceEntry::span(Clock::Wall, Name::Execute, 100 * i + lane as u64, 5, i),
                );
            }
        }
        // capacity 2 per lane: each lane shed exactly one entry
        let trace = sink.drain();
        assert_eq!(trace.entries.len(), 6);
        assert_eq!(trace.dropped, 3);
        assert!(trace.entries.windows(2).all(|w| w[0] <= w[1]));
        // lanes stamped: lane 0 kept, worker lanes offset into 1..LANES
        assert!(trace.entries.iter().any(|e| e.lane == 0));
        assert!(trace.entries.iter().any(|e| e.lane == 1));
        assert!(trace.entries.iter().any(|e| e.lane == 2));
    }

    #[test]
    fn virtual_clock_matches_replay_frame_times() {
        assert_eq!(virtual_us(0, 4000.0), 0);
        assert_eq!(virtual_us(1, 4000.0), 250);
        assert_eq!(virtual_us(240, 4000.0), 60_000);
    }
}
