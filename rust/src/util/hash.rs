//! Minimal FxHash-style hasher (no `rustc-hash`/`fnv` in the offline
//! vendor set).
//!
//! The DSE memo cache keys on whole chromosomes (`[usize]` gene
//! vectors); SipHash's per-lookup cost is visible at that call rate, so
//! we vendor the tiny multiply-rotate word hasher rustc itself uses.
//! Not DoS-resistant — fine for keys we generate ourselves.

use std::hash::{BuildHasher, Hasher};

/// The Firefox/rustc FxHash multiplier (a pi-derived odd constant).
const K: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Word-at-a-time multiply-rotate hasher.
#[derive(Debug, Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(K);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

/// `BuildHasher` for [`FxHasher`] (zero-sized, `Default`-constructible,
/// so `FxHashMap::default()` works everywhere `HashMap::new` would).
#[derive(Debug, Default, Clone)]
pub struct FxBuildHasher;

impl BuildHasher for FxBuildHasher {
    type Hasher = FxHasher;

    #[inline]
    fn build_hasher(&self) -> FxHasher {
        FxHasher::default()
    }
}

/// A `HashMap` using [`FxHasher`].
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    fn hash_of(genes: &[usize]) -> u64 {
        use std::hash::Hash;
        let mut h = FxHasher::default();
        genes.hash(&mut h);
        h.finish()
    }

    #[test]
    fn deterministic() {
        assert_eq!(hash_of(&[1, 2, 3]), hash_of(&[1, 2, 3]));
    }

    #[test]
    fn distinguishes_nearby_keys() {
        let a = hash_of(&[1, 2, 3]);
        assert_ne!(a, hash_of(&[1, 2, 4]));
        assert_ne!(a, hash_of(&[3, 2, 1]));
        assert_ne!(a, hash_of(&[1, 2]));
        assert_ne!(a, hash_of(&[1, 2, 3, 0]));
    }

    #[test]
    fn map_lookup_by_borrowed_slice() {
        let mut m: FxHashMap<Box<[usize]>, u32> = FxHashMap::default();
        m.insert(vec![4, 8, 16].into_boxed_slice(), 7);
        // Box<[usize]>: Borrow<[usize]> — lookups need no allocation
        let key: &[usize] = &[4, 8, 16];
        assert_eq!(m.get(key), Some(&7));
        let miss: &[usize] = &[4, 8, 17];
        assert_eq!(m.get(miss), None);
    }

    #[test]
    fn spread_over_buckets() {
        // weak avalanche check: 256 sequential 3-gene keys should not
        // collide at 64-bit width
        let mut seen = std::collections::BTreeSet::new();
        for a in 0..4usize {
            for b in 0..8usize {
                for c in 0..8usize {
                    seen.insert(hash_of(&[a, b, c]));
                }
            }
        }
        assert_eq!(seen.len(), 4 * 8 * 8);
    }
}
