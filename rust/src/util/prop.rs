//! In-tree property-test harness (no `proptest` in the offline vendor set).
//!
//! A deliberately small shrink-free QuickCheck: generate `n` random cases
//! from a seeded [`Rng`](super::rng::Rng), run the property, and on
//! failure report the case index + seed so the exact case replays.

use super::rng::Rng;

/// Run `prop` against `n` generated cases. `gen` builds a case from the
/// RNG; `prop` returns `Err(description)` on violation.
pub fn check<T, G, P>(name: &str, n: usize, seed: u64, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..n {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Assert helper for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("sum-commutes", 50, 1, |r| (r.range(0, 9), r.range(0, 9)), |&(a, b)| {
            count += 1;
            ensure(a + b == b + a, "addition must commute")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_context() {
        check("always-fails", 10, 2, |r| r.range(0, 9), |_| ensure(false, "nope"));
    }
}
