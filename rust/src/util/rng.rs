//! Deterministic PRNG (xoshiro256**) — no `rand` crate in the offline set.
//!
//! Used by the MOGA (NeuroForge's stochastic search), workload generators
//! and the in-tree property-test harness. Seeded runs are fully
//! reproducible across platforms.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi]` (inclusive). Panics if `lo > hi`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "range({lo}, {hi})");
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inter-arrival times for the
    /// serving workload generator).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Power-distribution sample in `[0,1]` (Algorithm 1's mutation draws
    /// `s` from a power distribution; exponent > 1 biases small steps).
    pub fn power(&mut self, exponent: f64) -> f64 {
        self.f64().powf(exponent)
    }

    /// Jump 2^128 draws ahead (the xoshiro256** jump polynomial).
    ///
    /// Partitions one seed's period into non-overlapping substreams:
    /// callers that hand work to parallel evaluators can give each
    /// worker its own jumped stream and stay reproducible for any
    /// worker count.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180ec6d33cfd0aba,
            0xd5a61266f0c9392c,
            0xa9582618e03fc9aa,
            0x39abdc4529b1661c,
        ];
        let mut s = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j >> b) & 1 == 1 {
                    s[0] ^= self.s[0];
                    s[1] ^= self.s[1];
                    s[2] ^= self.s[2];
                    s[3] ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = s;
    }

    /// Split off an independent child stream. The child continues from
    /// the current state; `self` jumps 2^128 draws ahead, so successive
    /// children (and the parent) never overlap within 2^128 draws each.
    pub fn split(&mut self) -> Rng {
        let child = self.clone();
        self.jump();
        child
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a reference to a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_inclusive_and_covering() {
        let mut r = Rng::new(4);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            let v = r.range(2, 6);
            assert!((2..=6).contains(&v));
            seen[(v - 2) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn jump_is_deterministic_and_advances() {
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        a.jump();
        b.jump();
        let mut c = Rng::new(11); // un-jumped control
        let ja: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let jb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let cc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(ja, jb, "jump must be deterministic");
        assert_ne!(ja, cc, "jump must move to a different stream position");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Rng::new(12);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let s1: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let s2: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        let sp: Vec<u64> = (0..8).map(|_| parent.next_u64()).collect();
        assert_ne!(s1, s2);
        assert_ne!(s1, sp);
        assert_ne!(s2, sp);
        // same seed → same children
        let mut parent_b = Rng::new(12);
        let mut c1b = parent_b.split();
        assert_eq!(s1, (0..8).map(|_| c1b.next_u64()).collect::<Vec<_>>());
    }

    #[test]
    fn exp_positive() {
        let mut r = Rng::new(8);
        for _ in 0..100 {
            assert!(r.exp(10.0) > 0.0);
        }
    }
}
