//! Cross-cutting utilities: JSON, PRNG, CLI parsing, property testing.
//!
//! These exist because the offline vendor set carries only the `xla`
//! crate's dependency closure — no serde / rand / clap / proptest.

pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;

/// Levenshtein distance — the shared kernel of every did-you-mean
/// suggestion (graph descriptor ops, fault-trace kinds).
pub fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Closest candidate within edit distance 2 of `unknown`, if any.
pub fn suggest<'a>(unknown: &str, candidates: &[&'a str]) -> Option<&'a str> {
    candidates
        .iter()
        .map(|&c| (edit_distance(unknown, c), c))
        .filter(|&(d, _)| d <= 2)
        .min_by_key(|&(d, _)| d)
        .map(|(_, c)| c)
}

/// The canonical "did you mean" suffix built on [`suggest`]: returns
/// ` (did you mean 'X'?)` when a candidate is within edit distance 2,
/// or an empty string otherwise. Every user-facing unknown-identifier
/// error (graph parser, zoo lookup, fault kinds, report ids, ONNX ops)
/// appends this so the phrasing stays uniform and greppable.
pub fn did_you_mean(unknown: &str, candidates: &[&str]) -> String {
    match suggest(unknown, candidates) {
        Some(s) => format!(" (did you mean '{s}'?)"),
        None => String::new(),
    }
}

/// Format a f64 with engineering-friendly precision (tables/reports).
pub fn fmt_sig(x: f64, sig: usize) -> String {
    if x == 0.0 || !x.is_finite() {
        return format!("{x}");
    }
    let mag = x.abs().log10().floor() as i32;
    let decimals = (sig as i32 - 1 - mag).max(0) as usize;
    format!("{:.*}", decimals, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(edit_distance("stall", "stall"), 0);
        assert_eq!(suggest("sue", &["transient", "stall", "swapfail", "seu"]), Some("seu"));
        assert_eq!(suggest("completely-off", &["seu", "stall"]), None);
    }

    #[test]
    fn fmt_sig_basics() {
        assert_eq!(fmt_sig(1234.5, 3), "1234"); // ties-to-even
        assert_eq!(fmt_sig(0.012345, 3), "0.0123");
        assert_eq!(fmt_sig(2.5, 2), "2.5");
        assert_eq!(fmt_sig(0.0, 3), "0");
    }
}
