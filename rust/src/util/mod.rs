//! Cross-cutting utilities: JSON, PRNG, CLI parsing, property testing.
//!
//! These exist because the offline vendor set carries only the `xla`
//! crate's dependency closure — no serde / rand / clap / proptest.

pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;

/// Format a f64 with engineering-friendly precision (tables/reports).
pub fn fmt_sig(x: f64, sig: usize) -> String {
    if x == 0.0 || !x.is_finite() {
        return format!("{x}");
    }
    let mag = x.abs().log10().floor() as i32;
    let decimals = (sig as i32 - 1 - mag).max(0) as usize;
    format!("{:.*}", decimals, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_sig_basics() {
        assert_eq!(fmt_sig(1234.5, 3), "1234"); // ties-to-even
        assert_eq!(fmt_sig(0.012345, 3), "0.0123");
        assert_eq!(fmt_sig(2.5, 2), "2.5");
        assert_eq!(fmt_sig(0.0, 3), "0");
    }
}
