//! Minimal JSON parser/writer.
//!
//! The offline vendor set has no `serde`, so the coordinator carries its
//! own small JSON module — enough for `artifacts/manifest.json` and the
//! report harness. Full RFC 8259 input syntax (objects, arrays, strings
//! with escapes, numbers, bools, null); no serde-style derive.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors ------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` lookup that tolerates non-objects (returns None).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Array of f64s (None if any element is non-numeric).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_u64().map(|u| u as usize))
            .collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // surrogate pairs are rare in our manifests;
                            // map unpaired surrogates to U+FFFD
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape character")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":false}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"arr":[1,2.5,null],"s":"a\"b","t":true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""A\té日""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\té日");
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n":3,"v":[1,2,3]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("v").unwrap().as_usize_vec(), Some(vec![1, 2, 3]));
        assert_eq!(v.get("missing"), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
    }
}
