//! Tiny CLI argument parser (no `clap` in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

/// Parsed command line: positionals plus `--key [value]` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.opts.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse("serve --verbose --model mnist extra");
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("model"), Some("mnist"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--pop=64 --gens=10");
        assert_eq!(a.get_usize("pop", 0), 64);
        assert_eq!(a.get_usize("gens", 0), 10);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("model", "mnist"), "mnist");
        assert_eq!(a.get_f64("budget", 1.5), 1.5);
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn u64_values() {
        let a = parse("--seed 18446744073709551615");
        assert_eq!(a.get_u64("seed", 0), u64::MAX);
        assert_eq!(a.get_u64("missing", 7), 7);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--quiet --fast");
        assert!(a.flag("quiet") && a.flag("fast"));
        assert!(a.positional.is_empty());
    }
}
