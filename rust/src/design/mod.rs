//! Hardware design model: a scheduled network + per-layer parallelism ->
//! latency, resources, power (Sec. III-B/C, Eqs. 12-15).
//!
//! A **design point** assigns each conv-like stage of the
//! [`StagePlan`](crate::graph::passes::StagePlan) a parallelism degree
//! `p(i)` with `1 <= p(i) <= ub(i)` (ub = filter count). Following
//! Eq. 14, stage i instantiates `L(i) = p(i) * p(i-1)` C_PEs: `p(i)`
//! filter lanes, each replicated across `p(i-1)` input-channel streams —
//! with `p(i-1)` now resolved along the *dataflow edges* of the plan, not
//! the layer list, so forked branches inherit lanes from their true
//! producer.
//!
//! Pipeline timing follows Eq. 12-13: `T = m*P + (n-1)*I` with `m` the
//! fill delay (line buffers + MAC overheads), `n` the streamed elements
//! of the input frame, and `I` the initiation interval set by the most
//! serialized stage. Branchy topologies add merge costs the chain model
//! never paid: `Concat` stages carry channel-select mux logic per input
//! lane plus the BRAM of their branch re-sync FIFOs (the plan's
//! `Branch`-edge `fifo_words` at the datapath width), `Upsample` stages
//! pace at their *output* frame rate and buffer one input row, and
//! `SpatialPyramidPool` stages pay three pool PEs per lane, the four-tap
//! concat mux and the cascade's row-skew FIFO.

use crate::graph::passes::{self, StagePlan};
use crate::graph::{shapes, LayerKind, Network};
use crate::pe::conv::ConvPe;
use crate::pe::fc::FcPe;
use crate::pe::pool::{PoolKind, PoolPe};
use crate::pe::{Blanking, Device, FpRep, Resources};
use crate::power::{Activity, PowerModel};

/// A candidate hardware configuration (the MOGA chromosome, Sec. III-C).
#[derive(Debug, Clone, PartialEq)]
pub struct DesignConfig {
    /// parallelism p(i) per conv-like stage, in StagePlan gene order
    /// (identical to the legacy conv-layer order)
    pub parallelism: Vec<usize>,
    /// fixed-point width of the datapath
    pub rep: FpRep,
}

impl DesignConfig {
    pub fn uniform(net: &Network, p: usize, rep: FpRep) -> DesignConfig {
        DesignConfig {
            parallelism: net
                .conv_filter_bounds()
                .iter()
                .map(|&ub| p.min(ub).max(1))
                .collect(),
            rep,
        }
    }

    /// Fully parallel mapping (one PE lane per filter).
    pub fn full(net: &Network, rep: FpRep) -> DesignConfig {
        DesignConfig { parallelism: net.conv_filter_bounds(), rep }
    }

    /// Bottleneck-balancing greedy allocation under a device budget:
    /// start at p(i)=1 everywhere, repeatedly double the parallelism of
    /// the worst-occupancy stage until the next step would blow the
    /// budget or nothing improves. Deterministic fast-path for the big
    /// Table IV/V models (the MOGA finds the same knee; this gets there
    /// in O(stages x steps)).
    ///
    /// §Perf: every greedy step runs on the prebuilt [`Evaluator`]
    /// (plan scheduling hoisted out, trial vectors mutated in place) —
    /// the old path cloned the whole config and re-ran full `evaluate`
    /// per probe. Same answer (`balanced_matches_full_evaluate_greedy`
    /// pins equivalence), ~an order of magnitude fewer cycles.
    pub fn balanced(net: &Network, rep: FpRep, device: &Device) -> DesignConfig {
        let bounds = net.conv_filter_bounds();
        let Ok(ev) = Evaluator::new(net, device) else {
            return DesignConfig { parallelism: vec![1; bounds.len()], rep };
        };
        let mut par = vec![1usize; bounds.len()];
        let mut occ: Vec<usize> = Vec::with_capacity(bounds.len());
        let mut order: Vec<usize> = vec![0; bounds.len()];
        loop {
            if ev.conv_occupancies(&par, rep, &mut occ).is_err() {
                break;
            }
            // order chromosome slots by stage occupancy, worst first
            // (stable sort: ties resolve to the earlier slot, matching
            // the original full-evaluate greedy)
            for (slot, o) in order.iter_mut().enumerate() {
                *o = slot;
            }
            order.sort_by_key(|&slot| std::cmp::Reverse(occ[slot]));
            let mut improved = false;
            for &slot in &order {
                if par[slot] >= bounds[slot] {
                    continue;
                }
                let cur = par[slot];
                for next in [(cur * 2).min(bounds[slot]), (cur + 1).min(bounds[slot])] {
                    if next == cur {
                        continue;
                    }
                    par[slot] = next;
                    if let Ok(e) = ev.objectives(&par, rep) {
                        if ev.fits(&e) {
                            improved = true;
                            break;
                        }
                    }
                    par[slot] = cur;
                }
                if improved {
                    break;
                }
            }
            if !improved {
                break;
            }
        }
        DesignConfig { parallelism: par, rep }
    }
}

/// Per-stage mapping outcome.
#[derive(Debug, Clone)]
pub struct LayerMapping {
    /// stage id in the StagePlan (== canonical layer id)
    pub layer_id: usize,
    pub name: String,
    /// C_PE (or pool/FC/merge unit) count for this stage
    pub pe_count: usize,
    /// sequential passes needed to cover all (filter, channel) pairs
    pub serial_factor: usize,
    /// cycles this stage occupies per frame (pass cycles x serial)
    pub occupancy_cycles: usize,
    /// pipeline fill contribution (line buffer + MAC overheads)
    pub fill_cycles: usize,
    pub resources: Resources,
}

/// Full evaluation of one design point.
#[derive(Debug, Clone)]
pub struct DesignEval {
    /// one mapping per StagePlan stage, in stage order
    pub mappings: Vec<LayerMapping>,
    pub resources: Resources,
    /// total C_PE-equivalents (the "Design PEs" column of Table III)
    pub total_pes: usize,
    /// first-frame latency (Eq. 12-13)
    pub latency_cycles: usize,
    /// steady-state frame period (1/throughput)
    pub period_cycles: usize,
    pub clock_mhz: f64,
}

impl DesignEval {
    pub fn latency_ms(&self) -> f64 {
        self.latency_cycles as f64 / (self.clock_mhz * 1e3)
    }

    pub fn fps(&self) -> f64 {
        self.clock_mhz * 1e6 / self.period_cycles as f64
    }

    pub fn power_mw(&self, model: &PowerModel, act: Activity) -> f64 {
        model.total_mw(&self.resources, self.clock_mhz, act)
    }

    pub fn energy_per_frame_j(&self, model: &PowerModel, act: Activity) -> f64 {
        // energy of one frame at steady state
        let period_ms = self.period_cycles as f64 / (self.clock_mhz * 1e3);
        model.energy_per_frame_mj(&self.resources, self.clock_mhz, act, period_ms) / 1000.0
    }

    pub fn fits(&self, device: &Device) -> bool {
        self.resources.fits(&device.budget)
    }
}

#[derive(Debug)]
pub enum DesignError {
    Shape(shapes::ShapeError),
    Pass(passes::PassError),
    ArityMismatch { got: usize, want: usize },
    OutOfBounds { layer: usize, p: usize, ub: usize },
}

impl std::fmt::Display for DesignError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DesignError::Shape(e) => write!(f, "shape inference: {e}"),
            DesignError::Pass(e) => write!(f, "{e}"),
            DesignError::ArityMismatch { got, want } => write!(
                f,
                "parallelism vector has {got} entries, network has {want} conv stages"
            ),
            DesignError::OutOfBounds { layer, p, ub } => {
                write!(f, "stage {layer}: parallelism {p} outside [1, {ub}]")
            }
        }
    }
}

impl std::error::Error for DesignError {}

impl From<shapes::ShapeError> for DesignError {
    fn from(e: shapes::ShapeError) -> Self {
        DesignError::Shape(e)
    }
}

impl From<passes::PassError> for DesignError {
    fn from(e: passes::PassError) -> Self {
        DesignError::Pass(e)
    }
}

// ---------------------------------------------------------------------------
// Branch / merge cost constants (the logic the chain model never needed)
// ---------------------------------------------------------------------------

/// Concat merge: channel-select mux LUT/FF per input, per active lane.
const CONCAT_MUX_LUT: usize = 16;
const CONCAT_MUX_FF: usize = 8;
/// Upsample: row-repeat control per active lane.
const UPSAMPLE_LUT: usize = 40;
const UPSAMPLE_FF: usize = 24;
/// Standalone rectifier lane (comparator + output register).
const RELU_LUT: usize = 8;
const RELU_FF: usize = 4;

/// 18 Kb BRAM blocks needed to buffer `words` at the datapath width.
fn fifo_bram(words: usize, rep: FpRep) -> usize {
    if words == 0 {
        0
    } else {
        (words * rep.bits()).div_ceil(18 * 1024)
    }
}

/// BRAM of a merge stage's branch FIFOs, one independent FIFO per
/// incoming `Branch` edge (ceil-division applies per branch, matching
/// the per-branch FIFOs the RTL emits).
fn branch_fifo_bram(plan: &StagePlan, stage: usize, rep: FpRep) -> usize {
    plan.edges
        .iter()
        .filter(|e| e.dst == stage && e.kind == passes::EdgeKind::Branch)
        .map(|e| fifo_bram(e.fifo_words, rep))
        .sum()
}

/// Evaluate a design point on a device (the analytical fast path of the
/// DSE loop — no synthesis, microseconds per call). Schedules the pass
/// pipeline internally; hot paths that hold a [`StagePlan`] should call
/// [`evaluate_plan`] directly.
pub fn evaluate(
    net: &Network,
    cfg: &DesignConfig,
    device: &Device,
) -> Result<DesignEval, DesignError> {
    let plan = passes::schedule(net)?;
    evaluate_plan(&plan, cfg, device)
}

/// Evaluate a design point against a pre-scheduled [`StagePlan`].
pub fn evaluate_plan(
    plan: &StagePlan,
    cfg: &DesignConfig,
    device: &Device,
) -> Result<DesignEval, DesignError> {
    let bounds = plan.conv_bounds();
    if cfg.parallelism.len() != bounds.len() {
        return Err(DesignError::ArityMismatch {
            got: cfg.parallelism.len(),
            want: bounds.len(),
        });
    }
    for (i, (&p, &ub)) in cfg.parallelism.iter().zip(&bounds).enumerate() {
        if p == 0 || p > ub {
            return Err(DesignError::OutOfBounds { layer: i, p, ub });
        }
    }

    let blank = Blanking::default();
    // Pipeline pacing: each serialized stage re-reads its LOCAL input
    // feature map from the stage's BRAM buffers once per pass (filter
    // group x channel group), so a stage's occupancy per frame is
    // `local_frame_elements x serial_factor`. The steady-state frame
    // period is set by the most-occupied stage (Eq. 13's initiation
    // interval) — the "each stage constitutes a bottleneck" behaviour of
    // low-PE designs (Sec. V-B).
    let mut mappings = Vec::with_capacity(plan.stages.len());
    let mut total = Resources::default();
    // lanes flowing OUT of each already-scheduled stage, resolved along
    // the dataflow edges (the plan's preds), not the layer list
    let mut out_lanes: Vec<usize> = Vec::with_capacity(plan.stages.len());
    let mut first_conv_seen = false;

    for stage in &plan.stages {
        let inp = stage.input;
        let in_lanes = stage.preds.first().map(|&p| out_lanes[p]).unwrap_or(1);
        let mut lanes_out = in_lanes;
        let mapping = match &stage.kind {
            LayerKind::Conv { filters, k, relu, .. } => {
                let p = cfg.parallelism[stage.conv_slot.expect("conv stage has a gene slot")];
                let lanes_in = in_lanes.min(inp.c).max(1);
                let pe_count = p * lanes_in; // Eq. 14: L(i) = p(i) * p(i-1)
                let pe = ConvPe {
                    k: *k,
                    fm_w: inp.w,
                    fm_h: inp.h,
                    rep: cfg.rep,
                    relu: *relu,
                    first_layer: !first_conv_seen,
                };
                first_conv_seen = true;
                // sequential passes: filter groups x input-channel groups.
                // int8 packs two MACs per DSP48 (dual-lane SIMD), so each
                // PE lane covers two filters per pass — the 2x throughput
                // the paper's NeuroForge-8 rows show over NeuroForge-16.
                let simd = if cfg.rep == FpRep::Int8 { 2 } else { 1 };
                let serial = filters.div_ceil(p * simd) * inp.c.div_ceil(lanes_in);
                let pass = (inp.w + blank.back_porch + blank.front_porch) * inp.h;
                lanes_out = p;
                LayerMapping {
                    layer_id: stage.id,
                    name: stage.name.clone(),
                    pe_count,
                    serial_factor: serial,
                    occupancy_cycles: pass * serial,
                    fill_cycles: (k - 1) * (inp.w + blank.back_porch + blank.front_porch)
                        + pe.overhead_cycles(),
                    resources: pe.resources().scale(pe_count),
                }
            }
            LayerKind::DwConv { k, relu, .. } => {
                // depthwise: one lane per channel group, p carries over
                let p = cfg.parallelism[stage.conv_slot.expect("conv stage has a gene slot")];
                let pe = ConvPe {
                    k: *k,
                    fm_w: inp.w,
                    fm_h: inp.h,
                    rep: cfg.rep,
                    relu: *relu,
                    first_layer: !first_conv_seen,
                };
                first_conv_seen = true;
                let lanes = p.min(inp.c).max(1);
                let simd = if cfg.rep == FpRep::Int8 { 2 } else { 1 };
                let serial = inp.c.div_ceil(lanes * simd);
                let pass = (inp.w + blank.back_porch + blank.front_porch) * inp.h;
                lanes_out = lanes;
                LayerMapping {
                    layer_id: stage.id,
                    name: stage.name.clone(),
                    pe_count: lanes,
                    serial_factor: serial,
                    occupancy_cycles: pass * serial,
                    fill_cycles: (k - 1) * (inp.w + blank.back_porch + blank.front_porch)
                        + pe.overhead_cycles(),
                    resources: pe.resources().scale(lanes),
                }
            }
            LayerKind::MaxPool { k, stride } | LayerKind::AvgPool { k, stride } => {
                let kind = if matches!(stage.kind, LayerKind::MaxPool { .. }) {
                    PoolKind::Max
                } else {
                    PoolKind::Avg
                };
                let pe = PoolPe { k: *k, stride: *stride, fm_w: inp.w, fm_h: inp.h, kind };
                // one PU_PE per active channel lane, streams inline
                let lanes = in_lanes.min(inp.c).max(1);
                let serial = inp.c.div_ceil(lanes);
                let pass = (inp.w + blank.back_porch + blank.front_porch) * inp.h;
                LayerMapping {
                    layer_id: stage.id,
                    name: stage.name.clone(),
                    pe_count: lanes,
                    serial_factor: serial,
                    occupancy_cycles: pass * serial,
                    fill_cycles: (k - 1) * (inp.w + blank.back_porch + blank.front_porch) + 6,
                    resources: pe.resources().scale(lanes),
                }
            }
            LayerKind::Fc { out, .. } => {
                let n_pe = in_lanes.min(inp.c).max(1);
                let pe = FcPe {
                    fc_out: *out,
                    n_pe,
                    channels: inp.c,
                    fm_w: inp.w,
                    fm_h: inp.h.max(1),
                };
                LayerMapping {
                    layer_id: stage.id,
                    name: stage.name.clone(),
                    pe_count: *out * n_pe,
                    serial_factor: pe.parallelism(),
                    occupancy_cycles: pe.latency_cycles(blank),
                    fill_cycles: 4,
                    resources: pe.resources(),
                }
            }
            LayerKind::ResidualAdd { .. } => LayerMapping {
                layer_id: stage.id,
                name: stage.name.clone(),
                pe_count: in_lanes,
                serial_factor: 1,
                occupancy_cycles: 0,
                fill_cycles: 1,
                // one adder lane per active channel: LUT adders, no DSP
                resources: Resources { dsp: 0, lut: 24 * in_lanes, ff: 16 * in_lanes, bram: 0 },
            },
            LayerKind::Concat { .. } => {
                // channel-select mux over the input branches + the branch
                // re-sync FIFOs the plan sized on the incoming edges.
                // BRAM is summed PER EDGE — each branch instantiates its
                // own FIFO, so the ceil-division happens per branch.
                let n_in = stage.preds.len().max(1);
                let lanes =
                    stage.preds.iter().map(|&p| out_lanes[p]).max().unwrap_or(1);
                let bram = branch_fifo_bram(plan, stage.id, cfg.rep);
                lanes_out = lanes;
                LayerMapping {
                    layer_id: stage.id,
                    name: stage.name.clone(),
                    pe_count: lanes,
                    serial_factor: 1,
                    occupancy_cycles: 0,
                    fill_cycles: 2,
                    resources: Resources {
                        dsp: 0,
                        lut: CONCAT_MUX_LUT * n_in * lanes,
                        ff: CONCAT_MUX_FF * n_in * lanes,
                        bram,
                    },
                }
            }
            LayerKind::Upsample { .. } => {
                // row repeater: paces at the OUTPUT frame rate, buffers
                // one full input row across all channels
                let out = stage.output;
                let occ = (out.w + blank.back_porch + blank.front_porch) * out.h;
                LayerMapping {
                    layer_id: stage.id,
                    name: stage.name.clone(),
                    pe_count: in_lanes,
                    serial_factor: 1,
                    occupancy_cycles: occ,
                    fill_cycles: inp.w + 4,
                    resources: Resources {
                        dsp: 0,
                        lut: UPSAMPLE_LUT * in_lanes,
                        ff: UPSAMPLE_FF * in_lanes,
                        bram: fifo_bram(inp.w * inp.c, cfg.rep),
                    },
                }
            }
            LayerKind::SpatialPyramidPool { k } => {
                // three cascaded stride-1 pools per lane + four-tap concat;
                // the taps skew by (k-1) rows per cascade level, so the
                // re-sync FIFO holds (3+2+1)*(k-1) rows of all channels
                let lanes = in_lanes.min(inp.c).max(1);
                let pool = PoolPe { k: *k, stride: 1, fm_w: inp.w, fm_h: inp.h, kind: PoolKind::Max };
                let pass = (inp.w + blank.back_porch + blank.front_porch) * inp.h;
                let skew_words = 6 * (k - 1) * inp.w * inp.c;
                let mux = Resources {
                    dsp: 0,
                    lut: CONCAT_MUX_LUT * 4 * lanes,
                    ff: CONCAT_MUX_FF * 4 * lanes,
                    bram: fifo_bram(skew_words, cfg.rep),
                };
                LayerMapping {
                    layer_id: stage.id,
                    name: stage.name.clone(),
                    pe_count: 3 * lanes,
                    // the four taps stream out sequentially per merge port
                    serial_factor: 4,
                    occupancy_cycles: pass * 4,
                    fill_cycles: 3 * (k - 1) * (inp.w + blank.back_porch + blank.front_porch)
                        + 8,
                    resources: pool.resources().scale(3 * lanes).add(&mux),
                }
            }
            LayerKind::Relu => LayerMapping {
                layer_id: stage.id,
                name: stage.name.clone(),
                pe_count: in_lanes,
                serial_factor: 1,
                occupancy_cycles: 0,
                fill_cycles: 1,
                resources: Resources { dsp: 0, lut: RELU_LUT * in_lanes, ff: RELU_FF * in_lanes, bram: 0 },
            },
            LayerKind::GlobalAvgPool => LayerMapping {
                layer_id: stage.id,
                name: stage.name.clone(),
                pe_count: in_lanes,
                serial_factor: 1,
                occupancy_cycles: (inp.w + 4) * inp.h,
                fill_cycles: 4,
                resources: Resources { dsp: 0, lut: 60 * in_lanes, ff: 32 * in_lanes, bram: 0 },
            },
            LayerKind::Softmax => LayerMapping {
                layer_id: stage.id,
                name: stage.name.clone(),
                pe_count: 1,
                serial_factor: 1,
                occupancy_cycles: inp.c * 4,
                fill_cycles: 8,
                // exp LUT table + normalizer
                resources: Resources { dsp: 2, lut: 900, ff: 600, bram: 1 },
            },
            LayerKind::Input { .. } => LayerMapping {
                layer_id: stage.id,
                name: stage.name.clone(),
                pe_count: 0,
                serial_factor: 1,
                occupancy_cycles: 0,
                fill_cycles: 0,
                resources: Resources::default(),
            },
        };
        total = total.add(&mapping.resources);
        mappings.push(mapping);
        out_lanes.push(lanes_out);
    }

    // Eq. 12-13. Throughput: the steady-state frame period is the most
    // occupied stage (initiation interval I). Latency: streaming stages
    // (serial == 1) overlap wavefront-style and add only their fill;
    // a serialized stage must buffer its whole input fmap before pass 2,
    // so it adds its full occupancy to the critical path — this is why
    // low-PE designs are orders of magnitude slower end-to-end and why
    // depth-gating them (NeuroMorph) wins big.
    let (in_h, in_w, _) = plan.input_dims;
    let source = (in_w + blank.back_porch + blank.front_porch) * in_h;
    let fill: usize = mappings.iter().map(|m| m.fill_cycles).sum();
    let serialized: usize = mappings
        .iter()
        .filter(|m| m.serial_factor > 1)
        .map(|m| m.occupancy_cycles)
        .sum();
    let period = mappings
        .iter()
        .map(|m| m.occupancy_cycles)
        .max()
        .unwrap_or(1)
        .max(source);
    let latency = source + fill + serialized;
    let total_pes = plan
        .stages
        .iter()
        .zip(&mappings)
        .filter(|(s, _)| s.is_conv_like())
        .map(|(_, m)| m.pe_count)
        .sum();

    Ok(DesignEval {
        mappings,
        resources: total,
        total_pes,
        latency_cycles: latency,
        period_cycles: period.max(1),
        clock_mhz: device.clock_mhz,
    })
}


// ---------------------------------------------------------------------------
// Fast path for the DSE inner loop
// ---------------------------------------------------------------------------

/// Statically resolved lane provenance of a stage input: which chromosome
/// slot (if any) decides how many parallel channel streams arrive. The
/// resolution follows the plan's dataflow edges once, at `Evaluator`
/// construction, so `objectives()` never touches the graph.
#[derive(Debug, Clone, Copy)]
enum LaneSrc {
    /// no conv upstream (the source streams one lane)
    One,
    /// a standard conv: lanes = p(slot)
    Conv { slot: usize },
    /// a depthwise conv: lanes = min(p(slot), cin).max(1)
    Dw { slot: usize, cin: usize },
    /// a concat merge: lanes = max over `lane_pool[start..start+len]`
    /// (entries are guaranteed non-Max)
    Max { start: usize, len: usize },
}

fn lanes_flat(src: LaneSrc, genes: &[usize]) -> usize {
    match src {
        LaneSrc::One => 1,
        LaneSrc::Conv { slot } => genes[slot],
        LaneSrc::Dw { slot, cin } => genes[slot].min(cin).max(1),
        LaneSrc::Max { .. } => unreachable!("lane pool entries are flat"),
    }
}

fn lanes_of(src: LaneSrc, genes: &[usize], pool: &[LaneSrc]) -> usize {
    match src {
        LaneSrc::Max { start, len } => pool[start..start + len]
            .iter()
            .map(|&s| lanes_flat(s, genes))
            .max()
            .unwrap_or(1),
        flat => lanes_flat(flat, genes),
    }
}

/// Pre-digested per-stage facts, computed once per (network, device).
#[derive(Debug, Clone, Copy)]
enum StagePre {
    Conv {
        /// chromosome slot owning this stage's parallelism gene
        slot: usize,
        filters: usize,
        cin: usize,
        pass: usize,
        fill: usize,
        /// per-PE resources at Int16 / Int8 (BRAM differs with FP_rep)
        res16: Resources,
        res8: Resources,
    },
    DwConv {
        slot: usize,
        cin: usize,
        pass: usize,
        fill: usize,
        res16: Resources,
        res8: Resources,
    },
    Pool { cin: usize, pass: usize, fill: usize, res: Resources },
    Fc { out: usize, cin: usize, fm_w: usize, fm_h: usize, fill: usize },
    Fixed { occupancy: usize, fill: usize, res_per_lane: Resources, lanes_from_prev: bool, extra: Resources },
    Concat {
        n_in: usize,
        /// branch re-sync FIFO BRAM at Int8 / Int16 (summed per branch:
        /// every incoming Branch edge owns an independent FIFO)
        bram8: usize,
        bram16: usize,
        /// the merge's own lane provenance (max over inputs)
        src_max: LaneSrc,
    },
    Upsample { occupancy: usize, fill: usize, row_words: usize },
    Spp {
        cin: usize,
        pass: usize,
        fill: usize,
        pool_res: Resources,
        skew_words: usize,
    },
}

/// Lightweight evaluation result (what the MOGA fitness needs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FastEval {
    pub resources: Resources,
    pub total_pes: usize,
    pub latency_cycles: usize,
    pub period_cycles: usize,
}

/// Per-stage (segment) evaluation result — the unit of the DSE's
/// stage-level cache. A `StageFit` is a pure function of the packed
/// [`Evaluator::stage_key`] (the stage's local gene window plus its
/// boundary lane context), so identical keys across chromosomes share
/// one computation; [`Evaluator::compose`] reassembles whole-candidate
/// fitness with the same order-independent integer math as
/// [`Evaluator::objectives`], keeping fronts bit-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageFit {
    /// cycles this stage occupies per frame (pass cycles x serial)
    pub occupancy_cycles: usize,
    /// serial factor > 1: the stage buffers its fmap and adds its full
    /// occupancy to first-frame latency (Eq. 12's serialized term)
    pub serialized: bool,
    /// pipeline fill contribution
    pub fill_cycles: usize,
    pub resources: Resources,
    /// conv-like C_PE contribution to `total_pes` (0 for other stages)
    pub pe_count: usize,
    /// words/frame streamed across the stage's output boundary
    pub bandwidth_words: usize,
}

/// Per-chromosome-slot facts for gene-dependent lower bounds
/// ([`crate::dse::roofline::GeneBounds`]): everything a sound latency /
/// DSP bound needs about the conv stage owning that gene.
#[derive(Debug, Clone, Copy, Default)]
pub struct SlotFact {
    /// depthwise stage: its serial factor (and so its latency term) is
    /// exactly determined by the gene, independent of boundary lanes
    pub dw: bool,
    pub filters: usize,
    pub cin: usize,
    /// pass cycles (frame scan incl. blanking)
    pub pass: usize,
    pub dsp_per_pe16: usize,
    pub dsp_per_pe8: usize,
}

/// Reusable evaluator: hoists pass scheduling, shape inference, bound
/// checks and per-PE resource lookups out of the 10^4-10^5-call DSE loop.
/// `objectives()` performs zero heap allocation.
pub struct Evaluator {
    /// per stage: pre-digested facts + the lane provenance of its input
    stages: Vec<(StagePre, LaneSrc)>,
    /// flat pool backing `LaneSrc::Max` ranges
    lane_pool: Vec<LaneSrc>,
    /// per stage: output boundary words per frame (w*h*c) — the
    /// gene-independent bandwidth figure reported in [`StageFit`]
    out_words: Vec<usize>,
    bounds: Vec<usize>,
    source: usize,
    clock_mhz: f64,
    budget: Resources,
}

impl Evaluator {
    pub fn new(net: &Network, device: &Device) -> Result<Evaluator, DesignError> {
        let plan = passes::schedule(net)?;
        Evaluator::from_plan(&plan, device)
    }

    pub fn from_plan(plan: &StagePlan, device: &Device) -> Result<Evaluator, DesignError> {
        let blank = Blanking::default();
        let mut stages: Vec<(StagePre, LaneSrc)> = Vec::with_capacity(plan.stages.len());
        let mut out_words: Vec<usize> = Vec::with_capacity(plan.stages.len());
        let mut lane_pool: Vec<LaneSrc> = Vec::new();
        // lane provenance flowing OUT of each scheduled stage
        let mut out_src: Vec<LaneSrc> = Vec::with_capacity(plan.stages.len());
        let mut first_conv_seen = false;

        for stage in &plan.stages {
            let inp = stage.input;
            let pass = (inp.w + blank.back_porch + blank.front_porch) * inp.h;
            let in_src =
                stage.preds.first().map(|&p| out_src[p]).unwrap_or(LaneSrc::One);
            let mut self_src = in_src;
            let pre = match &stage.kind {
                LayerKind::Conv { filters, k, relu, .. } => {
                    let first = !first_conv_seen;
                    first_conv_seen = true;
                    let mk = |rep| ConvPe {
                        k: *k,
                        fm_w: inp.w,
                        fm_h: inp.h,
                        rep,
                        relu: *relu,
                        first_layer: first,
                    };
                    let pe = mk(FpRep::Int16);
                    let fill = (*k - 1) * (inp.w + blank.back_porch + blank.front_porch)
                        + pe.overhead_cycles();
                    let slot = stage.conv_slot.expect("conv slot");
                    self_src = LaneSrc::Conv { slot };
                    StagePre::Conv {
                        slot,
                        filters: *filters,
                        cin: inp.c,
                        pass,
                        fill,
                        res16: pe.resources(),
                        res8: mk(FpRep::Int8).resources(),
                    }
                }
                LayerKind::DwConv { k, relu, .. } => {
                    let first = !first_conv_seen;
                    first_conv_seen = true;
                    let mk = |rep| ConvPe {
                        k: *k,
                        fm_w: inp.w,
                        fm_h: inp.h,
                        rep,
                        relu: *relu,
                        first_layer: first,
                    };
                    let pe = mk(FpRep::Int16);
                    let fill = (*k - 1) * (inp.w + blank.back_porch + blank.front_porch)
                        + pe.overhead_cycles();
                    let slot = stage.conv_slot.expect("conv slot");
                    self_src = LaneSrc::Dw { slot, cin: inp.c };
                    StagePre::DwConv {
                        slot,
                        cin: inp.c,
                        pass,
                        fill,
                        res16: pe.resources(),
                        res8: mk(FpRep::Int8).resources(),
                    }
                }
                LayerKind::MaxPool { k, stride } | LayerKind::AvgPool { k, stride } => {
                    let kind = if matches!(stage.kind, LayerKind::MaxPool { .. }) {
                        PoolKind::Max
                    } else {
                        PoolKind::Avg
                    };
                    let pe = PoolPe { k: *k, stride: *stride, fm_w: inp.w, fm_h: inp.h, kind };
                    StagePre::Pool {
                        cin: inp.c,
                        pass,
                        fill: (*k - 1) * (inp.w + blank.back_porch + blank.front_porch) + 6,
                        res: pe.resources(),
                    }
                }
                LayerKind::Fc { out, .. } => StagePre::Fc {
                    out: *out,
                    cin: inp.c,
                    fm_w: inp.w,
                    fm_h: inp.h.max(1),
                    fill: 4,
                },
                LayerKind::ResidualAdd { .. } => StagePre::Fixed {
                    occupancy: 0,
                    fill: 1,
                    res_per_lane: Resources { dsp: 0, lut: 24, ff: 16, bram: 0 },
                    lanes_from_prev: true,
                    extra: Resources::default(),
                },
                LayerKind::Concat { .. } => {
                    // flatten input provenances into the lane pool (max of
                    // max collapses, so entries stay flat)
                    let start = lane_pool.len();
                    for &p in &stage.preds {
                        match out_src[p] {
                            LaneSrc::Max { start: s, len: l } => {
                                lane_pool.extend_from_within(s..s + l);
                            }
                            flat => lane_pool.push(flat),
                        }
                    }
                    let len = (lane_pool.len() - start).max(1);
                    if lane_pool.len() == start {
                        lane_pool.push(LaneSrc::One);
                    }
                    let src_max = LaneSrc::Max { start, len };
                    self_src = src_max;
                    StagePre::Concat {
                        n_in: stage.preds.len().max(1),
                        bram8: branch_fifo_bram(plan, stage.id, FpRep::Int8),
                        bram16: branch_fifo_bram(plan, stage.id, FpRep::Int16),
                        src_max,
                    }
                }
                LayerKind::Upsample { .. } => {
                    let out = stage.output;
                    StagePre::Upsample {
                        occupancy: (out.w + blank.back_porch + blank.front_porch) * out.h,
                        fill: inp.w + 4,
                        row_words: inp.w * inp.c,
                    }
                }
                LayerKind::SpatialPyramidPool { k } => {
                    let pool =
                        PoolPe { k: *k, stride: 1, fm_w: inp.w, fm_h: inp.h, kind: PoolKind::Max };
                    StagePre::Spp {
                        cin: inp.c,
                        pass,
                        fill: 3 * (*k - 1) * (inp.w + blank.back_porch + blank.front_porch) + 8,
                        pool_res: pool.resources(),
                        skew_words: 6 * (*k - 1) * inp.w * inp.c,
                    }
                }
                LayerKind::Relu => StagePre::Fixed {
                    occupancy: 0,
                    fill: 1,
                    res_per_lane: Resources { dsp: 0, lut: RELU_LUT, ff: RELU_FF, bram: 0 },
                    lanes_from_prev: true,
                    extra: Resources::default(),
                },
                LayerKind::GlobalAvgPool => StagePre::Fixed {
                    occupancy: (inp.w + 4) * inp.h,
                    fill: 4,
                    res_per_lane: Resources { dsp: 0, lut: 60, ff: 32, bram: 0 },
                    lanes_from_prev: true,
                    extra: Resources::default(),
                },
                LayerKind::Softmax => StagePre::Fixed {
                    occupancy: inp.c * 4,
                    fill: 8,
                    res_per_lane: Resources::default(),
                    lanes_from_prev: false,
                    extra: Resources { dsp: 2, lut: 900, ff: 600, bram: 1 },
                },
                LayerKind::Input { .. } => StagePre::Fixed {
                    occupancy: 0,
                    fill: 0,
                    res_per_lane: Resources::default(),
                    lanes_from_prev: false,
                    extra: Resources::default(),
                },
            };
            stages.push((pre, in_src));
            out_words.push(stage.output.w * stage.output.h * stage.output.c);
            out_src.push(self_src);
        }
        let (in_h, in_w, _) = plan.input_dims;
        Ok(Evaluator {
            stages,
            lane_pool,
            out_words,
            bounds: plan.conv_bounds(),
            source: (in_w + blank.back_porch + blank.front_porch) * in_h,
            clock_mhz: device.clock_mhz,
            budget: device.budget,
        })
    }

    pub fn bounds(&self) -> &[usize] {
        &self.bounds
    }

    fn check(&self, parallelism: &[usize]) -> Result<(), DesignError> {
        if parallelism.len() != self.bounds.len() {
            return Err(DesignError::ArityMismatch {
                got: parallelism.len(),
                want: self.bounds.len(),
            });
        }
        for (i, (&p, &ub)) in parallelism.iter().zip(&self.bounds).enumerate() {
            if p == 0 || p > ub {
                return Err(DesignError::OutOfBounds { layer: i, p, ub });
            }
        }
        Ok(())
    }

    /// Allocation-free evaluation; semantics identical to [`evaluate`]
    /// (cross-checked by `fast_eval_matches_full` below, chain and
    /// branchy networks alike). Conv-like stages appear in gene order in
    /// `stages`, so a running slot counter indexes `parallelism` exactly
    /// as the plan's `conv_slot` would.
    pub fn objectives(&self, parallelism: &[usize], rep: FpRep) -> Result<FastEval, DesignError> {
        self.check(parallelism)?;
        let simd = if rep == FpRep::Int8 { 2 } else { 1 };
        let mut total = Resources::default();
        let mut total_pes = 0usize;
        let mut conv_idx = 0usize;
        let mut fill_sum = 0usize;
        let mut serialized = 0usize;
        let mut period = self.source;

        for &(pre, in_src) in &self.stages {
            let in_lanes = lanes_of(in_src, parallelism, &self.lane_pool);
            match pre {
                StagePre::Conv { filters, cin, pass, fill, res16, res8, .. } => {
                    let p = parallelism[conv_idx];
                    conv_idx += 1;
                    let lanes_in = in_lanes.min(cin).max(1);
                    let pe_count = p * lanes_in;
                    let serial = filters.div_ceil(p * simd) * cin.div_ceil(lanes_in);
                    let occ = pass * serial;
                    let res = if rep == FpRep::Int8 { res8 } else { res16 };
                    total = total.add(&res.scale(pe_count));
                    total_pes += pe_count;
                    fill_sum += fill;
                    if serial > 1 {
                        serialized += occ;
                    }
                    period = period.max(occ);
                }
                StagePre::DwConv { cin, pass, fill, res16, res8, .. } => {
                    let p = parallelism[conv_idx];
                    conv_idx += 1;
                    let lanes = p.min(cin).max(1);
                    let serial = cin.div_ceil(lanes * simd);
                    let occ = pass * serial;
                    let res = if rep == FpRep::Int8 { res8 } else { res16 };
                    total = total.add(&res.scale(lanes));
                    total_pes += lanes;
                    fill_sum += fill;
                    if serial > 1 {
                        serialized += occ;
                    }
                    period = period.max(occ);
                }
                StagePre::Pool { cin, pass, fill, res } => {
                    let lanes = in_lanes.min(cin).max(1);
                    let serial = cin.div_ceil(lanes);
                    let occ = pass * serial;
                    total = total.add(&res.scale(lanes));
                    fill_sum += fill;
                    if serial > 1 {
                        serialized += occ;
                    }
                    period = period.max(occ);
                }
                StagePre::Fc { out, cin, fm_w, fm_h, fill } => {
                    let n_pe = in_lanes.min(cin).max(1);
                    let pe = FcPe { fc_out: out, n_pe, channels: cin, fm_w, fm_h };
                    let occ = pe.latency_cycles(Blanking::default());
                    total = total.add(&pe.resources());
                    fill_sum += fill;
                    if pe.parallelism() > 1 {
                        serialized += occ;
                    }
                    period = period.max(occ);
                }
                StagePre::Fixed { occupancy, fill, res_per_lane, lanes_from_prev, extra } => {
                    let lanes = if lanes_from_prev { in_lanes } else { 1 };
                    total = total.add(&res_per_lane.scale(lanes)).add(&extra);
                    fill_sum += fill;
                    period = period.max(occupancy);
                }
                StagePre::Concat { n_in, bram8, bram16, src_max } => {
                    let lanes = lanes_of(src_max, parallelism, &self.lane_pool);
                    total = total.add(&Resources {
                        dsp: 0,
                        lut: CONCAT_MUX_LUT * n_in * lanes,
                        ff: CONCAT_MUX_FF * n_in * lanes,
                        bram: if rep == FpRep::Int8 { bram8 } else { bram16 },
                    });
                    fill_sum += 2;
                }
                StagePre::Upsample { occupancy, fill, row_words } => {
                    total = total.add(&Resources {
                        dsp: 0,
                        lut: UPSAMPLE_LUT * in_lanes,
                        ff: UPSAMPLE_FF * in_lanes,
                        bram: fifo_bram(row_words, rep),
                    });
                    fill_sum += fill;
                    period = period.max(occupancy);
                }
                StagePre::Spp { cin, pass, fill, pool_res, skew_words } => {
                    let lanes = in_lanes.min(cin).max(1);
                    total = total.add(&pool_res.scale(3 * lanes)).add(&Resources {
                        dsp: 0,
                        lut: CONCAT_MUX_LUT * 4 * lanes,
                        ff: CONCAT_MUX_FF * 4 * lanes,
                        bram: fifo_bram(skew_words, rep),
                    });
                    fill_sum += fill;
                    let occ = pass * 4;
                    serialized += occ;
                    period = period.max(occ);
                }
            }
        }
        Ok(FastEval {
            resources: total,
            total_pes,
            latency_cycles: self.source + fill_sum + serialized,
            period_cycles: period.max(1),
        })
    }

    /// Per-conv-slot occupancy cycles (`pass x serial`, matching the
    /// `occupancy_cycles` of [`evaluate`]'s conv/dwconv mappings), for
    /// the bottleneck-balancing greedy. Writes into `out`; allocation-
    /// free once the buffer has grown to the conv count.
    pub fn conv_occupancies(
        &self,
        parallelism: &[usize],
        rep: FpRep,
        out: &mut Vec<usize>,
    ) -> Result<(), DesignError> {
        self.check(parallelism)?;
        out.clear();
        let simd = if rep == FpRep::Int8 { 2 } else { 1 };
        let mut conv_idx = 0usize;
        for &(pre, in_src) in &self.stages {
            match pre {
                StagePre::Conv { filters, cin, pass, .. } => {
                    let p = parallelism[conv_idx];
                    conv_idx += 1;
                    let in_lanes = lanes_of(in_src, parallelism, &self.lane_pool);
                    let lanes_in = in_lanes.min(cin).max(1);
                    let serial = filters.div_ceil(p * simd) * cin.div_ceil(lanes_in);
                    out.push(pass * serial);
                }
                StagePre::DwConv { cin, pass, .. } => {
                    let p = parallelism[conv_idx];
                    conv_idx += 1;
                    let lanes = p.min(cin).max(1);
                    let serial = cin.div_ceil(lanes * simd);
                    out.push(pass * serial);
                }
                _ => {}
            }
        }
        Ok(())
    }

    pub fn latency_ms(&self, eval: &FastEval) -> f64 {
        eval.latency_cycles as f64 / (self.clock_mhz * 1e3)
    }

    /// Deployed clock (MHz) — the power/energy model's frequency input.
    pub fn clock_mhz(&self) -> f64 {
        self.clock_mhz
    }

    pub fn fits(&self, eval: &FastEval) -> bool {
        eval.resources.fits(&self.budget)
    }

    // -- per-stage (segment) kernel ------------------------------------

    /// Number of StagePlan stages (segments) this evaluator models.
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Normalized `(own gene, boundary lanes)` inputs that fully
    /// determine stage `idx`'s fit for a chromosome — its local gene
    /// window plus boundary context. Normalization (`min(cin).max(1)`
    /// clamps, constant-lane collapse) happens here so distinct
    /// chromosomes that resolve to the same effective inputs share one
    /// cache entry.
    fn stage_inputs(&self, idx: usize, parallelism: &[usize]) -> (usize, usize) {
        let (pre, in_src) = self.stages[idx];
        let in_lanes = || lanes_of(in_src, parallelism, &self.lane_pool);
        match pre {
            StagePre::Conv { slot, cin, .. } => {
                (parallelism[slot], in_lanes().min(cin).max(1))
            }
            // depthwise: the fit depends on the own gene alone
            StagePre::DwConv { slot, .. } => (parallelism[slot], 0),
            StagePre::Pool { cin, .. }
            | StagePre::Spp { cin, .. }
            | StagePre::Fc { cin, .. } => (0, in_lanes().min(cin).max(1)),
            StagePre::Fixed { lanes_from_prev, .. } => {
                (0, if lanes_from_prev { in_lanes() } else { 1 })
            }
            StagePre::Concat { src_max, .. } => {
                (0, lanes_of(src_max, parallelism, &self.lane_pool))
            }
            StagePre::Upsample { .. } => (0, in_lanes()),
        }
    }

    /// Packed stage-cache key: `(stage, own gene, boundary lanes)` in
    /// one u64 (`rep` is fixed per search, so it stays out of the key).
    pub fn stage_key(&self, idx: usize, parallelism: &[usize]) -> u64 {
        let (p, lanes) = self.stage_inputs(idx, parallelism);
        debug_assert!(idx < (1 << 24) && p < (1 << 20) && lanes < (1 << 20));
        ((idx as u64) << 40) | ((p as u64) << 20) | lanes as u64
    }

    /// The per-stage kernel: fit of stage `idx` from its normalized
    /// inputs (see [`Evaluator::stage_inputs`]). A pure function of
    /// `(idx, p, lanes, rep)`; arm-for-arm identical math to
    /// [`Evaluator::objectives`].
    pub fn stage_fit(&self, idx: usize, p: usize, lanes: usize, rep: FpRep) -> StageFit {
        let simd = if rep == FpRep::Int8 { 2 } else { 1 };
        let bandwidth_words = self.out_words[idx];
        let (pre, _) = self.stages[idx];
        match pre {
            StagePre::Conv { filters, cin, pass, fill, res16, res8, .. } => {
                let pe_count = p * lanes;
                let serial = filters.div_ceil(p * simd) * cin.div_ceil(lanes);
                let res = if rep == FpRep::Int8 { res8 } else { res16 };
                StageFit {
                    occupancy_cycles: pass * serial,
                    serialized: serial > 1,
                    fill_cycles: fill,
                    resources: res.scale(pe_count),
                    pe_count,
                    bandwidth_words,
                }
            }
            StagePre::DwConv { cin, pass, fill, res16, res8, .. } => {
                let l = p.min(cin).max(1);
                let serial = cin.div_ceil(l * simd);
                let res = if rep == FpRep::Int8 { res8 } else { res16 };
                StageFit {
                    occupancy_cycles: pass * serial,
                    serialized: serial > 1,
                    fill_cycles: fill,
                    resources: res.scale(l),
                    pe_count: l,
                    bandwidth_words,
                }
            }
            StagePre::Pool { cin, pass, fill, res } => {
                let serial = cin.div_ceil(lanes);
                StageFit {
                    occupancy_cycles: pass * serial,
                    serialized: serial > 1,
                    fill_cycles: fill,
                    resources: res.scale(lanes),
                    pe_count: 0,
                    bandwidth_words,
                }
            }
            StagePre::Fc { out, cin, fm_w, fm_h, fill } => {
                let pe = FcPe { fc_out: out, n_pe: lanes, channels: cin, fm_w, fm_h };
                StageFit {
                    occupancy_cycles: pe.latency_cycles(Blanking::default()),
                    serialized: pe.parallelism() > 1,
                    fill_cycles: fill,
                    resources: pe.resources(),
                    pe_count: 0,
                    bandwidth_words,
                }
            }
            StagePre::Fixed { occupancy, fill, res_per_lane, extra, .. } => StageFit {
                occupancy_cycles: occupancy,
                serialized: false,
                fill_cycles: fill,
                resources: res_per_lane.scale(lanes).add(&extra),
                pe_count: 0,
                bandwidth_words,
            },
            StagePre::Concat { n_in, bram8, bram16, .. } => StageFit {
                occupancy_cycles: 0,
                serialized: false,
                fill_cycles: 2,
                resources: Resources {
                    dsp: 0,
                    lut: CONCAT_MUX_LUT * n_in * lanes,
                    ff: CONCAT_MUX_FF * n_in * lanes,
                    bram: if rep == FpRep::Int8 { bram8 } else { bram16 },
                },
                pe_count: 0,
                bandwidth_words,
            },
            StagePre::Upsample { occupancy, fill, row_words } => StageFit {
                occupancy_cycles: occupancy,
                serialized: false,
                fill_cycles: fill,
                resources: Resources {
                    dsp: 0,
                    lut: UPSAMPLE_LUT * lanes,
                    ff: UPSAMPLE_FF * lanes,
                    bram: fifo_bram(row_words, rep),
                },
                pe_count: 0,
                bandwidth_words,
            },
            StagePre::Spp { pass, fill, pool_res, skew_words, .. } => StageFit {
                occupancy_cycles: pass * 4,
                // the four SPP taps always stream out sequentially
                serialized: true,
                fill_cycles: fill,
                resources: pool_res.scale(3 * lanes).add(&Resources {
                    dsp: 0,
                    lut: CONCAT_MUX_LUT * 4 * lanes,
                    ff: CONCAT_MUX_FF * 4 * lanes,
                    bram: fifo_bram(skew_words, rep),
                }),
                pe_count: 0,
                bandwidth_words,
            },
        }
    }

    /// [`Evaluator::stage_fit`] from a packed [`Evaluator::stage_key`]
    /// (what the DSE workers compute cache fills from).
    pub fn stage_fit_packed(&self, key: u64, rep: FpRep) -> StageFit {
        let idx = (key >> 40) as usize;
        let p = ((key >> 20) & 0xF_FFFF) as usize;
        let lanes = (key & 0xF_FFFF) as usize;
        self.stage_fit(idx, p, lanes, rep)
    }

    /// Assemble whole-candidate fitness from per-stage fits (in stage
    /// order). Pipeline-max for the frame period, sums for resources /
    /// fill / serialized latency — all order-independent integer math,
    /// so the result is bitwise-equal to [`Evaluator::objectives`] on
    /// the same chromosome (test-enforced).
    pub fn compose<I: IntoIterator<Item = StageFit>>(&self, fits: I) -> FastEval {
        let mut total = Resources::default();
        let mut total_pes = 0usize;
        let mut fill_sum = 0usize;
        let mut serialized = 0usize;
        let mut period = self.source;
        for f in fits {
            total = total.add(&f.resources);
            total_pes += f.pe_count;
            fill_sum += f.fill_cycles;
            if f.serialized {
                serialized += f.occupancy_cycles;
            }
            period = period.max(f.occupancy_cycles);
        }
        FastEval {
            resources: total,
            total_pes,
            latency_cycles: self.source + fill_sum + serialized,
            period_cycles: period.max(1),
        }
    }

    // -- roofline lower-bound facts ------------------------------------

    /// Gene-independent latency floor: source scan + every stage's fill
    /// + the always-serialized SPP occupancies. Every chromosome's
    /// `latency_cycles` is >= this.
    pub fn latency_floor_cycles(&self) -> usize {
        let mut fill_sum = 0usize;
        let mut fixed_serialized = 0usize;
        for &(pre, _) in &self.stages {
            match pre {
                StagePre::Conv { fill, .. }
                | StagePre::DwConv { fill, .. }
                | StagePre::Pool { fill, .. }
                | StagePre::Fc { fill, .. }
                | StagePre::Fixed { fill, .. }
                | StagePre::Upsample { fill, .. } => fill_sum += fill,
                StagePre::Concat { .. } => fill_sum += 2,
                StagePre::Spp { pass, fill, .. } => {
                    fill_sum += fill;
                    fixed_serialized += pass * 4;
                }
            }
        }
        self.source + fill_sum + fixed_serialized
    }

    /// Per-chromosome-slot conv facts, in gene order (the inputs of
    /// [`crate::dse::roofline::GeneBounds`]).
    pub fn slot_facts(&self) -> Vec<SlotFact> {
        let mut out = vec![SlotFact::default(); self.bounds.len()];
        for &(pre, _) in &self.stages {
            match pre {
                StagePre::Conv { slot, filters, cin, pass, res16, res8, .. } => {
                    out[slot] = SlotFact {
                        dw: false,
                        filters,
                        cin,
                        pass,
                        dsp_per_pe16: res16.dsp,
                        dsp_per_pe8: res8.dsp,
                    };
                }
                StagePre::DwConv { slot, cin, pass, res16, res8, .. } => {
                    out[slot] = SlotFact {
                        dw: true,
                        filters: cin,
                        cin,
                        pass,
                        dsp_per_pe16: res16.dsp,
                        dsp_per_pe8: res8.dsp,
                    };
                }
                _ => {}
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::pe::ZYNQ_7100;

    #[test]
    fn full_parallel_mnist_is_fast_and_big() {
        let net = zoo::mnist();
        let full = evaluate(&net, &DesignConfig::full(&net, FpRep::Int16), &ZYNQ_7100).unwrap();
        let tiny = evaluate(&net, &DesignConfig::uniform(&net, 1, FpRep::Int16), &ZYNQ_7100).unwrap();
        assert!(full.latency_ms() < 0.05, "full {}", full.latency_ms());
        assert!(tiny.latency_ms() > 0.1, "tiny {}", tiny.latency_ms());
        // paper reports orders-of-magnitude trade-off span; with local
        // fmap buffering our span is >25x (see EXPERIMENTS.md discussion)
        let span = tiny.latency_ms() / full.latency_ms();
        assert!(span > 25.0, "span {span}");
        assert!(full.resources.dsp > 20 * tiny.resources.dsp);
    }

    #[test]
    fn balanced_allocation_fits_and_beats_uniform() {
        let net = zoo::mobilenet_v2();
        let bal = DesignConfig::balanced(&net, FpRep::Int8, &ZYNQ_7100);
        let eval = evaluate(&net, &bal, &ZYNQ_7100).unwrap();
        assert!(eval.fits(&ZYNQ_7100), "balanced must fit the device");
        let uni =
            evaluate(&net, &DesignConfig::uniform(&net, 1, FpRep::Int8), &ZYNQ_7100).unwrap();
        assert!(
            eval.period_cycles < uni.period_cycles,
            "balanced {} !< uniform {}",
            eval.period_cycles,
            uni.period_cycles
        );
    }

    /// The pre-optimization `balanced` greedy, verbatim: full `evaluate`
    /// per probe, config cloned per trial. Kept as the reference spec
    /// for the Evaluator fast path.
    fn balanced_reference(net: &Network, rep: FpRep, device: &Device) -> DesignConfig {
        let bounds = net.conv_filter_bounds();
        let conv_ids: Vec<usize> = net.conv_layer_ids();
        let mut cfg = DesignConfig { parallelism: vec![1; bounds.len()], rep };
        loop {
            let Ok(eval) = evaluate(net, &cfg, device) else { break };
            let mut order: Vec<usize> = (0..conv_ids.len()).collect();
            order.sort_by_key(|&slot| {
                std::cmp::Reverse(eval.mappings[conv_ids[slot]].occupancy_cycles)
            });
            let mut improved = false;
            for slot in order {
                if cfg.parallelism[slot] >= bounds[slot] {
                    continue;
                }
                for next in [
                    (cfg.parallelism[slot] * 2).min(bounds[slot]),
                    (cfg.parallelism[slot] + 1).min(bounds[slot]),
                ] {
                    if next == cfg.parallelism[slot] {
                        continue;
                    }
                    let mut trial = cfg.clone();
                    trial.parallelism[slot] = next;
                    if let Ok(e) = evaluate(net, &trial, device) {
                        if e.fits(device) {
                            cfg = trial;
                            improved = true;
                            break;
                        }
                    }
                }
                if improved {
                    break;
                }
            }
            if !improved {
                break;
            }
        }
        cfg
    }

    #[test]
    fn balanced_matches_full_evaluate_greedy() {
        for (net, rep) in [
            (zoo::mnist(), FpRep::Int16),
            (zoo::cifar10(), FpRep::Int16),
            (zoo::mobilenet_v2(), FpRep::Int8),
        ] {
            let fast = DesignConfig::balanced(&net, rep, &ZYNQ_7100);
            let slow = balanced_reference(&net, rep, &ZYNQ_7100);
            assert_eq!(fast, slow, "{} diverged from reference greedy", net.name);
        }
    }

    #[test]
    fn conv_occupancies_match_full_mappings() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(33);
        for net in [zoo::mnist(), zoo::cifar10(), zoo::mobilenet_v2(), zoo::unet_tiny()] {
            let ev = Evaluator::new(&net, &ZYNQ_7100).unwrap();
            let bounds = net.conv_filter_bounds();
            let conv_ids = net.conv_layer_ids();
            let mut occ = Vec::new();
            for _ in 0..10 {
                let parallelism: Vec<usize> =
                    bounds.iter().map(|&ub| rng.range(1, ub as i64) as usize).collect();
                let rep = if rng.chance(0.5) { FpRep::Int8 } else { FpRep::Int16 };
                let cfg = DesignConfig { parallelism: parallelism.clone(), rep };
                let full = evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
                ev.conv_occupancies(&parallelism, rep, &mut occ).unwrap();
                let want: Vec<usize> = conv_ids
                    .iter()
                    .map(|&id| full.mappings[id].occupancy_cycles)
                    .collect();
                assert_eq!(occ, want, "{}", net.name);
            }
        }
    }

    #[test]
    fn conv_occupancies_check_bounds() {
        let net = zoo::mnist();
        let ev = Evaluator::new(&net, &ZYNQ_7100).unwrap();
        let mut occ = Vec::new();
        assert!(ev.conv_occupancies(&[1, 1], FpRep::Int16, &mut occ).is_err());
        assert!(ev.conv_occupancies(&[0, 1, 1], FpRep::Int16, &mut occ).is_err());
        assert!(ev.conv_occupancies(&[99, 1, 1], FpRep::Int16, &mut occ).is_err());
    }

    #[test]
    fn eq14_pe_counts() {
        let net = zoo::mnist();
        let cfg = DesignConfig { parallelism: vec![2, 4, 8], rep: FpRep::Int16 };
        let eval = evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
        let conv_pes: Vec<usize> = eval
            .mappings
            .iter()
            .filter(|m| m.name.starts_with("conv"))
            .map(|m| m.pe_count)
            .collect();
        // L(1)=2*1 (1 input channel), L(2)=4*2, L(3)=8*4
        assert_eq!(conv_pes, vec![2, 8, 32]);
        assert_eq!(eval.total_pes, 42);
    }

    #[test]
    fn serialization_factors() {
        let net = zoo::mnist();
        let cfg = DesignConfig { parallelism: vec![1, 1, 1], rep: FpRep::Int16 };
        let eval = evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
        let serials: Vec<usize> = eval
            .mappings
            .iter()
            .filter(|m| m.name.starts_with("conv"))
            .map(|m| m.serial_factor)
            .collect();
        // conv1: 8 filters x 1 ch, conv2: 16 x 8, conv3: 32 x 16
        assert_eq!(serials, vec![8, 128, 512]);
    }

    #[test]
    fn arity_checked() {
        let net = zoo::mnist();
        let bad = DesignConfig { parallelism: vec![1, 1], rep: FpRep::Int8 };
        assert!(matches!(
            evaluate(&net, &bad, &ZYNQ_7100),
            Err(DesignError::ArityMismatch { .. })
        ));
    }

    #[test]
    fn bounds_checked() {
        let net = zoo::mnist();
        let bad = DesignConfig { parallelism: vec![9, 1, 1], rep: FpRep::Int8 };
        assert!(matches!(
            evaluate(&net, &bad, &ZYNQ_7100),
            Err(DesignError::OutOfBounds { .. })
        ));
        let zero = DesignConfig { parallelism: vec![0, 1, 1], rep: FpRep::Int8 };
        assert!(evaluate(&net, &zero, &ZYNQ_7100).is_err());
    }

    #[test]
    fn int8_uses_less_bram_on_wide_frames() {
        let net = zoo::yolov5l();
        let cfg8 = DesignConfig::uniform(&net, 2, FpRep::Int8);
        let cfg16 = DesignConfig::uniform(&net, 2, FpRep::Int16);
        let r8 = evaluate(&net, &cfg8, &ZYNQ_7100).unwrap().resources.bram;
        let r16 = evaluate(&net, &cfg16, &ZYNQ_7100).unwrap().resources.bram;
        assert!(r8 < r16, "{r8} vs {r16}");
    }

    #[test]
    fn monotone_latency_in_parallelism() {
        let net = zoo::cifar10();
        let mut prev = f64::INFINITY;
        for p in [1, 2, 4, 8, 16] {
            let eval =
                evaluate(&net, &DesignConfig::uniform(&net, p, FpRep::Int16), &ZYNQ_7100).unwrap();
            assert!(eval.latency_ms() <= prev + 1e-9, "p={p}");
            prev = eval.latency_ms();
        }
    }

    #[test]
    fn fps_consistent_with_period() {
        let net = zoo::mnist();
        let eval = evaluate(&net, &DesignConfig::full(&net, FpRep::Int8), &ZYNQ_7100).unwrap();
        let fps = eval.fps();
        assert!((fps - 250e6 / eval.period_cycles as f64).abs() < 1e-6);
    }

    #[test]
    fn residual_nets_evaluate() {
        let net = zoo::resnet50();
        let cfg = DesignConfig::uniform(&net, 4, FpRep::Int8);
        let eval = evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
        assert!(eval.resources.dsp > 0);
        assert!(eval.latency_ms() > 0.0);
    }

    #[test]
    fn branchy_nets_pay_merge_costs() {
        // the faithful yolov5l carries Concat/Upsample/SPPF stages whose
        // branch FIFOs and mux logic must land in the resource model
        let net = zoo::yolov5l();
        let plan = passes::schedule(&net).unwrap();
        let cfg = DesignConfig::uniform(&net, 2, FpRep::Int8);
        let eval = evaluate_plan(&plan, &cfg, &ZYNQ_7100).unwrap();
        let concat_stage = plan
            .stages
            .iter()
            .find(|s| matches!(s.kind, LayerKind::Concat { .. }))
            .expect("yolov5l has concats");
        let m = &eval.mappings[concat_stage.id];
        assert!(m.resources.bram > 0, "branch FIFO BRAM missing");
        assert!(m.resources.lut > 0, "concat mux LUTs missing");
        let spp = plan
            .stages
            .iter()
            .find(|s| matches!(s.kind, LayerKind::SpatialPyramidPool { .. }))
            .expect("yolov5l has an SPPF");
        assert!(eval.mappings[spp.id].serial_factor > 1);
    }

    #[test]
    fn fast_eval_matches_full() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(21);
        for net in [
            zoo::mnist(),
            zoo::svhn(),
            zoo::cifar10(),
            zoo::mobilenet_v2(),
            zoo::unet_tiny(),
            zoo::yolov5l(),
        ] {
            let ev = Evaluator::new(&net, &ZYNQ_7100).unwrap();
            let bounds = net.conv_filter_bounds();
            let iters = if bounds.len() > 60 { 4 } else { 25 };
            for _ in 0..iters {
                let parallelism: Vec<usize> =
                    bounds.iter().map(|&ub| rng.range(1, ub as i64) as usize).collect();
                let rep = if rng.chance(0.5) { FpRep::Int8 } else { FpRep::Int16 };
                let cfg = DesignConfig { parallelism: parallelism.clone(), rep };
                let full = evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
                let fast = ev.objectives(&parallelism, rep).unwrap();
                assert_eq!(fast.resources, full.resources, "{} {:?}", net.name, cfg);
                assert_eq!(fast.total_pes, full.total_pes);
                assert_eq!(fast.latency_cycles, full.latency_cycles);
                assert_eq!(fast.period_cycles, full.period_cycles);
            }
        }
    }

    #[test]
    fn fast_eval_checks_bounds() {
        let net = zoo::mnist();
        let ev = Evaluator::new(&net, &ZYNQ_7100).unwrap();
        assert!(ev.objectives(&[1, 1], FpRep::Int8).is_err());
        assert!(ev.objectives(&[0, 1, 1], FpRep::Int8).is_err());
        assert!(ev.objectives(&[99, 1, 1], FpRep::Int8).is_err());
    }

    #[test]
    fn stage_composition_matches_objectives() {
        // the segment kernel + compose pass must be bitwise-identical to
        // the monolithic walk: sums and maxes over the same integers in
        // the same stage order, so FastEval equality is exact
        use crate::util::rng::Rng;
        let mut rng = Rng::new(33);
        for net in [
            zoo::mnist(),
            zoo::svhn(),
            zoo::cifar10(),
            zoo::mobilenet_v2(),
            zoo::unet_tiny(),
            zoo::yolov5l(),
        ] {
            let ev = Evaluator::new(&net, &ZYNQ_7100).unwrap();
            let bounds = net.conv_filter_bounds();
            let iters = if bounds.len() > 60 { 4 } else { 25 };
            for _ in 0..iters {
                let parallelism: Vec<usize> =
                    bounds.iter().map(|&ub| rng.range(1, ub as i64) as usize).collect();
                let rep = if rng.chance(0.5) { FpRep::Int8 } else { FpRep::Int16 };
                let mono = ev.objectives(&parallelism, rep).unwrap();
                let composed = ev.compose(
                    (0..ev.n_stages())
                        .map(|s| ev.stage_fit_packed(ev.stage_key(s, &parallelism), rep)),
                );
                assert_eq!(composed, mono, "{} {:?} {:?}", net.name, parallelism, rep);
                // and the floor really floors
                assert!(ev.latency_floor_cycles() <= mono.latency_cycles);
            }
        }
    }
}
