//! PJRT runtime — loads and executes the AOT morph-path artifacts.
//!
//! The deployment contract (DESIGN.md §3): `make artifacts` is the last
//! time Python runs. This module loads each morph path's HLO **text**
//! (the interchange format xla_extension 0.5.1 accepts — serialized
//! jax>=0.5 protos carry 64-bit ids it rejects), compiles one PJRT
//! executable per (path, batch), and serves `execute()` calls from the
//! coordinator hot path.
//!
//! All executables come from ONE artifact set — the software analogue of
//! NeuroMorph's single multi-path bitstream; "clock gating" a path is
//! simply dispatching to a cheaper executable.

pub mod manifest;

pub use manifest::{Manifest, ManifestError, ModelManifest};

use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug)]
pub enum RuntimeError {
    Manifest(ManifestError),
    Xla(String),
    NoArtifact { path: String, batch: usize },
    BadInput { got: usize, batch: usize, frame: usize },
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Manifest(e) => write!(f, "manifest: {e}"),
            RuntimeError::Xla(msg) => write!(f, "xla: {msg}"),
            RuntimeError::NoArtifact { path, batch } => {
                write!(f, "no artifact for path '{path}' at batch {batch}")
            }
            RuntimeError::BadInput { got, batch, frame } => {
                write!(f, "input length {got} != batch {batch} x frame {frame}")
            }
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<ManifestError> for RuntimeError {
    fn from(e: ManifestError) -> Self {
        RuntimeError::Manifest(e)
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// A compiled morph-path executable.
struct PathExe {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
}

/// The per-model PJRT engine: one executable per (morph path, batch).
pub struct Engine {
    client: xla::PjRtClient,
    model: ModelManifest,
    exes: BTreeMap<(String, usize), PathExe>,
}

impl Engine {
    /// Load every (path, batch) artifact of `model_name` from `dir`.
    pub fn load(dir: &Path, model_name: &str) -> Result<Engine, RuntimeError> {
        let manifest = Manifest::load(dir)?;
        let model = manifest
            .model(model_name)
            .ok_or_else(|| {
                RuntimeError::Manifest(ManifestError::Schema(format!(
                    "model '{model_name}' not in manifest"
                )))
            })?
            .clone();
        let client = xla::PjRtClient::cpu()?;
        let mut exes = BTreeMap::new();
        for pa in &model.paths {
            for (&batch, file) in &pa.files {
                let proto =
                    xla::HloModuleProto::from_text_file(manifest.file_path(file).to_str().unwrap())?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp)?;
                exes.insert((pa.path.name.clone(), batch), PathExe { exe, batch });
            }
        }
        Ok(Engine { client, model, exes })
    }

    pub fn model(&self) -> &ModelManifest {
        &self.model
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Frame element count (H*W*C).
    pub fn frame_len(&self) -> usize {
        let (h, w, c) = self.model.input_shape;
        h * w * c
    }

    /// Batch sizes available for a path.
    pub fn batches_for(&self, path: &str) -> Vec<usize> {
        self.exes
            .keys()
            .filter(|(p, _)| p == path)
            .map(|(_, b)| *b)
            .collect()
    }

    /// Execute one morph path on a flat NHWC input of `batch` frames;
    /// returns flattened logits `[batch * num_classes]`.
    pub fn execute(
        &self,
        path: &str,
        batch: usize,
        input: &[f32],
    ) -> Result<Vec<f32>, RuntimeError> {
        let frame = self.frame_len();
        if input.len() != batch * frame {
            return Err(RuntimeError::BadInput {
                got: input.len(),
                batch,
                frame,
            });
        }
        let pe = self
            .exes
            .get(&(path.to_string(), batch))
            .ok_or_else(|| RuntimeError::NoArtifact { path: path.to_string(), batch })?;
        let (h, w, c) = self.model.input_shape;
        let x = xla::Literal::vec1(input)
            .reshape(&[pe.batch as i64, h as i64, w as i64, c as i64])?;
        let result = pe.exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True -> 1-tuple of logits
        let logits = result.to_tuple1()?;
        Ok(logits.to_vec::<f32>()?)
    }

    /// Argmax class ids for a batch of logits.
    pub fn argmax(&self, logits: &[f32]) -> Vec<usize> {
        logits
            .chunks(self.model.num_classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Run the manifest's probe batch through every path and compare with
    /// the golden logits recorded at AOT time. Returns max |err| per path.
    pub fn verify_probe(&self) -> Result<BTreeMap<String, f32>, RuntimeError> {
        let probe = &self.model.probe;
        let batch = probe.shape[0];
        let frame = self.frame_len();
        let mut out = BTreeMap::new();
        for pa in &self.model.paths {
            let name = &pa.path.name;
            // probe recorded at the largest batch; use matching exe if
            // present, else slice the first frame for a batch-1 check
            let (use_batch, x): (usize, Vec<f32>) =
                if self.exes.contains_key(&(name.clone(), batch)) {
                    (batch, probe.x.clone())
                } else {
                    (1, probe.x[..frame].to_vec())
                };
            let got = self.execute(name, use_batch, &x)?;
            let want = &self.model.probe.logits[name];
            let err = got
                .iter()
                .zip(want.iter())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            out.insert(name.clone(), err);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    // Engine tests requiring built artifacts live in
    // rust/tests/integration_runtime.rs (they need `make artifacts` and a
    // PJRT client, which unit tests avoid).
    use super::*;

    #[test]
    fn error_display() {
        let e = RuntimeError::NoArtifact { path: "d1".into(), batch: 4 };
        assert!(e.to_string().contains("d1"));
        let e = RuntimeError::BadInput { got: 3, batch: 1, frame: 4 };
        assert!(e.to_string().contains("3"));
    }
}
