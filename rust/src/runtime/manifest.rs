//! AOT manifest loader.
//!
//! `python/compile/aot.py` records everything the runtime needs in
//! `artifacts/manifest.json`: per-model input geometry, the morph-path
//! set with DistillCycle accuracies and cost counts, the HLO artifact
//! file per (path, batch), and a probe batch with golden logits for
//! end-to-end verification (no Python at runtime).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::morph::MorphPath;
use crate::util::json::Json;

#[derive(Debug)]
pub enum ManifestError {
    Io(std::io::Error),
    Json(crate::util::json::JsonError),
    Schema(String),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io(e) => write!(f, "io: {e}"),
            ManifestError::Json(e) => write!(f, "json: {e}"),
            ManifestError::Schema(msg) => write!(f, "manifest schema: {msg}"),
        }
    }
}

impl std::error::Error for ManifestError {}

impl From<std::io::Error> for ManifestError {
    fn from(e: std::io::Error) -> Self {
        ManifestError::Io(e)
    }
}

impl From<crate::util::json::JsonError> for ManifestError {
    fn from(e: crate::util::json::JsonError) -> Self {
        ManifestError::Json(e)
    }
}

fn schema(msg: impl Into<String>) -> ManifestError {
    ManifestError::Schema(msg.into())
}

/// One morph path's artifact set.
#[derive(Debug, Clone)]
pub struct PathArtifacts {
    pub path: MorphPath,
    /// batch size -> HLO text file name
    pub files: BTreeMap<usize, String>,
}

/// Probe batch with golden logits recorded at AOT time.
#[derive(Debug, Clone)]
pub struct Probe {
    pub shape: Vec<usize>,
    pub x: Vec<f32>,
    /// path name -> flattened logits
    pub logits: BTreeMap<String, Vec<f32>>,
}

/// One model's manifest entry.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub input_shape: (usize, usize, usize),
    pub num_classes: usize,
    pub filters: Vec<usize>,
    pub batches: Vec<usize>,
    pub paths: Vec<PathArtifacts>,
    /// intN full-path artifacts: bits -> file
    pub quant_full: BTreeMap<u32, String>,
    pub probe: Probe,
}

impl ModelManifest {
    pub fn morph_paths(&self) -> Vec<MorphPath> {
        self.paths.iter().map(|p| p.path.clone()).collect()
    }

    pub fn artifact_for(&self, path_name: &str, batch: usize) -> Option<&str> {
        self.paths
            .iter()
            .find(|p| p.path.name == path_name)
            .and_then(|p| p.files.get(&batch))
            .map(String::as_str)
    }
}

/// The full artifacts manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, ManifestError> {
        let root = Json::parse(text)?;
        if root.get("version").and_then(Json::as_u64) != Some(1) {
            return Err(schema("unsupported manifest version"));
        }
        let mut models = BTreeMap::new();
        let model_objs = root
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| schema("missing 'models'"))?;
        for (name, m) in model_objs {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    pub fn model(&self, name: &str) -> Option<&ModelManifest> {
        self.models.get(name)
    }

    /// Absolute path of an artifact file.
    pub fn file_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

fn parse_model(name: &str, m: &Json) -> Result<ModelManifest, ManifestError> {
    let ctx = |f: &str| format!("model {name}: missing/invalid '{f}'");
    let input = m
        .get("input_shape")
        .and_then(Json::as_usize_vec)
        .ok_or_else(|| schema(ctx("input_shape")))?;
    if input.len() != 3 {
        return Err(schema(ctx("input_shape (want [h,w,c])")));
    }
    let num_classes = m
        .get("num_classes")
        .and_then(Json::as_u64)
        .ok_or_else(|| schema(ctx("num_classes")))? as usize;
    let filters = m
        .get("filters")
        .and_then(Json::as_usize_vec)
        .ok_or_else(|| schema(ctx("filters")))?;
    let batches = m
        .get("batches")
        .and_then(Json::as_usize_vec)
        .ok_or_else(|| schema(ctx("batches")))?;

    let mut paths = Vec::new();
    for p in m.get("paths").and_then(Json::as_arr).ok_or_else(|| schema(ctx("paths")))? {
        let pname = p
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| schema(ctx("paths[].name")))?;
        let mut files = BTreeMap::new();
        let arts = p
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| schema(ctx("paths[].artifacts")))?;
        for (b, f) in arts {
            let batch: usize =
                b.parse().map_err(|_| schema(ctx("paths[].artifacts key")))?;
            files.insert(
                batch,
                f.as_str().ok_or_else(|| schema(ctx("artifact file")))?.to_string(),
            );
        }
        // accuracy is load-bearing (governor floor, DSE objective): a
        // missing or out-of-range value is a schema error, never a silent
        // 0.0. Untrained paths must say so explicitly with `null`.
        let accuracy = match p.get("accuracy") {
            None => {
                return Err(schema(format!(
                    "model {name}: path '{pname}': missing 'accuracy' \
                     (use null for an untrained path)"
                )))
            }
            Some(Json::Null) => 0.0,
            Some(v) => {
                let a = v.as_f64().ok_or_else(|| {
                    schema(format!("model {name}: path '{pname}': non-numeric 'accuracy'"))
                })?;
                if !(0.0..=1.0).contains(&a) {
                    return Err(schema(format!(
                        "model {name}: path '{pname}': accuracy {a} outside 0.0..=1.0"
                    )));
                }
                a
            }
        };
        paths.push(PathArtifacts {
            path: MorphPath {
                name: pname.to_string(),
                depth: p.get("depth").and_then(Json::as_u64).unwrap_or(0) as usize,
                width_pct: p.get("width_pct").and_then(Json::as_u64).unwrap_or(100) as usize,
                accuracy,
                params: p.get("params").and_then(Json::as_u64).unwrap_or(0) as usize,
                macs: p.get("macs").and_then(Json::as_u64).unwrap_or(0) as usize,
            },
            files,
        });
    }
    if paths.is_empty() {
        return Err(schema(ctx("paths (empty)")));
    }

    let mut quant_full = BTreeMap::new();
    if let Some(q) = m.get("quant_full").and_then(Json::as_obj) {
        for (bits, f) in q {
            let b: u32 = bits.parse().map_err(|_| schema(ctx("quant_full key")))?;
            quant_full.insert(
                b,
                f.as_str().ok_or_else(|| schema(ctx("quant_full file")))?.to_string(),
            );
        }
    }

    let probe_j = m.get("probe").ok_or_else(|| schema(ctx("probe")))?;
    let shape = probe_j
        .get("shape")
        .and_then(Json::as_usize_vec)
        .ok_or_else(|| schema(ctx("probe.shape")))?;
    let x: Vec<f32> = probe_j
        .get("x")
        .and_then(Json::as_f64_vec)
        .ok_or_else(|| schema(ctx("probe.x")))?
        .into_iter()
        .map(|v| v as f32)
        .collect();
    let mut logits = BTreeMap::new();
    for (pname, arr) in probe_j
        .get("logits")
        .and_then(Json::as_obj)
        .ok_or_else(|| schema(ctx("probe.logits")))?
    {
        logits.insert(
            pname.clone(),
            arr.as_f64_vec()
                .ok_or_else(|| schema(ctx("probe.logits values")))?
                .into_iter()
                .map(|v| v as f32)
                .collect(),
        );
    }
    let expect: usize = shape.iter().product();
    if x.len() != expect {
        return Err(schema(format!(
            "model {name}: probe.x has {} values, shape implies {expect}",
            x.len()
        )));
    }

    Ok(ModelManifest {
        name: name.to_string(),
        input_shape: (input[0], input[1], input[2]),
        num_classes,
        filters,
        batches,
        paths,
        quant_full,
        probe: Probe { shape, x, logits },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {
        "mnist": {
          "input_shape": [2, 2, 1],
          "num_classes": 2,
          "filters": [4],
          "batches": [1],
          "paths": [
            {"name": "d1_w100", "depth": 1, "width_pct": 100,
             "accuracy": 0.9, "params": 10, "macs": 100,
             "artifacts": {"1": "m_d1_b1.hlo.txt"}}
          ],
          "quant_full": {"8": "m_q8.hlo.txt"},
          "probe": {
            "shape": [1, 2, 2, 1],
            "x": [0.0, 0.25, 0.5, 1.0],
            "logits": {"d1_w100": [0.1, 0.9]}
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let model = m.model("mnist").unwrap();
        assert_eq!(model.input_shape, (2, 2, 1));
        assert_eq!(model.paths.len(), 1);
        assert_eq!(model.artifact_for("d1_w100", 1), Some("m_d1_b1.hlo.txt"));
        assert_eq!(model.artifact_for("d1_w100", 8), None);
        assert_eq!(model.quant_full.get(&8).unwrap(), "m_q8.hlo.txt");
        assert_eq!(model.probe.logits["d1_w100"].len(), 2);
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 2");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn missing_accuracy_is_schema_error_not_zero() {
        let bad = SAMPLE.replace("\"accuracy\": 0.9, ", "");
        match Manifest::parse(Path::new("/tmp"), &bad) {
            Err(ManifestError::Schema(msg)) => {
                assert!(msg.contains("accuracy") && msg.contains("null"), "{msg}")
            }
            other => panic!("expected schema error, got {other:?}"),
        }
    }

    #[test]
    fn explicit_null_accuracy_means_untrained() {
        let untrained = SAMPLE.replace("\"accuracy\": 0.9", "\"accuracy\": null");
        let m = Manifest::parse(Path::new("/tmp"), &untrained).unwrap();
        assert_eq!(m.model("mnist").unwrap().paths[0].path.accuracy, 0.0);
    }

    #[test]
    fn out_of_range_accuracy_rejected() {
        for v in ["1.5", "-0.1", "\"high\""] {
            let bad = SAMPLE.replace("\"accuracy\": 0.9", &format!("\"accuracy\": {v}"));
            assert!(
                matches!(Manifest::parse(Path::new("/tmp"), &bad), Err(ManifestError::Schema(_))),
                "accuracy {v} must be rejected"
            );
        }
    }

    #[test]
    fn rejects_probe_shape_mismatch() {
        let bad = SAMPLE.replace("[1, 2, 2, 1]", "[1, 3, 3, 1]");
        assert!(matches!(
            Manifest::parse(Path::new("/tmp"), &bad),
            Err(ManifestError::Schema(_))
        ));
    }

    #[test]
    fn real_manifest_if_built() {
        // integration sanity against the actual artifacts when present
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            let model = m.model("mnist").expect("mnist built");
            assert_eq!(model.input_shape, (28, 28, 1));
            assert!(model.paths.len() >= 4);
            for p in &model.paths {
                for f in p.files.values() {
                    assert!(m.file_path(f).exists(), "missing {f}");
                }
            }
        }
    }
}
