//! PJRT backend — the AOT-artifact execution engine behind the trait.
//!
//! Thin adapter over [`crate::runtime::Engine`] (which owns the PJRT
//! client and per-(path, batch) executables). FPGA-side costs still come
//! from the cycle simulator over the deployed design point, exactly as
//! the pre-refactor coordinator computed them: PJRT provides numerics,
//! the simulator provides the power/latency the governor trades on.
//!
//! Engines are thread-local by construction, so the coordinator builds
//! one `PjrtBackend` per worker shard via [`super::BackendSpec::Pjrt`].

use std::cell::OnceCell;
use std::path::Path;

use super::{sim_path_costs, BackendError, InferenceBackend};
use crate::design::DesignConfig;
use crate::graph::Network;
use crate::morph::governor::PathCosts;
use crate::morph::{MorphPath, PathRegistry};
use crate::pe::Device;
use crate::runtime::Engine;

/// Hardware-backed (PJRT) inference behind [`InferenceBackend`].
pub struct PjrtBackend {
    engine: Engine,
    net: Network,
    design: DesignConfig,
    device: Device,
    /// governor cost table, simulated on first request — only shard 0's
    /// table is consumed, so the other shards skip the per-path sims
    costs: OnceCell<PathCosts>,
}

impl PjrtBackend {
    /// Load every (path, batch) artifact of `model` from `dir`.
    pub fn load(
        dir: &Path,
        model: &str,
        net: Network,
        design: DesignConfig,
        device: Device,
    ) -> Result<PjrtBackend, BackendError> {
        let engine =
            Engine::load(dir, model).map_err(|e| BackendError::Init(e.to_string()))?;
        // validate every manifest morph path against the fabric up front:
        // an out-of-range width is a load error, not a silent clamp
        for p in engine.model().morph_paths() {
            crate::morph::gate_mask_for(&net, &p)
                .map_err(|e| BackendError::Init(e.to_string()))?;
        }
        Ok(PjrtBackend { engine, net, design, device, costs: OnceCell::new() })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl InferenceBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn frame_len(&self) -> usize {
        self.engine.frame_len()
    }

    fn num_classes(&self) -> usize {
        self.engine.model().num_classes
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.engine.model().batches.clone()
    }

    fn morph_paths(&self) -> Vec<MorphPath> {
        self.engine.model().morph_paths()
    }

    // `path_energy` stays the trait default: FPGA-side power/latency for
    // a PJRT deployment come from the cycle simulator's cost table below
    // (host-side PJRT numerics carry no power model of their own).
    fn path_costs(&self) -> PathCosts {
        self.costs
            .get_or_init(|| {
                let registry = PathRegistry::new(self.engine.model().morph_paths());
                sim_path_costs(&self.net, &self.design, &self.device, &registry)
                    .expect("morph paths validated at load")
            })
            .clone()
    }

    fn execute(
        &mut self,
        path: &str,
        batch: usize,
        input: &[f32],
    ) -> Result<Vec<f32>, BackendError> {
        self.engine
            .execute(path, batch, input)
            .map_err(|e| BackendError::Execute(e.to_string()))
    }
}
