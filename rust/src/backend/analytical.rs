//! Analytical-model backend — the Eq. 12-15 fast path for serving.
//!
//! Uses [`crate::design::Evaluator`] (the allocation-free DSE fitness
//! function) for the full-design baseline and scales per-path costs by
//! each morph path's MAC fraction — the same first-order model NeuroForge
//! trades on during search. Orders of magnitude cheaper per batch than
//! the cycle simulator while preserving the cost *ordering* the governor
//! needs, so morph decisions match the sim backend on the same budget
//! trace. Numerics come from the shared [`SurrogateClassifier`]'s packed
//! batch pass (one pass per batch, nothing allocated per frame), making
//! logits bit-identical to the sim backend.

use super::{BackendError, InferenceBackend, SurrogateClassifier};
use crate::design::{DesignConfig, Evaluator};
use crate::graph::Network;
use crate::morph::governor::PathCosts;
use crate::morph::{MorphPath, PathRegistry};
use crate::pe::{Device, Resources};
use crate::power::{Activity, PathEnergy, PowerModel};

/// The analytical serving backend.
pub struct AnalyticalBackend {
    registry: PathRegistry,
    batches: Vec<usize>,
    classifier: SurrogateClassifier,
    frame_len: usize,
    num_classes: usize,
    costs: PathCosts,
    energy: Vec<PathEnergy>,
}

impl AnalyticalBackend {
    pub fn new(
        net: Network,
        design: DesignConfig,
        device: Device,
        paths: Vec<MorphPath>,
        batches: Vec<usize>,
    ) -> Result<AnalyticalBackend, BackendError> {
        if paths.is_empty() {
            return Err(BackendError::Init("no morph paths".into()));
        }
        if batches.is_empty() {
            return Err(BackendError::Init("no batch sizes".into()));
        }
        let ev = Evaluator::new(&net, &device).map_err(|e| BackendError::Init(e.to_string()))?;
        let full = ev
            .objectives(&design.parallelism, design.rep)
            .map_err(|e| BackendError::Init(e.to_string()))?;
        let full_latency_ms = ev.latency_ms(&full);
        let pm = PowerModel::default();
        let full_power = pm.total_mw(&full.resources, device.clock_mhz, Activity::default());
        // clock-gated blocks stop toggling: only the dynamic share scales
        // with the active MAC fraction, the static + clock-tree floor stays
        let floor = pm.total_mw(&Resources::default(), device.clock_mhz, Activity::default());

        let registry = PathRegistry::new(paths);
        // same init-time manifest validation as the sim/pjrt backends: an
        // out-of-range morph width is a loud error, not a silent cost row
        for p in registry.paths() {
            crate::morph::gate_mask_for(&net, p)
                .map_err(|e| BackendError::Init(e.to_string()))?;
        }
        let full_macs = registry.full().macs.max(1);
        let mut rows = Vec::with_capacity(registry.paths().len());
        let mut energy = Vec::with_capacity(registry.paths().len());
        for p in registry.paths() {
            let ratio = p.macs as f64 / full_macs as f64;
            let power = floor + (full_power - floor) * ratio;
            let latency = full_latency_ms * ratio;
            rows.push((p.name.clone(), power, latency));
            // first-order activity: the MAC fraction is the fraction of
            // the fabric still toggling on this path
            energy.push(PathEnergy {
                name: p.name.clone(),
                activity: Activity { active_fraction: ratio, ..Activity::default() },
                power_mw: power,
                frame_ms: latency,
            });
        }

        let (h, w, c) = net.input_dims();
        let frame_len = h * w * c;
        let num_classes = super::net_num_classes(&net);
        let classifier = SurrogateClassifier::new(frame_len, num_classes, registry.paths());
        Ok(AnalyticalBackend {
            registry,
            batches,
            classifier,
            frame_len,
            num_classes,
            costs: PathCosts { rows },
            energy,
        })
    }
}

impl InferenceBackend for AnalyticalBackend {
    fn name(&self) -> &'static str {
        "analytical"
    }

    fn frame_len(&self) -> usize {
        self.frame_len
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.batches.clone()
    }

    fn morph_paths(&self) -> Vec<MorphPath> {
        self.registry.paths().to_vec()
    }

    fn path_costs(&self) -> PathCosts {
        self.costs.clone()
    }

    fn path_energy(&self) -> Vec<PathEnergy> {
        self.energy.clone()
    }

    fn execute(
        &mut self,
        path: &str,
        batch: usize,
        input: &[f32],
    ) -> Result<Vec<f32>, BackendError> {
        if self.registry.by_name(path).is_none() {
            return Err(BackendError::UnknownPath(path.to_string()));
        }
        self.classifier.batch_logits(path, batch, input)
    }

    fn probe(&mut self) -> Result<(), BackendError> {
        // self-check mirroring SimBackend::probe: one zero frame through
        // the surrogate on the lightest deployed path
        let path = self
            .registry
            .paths()
            .first()
            .map(|p| p.name.clone())
            .ok_or_else(|| BackendError::Execute("no deployed paths".into()))?;
        let frame = vec![0.0f32; self.frame_len];
        self.classifier.batch_logits(&path, 1, &frame).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SimBackend;
    use crate::graph::zoo;
    use crate::morph;
    use crate::pe::{FpRep, ZYNQ_7100};

    fn backend() -> AnalyticalBackend {
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
        let paths = morph::depth_ladder(&net);
        AnalyticalBackend::new(net, design, ZYNQ_7100, paths, vec![1, 8]).unwrap()
    }

    #[test]
    fn costs_monotone_in_depth() {
        let b = backend();
        let costs = b.path_costs();
        let mut by_depth: Vec<(f64, f64)> = (1..=3)
            .map(|d| {
                let (_, p, l) = costs
                    .rows
                    .iter()
                    .find(|(n, _, _)| n == &format!("d{d}_w100"))
                    .unwrap()
                    .clone();
                (p, l)
            })
            .collect();
        by_depth.dedup();
        assert!(by_depth.windows(2).all(|w| w[0].0 < w[1].0 && w[0].1 < w[1].1));
    }

    #[test]
    fn logits_match_sim_backend_exactly() {
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
        let paths = morph::depth_ladder(&net);
        let mut ana = backend();
        let mut sim =
            SimBackend::new(net, design, ZYNQ_7100, paths, vec![1, 8], 1).unwrap();
        let input: Vec<f32> = (0..784).map(|i| (i % 37) as f32 / 37.0).collect();
        for path in ["d1_w100", "d2_w100", "d3_w100"] {
            assert_eq!(
                ana.execute(path, 1, &input).unwrap(),
                sim.execute(path, 1, &input).unwrap(),
                "backend numerics diverge on {path}"
            );
        }
    }

    #[test]
    fn cost_ordering_agrees_with_sim() {
        // the governor must make the same relative choices on both models
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
        let paths = morph::depth_ladder(&net);
        let ana = backend();
        let sim = SimBackend::new(net, design, ZYNQ_7100, paths, vec![1], 1).unwrap();
        let order = |c: &PathCosts| {
            let mut rows = c.rows.clone();
            rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            rows.into_iter().map(|(n, _, _)| n).collect::<Vec<_>>()
        };
        assert_eq!(order(&ana.path_costs()), order(&sim.path_costs()));
    }
}
