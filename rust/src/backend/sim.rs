//! Cycle-level simulation backend — the hardware stand-in for serving.
//!
//! Every executed frame streams through the simulated pipeline of the
//! deployed design point under the morph path's clock-gate mask, at
//! row/event granularity (`sim::simulate_with`). The design evaluation
//! and shape inference are hoisted out of the frame loop — the serving
//! hot path only pays the per-layer event walk. Logits come from the
//! shared [`SurrogateClassifier`], so numerics are bit-identical to the
//! analytical backend and independent of worker count.

use std::cell::OnceCell;
use std::collections::BTreeMap;

use super::{BackendError, InferenceBackend, SurrogateClassifier};
use crate::design::{self, DesignConfig, DesignEval};
use crate::graph::{shapes, Network};
use crate::morph::governor::PathCosts;
use crate::morph::{gate_mask_for, MorphPath, PathRegistry};
use crate::pe::Device;
use crate::sim::{self, GateMask, SimReport};

/// Build the per-path cost table from the cycle simulator — the data the
/// governor trades on (power mW, latency ms per morph path).
pub fn sim_path_costs(
    net: &Network,
    design: &DesignConfig,
    device: &Device,
    registry: &PathRegistry,
) -> PathCosts {
    let rows = registry
        .paths()
        .iter()
        .map(|p| {
            let mask = gate_mask_for(net, p);
            let rep = sim::simulate(net, design, device, &mask);
            (p.name.clone(), rep.power_mw, rep.latency_ms())
        })
        .collect();
    PathCosts { rows }
}

/// The cycle-accurate serving backend.
pub struct SimBackend {
    net: Network,
    device: Device,
    registry: PathRegistry,
    batches: Vec<usize>,
    fidelity: usize,
    classifier: SurrogateClassifier,
    frame_len: usize,
    num_classes: usize,
    eval: DesignEval,
    shapes: shapes::Shapes,
    masks: BTreeMap<String, GateMask>,
    /// governor cost table, computed on first request — only shard 0's
    /// table feeds the shared governor, so the other shards never pay
    /// the per-path frame simulations
    costs: OnceCell<PathCosts>,
    /// cycle report of the most recently executed path (telemetry)
    last_report: Option<SimReport>,
}

impl SimBackend {
    pub fn new(
        net: Network,
        design: DesignConfig,
        device: Device,
        paths: Vec<MorphPath>,
        batches: Vec<usize>,
        fidelity: usize,
    ) -> Result<SimBackend, BackendError> {
        if paths.is_empty() {
            return Err(BackendError::Init("no morph paths".into()));
        }
        if batches.is_empty() {
            return Err(BackendError::Init("no batch sizes".into()));
        }
        let eval = design::evaluate(&net, &design, &device)
            .map_err(|e| BackendError::Init(e.to_string()))?;
        let shp =
            shapes::infer(&net).map_err(|e| BackendError::Init(e.to_string()))?;
        let registry = PathRegistry::new(paths);
        let masks: BTreeMap<String, GateMask> = registry
            .paths()
            .iter()
            .map(|p| (p.name.clone(), gate_mask_for(&net, p)))
            .collect();
        let (h, w, c) = net.input_dims();
        let frame_len = h * w * c;
        let num_classes = super::net_num_classes(&net);
        let classifier = SurrogateClassifier::new(frame_len, num_classes, registry.paths());
        Ok(SimBackend {
            net,
            device,
            registry,
            batches,
            fidelity: fidelity.max(1),
            classifier,
            frame_len,
            num_classes,
            eval,
            shapes: shp,
            masks,
            costs: OnceCell::new(),
            last_report: None,
        })
    }

    /// Cycle report of the last executed batch's path, if any.
    pub fn last_report(&self) -> Option<&SimReport> {
        self.last_report.as_ref()
    }
}

impl InferenceBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn frame_len(&self) -> usize {
        self.frame_len
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.batches.clone()
    }

    fn morph_paths(&self) -> Vec<MorphPath> {
        self.registry.paths().to_vec()
    }

    fn path_costs(&self) -> PathCosts {
        // one frame sim per path against the pre-evaluated design point
        // (cheaper than the standalone sim_path_costs() convenience,
        // which re-runs evaluate/infer per path)
        self.costs
            .get_or_init(|| PathCosts {
                rows: self
                    .registry
                    .paths()
                    .iter()
                    .map(|p| {
                        let rep = sim::simulate_with(
                            &self.net,
                            &self.device,
                            &self.masks[&p.name],
                            &self.eval,
                            &self.shapes,
                        );
                        (p.name.clone(), rep.power_mw, rep.latency_ms())
                    })
                    .collect(),
            })
            .clone()
    }

    fn execute(
        &mut self,
        path: &str,
        batch: usize,
        input: &[f32],
    ) -> Result<Vec<f32>, BackendError> {
        let mask = self
            .masks
            .get(path)
            .ok_or_else(|| BackendError::UnknownPath(path.to_string()))?;
        if input.len() != batch * self.frame_len {
            return Err(BackendError::BadInput {
                got: input.len(),
                want: batch * self.frame_len,
            });
        }
        // stream every frame through the cycle simulator (fidelity
        // independent replays per frame, as a hardware run would average
        // repeated measurements)
        let mut report = None;
        for _frame in 0..batch {
            for _ in 0..self.fidelity {
                report = Some(sim::simulate_with(
                    &self.net,
                    &self.device,
                    mask,
                    &self.eval,
                    &self.shapes,
                ));
            }
        }
        self.last_report = report;
        self.classifier.batch_logits(path, batch, input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::morph;
    use crate::pe::{FpRep, ZYNQ_7100};

    fn backend() -> SimBackend {
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
        let paths = morph::depth_ladder(&net);
        SimBackend::new(net, design, ZYNQ_7100, paths, vec![1, 8], 1).unwrap()
    }

    #[test]
    fn executes_and_reports_cycles() {
        let mut b = backend();
        let input = vec![0.5f32; 784];
        let logits = b.execute("d1_w100", 1, &input).unwrap();
        assert_eq!(logits.len(), 10);
        let light = b.last_report().unwrap().latency_cycles;
        b.execute("d3_w100", 1, &input).unwrap();
        let full = b.last_report().unwrap().latency_cycles;
        assert!(light < full, "gated path must be faster ({light} vs {full})");
    }

    #[test]
    fn validates_path_and_input() {
        let mut b = backend();
        assert!(matches!(
            b.execute("bogus", 1, &[0.0; 784]),
            Err(BackendError::UnknownPath(_))
        ));
        assert!(matches!(
            b.execute("d1_w100", 2, &[0.0; 784]),
            Err(BackendError::BadInput { .. })
        ));
    }

    #[test]
    fn costs_ordered_by_depth() {
        let b = backend();
        let costs = b.path_costs();
        let get = |n: &str| costs.rows.iter().find(|(m, _, _)| m == n).unwrap().clone();
        let (_, p1, l1) = get("d1_w100");
        let (_, p3, l3) = get("d3_w100");
        assert!(p1 < p3 && l1 < l3);
    }
}
