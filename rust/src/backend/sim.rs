//! Cycle-level simulation backend — the hardware stand-in for serving.
//!
//! Every executed batch walks the simulated pipeline of the deployed
//! design point under the morph path's clock-gate mask, at row/event
//! granularity (`sim::simulate_with`). The pass-pipeline schedule and
//! the design evaluation are hoisted out of serving entirely, and the
//! event walk itself runs `fidelity` times per *batch*, not per frame:
//! the simulator is deterministic in (plan, mask, eval), so the
//! per-frame replays the old hot path paid produced bit-identical
//! reports — the modeled per-frame latency already lives inside the
//! report. Logits come from the shared [`SurrogateClassifier`]'s packed
//! batch pass, so numerics are bit-identical to the analytical backend
//! and independent of worker count.

use std::cell::OnceCell;
use std::collections::BTreeMap;

use super::{BackendError, InferenceBackend, SurrogateClassifier};
use crate::design::{self, DesignConfig, DesignEval};
use crate::graph::passes::{self, StagePlan};
use crate::graph::Network;
use crate::morph::governor::PathCosts;
use crate::morph::{gate_mask_for, MorphError, MorphPath, PathRegistry};
use crate::pe::Device;
use crate::power::{Activity, PathEnergy};
use crate::sim::{self, GateMask, SimReport};

/// Build the per-path cost table from the cycle simulator — the data the
/// governor trades on (power mW, latency ms per morph path). Fails when a
/// registry path cannot be lowered onto the fabric (e.g. a corrupt
/// manifest width) instead of clamping it.
pub fn sim_path_costs(
    net: &Network,
    design: &DesignConfig,
    device: &Device,
    registry: &PathRegistry,
) -> Result<PathCosts, MorphError> {
    let mut rows = Vec::with_capacity(registry.paths().len());
    for p in registry.paths() {
        let mask = gate_mask_for(net, p)?;
        let rep = sim::simulate(net, design, device, &mask);
        rows.push((p.name.clone(), rep.power_mw, rep.latency_ms()));
    }
    Ok(PathCosts { rows })
}

/// The cycle-accurate serving backend.
pub struct SimBackend {
    plan: StagePlan,
    device: Device,
    registry: PathRegistry,
    batches: Vec<usize>,
    fidelity: usize,
    classifier: SurrogateClassifier,
    frame_len: usize,
    num_classes: usize,
    eval: DesignEval,
    masks: BTreeMap<String, GateMask>,
    /// governor cost table + per-path energy rows, computed on first
    /// request — only shard 0's tables feed the shared governor, so the
    /// other shards never pay the per-path frame simulations
    costs: OnceCell<(PathCosts, Vec<PathEnergy>)>,
    /// cycle report of the most recently executed path (telemetry)
    last_report: Option<SimReport>,
}

/// Runtime [`Activity`] of a gated path, derived from its gate mask and
/// cycle report: the active gate-block fraction (scaled by the width
/// lanes still toggling) times the surviving stages' busy toggle rate —
/// the StagePlan-level stand-in for a SAIF activity trace.
fn activity_from(mask: &GateMask, rep: &SimReport) -> Activity {
    let total = rep.per_stage.len().max(1);
    let active = rep.per_stage.iter().filter(|s| !s.gated).count();
    let block_fraction = active as f64 / total as f64;
    let busy: u64 = rep
        .per_stage
        .iter()
        .filter(|s| !s.gated)
        .map(|s| s.busy_cycles)
        .sum();
    let denom = (active.max(1) as u64 * rep.period_cycles.max(1)) as f64;
    let toggle = (Activity::default().toggle_rate * (busy as f64 / denom)).clamp(0.05, 1.0);
    Activity {
        active_fraction: (block_fraction * mask.width_fraction).clamp(0.0, 1.0),
        toggle_rate: toggle,
    }
}

impl SimBackend {
    pub fn new(
        net: Network,
        design: DesignConfig,
        device: Device,
        paths: Vec<MorphPath>,
        batches: Vec<usize>,
        fidelity: usize,
    ) -> Result<SimBackend, BackendError> {
        if paths.is_empty() {
            return Err(BackendError::Init("no morph paths".into()));
        }
        if batches.is_empty() {
            return Err(BackendError::Init("no batch sizes".into()));
        }
        let plan = passes::schedule(&net)
            .map_err(|e| BackendError::Init(e.to_string()))?;
        let eval = design::evaluate_plan(&plan, &design, &device)
            .map_err(|e| BackendError::Init(e.to_string()))?;
        let registry = PathRegistry::new(paths);
        // validate every morph path at init — a bad manifest fails loudly
        // here, not silently at the clamp floor mid-serve
        let mut masks: BTreeMap<String, GateMask> = BTreeMap::new();
        for p in registry.paths() {
            let mask =
                gate_mask_for(&net, p).map_err(|e| BackendError::Init(e.to_string()))?;
            masks.insert(p.name.clone(), mask);
        }
        let (h, w, c) = net.input_dims();
        let frame_len = h * w * c;
        let num_classes = super::net_num_classes(&net);
        let classifier = SurrogateClassifier::new(frame_len, num_classes, registry.paths());
        Ok(SimBackend {
            plan,
            device,
            registry,
            batches,
            fidelity: fidelity.max(1),
            classifier,
            frame_len,
            num_classes,
            eval,
            masks,
            costs: OnceCell::new(),
            last_report: None,
        })
    }

    /// Cycle report of the last executed batch's path, if any.
    pub fn last_report(&self) -> Option<&SimReport> {
        self.last_report.as_ref()
    }

    /// One frame sim per path against the pre-scheduled plan and
    /// pre-evaluated design point (cheaper than the standalone
    /// [`sim_path_costs`] convenience, which re-schedules per path);
    /// yields the governor cost table and the energy rows in one pass.
    fn tables(&self) -> &(PathCosts, Vec<PathEnergy>) {
        self.costs.get_or_init(|| {
            let mut rows = Vec::with_capacity(self.registry.paths().len());
            let mut energy = Vec::with_capacity(self.registry.paths().len());
            for p in self.registry.paths() {
                let mask = &self.masks[&p.name];
                let rep = sim::simulate_with(&self.plan, &self.device, mask, &self.eval);
                rows.push((p.name.clone(), rep.power_mw, rep.latency_ms()));
                energy.push(PathEnergy {
                    name: p.name.clone(),
                    activity: activity_from(mask, &rep),
                    power_mw: rep.power_mw,
                    frame_ms: rep.latency_ms(),
                });
            }
            (PathCosts { rows }, energy)
        })
    }
}

impl InferenceBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn frame_len(&self) -> usize {
        self.frame_len
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.batches.clone()
    }

    fn morph_paths(&self) -> Vec<MorphPath> {
        self.registry.paths().to_vec()
    }

    fn path_costs(&self) -> PathCosts {
        self.tables().0.clone()
    }

    fn path_energy(&self) -> Vec<PathEnergy> {
        self.tables().1.clone()
    }

    fn execute(
        &mut self,
        path: &str,
        batch: usize,
        input: &[f32],
    ) -> Result<Vec<f32>, BackendError> {
        let mask = self
            .masks
            .get(path)
            .ok_or_else(|| BackendError::UnknownPath(path.to_string()))?;
        if input.len() != batch * self.frame_len {
            return Err(BackendError::BadInput {
                got: input.len(),
                want: batch * self.frame_len,
            });
        }
        // one pipeline walk per batch (fidelity independent replays, as
        // a hardware run would average repeated measurements): the
        // simulator is deterministic in (plan, mask, eval), so the
        // per-frame replays the old loop paid were bit-identical — the
        // modeled per-frame streaming cost is the report's latency, not
        // host CPU spent re-walking identical events
        let mut report = None;
        for _ in 0..self.fidelity {
            report = Some(sim::simulate_with(
                &self.plan,
                &self.device,
                mask,
                &self.eval,
            ));
        }
        self.last_report = report;
        self.classifier.batch_logits(path, batch, input)
    }

    fn probe(&mut self) -> Result<(), BackendError> {
        // real self-check: one zero frame through the full surrogate on
        // the lightest deployed path (cheap, but exercises the same
        // classifier state execute() uses)
        let path = self
            .registry
            .paths()
            .first()
            .map(|p| p.name.clone())
            .ok_or_else(|| BackendError::Execute("no deployed paths".into()))?;
        let frame = vec![0.0f32; self.frame_len];
        self.classifier.batch_logits(&path, 1, &frame).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::morph;
    use crate::pe::{FpRep, ZYNQ_7100};

    fn backend() -> SimBackend {
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
        let paths = morph::depth_ladder(&net);
        SimBackend::new(net, design, ZYNQ_7100, paths, vec![1, 8], 1).unwrap()
    }

    #[test]
    fn executes_and_reports_cycles() {
        let mut b = backend();
        let input = vec![0.5f32; 784];
        let logits = b.execute("d1_w100", 1, &input).unwrap();
        assert_eq!(logits.len(), 10);
        let light = b.last_report().unwrap().latency_cycles;
        b.execute("d3_w100", 1, &input).unwrap();
        let full = b.last_report().unwrap().latency_cycles;
        assert!(light < full, "gated path must be faster ({light} vs {full})");
    }

    #[test]
    fn validates_path_and_input() {
        let mut b = backend();
        assert!(matches!(
            b.execute("bogus", 1, &[0.0; 784]),
            Err(BackendError::UnknownPath(_))
        ));
        assert!(matches!(
            b.execute("d1_w100", 2, &[0.0; 784]),
            Err(BackendError::BadInput { .. })
        ));
    }

    #[test]
    fn costs_ordered_by_depth() {
        let b = backend();
        let costs = b.path_costs();
        let get = |n: &str| costs.rows.iter().find(|(m, _, _)| m == n).unwrap().clone();
        let (_, p1, l1) = get("d1_w100");
        let (_, p3, l3) = get("d3_w100");
        assert!(p1 < p3 && l1 < l3);
    }

    #[test]
    fn activity_tracks_gating_depth_and_width() {
        let b = backend();
        let energy = b.path_energy();
        let frac = |n: &str| {
            energy
                .iter()
                .find(|e| e.name == n)
                .unwrap()
                .activity
                .active_fraction
        };
        // deeper paths keep more gate blocks toggling
        assert!(frac("d1_w100") < frac("d2_w100"));
        assert!(frac("d2_w100") < frac("d3_w100"));
        // a width-gated full-depth path sits below the full path
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
        let mut paths = morph::depth_ladder(&net);
        paths.push(MorphPath {
            name: "d3_w50".into(),
            depth: 3,
            width_pct: 50,
            accuracy: 0.95,
            params: 1,
            macs: paths.last().unwrap().macs / 2,
        });
        let b = SimBackend::new(net, design, ZYNQ_7100, paths, vec![1], 1).unwrap();
        let energy = b.path_energy();
        let get = |n: &str| energy.iter().find(|e| e.name == n).unwrap();
        assert!(
            get("d3_w50").activity.active_fraction
                < get("d3_w100").activity.active_fraction
        );
        assert!(get("d3_w50").power_mw < get("d3_w100").power_mw);
    }

    #[test]
    fn corrupt_manifest_width_fails_at_init() {
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
        let mut paths = morph::depth_ladder(&net);
        paths.push(MorphPath {
            name: "d3_w5".into(),
            depth: 3,
            width_pct: 5,
            accuracy: 0.5,
            params: 1,
            macs: 1,
        });
        let err = SimBackend::new(net, design, ZYNQ_7100, paths, vec![1], 1)
            .err()
            .expect("5% width must be rejected");
        assert!(err.to_string().contains("width"), "{err}");
    }
}
