//! Unified inference backends for the serving engine.
//!
//! Before this module existed, the three ways of executing a morph path
//! — the PJRT [`crate::runtime::Engine`], the cycle-level simulator
//! (`crate::sim`) and the analytical model (`crate::design::Evaluator`)
//! — were called through ad-hoc, incompatible paths in the coordinator,
//! the CLI and the report harness. [`InferenceBackend`] gives all three
//! one contract the sharded coordinator can drive:
//!
//! * [`PjrtBackend`] — hardware-backed numerics from AOT HLO artifacts
//!   (requires a real `xla` binding; the offline stub fails cleanly).
//! * [`SimBackend`] — the cycle-accurate stand-in: every frame streams
//!   through the simulated pipeline, logits come from the deterministic
//!   [`SurrogateClassifier`].
//! * [`AnalyticalBackend`] — the Eq. 12-15 fast path: costs from
//!   [`crate::design::Evaluator`], same surrogate numerics, microseconds
//!   per batch. Used for capacity planning and as the DSE-facing twin.
//!
//! Backends are *per-worker-shard* objects (PJRT executables are
//! thread-local by construction), so the coordinator receives a cloneable
//! [`BackendSpec`] recipe and each shard builds its own instance.

pub mod analytical;
pub mod pjrt;
pub mod sim;

pub use analytical::AnalyticalBackend;
pub use pjrt::PjrtBackend;
pub use sim::{sim_path_costs, SimBackend};

use std::collections::BTreeMap;
use std::fmt;
use std::path::PathBuf;

use crate::design::DesignConfig;
use crate::graph::{LayerKind, Network};
use crate::morph::governor::PathCosts;
use crate::morph::MorphPath;
use crate::pe::Device;
use crate::power::{Activity, PathEnergy};
use crate::util::rng::Rng;

/// Errors surfaced by backend construction and execution.
#[derive(Debug)]
pub enum BackendError {
    /// backend could not be constructed (artifacts missing, bad design…)
    Init(String),
    /// the requested morph path is not deployed on this backend
    UnknownPath(String),
    /// flat input length does not match batch x frame
    BadInput { got: usize, want: usize },
    /// execution failed after successful init
    Execute(String),
}

impl fmt::Display for BackendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BackendError::Init(msg) => write!(f, "backend init: {msg}"),
            BackendError::UnknownPath(p) => write!(f, "unknown morph path '{p}'"),
            BackendError::BadInput { got, want } => {
                write!(f, "input length {got} != expected {want}")
            }
            BackendError::Execute(msg) => write!(f, "execute: {msg}"),
        }
    }
}

impl std::error::Error for BackendError {}

/// The contract every execution engine offers the serving layer.
///
/// One instance serves one worker shard; `execute` takes `&mut self` so
/// implementations may keep per-shard scratch state without locking.
pub trait InferenceBackend: Send {
    /// Stable backend identifier ("pjrt", "sim", "analytical").
    fn name(&self) -> &'static str;

    /// Flat input element count per frame (H*W*C).
    fn frame_len(&self) -> usize;

    /// Output logit count per frame.
    fn num_classes(&self) -> usize;

    /// Batch sizes this backend can execute, ascending.
    fn batch_sizes(&self) -> Vec<usize>;

    /// The deployed morph-path set with accuracy/cost metadata.
    fn morph_paths(&self) -> Vec<MorphPath>;

    /// Per-path (power mW, latency ms) table the governor trades on.
    fn path_costs(&self) -> PathCosts;

    /// Per-path power/energy operating points the serving layer's energy
    /// accounting consumes. The default derives rows from [`path_costs`]
    /// at the default activity; backends with a richer activity model
    /// (the cycle simulator's StagePlan gating footprint, the analytical
    /// model's MAC fraction) override it.
    ///
    /// [`path_costs`]: InferenceBackend::path_costs
    fn path_energy(&self) -> Vec<PathEnergy> {
        self.path_costs()
            .rows
            .iter()
            .map(|(name, power_mw, frame_ms)| PathEnergy {
                name: name.clone(),
                activity: Activity::default(),
                power_mw: *power_mw,
                frame_ms: *frame_ms,
            })
            .collect()
    }

    /// Execute `batch` frames on `path`; returns flattened logits
    /// `[batch * num_classes]`.
    fn execute(
        &mut self,
        path: &str,
        batch: usize,
        input: &[f32],
    ) -> Result<Vec<f32>, BackendError>;

    /// Cheap liveness self-check a quarantined worker shard runs before
    /// the health board releases it back to duty. The default is
    /// optimistic; backends with real state override it with an actual
    /// sanity probe.
    fn probe(&mut self) -> Result<(), BackendError> {
        Ok(())
    }

    /// Argmax class ids for a flattened logits buffer.
    fn argmax(&self, logits: &[f32]) -> Vec<usize> {
        logits
            .chunks(self.num_classes().max(1))
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

/// Cloneable recipe the coordinator hands to each worker shard; every
/// shard builds its own backend instance from it (PJRT executables must
/// live on the thread that created them).
#[derive(Clone)]
pub enum BackendSpec {
    /// AOT artifacts through the PJRT runtime; FPGA-side costs come from
    /// the cycle simulator over `net`/`design`, as before the refactor.
    Pjrt {
        artifacts_dir: PathBuf,
        model: String,
        net: Network,
        design: DesignConfig,
        device: Device,
    },
    /// Cycle-level simulation of `design` with surrogate numerics.
    Sim {
        net: Network,
        design: DesignConfig,
        device: Device,
        paths: Vec<MorphPath>,
        batches: Vec<usize>,
        /// independent simulation replays averaged per frame (models
        /// on-hardware measurement averaging; also the compute-density
        /// dial of the serving benchmarks)
        fidelity: usize,
    },
    /// Analytical Eq. 12-15 cost model with surrogate numerics.
    Analytical {
        net: Network,
        design: DesignConfig,
        device: Device,
        paths: Vec<MorphPath>,
        batches: Vec<usize>,
    },
}

impl BackendSpec {
    /// Sim spec with the default {1, 8} batch menu and fidelity 1.
    pub fn sim(
        net: Network,
        design: DesignConfig,
        device: Device,
        paths: Vec<MorphPath>,
    ) -> BackendSpec {
        BackendSpec::Sim { net, design, device, paths, batches: vec![1, 8], fidelity: 1 }
    }

    /// Analytical spec with the default {1, 8} batch menu.
    pub fn analytical(
        net: Network,
        design: DesignConfig,
        device: Device,
        paths: Vec<MorphPath>,
    ) -> BackendSpec {
        BackendSpec::Analytical { net, design, device, paths, batches: vec![1, 8] }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            BackendSpec::Pjrt { .. } => "pjrt",
            BackendSpec::Sim { .. } => "sim",
            BackendSpec::Analytical { .. } => "analytical",
        }
    }

    /// One-line backend description for trace metadata
    /// (`otherData.backend` in the Chrome export).
    pub fn describe(&self) -> String {
        match self {
            BackendSpec::Pjrt { model, .. } => format!("pjrt({model})"),
            BackendSpec::Sim { paths, fidelity, .. } => {
                format!("sim({} paths, fidelity {fidelity})", paths.len())
            }
            BackendSpec::Analytical { paths, .. } => {
                format!("analytical({} paths)", paths.len())
            }
        }
    }

    /// Build one backend instance (called once per worker shard).
    pub fn build(&self) -> Result<Box<dyn InferenceBackend>, BackendError> {
        match self {
            BackendSpec::Pjrt { artifacts_dir, model, net, design, device } => Ok(Box::new(
                PjrtBackend::load(artifacts_dir, model, net.clone(), design.clone(), *device)?,
            )),
            BackendSpec::Sim { net, design, device, paths, batches, fidelity } => {
                Ok(Box::new(SimBackend::new(
                    net.clone(),
                    design.clone(),
                    *device,
                    paths.clone(),
                    batches.clone(),
                    *fidelity,
                )?))
            }
            BackendSpec::Analytical { net, design, device, paths, batches } => {
                Ok(Box::new(AnalyticalBackend::new(
                    net.clone(),
                    design.clone(),
                    *device,
                    paths.clone(),
                    batches.clone(),
                )?))
            }
        }
    }
}

/// Number of classes a network's head produces (last FC width).
pub fn net_num_classes(net: &Network) -> usize {
    net.layers
        .iter()
        .rev()
        .find_map(|l| match l.kind {
            LayerKind::Fc { out, .. } => Some(out),
            _ => None,
        })
        .unwrap_or(10)
}

/// Deterministic per-path linear classifier shared by the sim and
/// analytical backends.
///
/// Neither backend carries trained weights, but the serving layer still
/// needs *reproducible* numerics: the same (path, frame) must yield the
/// same logits on any backend, any worker shard, any worker count — the
/// property the sharding determinism test pins. Weights are derived from
/// a seeded [`Rng`] keyed on the path name only, so two independently
/// constructed backends agree exactly.
///
/// The batch hot path runs one packed pass over the whole batch against
/// a *transposed* `[frame_len, num_classes]` weight copy: per frame the
/// `classes`-wide logit row is the vector lane and `d` ascends per
/// accumulator — the same per-(frame, class) reduction order as the
/// scalar per-class dot (weights are drawn in the original row-major RNG
/// order and only then transposed), so logits stay bit-identical to
/// [`SurrogateClassifier::scalar_logits`] while the batch loop allocates
/// nothing per frame.
#[derive(Debug, Clone)]
pub struct SurrogateClassifier {
    frame_len: usize,
    num_classes: usize,
    /// path name -> transposed [frame_len * num_classes] weights
    /// (`wt[d * num_classes + c]`)
    weights_t: BTreeMap<String, Vec<f32>>,
}

impl SurrogateClassifier {
    pub fn new(frame_len: usize, num_classes: usize, paths: &[MorphPath]) -> SurrogateClassifier {
        let mut weights_t = BTreeMap::new();
        for p in paths {
            let mut rng = Rng::new(fnv1a(&p.name));
            // draw in the historical row-major [classes, frame_len] order
            // (the RNG stream defines the weights), then transpose for
            // the packed batch pass
            let w: Vec<f32> = (0..num_classes * frame_len)
                .map(|_| (rng.f64() * 2.0 - 1.0) as f32)
                .collect();
            let mut wt = vec![0.0f32; num_classes * frame_len];
            for c in 0..num_classes {
                for d in 0..frame_len {
                    wt[d * num_classes + c] = w[c * frame_len + d];
                }
            }
            weights_t.insert(p.name.clone(), wt);
        }
        SurrogateClassifier { frame_len, num_classes, weights_t }
    }

    fn path_weights(&self, path: &str) -> Result<&[f32], BackendError> {
        self.weights_t
            .get(path)
            .map(Vec::as_slice)
            .ok_or_else(|| BackendError::UnknownPath(path.to_string()))
    }

    /// Logits for one frame on one path.
    pub fn logits(&self, path: &str, frame: &[f32]) -> Result<Vec<f32>, BackendError> {
        if frame.len() != self.frame_len {
            // check before the batch path so the error reports the
            // per-frame expectation, as it always has
            self.path_weights(path)?;
            return Err(BackendError::BadInput { got: frame.len(), want: self.frame_len });
        }
        self.batch_logits(path, 1, frame)
    }

    /// The retained scalar reference: per-class dots, one frame at a
    /// time. Kept as the bit-level spec the packed batch pass is tested
    /// against, and as the serving bench's batched-vs-scalar baseline.
    pub fn scalar_logits(&self, path: &str, frame: &[f32]) -> Result<Vec<f32>, BackendError> {
        let wt = self.path_weights(path)?;
        if frame.len() != self.frame_len {
            return Err(BackendError::BadInput { got: frame.len(), want: self.frame_len });
        }
        let classes = self.num_classes;
        Ok((0..classes)
            .map(|c| (0..self.frame_len).map(|d| wt[d * classes + c] * frame[d]).sum())
            .collect())
    }

    /// Logits for a flat batch (caller guarantees `batch * frame_len`).
    pub fn batch_logits(
        &self,
        path: &str,
        batch: usize,
        input: &[f32],
    ) -> Result<Vec<f32>, BackendError> {
        let mut out = Vec::new();
        self.batch_logits_into(path, batch, input, &mut out)?;
        Ok(out)
    }

    /// [`batch_logits`](SurrogateClassifier::batch_logits) into a
    /// caller-held buffer: the per-shard scratch-reuse entry — a shard
    /// that keeps `out` across batches allocates nothing here once the
    /// buffer has grown to the largest batch it serves.
    pub fn batch_logits_into(
        &self,
        path: &str,
        batch: usize,
        input: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<(), BackendError> {
        let wt = self.path_weights(path)?;
        if input.len() != batch * self.frame_len {
            return Err(BackendError::BadInput {
                got: input.len(),
                want: batch * self.frame_len,
            });
        }
        let classes = self.num_classes;
        out.clear();
        out.resize(batch * classes, 0.0);
        if self.frame_len == 0 || classes == 0 {
            return Ok(());
        }
        for (orow, frame) in
            out.chunks_exact_mut(classes).zip(input.chunks_exact(self.frame_len))
        {
            for (d, &xv) in frame.iter().enumerate() {
                let wrow = &wt[d * classes..(d + 1) * classes];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
        Ok(())
    }
}

/// FNV-1a over the path name: stable, dependency-free seed derivation.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::morph;
    use crate::pe::{FpRep, ZYNQ_7100};

    fn paths() -> Vec<MorphPath> {
        morph::depth_ladder(&zoo::mnist())
    }

    #[test]
    fn surrogate_is_deterministic_across_instances() {
        let a = SurrogateClassifier::new(784, 10, &paths());
        let b = SurrogateClassifier::new(784, 10, &paths());
        let frame: Vec<f32> = (0..784).map(|i| (i as f32) / 784.0).collect();
        assert_eq!(
            a.logits("d3_w100", &frame).unwrap(),
            b.logits("d3_w100", &frame).unwrap()
        );
        // different paths give different heads
        assert_ne!(
            a.logits("d1_w100", &frame).unwrap(),
            a.logits("d3_w100", &frame).unwrap()
        );
    }

    #[test]
    fn surrogate_validates_inputs() {
        let c = SurrogateClassifier::new(4, 2, &paths());
        assert!(matches!(
            c.logits("nope", &[0.0; 4]),
            Err(BackendError::UnknownPath(_))
        ));
        assert!(matches!(
            c.logits("d1_w100", &[0.0; 3]),
            Err(BackendError::BadInput { .. })
        ));
        assert!(matches!(
            c.batch_logits("d1_w100", 2, &[0.0; 7]),
            Err(BackendError::BadInput { .. })
        ));
    }

    #[test]
    fn batched_logits_match_scalar_reference_bitwise() {
        let c = SurrogateClassifier::new(37, 5, &paths());
        let batch = 9;
        let input: Vec<f32> = (0..batch * 37)
            .map(|i| ((i * 2_654_435_761_usize) % 1000) as f32 / 500.0 - 1.0)
            .collect();
        let out = c.batch_logits("d2_w100", batch, &input).unwrap();
        let mut reused = vec![0.0f32; 1]; // scratch-reuse entry agrees too
        c.batch_logits_into("d2_w100", batch, &input, &mut reused).unwrap();
        assert_eq!(out, reused);
        for f in 0..batch {
            let frame = &input[f * 37..(f + 1) * 37];
            let want = c.scalar_logits("d2_w100", frame).unwrap();
            let got = &out[f * 5..(f + 1) * 5];
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "frame {f}"
            );
        }
    }

    #[test]
    fn net_num_classes_reads_head() {
        assert_eq!(net_num_classes(&zoo::mnist()), 10);
        assert_eq!(net_num_classes(&zoo::cifar10()), 10);
    }

    #[test]
    fn spec_builds_sim_and_analytical() {
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
        for spec in [
            BackendSpec::sim(net.clone(), design.clone(), ZYNQ_7100, paths()),
            BackendSpec::analytical(net.clone(), design.clone(), ZYNQ_7100, paths()),
        ] {
            let b = spec.build().expect("build");
            assert_eq!(b.frame_len(), 784);
            assert_eq!(b.num_classes(), 10);
            assert_eq!(b.batch_sizes(), vec![1, 8]);
            assert_eq!(b.morph_paths().len(), 3);
        }
        let sim = BackendSpec::sim(net.clone(), design.clone(), ZYNQ_7100, paths());
        assert_eq!(sim.describe(), "sim(3 paths, fidelity 1)");
        let ana = BackendSpec::analytical(net, design, ZYNQ_7100, paths());
        assert_eq!(ana.describe(), "analytical(3 paths)");
    }

    #[test]
    fn path_energy_consistent_with_costs_on_every_backend() {
        // the energy rows must cover exactly the cost-table paths, agree
        // on power/latency, and be monotone in path depth (gating fewer
        // blocks can only draw more power)
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
        for spec in [
            BackendSpec::sim(net.clone(), design.clone(), ZYNQ_7100, paths()),
            BackendSpec::analytical(net.clone(), design.clone(), ZYNQ_7100, paths()),
        ] {
            let b = spec.build().expect("build");
            let costs = b.path_costs();
            let energy = b.path_energy();
            assert_eq!(energy.len(), costs.rows.len());
            for (name, power, lat) in &costs.rows {
                let e = energy
                    .iter()
                    .find(|e| &e.name == name)
                    .unwrap_or_else(|| panic!("no energy row for {name}"));
                assert!((e.power_mw - power).abs() < 1e-9, "{name} power");
                assert!((e.frame_ms - lat).abs() < 1e-9, "{name} latency");
                assert!(e.energy_mj_per_frame() > 0.0);
                assert!((0.0..=1.0).contains(&e.activity.active_fraction));
                assert!((0.0..=1.0).contains(&e.activity.toggle_rate));
            }
            let by_depth = |d: usize| {
                energy
                    .iter()
                    .find(|e| e.name == format!("d{d}_w100"))
                    .unwrap()
                    .clone()
            };
            let (e1, e3) = (by_depth(1), by_depth(3));
            assert!(e1.power_mw < e3.power_mw);
            assert!(e1.activity.active_fraction <= e3.activity.active_fraction);
            assert!(e1.energy_mj_per_frame() < e3.energy_mj_per_frame());
        }
    }

    #[test]
    fn pjrt_spec_fails_cleanly_without_artifacts() {
        let net = zoo::mnist();
        let spec = BackendSpec::Pjrt {
            artifacts_dir: PathBuf::from("/nonexistent/artifacts"),
            model: "mnist".into(),
            net: net.clone(),
            design: DesignConfig::uniform(&net, 4, FpRep::Int16),
            device: ZYNQ_7100,
        };
        assert!(matches!(spec.build(), Err(BackendError::Init(_))));
    }
}
