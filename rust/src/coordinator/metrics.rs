//! Serving metrics: latency histogram, counters, per-path accounting,
//! and the modeled power/energy telemetry of the power-aware loop.

use std::time::Duration;

use crate::power::PathEnergy;

/// Log-bucketed latency histogram (microsecond resolution, ~7 decades).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// bucket i covers [2^i, 2^(i+1)) microseconds
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: vec![0; 40], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl Histogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Fold another histogram into this one. Buckets are fixed-width
    /// power-of-two bins shared by construction, so the merge is exact:
    /// counts, means and bucket-quantiles match a histogram that had
    /// recorded both streams directly.
    pub fn merge(&mut self, other: &Histogram) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((self.count as f64) * q.clamp(0.0, 1.0)).ceil() as u64;
        let mut seen = 0;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }

    /// Exact bucket-interpolated quantile (microseconds): the bucket
    /// holding the target rank is interpolated linearly between its
    /// `[2^i, 2^(i+1))` bounds by the rank's position among that
    /// bucket's samples, and the result is capped at the observed
    /// maximum — so a single-sample bucket reports the sample's bucket
    /// ceiling-or-max instead of jumping a full power of two like
    /// [`quantile_us`]. Deterministic and merge-exact (the buckets
    /// are).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((self.count as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            if seen + n >= target {
                let lo = (1u64 << i) as f64;
                let hi = (1u64 << (i + 1)) as f64;
                let frac = (target - seen) as f64 / n as f64;
                return (lo + (hi - lo) * frac).min(self.max_us as f64);
            }
            seen += n;
        }
        self.max_us as f64
    }
}

/// Aggregated serving-run metrics.
#[derive(Debug, Clone, Default)]
pub struct ServingMetrics {
    pub requests: u64,
    pub batches: u64,
    pub frames_by_path: std::collections::BTreeMap<String, u64>,
    pub queue_latency: Histogram,
    pub exec_latency: Histogram,
    pub e2e_latency: Histogram,
    pub morph_switches: u64,
    pub stall_frames: u64,
    /// modeled FPGA energy integral (J) over the run
    pub energy_j: f64,
    /// modeled energy per morph path (mJ) — the Figs. 11-12 breakdown
    pub energy_mj_by_path: std::collections::BTreeMap<String, f64>,
    /// Σ power x modeled busy time (mW·ms): mean power = this / modeled_ms
    pub power_mw_ms: f64,
    /// modeled FPGA busy time (ms) the energy integral covers
    pub modeled_ms: f64,
    // --- fault telemetry (all pure sums: merge stays associative) ---
    /// faults injected by the `--fault-trace` engine
    pub faults_injected: u64,
    /// request re-executions after a transient failure
    pub retries: u64,
    /// requests terminally failed on deadline expiry
    pub timeouts: u64,
    /// requests that exhausted retries (terminal `Failed`)
    pub failed_requests: u64,
    /// requests answered on a corrupted/misrouted path (`Degraded`)
    pub degraded_requests: u64,
    /// DPR swaps that failed mid-window and rolled back
    pub swaps_rolled_back: u64,
    /// SEUs detected and repaired by the CRC scrubber
    pub scrub_repairs: u64,
    /// Σ time-to-recovery (ms) over `recoveries` healing events
    pub recovery_ms_sum: f64,
    /// healing events (scrub repairs + recovered retries)
    pub recoveries: u64,
}

impl ServingMetrics {
    pub fn record_batch(
        &mut self,
        path: &str,
        batch: usize,
        queue: Duration,
        exec: Duration,
    ) {
        self.batches += 1;
        self.requests += batch as u64;
        *self.frames_by_path.entry(path.to_string()).or_insert(0) += batch as u64;
        self.queue_latency.record(queue);
        self.exec_latency.record(exec);
        self.e2e_latency.record(queue + exec);
    }

    /// Account `frames` executed on a path with the given energy row:
    /// the per-inference energy integral of the power-aware loop.
    pub fn record_energy(&mut self, e: &PathEnergy, frames: usize) {
        let f = frames as f64;
        let mj = f * e.energy_mj_per_frame();
        *self.energy_mj_by_path.entry(e.name.clone()).or_insert(0.0) += mj;
        self.energy_j += mj / 1000.0;
        self.power_mw_ms += f * e.frame_ms * e.power_mw;
        self.modeled_ms += f * e.frame_ms;
    }

    /// Modeled energy over the run, mJ.
    pub fn energy_mj(&self) -> f64 {
        self.energy_mj_by_path.values().sum()
    }

    /// Time-weighted mean modeled power (mW) while frames executed.
    pub fn mean_power_mw(&self) -> f64 {
        if self.modeled_ms == 0.0 {
            0.0
        } else {
            self.power_mw_ms / self.modeled_ms
        }
    }

    /// Fold another shard's metrics into this one (cross-shard
    /// aggregation at coordinator shutdown). Associative up to f64
    /// rounding: every field is a sum, count-merge or max.
    pub fn merge(&mut self, other: &ServingMetrics) {
        self.requests += other.requests;
        self.batches += other.batches;
        for (path, n) in &other.frames_by_path {
            *self.frames_by_path.entry(path.clone()).or_insert(0) += n;
        }
        self.queue_latency.merge(&other.queue_latency);
        self.exec_latency.merge(&other.exec_latency);
        self.e2e_latency.merge(&other.e2e_latency);
        self.morph_switches += other.morph_switches;
        self.stall_frames += other.stall_frames;
        self.energy_j += other.energy_j;
        for (path, mj) in &other.energy_mj_by_path {
            *self.energy_mj_by_path.entry(path.clone()).or_insert(0.0) += mj;
        }
        self.power_mw_ms += other.power_mw_ms;
        self.modeled_ms += other.modeled_ms;
        self.faults_injected += other.faults_injected;
        self.retries += other.retries;
        self.timeouts += other.timeouts;
        self.failed_requests += other.failed_requests;
        self.degraded_requests += other.degraded_requests;
        self.swaps_rolled_back += other.swaps_rolled_back;
        self.scrub_repairs += other.scrub_repairs;
        self.recovery_ms_sum += other.recovery_ms_sum;
        self.recoveries += other.recoveries;
    }

    /// Mean time-to-recovery (ms) across healing events: how long an
    /// injected fault stayed live before a scrub/retry repaired it.
    pub fn mean_time_to_recovery_ms(&self) -> f64 {
        if self.recoveries == 0 {
            0.0
        } else {
            self.recovery_ms_sum / self.recoveries as f64
        }
    }

    pub fn throughput_fps(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.requests as f64 / wall.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_quantiles() {
        let mut h = Histogram::default();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        assert!(h.mean_us() > 1000.0);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert_eq!(h.max_us(), 100_000);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn histogram_merge_is_exact() {
        // two shards recording disjoint streams must merge into exactly
        // the histogram of the combined stream
        let mut combined = Histogram::default();
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        for (i, us) in [5u64, 17, 90, 400, 2_000, 9_000, 65_000, 900_000]
            .iter()
            .enumerate()
        {
            combined.record(Duration::from_micros(*us));
            if i % 2 == 0 {
                a.record(Duration::from_micros(*us));
            } else {
                b.record(Duration::from_micros(*us));
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), combined.count());
        assert_eq!(a.max_us(), combined.max_us());
        assert!((a.mean_us() - combined.mean_us()).abs() < 1e-9);
        for q in [0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.quantile_us(q), combined.quantile_us(q), "q={q}");
            // interpolated quantiles are merge-exact too (same buckets)
            assert!((a.quantile(q) - combined.quantile(q)).abs() < 1e-12, "q={q}");
        }
    }

    #[test]
    fn interpolated_quantile_pins_known_streams() {
        // 4 identical 1000 us samples land in bucket 9 = [512, 1024):
        // rank interpolation walks the bucket linearly, capped at max
        let mut h = Histogram::default();
        for _ in 0..4 {
            h.record(Duration::from_micros(1000));
        }
        assert_eq!(h.quantile(0.25), 640.0); // 512 + 512 * 1/4
        assert_eq!(h.quantile(0.5), 768.0); // 512 + 512 * 2/4
        assert_eq!(h.quantile(1.0), 1000.0); // 1024 capped at max_us

        // one sample per bucket: the rank's bucket ceiling, max-capped
        let mut m = Histogram::default();
        for us in [10u64, 20, 40, 80] {
            m.record(Duration::from_micros(us));
        }
        assert_eq!(m.quantile(0.5), 32.0);
        assert_eq!(m.quantile(0.75), 64.0);
        assert_eq!(m.quantile(1.0), 80.0);

        // tail quantiles on a 100-sample stream with one outlier
        let mut t = Histogram::default();
        for _ in 0..99 {
            t.record(Duration::from_micros(100));
        }
        t.record(Duration::from_micros(10_000));
        assert_eq!(t.quantile(0.99), 128.0); // rank 99 fills bucket [64,128)
        assert_eq!(t.quantile(0.999), 10_000.0); // rank 100 is the capped outlier

        // interpolation never exceeds the bucket-ceiling approximation
        for q in [0.5, 0.9, 0.99, 0.999] {
            assert!(t.quantile(q) <= t.quantile_us(q) as f64, "q={q}");
        }
        assert_eq!(Histogram::default().quantile(0.99), 0.0);
    }

    #[test]
    fn metrics_merge_preserves_counts() {
        let mut a = ServingMetrics::default();
        a.record_batch("d3_w100", 8, Duration::from_micros(50), Duration::from_micros(200));
        a.morph_switches = 1;
        a.energy_j = 0.25;
        let mut b = ServingMetrics::default();
        b.record_batch("d3_w100", 4, Duration::from_micros(10), Duration::from_micros(90));
        b.record_batch("d1_w100", 1, Duration::from_micros(20), Duration::from_micros(30));
        b.stall_frames = 2;
        b.energy_j = 0.5;
        a.merge(&b);
        assert_eq!(a.requests, 13);
        assert_eq!(a.batches, 3);
        assert_eq!(a.frames_by_path["d3_w100"], 12);
        assert_eq!(a.frames_by_path["d1_w100"], 1);
        assert_eq!(a.e2e_latency.count(), 3);
        assert_eq!(a.morph_switches, 1);
        assert_eq!(a.stall_frames, 2);
        assert!((a.energy_j - 0.75).abs() < 1e-12);
    }

    #[test]
    fn energy_telemetry_records_and_merges() {
        let row = |name: &str, power_mw: f64, frame_ms: f64| PathEnergy {
            name: name.into(),
            activity: crate::power::Activity::default(),
            power_mw,
            frame_ms,
        };
        let full = row("d3_w100", 800.0, 2.0);
        let light = row("d1_w100", 500.0, 0.5);
        let mut a = ServingMetrics::default();
        a.record_energy(&full, 10); // 10 x 1.6 mJ
        let mut b = ServingMetrics::default();
        b.record_energy(&light, 4); // 4 x 0.25 mJ
        a.merge(&b);
        assert!((a.energy_mj() - (16.0 + 1.0)).abs() < 1e-9);
        assert!((a.energy_j - a.energy_mj() / 1000.0).abs() < 1e-12);
        assert!((a.energy_mj_by_path["d3_w100"] - 16.0).abs() < 1e-9);
        assert!((a.energy_mj_by_path["d1_w100"] - 1.0).abs() < 1e-9);
        // time-weighted mean power: (10*2*800 + 4*0.5*500) / (20 + 2)
        let want = (10.0 * 2.0 * 800.0 + 4.0 * 0.5 * 500.0) / 22.0;
        assert!((a.mean_power_mw() - want).abs() < 1e-9, "{}", a.mean_power_mw());
        // empty metrics report zero power, not NaN
        assert_eq!(ServingMetrics::default().mean_power_mw(), 0.0);
    }

    #[test]
    fn fault_telemetry_merges_as_sums() {
        let mut a = ServingMetrics::default();
        a.faults_injected = 3;
        a.retries = 2;
        a.scrub_repairs = 1;
        a.recovery_ms_sum = 3.0;
        a.recoveries = 1;
        let mut b = ServingMetrics::default();
        b.faults_injected = 1;
        b.timeouts = 1;
        b.failed_requests = 1;
        b.degraded_requests = 4;
        b.swaps_rolled_back = 1;
        b.recovery_ms_sum = 1.0;
        b.recoveries = 1;
        a.merge(&b);
        assert_eq!(a.faults_injected, 4);
        assert_eq!(a.retries, 2);
        assert_eq!(a.timeouts, 1);
        assert_eq!(a.failed_requests, 1);
        assert_eq!(a.degraded_requests, 4);
        assert_eq!(a.swaps_rolled_back, 1);
        assert_eq!(a.scrub_repairs, 1);
        assert!((a.mean_time_to_recovery_ms() - 2.0).abs() < 1e-12);
        // empty metrics report zero MTTR, not NaN
        assert_eq!(ServingMetrics::default().mean_time_to_recovery_ms(), 0.0);
    }

    #[test]
    fn metrics_accumulate() {
        let mut m = ServingMetrics::default();
        m.record_batch("d3_w100", 8, Duration::from_micros(50), Duration::from_micros(200));
        m.record_batch("d1_w100", 1, Duration::from_micros(10), Duration::from_micros(20));
        assert_eq!(m.requests, 9);
        assert_eq!(m.batches, 2);
        assert_eq!(m.frames_by_path["d3_w100"], 8);
        let fps = m.throughput_fps(Duration::from_secs(1));
        assert!((fps - 9.0).abs() < 1e-9);
    }
}
