//! The ForgeMorph serving coordinator (L3 leader) — sharded edition.
//!
//! The engine owns N worker shards. Each shard runs its own
//! [`crate::backend::InferenceBackend`] instance (PJRT executables are
//! thread-local — each backend is created *inside* its worker thread)
//! and its own [`BatchPolicy`]. Requests land in per-shard queues
//! (round-robin) and idle workers steal ready batches from their
//! neighbours, so one hot shard never caps throughput.
//!
//! The NeuroMorph [`Governor`] is **shared state** (`Arc<Mutex<_>>`),
//! consulted by every shard between batches (never mid-batch): morph
//! decisions stay globally consistent — all shards execute the same
//! active path, and a budget squeeze downshifts the whole fleet at once.
//! Per-shard [`ServingMetrics`] merge into one run report at shutdown.

pub mod batcher;
pub mod metrics;
pub mod trace;

pub use batcher::BatchPolicy;
pub use metrics::{Histogram, ServingMetrics};

// re-exported for compatibility: the cost-table builder moved to the
// backend layer with the rest of the sim-serving glue
pub use crate::backend::sim_path_costs;

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::backend::{BackendSpec, InferenceBackend as _};
use crate::fault::{FaultDirective, FaultPlan, FaultRecord, HealthBoard, Injector, RetryPolicy};
use crate::morph::governor::{Budget, Decision, Governor};
use crate::morph::{schedule, PathRegistry};
use crate::obs::{self, Clock, Name, TraceEntry};
use crate::power::PathEnergy;
use crate::util::rng::Rng;

/// An inference request: one flat NHWC frame.
pub struct Request {
    pub id: u64,
    pub data: Vec<f32>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Response>,
    /// morph path pre-selected by the submitter (trace-replay mode): the
    /// worker executes exactly this path instead of observing the
    /// governor, so decisions are deterministic for any worker count. A
    /// batch never mixes pins — the old path drains before a swap.
    pub pinned_path: Option<String>,
    /// injected fault stamp: the executing shard honors it mechanically
    /// (stall, or fail while `attempt < fail_attempts`)
    pub fault: Option<FaultDirective>,
    /// execution attempts already consumed (bumped on every requeue)
    pub attempt: u32,
    /// absolute per-request deadline: expired requests get a terminal
    /// `Failed` response instead of executing
    pub deadline: Option<Instant>,
    /// submit-side verdict that this frame runs on a corrupted/misrouted
    /// path (SEU window): the response reports `Degraded`
    pub degraded: bool,
}

impl Request {
    /// Must this request run in a batch of its own? Stall-injected
    /// stragglers are isolated so the penalty never lands on innocent
    /// batch neighbours.
    pub fn isolating(&self) -> bool {
        self.fault.map(|f| f.isolating()).unwrap_or(false)
    }
}

/// Terminal disposition of a request. Every accepted request gets
/// exactly one `Response`, and this field says which kind: the zero-loss
/// contract the fault tests assert (`ok + degraded + failed == submitted`).
#[derive(Debug, Clone, PartialEq)]
pub enum ResponseStatus {
    /// healthy execution on the intended path
    Ok,
    /// answered, but on a corrupted/misrouted path (SEU window)
    Degraded,
    /// terminally failed: retries exhausted or deadline expired
    Failed { reason: String },
}

impl ResponseStatus {
    pub fn is_ok(&self) -> bool {
        matches!(self, ResponseStatus::Ok)
    }

    pub fn is_failed(&self) -> bool {
        matches!(self, ResponseStatus::Failed { .. })
    }
}

/// The reply: logits + serving telemetry.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub class: usize,
    pub path: String,
    /// worker shard that executed the batch
    pub shard: usize,
    pub queue: Duration,
    pub exec: Duration,
    /// terminal disposition (`Failed` responses carry empty logits)
    pub status: ResponseStatus,
    /// execution attempts consumed (1 = first try succeeded)
    pub attempts: u32,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// batcher flush deadline
    pub max_wait: Duration,
    /// governor hysteresis (observations)
    pub patience: usize,
    /// worker shards (each with its own backend instance)
    pub workers: usize,
    /// hard governor accuracy floor (DistillCycle profile floor or an
    /// application SLO); 0.0 = unconstrained
    pub accuracy_floor: f64,
    /// external budget pacing: morph decisions are made on the submit
    /// side (trace replay) and pinned per request — workers never
    /// observe the governor, so the decision sequence is independent of
    /// worker count. Default `false` = classic batch-paced observation.
    pub external_pacing: bool,
    /// per-request wall-clock deadline: a request still queued past it
    /// gets a terminal `Failed` response instead of executing. `None`
    /// (default) = no deadline.
    pub request_deadline: Option<Duration>,
    /// bounded-retry policy for transient execute failures; retry
    /// instants in the canonical fault log are a pure function of
    /// `(request id, attempt)` under this policy's seed
    pub retry: RetryPolicy,
    /// frames between CRC scrub passes over the gate state during fault
    /// trace replays
    pub scrub_period_frames: usize,
    /// structured span recorder (DESIGN.md §14). `None` (default) =
    /// tracing off; the serving loops then pay exactly one branch per
    /// would-be record, and every log/summary byte matches the untraced
    /// run (test-enforced).
    pub trace: Option<Arc<obs::TraceSink>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_wait: Duration::from_millis(2),
            patience: 2,
            workers: 1,
            accuracy_floor: 0.0,
            external_pacing: false,
            request_deadline: None,
            retry: RetryPolicy::default(),
            scrub_period_frames: 16,
            trace: None,
        }
    }
}

/// Why a coordinator call was rejected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CoordinatorError {
    /// the coordinator has shut down (or never finished starting)
    Closed,
    /// submitted frame length does not match the backend's frame
    BadFrame { got: usize, want: usize },
    /// trace replay on a coordinator whose workers also observe the
    /// governor — the replay would race shard 0's idle observer and
    /// lose its determinism guarantee
    ExternalPacingRequired,
}

impl fmt::Display for CoordinatorError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CoordinatorError::Closed => write!(f, "coordinator is closed"),
            CoordinatorError::BadFrame { got, want } => {
                write!(f, "frame has {got} elements, backend expects {want}")
            }
            CoordinatorError::ExternalPacingRequired => write!(
                f,
                "trace replay needs a coordinator started with \
                 ServeConfig.external_pacing (worker-side governor \
                 observation would race the replay)"
            ),
        }
    }
}

impl std::error::Error for CoordinatorError {}

/// State shared by the submit side and every worker shard.
struct Shared {
    /// per-shard request queues (work-stealing deques)
    queues: Vec<Mutex<VecDeque<Request>>>,
    /// accepting new work? cleared by shutdown / failed startup
    open: AtomicBool,
    /// requests enqueued but not yet taken (incremented *before* push)
    pending: AtomicUsize,
    /// operating budget the governor sees
    budget: Mutex<Budget>,
    /// the shared NeuroMorph governor (installed by shard 0 at startup)
    governor: OnceLock<Mutex<Governor>>,
    /// per-path power/energy rows for the per-inference energy integral
    energy_rows: OnceLock<Vec<PathEnergy>>,
    /// backend frame length, for validating submissions up front
    frame_len: OnceLock<usize>,
    /// workers never observe the governor (submit-side pacing); the
    /// precondition `replay_power_trace` validates
    external_pacing: bool,
    /// per-shard Healthy/Degraded/Quarantined states (live-mode routing
    /// and quarantine only — never consulted on the deterministic
    /// replay-log path)
    health: HealthBoard,
    /// bounded-retry policy for transient execute failures
    retry: RetryPolicy,
    /// per-request deadline applied at submit time
    request_deadline: Option<Duration>,
    /// frames between CRC scrub passes during fault trace replays
    scrub_period_frames: usize,
    /// span recorder: submit side stamps virtual-clock entries on lane
    /// 0, worker shard `s` stamps wall-clock entries on lane `1 + s`
    trace: Option<Arc<obs::TraceSink>>,
    /// sleep/wake for idle workers
    wake: Mutex<()>,
    wake_cv: Condvar,
}

impl Shared {
    fn new(shards: usize, cfg: &ServeConfig) -> Shared {
        Shared {
            queues: (0..shards).map(|_| Mutex::new(VecDeque::new())).collect(),
            open: AtomicBool::new(true),
            pending: AtomicUsize::new(0),
            budget: Mutex::new(Budget::unconstrained()),
            governor: OnceLock::new(),
            energy_rows: OnceLock::new(),
            frame_len: OnceLock::new(),
            external_pacing: cfg.external_pacing,
            health: HealthBoard::new(shards),
            retry: cfg.retry,
            request_deadline: cfg.request_deadline,
            scrub_period_frames: cfg.scrub_period_frames.max(1),
            trace: cfg.trace.clone(),
            wake: Mutex::new(()),
            wake_cv: Condvar::new(),
        }
    }

    fn notify_one(&self) {
        self.wake_cv.notify_one();
    }

    fn notify_all(&self) {
        self.wake_cv.notify_all();
    }

    /// Park briefly until new work may be available.
    fn wait_brief(&self, d: Duration) {
        let guard = self.wake.lock().unwrap();
        let _ = self
            .wake_cv
            .wait_timeout(guard, d.max(Duration::from_micros(200)))
            .unwrap();
    }
}

/// Handle to a running sharded coordinator.
pub struct Coordinator {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<ServingMetrics>>,
    next_id: AtomicU64,
    next_shard: AtomicUsize,
}

impl Coordinator {
    /// Start `cfg.workers` serving shards, each building its own backend
    /// from `spec`. Fails if any shard's backend fails to initialize.
    pub fn start(cfg: ServeConfig, spec: BackendSpec) -> anyhow::Result<Coordinator> {
        let n = cfg.workers.max(1);
        let shared = Arc::new(Shared::new(n, &cfg));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let mut workers = Vec::with_capacity(n);
        for shard_id in 0..n {
            let shared = Arc::clone(&shared);
            let spec = spec.clone();
            let cfg = cfg.clone();
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(shard_id, cfg, spec, shared, ready)
            }));
        }
        drop(ready_tx);

        let mut failure: Option<String> = None;
        for _ in 0..n {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => failure = Some(e),
                Err(_) => failure = Some("worker died during startup".into()),
            }
        }
        if let Some(e) = failure {
            shared.open.store(false, Ordering::Release);
            shared.notify_all();
            for w in workers {
                let _ = w.join();
            }
            anyhow::bail!("backend init failed: {e}");
        }
        Ok(Coordinator {
            shared,
            workers,
            next_id: AtomicU64::new(0),
            next_shard: AtomicUsize::new(0),
        })
    }

    /// Submit one frame; returns the reply receiver, or
    /// [`CoordinatorError::Closed`] once the coordinator has shut down
    /// (previously this silently dropped the request).
    pub fn submit(&self, data: Vec<f32>) -> Result<mpsc::Receiver<Response>, CoordinatorError> {
        self.submit_inner(data, None, None, false)
    }

    /// Submit one frame pinned to a morph path chosen by the caller (the
    /// trace-replay loop). The worker executes exactly this path; pinned
    /// requests drain in submission order across any reconfiguration.
    pub fn submit_pinned(
        &self,
        data: Vec<f32>,
        path: String,
    ) -> Result<mpsc::Receiver<Response>, CoordinatorError> {
        self.submit_inner(data, Some(path), None, false)
    }

    /// Submit one frame carrying an injected fault stamp (live-mode
    /// fault testing: the executing shard honors the directive exactly
    /// as replay-injected ones).
    pub fn submit_with_fault(
        &self,
        data: Vec<f32>,
        fault: FaultDirective,
    ) -> Result<mpsc::Receiver<Response>, CoordinatorError> {
        self.submit_inner(data, None, Some(fault), false)
    }

    fn submit_inner(
        &self,
        data: Vec<f32>,
        pinned_path: Option<String>,
        fault: Option<FaultDirective>,
        degraded: bool,
    ) -> Result<mpsc::Receiver<Response>, CoordinatorError> {
        if !self.shared.open.load(Ordering::Acquire) {
            return Err(CoordinatorError::Closed);
        }
        if let Some(&want) = self.shared.frame_len.get() {
            if data.len() != want {
                return Err(CoordinatorError::BadFrame { got: data.len(), want });
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let shard =
            self.next_shard.fetch_add(1, Ordering::Relaxed) % self.shared.queues.len();
        let (reply, rx) = mpsc::channel();
        // pending is bumped before the push so a racing worker can never
        // drive the counter below zero
        self.shared.pending.fetch_add(1, Ordering::AcqRel);
        self.shared.queues[shard].lock().unwrap().push_back(Request {
            id,
            data,
            enqueued: Instant::now(),
            reply,
            pinned_path,
            fault,
            attempt: 0,
            deadline: self.shared.request_deadline.map(|d| Instant::now() + d),
            degraded,
        });
        if let Some(sink) = &self.shared.trace {
            // wall-clock twin of the replay's virtual enqueue — lives on
            // the quarantined side of the §14 clock rule
            let e = TraceEntry::instant(Clock::Wall, Name::Enqueue, sink.wall_now_us(), id);
            sink.record(0, e);
        }
        self.shared.notify_one();
        Ok(rx)
    }

    /// Per-path power/energy rows the serving engine accounts with
    /// (installed by shard 0 at startup; empty before the first shard is
    /// ready).
    pub fn path_energy_rows(&self) -> Vec<PathEnergy> {
        self.shared.energy_rows.get().cloned().unwrap_or_default()
    }

    /// Update the operating budget the governor sees. Errors once the
    /// coordinator is closed instead of silently doing nothing.
    pub fn set_budget(&self, budget: Budget) -> Result<(), CoordinatorError> {
        if !self.shared.open.load(Ordering::Acquire) {
            return Err(CoordinatorError::Closed);
        }
        *self.shared.budget.lock().unwrap() = budget;
        Ok(())
    }

    /// Worker shard count.
    pub fn workers(&self) -> usize {
        self.shared.queues.len()
    }

    /// Replay a deterministic power/latency budget trace through the
    /// serving stack on a **virtual clock**: frame `i` lands at trace
    /// time `i / rate_hz`, the submit thread feeds the budget in force
    /// to the shared governor (one observation per frame — the only
    /// governor mutations in the run) and pins the resulting path on the
    /// request. Workers drain pinned batches without re-deciding, so the
    /// decision log, per-path frame counts and energy integral are
    /// byte-identical for any worker count and any frame seed
    /// (test-enforced). Morph transitions follow drain→swap→resume:
    /// already-pinned requests finish on the outgoing path, the swap
    /// pays the modeled DPR window ([`schedule::swap_timeline`]), then
    /// the incoming path resumes — no in-flight request is lost.
    ///
    /// Consumes the serving run: the coordinator is shut down (and its
    /// merged metrics returned in the outcome) when the trace ends.
    ///
    /// Requires a coordinator started with
    /// [`ServeConfig::external_pacing`] (enforced — returns
    /// [`CoordinatorError::ExternalPacingRequired`] otherwise):
    /// worker-side observation would race the replay's budget and
    /// re-expand the fleet to the full path between frames.
    pub fn replay_power_trace(
        &mut self,
        events: &[trace::BudgetEvent],
        tcfg: &TraceConfig,
    ) -> Result<TraceOutcome, CoordinatorError> {
        self.replay_trace(events, tcfg, None)
    }

    /// [`replay_power_trace`](Coordinator::replay_power_trace) with an
    /// optional deterministic fault plan (`serve --fault-trace`). The
    /// injector runs entirely on the submit side: it scrubs/corrupts the
    /// gate state, stamps per-request fault directives, arms swap
    /// failures (rollback + cooldown on strike) and feeds virtual-fleet
    /// capacity to the governor — so the canonical fault log, like the
    /// decision log, is byte-identical for any worker count and rerun.
    /// `faults: None` is bit-identical to the pre-fault replay.
    pub fn replay_trace(
        &mut self,
        events: &[trace::BudgetEvent],
        tcfg: &TraceConfig,
        faults: Option<&FaultPlan>,
    ) -> Result<TraceOutcome, CoordinatorError> {
        if !self.shared.open.load(Ordering::Acquire) {
            return Err(CoordinatorError::Closed);
        }
        if !self.shared.external_pacing {
            return Err(CoordinatorError::ExternalPacingRequired);
        }
        // start() returns only after shard 0 installed these
        let governor = self.shared.governor.get().ok_or(CoordinatorError::Closed)?;
        let frame_len = self.shared.frame_len.get().copied().ok_or(CoordinatorError::Closed)?;
        let energy_rows = self.shared.energy_rows.get().cloned().unwrap_or_default();
        // reconfiguration stalls are measured in full-path frame periods
        let full_frame_ms = energy_rows.iter().map(|e| e.frame_ms).fold(0.0, f64::max);
        let rate_hz = tcfg.rate_hz.max(1e-9);
        // pre-intern the ladder so trace path indices are fixed by
        // registry order, never by which thread saw a name first —
        // part of the deterministic-export contract
        if let Some(sink) = &self.shared.trace {
            let gov = governor.lock().unwrap();
            for p in gov.registry().paths() {
                sink.intern(&p.name);
            }
        }

        let injection = faults.is_some();
        let mut injector = faults.map(|plan| {
            let gov = governor.lock().unwrap();
            Injector::new(
                plan,
                gov.registry().paths().len(),
                gov.current_index(),
                rate_hz,
                self.shared.scrub_period_frames,
                self.shared.retry,
            )
        });
        let mut rollbacks = 0u64;

        let mut rng = Rng::new(tcfg.seed);
        let mut receivers = Vec::with_capacity(tcfg.frames);
        let mut switches: Vec<SwitchRecord> = Vec::new();
        let mut seg_acc: Vec<(usize, f64)> = vec![(0, 0.0); events.len().max(1)];
        let mut frames_by_path: BTreeMap<String, usize> = BTreeMap::new();
        let mut energy_mj = 0.0f64;

        for i in 0..tcfg.frames {
            let t = i as f64 / rate_hz;
            let budget = trace::budget_at(events, t);
            // the id submit_inner will assign this frame's request —
            // the replay thread is the only submitter
            let id = self.next_id.load(Ordering::Relaxed) + 1;
            let directive = match injector.as_mut() {
                Some(inj) => {
                    inj.begin_frame(i);
                    let d = inj.directive_for(i, id);
                    // graceful degradation: the governor plans against
                    // the healthy fraction of the (virtual) fleet
                    governor.lock().unwrap().set_capacity(inj.capacity(i));
                    d
                }
                None => None,
            };
            let (path, degraded) = {
                let mut gov = governor.lock().unwrap();
                let from_idx = gov.current_index();
                match gov.observe(&budget) {
                    Decision::Switch { to, stall_frames } => {
                        let fail = injector
                            .as_mut()
                            .map(|inj| inj.swap_should_fail(i))
                            .unwrap_or(false);
                        let attempt = schedule::attempt_swap(
                            stall_frames,
                            full_frame_ms,
                            fail,
                            schedule::ROLLBACK_COOLDOWN_FRAMES,
                        );
                        if attempt.committed {
                            switches.push(SwitchRecord {
                                frame: i,
                                budget_mw: budget.power_mw,
                                from: gov.registry().paths()[from_idx].name.clone(),
                                to,
                                stall_frames,
                                swap_ms: attempt.timeline.swap_ms,
                            });
                            if let Some(inj) = injector.as_mut() {
                                // a committed DPR write refreshes the
                                // scrubbed gate state
                                inj.on_commit(gov.current_index());
                            }
                        } else {
                            // the DPR window opened but never committed:
                            // the outgoing path is still loaded — revert
                            // free of stall, hold through a cooldown
                            let from_name = gov.registry().paths()[from_idx].name.clone();
                            gov.rollback(from_idx);
                            gov.begin_cooldown(attempt.cooldown_frames);
                            rollbacks += 1;
                            if let Some(inj) = injector.as_mut() {
                                inj.record_rollback(
                                    i,
                                    from_name,
                                    to,
                                    attempt.timeline.swap_ms,
                                    attempt.cooldown_frames,
                                );
                            }
                        }
                    }
                    Decision::Hold => {}
                }
                let chosen = gov.current_index();
                // SEU window: corrupted gate state misroutes the frame
                let (actual, degraded) = match injector.as_mut() {
                    Some(inj) => inj.route(i, chosen),
                    None => (chosen, false),
                };
                (gov.registry().paths()[actual].name.clone(), degraded)
            };
            if let Some(e) = energy_rows.iter().find(|e| e.name == path) {
                let seg = trace::segment_at(events, t);
                seg_acc[seg].0 += 1;
                seg_acc[seg].1 += e.power_mw;
                energy_mj += e.energy_mj_per_frame();
            }
            *frames_by_path.entry(path.clone()).or_insert(0) += 1;
            if let Some(sink) = &self.shared.trace {
                // virtual-clock request lifecycle: enqueue instant at
                // the frame's trace time, execute span over the path's
                // modeled frame period — submit-side only, so the
                // entries are worker-invariant like the decision log
                let ts = obs::virtual_us(i, rate_hz);
                let p = sink.intern(&path);
                sink.record(
                    0,
                    TraceEntry::instant(Clock::Virtual, Name::Enqueue, ts, id)
                        .with_path(p)
                        .with_args(0, u64::from(degraded)),
                );
                let dur = energy_rows
                    .iter()
                    .find(|e| e.name == path)
                    .map(|e| (e.frame_ms * 1_000.0).round() as u64)
                    .unwrap_or(0);
                sink.record(
                    0,
                    TraceEntry::span(Clock::Virtual, Name::Execute, ts, dur, id).with_path(p),
                );
            }
            let data: Vec<f32> = (0..frame_len).map(|_| rng.f64() as f32).collect();
            receivers.push(self.submit_inner(data, Some(path), directive, degraded)?);
        }

        // drain every response: reconfigurations and injected faults
        // must not lose requests — every submission resolves terminally
        let mut answered = 0usize;
        let (mut ok, mut degraded, mut failed) = (0usize, 0usize, 0usize);
        for rx in receivers {
            if let Ok(resp) = rx.recv_timeout(Duration::from_secs(60)) {
                answered += 1;
                match resp.status {
                    ResponseStatus::Ok => ok += 1,
                    ResponseStatus::Degraded => degraded += 1,
                    ResponseStatus::Failed { .. } => failed += 1,
                }
            }
        }
        let mut metrics = self.shutdown();
        // fold the submit-side decisions into the run telemetry (workers
        // never observed, so their counters carry none of them)
        metrics.morph_switches += switches.len() as u64;
        metrics.stall_frames += switches.iter().map(|s| s.stall_frames as u64).sum::<u64>();
        metrics.swaps_rolled_back += rollbacks;
        let fault_records = match injector {
            Some(inj) => {
                let stats = inj.stats();
                metrics.faults_injected += stats.faults_injected;
                metrics.scrub_repairs += stats.scrub_repairs;
                metrics.recovery_ms_sum += stats.recovery_ms_sum;
                metrics.recoveries += stats.recoveries;
                inj.into_records()
            }
            None => Vec::new(),
        };

        // stamp the submit-side governor/fault history onto the virtual
        // clock: one switch instant + DPR swap-window span per commit,
        // and every fault-log record via `fault::record_trace` (SEUs,
        // scrub-repair MTTR spans, transient retry ladders, stalls,
        // rollback windows). All derived from worker-invariant state.
        if let Some(sink) = &self.shared.trace {
            for sw in &switches {
                let ts = obs::virtual_us(sw.frame, rate_hz);
                let to = sink.intern(&sw.to);
                let from = sink.intern(&sw.from);
                let bmw = sw
                    .budget_mw
                    .filter(|b| b.is_finite())
                    .map(|b| b.max(0.0).round() as u64)
                    .unwrap_or(0);
                sink.record(
                    0,
                    TraceEntry::instant(Clock::Virtual, Name::Switch, ts, sw.frame as u64)
                        .with_path(to)
                        .with_args(u64::from(from), bmw),
                );
                let window = schedule::SwapTimeline {
                    stall_frames: sw.stall_frames,
                    swap_ms: sw.swap_ms,
                };
                sink.record(
                    0,
                    TraceEntry::span(
                        Clock::Virtual,
                        Name::SwapWindow,
                        ts,
                        window.window_us(),
                        sw.frame as u64,
                    )
                    .with_path(to)
                    .with_args(sw.stall_frames as u64, 0),
                );
            }
            crate::fault::record_trace(&fault_records, rate_hz, sink);
        }

        let segments = events
            .iter()
            .enumerate()
            .map(|(k, e)| SegmentPower {
                start_s: e.at_s,
                budget_mw: e.budget.power_mw,
                frames: seg_acc[k].0,
                mean_power_mw: if seg_acc[k].0 == 0 {
                    0.0
                } else {
                    seg_acc[k].1 / seg_acc[k].0 as f64
                },
            })
            .collect();
        Ok(TraceOutcome {
            switches,
            segments,
            frames_by_path,
            energy_mj,
            answered,
            metrics,
            injection,
            faults: fault_records,
            submitted: tcfg.frames,
            ok,
            degraded,
            failed,
        })
    }

    /// Stop accepting work, drain every in-flight request, and return
    /// the metrics of all shards merged. Idempotent: a second call
    /// returns empty metrics.
    pub fn shutdown(&mut self) -> ServingMetrics {
        self.shared.open.store(false, Ordering::Release);
        self.shared.notify_all();
        let mut merged = ServingMetrics::default();
        let mut panicked = 0usize;
        for w in self.workers.drain(..) {
            match w.join() {
                Ok(m) => merged.merge(&m),
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "unknown panic".into());
                    eprintln!("[coordinator] worker shard panicked: {msg}");
                    panicked += 1;
                }
            }
        }
        // surface the failure loudly (matching the pre-refactor
        // `.expect("worker panicked")`) unless we are already unwinding —
        // a panic inside Drop during unwind would abort the process
        if panicked > 0 && !std::thread::panicking() {
            panic!("{panicked} worker shard(s) panicked; metrics incomplete");
        }
        merged
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if !self.workers.is_empty() {
            self.shutdown();
        }
    }
}

/// Virtual-clock trace-replay configuration
/// ([`Coordinator::replay_power_trace`]).
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// frames to submit over the trace
    pub frames: usize,
    /// virtual frame rate mapping frame index -> trace time
    pub rate_hz: f64,
    /// frame-content seed; must not affect decisions (test-enforced)
    pub seed: u64,
}

/// One morph transition recorded during a trace replay.
#[derive(Debug, Clone, PartialEq)]
pub struct SwitchRecord {
    /// frame index the switch fired at
    pub frame: usize,
    /// power budget in force when it fired
    pub budget_mw: Option<f64>,
    pub from: String,
    pub to: String,
    /// reactivation stall (frames); 0 on down-shifts
    pub stall_frames: usize,
    /// modeled DPR/reconfiguration window, ms
    pub swap_ms: f64,
}

/// Mean modeled power over one trace segment (between budget events).
#[derive(Debug, Clone)]
pub struct SegmentPower {
    pub start_s: f64,
    pub budget_mw: Option<f64>,
    pub frames: usize,
    pub mean_power_mw: f64,
}

/// Everything a trace replay produces: the decision log, per-segment
/// power, per-path frame counts, the energy integral and the merged
/// serving metrics of the (shut-down) coordinator.
pub struct TraceOutcome {
    pub switches: Vec<SwitchRecord>,
    pub segments: Vec<SegmentPower>,
    pub frames_by_path: BTreeMap<String, usize>,
    /// modeled energy over the replay (mJ), from the pinned-path rows
    pub energy_mj: f64,
    /// responses actually received (must equal `TraceConfig::frames`)
    pub answered: usize,
    pub metrics: ServingMetrics,
    /// was a fault plan active? (gates the fault lines in the summary so
    /// fault-free replays render byte-identically to the pre-fault code)
    pub injection: bool,
    /// canonical submit-side fault records, in frame order
    pub faults: Vec<FaultRecord>,
    /// frames submitted (`TraceConfig::frames`)
    pub submitted: usize,
    /// terminal dispositions: `ok + degraded + failed == answered`, and
    /// the zero-loss contract demands `answered == submitted`
    pub ok: usize,
    pub degraded: usize,
    pub failed: usize,
}

impl TraceOutcome {
    /// Canonical decision-log text — byte-identical across worker counts
    /// and frame seeds (test-enforced), greppable in CI.
    pub fn decision_log(&self) -> String {
        let mut s = String::new();
        for r in &self.switches {
            let budget = r
                .budget_mw
                .map(|b| format!("{b:.0} mW"))
                .unwrap_or_else(|| "none".into());
            let _ = writeln!(
                s,
                "[frame {:05}] budget {budget}: switch {} -> {} (stall {}, swap {:.3} ms)",
                r.frame, r.from, r.to, r.stall_frames, r.swap_ms
            );
        }
        s
    }

    /// Canonical fault-log text — like the decision log, a pure function
    /// of (trace, fault plan, seeds): byte-identical across worker
    /// counts and reruns (test-enforced), greppable in CI.
    pub fn fault_log(&self) -> String {
        let mut s = String::new();
        for r in &self.faults {
            let _ = writeln!(s, "{r}");
        }
        s
    }

    /// Human-readable per-segment power table + squeeze summary — the
    /// ONE rendering shared by `serve --power-trace` and `report power`
    /// (CI greps the "power reduction after squeeze" line).
    pub fn render_summary(&self) -> String {
        let mut s = String::new();
        for seg in &self.segments {
            let budget = seg
                .budget_mw
                .map(|b| format!("{b:.0} mW"))
                .unwrap_or_else(|| "none".into());
            let _ = writeln!(
                s,
                "segment t={:>6.3}s budget {budget:>8}: {:>5} frames, mean power {:>7.1} mW",
                seg.start_s, seg.frames, seg.mean_power_mw
            );
        }
        if let Some(r) = self.squeeze_reduction_pct() {
            let _ = writeln!(s, "power reduction after squeeze: {r:.1}%");
        }
        for (path, n) in &self.frames_by_path {
            let _ = writeln!(s, "  path {path}: {n} frames");
        }
        let _ = writeln!(
            s,
            "modeled energy {:.2} mJ | {} switches ({} stall frames) | answered {}",
            self.energy_mj,
            self.switches.len(),
            self.metrics.stall_frames,
            self.answered
        );
        if self.injection {
            let m = &self.metrics;
            let _ = writeln!(
                s,
                "faults injected {} | retries {} | timeouts {} | swaps rolled back {} | \
                 scrub repairs {} | mttr {:.3} ms",
                m.faults_injected,
                m.retries,
                m.timeouts,
                m.swaps_rolled_back,
                m.scrub_repairs,
                m.mean_time_to_recovery_ms()
            );
            let lost = self.submitted.saturating_sub(self.answered);
            let _ = writeln!(
                s,
                "terminal: {} ok / {} degraded / {} failed of {} submitted ({lost} lost)",
                self.ok, self.degraded, self.failed, self.submitted
            );
        }
        s
    }

    /// Modeled power reduction (%) from the first unconstrained segment
    /// that served frames to the tightest-budget segment — the paper's
    /// Figs. 11-12 down-shift number.
    pub fn squeeze_reduction_pct(&self) -> Option<f64> {
        let base = self
            .segments
            .iter()
            .find(|s| s.budget_mw.is_none() && s.frames > 0)?;
        let tight = self
            .segments
            .iter()
            .filter(|s| s.budget_mw.is_some() && s.frames > 0)
            .min_by(|a, b| a.budget_mw.partial_cmp(&b.budget_mw).unwrap())?;
        if base.mean_power_mw <= 0.0 {
            return None;
        }
        Some((1.0 - tight.mean_power_mw / base.mean_power_mw) * 100.0)
    }
}

/// How often shard 0 tracks the budget while the fleet is idle — the
/// pre-refactor single worker's poll cadence, so a squeeze applied in a
/// traffic lull still downshifts within ~patience x 5ms.
const IDLE_OBSERVE_PERIOD: Duration = Duration::from_millis(5);

/// Feed one budget observation to the shared governor, record any
/// switch in this shard's metrics, and return the now-active path.
fn observe_governor(
    governor: &Mutex<Governor>,
    shared: &Shared,
    metrics: &mut ServingMetrics,
) -> String {
    let budget = *shared.budget.lock().unwrap();
    let mut gov = governor.lock().unwrap();
    match gov.observe(&budget) {
        Decision::Switch { stall_frames, .. } => {
            metrics.morph_switches += 1;
            metrics.stall_frames += stall_frames as u64;
        }
        Decision::Hold => {}
    }
    gov.current().to_string()
}

/// Pop a ready batch: own queue first, then steal from neighbours.
/// `force` (shutdown drain) flushes partial batches without waiting out
/// the batch deadline — pinned runs still split at path boundaries, so
/// a shutdown landing mid drain→swap still completes the pinned-run
/// timeline instead of stranding the incoming path's requests.
fn take_batch(
    shared: &Shared,
    own: usize,
    policy: &BatchPolicy,
    force: bool,
) -> Option<Vec<Request>> {
    let n = shared.queues.len();
    let now = Instant::now();
    for k in 0..n {
        let qi = (own + k) % n;
        let mut q = shared.queues[qi].lock().unwrap();
        let decided = if force {
            if q.is_empty() {
                None
            } else {
                Some(policy.max_size())
            }
        } else {
            policy.decide(q.len(), q.front().map(|r| r.enqueued), now)
        };
        if let Some(size) = decided {
            // a batch never straddles a pinned-path boundary: the old
            // path drains before the swap (drain→swap→resume)
            let take = batcher::pop_pinned_run(&mut q, size.min(q.len()));
            drop(q);
            if !take.is_empty() {
                shared.pending.fetch_sub(take.len(), Ordering::AcqRel);
                return Some(take);
            }
        }
    }
    None
}

/// Terminal `Failed` response: empty logits, the reason in the status.
/// Part of the zero-loss contract — a request that cannot execute is
/// answered explicitly, never silently dropped.
fn send_failed(r: &Request, shard: usize, reason: String, attempts: u32) {
    let _ = r.reply.send(Response {
        id: r.id,
        logits: Vec::new(),
        class: 0,
        path: r.pinned_path.clone().unwrap_or_default(),
        shard,
        queue: r.enqueued.elapsed(),
        exec: Duration::ZERO,
        status: ResponseStatus::Failed { reason },
        attempts,
    });
}

/// Bounded-retry ladder: requeue the request (attempt bumped) on the
/// next healthy shard, or answer terminally once retries are exhausted.
/// Either way the submitter's receiver resolves.
fn retry_or_fail(
    shared: &Shared,
    shard_id: usize,
    metrics: &mut ServingMetrics,
    mut r: Request,
    reason: &str,
) {
    if r.attempt < shared.retry.max_retries {
        r.attempt += 1;
        metrics.retries += 1;
        if let Some(sink) = &shared.trace {
            // wall-clock rung of the retry ladder (the deterministic
            // twin comes from the injector's transient records)
            let e = TraceEntry::instant(Clock::Wall, Name::Retry, sink.wall_now_us(), r.id)
                .with_args(u64::from(r.attempt), 0);
            sink.record(1 + shard_id, e);
        }
        // resubmission prefers the next healthy shard so a sick shard
        // does not immediately re-execute its own casualty
        let target = shared.health.next_healthy(shard_id + 1);
        shared.pending.fetch_add(1, Ordering::AcqRel);
        shared.queues[target].lock().unwrap().push_back(r);
        shared.notify_one();
    } else {
        metrics.failed_requests += 1;
        send_failed(&r, shard_id, reason.to_string(), r.attempt + 1);
    }
}

fn worker_loop(
    shard_id: usize,
    cfg: ServeConfig,
    spec: BackendSpec,
    shared: Arc<Shared>,
    ready: mpsc::Sender<Result<(), String>>,
) -> ServingMetrics {
    let mut backend = match spec.build() {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            return ServingMetrics::default();
        }
    };
    if shard_id == 0 {
        let registry = PathRegistry::new(backend.morph_paths());
        let costs = backend.path_costs();
        let _ = shared.frame_len.set(backend.frame_len());
        let _ = shared.energy_rows.set(backend.path_energy());
        let _ = shared.governor.set(Mutex::new(
            Governor::new(registry, costs, cfg.patience).with_accuracy_floor(cfg.accuracy_floor),
        ));
    }
    let _ = ready.send(Ok(()));
    // drop the handshake sender now: if another shard panics before its
    // own send, start() sees the channel disconnect instead of hanging
    drop(ready);

    // wait for shard 0 to install the shared governor
    let governor = loop {
        if let Some(g) = shared.governor.get() {
            break g;
        }
        if !shared.open.load(Ordering::Acquire) {
            return ServingMetrics::default();
        }
        std::thread::sleep(Duration::from_micros(200));
    };
    let energy_rows = shared.energy_rows.get().cloned().unwrap_or_default();
    // wall-clock (quarantined) span recording on this shard's own lane
    let sink = shared.trace.clone();
    let policy = BatchPolicy::new(backend.batch_sizes(), cfg.max_wait);
    let frame = backend.frame_len();
    let nc = backend.num_classes();
    let mut metrics = ServingMetrics::default();
    let mut last_idle_observe = Instant::now();

    loop {
        let open = shared.open.load(Ordering::Acquire);

        let Some(take) = take_batch(&shared, shard_id, &policy, !open) else {
            if !open && shared.pending.load(Ordering::Acquire) == 0 {
                break;
            }
            // budget changes must bite during traffic lulls too; shard 0
            // alone polls at the single-worker cadence so idle spinning
            // across N shards does not dilute the patience hysteresis.
            // Externally paced serving never observes from the workers —
            // the submit side owns every governor mutation.
            if shard_id == 0
                && !cfg.external_pacing
                && last_idle_observe.elapsed() >= IDLE_OBSERVE_PERIOD
            {
                let _ = observe_governor(governor, &shared, &mut metrics);
                last_idle_observe = Instant::now();
            }
            // quarantined shard? after the dwell, a cheap backend
            // self-check releases it back to Degraded duty
            if shared.health.probe_due(shard_id) && backend.probe().is_ok() {
                shared.health.release(shard_id);
            }
            shared.wait_brief(cfg.max_wait / 2);
            continue;
        };

        // expired deadlines never execute: answer them terminally first
        let now = Instant::now();
        let (expired, take): (Vec<Request>, Vec<Request>) = take
            .into_iter()
            .partition(|r| r.deadline.map(|d| now >= d).unwrap_or(false));
        for r in expired {
            metrics.timeouts += 1;
            metrics.failed_requests += 1;
            send_failed(&r, shard_id, "deadline exceeded".into(), r.attempt);
        }
        if take.is_empty() {
            continue;
        }
        // a run cut short at a pin boundary (or by expiry) re-fits to
        // the smallest covering menu size instead of padding all the
        // way to the pre-split decision
        let size = policy.cover(take.len());

        // morph decision between batches (never mid-batch), paced by
        // batch execution so `patience` keeps its meaning regardless of
        // worker count. The governor is shared, so the whole fleet
        // tracks one active path. Pinned requests (trace replay) carry
        // their decision with them; externally paced unpinned requests
        // read the active path without observing.
        let path = match take[0].pinned_path.as_ref() {
            Some(p) => p.clone(),
            None if cfg.external_pacing => governor.lock().unwrap().current().to_string(),
            None => observe_governor(governor, &shared, &mut metrics),
        };

        let mut input = Vec::with_capacity(size * frame);
        for r in &take {
            input.extend_from_slice(&r.data);
        }
        // pad the tail of a short batch by repeating the last frame
        // (submit() validated lengths, so input is a nonzero multiple
        // of `frame` here)
        while input.len() < size * frame {
            let start = input.len() - frame;
            input.extend_from_within(start..);
        }

        // injected straggler stall: burn the delay before executing (the
        // batcher isolated it in a batch of its own, so no innocent
        // neighbour pays the penalty)
        let stall_ms = take
            .iter()
            .filter_map(|r| r.fault.map(|f| f.stall_ms))
            .fold(0.0f64, f64::max);
        if stall_ms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(stall_ms / 1000.0));
        }

        let batch_len = take.len();
        let oldest = take[0].enqueued;
        let first_id = take[0].id;
        let t0 = Instant::now();
        match backend.execute(&path, size, &input) {
            Ok(logits) => {
                let exec = t0.elapsed();
                if let Some(sink) = &sink {
                    let exec_us = exec.as_micros() as u64;
                    let start = sink.wall_now_us().saturating_sub(exec_us);
                    let p = sink.intern(&path);
                    sink.record(
                        1 + shard_id,
                        TraceEntry::instant(Clock::Wall, Name::Batch, start, first_id)
                            .with_path(p)
                            .with_args(batch_len as u64, shard_id as u64),
                    );
                    sink.record(
                        1 + shard_id,
                        TraceEntry::span(Clock::Wall, Name::Execute, start, exec_us, first_id)
                            .with_path(p)
                            .with_args(batch_len as u64, shard_id as u64),
                    );
                }
                let classes = backend.argmax(&logits);
                let mut delivered = 0usize;
                for (i, r) in take.into_iter().enumerate() {
                    // transient-fault stamp: this request fails while its
                    // attempt counter is below the injected threshold —
                    // the retry ladder resubmits it to a healthy shard
                    let inject_fail =
                        r.fault.map(|f| r.attempt < f.fail_attempts).unwrap_or(false);
                    if inject_fail {
                        // (the submit-side injector owns the
                        // faults_injected counter — the worker only
                        // executes the consequence)
                        shared.health.record_failure(shard_id);
                        retry_or_fail(
                            &shared,
                            shard_id,
                            &mut metrics,
                            r,
                            "injected transient backend error",
                        );
                        continue;
                    }
                    let queue_d = t0.duration_since(r.enqueued);
                    let status = if r.degraded {
                        metrics.degraded_requests += 1;
                        ResponseStatus::Degraded
                    } else {
                        ResponseStatus::Ok
                    };
                    let _ = r.reply.send(Response {
                        id: r.id,
                        logits: logits[i * nc..(i + 1) * nc].to_vec(),
                        class: classes[i],
                        path: path.clone(),
                        shard: shard_id,
                        queue: queue_d,
                        exec,
                        status,
                        attempts: r.attempt + 1,
                    });
                    delivered += 1;
                }
                if delivered > 0 {
                    shared.health.record_success(shard_id);
                }
                if let Some(sink) = &sink {
                    let now = sink.wall_now_us();
                    sink.record(
                        1 + shard_id,
                        TraceEntry::instant(Clock::Wall, Name::Respond, now, first_id)
                            .with_args(delivered as u64, shard_id as u64),
                    );
                }
                let queue_d = t0.duration_since(oldest);
                metrics.record_batch(&path, batch_len, queue_d, exec);
                // modeled FPGA energy for these frames on the active path:
                // E = frames x P_path x T_frame (from the backend's
                // activity-derived energy rows)
                if let Some(e) = energy_rows.iter().find(|e| e.name == path) {
                    metrics.record_energy(e, batch_len);
                }
            }
            Err(e) => {
                // a failed execute no longer drops requests on the floor
                // (callers used to block on a dead channel forever):
                // every request is retried on a healthy shard or
                // answered terminally
                shared.health.record_failure(shard_id);
                let reason = format!("execute failed on {path}: {e}");
                for r in take {
                    retry_or_fail(&shared, shard_id, &mut metrics, r, &reason);
                }
            }
        }
    }
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::DesignConfig;
    use crate::graph::zoo;
    use crate::pe::{FpRep, ZYNQ_7100};

    #[test]
    fn sim_costs_ordered_by_path_weight() {
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
        let reg = PathRegistry::new(crate::morph::tests::sample_paths());
        let costs = sim_path_costs(&net, &design, &ZYNQ_7100, &reg).unwrap();
        assert_eq!(costs.rows.len(), 4);
        let get = |n: &str| costs.rows.iter().find(|(m, _, _)| m == n).unwrap().clone();
        let (_, p_full, l_full) = get("d3_w100");
        let (_, p_d1, l_d1) = get("d1_w100");
        assert!(p_d1 < p_full, "gated power {p_d1} < full {p_full}");
        assert!(l_d1 < l_full, "gated latency {l_d1} < full {l_full}");
    }

    #[test]
    fn submit_and_budget_fail_after_shutdown() {
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 2, FpRep::Int16);
        let spec = BackendSpec::sim(
            net.clone(),
            design,
            ZYNQ_7100,
            crate::morph::depth_ladder(&net),
        );
        let mut coord =
            Coordinator::start(ServeConfig { workers: 2, ..Default::default() }, spec).unwrap();
        assert_eq!(coord.workers(), 2);
        assert!(coord.submit(vec![0.0; 784]).is_ok());
        coord.shutdown();
        assert!(matches!(
            coord.submit(vec![0.0; 784]),
            Err(CoordinatorError::Closed)
        ));
        assert_eq!(
            coord.set_budget(Budget::unconstrained()),
            Err(CoordinatorError::Closed)
        );
    }

    #[test]
    fn submit_rejects_wrong_frame_length() {
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 2, FpRep::Int16);
        let spec = BackendSpec::sim(
            net.clone(),
            design,
            ZYNQ_7100,
            crate::morph::depth_ladder(&net),
        );
        let mut coord = Coordinator::start(ServeConfig::default(), spec).unwrap();
        assert!(matches!(
            coord.submit(vec![0.0; 100]),
            Err(CoordinatorError::BadFrame { got: 100, want: 784 })
        ));
        assert!(matches!(
            coord.submit(vec![0.0; 785]),
            Err(CoordinatorError::BadFrame { .. })
        ));
        assert!(coord.submit(vec![0.0; 784]).is_ok());
        coord.shutdown();
    }

    #[test]
    fn replay_refuses_batch_paced_coordinator() {
        // the determinism guarantee needs submit-side pacing; a default
        // (batch-paced) coordinator must be rejected, not silently raced
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 2, FpRep::Int16);
        let spec = BackendSpec::sim(
            net.clone(),
            design,
            ZYNQ_7100,
            crate::morph::depth_ladder(&net),
        );
        let mut coord = Coordinator::start(ServeConfig::default(), spec).unwrap();
        let events = trace::step(0.01, 500.0);
        let err = coord
            .replay_power_trace(&events, &TraceConfig { frames: 4, rate_hz: 1000.0, seed: 1 })
            .unwrap_err();
        assert_eq!(err, CoordinatorError::ExternalPacingRequired);
        coord.shutdown();
    }

    #[test]
    fn failed_backend_init_surfaces_error() {
        let net = zoo::mnist();
        let spec = BackendSpec::Pjrt {
            artifacts_dir: std::path::PathBuf::from("/nonexistent"),
            model: "mnist".into(),
            net: net.clone(),
            design: DesignConfig::uniform(&net, 2, FpRep::Int16),
            device: ZYNQ_7100,
        };
        let err = Coordinator::start(ServeConfig::default(), spec)
            .err()
            .expect("must fail");
        assert!(err.to_string().contains("backend init failed"));
    }
}
