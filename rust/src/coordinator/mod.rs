//! The ForgeMorph serving coordinator (L3 leader) — sharded edition.
//!
//! The engine owns N worker shards. Each shard runs its own
//! [`crate::backend::InferenceBackend`] instance (PJRT executables are
//! thread-local — each backend is created *inside* its worker thread)
//! and its own [`BatchPolicy`]. Requests land in per-shard queues
//! (round-robin) and idle workers steal ready batches from their
//! neighbours, so one hot shard never caps throughput.
//!
//! The NeuroMorph [`Governor`] is **shared state** (`Arc<Mutex<_>>`),
//! consulted by every shard between batches (never mid-batch): morph
//! decisions stay globally consistent — all shards execute the same
//! active path, and a budget squeeze downshifts the whole fleet at once.
//! Per-shard [`ServingMetrics`] merge into one run report at shutdown.

pub mod batcher;
pub mod metrics;
pub mod trace;

pub use batcher::BatchPolicy;
pub use metrics::{Histogram, ServingMetrics};

// re-exported for compatibility: the cost-table builder moved to the
// backend layer with the rest of the sim-serving glue
pub use crate::backend::sim_path_costs;

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::backend::{BackendSpec, InferenceBackend as _};
use crate::morph::governor::{Budget, Decision, Governor};
use crate::morph::PathRegistry;

/// An inference request: one flat NHWC frame.
pub struct Request {
    pub id: u64,
    pub data: Vec<f32>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// The reply: logits + serving telemetry.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub class: usize,
    pub path: String,
    /// worker shard that executed the batch
    pub shard: usize,
    pub queue: Duration,
    pub exec: Duration,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// batcher flush deadline
    pub max_wait: Duration,
    /// governor hysteresis (observations)
    pub patience: usize,
    /// worker shards (each with its own backend instance)
    pub workers: usize,
    /// hard governor accuracy floor (DistillCycle profile floor or an
    /// application SLO); 0.0 = unconstrained
    pub accuracy_floor: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_wait: Duration::from_millis(2),
            patience: 2,
            workers: 1,
            accuracy_floor: 0.0,
        }
    }
}

/// Why a coordinator call was rejected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CoordinatorError {
    /// the coordinator has shut down (or never finished starting)
    Closed,
    /// submitted frame length does not match the backend's frame
    BadFrame { got: usize, want: usize },
}

impl fmt::Display for CoordinatorError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CoordinatorError::Closed => write!(f, "coordinator is closed"),
            CoordinatorError::BadFrame { got, want } => {
                write!(f, "frame has {got} elements, backend expects {want}")
            }
        }
    }
}

impl std::error::Error for CoordinatorError {}

/// State shared by the submit side and every worker shard.
struct Shared {
    /// per-shard request queues (work-stealing deques)
    queues: Vec<Mutex<VecDeque<Request>>>,
    /// accepting new work? cleared by shutdown / failed startup
    open: AtomicBool,
    /// requests enqueued but not yet taken (incremented *before* push)
    pending: AtomicUsize,
    /// operating budget the governor sees
    budget: Mutex<Budget>,
    /// the shared NeuroMorph governor (installed by shard 0 at startup)
    governor: OnceLock<Mutex<Governor>>,
    /// (path, power mW, latency ms) rows for energy accounting
    cost_rows: OnceLock<Vec<(String, f64, f64)>>,
    /// backend frame length, for validating submissions up front
    frame_len: OnceLock<usize>,
    /// sleep/wake for idle workers
    wake: Mutex<()>,
    wake_cv: Condvar,
}

impl Shared {
    fn new(shards: usize) -> Shared {
        Shared {
            queues: (0..shards).map(|_| Mutex::new(VecDeque::new())).collect(),
            open: AtomicBool::new(true),
            pending: AtomicUsize::new(0),
            budget: Mutex::new(Budget::unconstrained()),
            governor: OnceLock::new(),
            cost_rows: OnceLock::new(),
            frame_len: OnceLock::new(),
            wake: Mutex::new(()),
            wake_cv: Condvar::new(),
        }
    }

    fn notify_one(&self) {
        self.wake_cv.notify_one();
    }

    fn notify_all(&self) {
        self.wake_cv.notify_all();
    }

    /// Park briefly until new work may be available.
    fn wait_brief(&self, d: Duration) {
        let guard = self.wake.lock().unwrap();
        let _ = self
            .wake_cv
            .wait_timeout(guard, d.max(Duration::from_micros(200)))
            .unwrap();
    }
}

/// Handle to a running sharded coordinator.
pub struct Coordinator {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<ServingMetrics>>,
    next_id: AtomicU64,
    next_shard: AtomicUsize,
}

impl Coordinator {
    /// Start `cfg.workers` serving shards, each building its own backend
    /// from `spec`. Fails if any shard's backend fails to initialize.
    pub fn start(cfg: ServeConfig, spec: BackendSpec) -> anyhow::Result<Coordinator> {
        let n = cfg.workers.max(1);
        let shared = Arc::new(Shared::new(n));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let mut workers = Vec::with_capacity(n);
        for shard_id in 0..n {
            let shared = Arc::clone(&shared);
            let spec = spec.clone();
            let cfg = cfg.clone();
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(shard_id, cfg, spec, shared, ready)
            }));
        }
        drop(ready_tx);

        let mut failure: Option<String> = None;
        for _ in 0..n {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => failure = Some(e),
                Err(_) => failure = Some("worker died during startup".into()),
            }
        }
        if let Some(e) = failure {
            shared.open.store(false, Ordering::Release);
            shared.notify_all();
            for w in workers {
                let _ = w.join();
            }
            anyhow::bail!("backend init failed: {e}");
        }
        Ok(Coordinator {
            shared,
            workers,
            next_id: AtomicU64::new(0),
            next_shard: AtomicUsize::new(0),
        })
    }

    /// Submit one frame; returns the reply receiver, or
    /// [`CoordinatorError::Closed`] once the coordinator has shut down
    /// (previously this silently dropped the request).
    pub fn submit(&self, data: Vec<f32>) -> Result<mpsc::Receiver<Response>, CoordinatorError> {
        if !self.shared.open.load(Ordering::Acquire) {
            return Err(CoordinatorError::Closed);
        }
        if let Some(&want) = self.shared.frame_len.get() {
            if data.len() != want {
                return Err(CoordinatorError::BadFrame { got: data.len(), want });
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let shard =
            self.next_shard.fetch_add(1, Ordering::Relaxed) % self.shared.queues.len();
        let (reply, rx) = mpsc::channel();
        // pending is bumped before the push so a racing worker can never
        // drive the counter below zero
        self.shared.pending.fetch_add(1, Ordering::AcqRel);
        self.shared.queues[shard].lock().unwrap().push_back(Request {
            id,
            data,
            enqueued: Instant::now(),
            reply,
        });
        self.shared.notify_one();
        Ok(rx)
    }

    /// Update the operating budget the governor sees. Errors once the
    /// coordinator is closed instead of silently doing nothing.
    pub fn set_budget(&self, budget: Budget) -> Result<(), CoordinatorError> {
        if !self.shared.open.load(Ordering::Acquire) {
            return Err(CoordinatorError::Closed);
        }
        *self.shared.budget.lock().unwrap() = budget;
        Ok(())
    }

    /// Worker shard count.
    pub fn workers(&self) -> usize {
        self.shared.queues.len()
    }

    /// Stop accepting work, drain every in-flight request, and return
    /// the metrics of all shards merged. Idempotent: a second call
    /// returns empty metrics.
    pub fn shutdown(&mut self) -> ServingMetrics {
        self.shared.open.store(false, Ordering::Release);
        self.shared.notify_all();
        let mut merged = ServingMetrics::default();
        let mut panicked = 0usize;
        for w in self.workers.drain(..) {
            match w.join() {
                Ok(m) => merged.merge(&m),
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "unknown panic".into());
                    eprintln!("[coordinator] worker shard panicked: {msg}");
                    panicked += 1;
                }
            }
        }
        // surface the failure loudly (matching the pre-refactor
        // `.expect("worker panicked")`) unless we are already unwinding —
        // a panic inside Drop during unwind would abort the process
        if panicked > 0 && !std::thread::panicking() {
            panic!("{panicked} worker shard(s) panicked; metrics incomplete");
        }
        merged
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if !self.workers.is_empty() {
            self.shutdown();
        }
    }
}

/// How often shard 0 tracks the budget while the fleet is idle — the
/// pre-refactor single worker's poll cadence, so a squeeze applied in a
/// traffic lull still downshifts within ~patience x 5ms.
const IDLE_OBSERVE_PERIOD: Duration = Duration::from_millis(5);

/// Feed one budget observation to the shared governor, record any
/// switch in this shard's metrics, and return the now-active path.
fn observe_governor(
    governor: &Mutex<Governor>,
    shared: &Shared,
    metrics: &mut ServingMetrics,
) -> String {
    let budget = *shared.budget.lock().unwrap();
    let mut gov = governor.lock().unwrap();
    match gov.observe(&budget) {
        Decision::Switch { stall_frames, .. } => {
            metrics.morph_switches += 1;
            metrics.stall_frames += stall_frames as u64;
        }
        Decision::Hold => {}
    }
    gov.current().to_string()
}

/// Pop a ready batch: own queue first, then steal from neighbours.
fn take_batch(
    shared: &Shared,
    own: usize,
    policy: &BatchPolicy,
) -> Option<(usize, Vec<Request>)> {
    let n = shared.queues.len();
    let now = Instant::now();
    for k in 0..n {
        let qi = (own + k) % n;
        let mut q = shared.queues[qi].lock().unwrap();
        let oldest = q.front().map(|r| r.enqueued);
        if let Some(size) = policy.decide(q.len(), oldest, now) {
            let take: Vec<Request> =
                (0..size.min(q.len())).filter_map(|_| q.pop_front()).collect();
            drop(q);
            if !take.is_empty() {
                shared.pending.fetch_sub(take.len(), Ordering::AcqRel);
                return Some((size, take));
            }
        }
    }
    None
}

fn worker_loop(
    shard_id: usize,
    cfg: ServeConfig,
    spec: BackendSpec,
    shared: Arc<Shared>,
    ready: mpsc::Sender<Result<(), String>>,
) -> ServingMetrics {
    let mut backend = match spec.build() {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            return ServingMetrics::default();
        }
    };
    if shard_id == 0 {
        let registry = PathRegistry::new(backend.morph_paths());
        let costs = backend.path_costs();
        let _ = shared.frame_len.set(backend.frame_len());
        let _ = shared.cost_rows.set(costs.rows.clone());
        let _ = shared.governor.set(Mutex::new(
            Governor::new(registry, costs, cfg.patience).with_accuracy_floor(cfg.accuracy_floor),
        ));
    }
    let _ = ready.send(Ok(()));
    // drop the handshake sender now: if another shard panics before its
    // own send, start() sees the channel disconnect instead of hanging
    drop(ready);

    // wait for shard 0 to install the shared governor
    let governor = loop {
        if let Some(g) = shared.governor.get() {
            break g;
        }
        if !shared.open.load(Ordering::Acquire) {
            return ServingMetrics::default();
        }
        std::thread::sleep(Duration::from_micros(200));
    };
    let cost_rows = shared.cost_rows.get().cloned().unwrap_or_default();
    let policy = BatchPolicy::new(backend.batch_sizes(), cfg.max_wait);
    let frame = backend.frame_len();
    let nc = backend.num_classes();
    let mut metrics = ServingMetrics::default();
    let mut last_idle_observe = Instant::now();

    loop {
        let open = shared.open.load(Ordering::Acquire);

        let Some((size, take)) = take_batch(&shared, shard_id, &policy) else {
            if !open && shared.pending.load(Ordering::Acquire) == 0 {
                break;
            }
            // budget changes must bite during traffic lulls too; shard 0
            // alone polls at the single-worker cadence so idle spinning
            // across N shards does not dilute the patience hysteresis
            if shard_id == 0 && last_idle_observe.elapsed() >= IDLE_OBSERVE_PERIOD {
                let _ = observe_governor(governor, &shared, &mut metrics);
                last_idle_observe = Instant::now();
            }
            shared.wait_brief(cfg.max_wait / 2);
            continue;
        };

        // morph decision between batches (never mid-batch), paced by
        // batch execution so `patience` keeps its meaning regardless of
        // worker count. The governor is shared, so the whole fleet
        // tracks one active path.
        let path = observe_governor(governor, &shared, &mut metrics);

        let mut input = Vec::with_capacity(size * frame);
        for r in &take {
            input.extend_from_slice(&r.data);
        }
        // pad the tail of a short batch by repeating the last frame
        // (submit() validated lengths, so input is a nonzero multiple
        // of `frame` here)
        while input.len() < size * frame {
            let start = input.len() - frame;
            input.extend_from_within(start..);
        }

        let t0 = Instant::now();
        match backend.execute(&path, size, &input) {
            Ok(logits) => {
                let exec = t0.elapsed();
                let classes = backend.argmax(&logits);
                for (i, r) in take.iter().enumerate() {
                    let queue_d = t0.duration_since(r.enqueued);
                    let _ = r.reply.send(Response {
                        id: r.id,
                        logits: logits[i * nc..(i + 1) * nc].to_vec(),
                        class: classes[i],
                        path: path.clone(),
                        shard: shard_id,
                        queue: queue_d,
                        exec,
                    });
                }
                let queue_d = t0.duration_since(take[0].enqueued);
                metrics.record_batch(&path, take.len(), queue_d, exec);
                // modeled FPGA energy for these frames on the active path:
                // E = frames x P_path x T_frame (from the backend's table)
                if let Some((_, pw, lat)) = cost_rows.iter().find(|(n, _, _)| *n == path) {
                    metrics.energy_j += take.len() as f64 * (pw / 1000.0) * (lat / 1000.0);
                }
            }
            Err(e) => {
                // failure injection path: report and drop (callers see a
                // closed channel); the shard keeps serving
                eprintln!("[coordinator:{shard_id}] execute failed on {path}: {e}");
            }
        }
    }
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::DesignConfig;
    use crate::graph::zoo;
    use crate::pe::{FpRep, ZYNQ_7100};

    #[test]
    fn sim_costs_ordered_by_path_weight() {
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
        let reg = PathRegistry::new(crate::morph::tests::sample_paths());
        let costs = sim_path_costs(&net, &design, &ZYNQ_7100, &reg).unwrap();
        assert_eq!(costs.rows.len(), 4);
        let get = |n: &str| costs.rows.iter().find(|(m, _, _)| m == n).unwrap().clone();
        let (_, p_full, l_full) = get("d3_w100");
        let (_, p_d1, l_d1) = get("d1_w100");
        assert!(p_d1 < p_full, "gated power {p_d1} < full {p_full}");
        assert!(l_d1 < l_full, "gated latency {l_d1} < full {l_full}");
    }

    #[test]
    fn submit_and_budget_fail_after_shutdown() {
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 2, FpRep::Int16);
        let spec = BackendSpec::sim(
            net.clone(),
            design,
            ZYNQ_7100,
            crate::morph::depth_ladder(&net),
        );
        let mut coord =
            Coordinator::start(ServeConfig { workers: 2, ..Default::default() }, spec).unwrap();
        assert_eq!(coord.workers(), 2);
        assert!(coord.submit(vec![0.0; 784]).is_ok());
        coord.shutdown();
        assert!(matches!(
            coord.submit(vec![0.0; 784]),
            Err(CoordinatorError::Closed)
        ));
        assert_eq!(
            coord.set_budget(Budget::unconstrained()),
            Err(CoordinatorError::Closed)
        );
    }

    #[test]
    fn submit_rejects_wrong_frame_length() {
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 2, FpRep::Int16);
        let spec = BackendSpec::sim(
            net.clone(),
            design,
            ZYNQ_7100,
            crate::morph::depth_ladder(&net),
        );
        let mut coord = Coordinator::start(ServeConfig::default(), spec).unwrap();
        assert!(matches!(
            coord.submit(vec![0.0; 100]),
            Err(CoordinatorError::BadFrame { got: 100, want: 784 })
        ));
        assert!(matches!(
            coord.submit(vec![0.0; 785]),
            Err(CoordinatorError::BadFrame { .. })
        ));
        assert!(coord.submit(vec![0.0; 784]).is_ok());
        coord.shutdown();
    }

    #[test]
    fn failed_backend_init_surfaces_error() {
        let net = zoo::mnist();
        let spec = BackendSpec::Pjrt {
            artifacts_dir: std::path::PathBuf::from("/nonexistent"),
            model: "mnist".into(),
            net: net.clone(),
            design: DesignConfig::uniform(&net, 2, FpRep::Int16),
            device: ZYNQ_7100,
        };
        let err = Coordinator::start(ServeConfig::default(), spec)
            .err()
            .expect("must fail");
        assert!(err.to_string().contains("backend init failed"));
    }
}
