//! The ForgeMorph serving coordinator (L3 leader).
//!
//! Owns the request loop: a worker thread holds the PJRT [`Engine`]
//! (executables are thread-local by construction — the engine is created
//! *inside* the worker), requests arrive over an mpsc channel, the
//! [`BatchPolicy`] groups them, and the NeuroMorph [`Governor`] is
//! consulted between batches to pick the morph path under the current
//! power/latency budget. FPGA-side power/latency for the active path
//! comes from the cycle simulator (`sim/`), PJRT provides the numerics.

pub mod batcher;
pub mod metrics;
pub mod trace;

pub use batcher::BatchPolicy;
pub use metrics::{Histogram, ServingMetrics};

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::design::DesignConfig;
use crate::graph::Network;
use crate::morph::governor::{Budget, Decision, Governor, PathCosts};
use crate::morph::{gate_mask_for, PathRegistry};
use crate::pe::Device;
use crate::runtime::Engine;
use crate::sim;

/// An inference request: one flat NHWC frame.
pub struct Request {
    pub id: u64,
    pub data: Vec<f32>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// The reply: logits + serving telemetry.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub class: usize,
    pub path: String,
    pub queue: Duration,
    pub exec: Duration,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts_dir: PathBuf,
    pub model: String,
    pub max_wait: Duration,
    /// governor hysteresis (observations)
    pub patience: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            model: "mnist".into(),
            max_wait: Duration::from_millis(2),
            patience: 2,
        }
    }
}

/// Build the per-path cost table from the cycle simulator — the data the
/// governor trades on (power mW, latency ms per morph path).
pub fn sim_path_costs(
    net: &Network,
    design: &DesignConfig,
    device: &Device,
    registry: &PathRegistry,
) -> PathCosts {
    let rows = registry
        .paths()
        .iter()
        .map(|p| {
            let mask = gate_mask_for(net, p);
            let rep = sim::simulate(net, design, device, &mask);
            (p.name.clone(), rep.power_mw, rep.latency_ms())
        })
        .collect();
    PathCosts { rows }
}

/// Commands understood by the serving worker.
enum Command {
    Infer(Request),
    SetBudget(Budget),
    Shutdown,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Command>,
    worker: Option<std::thread::JoinHandle<ServingMetrics>>,
    next_id: u64,
}

impl Coordinator {
    /// Start the serving worker. `net`/`design` parameterize the FPGA
    /// cost model; the engine loads inside the worker thread.
    pub fn start(
        cfg: ServeConfig,
        net: Network,
        design: DesignConfig,
        device: Device,
    ) -> anyhow::Result<Coordinator> {
        let (tx, rx) = mpsc::channel::<Command>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let worker = std::thread::spawn(move || {
            worker_loop(cfg, net, design, device, rx, ready_tx)
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker died during startup"))?
            .map_err(|e| anyhow::anyhow!("engine init failed: {e}"))?;
        Ok(Coordinator { tx, worker: Some(worker), next_id: 0 })
    }

    /// Submit one frame; returns the reply receiver.
    pub fn submit(&mut self, data: Vec<f32>) -> mpsc::Receiver<Response> {
        let (reply, rx) = mpsc::channel();
        self.next_id += 1;
        let _ = self.tx.send(Command::Infer(Request {
            id: self.next_id,
            data,
            enqueued: Instant::now(),
            reply,
        }));
        rx
    }

    /// Update the operating budget the governor sees.
    pub fn set_budget(&self, budget: Budget) {
        let _ = self.tx.send(Command::SetBudget(budget));
    }

    /// Stop and collect the run's metrics.
    pub fn shutdown(mut self) -> ServingMetrics {
        let _ = self.tx.send(Command::Shutdown);
        self.worker
            .take()
            .expect("shutdown called once")
            .join()
            .expect("worker panicked")
    }
}

fn worker_loop(
    cfg: ServeConfig,
    net: Network,
    design: DesignConfig,
    device: Device,
    rx: mpsc::Receiver<Command>,
    ready: mpsc::Sender<Result<(), String>>,
) -> ServingMetrics {
    let engine = match Engine::load(&cfg.artifacts_dir, &cfg.model) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            return ServingMetrics::default();
        }
    };
    let registry = PathRegistry::new(engine.model().morph_paths());
    let costs = sim_path_costs(&net, &design, &device, &registry);
    let cost_rows = costs.rows.clone();
    let mut governor = Governor::new(registry, costs, cfg.patience);
    let policy = BatchPolicy::new(engine.model().batches.clone(), cfg.max_wait);

    let mut metrics = ServingMetrics::default();
    let mut queue: VecDeque<Request> = VecDeque::new();
    let mut budget = Budget::unconstrained();
    let mut open = true;

    while open || !queue.is_empty() {
        // drain incoming commands (briefly blocking when idle)
        let timeout = if queue.is_empty() {
            Duration::from_millis(5)
        } else {
            cfg.max_wait / 2
        };
        match rx.recv_timeout(timeout) {
            Ok(Command::Infer(r)) => queue.push_back(r),
            Ok(Command::SetBudget(b)) => budget = b,
            Ok(Command::Shutdown) => open = false,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
        }
        while let Ok(cmd) = rx.try_recv() {
            match cmd {
                Command::Infer(r) => queue.push_back(r),
                Command::SetBudget(b) => budget = b,
                Command::Shutdown => open = false,
            }
        }

        // morph decision between batches (never mid-batch)
        match governor.observe(&budget) {
            Decision::Switch { stall_frames, .. } => {
                metrics.morph_switches += 1;
                metrics.stall_frames += stall_frames as u64;
            }
            Decision::Hold => {}
        }

        let now = Instant::now();
        let oldest = queue.front().map(|r| r.enqueued);
        let Some(size) = policy.decide(queue.len(), oldest, now) else {
            continue;
        };
        let take: Vec<Request> = (0..size.min(queue.len()))
            .filter_map(|_| queue.pop_front())
            .collect();
        if take.is_empty() {
            continue;
        }
        let path = governor.current().to_string();
        let frame = engine.frame_len();
        let mut input = Vec::with_capacity(size * frame);
        for r in &take {
            input.extend_from_slice(&r.data);
        }
        // pad the tail of a short batch by repeating the last frame
        while input.len() < size * frame {
            let start = input.len() - frame;
            input.extend_from_within(start..);
        }

        let t0 = Instant::now();
        let result = engine.execute(&path, size, &input);
        let exec = t0.elapsed();
        match result {
            Ok(logits) => {
                let classes = engine.argmax(&logits);
                let nc = engine.model().num_classes;
                for (i, r) in take.iter().enumerate() {
                    let queue_d = t0.duration_since(r.enqueued);
                    let _ = r.reply.send(Response {
                        id: r.id,
                        logits: logits[i * nc..(i + 1) * nc].to_vec(),
                        class: classes[i],
                        path: path.clone(),
                        queue: queue_d,
                        exec,
                    });
                }
                let queue_d = t0.duration_since(take[0].enqueued);
                metrics.record_batch(&path, take.len(), queue_d, exec);
                // modeled FPGA energy for these frames on the active path:
                // E = frames x P_path x T_frame (from the cycle simulator)
                if let Some((_, pw, lat)) = cost_rows.iter().find(|(n, _, _)| *n == path) {
                    metrics.energy_j += take.len() as f64 * (pw / 1000.0) * (lat / 1000.0);
                }
            }
            Err(e) => {
                // failure injection path: report and drop (callers see a
                // closed channel); the loop keeps serving
                eprintln!("[coordinator] execute failed on {path}: {e}");
            }
        }
    }
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::pe::{FpRep, ZYNQ_7100};

    #[test]
    fn sim_costs_ordered_by_path_weight() {
        let net = zoo::mnist();
        let design = DesignConfig::uniform(&net, 4, FpRep::Int16);
        let reg = PathRegistry::new(crate::morph::tests::sample_paths());
        let costs = sim_path_costs(&net, &design, &ZYNQ_7100, &reg);
        assert_eq!(costs.rows.len(), 4);
        let get = |n: &str| costs.rows.iter().find(|(m, _, _)| m == n).unwrap().clone();
        let (_, p_full, l_full) = get("d3_w100");
        let (_, p_d1, l_d1) = get("d1_w100");
        assert!(p_d1 < p_full, "gated power {p_d1} < full {p_full}");
        assert!(l_d1 < l_full, "gated latency {l_d1} < full {l_full}");
    }
}
