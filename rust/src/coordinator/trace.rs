//! Workload and budget trace generators for serving experiments.
//!
//! The paper's deployment scenarios (Sec. I): power-saving mode entries,
//! thermal throttling, bursty sensor streams. These generators produce
//! the deterministic traces the serving bench and the adaptive_serving
//! example replay: request arrival times plus a time-varying power/
//! latency budget the NeuroMorph governor must track.

use crate::morph::governor::Budget;
use crate::util::rng::Rng;

/// Arrival pattern of inference requests.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalPattern {
    /// Poisson arrivals at a fixed rate (steady sensor stream).
    Poisson { rate_hz: f64 },
    /// Alternating calm/burst phases (event-triggered cameras).
    Bursty {
        calm_hz: f64,
        burst_hz: f64,
        phase_s: f64,
    },
    /// Deterministic fixed-interval arrivals (control-loop ticks).
    Periodic { rate_hz: f64 },
}

/// Generate `n` arrival offsets (seconds from start), deterministic.
pub fn arrivals(pattern: ArrivalPattern, n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let dt = match pattern {
            ArrivalPattern::Poisson { rate_hz } => rng.exp(rate_hz),
            ArrivalPattern::Periodic { rate_hz } => 1.0 / rate_hz,
            ArrivalPattern::Bursty { calm_hz, burst_hz, phase_s } => {
                let in_burst = (t / phase_s) as u64 % 2 == 1;
                rng.exp(if in_burst { burst_hz } else { calm_hz })
            }
        };
        t += dt;
        out.push(t);
    }
    out
}

/// A budget change at a point in time.
#[derive(Debug, Clone, Copy)]
pub struct BudgetEvent {
    pub at_s: f64,
    pub budget: Budget,
}

/// The paper's power-saving scenario: run free, squeeze to a power cap
/// mid-run, release near the end.
pub fn squeeze_release(duration_s: f64, cap_mw: f64) -> Vec<BudgetEvent> {
    vec![
        BudgetEvent { at_s: 0.0, budget: Budget::unconstrained() },
        BudgetEvent {
            at_s: duration_s / 3.0,
            budget: Budget { power_mw: Some(cap_mw), latency_ms: None },
        },
        BudgetEvent { at_s: 2.0 * duration_s / 3.0, budget: Budget::unconstrained() },
    ]
}

/// A diurnal-style staircase: progressively tighter power caps, then
/// recovery — exercises multi-level morphing.
pub fn staircase(duration_s: f64, caps_mw: &[f64]) -> Vec<BudgetEvent> {
    let steps = caps_mw.len() as f64;
    let mut out = vec![BudgetEvent { at_s: 0.0, budget: Budget::unconstrained() }];
    for (i, &cap) in caps_mw.iter().enumerate() {
        out.push(BudgetEvent {
            at_s: duration_s * (i as f64 + 1.0) / (steps + 2.0),
            budget: Budget { power_mw: Some(cap), latency_ms: None },
        });
    }
    out.push(BudgetEvent {
        at_s: duration_s * (steps + 1.0) / (steps + 2.0),
        budget: Budget::unconstrained(),
    });
    out
}

/// Latency-SLA trace: a deadline tightens when the system enters a
/// "reactive" mode (the autonomous-vehicle scenario of Sec. I).
pub fn sla_tightening(duration_s: f64, relaxed_ms: f64, tight_ms: f64) -> Vec<BudgetEvent> {
    vec![
        BudgetEvent {
            at_s: 0.0,
            budget: Budget { power_mw: None, latency_ms: Some(relaxed_ms) },
        },
        BudgetEvent {
            at_s: duration_s / 2.0,
            budget: Budget { power_mw: None, latency_ms: Some(tight_ms) },
        },
    ]
}

/// Budget in force at time `t` (events must be at_s-sorted).
pub fn budget_at(events: &[BudgetEvent], t: f64) -> Budget {
    events
        .iter()
        .rev()
        .find(|e| e.at_s <= t)
        .map(|e| e.budget)
        .unwrap_or_else(Budget::unconstrained)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_arrivals_monotone_and_rate_correct() {
        let a = arrivals(ArrivalPattern::Poisson { rate_hz: 1000.0 }, 2000, 1);
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        let measured = a.len() as f64 / a.last().unwrap();
        assert!((measured - 1000.0).abs() / 1000.0 < 0.1, "rate {measured}");
    }

    #[test]
    fn periodic_is_exact() {
        let a = arrivals(ArrivalPattern::Periodic { rate_hz: 100.0 }, 10, 1);
        assert!((a[9] - 0.1).abs() < 1e-9);
    }

    #[test]
    fn bursty_has_two_speeds() {
        let a = arrivals(
            ArrivalPattern::Bursty { calm_hz: 100.0, burst_hz: 5000.0, phase_s: 0.5 },
            4000,
            2,
        );
        // count arrivals in calm [0,0.5) vs burst [0.5,1.0)
        let calm = a.iter().filter(|&&t| t < 0.5).count();
        let burst = a.iter().filter(|&&t| (0.5..1.0).contains(&t)).count();
        assert!(burst > 5 * calm, "calm {calm} burst {burst}");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = arrivals(ArrivalPattern::Poisson { rate_hz: 50.0 }, 100, 7);
        let b = arrivals(ArrivalPattern::Poisson { rate_hz: 50.0 }, 100, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn squeeze_release_structure() {
        let ev = squeeze_release(3.0, 500.0);
        assert_eq!(ev.len(), 3);
        assert!(budget_at(&ev, 0.5).power_mw.is_none());
        assert_eq!(budget_at(&ev, 1.5).power_mw, Some(500.0));
        assert!(budget_at(&ev, 2.5).power_mw.is_none());
    }

    #[test]
    fn staircase_tightens_then_recovers() {
        // events at t = 8*(1/5, 2/5, 3/5) caps and 8*(4/5) release
        let ev = staircase(8.0, &[700.0, 600.0, 500.0]);
        assert_eq!(ev.len(), 5);
        let mid = budget_at(&ev, 8.0 * 2.4 / 5.0);
        assert_eq!(mid.power_mw, Some(600.0));
        assert!(budget_at(&ev, 7.9).power_mw.is_none());
    }

    #[test]
    fn sla_tightens() {
        let ev = sla_tightening(2.0, 10.0, 1.0);
        assert_eq!(budget_at(&ev, 0.1).latency_ms, Some(10.0));
        assert_eq!(budget_at(&ev, 1.9).latency_ms, Some(1.0));
    }

    #[test]
    fn budget_before_first_event_unconstrained() {
        let ev = vec![BudgetEvent {
            at_s: 5.0,
            budget: Budget { power_mw: Some(1.0), latency_ms: None },
        }];
        assert!(budget_at(&ev, 1.0).power_mw.is_none());
    }
}
