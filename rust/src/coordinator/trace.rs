//! Workload and budget trace generators for serving experiments.
//!
//! The paper's deployment scenarios (Sec. I): power-saving mode entries,
//! thermal throttling, bursty sensor streams. These generators produce
//! the deterministic traces the serving bench and the adaptive_serving
//! example replay: request arrival times plus a time-varying power/
//! latency budget the NeuroMorph governor must track.

use crate::morph::governor::Budget;
use crate::power::PathEnergy;
use crate::util::rng::Rng;

/// Arrival pattern of inference requests.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalPattern {
    /// Poisson arrivals at a fixed rate (steady sensor stream).
    Poisson { rate_hz: f64 },
    /// Alternating calm/burst phases (event-triggered cameras).
    Bursty {
        calm_hz: f64,
        burst_hz: f64,
        phase_s: f64,
    },
    /// Deterministic fixed-interval arrivals (control-loop ticks).
    Periodic { rate_hz: f64 },
}

/// Generate `n` arrival offsets (seconds from start), deterministic.
pub fn arrivals(pattern: ArrivalPattern, n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let dt = match pattern {
            ArrivalPattern::Poisson { rate_hz } => rng.exp(rate_hz),
            ArrivalPattern::Periodic { rate_hz } => 1.0 / rate_hz,
            ArrivalPattern::Bursty { calm_hz, burst_hz, phase_s } => {
                let in_burst = (t / phase_s) as u64 % 2 == 1;
                rng.exp(if in_burst { burst_hz } else { calm_hz })
            }
        };
        t += dt;
        out.push(t);
    }
    out
}

/// A budget change at a point in time.
#[derive(Debug, Clone, Copy)]
pub struct BudgetEvent {
    pub at_s: f64,
    pub budget: Budget,
}

/// The paper's power-saving scenario: run free, squeeze to a power cap
/// mid-run, release near the end.
pub fn squeeze_release(duration_s: f64, cap_mw: f64) -> Vec<BudgetEvent> {
    vec![
        BudgetEvent { at_s: 0.0, budget: Budget::unconstrained() },
        BudgetEvent {
            at_s: duration_s / 3.0,
            budget: Budget { power_mw: Some(cap_mw), latency_ms: None },
        },
        BudgetEvent { at_s: 2.0 * duration_s / 3.0, budget: Budget::unconstrained() },
    ]
}

/// A diurnal-style staircase: progressively tighter power caps, then
/// recovery — exercises multi-level morphing.
pub fn staircase(duration_s: f64, caps_mw: &[f64]) -> Vec<BudgetEvent> {
    let steps = caps_mw.len() as f64;
    let mut out = vec![BudgetEvent { at_s: 0.0, budget: Budget::unconstrained() }];
    for (i, &cap) in caps_mw.iter().enumerate() {
        out.push(BudgetEvent {
            at_s: duration_s * (i as f64 + 1.0) / (steps + 2.0),
            budget: Budget { power_mw: Some(cap), latency_ms: None },
        });
    }
    out.push(BudgetEvent {
        at_s: duration_s * (steps + 1.0) / (steps + 2.0),
        budget: Budget::unconstrained(),
    });
    out
}

/// The canonical down-shift step (`--power-trace step`): run free,
/// squeeze to `cap_mw` at one third, release at two thirds — the paper's
/// power-saving-mode experiment (alias of [`squeeze_release`] under the
/// trace-spec grammar's name).
pub fn step(duration_s: f64, cap_mw: f64) -> Vec<BudgetEvent> {
    squeeze_release(duration_s, cap_mw)
}

/// Thermal-throttle ramp: unconstrained, then `steps` equal plateaus
/// descending linearly from `from_mw` to `to_mw` across the middle half,
/// releasing at three quarters.
pub fn ramp(duration_s: f64, from_mw: f64, to_mw: f64, steps: usize) -> Vec<BudgetEvent> {
    let steps = steps.max(1);
    let t0 = duration_s / 4.0;
    let t1 = 3.0 * duration_s / 4.0;
    let mut out = vec![BudgetEvent { at_s: 0.0, budget: Budget::unconstrained() }];
    for k in 0..steps {
        let f = if steps == 1 { 1.0 } else { k as f64 / (steps - 1) as f64 };
        out.push(BudgetEvent {
            at_s: t0 + (t1 - t0) * k as f64 / steps as f64,
            budget: Budget {
                power_mw: Some(from_mw + (to_mw - from_mw) * f),
                latency_ms: None,
            },
        });
    }
    out.push(BudgetEvent { at_s: t1, budget: Budget::unconstrained() });
    out
}

/// Repeated short dips to `cap_mw`, alternating every `period_s`
/// (event-triggered thermal spikes — the governor's hysteresis test).
pub fn spike(duration_s: f64, cap_mw: f64, period_s: f64) -> Vec<BudgetEvent> {
    let period_s = period_s.max(1e-6);
    let mut out = Vec::new();
    let mut t = 0.0;
    let mut tight = false;
    while t < duration_s {
        out.push(BudgetEvent {
            at_s: t,
            budget: if tight {
                Budget { power_mw: Some(cap_mw), latency_ms: None }
            } else {
                Budget::unconstrained()
            },
        });
        tight = !tight;
        t += period_s;
    }
    out
}

/// Day/night power envelope: a sampled cosine staircase between
/// `base_mw` (peak allowance) and `base_mw - amp_mw` (deepest night),
/// `cycles` full periods of 8 plateaus each.
pub fn diurnal(duration_s: f64, base_mw: f64, amp_mw: f64, cycles: usize) -> Vec<BudgetEvent> {
    let plateaus = cycles.max(1) * 8;
    (0..plateaus)
        .map(|k| {
            let phase = 2.0 * std::f64::consts::PI * (k % 8) as f64 / 8.0;
            BudgetEvent {
                at_s: duration_s * k as f64 / plateaus as f64,
                budget: Budget {
                    power_mw: Some(base_mw - amp_mw * (1.0 - phase.cos()) / 2.0),
                    latency_ms: None,
                },
            }
        })
        .collect()
}

/// Default squeeze cap for a deployed ladder: just above the lightest
/// path's draw (5% of the power span), so a bare `step`/`spike` spec
/// always has a feasible down-shift target strictly below every heavier
/// path. The ONE cap policy shared by the CLI, the power report, the
/// replay bench and the determinism tests. Returns 0.0 on an empty
/// table.
pub fn default_squeeze_cap(rows: &[PathEnergy]) -> f64 {
    if rows.is_empty() {
        return 0.0;
    }
    let full = rows.iter().map(|e| e.power_mw).fold(f64::NEG_INFINITY, f64::max);
    let light = rows.iter().map(|e| e.power_mw).fold(f64::INFINITY, f64::min);
    light + 0.05 * (full - light).max(0.0)
}

/// Parse a `serve --power-trace` spec into a budget-event trace.
///
/// Grammar: `<name>[:key=value[,key=value...]]` with the generator names
/// `step | ramp | spike | diurnal`. Power values are mW, times seconds;
/// omitted keys default relative to `default_cap_mw` (derived by the
/// caller from the deployed path table, so a bare `step` always has a
/// feasible down-shift target). Examples: `step`, `step:cap=520`,
/// `ramp:from=700,to=500,steps=4`, `spike:cap=500,period=0.25`,
/// `diurnal:base=700,amp=250,cycles=2`.
pub fn parse_spec(
    spec: &str,
    duration_s: f64,
    default_cap_mw: f64,
) -> Result<Vec<BudgetEvent>, String> {
    let (name, rest) = spec.split_once(':').unwrap_or((spec, ""));
    let kv = parse_kv_pairs(&format!("power-trace '{spec}'"), rest)?;
    let known: &[&str] = match name {
        "step" => &["cap"],
        "ramp" => &["from", "to", "steps"],
        "spike" => &["cap", "period"],
        "diurnal" => &["base", "amp", "cycles"],
        other => {
            return Err(format!(
                "unknown power-trace '{other}' (expected step|ramp|spike|diurnal)"
            ))
        }
    };
    if let Some(bad) = kv.keys().find(|k| !known.contains(&k.as_str())) {
        return Err(format!(
            "power-trace '{name}': unknown key '{bad}' (valid: {})",
            known.join(", ")
        ));
    }
    let get = |k: &str, d: f64| kv.get(k).copied().unwrap_or(d);
    Ok(match name {
        "step" => step(duration_s, get("cap", default_cap_mw)),
        "ramp" => ramp(
            duration_s,
            get("from", default_cap_mw * 1.4),
            get("to", default_cap_mw),
            get("steps", 3.0).max(1.0) as usize,
        ),
        "spike" => spike(
            duration_s,
            get("cap", default_cap_mw),
            get("period", duration_s / 6.0),
        ),
        "diurnal" => diurnal(
            duration_s,
            get("base", default_cap_mw * 1.4),
            get("amp", default_cap_mw * 0.6),
            get("cycles", 1.0).max(1.0) as usize,
        ),
        _ => unreachable!("name validated above"),
    })
}

/// Parse a comma-separated `key=value[,key=value...]` list into a map —
/// the shared kernel of the power-trace and fault-trace grammars.
/// `what` labels errors (e.g. `power-trace 'step:x=1'`).
pub(crate) fn parse_kv_pairs(
    what: &str,
    rest: &str,
) -> Result<std::collections::BTreeMap<String, f64>, String> {
    let mut kv = std::collections::BTreeMap::new();
    for pair in rest.split(',').filter(|p| !p.is_empty()) {
        let (k, v) = pair
            .split_once('=')
            .ok_or_else(|| format!("{what}: expected key=value, got '{pair}'"))?;
        let num: f64 =
            v.parse().map_err(|_| format!("{what}: non-numeric value '{v}' for '{k}'"))?;
        kv.insert(k.to_string(), num);
    }
    Ok(kv)
}

/// Latency-SLA trace: a deadline tightens when the system enters a
/// "reactive" mode (the autonomous-vehicle scenario of Sec. I).
pub fn sla_tightening(duration_s: f64, relaxed_ms: f64, tight_ms: f64) -> Vec<BudgetEvent> {
    vec![
        BudgetEvent {
            at_s: 0.0,
            budget: Budget { power_mw: None, latency_ms: Some(relaxed_ms) },
        },
        BudgetEvent {
            at_s: duration_s / 2.0,
            budget: Budget { power_mw: None, latency_ms: Some(tight_ms) },
        },
    ]
}

/// Budget in force at time `t` (events must be at_s-sorted).
pub fn budget_at(events: &[BudgetEvent], t: f64) -> Budget {
    events
        .iter()
        .rev()
        .find(|e| e.at_s <= t)
        .map(|e| e.budget)
        .unwrap_or_else(Budget::unconstrained)
}

/// Index of the trace event in force at time `t` (0 when `t` precedes
/// the first event) — the per-segment accounting key of trace replays.
pub fn segment_at(events: &[BudgetEvent], t: f64) -> usize {
    events.iter().rposition(|e| e.at_s <= t).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_arrivals_monotone_and_rate_correct() {
        let a = arrivals(ArrivalPattern::Poisson { rate_hz: 1000.0 }, 2000, 1);
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        let measured = a.len() as f64 / a.last().unwrap();
        assert!((measured - 1000.0).abs() / 1000.0 < 0.1, "rate {measured}");
    }

    #[test]
    fn periodic_is_exact() {
        let a = arrivals(ArrivalPattern::Periodic { rate_hz: 100.0 }, 10, 1);
        assert!((a[9] - 0.1).abs() < 1e-9);
    }

    #[test]
    fn bursty_has_two_speeds() {
        let a = arrivals(
            ArrivalPattern::Bursty { calm_hz: 100.0, burst_hz: 5000.0, phase_s: 0.5 },
            4000,
            2,
        );
        // count arrivals in calm [0,0.5) vs burst [0.5,1.0)
        let calm = a.iter().filter(|&&t| t < 0.5).count();
        let burst = a.iter().filter(|&&t| (0.5..1.0).contains(&t)).count();
        assert!(burst > 5 * calm, "calm {calm} burst {burst}");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = arrivals(ArrivalPattern::Poisson { rate_hz: 50.0 }, 100, 7);
        let b = arrivals(ArrivalPattern::Poisson { rate_hz: 50.0 }, 100, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn squeeze_release_structure() {
        let ev = squeeze_release(3.0, 500.0);
        assert_eq!(ev.len(), 3);
        assert!(budget_at(&ev, 0.5).power_mw.is_none());
        assert_eq!(budget_at(&ev, 1.5).power_mw, Some(500.0));
        assert!(budget_at(&ev, 2.5).power_mw.is_none());
    }

    #[test]
    fn staircase_tightens_then_recovers() {
        // events at t = 8*(1/5, 2/5, 3/5) caps and 8*(4/5) release
        let ev = staircase(8.0, &[700.0, 600.0, 500.0]);
        assert_eq!(ev.len(), 5);
        let mid = budget_at(&ev, 8.0 * 2.4 / 5.0);
        assert_eq!(mid.power_mw, Some(600.0));
        assert!(budget_at(&ev, 7.9).power_mw.is_none());
    }

    #[test]
    fn sla_tightens() {
        let ev = sla_tightening(2.0, 10.0, 1.0);
        assert_eq!(budget_at(&ev, 0.1).latency_ms, Some(10.0));
        assert_eq!(budget_at(&ev, 1.9).latency_ms, Some(1.0));
    }

    #[test]
    fn step_is_squeeze_release() {
        let a = step(3.0, 500.0);
        let b = squeeze_release(3.0, 500.0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_s, y.at_s);
            assert_eq!(x.budget.power_mw, y.budget.power_mw);
        }
    }

    #[test]
    fn ramp_descends_then_releases() {
        let ev = ramp(8.0, 700.0, 500.0, 3);
        assert_eq!(ev.len(), 5);
        assert!(budget_at(&ev, 0.5).power_mw.is_none());
        assert_eq!(budget_at(&ev, 2.1).power_mw, Some(700.0));
        assert_eq!(budget_at(&ev, 4.1).power_mw, Some(600.0));
        assert_eq!(budget_at(&ev, 5.9).power_mw, Some(500.0));
        assert!(budget_at(&ev, 6.1).power_mw.is_none());
    }

    #[test]
    fn spike_alternates_every_period() {
        let ev = spike(2.0, 500.0, 0.5);
        assert_eq!(ev.len(), 4);
        assert!(budget_at(&ev, 0.25).power_mw.is_none());
        assert_eq!(budget_at(&ev, 0.75).power_mw, Some(500.0));
        assert!(budget_at(&ev, 1.25).power_mw.is_none());
        assert_eq!(budget_at(&ev, 1.75).power_mw, Some(500.0));
    }

    #[test]
    fn diurnal_oscillates_within_envelope() {
        let ev = diurnal(8.0, 700.0, 200.0, 2);
        assert_eq!(ev.len(), 16);
        for e in &ev {
            let p = e.budget.power_mw.unwrap();
            assert!((500.0..=700.0).contains(&p), "{p}");
        }
        // peak at phase 0, trough half a cycle later
        assert_eq!(ev[0].budget.power_mw, Some(700.0));
        assert!((ev[4].budget.power_mw.unwrap() - 500.0).abs() < 1e-9);
        // second cycle repeats the first
        assert_eq!(ev[0].budget.power_mw, ev[8].budget.power_mw);
    }

    #[test]
    fn parse_spec_grammar() {
        // bare name uses the caller-derived default cap
        let ev = parse_spec("step", 3.0, 520.0).unwrap();
        assert_eq!(budget_at(&ev, 1.5).power_mw, Some(520.0));
        // explicit key overrides
        let ev = parse_spec("step:cap=480", 3.0, 520.0).unwrap();
        assert_eq!(budget_at(&ev, 1.5).power_mw, Some(480.0));
        let ev = parse_spec("ramp:from=700,to=500,steps=4", 8.0, 0.0).unwrap();
        assert_eq!(ev.len(), 6);
        assert!(parse_spec("spike:cap=500,period=0.5", 2.0, 0.0).is_ok());
        assert!(parse_spec("diurnal:base=700,amp=200,cycles=2", 8.0, 0.0).is_ok());
        // errors name the problem
        let e = parse_spec("sawtooth", 1.0, 500.0).unwrap_err();
        assert!(e.contains("sawtooth") && e.contains("step|ramp|spike|diurnal"), "{e}");
        let e = parse_spec("step:watts=5", 1.0, 500.0).unwrap_err();
        assert!(e.contains("watts") && e.contains("cap"), "{e}");
        let e = parse_spec("step:cap=high", 1.0, 500.0).unwrap_err();
        assert!(e.contains("non-numeric"), "{e}");
        let e = parse_spec("step:cap", 1.0, 500.0).unwrap_err();
        assert!(e.contains("key=value"), "{e}");
    }

    #[test]
    fn default_cap_sits_between_lightest_and_next_path() {
        let row = |name: &str, power_mw: f64| PathEnergy {
            name: name.into(),
            activity: crate::power::Activity::default(),
            power_mw,
            frame_ms: 1.0,
        };
        let rows = vec![row("d1", 466.0), row("d2", 635.0), row("d3", 974.0)];
        let cap = default_squeeze_cap(&rows);
        assert!(cap > 466.0 && cap < 635.0, "{cap}");
        assert_eq!(default_squeeze_cap(&[]), 0.0);
        // a one-path ladder degenerates to that path's own draw
        assert!((default_squeeze_cap(&rows[..1]) - 466.0).abs() < 1e-9);
    }

    #[test]
    fn segment_index_follows_events() {
        let ev = step(3.0, 500.0);
        assert_eq!(segment_at(&ev, 0.5), 0);
        assert_eq!(segment_at(&ev, 1.5), 1);
        assert_eq!(segment_at(&ev, 2.5), 2);
        assert_eq!(segment_at(&[], 1.0), 0);
    }

    #[test]
    fn budget_before_first_event_unconstrained() {
        let ev = vec![BudgetEvent {
            at_s: 5.0,
            budget: Budget { power_mw: Some(1.0), latency_ms: None },
        }];
        assert!(budget_at(&ev, 1.0).power_mw.is_none());
    }
}
