//! Dynamic batcher.
//!
//! The AOT artifact set carries a small menu of batch sizes per path
//! (typically {1, 8}). The batcher groups pending requests into the
//! largest supported batch, flushing early when the oldest request's
//! queueing deadline expires — the standard latency/throughput dial.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::Request;

/// Pop up to `size` requests that share one pinned morph path (all
/// unpinned requests count as one group). A batch never straddles a
/// pinned-path boundary, so across a morph transition the outgoing
/// path's requests drain first — the drain half of the serving engine's
/// drain→swap→resume reconfiguration timeline.
///
/// Stall-injected stragglers ([`Request::isolating`]) run in a batch of
/// their own: the injected delay must never land on innocent batch
/// neighbours, so an isolating request both ends the current run and,
/// when it is the front, is popped alone.
pub fn pop_pinned_run(q: &mut VecDeque<Request>, size: usize) -> Vec<Request> {
    let mut out: Vec<Request> = Vec::with_capacity(size.min(q.len()));
    while out.len() < size {
        match q.front() {
            Some(next) if !out.is_empty() && next.isolating() => break,
            Some(next)
                if out.is_empty() || next.pinned_path == out[0].pinned_path =>
            {
                let isolating = next.isolating();
                out.push(q.pop_front().expect("front just checked"));
                if isolating {
                    break;
                }
            }
            _ => break,
        }
    }
    out
}

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// supported batch sizes, ascending (from the manifest)
    pub sizes: Vec<usize>,
    /// flush when the oldest pending request has waited this long
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn new(mut sizes: Vec<usize>, max_wait: Duration) -> BatchPolicy {
        assert!(!sizes.is_empty(), "need at least one batch size");
        sizes.sort_unstable();
        sizes.dedup();
        BatchPolicy { sizes, max_wait }
    }

    pub fn max_size(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Largest supported size `<= n` (always at least the smallest size).
    pub fn fit(&self, n: usize) -> usize {
        self.sizes
            .iter()
            .rev()
            .find(|&&s| s <= n)
            .copied()
            .unwrap_or(self.sizes[0])
    }

    /// Smallest supported size that covers `n` requests (the menu's max
    /// when nothing does). The executed-batch size for a run that came
    /// up short of the decided size — e.g. split at a pinned-path
    /// boundary — so padding never exceeds the tightest menu entry.
    pub fn cover(&self, n: usize) -> usize {
        self.sizes.iter().find(|&&s| s >= n).copied().unwrap_or_else(|| self.max_size())
    }

    /// Decide whether to emit a batch given `pending` queued requests and
    /// the enqueue time of the oldest. Returns the batch size to run now,
    /// or None to keep waiting.
    pub fn decide(&self, pending: usize, oldest: Option<Instant>, now: Instant) -> Option<usize> {
        if pending == 0 {
            return None;
        }
        if pending >= self.max_size() {
            return Some(self.max_size());
        }
        match oldest {
            Some(t) if now.duration_since(t) >= self.max_wait => Some(self.fit(pending)),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![8, 1], Duration::from_millis(2))
    }

    #[test]
    fn sizes_sorted_and_deduped() {
        let p = BatchPolicy::new(vec![8, 1, 8], Duration::from_millis(1));
        assert_eq!(p.sizes, vec![1, 8]);
        assert_eq!(p.max_size(), 8);
    }

    #[test]
    fn fit_picks_largest_le() {
        let p = policy();
        assert_eq!(p.fit(8), 8);
        assert_eq!(p.fit(12), 8);
        assert_eq!(p.fit(5), 1);
        assert_eq!(p.fit(0), 1);
    }

    #[test]
    fn cover_picks_smallest_ge() {
        let p = BatchPolicy::new(vec![1, 4, 8], Duration::from_millis(1));
        assert_eq!(p.cover(0), 1);
        assert_eq!(p.cover(1), 1);
        assert_eq!(p.cover(2), 4);
        assert_eq!(p.cover(4), 4);
        assert_eq!(p.cover(5), 8);
        // beyond the menu: the max size (padding is capped by the menu)
        assert_eq!(p.cover(12), 8);
    }

    #[test]
    fn full_batch_fires_immediately() {
        let p = policy();
        let now = Instant::now();
        assert_eq!(p.decide(8, Some(now), now), Some(8));
        assert_eq!(p.decide(20, Some(now), now), Some(8));
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let p = policy();
        let now = Instant::now();
        assert_eq!(p.decide(3, Some(now), now), None);
        let later = now + Duration::from_millis(3);
        assert_eq!(p.decide(3, Some(now), later), Some(1));
    }

    #[test]
    fn max_wait_boundary_is_inclusive() {
        // the deadline comparison is `elapsed >= max_wait`: one tick
        // before the boundary holds, the boundary itself fires
        let p = policy();
        let now = Instant::now();
        let just_before = now + Duration::from_millis(2) - Duration::from_nanos(1);
        assert_eq!(p.decide(3, Some(now), just_before), None);
        let exactly = now + Duration::from_millis(2);
        assert_eq!(p.decide(3, Some(now), exactly), Some(1));
    }

    #[test]
    fn empty_queue_never_fires() {
        let p = policy();
        assert_eq!(p.decide(0, None, Instant::now()), None);
    }

    fn req(pin: Option<&str>) -> (Request, std::sync::mpsc::Receiver<super::super::Response>) {
        let (reply, rx) = std::sync::mpsc::channel();
        (
            Request {
                id: 0,
                data: Vec::new(),
                enqueued: Instant::now(),
                reply,
                pinned_path: pin.map(str::to_string),
                fault: None,
                attempt: 0,
                deadline: None,
                degraded: false,
            },
            rx,
        )
    }

    #[test]
    fn stall_injected_requests_run_alone() {
        use crate::fault::FaultDirective;
        let mut q = VecDeque::new();
        let mut keep = Vec::new();
        for stalled in [false, true, false, false] {
            let (mut r, rx) = req(Some("d3"));
            if stalled {
                r.fault = Some(FaultDirective { stall_ms: 2.0, fail_attempts: 0 });
            }
            q.push_back(r);
            keep.push(rx);
        }
        // the run stops short of the straggler...
        let run = pop_pinned_run(&mut q, 8);
        assert_eq!(run.len(), 1);
        assert!(!run[0].isolating());
        // ...which then pops in a batch of one despite sharing the pin
        let run = pop_pinned_run(&mut q, 8);
        assert_eq!(run.len(), 1);
        assert!(run[0].isolating());
        // the innocent tail batches together again
        assert_eq!(pop_pinned_run(&mut q, 8).len(), 2);
    }

    #[test]
    fn pinned_run_splits_at_path_boundary() {
        let mut q = VecDeque::new();
        let mut keep = Vec::new();
        for pin in [Some("d3"), Some("d3"), Some("d1"), Some("d1"), Some("d1")] {
            let (r, rx) = req(pin);
            q.push_back(r);
            keep.push(rx);
        }
        // the d3 run drains first even though 8 were requested
        let run = pop_pinned_run(&mut q, 8);
        assert_eq!(run.len(), 2);
        assert!(run.iter().all(|r| r.pinned_path.as_deref() == Some("d3")));
        // next call picks up the d1 run, capped by size
        let run = pop_pinned_run(&mut q, 2);
        assert_eq!(run.len(), 2);
        assert!(run.iter().all(|r| r.pinned_path.as_deref() == Some("d1")));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn unpinned_requests_batch_together() {
        let mut q = VecDeque::new();
        let mut keep = Vec::new();
        for _ in 0..3 {
            let (r, rx) = req(None);
            q.push_back(r);
            keep.push(rx);
        }
        let run = pop_pinned_run(&mut q, 8);
        assert_eq!(run.len(), 3);
        assert!(q.is_empty());
        assert!(pop_pinned_run(&mut q, 8).is_empty());
    }
}
