//! Dynamic batcher.
//!
//! The AOT artifact set carries a small menu of batch sizes per path
//! (typically {1, 8}). The batcher groups pending requests into the
//! largest supported batch, flushing early when the oldest request's
//! queueing deadline expires — the standard latency/throughput dial.

use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// supported batch sizes, ascending (from the manifest)
    pub sizes: Vec<usize>,
    /// flush when the oldest pending request has waited this long
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn new(mut sizes: Vec<usize>, max_wait: Duration) -> BatchPolicy {
        assert!(!sizes.is_empty(), "need at least one batch size");
        sizes.sort_unstable();
        sizes.dedup();
        BatchPolicy { sizes, max_wait }
    }

    pub fn max_size(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Largest supported size `<= n` (always at least the smallest size).
    pub fn fit(&self, n: usize) -> usize {
        self.sizes
            .iter()
            .rev()
            .find(|&&s| s <= n)
            .copied()
            .unwrap_or(self.sizes[0])
    }

    /// Decide whether to emit a batch given `pending` queued requests and
    /// the enqueue time of the oldest. Returns the batch size to run now,
    /// or None to keep waiting.
    pub fn decide(&self, pending: usize, oldest: Option<Instant>, now: Instant) -> Option<usize> {
        if pending == 0 {
            return None;
        }
        if pending >= self.max_size() {
            return Some(self.max_size());
        }
        match oldest {
            Some(t) if now.duration_since(t) >= self.max_wait => Some(self.fit(pending)),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![8, 1], Duration::from_millis(2))
    }

    #[test]
    fn sizes_sorted_and_deduped() {
        let p = BatchPolicy::new(vec![8, 1, 8], Duration::from_millis(1));
        assert_eq!(p.sizes, vec![1, 8]);
        assert_eq!(p.max_size(), 8);
    }

    #[test]
    fn fit_picks_largest_le() {
        let p = policy();
        assert_eq!(p.fit(8), 8);
        assert_eq!(p.fit(12), 8);
        assert_eq!(p.fit(5), 1);
        assert_eq!(p.fit(0), 1);
    }

    #[test]
    fn full_batch_fires_immediately() {
        let p = policy();
        let now = Instant::now();
        assert_eq!(p.decide(8, Some(now), now), Some(8));
        assert_eq!(p.decide(20, Some(now), now), Some(8));
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let p = policy();
        let now = Instant::now();
        assert_eq!(p.decide(3, Some(now), now), None);
        let later = now + Duration::from_millis(3);
        assert_eq!(p.decide(3, Some(now), later), Some(1));
    }

    #[test]
    fn max_wait_boundary_is_inclusive() {
        // the deadline comparison is `elapsed >= max_wait`: one tick
        // before the boundary holds, the boundary itself fires
        let p = policy();
        let now = Instant::now();
        let just_before = now + Duration::from_millis(2) - Duration::from_nanos(1);
        assert_eq!(p.decide(3, Some(now), just_before), None);
        let exactly = now + Duration::from_millis(2);
        assert_eq!(p.decide(3, Some(now), exactly), Some(1));
    }

    #[test]
    fn empty_queue_never_fires() {
        let p = policy();
        assert_eq!(p.decide(0, None, Instant::now()), None);
    }
}
