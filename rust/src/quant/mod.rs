//! Fixed-point quantization (the intN datapath, `FP_rep` of Eq. 11).
//!
//! Mirrors `python/compile/kernels/ref.py`'s symmetric per-tensor scheme
//! so Rust-side tooling (simulator stimulus, artifact verification) agrees
//! bit-for-bit with the build-time kernels.

/// Quantization parameters for one tensor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub scale: f64,
    pub bits: u32,
}

impl QParams {
    pub fn qmax(bits: u32) -> i64 {
        (1i64 << (bits - 1)) - 1
    }

    pub fn qmin(bits: u32) -> i64 {
        -(1i64 << (bits - 1))
    }

    /// Symmetric per-tensor scale: max|x| maps to the int max.
    pub fn fit(data: &[f64], bits: u32) -> QParams {
        let amax = data.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-8);
        QParams { scale: amax / Self::qmax(bits) as f64, bits }
    }

    /// Round-to-nearest quantization with range clipping.
    pub fn quantize(&self, x: f64) -> i64 {
        let q = (x / self.scale).round() as i64;
        q.clamp(Self::qmin(self.bits), Self::qmax(self.bits))
    }

    pub fn dequantize(&self, q: i64) -> f64 {
        q as f64 * self.scale
    }

    /// Quantize-dequantize round trip (the fake-quant the Pallas kernels
    /// apply in their MAC epilogue).
    pub fn fake_quant(&self, x: f64) -> f64 {
        self.dequantize(self.quantize(x))
    }
}

/// Quantize a whole tensor, returning (values, params).
pub fn quantize_tensor(data: &[f64], bits: u32) -> (Vec<i64>, QParams) {
    let p = QParams::fit(data, bits);
    (data.iter().map(|&x| p.quantize(x)).collect(), p)
}

/// Max absolute reconstruction error over a tensor.
pub fn max_abs_error(data: &[f64], bits: u32) -> f64 {
    let p = QParams::fit(data, bits);
    data.iter()
        .map(|&x| (x - p.fake_quant(x)).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};
    use crate::util::rng::Rng;

    #[test]
    fn ranges() {
        assert_eq!(QParams::qmax(8), 127);
        assert_eq!(QParams::qmin(8), -128);
        assert_eq!(QParams::qmax(16), 32767);
    }

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        let data: Vec<f64> = (-50..=50).map(|i| i as f64 * 0.013).collect();
        let p = QParams::fit(&data, 8);
        for &x in &data {
            assert!((x - p.fake_quant(x)).abs() <= p.scale / 2.0 + 1e-12);
        }
    }

    #[test]
    fn int16_strictly_tighter_than_int8() {
        let data: Vec<f64> = (0..100).map(|i| (i as f64 * 0.7).sin()).collect();
        assert!(max_abs_error(&data, 16) < max_abs_error(&data, 8));
    }

    #[test]
    fn clipping_at_extremes() {
        let p = QParams { scale: 0.1, bits: 8 };
        assert_eq!(p.quantize(1e9), 127);
        assert_eq!(p.quantize(-1e9), -128);
    }

    #[test]
    fn prop_fake_quant_idempotent_and_clamped() {
        // quantize∘dequantize is a projection: applying it twice changes
        // nothing, and values beyond the fitted range pin EXACTLY to the
        // int8/int16 grid boundaries (the QAT forward pass in
        // `distill` relies on both properties).
        check(
            "quant-idempotent",
            300,
            21,
            |r: &mut Rng| {
                let n = r.below(48) + 2;
                let bits = if r.chance(0.5) { 8 } else { 16 };
                let data: Vec<f64> = (0..n).map(|_| r.gauss() * 5.0).collect();
                (data, bits)
            },
            |(data, bits)| {
                let p = QParams::fit(data, *bits);
                for &x in data {
                    let once = p.fake_quant(x);
                    let twice = p.fake_quant(once);
                    if once != twice {
                        return ensure(false, format!("not idempotent at {x}: {once} vs {twice}"));
                    }
                }
                // clamp behavior at the signed-int boundaries
                let (qmin, qmax) = (QParams::qmin(*bits), QParams::qmax(*bits));
                let amax = data.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-8);
                ensure(p.quantize(amax) == qmax, "amax must hit qmax")?;
                ensure(p.quantize(-amax) == -qmax, "symmetric scheme: -amax -> -qmax")?;
                ensure(p.quantize(amax * 10.0) == qmax, "overflow clamps to qmax")?;
                ensure(p.quantize(-amax * 10.0) == qmin, "underflow clamps to qmin")?;
                ensure(
                    p.fake_quant(amax * 10.0) == p.dequantize(qmax),
                    "clamped round trip lands on the top grid point",
                )
            },
        );
    }

    #[test]
    fn prop_roundtrip_error_half_ulp() {
        check(
            "quant-roundtrip",
            200,
            9,
            |r: &mut Rng| {
                let n = r.below(64) + 1;
                let bits = if r.chance(0.5) { 8 } else { 16 };
                let data: Vec<f64> = (0..n).map(|_| r.gauss() * 10.0).collect();
                (data, bits)
            },
            |(data, bits)| {
                let p = QParams::fit(data, *bits);
                for &x in data {
                    if (x - p.fake_quant(x)).abs() > p.scale / 2.0 + 1e-9 {
                        return ensure(false, format!("error beyond scale/2 at {x}"));
                    }
                }
                Ok(())
            },
        );
    }
}
