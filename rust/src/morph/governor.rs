//! The NeuroMorph governor: runtime mode-switch policy.
//!
//! Watches the operating budget (power and/or latency) and selects the
//! most accurate morph path that satisfies it, with:
//!
//! * **hysteresis** — a path must be violating/slack for `patience`
//!   consecutive observations before a switch fires (no thrash on noisy
//!   budgets);
//! * **full-frame reactivation delay** — re-enabling gated blocks stalls
//!   one frame while line buffers re-prime (Sec. V: "resume execution
//!   only after reactivation and a full-frame delay"). Switching *down*
//!   (gating more) is free: gated blocks simply stop toggling;
//! * **hard accuracy floor** — a DistillCycle
//!   [`AccuracyProfile`](crate::distill::AccuracyProfile) (or the
//!   application) pins the minimum deployable accuracy: a path below the
//!   floor is never selected, even when it wins on latency/power. The
//!   floor is *hard* and the budget *soft* — when no floor-meeting path
//!   fits the budget, the governor picks the cheapest floor-meeting path
//!   (a budget overrun) rather than an inaccurate one.

use super::{MorphPath, PathRegistry};

/// Operating budget at a point in time.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    /// max tolerable power draw (mW); None = unconstrained
    pub power_mw: Option<f64>,
    /// max tolerable frame latency (ms); None = unconstrained
    pub latency_ms: Option<f64>,
}

impl Budget {
    pub fn unconstrained() -> Budget {
        Budget { power_mw: None, latency_ms: None }
    }
}

/// Per-path runtime cost table the governor consults (filled from the
/// simulator or from live measurements).
#[derive(Debug, Clone)]
pub struct PathCosts {
    /// (path name, power mW, latency ms) in registry order
    pub rows: Vec<(String, f64, f64)>,
}

impl PathCosts {
    fn for_path(&self, name: &str) -> Option<(f64, f64)> {
        self.rows
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, p, l)| (*p, *l))
    }
}

/// Switch decision returned by [`Governor::observe`].
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    /// keep the current path
    Hold,
    /// switch to path (index into registry), paying `stall_frames`
    Switch { to: String, stall_frames: usize },
}

/// Governor state machine.
///
/// All per-observation state is tracked by *registry index* — `observe`
/// allocates nothing on the hot path (it runs per frame once the trace
/// loop drives it); the target path name is cloned only when a switch
/// actually fires.
#[derive(Debug)]
pub struct Governor {
    registry: PathRegistry,
    costs: PathCosts,
    /// index of the active path in the (cost-sorted) registry
    current: usize,
    /// consecutive observations pointing at a different best path
    pending: Option<(usize, usize)>,
    /// observations required before switching
    patience: usize,
    /// frames of stall when re-activating gated blocks
    reactivation_frames: usize,
    /// hard floor: paths below this accuracy are never selected
    accuracy_floor: f64,
    /// healthy fraction of the serving fleet in `(0, 1]`: effective
    /// latency is `lat / capacity`, so a degraded fleet pushes the
    /// governor down the ladder to hold a latency budget
    capacity: f64,
    /// frames remaining before another swap may be attempted (set after
    /// a failed-swap rollback; `observe` holds while it drains)
    cooldown: usize,
    /// switches performed (telemetry)
    pub switch_count: usize,
    /// failed-swap rollbacks performed (telemetry)
    pub rollback_count: usize,
}

impl Governor {
    pub fn new(registry: PathRegistry, costs: PathCosts, patience: usize) -> Governor {
        // the registry is cost-sorted: the full path is last
        let current = registry.paths().len() - 1;
        Governor {
            registry,
            costs,
            current,
            pending: None,
            patience: patience.max(1),
            reactivation_frames: 1,
            accuracy_floor: 0.0,
            capacity: 1.0,
            cooldown: 0,
            switch_count: 0,
            rollback_count: 0,
        }
    }

    /// Install a hard accuracy floor (typically
    /// `AccuracyProfile::floor()` or an application SLO). Paths with
    /// `accuracy < floor` are excluded from every selection; a path at
    /// exactly the floor remains deployable.
    pub fn with_accuracy_floor(mut self, floor: f64) -> Governor {
        self.accuracy_floor = floor;
        self
    }

    pub fn accuracy_floor(&self) -> f64 {
        self.accuracy_floor
    }

    pub fn current(&self) -> &str {
        &self.registry.paths()[self.current].name
    }

    /// Registry index of the active path (allocation-free identity for
    /// callers that log transitions).
    pub fn current_index(&self) -> usize {
        self.current
    }

    pub fn registry(&self) -> &PathRegistry {
        &self.registry
    }

    /// Report the healthy fraction of the serving fleet (clamped to a
    /// small positive floor — a fleet is never "all dead" for planning
    /// purposes; someone keeps answering). 1.0 restores nominal fits.
    pub fn set_capacity(&mut self, capacity: f64) {
        self.capacity = capacity.clamp(1e-6, 1.0);
    }

    pub fn capacity(&self) -> f64 {
        self.capacity
    }

    /// Hold the current path for `frames` observations before another
    /// swap may fire (the re-attempt backoff after a failed swap).
    pub fn begin_cooldown(&mut self, frames: usize) {
        self.cooldown = frames;
        self.pending = None;
    }

    pub fn in_cooldown(&self) -> bool {
        self.cooldown > 0
    }

    /// Revert to `to_index` after a failed swap: the outgoing path is
    /// still loaded (the DPR window never committed), so the revert is
    /// free — no reactivation stall, no hysteresis.
    pub fn rollback(&mut self, to_index: usize) {
        assert!(to_index < self.registry.paths().len(), "rollback to unknown path");
        self.current = to_index;
        self.pending = None;
        self.rollback_count += 1;
    }

    /// The most accurate floor-meeting path whose measured power &
    /// latency fit `budget`. The floor is hard, the budget soft: with no
    /// floor-meeting path inside the budget the cheapest floor-meeting
    /// path wins; only when NO path meets the floor at all (corrupt or
    /// untrained profile) does the governor fall back to the most
    /// accurate path available.
    fn best_for(&self, budget: &Budget) -> usize {
        let paths = self.registry.paths();
        let meets_floor = |i: &usize| paths[*i].accuracy >= self.accuracy_floor;
        let fits = |i: &usize| -> bool {
            match self.costs.for_path(&paths[*i].name) {
                Some((pw, lat)) => {
                    // effective latency degrades with fleet capacity:
                    // fewer healthy shards, longer queues per survivor
                    budget.power_mw.map(|b| pw <= b).unwrap_or(true)
                        && budget.latency_ms.map(|b| lat / self.capacity <= b).unwrap_or(true)
                }
                None => false,
            }
        };
        let most_accurate = |a: &usize, b: &usize| {
            paths[*a]
                .accuracy
                .partial_cmp(&paths[*b].accuracy)
                .unwrap()
                .then(paths[*b].macs.cmp(&paths[*a].macs)) // tie-break: cheaper
        };
        (0..paths.len())
            .filter(meets_floor)
            .filter(fits)
            .max_by(most_accurate)
            .or_else(|| {
                // budget infeasible: cheapest path that still meets the
                // floor (registry is cost-sorted — first match is it)
                (0..paths.len()).find(meets_floor)
            })
            .unwrap_or_else(|| {
                // nothing meets the floor: degrade as little as possible
                (0..paths.len())
                    .max_by(most_accurate)
                    .expect("registry is non-empty")
            })
    }

    /// Feed one budget observation; returns the (possibly Hold) decision.
    /// Allocation-free except when a switch actually fires.
    pub fn observe(&mut self, budget: &Budget) -> Decision {
        if self.cooldown > 0 {
            // post-rollback hold: the fabric needs quiet frames before
            // another DPR attempt; hysteresis restarts afterwards
            self.cooldown -= 1;
            self.pending = None;
            return Decision::Hold;
        }
        let target = self.best_for(budget);
        if target == self.current {
            self.pending = None;
            return Decision::Hold;
        }
        let count = match self.pending {
            Some((idx, n)) if idx == target => n + 1,
            _ => 1,
        };
        if count < self.patience {
            self.pending = Some((target, count));
            return Decision::Hold;
        }
        // fire the switch. The registry is cost-sorted, so a larger index
        // grows the active region and re-primes line buffers: 1 frame stall
        self.pending = None;
        let stall = if target > self.current { self.reactivation_frames } else { 0 };
        self.current = target;
        self.switch_count += 1;
        Decision::Switch {
            to: self.registry.paths()[target].name.clone(),
            stall_frames: stall,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> PathRegistry {
        PathRegistry::new(crate::morph::tests::sample_paths())
    }

    fn costs() -> PathCosts {
        PathCosts {
            rows: vec![
                ("d1_w100".into(), 480.0, 0.10),
                ("d3_w50".into(), 560.0, 0.25),
                ("d2_w100".into(), 610.0, 0.60),
                ("d3_w100".into(), 740.0, 1.20),
            ],
        }
    }

    #[test]
    fn starts_on_full_path() {
        let gov = Governor::new(registry(), costs(), 2);
        assert_eq!(gov.current(), "d3_w100");
        assert_eq!(gov.current_index(), gov.registry().paths().len() - 1);
    }

    #[test]
    fn current_index_tracks_switches() {
        let mut gov = Governor::new(registry(), costs(), 1);
        let tight = Budget { power_mw: Some(500.0), latency_ms: None };
        gov.observe(&tight);
        let idx = gov.current_index();
        assert_eq!(gov.registry().paths()[idx].name, gov.current());
        assert_eq!(gov.current(), "d1_w100");
        gov.observe(&Budget::unconstrained());
        assert_eq!(
            gov.registry().paths()[gov.current_index()].name,
            "d3_w100"
        );
    }

    #[test]
    fn unconstrained_holds_full() {
        let mut gov = Governor::new(registry(), costs(), 1);
        assert_eq!(gov.observe(&Budget::unconstrained()), Decision::Hold);
        assert_eq!(gov.current(), "d3_w100");
    }

    #[test]
    fn power_squeeze_downshifts_immediately_with_patience_1() {
        let mut gov = Governor::new(registry(), costs(), 1);
        let tight = Budget { power_mw: Some(500.0), latency_ms: None };
        match gov.observe(&tight) {
            Decision::Switch { to, stall_frames } => {
                assert_eq!(to, "d1_w100");
                assert_eq!(stall_frames, 0, "downshift is free");
            }
            d => panic!("expected switch, got {d:?}"),
        }
    }

    #[test]
    fn hysteresis_requires_patience() {
        let mut gov = Governor::new(registry(), costs(), 3);
        let tight = Budget { power_mw: Some(500.0), latency_ms: None };
        assert_eq!(gov.observe(&tight), Decision::Hold);
        assert_eq!(gov.observe(&tight), Decision::Hold);
        assert!(matches!(gov.observe(&tight), Decision::Switch { .. }));
    }

    #[test]
    fn patience_delays_switch_by_exactly_k_observations() {
        // the k-th consecutive observation fires, never earlier: the
        // hysteresis that keeps a noisy budget from thrashing the fabric
        for patience in 1..=5usize {
            let mut gov = Governor::new(registry(), costs(), patience);
            let tight = Budget { power_mw: Some(500.0), latency_ms: None };
            for i in 1..patience {
                assert_eq!(
                    gov.observe(&tight),
                    Decision::Hold,
                    "patience {patience}: observation {i} must hold"
                );
                assert_eq!(gov.current(), "d3_w100");
            }
            assert!(
                matches!(gov.observe(&tight), Decision::Switch { .. }),
                "patience {patience}: observation {patience} must switch"
            );
        }
    }

    #[test]
    fn flapping_budget_resets_pending() {
        let mut gov = Governor::new(registry(), costs(), 2);
        let tight = Budget { power_mw: Some(500.0), latency_ms: None };
        assert_eq!(gov.observe(&tight), Decision::Hold);
        // budget relaxes: pending downshift must reset
        assert_eq!(gov.observe(&Budget::unconstrained()), Decision::Hold);
        assert_eq!(gov.observe(&tight), Decision::Hold);
        assert_eq!(gov.current(), "d3_w100");
    }

    #[test]
    fn upshift_pays_reactivation_stall() {
        let mut gov = Governor::new(registry(), costs(), 1);
        let tight = Budget { power_mw: Some(500.0), latency_ms: None };
        gov.observe(&tight); // down to d1
        assert_eq!(gov.current(), "d1_w100");
        match gov.observe(&Budget::unconstrained()) {
            Decision::Switch { to, stall_frames } => {
                assert_eq!(to, "d3_w100");
                assert_eq!(stall_frames, 1, "upshift re-primes line buffers");
            }
            d => panic!("expected switch, got {d:?}"),
        }
        assert_eq!(gov.switch_count, 2);
    }

    #[test]
    fn latency_budget_selects_mid_path() {
        let mut gov = Governor::new(registry(), costs(), 1);
        let b = Budget { power_mw: None, latency_ms: Some(0.7) };
        match gov.observe(&b) {
            // d2 fits (0.6 <= 0.7) and beats d3_w50/d1 on accuracy
            Decision::Switch { to, .. } => assert_eq!(to, "d2_w100"),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn impossible_budget_falls_back_to_lightest() {
        let mut gov = Governor::new(registry(), costs(), 1);
        let b = Budget { power_mw: Some(1.0), latency_ms: Some(0.0001) };
        match gov.observe(&b) {
            Decision::Switch { to, .. } => assert_eq!(to, "d1_w100"),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn below_floor_paths_never_selected_even_when_they_win_on_cost() {
        // d1_w100 (acc 0.93) wins every power/latency comparison, but a
        // 0.94 floor bans it: the governor must hold the floor on ANY
        // budget trace, including ones only d1 could satisfy.
        let mut gov = Governor::new(registry(), costs(), 1).with_accuracy_floor(0.94);
        let traces = [
            Budget { power_mw: Some(500.0), latency_ms: None }, // only d1 fits
            Budget { power_mw: Some(1.0), latency_ms: Some(0.0001) }, // nothing fits
            Budget { power_mw: Some(600.0), latency_ms: Some(0.3) }, // d1/d3_w50 region
            Budget::unconstrained(),
        ];
        for b in &traces {
            gov.observe(b);
            let cur = gov.registry().by_name(gov.current()).unwrap();
            assert!(
                cur.accuracy >= 0.94,
                "budget {b:?} selected below-floor path {} ({})",
                cur.name,
                cur.accuracy
            );
            assert_ne!(gov.current(), "d1_w100");
        }
    }

    #[test]
    fn floor_is_hard_budget_is_soft() {
        // floor 0.96 leaves {d2_w100 (610 mW), d3_w100 (740 mW)}; a
        // 500 mW cap fits neither -> the governor overruns the budget
        // with the cheapest floor-meeting path instead of dropping to d1
        let mut gov = Governor::new(registry(), costs(), 1).with_accuracy_floor(0.96);
        let tight = Budget { power_mw: Some(500.0), latency_ms: None };
        match gov.observe(&tight) {
            Decision::Switch { to, .. } => assert_eq!(to, "d2_w100"),
            d => panic!("expected budget-overrun switch to d2_w100, got {d:?}"),
        }
    }

    #[test]
    fn exactly_equal_accuracy_meets_the_floor() {
        // boundary: a path AT the floor stays deployable. Floor 0.95 ==
        // d3_w50's accuracy; with a budget only d1 (0.93) and d3_w50
        // (0.95) can satisfy, d3_w50 must be chosen.
        let mut gov = Governor::new(registry(), costs(), 1).with_accuracy_floor(0.95);
        let b = Budget { power_mw: Some(560.0), latency_ms: None };
        match gov.observe(&b) {
            Decision::Switch { to, .. } => assert_eq!(to, "d3_w50"),
            d => panic!("{d:?}"),
        }
        // nudging the floor past it bans it
        let mut gov = Governor::new(registry(), costs(), 1)
            .with_accuracy_floor(0.95 + 1e-12);
        match gov.observe(&b) {
            Decision::Switch { to, .. } => assert_eq!(to, "d2_w100", "soft-budget overrun"),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn unmeetable_floor_degrades_to_most_accurate() {
        let mut gov = Governor::new(registry(), costs(), 1).with_accuracy_floor(0.999);
        // full path is already current (most accurate): hold, never panic
        assert_eq!(gov.observe(&Budget { power_mw: Some(1.0), latency_ms: None }), Decision::Hold);
        assert_eq!(gov.current(), "d3_w100");
    }

    #[test]
    fn reduced_capacity_degrades_down_the_ladder() {
        // at full capacity a 0.7 ms budget picks d2_w100 (0.6 ms); at
        // half capacity its effective latency doubles to 1.2 ms, so the
        // governor degrades to d3_w50 (0.25/0.5 = 0.5 ms effective)
        let mut gov = Governor::new(registry(), costs(), 1);
        let b = Budget { power_mw: None, latency_ms: Some(0.7) };
        gov.set_capacity(0.5);
        match gov.observe(&b) {
            Decision::Switch { to, .. } => assert_eq!(to, "d3_w50"),
            d => panic!("{d:?}"),
        }
        // healing restores the nominal choice
        gov.set_capacity(1.0);
        match gov.observe(&b) {
            Decision::Switch { to, .. } => assert_eq!(to, "d2_w100"),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn capacity_never_degrades_below_the_floor() {
        // floor 0.96 leaves {d2_w100, d3_w100}; even a nearly dead fleet
        // must not pick a below-floor path — budget overrun instead
        let mut gov = Governor::new(registry(), costs(), 1).with_accuracy_floor(0.96);
        gov.set_capacity(0.25);
        let b = Budget { power_mw: None, latency_ms: Some(0.7) };
        match gov.observe(&b) {
            Decision::Switch { to, .. } => assert_eq!(to, "d2_w100"),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn full_capacity_is_bitwise_legacy() {
        // `lat / 1.0` must be exactly `lat`: the default-capacity
        // governor replays pre-fault decision logs byte-identically
        let mut a = Governor::new(registry(), costs(), 2);
        let mut b = Governor::new(registry(), costs(), 2);
        b.set_capacity(1.0);
        let budgets = [
            Budget { power_mw: Some(500.0), latency_ms: Some(0.6) },
            Budget { power_mw: None, latency_ms: Some(0.25) },
            Budget::unconstrained(),
        ];
        for budget in budgets.iter().cycle().take(30) {
            assert_eq!(a.observe(budget), b.observe(budget));
        }
    }

    #[test]
    fn rollback_reverts_without_stall_and_cooldown_holds() {
        let mut gov = Governor::new(registry(), costs(), 1);
        let from = gov.current_index();
        let tight = Budget { power_mw: Some(500.0), latency_ms: None };
        assert!(matches!(gov.observe(&tight), Decision::Switch { .. }));
        // the swap failed mid-window: revert and cool down
        gov.rollback(from);
        assert_eq!(gov.current(), "d3_w100");
        assert_eq!(gov.rollback_count, 1);
        gov.begin_cooldown(3);
        for i in 0..3 {
            assert!(gov.in_cooldown(), "cooldown frame {i}");
            assert_eq!(gov.observe(&tight), Decision::Hold, "cooldown frame {i}");
        }
        assert!(!gov.in_cooldown());
        // after the cooldown the re-attempt fires through normal hysteresis
        match gov.observe(&tight) {
            Decision::Switch { to, .. } => assert_eq!(to, "d1_w100"),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn zero_floor_preserves_legacy_behavior() {
        // with the default floor the selection must match the pre-floor
        // governor on every test budget above
        let mut legacy = Governor::new(registry(), costs(), 1);
        let b = Budget { power_mw: Some(500.0), latency_ms: None };
        match legacy.observe(&b) {
            Decision::Switch { to, .. } => assert_eq!(to, "d1_w100"),
            d => panic!("{d:?}"),
        }
    }
}
