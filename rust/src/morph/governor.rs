//! The NeuroMorph governor: runtime mode-switch policy.
//!
//! Watches the operating budget (power and/or latency) and selects the
//! most accurate morph path that satisfies it, with:
//!
//! * **hysteresis** — a path must be violating/slack for `patience`
//!   consecutive observations before a switch fires (no thrash on noisy
//!   budgets);
//! * **full-frame reactivation delay** — re-enabling gated blocks stalls
//!   one frame while line buffers re-prime (Sec. V: "resume execution
//!   only after reactivation and a full-frame delay"). Switching *down*
//!   (gating more) is free: gated blocks simply stop toggling.

use super::{MorphPath, PathRegistry};

/// Operating budget at a point in time.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    /// max tolerable power draw (mW); None = unconstrained
    pub power_mw: Option<f64>,
    /// max tolerable frame latency (ms); None = unconstrained
    pub latency_ms: Option<f64>,
}

impl Budget {
    pub fn unconstrained() -> Budget {
        Budget { power_mw: None, latency_ms: None }
    }
}

/// Per-path runtime cost table the governor consults (filled from the
/// simulator or from live measurements).
#[derive(Debug, Clone)]
pub struct PathCosts {
    /// (path name, power mW, latency ms) in registry order
    pub rows: Vec<(String, f64, f64)>,
}

impl PathCosts {
    fn for_path(&self, name: &str) -> Option<(f64, f64)> {
        self.rows
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, p, l)| (*p, *l))
    }
}

/// Switch decision returned by [`Governor::observe`].
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    /// keep the current path
    Hold,
    /// switch to path (index into registry), paying `stall_frames`
    Switch { to: String, stall_frames: usize },
}

/// Governor state machine.
#[derive(Debug)]
pub struct Governor {
    registry: PathRegistry,
    costs: PathCosts,
    current: String,
    /// consecutive observations pointing at a different best path
    pending: Option<(String, usize)>,
    /// observations required before switching
    patience: usize,
    /// frames of stall when re-activating gated blocks
    reactivation_frames: usize,
    /// switches performed (telemetry)
    pub switch_count: usize,
}

impl Governor {
    pub fn new(registry: PathRegistry, costs: PathCosts, patience: usize) -> Governor {
        let current = registry.full().name.clone();
        Governor {
            registry,
            costs,
            current,
            pending: None,
            patience: patience.max(1),
            reactivation_frames: 1,
            switch_count: 0,
        }
    }

    pub fn current(&self) -> &str {
        &self.current
    }

    pub fn registry(&self) -> &PathRegistry {
        &self.registry
    }

    /// The most accurate path whose measured power & latency fit `budget`.
    fn best_for(&self, budget: &Budget) -> &MorphPath {
        let fits = |p: &&MorphPath| -> bool {
            match self.costs.for_path(&p.name) {
                Some((pw, lat)) => {
                    budget.power_mw.map(|b| pw <= b).unwrap_or(true)
                        && budget.latency_ms.map(|b| lat <= b).unwrap_or(true)
                }
                None => false,
            }
        };
        self.registry
            .paths()
            .iter()
            .filter(fits)
            .max_by(|a, b| {
                a.accuracy
                    .partial_cmp(&b.accuracy)
                    .unwrap()
                    .then(b.macs.cmp(&a.macs)) // tie-break: cheaper
            })
            .unwrap_or_else(|| self.registry.lightest())
    }

    /// Feed one budget observation; returns the (possibly Hold) decision.
    pub fn observe(&mut self, budget: &Budget) -> Decision {
        let target = self.best_for(budget).name.clone();
        if target == self.current {
            self.pending = None;
            return Decision::Hold;
        }
        let count = match &self.pending {
            Some((name, n)) if *name == target => n + 1,
            _ => 1,
        };
        if count < self.patience {
            self.pending = Some((target, count));
            return Decision::Hold;
        }
        // fire the switch
        self.pending = None;
        let from_idx = self.registry.index_of(&self.current).unwrap();
        let to_idx = self.registry.index_of(&target).unwrap();
        // growing the active region re-primes line buffers: 1 frame stall
        let stall = if to_idx > from_idx { self.reactivation_frames } else { 0 };
        self.current = target.clone();
        self.switch_count += 1;
        Decision::Switch { to: target, stall_frames: stall }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> PathRegistry {
        PathRegistry::new(crate::morph::tests::sample_paths())
    }

    fn costs() -> PathCosts {
        PathCosts {
            rows: vec![
                ("d1_w100".into(), 480.0, 0.10),
                ("d3_w50".into(), 560.0, 0.25),
                ("d2_w100".into(), 610.0, 0.60),
                ("d3_w100".into(), 740.0, 1.20),
            ],
        }
    }

    #[test]
    fn starts_on_full_path() {
        let gov = Governor::new(registry(), costs(), 2);
        assert_eq!(gov.current(), "d3_w100");
    }

    #[test]
    fn unconstrained_holds_full() {
        let mut gov = Governor::new(registry(), costs(), 1);
        assert_eq!(gov.observe(&Budget::unconstrained()), Decision::Hold);
        assert_eq!(gov.current(), "d3_w100");
    }

    #[test]
    fn power_squeeze_downshifts_immediately_with_patience_1() {
        let mut gov = Governor::new(registry(), costs(), 1);
        let tight = Budget { power_mw: Some(500.0), latency_ms: None };
        match gov.observe(&tight) {
            Decision::Switch { to, stall_frames } => {
                assert_eq!(to, "d1_w100");
                assert_eq!(stall_frames, 0, "downshift is free");
            }
            d => panic!("expected switch, got {d:?}"),
        }
    }

    #[test]
    fn hysteresis_requires_patience() {
        let mut gov = Governor::new(registry(), costs(), 3);
        let tight = Budget { power_mw: Some(500.0), latency_ms: None };
        assert_eq!(gov.observe(&tight), Decision::Hold);
        assert_eq!(gov.observe(&tight), Decision::Hold);
        assert!(matches!(gov.observe(&tight), Decision::Switch { .. }));
    }

    #[test]
    fn patience_delays_switch_by_exactly_k_observations() {
        // the k-th consecutive observation fires, never earlier: the
        // hysteresis that keeps a noisy budget from thrashing the fabric
        for patience in 1..=5usize {
            let mut gov = Governor::new(registry(), costs(), patience);
            let tight = Budget { power_mw: Some(500.0), latency_ms: None };
            for i in 1..patience {
                assert_eq!(
                    gov.observe(&tight),
                    Decision::Hold,
                    "patience {patience}: observation {i} must hold"
                );
                assert_eq!(gov.current(), "d3_w100");
            }
            assert!(
                matches!(gov.observe(&tight), Decision::Switch { .. }),
                "patience {patience}: observation {patience} must switch"
            );
        }
    }

    #[test]
    fn flapping_budget_resets_pending() {
        let mut gov = Governor::new(registry(), costs(), 2);
        let tight = Budget { power_mw: Some(500.0), latency_ms: None };
        assert_eq!(gov.observe(&tight), Decision::Hold);
        // budget relaxes: pending downshift must reset
        assert_eq!(gov.observe(&Budget::unconstrained()), Decision::Hold);
        assert_eq!(gov.observe(&tight), Decision::Hold);
        assert_eq!(gov.current(), "d3_w100");
    }

    #[test]
    fn upshift_pays_reactivation_stall() {
        let mut gov = Governor::new(registry(), costs(), 1);
        let tight = Budget { power_mw: Some(500.0), latency_ms: None };
        gov.observe(&tight); // down to d1
        assert_eq!(gov.current(), "d1_w100");
        match gov.observe(&Budget::unconstrained()) {
            Decision::Switch { to, stall_frames } => {
                assert_eq!(to, "d3_w100");
                assert_eq!(stall_frames, 1, "upshift re-primes line buffers");
            }
            d => panic!("expected switch, got {d:?}"),
        }
        assert_eq!(gov.switch_count, 2);
    }

    #[test]
    fn latency_budget_selects_mid_path() {
        let mut gov = Governor::new(registry(), costs(), 1);
        let b = Budget { power_mw: None, latency_ms: Some(0.7) };
        match gov.observe(&b) {
            // d2 fits (0.6 <= 0.7) and beats d3_w50/d1 on accuracy
            Decision::Switch { to, .. } => assert_eq!(to, "d2_w100"),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn impossible_budget_falls_back_to_lightest() {
        let mut gov = Governor::new(registry(), costs(), 1);
        let b = Budget { power_mw: Some(1.0), latency_ms: Some(0.0001) };
        match gov.observe(&b) {
            Decision::Switch { to, .. } => assert_eq!(to, "d1_w100"),
            d => panic!("{d:?}"),
        }
    }
}
