//! NeuroMorph — online design reconfiguration (Sec. IV).
//!
//! A deployed ForgeMorph design carries every morph path in one
//! "bitstream": all subnetwork PEs are present, and lightweight toggles
//! clock-gate the inactive ones. This module is the runtime half:
//!
//! * [`MorphPath`] / [`PathRegistry`] — the DistillCycle-trained
//!   execution paths (depth prefixes + width fractions) with their
//!   accuracy/cost metadata, loaded from the AOT manifest.
//! * [`governor`] — the mode-switch policy: budget-driven selection with
//!   hysteresis and the full-frame reactivation delay of Sec. V.
//! * [`GateMask`](crate::sim::GateMask) translation — depth/width morphs
//!   map onto simulator/RTL clock-gate masks via [`gate_mask_for`].

pub mod governor;
pub mod schedule;

use crate::graph::{shapes, LayerKind, Network};
use crate::sim::{GateError, GateMask};

/// A morph path that cannot be lowered onto the deployed fabric — the
/// explicit error a corrupt manifest hits at the morph/governor boundary
/// instead of silently running at a clamped width.
#[derive(Debug, Clone, PartialEq)]
pub enum MorphError {
    /// width percentage outside the deployable (10..=100] range
    Width { path: String, pct: usize },
}

impl std::fmt::Display for MorphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MorphError::Width { path, pct } => write!(
                f,
                "morph path '{path}': width {pct}% outside the deployable \
                 range (10..=100) — rejecting instead of clamping"
            ),
        }
    }
}

impl std::error::Error for MorphError {}

/// One morphable execution path (a (depth, width) pair with a dedicated
/// output head — Fig. 9).
#[derive(Debug, Clone, PartialEq)]
pub struct MorphPath {
    pub name: String,
    pub depth: usize,
    pub width_pct: usize,
    /// DistillCycle test accuracy of this path
    pub accuracy: f64,
    /// active parameters on this path
    pub params: usize,
    /// MACs per frame on this path (the governor's cost signal)
    pub macs: usize,
}

impl MorphPath {
    /// Relative compute cost vs a reference path.
    pub fn cost_ratio(&self, reference: &MorphPath) -> f64 {
        self.macs as f64 / reference.macs as f64
    }
}

/// The deployed path set, sorted by ascending compute cost.
#[derive(Debug, Clone)]
pub struct PathRegistry {
    paths: Vec<MorphPath>,
}

impl PathRegistry {
    pub fn new(mut paths: Vec<MorphPath>) -> PathRegistry {
        assert!(!paths.is_empty(), "registry needs at least one path");
        paths.sort_by_key(|p| p.macs);
        PathRegistry { paths }
    }

    pub fn paths(&self) -> &[MorphPath] {
        &self.paths
    }

    /// The full network (highest-cost path).
    pub fn full(&self) -> &MorphPath {
        self.paths.last().unwrap()
    }

    /// Cheapest path.
    pub fn lightest(&self) -> &MorphPath {
        self.paths.first().unwrap()
    }

    pub fn by_name(&self, name: &str) -> Option<&MorphPath> {
        self.paths.iter().find(|p| p.name == name)
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.paths.iter().position(|p| p.name == name)
    }

    /// Most accurate path whose MACs fit the budget; falls back to the
    /// lightest path when nothing fits.
    pub fn best_within_macs(&self, macs_budget: usize) -> &MorphPath {
        self.paths
            .iter()
            .filter(|p| p.macs <= macs_budget)
            .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
            .unwrap_or_else(|| self.lightest())
    }
}

/// Synthetic depth-path ladder for networks that carry no AOT manifest
/// (the sim/analytical serving backends): one path per conv-block
/// prefix, with MACs/params accumulated from the shape-inferred
/// per-block work and a monotone accuracy ladder standing in for
/// DistillCycle calibration. The full-depth path lands at 0.99.
pub fn depth_ladder(net: &Network) -> Vec<MorphPath> {
    let shp = shapes::infer(net).expect("validated network");
    let mut block_work: Vec<(usize, usize)> = Vec::new(); // (macs, params)
    for layer in &net.layers {
        match &layer.kind {
            LayerKind::Conv { filters, k, .. } => {
                let inp = shp.input(layer.id);
                let out = shp.output(layer.id);
                block_work.push((
                    k * k * inp.c * filters * out.h * out.w,
                    k * k * inp.c * filters + filters,
                ));
            }
            LayerKind::DwConv { k, .. } => {
                let inp = shp.input(layer.id);
                let out = shp.output(layer.id);
                block_work.push((k * k * inp.c * out.h * out.w, k * k * inp.c + inp.c));
            }
            _ => {}
        }
    }
    let d_max = block_work.len().max(1);
    let mut macs_acc = 0usize;
    let mut params_acc = 0usize;
    block_work
        .iter()
        .enumerate()
        .map(|(i, &(m, p))| {
            let depth = i + 1;
            macs_acc += m;
            params_acc += p;
            MorphPath {
                name: format!("d{depth}_w100"),
                depth,
                width_pct: 100,
                accuracy: 0.90 + 0.09 * depth as f64 / d_max as f64,
                params: params_acc,
                macs: macs_acc,
            }
        })
        .collect()
}

/// Translate a morph path into the clock-gate mask the simulator/RTL
/// use. Gate bits follow the StagePlan's gate-block numbering (== the
/// network's conv-like stage order). A width outside the deployable
/// range is an explicit error — the governor refuses the path instead of
/// silently clamping a corrupt manifest to 10% width.
pub fn gate_mask_for(net: &Network, path: &MorphPath) -> Result<GateMask, MorphError> {
    let n_blocks = net.conv_layer_ids().len();
    if path.width_pct != 100 {
        GateMask::try_width(path.width_pct as f64 / 100.0).map_err(|_: GateError| {
            MorphError::Width { path: path.name.clone(), pct: path.width_pct }
        })
    } else if path.depth < n_blocks {
        Ok(GateMask::depth_prefix(net, path.depth))
    } else {
        Ok(GateMask::all_active())
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::graph::zoo;

    pub(crate) fn sample_paths() -> Vec<MorphPath> {
        vec![
            MorphPath { name: "d3_w100".into(), depth: 3, width_pct: 100, accuracy: 0.99, params: 8778, macs: 510_912 },
            MorphPath { name: "d1_w100".into(), depth: 1, width_pct: 100, accuracy: 0.93, params: 15_762, macs: 72_128 },
            MorphPath { name: "d2_w100".into(), depth: 2, width_pct: 100, accuracy: 0.96, params: 9114, macs: 293_216 },
            MorphPath { name: "d3_w50".into(), depth: 3, width_pct: 50, accuracy: 0.95, params: 3562, macs: 140_048 },
        ]
    }

    #[test]
    fn registry_sorted_by_cost() {
        let reg = PathRegistry::new(sample_paths());
        let names: Vec<&str> = reg.paths().iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["d1_w100", "d3_w50", "d2_w100", "d3_w100"]);
        assert_eq!(reg.full().name, "d3_w100");
        assert_eq!(reg.lightest().name, "d1_w100");
    }

    #[test]
    fn budget_selection_prefers_accuracy() {
        let reg = PathRegistry::new(sample_paths());
        // budget fits d1 and d3_w50: d3_w50 has higher accuracy
        assert_eq!(reg.best_within_macs(150_000).name, "d3_w50");
        // everything fits: full path wins on accuracy
        assert_eq!(reg.best_within_macs(usize::MAX).name, "d3_w100");
        // nothing fits: fall back to lightest
        assert_eq!(reg.best_within_macs(10).name, "d1_w100");
    }

    #[test]
    fn gate_masks() {
        let net = zoo::mnist();
        let reg = PathRegistry::new(sample_paths());
        let full = gate_mask_for(&net, reg.by_name("d3_w100").unwrap()).unwrap();
        assert!(full.block_active.is_empty() && full.width_fraction == 1.0);
        let d1 = gate_mask_for(&net, reg.by_name("d1_w100").unwrap()).unwrap();
        assert_eq!(d1.block_active, vec![true, false, false]);
        let w50 = gate_mask_for(&net, reg.by_name("d3_w50").unwrap()).unwrap();
        assert!((w50.width_fraction - 0.5).abs() < 1e-9);
    }

    #[test]
    fn bad_manifest_width_is_rejected_not_clamped() {
        let net = zoo::mnist();
        for pct in [0usize, 5, 9, 101, 500] {
            let path = MorphPath {
                name: format!("d3_w{pct}"),
                depth: 3,
                width_pct: pct,
                accuracy: 0.5,
                params: 1,
                macs: 1,
            };
            let err = gate_mask_for(&net, &path).unwrap_err();
            let MorphError::Width { pct: got, .. } = err.clone();
            assert_eq!(got, pct);
            assert!(err.to_string().contains("rejecting"), "{err}");
        }
    }

    #[test]
    fn depth_ladder_monotone() {
        let net = zoo::mnist();
        let ladder = depth_ladder(&net);
        assert_eq!(ladder.len(), 3);
        assert!(ladder
            .windows(2)
            .all(|w| w[0].macs < w[1].macs && w[0].accuracy < w[1].accuracy));
        let full = ladder.last().unwrap();
        assert_eq!(full.name, "d3_w100");
        assert!((full.accuracy - 0.99).abs() < 1e-9);
        // registry order must equal depth order (macs are cumulative)
        let reg = PathRegistry::new(ladder);
        assert_eq!(reg.full().depth, 3);
        assert_eq!(reg.lightest().depth, 1);
    }

    #[test]
    fn cost_ratio() {
        let reg = PathRegistry::new(sample_paths());
        let r = reg.lightest().cost_ratio(reg.full());
        assert!(r < 0.2, "{r}");
    }
}
