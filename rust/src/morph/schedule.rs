//! Morph-configuration extraction (the paper's stated future work,
//! Sec. VII: "automating NeuroMorph's configuration extraction via
//! combinatorial analysis, enabling automatic selection of optimal
//! runtime paths that meet application-specific accuracy constraints").
//!
//! Given the full (depth, width) candidate lattice with measured
//! accuracy and simulated cost, select the small set of paths worth
//! baking into the deployment:
//!
//! 1. prune paths below the accuracy floor;
//! 2. keep only the accuracy/cost Pareto frontier (a slower path must be
//!    more accurate to earn its gates);
//! 3. cap the set size by maximizing coverage of the cost axis (the
//!    governor wants well-spread operating points, not near-duplicates).

use super::MorphPath;

/// A morph candidate with its simulated runtime cost.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub path: MorphPath,
    pub latency_ms: f64,
    pub power_mw: f64,
}

/// Selection constraints.
#[derive(Debug, Clone, Copy)]
pub struct ScheduleSpec {
    /// drop candidates below this accuracy
    pub min_accuracy: f64,
    /// maximum number of deployed paths (gate-toggle ROM size)
    pub max_paths: usize,
}

impl Default for ScheduleSpec {
    fn default() -> Self {
        ScheduleSpec { min_accuracy: 0.0, max_paths: 4 }
    }
}

/// Accuracy/latency Pareto filter: keep candidates not dominated by a
/// faster-and-at-least-as-accurate alternative.
pub fn pareto_paths(mut cands: Vec<Candidate>) -> Vec<Candidate> {
    cands.sort_by(|a, b| a.latency_ms.partial_cmp(&b.latency_ms).unwrap());
    let mut out: Vec<Candidate> = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for c in cands {
        if c.path.accuracy > best_acc + 1e-12 {
            best_acc = c.path.accuracy;
            out.push(c);
        }
    }
    out
}

/// Full extraction pipeline: floor -> Pareto -> spread-capped subset.
pub fn extract(cands: Vec<Candidate>, spec: &ScheduleSpec) -> Vec<Candidate> {
    let eligible: Vec<Candidate> = cands
        .into_iter()
        .filter(|c| c.path.accuracy >= spec.min_accuracy)
        .collect();
    let front = pareto_paths(eligible);
    if front.len() <= spec.max_paths {
        return front;
    }
    // maximize spread over the (log) latency axis: always keep the two
    // extremes, then greedily insert the candidate farthest from its
    // nearest kept neighbour
    let mut keep = vec![0usize, front.len() - 1];
    while keep.len() < spec.max_paths {
        let mut best: Option<(usize, f64)> = None;
        for (i, c) in front.iter().enumerate() {
            if keep.contains(&i) {
                continue;
            }
            let d = keep
                .iter()
                .map(|&j| (c.latency_ms.ln() - front[j].latency_ms.ln()).abs())
                .fold(f64::INFINITY, f64::min);
            if best.map(|(_, bd)| d > bd).unwrap_or(true) {
                best = Some((i, d));
            }
        }
        keep.push(best.expect("front larger than keep set").0);
    }
    keep.sort_unstable();
    keep.into_iter().map(|i| front[i].clone()).collect()
}

/// Drain→swap→resume timeline of one runtime morph transition (Sec. V).
///
/// The serving engine realizes a governor switch in three phases:
/// requests already pinned to the outgoing path **drain** on it (no
/// in-flight request is ever lost to a reconfiguration), the fabric
/// **swaps** its clock-gate state — the modeled DPR window: the
/// governor's reactivation stall times the full-path frame period, zero
/// on a pure down-shift where gated blocks simply stop toggling — and
/// the incoming path **resumes**.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwapTimeline {
    /// reactivation stall in frames (0 for down-shifts)
    pub stall_frames: usize,
    /// modeled reconfiguration window, milliseconds
    pub swap_ms: f64,
}

impl SwapTimeline {
    /// The modeled DPR window in whole microseconds — the span length
    /// trace recording (`obs`) stamps for a switch's swap window or a
    /// rollback's wasted window.
    pub fn window_us(&self) -> u64 {
        (self.swap_ms.max(0.0) * 1_000.0).round() as u64
    }
}

/// Timeline of a switch that stalls `stall_frames` full frames of
/// `full_frame_ms` each (the paper's full-frame reactivation delay).
pub fn swap_timeline(stall_frames: usize, full_frame_ms: f64) -> SwapTimeline {
    SwapTimeline {
        stall_frames,
        swap_ms: stall_frames as f64 * full_frame_ms.max(0.0),
    }
}

/// Outcome of one DPR swap attempt: the timeline is always paid (the
/// window opened), but a failed attempt never commits — the outgoing
/// path is still loaded, so the runtime rolls back to it and cools down
/// before re-attempting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwapAttempt {
    pub timeline: SwapTimeline,
    /// did the incoming path actually load?
    pub committed: bool,
    /// frames the governor must hold before the next attempt (0 when
    /// committed)
    pub cooldown_frames: usize,
}

/// Frames of post-rollback quiet time after a failed swap. One full DPR
/// window's worth of frames on this fabric class: long enough that a
/// persistently failing region doesn't thrash drain→fail→drain.
pub const ROLLBACK_COOLDOWN_FRAMES: usize = 8;

/// Model one swap attempt. A failing attempt (injected via
/// `--fault-trace swapfail`) still pays the full modeled window — the
/// fabric was mid-reconfiguration when the CRC check rejected the
/// partial bitstream — then reports rollback with a cooldown.
pub fn attempt_swap(
    stall_frames: usize,
    full_frame_ms: f64,
    fail: bool,
    cooldown_frames: usize,
) -> SwapAttempt {
    SwapAttempt {
        timeline: swap_timeline(stall_frames, full_frame_ms),
        committed: !fail,
        cooldown_frames: if fail { cooldown_frames } else { 0 },
    }
}

/// Accuracy-constrained operating point: the cheapest kept path meeting
/// `min_accuracy` (what the paper's future-work selector would return).
pub fn cheapest_meeting<'a>(
    selected: &'a [Candidate],
    min_accuracy: f64,
) -> Option<&'a Candidate> {
    selected
        .iter()
        .filter(|c| c.path.accuracy >= min_accuracy)
        .min_by(|a, b| a.latency_ms.partial_cmp(&b.latency_ms).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(name: &str, acc: f64, lat: f64) -> Candidate {
        Candidate {
            path: MorphPath {
                name: name.into(),
                depth: 1,
                width_pct: 100,
                accuracy: acc,
                params: 0,
                macs: (lat * 1000.0) as usize,
            },
            latency_ms: lat,
            power_mw: 500.0,
        }
    }

    #[test]
    fn pareto_drops_dominated() {
        let front = pareto_paths(vec![
            cand("a", 0.90, 1.0),
            cand("b", 0.85, 2.0), // slower AND less accurate -> dropped
            cand("c", 0.95, 3.0),
        ]);
        let names: Vec<&str> = front.iter().map(|c| c.path.name.as_str()).collect();
        assert_eq!(names, vec!["a", "c"]);
    }

    #[test]
    fn accuracy_floor_applied() {
        let sel = extract(
            vec![cand("a", 0.5, 1.0), cand("b", 0.9, 2.0)],
            &ScheduleSpec { min_accuracy: 0.8, max_paths: 4 },
        );
        assert_eq!(sel.len(), 1);
        assert_eq!(sel[0].path.name, "b");
    }

    #[test]
    fn capped_set_keeps_extremes() {
        let cands: Vec<Candidate> = (0..8)
            .map(|i| cand(&format!("p{i}"), 0.8 + i as f64 * 0.02, 2f64.powi(i)))
            .collect();
        let sel = extract(cands, &ScheduleSpec { min_accuracy: 0.0, max_paths: 3 });
        assert_eq!(sel.len(), 3);
        assert_eq!(sel.first().unwrap().path.name, "p0");
        assert_eq!(sel.last().unwrap().path.name, "p7");
    }

    #[test]
    fn spread_maximized() {
        let cands: Vec<Candidate> = (0..5)
            .map(|i| cand(&format!("p{i}"), 0.8 + i as f64 * 0.02, 10f64.powi(i)))
            .collect();
        let sel = extract(cands, &ScheduleSpec { min_accuracy: 0.0, max_paths: 3 });
        // log-equidistant picks: ends + middle
        let names: Vec<&str> = sel.iter().map(|c| c.path.name.as_str()).collect();
        assert_eq!(names, vec!["p0", "p2", "p4"]);
    }

    #[test]
    fn swap_timeline_models_dpr_cost() {
        // down-shift: gated blocks stop toggling — free
        let down = swap_timeline(0, 1.2);
        assert_eq!(down.stall_frames, 0);
        assert_eq!(down.swap_ms, 0.0);
        // up-shift: one full-frame reactivation delay
        let up = swap_timeline(1, 1.2);
        assert_eq!(up.stall_frames, 1);
        assert!((up.swap_ms - 1.2).abs() < 1e-12);
        // degenerate frame period never yields negative windows
        assert_eq!(swap_timeline(3, -1.0).swap_ms, 0.0);
        // trace-span length: milliseconds to whole microseconds
        assert_eq!(up.window_us(), 1_200);
        assert_eq!(down.window_us(), 0);
        assert_eq!(swap_timeline(3, -1.0).window_us(), 0);
    }

    #[test]
    fn failed_swap_pays_the_window_but_never_commits() {
        let ok = attempt_swap(1, 1.2, false, ROLLBACK_COOLDOWN_FRAMES);
        assert!(ok.committed);
        assert_eq!(ok.cooldown_frames, 0);
        assert_eq!(ok.timeline, swap_timeline(1, 1.2));
        let bad = attempt_swap(1, 1.2, true, ROLLBACK_COOLDOWN_FRAMES);
        assert!(!bad.committed);
        assert_eq!(bad.cooldown_frames, ROLLBACK_COOLDOWN_FRAMES);
        assert_eq!(bad.timeline, ok.timeline, "the window was opened either way");
        // a failed down-shift (0-frame window) still cools down
        let down = attempt_swap(0, 1.2, true, 4);
        assert_eq!(down.timeline.swap_ms, 0.0);
        assert_eq!(down.cooldown_frames, 4);
    }

    #[test]
    fn cheapest_meeting_constraint() {
        let sel = vec![cand("fast", 0.82, 1.0), cand("slow", 0.95, 8.0)];
        assert_eq!(cheapest_meeting(&sel, 0.9).unwrap().path.name, "slow");
        assert_eq!(cheapest_meeting(&sel, 0.8).unwrap().path.name, "fast");
        assert!(cheapest_meeting(&sel, 0.99).is_none());
    }
}
