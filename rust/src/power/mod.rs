//! Activity-based power model (the SAIF-measurement substitute).
//!
//! The paper reports post-place-and-route power from Vivado SAIF traces
//! (Table III's mW column, Figs. 11-12). We model the same quantities:
//!
//! `P = P_static + P_clock + Σ_active_PE (toggle activity x unit power)`
//!
//! Clock-gated blocks contribute *zero* dynamic power (their flops never
//! toggle) but still leak — exactly the saving NeuroMorph banks on.
//! Constants are fit to Table III's measured range (475-743 mW for the
//! MNIST sweeps, up to ~1.9 W for CIFAR-scale designs).

use crate::pe::Resources;

/// Power model constants (mW), fit against Table III.
#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    /// device static leakage + PS-side base draw
    pub static_mw: f64,
    /// clock-tree power per MHz
    pub clock_mw_per_mhz: f64,
    /// dynamic power per active DSP slice at full toggle rate
    pub dsp_mw: f64,
    /// dynamic power per kLUT of active logic
    pub klut_mw: f64,
    /// dynamic power per active 18 Kb BRAM
    pub bram_mw: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        // Fit: MNIST design with 35 DSP/9 BRAM/6.6 kLUT -> ~475 mW and
        // 1556 DSP/356 BRAM/192 kLUT -> ~743 mW at 250 MHz (Table III),
        // with CIFAR-scale designs reaching 1.5-2 W.
        PowerModel {
            static_mw: 380.0,
            clock_mw_per_mhz: 0.30,
            dsp_mw: 0.12,
            klut_mw: 0.35,
            bram_mw: 0.18,
        }
    }
}

/// A runtime activity snapshot: which fraction of each resource class is
/// actually toggling (clock gating drives these to 0 for gated blocks).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Activity {
    /// fraction of allocated PEs not clock-gated, in [0,1]
    pub active_fraction: f64,
    /// datapath toggle rate relative to full utilization, in [0,1]
    pub toggle_rate: f64,
}

impl Default for Activity {
    fn default() -> Self {
        Activity { active_fraction: 1.0, toggle_rate: 0.85 }
    }
}

impl PowerModel {
    /// Total power (mW) for a design with the given resource footprint,
    /// clock, and runtime activity.
    pub fn total_mw(&self, res: &Resources, clock_mhz: f64, act: Activity) -> f64 {
        let util = act.active_fraction.clamp(0.0, 1.0) * act.toggle_rate.clamp(0.0, 1.0);
        let dynamic = res.dsp as f64 * self.dsp_mw
            + res.lut as f64 / 1000.0 * self.klut_mw
            + res.bram as f64 * self.bram_mw;
        self.static_mw + clock_mhz * self.clock_mw_per_mhz + dynamic * util
    }

    /// Energy per frame in mJ given the frame latency.
    pub fn energy_per_frame_mj(
        &self,
        res: &Resources,
        clock_mhz: f64,
        act: Activity,
        latency_ms: f64,
    ) -> f64 {
        self.total_mw(res, clock_mhz, act) * latency_ms / 1000.0
    }
}

/// One morph path's modeled runtime operating point: the activity the
/// path toggles at, the resulting power draw and the per-frame latency —
/// the row the serving layer's energy accounting and the trace-driven
/// budget loop consume (the SAIF-style measurement the paper reads off
/// the board, Figs. 11-12).
#[derive(Debug, Clone, PartialEq)]
pub struct PathEnergy {
    pub name: String,
    /// activity snapshot the power figure was computed at
    pub activity: Activity,
    /// modeled total draw while this path executes (mW)
    pub power_mw: f64,
    /// modeled frame latency on this path (ms)
    pub frame_ms: f64,
}

impl PathEnergy {
    /// Modeled energy per frame (mJ): `P[mW] x T[ms] / 1000`.
    pub fn energy_mj_per_frame(&self) -> f64 {
        self.power_mw * self.frame_ms / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mnist_small() -> Resources {
        Resources { dsp: 35, lut: 6590, ff: 0, bram: 9 }
    }

    fn mnist_big() -> Resources {
        Resources { dsp: 1556, lut: 192_000, ff: 0, bram: 356 }
    }

    #[test]
    fn calibration_matches_table3_range() {
        let m = PowerModel::default();
        let small = m.total_mw(&mnist_small(), 250.0, Activity::default());
        let big = m.total_mw(&mnist_big(), 250.0, Activity::default());
        // Table III: 475 mW (3-PE design) ... 743 mW (164-PE design)
        assert!((430.0..=540.0).contains(&small), "small {small}");
        assert!((650.0..=820.0).contains(&big), "big {big}");
    }

    #[test]
    fn gating_reduces_power() {
        let m = PowerModel::default();
        let full = m.total_mw(&mnist_big(), 250.0, Activity::default());
        let gated = m.total_mw(
            &mnist_big(),
            250.0,
            Activity { active_fraction: 0.3, ..Activity::default() },
        );
        assert!(gated < full);
        // dynamic share scales with active fraction
        let dyn_full = full - m.total_mw(&mnist_big(), 250.0, Activity { active_fraction: 0.0, toggle_rate: 0.85 });
        let dyn_gated = gated - m.total_mw(&mnist_big(), 250.0, Activity { active_fraction: 0.0, toggle_rate: 0.85 });
        assert!((dyn_gated / dyn_full - 0.3).abs() < 1e-9);
    }

    #[test]
    fn energy_scales_with_latency() {
        let m = PowerModel::default();
        let e1 = m.energy_per_frame_mj(&mnist_small(), 250.0, Activity::default(), 1.0);
        let e2 = m.energy_per_frame_mj(&mnist_small(), 250.0, Activity::default(), 2.0);
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn path_energy_row_consistent() {
        let m = PowerModel::default();
        let act = Activity { active_fraction: 0.4, toggle_rate: 0.8 };
        let power = m.total_mw(&mnist_small(), 250.0, act);
        let row = PathEnergy {
            name: "d1_w100".into(),
            activity: act,
            power_mw: power,
            frame_ms: 0.25,
        };
        assert!((row.energy_mj_per_frame() - power * 0.25 / 1000.0).abs() < 1e-12);
        // the row's energy matches the model's own per-frame figure
        let direct = m.energy_per_frame_mj(&mnist_small(), 250.0, act, 0.25);
        assert!((row.energy_mj_per_frame() - direct).abs() < 1e-12);
    }

    #[test]
    fn activity_clamped() {
        let m = PowerModel::default();
        let a = m.total_mw(&mnist_small(), 250.0, Activity { active_fraction: 5.0, toggle_rate: 1.0 });
        let b = m.total_mw(&mnist_small(), 250.0, Activity { active_fraction: 1.0, toggle_rate: 1.0 });
        assert_eq!(a, b);
    }
}
