//! ForgeMorph CLI — the L3 leader entrypoint.
//!
//! ```text
//! forgemorph report <table1|...|fig12|power|trace|all>   regenerate paper tables/figures
//! forgemorph report trace [--in FILE]   render an exported trace timeline
//! forgemorph report bench-check --baseline FILE [--current FILE
//!                   --tolerance-pct 20 --absolute]   perf-regression gate
//! forgemorph dse|explore --model cifar10 [--pop N --gens N --seed N --dsp N
//!                   --latency MS --power-budget MW --energy-front
//!                   --threads N --no-memo --no-stage-memo --prune
//!                   --surrogate --profile FILE
//!                   --trace-out FILE --trace-deterministic]
//! forgemorph distill --model mnist [--train N --test N --epochs N --batch N
//!                   --seed N --qbits B --threads N --out FILE
//!                   --trace-out FILE --trace-deterministic]   train the
//!                   morph-path ladder (DistillCycle) and emit an
//!                   AccuracyProfile
//! forgemorph rtl --model mnist --p 4 [--out DIR]   emit Verilog for a design point
//! forgemorph sim --model mnist --p 4 [--depth D | --width PCT]
//! forgemorph graph dump --model yolov5l        topology + StagePlan as JSON
//! forgemorph serve [--model mnist --requests N --rate HZ --artifacts DIR
//!                   --workers N --backend pjrt|sim|analytical
//!                   --accuracy-floor F --patience K
//!                   --power-trace step|ramp|spike|diurnal[:k=v,...]
//!                   --fault-trace "seu;stall;swapfail;transient"[:k=v,...]
//!                   --fault-seed N --trace-out FILE --trace-deterministic]
//! forgemorph verify [--artifacts DIR --model mnist]   probe-check AOT artifacts
//! ```

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context};
use forgemorph::backend::BackendSpec;
use forgemorph::coordinator::{trace, Coordinator, ServeConfig, TraceConfig};
use forgemorph::fault::FaultPlan;
use forgemorph::morph;
use forgemorph::design::{self, DesignConfig};
use forgemorph::dse;
use forgemorph::graph::zoo;
use forgemorph::morph::governor::Budget;
use forgemorph::pe::{FpRep, ZYNQ_7100};
use forgemorph::report;
use forgemorph::runtime::Engine;
use forgemorph::sim::{self, GateMask};
use forgemorph::util::cli::Args;
use forgemorph::util::json::Json;
use forgemorph::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("report") => cmd_report(&args),
        Some("dse") | Some("explore") => cmd_dse(&args),
        Some("distill") => cmd_distill(&args),
        Some("rtl") => cmd_rtl(&args),
        Some("sim") => cmd_sim(&args),
        Some("graph") => cmd_graph(&args),
        Some("serve") => cmd_serve(&args),
        Some("verify") => cmd_verify(&args),
        _ => {
            eprintln!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
forgemorph — adaptive CNN deployment compiler (paper reproduction)
commands:
  report <id>   regenerate a paper table/figure (table1..table6, fig2, fig8,
                fig10, fig11, fig12, backends, graphs, distill, power,
                faults, trace, all);
                `report trace` replays the canonical fault storm traced
                and renders its timeline — per-path occupancy,
                switch/swap annotations, retry ladders; `report trace
                --in FILE` renders a trace exported with --trace-out;
                `report bench-check --baseline BENCH_x.json` gates perf
                regressions against the committed bench trajectory
  dse|explore   NeuroForge design space exploration (--threads N fans the
                fitness evaluation out; results are bit-identical for any
                thread count. --no-memo disables both cache levels;
                --no-stage-memo keeps the chromosome memo but disables
                the segment-level primary cache — fronts are identical
                either way. --surrogate pre-orders offspring evaluation
                with a deterministic linear ranker (dispatch order only;
                bit-identical fronts). --prune skips offspring whose
                roofline lower bound is constraint-violating or
                front-dominated (changes the search trajectory).
                --profile FILE adds a DistillCycle AccuracyProfile and
                switches to 3-objective latency/DSP/accuracy fronts.
                --power-budget MW caps modeled power; --energy-front adds
                energy-per-frame as a minimized objective.
                --trace-out FILE records per-generation DSE telemetry —
                .json Chrome trace events, .folded flamegraph stacks,
                .txt snapshot)
  distill       DistillCycle-train a small zoo model's morph-path ladder
                (hierarchical KD) and emit its AccuracyProfile JSON
                (--threads N fans the independent ladder phases out —
                same semantics as explore's flag, byte-identical profile
                for any value; --threads 0 runs the serial scalar
                reference kernels; --trace-out FILE records one KD-cycle
                span per stage/phase/epoch loss record)
  rtl           emit Verilog for a design point
  sim           cycle-simulate a design point (optionally morphed)
  graph         graph dump --model M: topology + scheduled StagePlan
                (stages, dataflow edges, FIFO words, gate blocks) as JSON;
                graph dump --onnx FILE imports an exported ONNX model
                instead of a zoo entry (docs/ONNX.md has the op-coverage
                contract) — --onnx works on every subcommand that takes
                --model (explore, serve, distill, rtl, sim)
  serve         run the NeuroMorph serving demo (--workers N shards;
                --backend pjrt needs AOT artifacts, sim/analytical run
                self-contained; --accuracy-floor F pins the governor's
                hard minimum path accuracy; --power-trace SPEC replays a
                deterministic budget trace — step|ramp|spike|diurnal with
                optional k=v params — and prints the decision log, which
                is byte-identical for any --workers value; --fault-trace
                SPEC injects deterministic faults — ;-separated
                transient|stall|swapfail|seu clauses with optional k=v
                params — and prints the self-healing fault log, also
                byte-identical for any --workers value; --trace-out FILE
                records request/governor/fault lifecycle spans —
                with --trace-deterministic the export keeps only
                virtual-clock spans and is byte-identical across
                --workers values and reruns)
  verify        check AOT artifacts against golden probe logits";

fn net_for(args: &Args) -> anyhow::Result<forgemorph::graph::Network> {
    // `--onnx FILE` loads an exported model; `--model NAME` a zoo entry.
    // Every subcommand resolves its network here, so imported models
    // flow through explore/serve/distill/rtl/sim/graph identically.
    if let Some(path) = args.get("onnx") {
        if args.get("model").is_some() {
            bail!("--onnx and --model are mutually exclusive (the ONNX file names its own graph)");
        }
        let bytes =
            std::fs::read(path).with_context(|| format!("reading onnx model {path}"))?;
        return forgemorph::onnx::import_bytes(&bytes)
            .map_err(|e| anyhow::anyhow!("{path}: {e}"));
    }
    let name = args.get_or("model", "mnist");
    // the zoo error already lists every valid model name
    Ok(zoo::by_name(name)?)
}

fn rep_for(args: &Args) -> FpRep {
    match args.get_or("rep", "int16") {
        "int8" => FpRep::Int8,
        _ => FpRep::Int16,
    }
}

/// `--trace-out FILE`: a shared span/event sink for the run, or `None`
/// (tracing fully disabled — every subsystem takes the no-sink branch).
fn trace_sink_for(args: &Args) -> Option<std::sync::Arc<forgemorph::obs::TraceSink>> {
    args.get("trace-out").map(|_| forgemorph::obs::TraceSink::shared())
}

/// Drain the sink and export by file extension: `.folded` writes
/// flamegraph stacks, `.txt` the plain-text snapshot, anything else
/// Chrome trace-event JSON (Perfetto-loadable). `--trace-deterministic`
/// keeps only virtual-clock entries so the file is byte-identical
/// across worker counts and reruns.
fn write_trace(
    sink: &forgemorph::obs::TraceSink,
    path: &str,
    deterministic: bool,
) -> anyhow::Result<()> {
    use forgemorph::obs::export;
    let trace = sink.drain();
    let text = if path.ends_with(".folded") {
        export::folded(&trace, deterministic)
    } else if path.ends_with(".txt") {
        export::text_snapshot(&trace)
    } else {
        export::chrome_trace(&trace, deterministic)
    };
    std::fs::write(path, &text).with_context(|| format!("writing trace {path}"))?;
    println!(
        "wrote trace: {} events, {} dropped -> {path}",
        trace.entries.len(),
        trace.dropped
    );
    Ok(())
}

fn cmd_report(args: &Args) -> anyhow::Result<()> {
    let id = args.positional.get(1).map(String::as_str).unwrap_or("all");
    if id == "bench-check" {
        return cmd_bench_check(args);
    }
    // `report trace --in FILE` renders an exported Chrome trace instead
    // of replaying the canonical storm (`report trace` with no --in)
    if id == "trace" {
        if let Some(path) = args.get("in") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading trace {path}"))?;
            let rendered =
                report::render_trace_json(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            println!("{rendered}");
            return Ok(());
        }
    }
    match report::by_name(id) {
        Some(text) => {
            println!("{text}");
            Ok(())
        }
        None => {
            let hint = forgemorph::util::did_you_mean(id, report::KNOWN_IDS);
            bail!("unknown report id '{id}'{hint} (valid: {})", report::KNOWN_IDS.join("|"))
        }
    }
}

/// `report bench-check --baseline FILE [--current FILE]
/// [--tolerance-pct 20] [--absolute]`: the CI perf-regression gate over
/// the BENCH_*.json trajectory files. Exits nonzero on regression.
fn cmd_bench_check(args: &Args) -> anyhow::Result<()> {
    let baseline_path = args
        .get("baseline")
        .context("bench-check needs --baseline FILE (a committed BENCH_*.json)")?;
    let tolerance = args.get_f64("tolerance-pct", 20.0);
    let base_text = std::fs::read_to_string(baseline_path)
        .with_context(|| format!("reading baseline {baseline_path}"))?;
    let base = Json::parse(&base_text)
        .map_err(|e| anyhow::anyhow!("parsing baseline {baseline_path}: {e}"))?;
    // the baseline's bench id names the default current file at the repo root
    let current_path = match args.get("current") {
        Some(p) => p.to_string(),
        None => match base.get("bench").and_then(Json::as_str) {
            Some("dse_engine") => "BENCH_dse.json".to_string(),
            Some("distill_engine") => "BENCH_distill.json".to_string(),
            other => bail!(
                "baseline carries unknown bench id {other:?}; pass --current FILE explicitly"
            ),
        },
    };
    let cur_text = std::fs::read_to_string(&current_path).with_context(|| {
        format!("reading current run {current_path} (run `cargo bench --bench bench_hotpath` first)")
    })?;
    let cur = Json::parse(&cur_text)
        .map_err(|e| anyhow::anyhow!("parsing current run {current_path}: {e}"))?;
    let result = report::bench::check(&base, &cur, tolerance, args.flag("absolute"));
    print!("{}", result.report());
    if !result.passed() {
        bail!(
            "{} perf regression(s) beyond {tolerance}% tolerance vs {baseline_path}",
            result.regressions.len()
        );
    }
    if result.gated == 0 {
        println!("bench-check: no gated metrics in {baseline_path} (informational only) — OK");
    } else {
        println!(
            "bench-check OK: {} gated metric(s) within {tolerance}% of {baseline_path}",
            result.gated
        );
    }
    Ok(())
}

fn cmd_dse(args: &Args) -> anyhow::Result<()> {
    let net = net_for(args)?;
    let default_threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // --profile FILE: DistillCycle AccuracyProfile -> 3-objective search
    let profile = match args.get("profile") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading profile {path}"))?;
            let p = forgemorph::distill::AccuracyProfile::parse(&text)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            // a ladder trained for another model would silently attach
            // meaningless accuracies/MAC fractions to this search
            if p.model != net.name {
                bail!(
                    "profile {path} was trained for model '{}' but exploring '{}' — \
                     regenerate it with `distill --model`",
                    p.model,
                    net.name
                );
            }
            Some(p)
        }
        None => None,
    };
    let sink = trace_sink_for(args);
    if let Some(s) = &sink {
        s.set_meta("cmd", "explore");
        s.set_meta("model", &net.name);
    }
    let cfg = dse::DseConfig {
        population: args.get_usize("pop", 96),
        generations: args.get_usize("gens", 40),
        seed: args.get_u64("seed", 0),
        rep: rep_for(args),
        threads: args.get_usize("threads", default_threads),
        memo: !args.flag("no-memo"),
        stage_memo: !args.flag("no-stage-memo"),
        prune: args.flag("prune"),
        surrogate: args.flag("surrogate"),
        accuracy_paths: profile.as_ref().map(|p| p.morph_paths()),
        energy_objective: args.flag("energy-front"),
        trace: sink.clone(),
        constraints: dse::Constraints {
            latency_ms: args.get("latency").and_then(|s| s.parse().ok()),
            dsp: args.get("dsp").and_then(|s| s.parse().ok()),
            lut: args.get("lut").and_then(|s| s.parse().ok()),
            bram: args.get("bram").and_then(|s| s.parse().ok()),
            power_mw: args.get("power-budget").and_then(|s| s.parse().ok()),
        },
        ..dse::DseConfig::default()
    };
    let res = dse::run(&net, &ZYNQ_7100, &cfg);
    // telemetry stays on this one line: smoke scripts diff the front
    // table below it across flag combinations (`tail -n +2`)
    println!(
        "explored {} candidates in {:.2}s ({} threads, {} unique evals, \
         cache hit rate {:.1}%, stage hit rate {:.1}%{}{}) — Pareto front ({} points{}):",
        res.evaluations,
        res.wall_ms / 1e3,
        cfg.threads,
        res.unique_evaluations,
        res.cache_hit_rate() * 100.0,
        res.stage_hit_rate() * 100.0,
        if cfg.prune {
            format!(", {} roofline-pruned", res.roofline_pruned)
        } else {
            String::new()
        },
        if cfg.surrogate {
            format!(", {} surrogate-reordered", res.surrogate_reorders)
        } else {
            String::new()
        },
        res.pareto.len(),
        if profile.is_some() { ", 3 objectives" } else { "" }
    );
    // power/energy columns join the table when the new axes are in play
    let show_power = cfg.constraints.power_mw.is_some() || cfg.energy_objective;
    match &profile {
        None if show_power => {
            println!(
                "{:<28} {:>8} {:>12} {:>9} {:>9} {:>10} {:>11}",
                "p(i)", "DSP", "latency ms", "LUT", "BRAM", "power mW", "energy mJ"
            );
            for c in &res.pareto {
                println!(
                    "{:<28} {:>8} {:>12.4} {:>9} {:>9} {:>10.1} {:>11.4}",
                    format!("{:?}", c.config.parallelism),
                    c.objectives.dsp,
                    c.objectives.latency_ms,
                    c.objectives.lut,
                    c.objectives.bram,
                    c.objectives.power_mw,
                    c.objectives.energy_mj
                );
            }
        }
        None => {
            println!(
                "{:<28} {:>8} {:>12} {:>9} {:>9}",
                "p(i)", "DSP", "latency ms", "LUT", "BRAM"
            );
            for c in &res.pareto {
                println!(
                    "{:<28} {:>8} {:>12.4} {:>9} {:>9}",
                    format!("{:?}", c.config.parallelism),
                    c.objectives.dsp,
                    c.objectives.latency_ms,
                    c.objectives.lut,
                    c.objectives.bram
                );
            }
        }
        Some(prof) => {
            println!(
                "{:<24} {:>8} {:>12} {:>9} {:>9} {:>9} {:>9}",
                "p(i)", "DSP", "latency ms", "LUT", "BRAM", "path", "accuracy"
            );
            for c in &res.pareto {
                // the trailing gene selects the execution path (1-based)
                let (path_gene, conv) = c.config.parallelism.split_last().unwrap();
                let path = &prof.paths[path_gene - 1];
                println!(
                    "{:<24} {:>8} {:>12.4} {:>9} {:>9} {:>9} {:>8.1}%",
                    format!("{conv:?}"),
                    c.objectives.dsp,
                    c.objectives.latency_ms,
                    c.objectives.lut,
                    c.objectives.bram,
                    path.name,
                    c.objectives.accuracy * 100.0
                );
            }
        }
    }
    if let (Some(s), Some(out)) = (&sink, args.get("trace-out")) {
        write_trace(s, out, args.flag("trace-deterministic"))?;
    }
    Ok(())
}

fn cmd_distill(args: &Args) -> anyhow::Result<()> {
    use forgemorph::distill::{self, DistillConfig, DistillSpec};
    let net = net_for(args)?;
    let spec = DistillSpec::from_network(&net).map_err(|e| anyhow::anyhow!("{e}"))?;
    let qat_bits: Option<u32> = match args.get("qbits") {
        None => None,
        Some(s) => {
            let bits: u32 = s.parse().with_context(|| format!("--qbits {s}"))?;
            // QParams shifts 1 << (bits-1) in i64 and needs a usable grid
            if !(2..=32).contains(&bits) {
                bail!("--qbits {bits}: supported quantization widths are 2..=32");
            }
            Some(bits)
        }
    };
    // same default as explore: all available cores. 0 is meaningful
    // (the serial scalar-reference path), so no .max(1) clamp here.
    let default_threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let sink = trace_sink_for(args);
    if let Some(s) = &sink {
        s.set_meta("cmd", "distill");
        s.set_meta("model", &net.name);
    }
    let cfg = DistillConfig {
        epochs_per_stage: args.get_usize("epochs", 2),
        batch: args.get_usize("batch", 32),
        seed: args.get_u64("seed", 0),
        qat_bits,
        threads: args.get_usize("threads", default_threads),
        trace: sink.clone(),
        ..DistillConfig::default()
    };
    let n_train = args.get_usize("train", 512);
    let n_test = args.get_usize("test", 128);
    if n_train == 0 {
        bail!("--train 0: nothing to train on");
    }
    if n_test == 0 {
        bail!("--test 0: accuracy needs at least one test sample");
    }
    // the engine clamps the batch to the train count, then drops any
    // trailing partial batch each epoch (train.py parity) — say so
    let eff_batch = cfg.batch.min(n_train);
    if n_train % eff_batch != 0 {
        println!(
            "note: trailing {} samples are dropped each epoch (batch {eff_batch})",
            n_train % eff_batch
        );
    }
    let ds = spec.dataset(n_train, n_test, cfg.seed);
    println!(
        "DistillCycle: training '{}' ladder ({} paths) on {n_train}+{n_test} samples, \
         {} epochs/stage, seed {}{}, {}",
        spec.name,
        spec.paths().len(),
        cfg.epochs_per_stage,
        cfg.seed,
        cfg.qat_bits.map(|b| format!(", int{b} QAT")).unwrap_or_default(),
        if cfg.threads == 0 {
            "serial reference kernels".to_string()
        } else {
            format!("{} thread(s)", cfg.threads)
        }
    );
    let t0 = std::time::Instant::now();
    let profile = distill::train_profile(&spec, &ds, &cfg);
    println!("trained in {:.2}s", t0.elapsed().as_secs_f64());
    println!(
        "{:<10} {:>7} {:>10} {:>12} {:>10}",
        "path", "depth", "params", "MACs", "accuracy"
    );
    for p in &profile.paths {
        println!(
            "{:<10} {:>7} {:>10} {:>12} {:>9.1}%",
            p.name, p.depth, p.params, p.macs, p.accuracy * 100.0
        );
    }
    println!("accuracy floor (worst path): {:.1}%", profile.floor() * 100.0);
    if let Some(out) = args.get("out") {
        std::fs::write(out, profile.to_json()).with_context(|| format!("writing {out}"))?;
        println!("wrote AccuracyProfile to {out}");
    } else {
        println!("{}", profile.to_json());
    }
    if let (Some(s), Some(out)) = (&sink, args.get("trace-out")) {
        write_trace(s, out, args.flag("trace-deterministic"))?;
    }
    Ok(())
}

fn cmd_rtl(args: &Args) -> anyhow::Result<()> {
    let net = net_for(args)?;
    let cfg = DesignConfig::uniform(&net, args.get_usize("p", 4), rep_for(args));
    // one pass-pipeline schedule shared by evaluation and emission
    let plan = forgemorph::graph::passes::schedule(&net)
        .map_err(|e| anyhow::anyhow!("scheduling '{}': {e}", net.name))?;
    let eval = design::evaluate_plan(&plan, &cfg, &ZYNQ_7100)?;
    let bundle = forgemorph::rtl::emit_plan(&plan, &cfg, &eval);
    let out = PathBuf::from(args.get_or("out", "rtl_out"));
    bundle.write_to(&out)?;
    println!(
        "emitted {} files ({} bytes) to {} — top module {}",
        bundle.files.len(),
        bundle.total_bytes(),
        out.display(),
        bundle.top_name
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let net = net_for(args)?;
    let cfg = DesignConfig::uniform(&net, args.get_usize("p", 4), rep_for(args));
    let mask = if let Some(d) = args.get("depth") {
        GateMask::depth_prefix(&net, d.parse().context("--depth")?)
    } else if let Some(wp) = args.get("width") {
        // validated boundary: an out-of-range width is an error, not a clamp
        GateMask::try_width(wp.parse::<f64>().context("--width")? / 100.0)
            .context("--width")?
    } else {
        GateMask::all_active()
    };
    let r = sim::simulate(&net, &cfg, &ZYNQ_7100, &mask);
    println!(
        "{}: latency {:.4} ms ({} cycles), {:.1} FPS, {:.0} mW, {:.4} J/frame",
        net.name,
        r.latency_ms(),
        r.latency_cycles,
        r.fps(),
        r.power_mw,
        r.energy_per_frame_j()
    );
    println!("{:<12} {:>8} {:>14} {:>8}", "stage", "passes", "busy cycles", "gated");
    for st in &r.per_stage {
        println!(
            "{:<12} {:>8} {:>14} {:>8}",
            st.name, st.passes, st.busy_cycles, st.gated
        );
    }
    Ok(())
}

fn cmd_graph(args: &Args) -> anyhow::Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("dump") => {}
        other => bail!(
            "graph: unknown subcommand {:?} (expected: graph dump --model M, or graph dump --onnx FILE)",
            other.unwrap_or("<none>")
        ),
    }
    let net = net_for(args)?;
    let plan = forgemorph::graph::passes::schedule(&net)
        .map_err(|e| anyhow::anyhow!("scheduling '{}': {e}", net.name))?;
    // topology (raw layer list + edges) alongside the scheduled plan
    let mut layers = Vec::new();
    for l in &net.layers {
        let mut o = std::collections::BTreeMap::new();
        o.insert("id".to_string(), Json::Num(l.id as f64));
        o.insert("name".to_string(), Json::Str(l.name.clone()));
        o.insert(
            "op".to_string(),
            Json::Str(forgemorph::graph::passes::kind_name(&l.kind).to_string()),
        );
        layers.push(Json::Obj(o));
    }
    let connections = net
        .connections
        .iter()
        .map(|&(s, d)| Json::Arr(vec![Json::Num(s as f64), Json::Num(d as f64)]))
        .collect();
    let mut root = std::collections::BTreeMap::new();
    root.insert("model".to_string(), Json::Str(net.name.clone()));
    root.insert(
        "topology".to_string(),
        Json::Obj(
            [
                ("layers".to_string(), Json::Arr(layers)),
                ("connections".to_string(), Json::Arr(connections)),
            ]
            .into_iter()
            .collect(),
        ),
    );
    root.insert("stage_plan".to_string(), plan.to_json());
    println!("{}", Json::Obj(root));
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let net = net_for(args)?;
    // with --onnx the graph names itself; otherwise the zoo entry name
    let model = if args.get("onnx").is_some() {
        net.name.clone()
    } else {
        args.get_or("model", "mnist").to_string()
    };
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let requests = args.get_usize("requests", 256);
    let rate_hz = args.get_f64("rate", 2000.0);
    let workers = args.get_usize("workers", 1);
    let backend = args.get_or("backend", "pjrt").to_string();
    let trace_spec = args.get("power-trace").map(str::to_string);
    let fault_spec = args.get("fault-trace").map(str::to_string);
    // trace mode defaults to the Table III 164-PE-class mapping: large
    // enough that gated blocks dominate the draw — where the paper's
    // ~32% runtime power saving lives
    let p_default = if trace_spec.is_some() || fault_spec.is_some() { 16 } else { 4 };
    let design = DesignConfig::uniform(&net, args.get_usize("p", p_default), rep_for(args));

    let spec = match backend.as_str() {
        "pjrt" => BackendSpec::Pjrt {
            artifacts_dir: artifacts,
            model: model.clone(),
            net: net.clone(),
            design,
            device: ZYNQ_7100,
        },
        "sim" => BackendSpec::sim(net.clone(), design, ZYNQ_7100, morph::depth_ladder(&net)),
        "analytical" => {
            BackendSpec::analytical(net.clone(), design, ZYNQ_7100, morph::depth_ladder(&net))
        }
        other => bail!("unknown backend '{other}' (pjrt|sim|analytical)"),
    };
    let accuracy_floor = args.get_f64("accuracy-floor", 0.0);
    // same strict boundary as every other accuracy entry point (manifest,
    // AccuracyProfile): an out-of-range floor would silently disable the
    // SLO via the governor's degraded-profile fallback
    if !(0.0..=1.0).contains(&accuracy_floor) {
        bail!("--accuracy-floor {accuracy_floor}: must be within 0.0..=1.0 (a fraction, not a percent)");
    }
    let sink = trace_sink_for(args);
    if let Some(s) = &sink {
        s.set_meta("cmd", "serve");
        s.set_meta("model", &model);
        s.set_meta("backend", &spec.describe());
    }
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(2),
        patience: args.get_usize("patience", 2),
        workers,
        accuracy_floor,
        external_pacing: trace_spec.is_some() || fault_spec.is_some(),
        trace: sink.clone(),
        ..Default::default()
    };
    if trace_spec.is_some() || fault_spec.is_some() {
        return cmd_serve_trace(
            args,
            cfg,
            spec,
            trace_spec.as_deref(),
            fault_spec.as_deref(),
            &model,
            &backend,
            requests,
            rate_hz,
        );
    }
    let mut coord = Coordinator::start(cfg, spec)?;
    println!(
        "serving {requests} requests at ~{rate_hz} Hz on '{model}' \
         ({backend} backend, {workers} worker shard(s), accuracy floor {:.1}%)",
        accuracy_floor * 100.0
    );

    let mut rng = Rng::new(42);
    let (in_h, in_w, in_c) = net.input_dims();
    let frame = in_h * in_w * in_c;
    let mut receivers = Vec::with_capacity(requests);
    let t0 = std::time::Instant::now();
    for i in 0..requests {
        // mid-run power squeeze: the governor must downshift
        if i == requests / 3 {
            coord.set_budget(Budget { power_mw: Some(520.0), latency_ms: None })?;
            println!("[budget] power cap 520 mW");
        }
        if i == 2 * requests / 3 {
            coord.set_budget(Budget::unconstrained())?;
            println!("[budget] unconstrained");
        }
        let data: Vec<f32> = (0..frame).map(|_| rng.f64() as f32).collect();
        receivers.push(coord.submit(data).context("submit")?);
        std::thread::sleep(Duration::from_secs_f64(rng.exp(rate_hz)));
    }
    let mut by_path = std::collections::BTreeMap::<String, u64>::new();
    for rx in receivers {
        if let Ok(resp) = rx.recv() {
            *by_path.entry(resp.path).or_insert(0) += 1;
        }
    }
    let wall = t0.elapsed();
    let metrics = coord.shutdown();
    println!(
        "done in {:.2}s: {} requests, {} batches, {:.1} req/s",
        wall.as_secs_f64(),
        metrics.requests,
        metrics.batches,
        metrics.throughput_fps(wall)
    );
    println!(
        "e2e latency: mean {:.2} ms, p50 {:.2} / p95 {:.2} / p99 {:.2} ms | \
         morph switches: {} | modeled energy {:.3} J",
        metrics.e2e_latency.mean_us() / 1000.0,
        metrics.e2e_latency.quantile(0.5) / 1000.0,
        metrics.e2e_latency.quantile(0.95) / 1000.0,
        metrics.e2e_latency.quantile(0.99) / 1000.0,
        metrics.morph_switches,
        metrics.energy_j
    );
    for (path, n) in by_path {
        println!("  path {path}: {n} frames");
    }
    if let (Some(s), Some(out)) = (&sink, args.get("trace-out")) {
        write_trace(s, out, args.flag("trace-deterministic"))?;
    }
    Ok(())
}

/// `serve --power-trace <spec>` / `serve --fault-trace <spec>`: replay a
/// deterministic budget trace (and optionally a fault plan) through the
/// serving stack on a virtual clock and print the decision log, the
/// fault log and per-segment modeled power (the paper's down-shift
/// experiment, plus the fault-storm self-healing experiment).
#[allow(clippy::too_many_arguments)]
fn cmd_serve_trace(
    args: &Args,
    cfg: ServeConfig,
    spec: BackendSpec,
    tspec: Option<&str>,
    fspec: Option<&str>,
    model: &str,
    backend: &str,
    requests: usize,
    rate_hz: f64,
) -> anyhow::Result<()> {
    let workers = cfg.workers;
    let sink = cfg.trace.clone();
    let mut coord = Coordinator::start(cfg, spec)?;
    let rows = coord.path_energy_rows();
    anyhow::ensure!(!rows.is_empty(), "backend reported no path energy rows");
    let default_cap = trace::default_squeeze_cap(&rows);
    let duration_s = requests as f64 / rate_hz;
    // no power trace (fault-only replay) = unconstrained budget throughout
    let events = match tspec {
        Some(t) => {
            trace::parse_spec(t, duration_s, default_cap).map_err(|e| anyhow::anyhow!("{e}"))?
        }
        None => Vec::new(),
    };
    let seed = args.get_u64("seed", 42);
    let plan = match fspec {
        Some(f) => Some(
            FaultPlan::parse_spec(f, requests, rate_hz, args.get_u64("fault-seed", seed))
                .map_err(|e| anyhow::anyhow!("{e}"))?,
        ),
        None => None,
    };
    if let Some(t) = tspec {
        println!(
            "power trace '{t}' on '{model}' ({backend} backend, {workers} worker shard(s)): \
             {} budget events, {requests} frames @ {rate_hz:.0} Hz virtual, {} deployed paths",
            events.len(),
            rows.len()
        );
    } else {
        println!(
            "unconstrained budget on '{model}' ({backend} backend, {workers} worker shard(s)): \
             {requests} frames @ {rate_hz:.0} Hz virtual, {} deployed paths",
            rows.len()
        );
    }
    if let (Some(f), Some(p)) = (fspec, plan.as_ref()) {
        println!(
            "fault trace '{f}': {} fault clause(s), seed {}",
            p.events.len(),
            p.seed
        );
    }
    let outcome = coord.replay_trace(
        &events,
        &TraceConfig { frames: requests, rate_hz, seed },
        plan.as_ref(),
    )?;
    print!("{}", outcome.decision_log());
    print!("{}", outcome.fault_log());
    print!("{}", outcome.render_summary());
    anyhow::ensure!(
        outcome.answered == requests,
        "dropped {} in-flight request(s) across reconfigurations",
        requests - outcome.answered
    );
    if plan.is_some() {
        anyhow::ensure!(
            outcome.ok + outcome.degraded + outcome.failed == outcome.answered,
            "terminal statuses do not cover every answered request"
        );
    }
    if let (Some(s), Some(out)) = (&sink, args.get("trace-out")) {
        write_trace(s, out, args.flag("trace-deterministic"))?;
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> anyhow::Result<()> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let model = args.get_or("model", "mnist");
    let engine = Engine::load(&artifacts, model)?;
    println!("platform: {}", engine.platform());
    let errs = engine.verify_probe()?;
    let mut ok = true;
    for (path, err) in &errs {
        let pass = *err < 1e-3;
        ok &= pass;
        println!("  {path}: max|err| = {err:.2e} {}", if pass { "OK" } else { "FAIL" });
    }
    if !ok {
        bail!("probe verification failed");
    }
    println!("all {} paths match golden logits", errs.len());
    Ok(())
}
