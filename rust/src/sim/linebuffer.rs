//! Functional line-buffer model — bit-true twin of `rtl/modules.rs`'s
//! `line_buffer`.
//!
//! Streams a frame pixel-by-pixel through K-1 row FIFOs and a KxK tap
//! bank, emitting the same window sequence the RTL produces. Tests
//! validate it against naive im2col window extraction — the concrete
//! microarchitecture-correctness check standing in for RTL simulation.

/// Line buffer state for a `k`x`k` window over a `w`-wide frame.
#[derive(Debug, Clone)]
pub struct LineBuffer {
    k: usize,
    w: usize,
    stride: usize,
    rows: Vec<Vec<i32>>, // K-1 row FIFOs
    taps: Vec<Vec<i32>>, // KxK register bank
    col: usize,
    row: usize,
}

/// A window emission: top-left output coordinate + KxK values
/// (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Window {
    pub out_row: usize,
    pub out_col: usize,
    pub values: Vec<i32>,
}

impl LineBuffer {
    pub fn new(k: usize, w: usize, stride: usize) -> LineBuffer {
        assert!(k >= 1 && w >= k && stride >= 1);
        LineBuffer {
            k,
            w,
            stride,
            rows: vec![vec![0; w]; k.saturating_sub(1)],
            taps: vec![vec![0; k]; k],
            col: 0,
            row: 0,
        }
    }

    /// Push one pixel (stream order: row-major). Returns a window when
    /// the tap bank holds a valid, stride-aligned KxK patch.
    pub fn push(&mut self, px: i32) -> Option<Window> {
        // shift tap bank left
        for r in 0..self.k {
            for c in 0..self.k - 1 {
                self.taps[r][c] = self.taps[r][c + 1];
            }
        }
        // new rightmost column: history rows then the live pixel
        for r in 0..self.k - 1 {
            self.taps[r][self.k - 1] = self.rows[r][self.col];
        }
        self.taps[self.k - 1][self.k - 1] = px;
        // rotate row FIFOs at this column
        for r in 0..self.k.saturating_sub(2) {
            self.rows[r][self.col] = self.rows[r + 1][self.col];
        }
        if self.k > 1 {
            self.rows[self.k - 2][self.col] = px;
        }

        let valid = self.row + 1 >= self.k
            && self.col + 1 >= self.k
            && (self.row + 1 - self.k) % self.stride == 0
            && (self.col + 1 - self.k) % self.stride == 0;
        let out = valid.then(|| Window {
            out_row: (self.row + 1 - self.k) / self.stride,
            out_col: (self.col + 1 - self.k) / self.stride,
            values: self.taps.iter().flatten().copied().collect(),
        });

        // advance scan position
        self.col += 1;
        if self.col == self.w {
            self.col = 0;
            self.row += 1;
        }
        out
    }

    /// Stream a full frame, returning every emitted window in order.
    pub fn stream_frame(&mut self, frame: &[Vec<i32>]) -> Vec<Window> {
        let mut out = Vec::new();
        for row in frame {
            assert_eq!(row.len(), self.w, "row width mismatch");
            for &px in row {
                if let Some(w) = self.push(px) {
                    out.push(w);
                }
            }
        }
        out
    }
}

/// Naive reference: all stride-aligned KxK windows of a frame (VALID).
pub fn naive_windows(frame: &[Vec<i32>], k: usize, stride: usize) -> Vec<Window> {
    let h = frame.len();
    let w = frame[0].len();
    let mut out = Vec::new();
    for r in (0..=(h - k)).step_by(stride) {
        for c in (0..=(w - k)).step_by(stride) {
            let mut values = Vec::with_capacity(k * k);
            for dr in 0..k {
                for dc in 0..k {
                    values.push(frame[r + dr][c + dc]);
                }
            }
            out.push(Window { out_row: r / stride, out_col: c / stride, values });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn frame(h: usize, w: usize, seed: u64) -> Vec<Vec<i32>> {
        let mut rng = Rng::new(seed);
        (0..h)
            .map(|_| (0..w).map(|_| rng.range(-128, 127) as i32).collect())
            .collect()
    }

    #[test]
    fn matches_naive_3x3_stride1() {
        let f = frame(8, 10, 1);
        let got = LineBuffer::new(3, 10, 1).stream_frame(&f);
        let want = naive_windows(&f, 3, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn matches_naive_2x2_stride2() {
        let f = frame(6, 6, 2);
        let got = LineBuffer::new(2, 6, 2).stream_frame(&f);
        let want = naive_windows(&f, 2, 2);
        assert_eq!(got, want);
    }

    #[test]
    fn matches_naive_5x5_stride1() {
        let f = frame(9, 7, 3);
        let got = LineBuffer::new(5, 7, 1).stream_frame(&f);
        let want = naive_windows(&f, 5, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn window_count_formula() {
        let f = frame(12, 12, 4);
        let got = LineBuffer::new(3, 12, 2).stream_frame(&f);
        // floor((12-3)/2)+1 = 5 per axis
        assert_eq!(got.len(), 25);
    }

    #[test]
    fn property_random_geometries() {
        let mut rng = Rng::new(99);
        for _ in 0..30 {
            let k = rng.range(1, 4) as usize;
            let h = rng.range(k as i64, 12) as usize;
            let w = rng.range(k as i64, 12) as usize;
            let stride = rng.range(1, 3) as usize;
            let f = frame(h, w, rng.next_u64());
            let got = LineBuffer::new(k, w, stride).stream_frame(&f);
            let want = naive_windows(&f, k, stride);
            assert_eq!(got, want, "k={k} h={h} w={w} s={stride}");
        }
    }
}
