//! Cycle-level streaming simulator — the hardware measurement substitute.
//!
//! The paper's "Real" columns (Table III, Fig. 10) come from synthesized
//! bitstreams measured on a Zynq-7100. Offline, this module plays that
//! role: it executes the *same microarchitecture* the RTL emitter
//! generates — row-by-row streaming through line buffers, serialized
//! passes with drain/refill and weight-reload overheads, handshake
//! bubbles, frame-boundary clock-gating — at row/event granularity with
//! integer cycle accounting.
//!
//! Crucially it models second-order effects the analytical estimator
//! (Eqs. 4-13) deliberately omits (pass-switch drain, per-row handshake,
//! weight reload), so simulated latency is consistently a few percent to
//! tens of percent *above* the MOGA estimate — the same error direction
//! and magnitude the paper reports for estimate-vs-measurement.

pub mod linebuffer;
pub mod stream;

pub use stream::{simulate, simulate_with, GateError, GateMask, SimReport, StageStats};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::{self, DesignConfig};
    use crate::graph::zoo;
    use crate::pe::{FpRep, ZYNQ_7100};

    #[test]
    fn simulated_latency_at_least_estimate() {
        // Fig. 10's validation shape: real >= estimated, within ~35%
        let net = zoo::mnist();
        for p in [1, 2, 4, 8] {
            let cfg = DesignConfig::uniform(&net, p, FpRep::Int16);
            let est = design::evaluate(&net, &cfg, &ZYNQ_7100).unwrap();
            let sim = simulate(&net, &cfg, &ZYNQ_7100, &GateMask::all_active());
            let ratio = sim.latency_cycles as f64 / est.latency_cycles as f64;
            assert!(
                (1.0..1.6).contains(&ratio),
                "p={p}: sim/est ratio {ratio} (sim {} est {})",
                sim.latency_cycles,
                est.latency_cycles
            );
        }
    }

    #[test]
    fn gating_reduces_latency_and_power() {
        let net = zoo::mnist();
        let cfg = DesignConfig::uniform(&net, 4, FpRep::Int16);
        let full = simulate(&net, &cfg, &ZYNQ_7100, &GateMask::all_active());
        let gated = simulate(&net, &cfg, &ZYNQ_7100, &GateMask::depth_prefix(&net, 1));
        assert!(gated.latency_cycles < full.latency_cycles);
        assert!(gated.power_mw < full.power_mw);
    }
}
